//! Remark 1 / Remark 3: CodedPrivateML for **linear** regression.
//!
//! The worker computation becomes X̃ᵀ(X̃·w̃ − ỹ) — already a polynomial,
//! so no sigmoid approximation is needed and the identity "activation"
//! makes the gradient exactly unbiased. This example runs the coded
//! pipeline by hand (encoder → Linear-op cluster → decoder) on a planted
//! regression problem and compares against plaintext gradient descent.
//!
//! ```sh
//! cargo run --release --example linear_regression
//! ```

use codedml::cluster::{Cluster, WorkerOp, WorkerSpec};
use codedml::coding::{CodingParams, Decoder, Encoder, WorkerResult};
use codedml::field::{PrimeField, PAPER_PRIME};
use codedml::model::LinearRegression;
use codedml::quant::{phi, round_half_up, DatasetQuantizer, Dequantizer, WeightQuantizer};
use codedml::runtime::BackendKind;
use codedml::util::Rng;
use std::path::PathBuf;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let field = PrimeField::new(PAPER_PRIME);
    let (n, k, t) = (10usize, 3usize, 1usize);
    // Linear worker f = X̃ᵀ(X̃w̃ − ỹ) has degree 3 in the inputs — the
    // same recovery threshold as logistic at r=1.
    let params = CodingParams::new(n, k, t, 1)?;
    println!("private linear regression: N={n} K={k} T={t}, threshold {}", params.recovery_threshold());

    // Planted problem: y = X·w* with small integer-ish data.
    let mut rng = Rng::new(31);
    let (m, d) = (120usize, 8usize);
    let w_star: Vec<f64> = (0..d).map(|i| (i as f64 - 3.5) * 0.15).collect();
    let mut x = Vec::with_capacity(m * d);
    let mut y = Vec::with_capacity(m);
    for _ in 0..m {
        let row: Vec<f64> = (0..d).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        y.push(row.iter().zip(&w_star).map(|(a, b)| a * b).sum::<f64>());
        x.extend(row);
    }

    // Quantize. Labels share the dataset scale so X̄w̄ − ȳ... needs care:
    // X̄w̄ carries scale l_x + l_w ⇒ quantize y at l_y = l_x + l_w.
    let (lx, lw) = (4u32, 6u32);
    let xq = DatasetQuantizer::new(field, lx);
    let xbar = xq.quantize(&x);
    let ly = lx + lw;
    let ybar: Vec<u64> = y
        .iter()
        .map(|&v| phi(&field, round_half_up((1u64 << ly) as f64 * v)))
        .collect();

    // Encode X and y with the same Lagrange structure.
    let encoder = Encoder::new(field, params);
    let x_shares = encoder.encode_dataset(&xbar, m, d, &mut rng);
    let y_shares = encoder.encode_dataset(&ybar, m, 1, &mut rng);

    // Spawn Linear-op workers.
    let rows = m / k;
    let specs: Vec<WorkerSpec> = (0..n)
        .map(|id| WorkerSpec {
            id,
            kind: BackendKind::Native,
            artifact_dir: PathBuf::from("artifacts"),
            field,
            rows,
            d,
            coeffs: vec![0, 1], // unused by the Linear op
            op: WorkerOp::Linear,
            fail_from_iter: None,
            par: codedml::util::Parallelism::Serial,
        })
        .collect();
    let cluster = Cluster::spawn(specs)?;
    cluster.load_data(
        x_shares.into_iter().map(|s| s.data).collect(),
        Some(y_shares.into_iter().map(|s| s.data).collect()),
    )?;

    let mut decoder = Decoder::new(field, params, encoder.points.clone());
    let wquant = WeightQuantizer::new(field, lw, 1);
    // f = X̄ᵀ(X̄w̄ − ȳ) carries scale l_x + (l_x + l_w).
    let dequant = Dequantizer::new(field, lx, lw, 0, 1);

    let mut w = vec![0.0f64; d];
    let mut plain = LinearRegression::new(d);
    let eta = plain.lipschitz_lr(&x, m, d);
    println!("iter | private loss | plaintext loss");
    for iter in 0..30u64 {
        let wq = wquant.quantize(&w, &mut rng);
        let w_shares = encoder.encode_weights(&wq, d, 1, &mut rng);
        cluster.dispatch(iter, w_shares.into_iter().map(|s| s.data).collect())?;
        let results = cluster.collect_all(iter)?;
        let worker_results: Vec<WorkerResult> = results
            .into_iter()
            .take(params.recovery_threshold())
            .map(|r| WorkerResult { worker: r.worker, data: r.data.unwrap() })
            .collect();
        let blocks = decoder.decode(&worker_results, d)?;
        let mut grad = vec![0.0f64; d];
        for block in blocks {
            for (g, &q) in grad.iter_mut().zip(block.iter()) {
                *g += dequant.dequantize_entry(q);
            }
        }
        for (wi, gi) in w.iter_mut().zip(grad.iter()) {
            *wi -= eta / m as f64 * gi;
        }
        plain.step(&x, &y, m, d, eta);
        if iter % 5 == 0 {
            let private_loss = {
                let model = LinearRegression { w: w.clone() };
                model.loss(&x, &y, m, d)
            };
            println!(
                "{iter:>4} | {private_loss:>12.6} | {:>14.6}",
                plain.loss(&x, &y, m, d)
            );
        }
    }

    let err: f64 = w
        .iter()
        .zip(&w_star)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    println!("\n‖w_private − w*‖ = {err:.4} (plaintext {:.4})", {
        plain
            .w
            .iter()
            .zip(&w_star)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    });
    if err > 0.15 {
        return Err(format!("private linear regression did not converge: err {err}").into());
    }
    println!("linear regression OK: coded training recovers the planted model");
    Ok(())
}
