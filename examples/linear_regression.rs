//! Remark 1 / Remark 3: CodedPrivateML for **linear** regression.
//!
//! The worker computation becomes X̃ᵀ(X̃·w̃ − ỹ) — already a polynomial,
//! so no sigmoid approximation is needed and the identity "activation"
//! makes the gradient exactly unbiased. Since the `CodedObjective`
//! refactor this is a first-class session: `CodedMlSession::new_linear`
//! quantizes and secret-shares the labels, spawns Linear-op workers, and
//! the streaming round engine decodes the fastest R responses per round.
//!
//! ```sh
//! cargo run --release --example linear_regression
//! ```

use codedml::cluster::{NetworkModel, StragglerModel};
use codedml::coordinator::{CodedMlConfig, CodedMlSession};
use codedml::data::synthetic_planted_linear;
use codedml::model::LinearRegression;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Planted problem: y = X·w* with x ~ U[-1, 1].
    let (m, d) = (120usize, 8usize);
    let (train, w_star) = synthetic_planted_linear(m, d, 31);

    // CodedMlConfig::linear() carries the Remark-1 scale choices: labels
    // share the dataset scale chain (X̄w̄ carries l_x + l_w, so ȳ
    // quantizes at l_y = l_x + l_w) and the decode scale is
    // l_x + (l_x + l_w) — the logistic formula with l_c = 0, r = 1.
    let cfg = CodedMlConfig {
        n: 10,
        k: 3,
        t: 1,
        net: NetworkModel::free(),
        straggler: StragglerModel::none(),
        ..CodedMlConfig::linear()
    };
    let mut sess = CodedMlSession::new_linear(cfg, &train)?;
    println!(
        "private linear regression: N=10 K=3 T=1, threshold {}",
        sess.params().recovery_threshold()
    );

    // Plaintext twin for comparison.
    let mut plain = LinearRegression::new(d);
    let eta = sess.eta;
    println!("iter | private MSE | plaintext MSE");
    for iter in 0..30 {
        sess.step()?;
        plain.step(&train.x, &train.y, m, d, eta);
        if iter % 5 == 0 {
            println!(
                "{iter:>4} | {:>11.6} | {:>13.6}",
                sess.train_loss(),
                plain.loss(&train.x, &train.y, m, d)
            );
        }
    }

    let err = LinearRegression::with_weights(sess.w.clone()).distance_to(&w_star);
    let plain_err = plain.distance_to(&w_star);
    println!("\n‖w_private − w*‖ = {err:.4} (plaintext {plain_err:.4})");
    if err > 0.15 {
        return Err(format!("private linear regression did not converge: err {err}").into());
    }
    println!("linear regression OK: coded training recovers the planted model");
    Ok(())
}
