//! Figure-2 style sweep at example scale: total training time vs N for
//! the MPC baseline and CodedPrivateML Cases 1/2, plus the
//! privacy/parallelization trade-off table of Remark 2.
//!
//! ```sh
//! cargo run --release --example cluster_sweep -- [scale] [iters]
//! ```

use codedml::coding::CodingParams;
use codedml::reproduce::{run_cpml, run_mpc, ExpParams};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let scale: f64 = argv.first().map(|s| s.parse()).transpose()?.unwrap_or(0.02);
    let iters: usize = argv.get(1).map(|s| s.parse()).transpose()?.unwrap_or(10);

    let params = ExpParams { scale, d: 784, iters, ..Default::default() };
    println!("training-time sweep (m≈{:.0}, {iters} iters)", 12396.0 * scale);
    println!("|  N | MPC (s) | Case 1 (s) | Case 2 (s) | speedup C1 | K(C1) | T(C2) |");
    println!("|----|---------|------------|------------|------------|-------|-------|");
    for n in [5usize, 10, 25, 40] {
        let mpc = run_mpc(n, &params, false)?;
        let c1 = run_cpml(n, 1, &params, false)?;
        let c2 = run_cpml(n, 2, &params, false)?;
        let p1 = CodingParams::case1(n, 1)?;
        let p2 = CodingParams::case2(n, 1)?;
        println!(
            "| {n:>2} | {:>7.2} | {:>10.2} | {:>10.2} | {:>9.1}x | {:>5} | {:>5} |",
            mpc.total_s,
            c1.total_s,
            c2.total_s,
            mpc.total_s / c1.total_s,
            p1.k,
            p2.t
        );
    }
    println!("\nRemark 2 in action: every extra worker buys either parallelization");
    println!("(K, Case 1) or privacy (T, Case 2) — linearly in N.");
    Ok(())
}
