//! End-to-end driver (the repository's E2E validation): the paper's
//! Figure 3/4 experiment — private training on the 3-vs-7 task vs
//! conventional logistic regression, through **all three layers**: the
//! rust coordinator (L3) dispatches to workers running the AOT-compiled
//! JAX+Pallas worker kernel (L1/L2) via PJRT when artifacts exist, and
//! logs loss + accuracy per iteration. Recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example mnist_3v7
//! ```
//!
//! Set `MNIST_DIR=/path/to/idx/files` to use real MNIST; otherwise the
//! synthetic surrogate (same dims, same accuracy regime) is used.

use codedml::cluster::{NetworkModel, StragglerModel};
use codedml::coordinator::{CodedMlConfig, CodedMlSession};
use codedml::data::paper_dataset;
use codedml::model::LogisticRegression;
use codedml::runtime::BackendKind;
use std::path::PathBuf;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // m = 256 rows at d = 784 matches the worker_f_m128_d784_r1 artifact
    // for K=2 (128 rows/block) — so the hot path runs the Pallas kernel.
    let (train, test) = paper_dataset(256, 128, 11);

    let artifacts = PathBuf::from("artifacts");
    let have_artifacts = artifacts.join("manifest.json").exists();
    let backend = if have_artifacts { BackendKind::Xla } else { BackendKind::Native };

    let cfg = CodedMlConfig {
        n: 7,
        k: 2,
        t: 1,
        r: 1,
        backend,
        artifact_dir: artifacts,
        straggler: StragglerModel::default(),
        net: NetworkModel::default(),
        ..Default::default()
    };
    println!("=== CodedPrivateML 3-vs-7 (backend {:?}) ===", cfg.backend);

    let mut session = CodedMlSession::new(cfg, &train)?;
    let report = session.train(25, Some(&test))?;

    // Conventional logistic regression baseline (real sigmoid, floats).
    let mut plain = LogisticRegression::new(train.d);
    let eta = plain.lipschitz_lr(&train);
    println!("\niter |  CPML loss | CPML acc || plain loss | plain acc");
    for (i, it) in report.iterations.iter().enumerate() {
        plain.step(&train, eta);
        println!(
            "{:>4} | {:>10.5} | {:>8.4} || {:>10.5} | {:>9.4}",
            i,
            it.train_loss,
            it.test_accuracy.unwrap(),
            plain.loss(&train),
            plain.accuracy(&test)
        );
    }

    let cpml = 100.0 * report.final_accuracy().unwrap();
    let conv = 100.0 * plain.accuracy(&test);
    println!("\nfinal test accuracy: CodedPrivateML {cpml:.2}%  vs  conventional {conv:.2}%");
    println!("(paper Figure 3: 95.04% vs 95.98% at 25 iterations)");
    println!("\n| Protocol                 |  Encode  |   Comm.  |   Comp.  | Total run |");
    println!("{}", report.breakdown.row("CodedPrivateML"));
    println!(
        "decode cache {}h/{}m; recovery threshold {} of {}",
        report.decode_cache.0, report.decode_cache.1, report.recovery_threshold, 7
    );

    if (cpml - conv).abs() > 5.0 {
        return Err(format!("accuracy gap too large: {cpml:.2}% vs {conv:.2}%").into());
    }
    println!("E2E OK: private training tracks conventional LR through all three layers");
    Ok(())
}
