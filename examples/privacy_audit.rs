//! Privacy audit: demonstrates, on live encodings, the two halves of
//! Theorem 1's privacy claim —
//!
//!  * what T colluding workers see is statistically independent of the
//!    dataset (empirical histogram + MDS invertibility of the mask
//!    sub-matrix), and
//!  * the threshold is *sharp*: K+T shares reconstruct the data exactly.
//!
//! ```sh
//! cargo run --release --example privacy_audit
//! ```

use codedml::coding::{CodingParams, Encoder};
use codedml::field::{eval_poly, interpolate, PrimeField, PAPER_PRIME};
use codedml::util::Rng;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let field = PrimeField::new(PAPER_PRIME);
    let (n, k, t) = (10usize, 2usize, 2usize);
    let params = CodingParams::new(n, k, t, 1)?;
    let enc = Encoder::new(field, params);
    let mut rng = Rng::new(2718);

    println!("=== CodedPrivateML privacy audit (N={n}, K={k}, T={t}) ===\n");

    // 1. Collusion view histogram: encode a hospital-like dataset and an
    //    all-zeros dataset; a T-collusion's view is uniform either way.
    let (m, d) = (4usize, 8usize);
    let secret: Vec<u64> = (0..m * d).map(|i| (i as u64 * 37 + 11) % field.modulus()).collect();
    let zeros = vec![0u64; m * d];
    let buckets = 10;
    let trials = 3000;
    let mut h_secret = vec![0usize; buckets];
    let mut h_zero = vec![0usize; buckets];
    for _ in 0..trials {
        let ss = enc.encode_dataset(&secret, m, d, &mut rng);
        let sz = enc.encode_dataset(&zeros, m, d, &mut rng);
        let b = |v: u64| (v as u128 * buckets as u128 / field.modulus() as u128) as usize;
        h_secret[b(ss[0].data[0])] += 1;
        h_zero[b(sz[0].data[0])] += 1;
    }
    println!("collusion-view histogram of one coded entry ({trials} fresh encodings):");
    println!("bucket |   real data |  all-zero data  (both ≈ uniform {})", trials / buckets);
    let mut max_dev: f64 = 0.0;
    for b in 0..buckets {
        println!("{b:>6} | {:>11} | {:>14}", h_secret[b], h_zero[b]);
        let e = trials as f64 / buckets as f64;
        max_dev = max_dev.max(((h_secret[b] as f64 - e) / e).abs());
        max_dev = max_dev.max(((h_zero[b] as f64 - e) / e).abs());
    }
    println!("max relative deviation from uniform: {:.1}%  (expected ~±{:.0}%)\n",
        100.0 * max_dev, 300.0 / (trials as f64 / buckets as f64).sqrt());

    // 2. Sharpness: K+T shares reconstruct the dataset exactly.
    let shares = enc.encode_dataset(&secret, m, d, &mut rng);
    let pts: Vec<u64> = enc.points.alphas[..k + t].to_vec();
    let vals: Vec<u64> = shares[..k + t].iter().map(|s| s.data[0]).collect();
    let coeffs = interpolate(&field, &pts, &vals)?;
    let recovered = eval_poly(&field, &coeffs, enc.points.betas[0]);
    println!("negative control: {} shares (K+T) interpolate u(z) and recover", k + t);
    println!("  entry X̄[0,0] = {} → recovered {} ({})",
        secret[0], recovered, if recovered == secret[0] { "EXACT" } else { "mismatch!" });
    assert_eq!(recovered, secret[0]);

    // 3. The paper's trade-off table (Remark 2 / §5 discussion).
    println!("\nprivacy vs parallelization at r=1 (Theorem 1: N ≥ 3(K+T-1)+1):");
    println!("|  N | Case 1 (K, T) | Case 2 (K, T) | MPC T=(N-1)/2 |");
    for n in [10usize, 16, 25, 40] {
        let c1 = CodingParams::case1(n, 1)?;
        let c2 = CodingParams::case2(n, 1)?;
        println!(
            "| {n:>2} | ({:>2}, {:>2})      | ({:>2}, {:>2})      | {:>13} |",
            c1.k, c1.t, c2.k, c2.t, (n - 1) / 2
        );
    }
    println!("\naudit OK: T-views uniform, K+T-views decodable, thresholds as in Theorem 1");
    Ok(())
}
