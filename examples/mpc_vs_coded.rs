//! Head-to-head: CodedPrivateML vs the BGW MPC baseline on the same task,
//! same quantization, same polynomial — the paper's §5 comparison distilled
//! to one run with the full cost anatomy (storage per worker, bytes on the
//! wire, resharing rounds, timing breakdown).
//!
//! ```sh
//! cargo run --release --example mpc_vs_coded -- [n] [m] [iters]
//! ```

use codedml::cluster::{NetworkModel, StragglerModel};
use codedml::coordinator::{CodedMlConfig, CodedMlSession};
use codedml::data::paper_dataset;
use codedml::mpc::{BgwConfig, BgwGradientProtocol};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = argv.first().map(|s| s.parse()).transpose()?.unwrap_or(10);
    let m: usize = argv.get(1).map(|s| s.parse()).transpose()?.unwrap_or(600);
    let iters: usize = argv.get(2).map(|s| s.parse()).transpose()?.unwrap_or(25);

    let (train, test) = paper_dataset(m, (m / 6).max(30), 5);
    println!("=== CodedPrivateML vs BGW MPC (N={n}, m={}, d={}, {iters} iters) ===\n", train.m, train.d);

    // --- CodedPrivateML, Case 1 ------------------------------------------
    let cfg = CodedMlConfig::case1(n, 1)?;
    let k = cfg.k;
    let mut sess = CodedMlSession::new(cfg, &train)?;
    let cpml = sess.train(iters, Some(&test))?;

    // --- BGW baseline at its natural maximum privacy ----------------------
    let bgw_cfg = BgwConfig {
        n,
        t: ((n - 1) / 2).max(1),
        net: NetworkModel::default(),
        straggler: StragglerModel::default(),
        ..Default::default()
    };
    let bgw_t = bgw_cfg.t;
    let mut proto = BgwGradientProtocol::new(bgw_cfg, &train)?;
    let mpc = proto.train(iters, Some(&test));

    // --- Anatomy -----------------------------------------------------------
    println!("| Protocol                 |  Encode  |   Comm.  |   Comp.  | Total run |");
    println!("|--------------------------|----------|----------|----------|-----------|");
    println!("{}", mpc.breakdown.row("MPC approach"));
    println!("{}", cpml.breakdown.row("CodedPrivateML (Case 1)"));
    println!();
    println!("speedup: {:.1}x (paper at N=40, d=1568: 34.1x)", mpc.breakdown.total() / cpml.breakdown.total());
    println!();
    println!("cost anatomy:");
    println!("  storage per worker  : MPC = full m×d; CPML = m/K×d (K={k}) → {k}x smaller");
    println!(
        "  privacy threshold   : MPC T={bgw_t} vs CPML T=1 (Case 1) — MPC's edge, the paper's stated trade-off"
    );
    println!(
        "  resharing rounds    : MPC {} (one per mult level per iter); CPML 0 — decode is one-shot interpolation",
        proto.protocol_report().resharing_rounds
    );
    println!(
        "  worker↔worker bytes : MPC {}; CPML 0",
        proto.protocol_report().bytes_worker_to_worker
    );
    println!(
        "  accuracy            : MPC {:.2}%  CPML {:.2}% — same learning algorithm",
        100.0 * mpc.final_accuracy().unwrap_or(f64::NAN),
        100.0 * cpml.final_accuracy().unwrap_or(f64::NAN)
    );
    Ok(())
}
