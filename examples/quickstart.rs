//! Quickstart: train a logistic-regression model privately on a synthetic
//! 3-vs-7 task with 10 workers, tolerating stragglers, and print the
//! paper-style timing breakdown.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use codedml::coordinator::{CodedMlConfig, CodedMlSession};
use codedml::data::paper_dataset;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 600 training samples, 300 test samples, 28×28 features.
    let (train, test) = paper_dataset(600, 300, 7);

    // N=10 workers, dataset split K=3 ways, privacy threshold T=1,
    // degree-1 sigmoid approximation — recovery threshold 3·3+1 = 10.
    let cfg = CodedMlConfig { n: 10, k: 3, t: 1, r: 1, ..Default::default() };
    println!(
        "CodedPrivateML quickstart: N={} K={} T={} (any {} colluding workers learn nothing)",
        cfg.n, cfg.k, cfg.t, cfg.t
    );

    let mut session = CodedMlSession::new(cfg, &train)?;
    println!(
        "recovery threshold: {} of {} workers",
        session.params().recovery_threshold(),
        session.params().n
    );

    let report = session.train(25, Some(&test))?;

    for it in report.iterations.iter().step_by(5) {
        println!(
            "iter {:>2}: loss {:.4}, test accuracy {:.2}%",
            it.iter,
            it.train_loss,
            100.0 * it.test_accuracy.unwrap()
        );
    }
    println!(
        "final accuracy: {:.2}% (paper's regime: ~95%)",
        100.0 * report.final_accuracy().unwrap()
    );
    println!("\n| Protocol                 |  Encode  |   Comm.  |   Comp.  | Total run |");
    println!("{}", report.breakdown.row("CodedPrivateML"));
    Ok(())
}
