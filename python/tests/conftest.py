"""Test bootstrap: make the `compile` package importable without an
install step.

The python layer is deliberately not packaged (no setup.py/pyproject —
it is an AOT compile-time tool, not a deployed library), so the tests
add `python/` to sys.path themselves. Run from anywhere:

    python -m pytest python/tests -q
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
