"""AOT pipeline tests: HLO-text emission, manifest integrity, and the
no-op rebuild contract `make artifacts` relies on."""

import json
import os
import tempfile

import jax

jax.config.update("jax_enable_x64", True)

from compile import aot, shapes


def test_lower_worker_emits_parsable_hlo_text():
    text = aot.lower_worker(32, 16, 1, shapes.PAPER_PRIME)
    # HLO text, not proto bytes.
    assert text.startswith("HloModule")
    # int64 end to end, correct result arity (tuple of one s64[d]).
    assert "s64[32,16]" in text
    assert "s64[16]" in text
    # No TPU Mosaic custom-calls (would be unrunnable on CPU PJRT).
    assert "custom-call" not in text.lower()


def test_lower_lr_step_is_f64_two_tuple():
    text = aot.lower_lr_step(64, 8)
    assert text.startswith("HloModule")
    assert "f64[64,8]" in text
    assert "(f64[8]" in text  # tuple(w', loss)


def test_write_if_changed_is_idempotent():
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "x.txt")
        assert aot.write_if_changed(p, "hello") is True
        before = os.stat(p).st_mtime_ns
        assert aot.write_if_changed(p, "hello") is False
        assert os.stat(p).st_mtime_ns == before
        assert aot.write_if_changed(p, "world") is True


def test_shape_matrix_covers_e2e_driver():
    """The shapes used by examples/mnist_3v7.rs (K=2 over m=256 at d=784)
    and the quickstart tests must stay in the artifact matrix."""
    combos = {(s["rows"], s["d"], s["r"]) for s in shapes.WORKER_SHAPES}
    assert (128, 784, 1) in combos
    assert (32, 64, 1) in combos
    # r=2 coverage for the ablation.
    assert any(r == 2 for (_, _, r) in combos)


def test_manifest_written_and_loadable():
    with tempfile.TemporaryDirectory() as d:
        import sys

        argv = sys.argv
        sys.argv = ["aot", "--out-dir", d]
        try:
            aot.main()
        finally:
            sys.argv = argv
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["prime"] == shapes.PAPER_PRIME
        names = {e["name"] for e in manifest["artifacts"]}
        assert shapes.worker_name(32, 64, 1) in names
        for e in manifest["artifacts"]:
            assert os.path.exists(os.path.join(d, e["file"]))


def test_block_rows_divides_all_worker_shapes():
    for s in shapes.WORKER_SHAPES:
        br = shapes.cpu_block_rows(s["rows"])
        assert s["rows"] % br == 0, s
        assert s["rows"] % shapes.BLOCK_ROWS == 0, s
