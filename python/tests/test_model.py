"""L2 graph tests: shapes, semantics, and the lr_step training loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Optional in this offline image (see test_kernel.py).
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile import model
from compile.kernels.ref import lr_step_ref, worker_f_ref
from compile.shapes import PAPER_PRIME


def test_worker_step_is_tuple_of_d_vector():
    p = PAPER_PRIME
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, p, (64, 32), dtype=np.int64))
    w = jnp.asarray(rng.integers(0, p, (32, 1), dtype=np.int64))
    c = jnp.asarray(rng.integers(0, p, (2,), dtype=np.int64))
    (out,) = model.worker_step(x, w, c, p=p, block_rows=32)
    assert out.shape == (32,)
    assert out.dtype == jnp.int64
    np.testing.assert_array_equal(np.asarray(out), np.asarray(worker_f_ref(x, w, c, p)))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_lr_step_matches_ref(seed):
    rng = np.random.default_rng(seed)
    m, d = 32, 8
    x = jnp.asarray(rng.normal(size=(m, d)))
    y = jnp.asarray((rng.random(m) > 0.5).astype(np.float64))
    w = jnp.asarray(rng.normal(size=d) * 0.1)
    eta = 0.3
    w2, loss = model.lr_step(x, y, w, eta)
    w_ref = lr_step_ref(x, y, w, eta)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(w_ref), rtol=1e-12)
    assert loss.shape == ()
    assert float(loss) > 0.0


def test_lr_step_training_converges():
    """Gradient descent through the L2 graph drives the loss down on a
    separable problem (the same sanity the rust oracle enforces)."""
    rng = np.random.default_rng(3)
    m, d = 128, 4
    w_true = np.array([2.0, -1.0, 0.5, 1.5])
    x = rng.normal(size=(m, d))
    y = (x @ w_true > 0).astype(np.float64)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    w = jnp.zeros(d)
    step = jax.jit(model.lr_step)
    losses = []
    for _ in range(60):
        w, loss = step(xj, yj, w, 1.0)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.3, losses[::10]
    # Signs of the learned weights match the planted model.
    assert np.all(np.sign(np.asarray(w)) == np.sign(w_true))


def test_worker_step_jit_and_eager_agree():
    p = PAPER_PRIME
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.integers(0, p, (32, 16), dtype=np.int64))
    w = jnp.asarray(rng.integers(0, p, (16, 2), dtype=np.int64))
    c = jnp.asarray(rng.integers(0, p, (3,), dtype=np.int64))
    import functools
    fn = functools.partial(model.worker_step, p=p, block_rows=32)
    (eager,) = fn(x, w, c)
    (jitted,) = jax.jit(fn)(x, w, c)
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(jitted))
