"""Cross-layer semantic checks: the finite-field conventions the python
kernel and the rust coordinator must share (two's-complement embedding,
scale bookkeeping, coefficient quantization). These mirror the rust unit
tests in rust/src/quant — if either side changes, one of the two suites
breaks."""

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from compile.kernels.ref import worker_f_ref
from compile.shapes import PAPER_PRIME


def phi(x, p):
    return x % p


def phi_inv(x, p):
    x = np.asarray(x, dtype=np.int64)
    return np.where(x <= (p - 1) // 2, x, x - p)


def test_phi_roundtrip_matches_rust_convention():
    p = PAPER_PRIME
    vals = np.array([-(p - 1) // 2, -1000, -1, 0, 1, 1000, (p - 1) // 2])
    assert np.all(phi_inv(phi(vals, p), p) == vals)


def test_worker_f_of_negative_embeddings():
    """Signed semantics survive the field round trip: computing on
    φ(negative) values and mapping back equals the integer computation —
    the property the whole quantization scheme rests on."""
    p = PAPER_PRIME
    rng = np.random.default_rng(7)
    rows, d, r = 32, 8, 1
    xs = rng.integers(-5, 6, size=(rows, d)).astype(np.int64)
    ws = rng.integers(-5, 6, size=(d, r)).astype(np.int64)
    cs = rng.integers(-5, 6, size=(r + 1,)).astype(np.int64)

    x = jnp.asarray(phi(xs, p))
    w = jnp.asarray(phi(ws, p))
    c = jnp.asarray(phi(cs, p))
    got = phi_inv(np.asarray(worker_f_ref(x, w, c, p)), p)

    # Integer reference with python bignums.
    g = cs[0] + cs[1] * (xs @ ws[:, 0])
    want = xs.T @ g
    assert np.all(got == want)


def test_scale_bookkeeping_degree1():
    """l = l_c + l_x + r(l_x + l_w): quantize a real computation, run in
    the field, dequantize, compare against the float result."""
    p = PAPER_PRIME
    lx, lw, lc, r = 2, 4, 3, 1
    rng = np.random.default_rng(11)
    rows, d = 32, 6
    xr = rng.random((rows, d))  # [0, 1) like normalized pixels
    wr = rng.normal(size=(d, 1)) * 0.2
    c0, c1 = 0.5, 0.15

    xq = np.round(xr * 2**lx).astype(np.int64)
    wq = np.round(wr * 2**lw).astype(np.int64)  # deterministic stand-in
    cq = np.array(
        [round(c0 * 2 ** (lc + (lx + lw))), round(c1 * 2**lc)], dtype=np.int64
    )

    out = worker_f_ref(
        jnp.asarray(phi(xq, p)), jnp.asarray(phi(wq, p)), jnp.asarray(phi(cq, p)), p
    )
    scale = 2 ** (lc + lx + r * (lx + lw))
    got = phi_inv(np.asarray(out), p) / scale

    g = c0 + c1 * (xr @ wr[:, 0])
    want = xr.T @ g
    # Error budget: quantization of x (2^-lx-1), w (2^-lw-1), c (2^-lc-1)
    # propagated through the bilinear form — generous bound.
    np.testing.assert_allclose(got, want, atol=rows * 0.2)
