"""L1 correctness: Pallas kernel vs pure-jnp oracle.

Hypothesis sweeps shapes, degrees, block sizes, and primes; exact equality
is required — this is finite-field arithmetic, not floats.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Optional in this offline image; the deterministic tests elsewhere still
# cover the kernel when hypothesis is absent.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels.coded_gradient import modmatmul_pallas, worker_f_pallas
from compile.kernels.ref import g_bar_ref, worker_f_ref
from compile.shapes import PAPER_PRIME

PRIMES = [97, 15485863, 67108859]  # toy, paper 24-bit, max 26-bit


def rand_field(rng, shape, p):
    return jnp.asarray(rng.integers(0, p, size=shape, dtype=np.int64))


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(1, 4),
    block_rows=st.sampled_from([8, 16, 32]),
    d=st.integers(1, 96),
    r=st.integers(1, 3),
    p=st.sampled_from(PRIMES),
    seed=st.integers(0, 2**31 - 1),
)
def test_worker_f_matches_ref(blocks, block_rows, d, r, p, seed):
    rng = np.random.default_rng(seed)
    rows = blocks * block_rows
    x = rand_field(rng, (rows, d), p)
    w = rand_field(rng, (d, r), p)
    c = rand_field(rng, (r + 1,), p)
    got = worker_f_pallas(x, w, c, p=p, block_rows=block_rows)
    want = worker_f_ref(x, w, c, p)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    m_blocks=st.integers(1, 3),
    k=st.integers(1, 64),
    n=st.integers(1, 8),
    p=st.sampled_from(PRIMES),
    seed=st.integers(0, 2**31 - 1),
)
def test_modmatmul_matches_numpy(m_blocks, k, n, p, seed):
    rng = np.random.default_rng(seed)
    m = 32 * m_blocks
    a = rand_field(rng, (m, k), p)
    b = rand_field(rng, (k, n), p)
    got = modmatmul_pallas(a, b, p=p)
    want = (np.asarray(a, dtype=object) @ np.asarray(b, dtype=object)) % p
    np.testing.assert_array_equal(np.asarray(got), want.astype(np.int64))


def test_worker_f_paper_scale_shape():
    """One paper-scale shape (m/K=256, d=1568, r=1) — exact vs ref."""
    rng = np.random.default_rng(0)
    p = PAPER_PRIME
    x = rand_field(rng, (256, 1568), p)
    w = rand_field(rng, (1568, 1), p)
    c = rand_field(rng, (2,), p)
    got = worker_f_pallas(x, w, c, p=p)
    want = worker_f_ref(x, w, c, p)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_g_bar_polynomial_semantics():
    """ḡ with c = [c0, c1] equals c0 + c1·(x @ w) elementwise (mod p)."""
    rng = np.random.default_rng(3)
    p = 97
    x = rand_field(rng, (8, 5), p)
    w = rand_field(rng, (5, 1), p)
    c = jnp.asarray([7, 11], dtype=jnp.int64)
    got = g_bar_ref(x, w, c, p)
    want = (7 + 11 * ((np.asarray(x) @ np.asarray(w)[:, 0]) % p)) % p
    np.testing.assert_array_equal(np.asarray(got), want)


def test_block_rows_must_divide():
    x = jnp.zeros((33, 4), dtype=jnp.int64)
    w = jnp.zeros((4, 1), dtype=jnp.int64)
    c = jnp.zeros((2,), dtype=jnp.int64)
    with pytest.raises(AssertionError):
        worker_f_pallas(x, w, c, p=97, block_rows=32)


def test_prime_bound_enforced():
    x = jnp.zeros((32, 4), dtype=jnp.int64)
    w = jnp.zeros((4, 1), dtype=jnp.int64)
    c = jnp.zeros((2,), dtype=jnp.int64)
    with pytest.raises(AssertionError):
        worker_f_pallas(x, w, c, p=(1 << 27) - 39, block_rows=32)


def test_deferred_reduction_extreme_values():
    """All entries at p-1 — the worst case for the overflow discipline."""
    p = 67108859  # 26-bit: tightest margins
    rows, d, r = 64, 96, 3
    x = jnp.full((rows, d), p - 1, dtype=jnp.int64)
    w = jnp.full((d, r), p - 1, dtype=jnp.int64)
    c = jnp.full((r + 1,), p - 1, dtype=jnp.int64)
    got = worker_f_pallas(x, w, c, p=p, block_rows=32)
    want = worker_f_ref(x, w, c, p)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.all(np.asarray(got) >= 0) and np.all(np.asarray(got) < p)
