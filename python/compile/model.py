"""Layer-2: the JAX compute graphs that get AOT-lowered for the rust runtime.

Two graphs:

* `worker_step` — the CodedPrivateML worker computation over F_p, calling
  the L1 Pallas kernel. This is what every worker executes each training
  iteration (paper eq. 20).
* `lr_step` — a plaintext f64 logistic-regression gradient step (paper
  eq. 3), used by the conventional-LR baseline of Figures 3–4 so the
  baseline also exercises the JAX→PJRT path.

Both are pure functions of arrays, lowered with static shapes by aot.py.
"""

import jax
import jax.numpy as jnp

from .kernels.coded_gradient import worker_f_pallas
from .shapes import BLOCK_ROWS


def worker_step(x, w, coeffs, *, p, block_rows=BLOCK_ROWS):
    """f(X̃, W̃) ∈ F_p^d — wraps the Pallas kernel (tuple-returning for AOT)."""
    return (worker_f_pallas(x, w, coeffs, p=p, block_rows=block_rows),)


def lr_step(x, y, w, eta):
    """One full-batch GD step of logistic regression; returns (w', loss).

    The loss output lets the rust baseline log Figure-4 curves from the
    same executable without a second artifact.
    """
    z = x @ w
    pred = jax.nn.sigmoid(z)
    eps = 1e-12
    loss = -jnp.mean(y * jnp.log(pred + eps) + (1.0 - y) * jnp.log(1.0 - pred + eps))
    grad = x.T @ (pred - y) / x.shape[0]
    return (w - eta * grad, loss)
