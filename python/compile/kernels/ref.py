"""Pure-jnp correctness oracle for the worker computation (paper eq. 17, 20).

This is the ground truth the Pallas kernel is tested against (pytest +
hypothesis, python/tests/test_kernel.py), and it mirrors — operation for
operation — the rust native backend in rust/src/compute/, which is itself
property-tested against a big-integer reference. Between the three
implementations every pair is checked somewhere.
"""

import jax.numpy as jnp


def g_bar_ref(x, w, coeffs, p):
    """ḡ(X̃, W̃) = Σ_i c̄_i Π_{j≤i}(X̃ w̃_j) over F_p — int64[rows].

    Overflow discipline: products of reduced elements are < p² ≤ 2^52
    (p ≤ 26 bits) and dot-accumulations over ≤ 2^11 terms stay < 2^63, so
    a single mod after each contraction is exact.
    """
    r = w.shape[1]
    g = jnp.full((x.shape[0],), coeffs[0], dtype=jnp.int64)
    prod = jnp.ones((x.shape[0],), dtype=jnp.int64)
    for j in range(r):
        u_j = (x @ w[:, j]) % p
        prod = (prod * u_j) % p
        g = (g + coeffs[j + 1] * prod) % p
    return g


def worker_f_ref(x, w, coeffs, p):
    """f(X̃, W̃) = X̃ᵀ ḡ(X̃, W̃) over F_p.

    Args:
      x: int64[rows, d]  coded data block, entries in [0, p)
      w: int64[d, r]     coded weight quantizations, entries in [0, p)
      coeffs: int64[r+1] field-quantized sigmoid-polynomial coefficients
      p: python int prime (static)

    Returns:
      int64[d] in [0, p).
    """
    g = g_bar_ref(x, w, coeffs, p)
    return (x.T @ g) % p


def lr_step_ref(x, y, w, eta):
    """One plaintext logistic-regression GD step (paper eq. 3), f64."""
    z = x @ w
    pred = 1.0 / (1.0 + jnp.exp(-z))
    grad = x.T @ (pred - y) / x.shape[0]
    return w - eta * grad
