"""Pallas kernel for the worker computation f(X̃, W̃) = X̃ᵀ ḡ(X̃, W̃) over F_p.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's workers
are CPUs, so the kernel design question is how finite-field GEMM maps onto
a TPU-shaped memory hierarchy. We tile X̃ into (BLOCK_ROWS × d) VMEM blocks
via BlockSpec; the weight panel W̃ (d × r, a few KiB) and the output
accumulator (d,) stay resident across the grid. Each grid step

  1. computes the r row-dots u_j = x_blk @ w_j          (int64 MACs)
  2. evaluates the degree-r polynomial ḡ elementwise     (VPU)
  3. accumulates x_blkᵀ ḡ into the output, mod p once    (int64 MACs)

Modular arithmetic is integer, so the MXU (bf16 systolic array) is not
usable — the schedule targets the VPU with lane-aligned blocks, and the
deferred-reduction discipline (one `% p` per contraction, legal because
p ≤ 26 bits keeps partial sums < 2^63) minimizes the expensive modulo ops.

interpret=True always: CPU PJRT cannot execute Mosaic custom-calls; the
interpret path lowers to plain HLO the rust runtime can run. VMEM estimate
for the default BLOCK_ROWS=32, d=1568, r=2: (32·1568 + 1568·2 + 1568 + 32)
int64 ≈ 430 KiB ≪ 16 MiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, c_ref, o_ref, *, p, r):
    """One grid step over a block of rows."""
    blk = x_ref[...]  # (bm, d) int64

    # ḡ over this block: g = c_0 + Σ_i c_i Π_{j≤i} (x_blk @ w_j)
    g = jnp.full((blk.shape[0],), c_ref[0], dtype=jnp.int64)
    prod = jnp.ones((blk.shape[0],), dtype=jnp.int64)
    for j in range(r):
        u_j = (blk @ w_ref[:, j]) % p
        prod = (prod * u_j) % p
        g = (g + c_ref[j + 1] * prod) % p

    # Accumulate the block's contribution to X̃ᵀ ḡ. All grid steps map to
    # the same output block; initialize it on the first step.
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    partial = (blk.T @ g) % p
    o_ref[...] = (o_ref[...] + partial) % p


def worker_f_pallas(x, w, coeffs, *, p, block_rows=32):
    """Tiled Pallas evaluation of f(X̃, W̃). Shapes as in ref.worker_f_ref.

    `block_rows` must divide rows; `p` must fit in 26 bits so deferred
    reduction is exact (checked).
    """
    rows, d = x.shape
    r = w.shape[1]
    assert rows % block_rows == 0, f"rows={rows} not a multiple of {block_rows}"
    assert p < (1 << 26), "deferred-reduction discipline needs p < 2^26"
    assert coeffs.shape == (r + 1,)

    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_kernel, p=p, r=r),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),  # stream X̃ blocks
            pl.BlockSpec((d, r), lambda i: (0, 0)),           # W̃ resident
            pl.BlockSpec((r + 1,), lambda i: (0,)),           # coefficients
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),          # accumulator
        out_shape=jax.ShapeDtypeStruct((d,), jnp.int64),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w, coeffs)


def modmatmul_pallas(a, b, *, p, block_rows=32):
    """Tiled modular matmul (A @ B) % p — the reusable L1 building block.

    a: int64[m, k], b: int64[k, n], entries in [0, p); returns int64[m, n].
    Used by tests and available for alternative L2 graphs (e.g. the linear-
    regression variant, whose worker computation is a pure modmatmul chain).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    assert m % block_rows == 0, f"m={m} not a multiple of {block_rows}"
    assert p < (1 << 26)

    def kernel(a_ref, b_ref, o_ref):
        o_ref[...] = (a_ref[...] @ b_ref[...]) % p

    return pl.pallas_call(
        kernel,
        grid=(m // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int64),
        interpret=True,
    )(a, b)
