"""Layer-1 kernels: the modular-arithmetic hot spot as Pallas, plus the
pure-jnp reference oracle used by the build-time test suite."""
