"""AOT lowering: JAX graphs → HLO *text* artifacts + JSON manifest.

HLO text (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` 0.1.6 crate) rejects; the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example.

Usage: python -m compile.aot --out-dir ../artifacts
Skips unchanged artifacts (content-compare) so `make artifacts` is a no-op
when inputs haven't changed.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, shapes


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_worker(rows: int, d: int, r: int, p: int) -> str:
    fn = functools.partial(
        model.worker_step, p=p, block_rows=shapes.cpu_block_rows(rows)
    )
    x = jax.ShapeDtypeStruct((rows, d), jnp.int64)
    w = jax.ShapeDtypeStruct((d, r), jnp.int64)
    c = jax.ShapeDtypeStruct((r + 1,), jnp.int64)
    return to_hlo_text(jax.jit(fn).lower(x, w, c))


def lower_lr_step(m: int, d: int) -> str:
    x = jax.ShapeDtypeStruct((m, d), jnp.float64)
    y = jax.ShapeDtypeStruct((m,), jnp.float64)
    w = jax.ShapeDtypeStruct((d,), jnp.float64)
    eta = jax.ShapeDtypeStruct((), jnp.float64)
    return to_hlo_text(jax.jit(model.lr_step).lower(x, y, w, eta))


def write_if_changed(path: str, text: str) -> bool:
    if os.path.exists(path):
        with open(path) as f:
            if f.read() == text:
                return False
    with open(path, "w") as f:
        f.write(text)
    return True


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--prime", type=int, default=shapes.PAPER_PRIME)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    entries = []
    wrote = 0
    for s in shapes.WORKER_SHAPES:
        name = shapes.worker_name(s["rows"], s["d"], s["r"])
        fname = f"{name}.hlo.txt"
        text = lower_worker(s["rows"], s["d"], s["r"], args.prime)
        wrote += write_if_changed(os.path.join(args.out_dir, fname), text)
        entries.append(
            dict(
                kind="worker_f",
                name=name,
                file=fname,
                rows=s["rows"],
                d=s["d"],
                r=s["r"],
                p=args.prime,
                block_rows=shapes.BLOCK_ROWS,
            )
        )
        print(f"  worker_f rows={s['rows']} d={s['d']} r={s['r']} -> {fname}")

    for s in shapes.LR_STEP_SHAPES:
        name = shapes.lr_step_name(s["m"], s["d"])
        fname = f"{name}.hlo.txt"
        text = lower_lr_step(s["m"], s["d"])
        wrote += write_if_changed(os.path.join(args.out_dir, fname), text)
        entries.append(dict(kind="lr_step", name=name, file=fname, m=s["m"], d=s["d"]))
        print(f"  lr_step m={s['m']} d={s['d']} -> {fname}")

    manifest = dict(version=1, prime=args.prime, artifacts=entries)
    write_if_changed(
        os.path.join(args.out_dir, "manifest.json"), json.dumps(manifest, indent=1)
    )
    print(f"wrote {wrote} changed artifact(s), manifest lists {len(entries)}")


if __name__ == "__main__":
    main()
