"""The artifact shape matrix.

XLA executables have static shapes, so the AOT step emits one artifact per
(rows, d, r) the examples and benches use; any other shape falls back to
the rust native backend (bit-exact, see rust/src/compute/). Keep this list
small — each entry costs a compile at `make artifacts` and a PJRT compile
at first use.
"""

# Paper default field prime (24-bit). Must match rust::field::PAPER_PRIME.
PAPER_PRIME = 15_485_863

# Worker-computation artifacts: f(X̃, W̃) = X̃ᵀ ḡ(X̃, W̃) over F_p.
# rows = coded block height m/K; d = features; r = sigmoid degree.
WORKER_SHAPES = [
    # quickstart / integration-test scale
    dict(rows=32, d=64, r=1),
    dict(rows=64, d=784, r=1),
    dict(rows=128, d=784, r=1),
    dict(rows=64, d=784, r=2),
    # e2e / benchmark scale
    dict(rows=256, d=784, r=1),
    dict(rows=256, d=1568, r=1),
    dict(rows=1024, d=784, r=1),
]

# Plaintext logistic-regression gradient-step artifacts (f64): the L2
# "model" path used by the conventional-LR baseline example.
LR_STEP_SHAPES = [
    dict(m=256, d=784),
    dict(m=1024, d=784),
]

# Pallas kernel block size over rows (must divide every WORKER rows above).
# 32 is the TPU-shaped VMEM schedule the kernel is *designed* for (see the
# kernel docstring); the AOT artifacts for the CPU PJRT runtime are emitted
# with block_rows == rows (one grid step) because interpret-mode grid loops
# lower to XLA while-loops with dynamic slicing — measured 8-40x slower on
# CPU with no fidelity benefit (EXPERIMENTS.md §Perf, L1). Correctness of
# the tiled schedule is still enforced by python/tests/test_kernel.py,
# which sweeps block_rows ∈ {8, 16, 32}.
BLOCK_ROWS = 32


def cpu_block_rows(rows: int) -> int:
    """Block size used when emitting CPU-runtime artifacts: few grid
    steps, but blocks capped at 256 rows (a single huge block regressed
    the larger shapes — §Perf iteration log)."""
    return min(rows, 256)


def worker_name(rows: int, d: int, r: int) -> str:
    return f"worker_f_m{rows}_d{d}_r{r}"


def lr_step_name(m: int, d: int) -> str:
    return f"lr_step_m{m}_d{d}"
