"""Build-time compile path: JAX/Pallas → HLO text artifacts.

Python never runs on the request path — `aot.py` lowers the worker
computation (and a plaintext logistic-regression step for baselines) once,
and the rust coordinator loads the resulting `artifacts/*.hlo.txt` via the
PJRT C API.
"""

import jax

# Field elements are int64 end to end.
jax.config.update("jax_enable_x64", True)
