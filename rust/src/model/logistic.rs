//! Conventional logistic regression (paper eq. 1–3), full-batch GD.

use super::{matvec, max_eig_xtx, tr_matvec};
use crate::data::Dataset;
use crate::sigmoid::sigmoid;

/// Plaintext logistic regression model.
#[derive(Debug, Clone)]
pub struct LogisticRegression {
    pub w: Vec<f64>,
}

impl LogisticRegression {
    /// Zero-initialized weights (the paper's runs start near zero).
    pub fn new(d: usize) -> Self {
        LogisticRegression { w: vec![0.0; d] }
    }

    pub fn with_weights(w: Vec<f64>) -> Self {
        LogisticRegression { w }
    }

    /// Cross-entropy cost C(w) (eq. 1), clipped for numerical safety.
    pub fn loss(&self, ds: &Dataset) -> f64 {
        let z = matvec(&ds.x, &self.w, ds.m, ds.d);
        let mut acc = 0.0;
        for (zi, &yi) in z.iter().zip(ds.y.iter()) {
            let p = sigmoid(*zi).clamp(1e-12, 1.0 - 1e-12);
            acc += -yi * p.ln() - (1.0 - yi) * (1.0 - p).ln();
        }
        acc / ds.m as f64
    }

    /// ∇C(w) = (1/m) Xᵀ (g(Xw) − y) (eq. 3).
    pub fn gradient(&self, ds: &Dataset) -> Vec<f64> {
        let z = matvec(&ds.x, &self.w, ds.m, ds.d);
        let resid: Vec<f64> = z
            .iter()
            .zip(ds.y.iter())
            .map(|(&zi, &yi)| sigmoid(zi) - yi)
            .collect();
        let mut g = tr_matvec(&ds.x, &resid, ds.m, ds.d);
        for e in g.iter_mut() {
            *e /= ds.m as f64;
        }
        g
    }

    /// One gradient-descent step with rate `eta`.
    pub fn step(&mut self, ds: &Dataset, eta: f64) {
        let g = self.gradient(ds);
        for (w, gi) in self.w.iter_mut().zip(g.iter()) {
            *w -= eta * gi;
        }
    }

    /// Theorem-1 step size η = 1/L, L = ¼ max eig(XᵀX)/m.
    ///
    /// (Lemma 2 states L = ¼‖X‖₂² for the *unnormalized* sum; our cost is
    /// the 1/m-scaled eq. (1), so L scales by 1/m as well.)
    pub fn lipschitz_lr(&self, ds: &Dataset) -> f64 {
        let l = 0.25 * max_eig_xtx(&ds.x, ds.m, ds.d, 30) / ds.m as f64;
        if l <= 0.0 {
            1.0
        } else {
            1.0 / l
        }
    }

    /// Classification accuracy at threshold 0.5.
    pub fn accuracy(&self, ds: &Dataset) -> f64 {
        let z = matvec(&ds.x, &self.w, ds.m, ds.d);
        let correct = z
            .iter()
            .zip(ds.y.iter())
            .filter(|(&zi, &yi)| (sigmoid(zi) >= 0.5) == (yi == 1.0))
            .count();
        correct as f64 / ds.m as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic_3v7;
    use crate::data::Dataset;

    fn toy() -> Dataset {
        // Linearly separable 1-D task.
        let x = vec![-2.0, -1.5, -1.0, 1.0, 1.5, 2.0];
        let y = vec![0.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        Dataset::new(x, y, 6, 1, "toy")
    }

    #[test]
    fn loss_at_zero_weights_is_ln2() {
        let lr = LogisticRegression::new(1);
        assert!((lr.loss(&toy()) - std::f64::consts::LN_2).abs() < 1e-12);
    }

    #[test]
    fn gradient_descent_decreases_loss_monotonically() {
        let ds = toy();
        let mut lr = LogisticRegression::new(1);
        let eta = lr.lipschitz_lr(&ds);
        let mut prev = lr.loss(&ds);
        for _ in 0..50 {
            lr.step(&ds, eta);
            let cur = lr.loss(&ds);
            assert!(cur <= prev + 1e-12, "loss increased {prev} → {cur}");
            prev = cur;
        }
        assert!(lr.accuracy(&ds) == 1.0);
        assert!(lr.w[0] > 0.0);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let ds = synthetic_3v7(16, 5);
        let mut lr = LogisticRegression::new(ds.d);
        // Non-trivial point.
        for (i, w) in lr.w.iter_mut().enumerate() {
            *w = ((i % 7) as f64 - 3.0) * 0.01;
        }
        let g = lr.gradient(&ds);
        let eps = 1e-6;
        for &idx in &[0usize, 100, 405, 783] {
            let mut plus = lr.clone();
            plus.w[idx] += eps;
            let mut minus = lr.clone();
            minus.w[idx] -= eps;
            let fd = (plus.loss(&ds) - minus.loss(&ds)) / (2.0 * eps);
            assert!(
                (fd - g[idx]).abs() < 1e-6,
                "idx {idx}: fd={fd} analytic={}",
                g[idx]
            );
        }
    }

    #[test]
    fn accuracy_of_perfect_and_anti_model() {
        let ds = toy();
        let good = LogisticRegression::with_weights(vec![5.0]);
        assert_eq!(good.accuracy(&ds), 1.0);
        let bad = LogisticRegression::with_weights(vec![-5.0]);
        assert_eq!(bad.accuracy(&ds), 0.0);
    }
}
