//! Model persistence: save/load trained weights + training metadata as
//! JSON, so `codedml train --save-model m.json` output can be served or
//! resumed later (`--load-model`).

use std::path::Path;

use crate::util::json::{obj, Json};

/// A persisted model.
#[derive(Debug, Clone, PartialEq)]
pub struct SavedModel {
    pub weights: Vec<f64>,
    /// "logistic" | "linear".
    pub kind: String,
    /// Free-form provenance (dataset source, iterations, seed...).
    pub meta: Vec<(String, String)>,
}

#[derive(Debug)]
pub enum PersistError {
    Io(std::io::Error),
    Parse(String),
}

impl std::fmt::Display for PersistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PersistError::Io(e) => write!(f, "io: {e}"),
            PersistError::Parse(e) => write!(f, "parse: {e}"),
        }
    }
}

impl std::error::Error for PersistError {}

impl SavedModel {
    pub fn new(kind: &str, weights: Vec<f64>) -> Self {
        SavedModel { weights, kind: kind.to_string(), meta: Vec::new() }
    }

    pub fn with_meta(mut self, key: &str, value: impl ToString) -> Self {
        self.meta.push((key.to_string(), value.to_string()));
        self
    }

    pub fn to_json(&self) -> Json {
        obj(&[
            ("format", Json::Str("codedml-model-v1".into())),
            ("kind", Json::Str(self.kind.clone())),
            (
                "meta",
                Json::Obj(
                    self.meta
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                        .collect(),
                ),
            ),
            (
                "weights",
                Json::Arr(self.weights.iter().map(|&w| Json::Num(w)).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, PersistError> {
        if j.get("format").and_then(Json::as_str) != Some("codedml-model-v1") {
            return Err(PersistError::Parse("not a codedml-model-v1 file".into()));
        }
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| PersistError::Parse("missing kind".into()))?
            .to_string();
        let weights = j
            .get("weights")
            .and_then(Json::as_arr)
            .ok_or_else(|| PersistError::Parse("missing weights".into()))?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| PersistError::Parse("non-numeric weight".into())))
            .collect::<Result<Vec<f64>, _>>()?;
        let meta = j
            .get("meta")
            .and_then(Json::as_obj)
            .map(|o| {
                o.iter()
                    .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                    .collect()
            })
            .unwrap_or_default();
        Ok(SavedModel { weights, kind, meta })
    }

    pub fn save(&self, path: &Path) -> Result<(), PersistError> {
        std::fs::write(path, self.to_json().to_string()).map_err(PersistError::Io)
    }

    pub fn load(path: &Path) -> Result<Self, PersistError> {
        let text = std::fs::read_to_string(path).map_err(PersistError::Io)?;
        let j = Json::parse(&text).map_err(|e| PersistError::Parse(e.to_string()))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trip() {
        let m = SavedModel::new("logistic", vec![0.5, -1.25, 3.0])
            .with_meta("iters", 25)
            .with_meta("source", "synthetic-3v7");
        let j = m.to_json();
        let back = SavedModel::from_json(&j).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn file_round_trip() {
        let path = std::env::temp_dir().join(format!("model_{}.json", std::process::id()));
        let m = SavedModel::new("linear", vec![1.0; 8]);
        m.save(&path).unwrap();
        let back = SavedModel::load(&path).unwrap();
        assert_eq!(back, m);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_foreign_files() {
        let j = Json::parse(r#"{"format": "something-else"}"#).unwrap();
        assert!(matches!(SavedModel::from_json(&j), Err(PersistError::Parse(_))));
        let j = Json::parse(r#"{"format": "codedml-model-v1", "kind": "logistic", "weights": [1, "x"]}"#)
            .unwrap();
        assert!(SavedModel::from_json(&j).is_err());
    }
}
