//! Plaintext (non-private) models — the "conventional logistic regression"
//! baseline of Figures 3–4 and the correctness oracle for the private
//! training loop.

mod linear;
mod logistic;
mod persist;

pub use linear::LinearRegression;
pub use logistic::LogisticRegression;
pub use persist::{PersistError, SavedModel};

/// Dense matrix–vector product: y = X·w for row-major X (m×d).
pub fn matvec(x: &[f64], w: &[f64], m: usize, d: usize) -> Vec<f64> {
    assert_eq!(x.len(), m * d);
    assert_eq!(w.len(), d);
    (0..m)
        .map(|i| {
            let row = &x[i * d..(i + 1) * d];
            row.iter().zip(w.iter()).map(|(a, b)| a * b).sum()
        })
        .collect()
}

/// Xᵀ·v for row-major X (m×d), v length m.
pub fn tr_matvec(x: &[f64], v: &[f64], m: usize, d: usize) -> Vec<f64> {
    assert_eq!(x.len(), m * d);
    assert_eq!(v.len(), m);
    let mut out = vec![0.0; d];
    for i in 0..m {
        let vi = v[i];
        let row = &x[i * d..(i + 1) * d];
        for (o, &xv) in out.iter_mut().zip(row.iter()) {
            *o += xv * vi;
        }
    }
    out
}

/// Power iteration estimate of the largest eigenvalue of XᵀX — used for the
/// Lipschitz step size η = 1/L with L = ¼ max eig(X̄ᵀX̄) (Lemma 2).
pub fn max_eig_xtx(x: &[f64], m: usize, d: usize, iters: usize) -> f64 {
    let mut v = vec![1.0f64; d];
    let mut lambda = 0.0;
    for _ in 0..iters {
        let xv = matvec(x, &v, m, d);
        let mut nv = tr_matvec(x, &xv, m, d);
        let norm = nv.iter().map(|a| a * a).sum::<f64>().sqrt();
        if norm < 1e-30 {
            return 0.0;
        }
        for e in nv.iter_mut() {
            *e /= norm;
        }
        lambda = norm;
        v = nv;
    }
    lambda
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_and_transpose() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3×2
        let w = [1.0, -1.0];
        assert_eq!(matvec(&x, &w, 3, 2), vec![-1.0, -1.0, -1.0]);
        let v = [1.0, 1.0, 1.0];
        assert_eq!(tr_matvec(&x, &v, 3, 2), vec![9.0, 12.0]);
    }

    #[test]
    fn power_iteration_known_matrix() {
        // X = I₂ → XᵀX = I, max eig 1.
        let x = [1.0, 0.0, 0.0, 1.0];
        let l = max_eig_xtx(&x, 2, 2, 50);
        assert!((l - 1.0).abs() < 1e-9, "l={l}");
        // X = diag(2, 1) → max eig of XᵀX = 4.
        let x = [2.0, 0.0, 0.0, 1.0];
        let l = max_eig_xtx(&x, 2, 2, 100);
        assert!((l - 4.0).abs() < 1e-6, "l={l}");
    }
}
