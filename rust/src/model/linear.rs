//! Linear regression — Remark 1/3: CodedPrivateML applies with minor
//! modifications (the "activation" is the identity, already a degree-1
//! polynomial, so no sigmoid approximation error term).

use super::{matvec, max_eig_xtx, tr_matvec};

/// Plaintext least-squares linear regression trained by gradient descent.
#[derive(Debug, Clone)]
pub struct LinearRegression {
    pub w: Vec<f64>,
}

impl LinearRegression {
    pub fn new(d: usize) -> Self {
        LinearRegression { w: vec![0.0; d] }
    }

    pub fn with_weights(w: Vec<f64>) -> Self {
        LinearRegression { w }
    }

    /// ‖w − w*‖₂ — recovery error against a planted model.
    pub fn distance_to(&self, w_star: &[f64]) -> f64 {
        self.w
            .iter()
            .zip(w_star.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// Mean squared error ½·mean((Xw − y)²).
    pub fn loss(&self, x: &[f64], y: &[f64], m: usize, d: usize) -> f64 {
        let z = matvec(x, &self.w, m, d);
        z.iter()
            .zip(y.iter())
            .map(|(&zi, &yi)| (zi - yi) * (zi - yi))
            .sum::<f64>()
            / (2.0 * m as f64)
    }

    /// ∇ = (1/m) Xᵀ(Xw − y).
    pub fn gradient(&self, x: &[f64], y: &[f64], m: usize, d: usize) -> Vec<f64> {
        let z = matvec(x, &self.w, m, d);
        let resid: Vec<f64> = z.iter().zip(y.iter()).map(|(&zi, &yi)| zi - yi).collect();
        let mut g = tr_matvec(x, &resid, m, d);
        for e in g.iter_mut() {
            *e /= m as f64;
        }
        g
    }

    pub fn step(&mut self, x: &[f64], y: &[f64], m: usize, d: usize, eta: f64) {
        let g = self.gradient(x, y, m, d);
        for (w, gi) in self.w.iter_mut().zip(g.iter()) {
            *w -= eta * gi;
        }
    }

    /// Safe constant step size 1/L with L = max eig(XᵀX)/m.
    pub fn lipschitz_lr(&self, x: &[f64], m: usize, d: usize) -> f64 {
        let l = max_eig_xtx(x, m, d, 30) / m as f64;
        if l <= 0.0 {
            1.0
        } else {
            1.0 / l
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn recovers_planted_linear_model() {
        let mut rng = Rng::new(3);
        let (m, d) = (64, 4);
        let w_true = [1.5, -2.0, 0.5, 3.0];
        let mut x = Vec::with_capacity(m * d);
        let mut y = Vec::with_capacity(m);
        for _ in 0..m {
            let row: Vec<f64> = (0..d).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            y.push(row.iter().zip(w_true.iter()).map(|(a, b)| a * b).sum());
            x.extend(row);
        }
        let mut lin = LinearRegression::new(d);
        let eta = lin.lipschitz_lr(&x, m, d);
        for _ in 0..500 {
            lin.step(&x, &y, m, d, eta);
        }
        for (got, want) in lin.w.iter().zip(w_true.iter()) {
            assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        }
        assert!(lin.loss(&x, &y, m, d) < 1e-10);
    }

    #[test]
    fn gradient_zero_at_optimum() {
        // y = 2x exactly; w = 2 ⇒ gradient 0.
        let x = [1.0, 2.0, 3.0];
        let y = [2.0, 4.0, 6.0];
        let lin = LinearRegression { w: vec![2.0] };
        let g = lin.gradient(&x, &y, 3, 1);
        assert!(g[0].abs() < 1e-12);
    }
}
