//! Synthetic 3-vs-7 surrogate dataset.
//!
//! Offline stand-in for MNIST (DESIGN.md §Substitutions): two smooth
//! 28×28 class prototypes — a stylized "3" and "7" drawn with thick
//! strokes — plus per-sample amplitude jitter, translation, and pixel
//! noise. Pixels live in [0, 1] like normalized MNIST; plaintext logistic
//! regression reaches the same ≈95–96% accuracy regime at 25 iterations,
//! which is the property Figures 3–4 depend on. Runtime-scaling
//! experiments only depend on (m, d), which match exactly.

use super::Dataset;
use crate::util::Rng;

const SIDE: usize = 28;
const D: usize = SIDE * SIDE;

/// Rasterize a polyline with a thick soft brush into a SIDE×SIDE canvas.
fn draw(canvas: &mut [f64], pts: &[(f64, f64)], thickness: f64) {
    let steps = 160;
    for seg in pts.windows(2) {
        let (x0, y0) = seg[0];
        let (x1, y1) = seg[1];
        for s in 0..=steps {
            let t = s as f64 / steps as f64;
            let cx = x0 + (x1 - x0) * t;
            let cy = y0 + (y1 - y0) * t;
            let lo_r = (cy - 2.0 * thickness).floor().max(0.0) as usize;
            let hi_r = (cy + 2.0 * thickness).ceil().min(SIDE as f64 - 1.0) as usize;
            let lo_c = (cx - 2.0 * thickness).floor().max(0.0) as usize;
            let hi_c = (cx + 2.0 * thickness).ceil().min(SIDE as f64 - 1.0) as usize;
            for rr in lo_r..=hi_r {
                for cc in lo_c..=hi_c {
                    let dist2 = (rr as f64 - cy).powi(2) + (cc as f64 - cx).powi(2);
                    let v = (-dist2 / (thickness * thickness)).exp();
                    let cell = &mut canvas[rr * SIDE + cc];
                    *cell = (*cell + v).min(1.0);
                }
            }
        }
    }
}

/// Class prototype for digit "3".
fn proto3() -> Vec<f64> {
    let mut c = vec![0.0; D];
    // Two stacked arcs approximated by polylines.
    draw(
        &mut c,
        &[(8.0, 6.0), (18.0, 5.0), (20.0, 9.0), (14.0, 13.0)],
        1.3,
    );
    draw(
        &mut c,
        &[(14.0, 13.0), (21.0, 16.0), (19.0, 21.0), (8.0, 22.0)],
        1.3,
    );
    c
}

/// Class prototype for digit "7".
fn proto7() -> Vec<f64> {
    let mut c = vec![0.0; D];
    draw(&mut c, &[(7.0, 6.0), (21.0, 6.0)], 1.3); // top bar
    draw(&mut c, &[(21.0, 6.0), (12.0, 22.0)], 1.3); // diagonal
    draw(&mut c, &[(11.0, 14.0), (18.0, 14.0)], 1.0); // crossbar
    c
}

/// Translate a canvas by integer (dr, dc), zero-filling.
fn shift(src: &[f64], dr: i64, dc: i64) -> Vec<f64> {
    let mut out = vec![0.0; D];
    for r in 0..SIDE as i64 {
        for c in 0..SIDE as i64 {
            let (sr, sc) = (r - dr, c - dc);
            if (0..SIDE as i64).contains(&sr) && (0..SIDE as i64).contains(&sc) {
                out[(r * SIDE as i64 + c) as usize] = src[(sr * SIDE as i64 + sc) as usize];
            }
        }
    }
    out
}

/// Generate `m` samples (alternating labels), d = 784, pixels in [0, 1].
/// Label 1 ↦ digit 3, label 0 ↦ digit 7 (binary task of Figure 3).
///
/// Difficulty is tuned so plaintext logistic regression lands in the
/// paper's ≈95–97% regime at 25 iterations rather than saturating: per-
/// sample translation, amplitude jitter, pixel noise, a random occlusion
/// patch, and a small rate of ambiguous samples (a blend of both
/// prototypes — MNIST's hard 3s-that-look-like-7s).
pub fn synthetic_3v7(m: usize, seed: u64) -> Dataset {
    let p3 = proto3();
    let p7 = proto7();
    let mut rng = Rng::new(seed ^ 0x3A7);
    let mut x = Vec::with_capacity(m * D);
    let mut y = Vec::with_capacity(m);
    for i in 0..m {
        let label = (i % 2) as u64;
        let (own, other) = if label == 1 { (&p3, &p7) } else { (&p7, &p3) };
        let dr = rng.below(7) as i64 - 3;
        let dc = rng.below(7) as i64 - 3;
        let shifted = shift(own, dr, dc);
        let amp = rng.range_f64(0.65, 1.0);
        // ~5% ambiguous samples blend in a dose of the other class.
        let blend = if rng.bernoulli(0.05) { rng.range_f64(0.40, 0.65) } else { 0.0 };
        // Random occlusion patch (sensor dropout / heavy stroke overlap).
        let (pr, pc) = (rng.below_usize(SIDE - 5), rng.below_usize(SIDE - 5));
        let start = x.len();
        for (idx, (&v, &o)) in shifted.iter().zip(other.iter()).enumerate() {
            let noise = rng.range_f64(-0.07, 0.07);
            let mixed = v * (1.0 - blend) + o * blend;
            let (r, c) = (idx / SIDE, idx % SIDE);
            let occluded = r >= pr && r < pr + 5 && c >= pc && c < pc + 5;
            let px = if occluded { 0.0 } else { (mixed * amp + noise).clamp(0.0, 1.0) };
            x.push(px);
        }
        debug_assert_eq!(x.len() - start, D);
        y.push(label as f64);
    }
    Dataset::new(x, y, m, D, "synthetic-3v7")
}

/// Planted linear-regression task (Remark 1's workload): x ~ U[-1, 1]^d,
/// y = x·w* exactly, with a fixed seeded w* of entries in [-0.5, 0.5].
/// Returns `(dataset, w*)` so callers can measure recovery error.
pub fn synthetic_planted_linear(m: usize, d: usize, seed: u64) -> (Dataset, Vec<f64>) {
    let mut rng = Rng::new(seed ^ 0x11EA);
    let w_star: Vec<f64> = (0..d).map(|_| rng.range_f64(-0.5, 0.5)).collect();
    let mut x = Vec::with_capacity(m * d);
    let mut y = Vec::with_capacity(m);
    for _ in 0..m {
        let row: Vec<f64> = (0..d).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        y.push(row.iter().zip(w_star.iter()).map(|(a, b)| a * b).sum());
        x.extend(row);
    }
    (Dataset::regression(x, y, m, d, "planted-linear"), w_star)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LogisticRegression;

    #[test]
    fn shapes_and_range() {
        let ds = synthetic_3v7(20, 1);
        assert_eq!(ds.m, 20);
        assert_eq!(ds.d, 784);
        assert!(ds.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(ds.max_abs_x() <= 1.0);
        // Balanced labels.
        let ones: usize = ds.y.iter().filter(|&&v| v == 1.0).count();
        assert_eq!(ones, 10);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = synthetic_3v7(8, 42);
        let b = synthetic_3v7(8, 42);
        assert_eq!(a.x, b.x);
        let c = synthetic_3v7(8, 43);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn class_means_are_separated() {
        // Per-sample jitter is large by design; what must hold is that the
        // *class mean images* are well separated — that is what a linear
        // model exploits.
        let ds = synthetic_3v7(100, 3);
        let mut mean0 = vec![0.0f64; ds.d];
        let mut mean1 = vec![0.0f64; ds.d];
        let (mut n0, mut n1) = (0.0, 0.0);
        for i in 0..ds.m {
            let row = &ds.x[i * ds.d..(i + 1) * ds.d];
            if ds.y[i] == 0.0 {
                n0 += 1.0;
                for (m, &v) in mean0.iter_mut().zip(row) {
                    *m += v;
                }
            } else {
                n1 += 1.0;
                for (m, &v) in mean1.iter_mut().zip(row) {
                    *m += v;
                }
            }
        }
        let sep: f64 = mean0
            .iter()
            .zip(mean1.iter())
            .map(|(a, b)| (a / n0 - b / n1).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(sep > 3.0, "class-mean separation {sep}");
    }

    #[test]
    fn planted_linear_is_deterministic_and_recoverable() {
        let (ds, w_star) = synthetic_planted_linear(64, 4, 3);
        assert_eq!(ds.m, 64);
        assert_eq!(ds.d, 4);
        assert_eq!(w_star.len(), 4);
        assert!(ds.x.iter().all(|&v| (-1.0..=1.0).contains(&v)));
        let (ds2, w2) = synthetic_planted_linear(64, 4, 3);
        assert_eq!(ds.x, ds2.x);
        assert_eq!(w_star, w2);
        // y really is X·w* — plaintext GD recovers the planted model.
        let mut lin = crate::model::LinearRegression::new(4);
        let eta = lin.lipschitz_lr(&ds.x, 64, 4);
        for _ in 0..500 {
            lin.step(&ds.x, &ds.y, 64, 4, eta);
        }
        assert!(lin.distance_to(&w_star) < 1e-6, "{:?}", lin.w);
    }

    #[test]
    fn plaintext_lr_reaches_paper_accuracy_regime() {
        // The surrogate must land logistic regression in the ≈95% range
        // within 25 iterations — the property Figures 3/4 rely on.
        let train = synthetic_3v7(256, 11);
        let test = synthetic_3v7(256, 12);
        let mut lr = LogisticRegression::new(train.d);
        let eta = lr.lipschitz_lr(&train);
        for _ in 0..25 {
            lr.step(&train, eta);
        }
        let acc = lr.accuracy(&test);
        // Paper regime ≈95%; the surrogate's ambiguous-sample rate gives
        // ±3% seed variance, so gate at 90 and cap at 99.5 (must not
        // saturate — that would make Figure 3 meaningless).
        assert!((0.90..=0.995).contains(&acc), "accuracy={acc}");
    }
}
