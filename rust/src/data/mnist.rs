//! MNIST IDX loader.
//!
//! Parses the classic `train-images-idx3-ubyte` / `train-labels-idx1-ubyte`
//! files (optionally the t10k pair for the test split), filters digits
//! 3 and 7, normalizes pixels to [0, 1], and relabels 3 ↦ 1, 7 ↦ 0 — the
//! binary task of the paper's Figure 3. Used when `MNIST_DIR` is set;
//! otherwise [`super::synthetic_3v7`] is the offline substitute.

use std::fs;
use std::path::Path;

use super::Dataset;

#[derive(Debug)]
pub enum MnistError {
    Io(std::io::Error),
    BadMagic { file: String, got: u32 },
    Truncated(String),
    CountMismatch { images: usize, labels: usize },
    NotEnough { want: usize, have: usize },
}

impl std::fmt::Display for MnistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MnistError::Io(e) => write!(f, "io: {e}"),
            MnistError::BadMagic { file, got } => write!(f, "{file}: bad magic {got:#x}"),
            MnistError::Truncated(file) => write!(f, "{file}: truncated"),
            MnistError::CountMismatch { images, labels } => {
                write!(f, "{images} images vs {labels} labels")
            }
            MnistError::NotEnough { want, have } => {
                write!(f, "need {want} 3/7 samples, file has {have}")
            }
        }
    }
}

impl std::error::Error for MnistError {}

impl From<std::io::Error> for MnistError {
    fn from(e: std::io::Error) -> Self {
        MnistError::Io(e)
    }
}

fn read_u32(buf: &[u8], at: usize, file: &str) -> Result<u32, MnistError> {
    buf.get(at..at + 4)
        .map(|b| u32::from_be_bytes([b[0], b[1], b[2], b[3]]))
        .ok_or_else(|| MnistError::Truncated(file.to_string()))
}

/// Parse an IDX3 image file → (images as flat rows of d pixels, d).
pub(crate) fn parse_idx3(buf: &[u8], file: &str) -> Result<(Vec<Vec<u8>>, usize), MnistError> {
    let magic = read_u32(buf, 0, file)?;
    if magic != 0x0000_0803 {
        return Err(MnistError::BadMagic { file: file.to_string(), got: magic });
    }
    let n = read_u32(buf, 4, file)? as usize;
    let rows = read_u32(buf, 8, file)? as usize;
    let cols = read_u32(buf, 12, file)? as usize;
    let d = rows * cols;
    let body = buf.get(16..).ok_or_else(|| MnistError::Truncated(file.to_string()))?;
    if body.len() < n * d {
        return Err(MnistError::Truncated(file.to_string()));
    }
    Ok(((0..n).map(|i| body[i * d..(i + 1) * d].to_vec()).collect(), d))
}

/// Parse an IDX1 label file.
pub(crate) fn parse_idx1(buf: &[u8], file: &str) -> Result<Vec<u8>, MnistError> {
    let magic = read_u32(buf, 0, file)?;
    if magic != 0x0000_0801 {
        return Err(MnistError::BadMagic { file: file.to_string(), got: magic });
    }
    let n = read_u32(buf, 4, file)? as usize;
    let body = buf.get(8..).ok_or_else(|| MnistError::Truncated(file.to_string()))?;
    if body.len() < n {
        return Err(MnistError::Truncated(file.to_string()));
    }
    Ok(body[..n].to_vec())
}

fn load_pair(dir: &Path, images: &str, labels: &str) -> Result<(Vec<Vec<u8>>, Vec<u8>, usize), MnistError> {
    let ibuf = fs::read(dir.join(images))?;
    let lbuf = fs::read(dir.join(labels))?;
    let (imgs, d) = parse_idx3(&ibuf, images)?;
    let labs = parse_idx1(&lbuf, labels)?;
    if imgs.len() != labs.len() {
        return Err(MnistError::CountMismatch { images: imgs.len(), labels: labs.len() });
    }
    Ok((imgs, labs, d))
}

fn filter_3v7(imgs: &[Vec<u8>], labs: &[u8], want: usize, d: usize, source: &str) -> Result<Dataset, MnistError> {
    let mut x = Vec::with_capacity(want * d);
    let mut y = Vec::with_capacity(want);
    for (img, &lab) in imgs.iter().zip(labs.iter()) {
        if y.len() == want {
            break;
        }
        let label = match lab {
            3 => 1.0,
            7 => 0.0,
            _ => continue,
        };
        x.extend(img.iter().map(|&px| px as f64 / 255.0));
        y.push(label);
    }
    if y.len() < want {
        return Err(MnistError::NotEnough { want, have: y.len() });
    }
    Ok(Dataset::new(x, y, want, d, source))
}

/// Load train/test 3-vs-7 datasets from an MNIST directory. The test split
/// comes from the t10k files when present, otherwise from the tail of the
/// training files.
pub fn load_mnist_3v7(dir: &str, train_m: usize, test_m: usize) -> Result<(Dataset, Dataset), MnistError> {
    let dir = Path::new(dir);
    let (imgs, labs, d) = load_pair(dir, "train-images-idx3-ubyte", "train-labels-idx1-ubyte")?;
    let train = filter_3v7(&imgs, &labs, train_m, d, "mnist-3v7")?;
    let test = if dir.join("t10k-images-idx3-ubyte").exists() {
        let (ti, tl, _) = load_pair(dir, "t10k-images-idx3-ubyte", "t10k-labels-idx1-ubyte")?;
        filter_3v7(&ti, &tl, test_m, d, "mnist-3v7-test")?
    } else {
        let mut ri: Vec<Vec<u8>> = imgs;
        let mut rl = labs;
        ri.reverse();
        rl.reverse();
        filter_3v7(&ri, &rl, test_m, d, "mnist-3v7-test")?
    };
    Ok((train, test))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny valid IDX pair in memory.
    fn fake_idx(n: usize, side: usize) -> (Vec<u8>, Vec<u8>) {
        let mut img = vec![];
        img.extend_from_slice(&0x0803u32.to_be_bytes());
        img.extend_from_slice(&(n as u32).to_be_bytes());
        img.extend_from_slice(&(side as u32).to_be_bytes());
        img.extend_from_slice(&(side as u32).to_be_bytes());
        for i in 0..n * side * side {
            img.push((i % 251) as u8);
        }
        let mut lab = vec![];
        lab.extend_from_slice(&0x0801u32.to_be_bytes());
        lab.extend_from_slice(&(n as u32).to_be_bytes());
        for i in 0..n {
            lab.push(if i % 2 == 0 { 3 } else { 7 });
        }
        (img, lab)
    }

    #[test]
    fn parses_valid_idx() {
        let (img, lab) = fake_idx(6, 4);
        let (imgs, d) = parse_idx3(&img, "t").unwrap();
        assert_eq!(imgs.len(), 6);
        assert_eq!(d, 16);
        let labs = parse_idx1(&lab, "t").unwrap();
        assert_eq!(labs, vec![3, 7, 3, 7, 3, 7]);
    }

    #[test]
    fn rejects_bad_magic() {
        let (mut img, _) = fake_idx(2, 4);
        img[3] = 0x99;
        assert!(matches!(parse_idx3(&img, "t"), Err(MnistError::BadMagic { .. })));
    }

    #[test]
    fn rejects_truncated() {
        let (img, _) = fake_idx(2, 4);
        assert!(matches!(
            parse_idx3(&img[..20], "t"),
            Err(MnistError::Truncated(_))
        ));
        assert!(matches!(parse_idx1(&[0, 0], "t"), Err(MnistError::Truncated(_))));
    }

    #[test]
    fn end_to_end_via_tempdir() {
        let dir = std::env::temp_dir().join(format!("mnist_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (img, lab) = fake_idx(20, 28);
        std::fs::write(dir.join("train-images-idx3-ubyte"), &img).unwrap();
        std::fs::write(dir.join("train-labels-idx1-ubyte"), &lab).unwrap();
        let (train, test) = load_mnist_3v7(dir.to_str().unwrap(), 8, 4).unwrap();
        assert_eq!(train.m, 8);
        assert_eq!(train.d, 784);
        assert_eq!(test.m, 4);
        assert!(train.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // 3 ↦ 1, 7 ↦ 0, alternating in the fake file.
        assert_eq!(train.y[0], 1.0);
        assert_eq!(train.y[1], 0.0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn not_enough_samples_error() {
        let (img, lab) = fake_idx(4, 4);
        let (imgs, d) = parse_idx3(&img, "t").unwrap();
        let labs = parse_idx1(&lab, "t").unwrap();
        assert!(matches!(
            filter_3v7(&imgs, &labs, 10, d, "t"),
            Err(MnistError::NotEnough { want: 10, have: 4 })
        ));
    }
}
