//! Datasets: the MNIST 3-vs-7 task of §5, with a synthetic surrogate when
//! the real IDX files are absent (this environment is offline; see
//! DESIGN.md §Substitutions).

mod mnist;
mod synth;

pub use mnist::{load_mnist_3v7, MnistError};
pub use synth::{synthetic_3v7, synthetic_planted_linear};

/// A dense supervised dataset: {0,1} labels for classification
/// ([`Dataset::new`]) or real targets for regression
/// ([`Dataset::regression`]).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Row-major m×d features.
    pub x: Vec<f64>,
    /// Labels — {0.0, 1.0} for classification, arbitrary reals for
    /// regression.
    pub y: Vec<f64>,
    pub m: usize,
    pub d: usize,
    /// Provenance, e.g. "mnist-3v7" or "synthetic-3v7".
    pub source: String,
}

impl Dataset {
    /// Binary-classification dataset; labels must be exactly 0.0 or 1.0.
    pub fn new(x: Vec<f64>, y: Vec<f64>, m: usize, d: usize, source: &str) -> Self {
        assert!(y.iter().all(|&v| v == 0.0 || v == 1.0));
        Self::unchecked(x, y, m, d, source)
    }

    /// Regression dataset — real-valued targets, no label constraint.
    pub fn regression(x: Vec<f64>, y: Vec<f64>, m: usize, d: usize, source: &str) -> Self {
        Self::unchecked(x, y, m, d, source)
    }

    fn unchecked(x: Vec<f64>, y: Vec<f64>, m: usize, d: usize, source: &str) -> Self {
        assert_eq!(x.len(), m * d);
        assert_eq!(y.len(), m);
        Dataset { x, y, m, d, source: source.to_string() }
    }

    /// Largest absolute feature value (drives the overflow budget).
    pub fn max_abs_x(&self) -> f64 {
        self.x.iter().fold(0.0f64, |acc, &v| acc.max(v.abs()))
    }

    /// Duplicate features d → 2d (the paper's footnote 1: "To have a larger
    /// dataset we duplicate the MNIST dataset", giving d = 1568).
    pub fn duplicate_features(&self) -> Dataset {
        let d2 = self.d * 2;
        let mut x = Vec::with_capacity(self.m * d2);
        for i in 0..self.m {
            let row = &self.x[i * self.d..(i + 1) * self.d];
            x.extend_from_slice(row);
            x.extend_from_slice(row);
        }
        Dataset::unchecked(x, self.y.clone(), self.m, d2, &format!("{}-dup", self.source))
    }

    /// Truncate (or keep) to the first `m` rows, rounding down so `m` is a
    /// multiple of `k` (LCC needs K equal blocks).
    pub fn take_rows_multiple_of(&self, m: usize, k: usize) -> Dataset {
        let m = (m.min(self.m) / k) * k;
        assert!(m > 0, "dataset too small for K={k}");
        Dataset::unchecked(
            self.x[..m * self.d].to_vec(),
            self.y[..m].to_vec(),
            m,
            self.d,
            &self.source,
        )
    }

    /// Split into (train, test) at `train_m` rows.
    pub fn split(&self, train_m: usize) -> (Dataset, Dataset) {
        assert!(train_m < self.m);
        let train = Dataset::unchecked(
            self.x[..train_m * self.d].to_vec(),
            self.y[..train_m].to_vec(),
            train_m,
            self.d,
            &self.source,
        );
        let test_m = self.m - train_m;
        let test = Dataset::unchecked(
            self.x[train_m * self.d..].to_vec(),
            self.y[train_m..].to_vec(),
            test_m,
            self.d,
            &self.source,
        );
        (train, test)
    }
}

/// Load the paper's dataset: real MNIST if `MNIST_DIR` is set and parses,
/// otherwise the synthetic surrogate. Returns (train, test).
pub fn paper_dataset(train_m: usize, test_m: usize, seed: u64) -> (Dataset, Dataset) {
    if let Ok(dir) = std::env::var("MNIST_DIR") {
        match load_mnist_3v7(&dir, train_m, test_m) {
            Ok(pair) => return pair,
            // lint: allow(no-stray-io): user-facing env-var misconfiguration warning with no tracer in scope
            Err(e) => eprintln!("MNIST_DIR set but unusable ({e}); using synthetic surrogate"),
        }
    }
    let full = synthetic_3v7(train_m + test_m, seed);
    full.split(train_m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duplicate_features_doubles_d() {
        let ds = synthetic_3v7(10, 1);
        let dup = ds.duplicate_features();
        assert_eq!(dup.d, ds.d * 2);
        assert_eq!(dup.m, ds.m);
        // Row content is the row twice.
        for i in 0..ds.m {
            let orig = &ds.x[i * ds.d..(i + 1) * ds.d];
            let two = &dup.x[i * dup.d..(i + 1) * dup.d];
            assert_eq!(&two[..ds.d], orig);
            assert_eq!(&two[ds.d..], orig);
        }
    }

    #[test]
    fn take_rows_rounds_to_block_multiple() {
        let ds = synthetic_3v7(100, 2);
        let cut = ds.take_rows_multiple_of(95, 8);
        assert_eq!(cut.m, 88);
        assert_eq!(cut.d, ds.d);
    }

    #[test]
    fn split_partitions_rows() {
        let ds = synthetic_3v7(50, 3);
        let (tr, te) = ds.split(40);
        assert_eq!(tr.m, 40);
        assert_eq!(te.m, 10);
        assert_eq!(tr.x.len(), 40 * ds.d);
        assert_eq!(te.y.len(), 10);
    }

    #[test]
    #[should_panic]
    fn rejects_mislabeled() {
        Dataset::new(vec![0.0; 4], vec![0.5, 1.0], 2, 2, "bad");
    }

    #[test]
    fn paper_dataset_falls_back_to_synthetic() {
        // (MNIST_DIR unset in tests.)
        let (tr, te) = paper_dataset(64, 16, 7);
        assert_eq!(tr.m, 64);
        assert_eq!(te.m, 16);
        assert_eq!(tr.d, 784);
        assert!(tr.source.contains("synthetic"));
    }
}
