//! Shamir secret sharing over F_p (Shamir 1979).
//!
//! A secret s becomes shares P(x_i) of a uniformly random degree-T
//! polynomial with P(0) = s; any T+1 shares reconstruct by Lagrange
//! interpolation at 0 and any T reveal nothing.

use crate::field::{lagrange_coeffs, PrimeField};
use crate::util::Rng;

/// Sharing context: field, threshold T, and the workers' evaluation
/// points x_1..x_N (distinct, nonzero).
#[derive(Debug, Clone)]
pub struct ShamirScheme {
    pub field: PrimeField,
    pub t: usize,
    pub points: Vec<u64>,
}

impl ShamirScheme {
    pub fn new(field: PrimeField, n: usize, t: usize) -> Self {
        assert!(t < n, "need more than T workers to reconstruct");
        ShamirScheme { field, t, points: field.distinct_points(n) }
    }

    pub fn n(&self) -> usize {
        self.points.len()
    }

    /// Share one secret: returns N shares.
    pub fn share(&self, secret: u64, rng: &mut Rng) -> Vec<u64> {
        let f = &self.field;
        // P(z) = secret + a_1 z + ... + a_T z^T
        let coeffs: Vec<u64> = std::iter::once(secret)
            .chain((0..self.t).map(|_| f.random(rng)))
            .collect();
        self.points
            .iter()
            .map(|&x| crate::field::eval_poly(f, &coeffs, x))
            .collect()
    }

    /// Share a vector of secrets: returns per-worker share vectors
    /// (worker-major: `out[i][j]` = share of secret j at worker i).
    pub fn share_vec(&self, secrets: &[u64], rng: &mut Rng) -> Vec<Vec<u64>> {
        let n = self.n();
        let mut out = vec![vec![0u64; secrets.len()]; n];
        for (j, &s) in secrets.iter().enumerate() {
            let shares = self.share(s, rng);
            for i in 0..n {
                out[i][j] = shares[i];
            }
        }
        out
    }

    /// Reconstruct from shares at the given worker indices (need ≥ T+1,
    /// or ≥ deg+1 for a degree-`deg` sharing, e.g. 2T after one
    /// unreduced multiplication).
    pub fn reconstruct_deg(&self, idx: &[usize], shares: &[u64], deg: usize) -> u64 {
        assert!(idx.len() == shares.len());
        assert!(idx.len() >= deg + 1, "need {} shares, have {}", deg + 1, idx.len());
        let f = &self.field;
        let pts: Vec<u64> = idx[..deg + 1].iter().map(|&i| self.points[i]).collect();
        let lam = lagrange_coeffs(f, &pts, 0).expect("distinct points");
        lam.iter()
            .zip(shares.iter())
            .fold(0u64, |acc, (&l, &s)| f.add(acc, f.mul(l, s)))
    }

    /// Reconstruct a degree-T sharing.
    pub fn reconstruct(&self, idx: &[usize], shares: &[u64]) -> u64 {
        self.reconstruct_deg(idx, shares, self.t)
    }

    /// Lagrange-at-zero coefficients for the *full* worker set at a given
    /// degree — used by the degree-reduction step.
    pub fn reduction_coeffs(&self, deg: usize) -> Vec<u64> {
        let pts: Vec<u64> = self.points[..deg + 1].to_vec();
        lagrange_coeffs(&self.field, &pts, 0).expect("distinct points")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::PAPER_PRIME;
    use crate::util::proptest::check;

    fn scheme(n: usize, t: usize) -> ShamirScheme {
        ShamirScheme::new(PrimeField::new(PAPER_PRIME), n, t)
    }

    #[test]
    fn share_reconstruct_roundtrip() {
        let s = scheme(7, 2);
        check("shamir-roundtrip", 100, move |rng| {
            let secret = s.field.random(rng);
            let shares = s.share(secret, rng);
            // Any T+1 = 3 of the 7 shares reconstruct.
            let idx = rng.sample_indices(7, 3);
            let picked: Vec<u64> = idx.iter().map(|&i| shares[i]).collect();
            if s.reconstruct(&idx, &picked) != secret {
                return Err(format!("secret {secret} not reconstructed"));
            }
            Ok(())
        });
    }

    #[test]
    fn t_shares_are_uniform() {
        // Statistical: fix two different secrets; the marginal of any
        // single share must look uniform — compare first-share histograms
        // over a coarse partition.
        let s = scheme(5, 2);
        let mut rng = Rng::new(9);
        let buckets = 8;
        let mut h0 = vec![0usize; buckets];
        let mut h1 = vec![0usize; buckets];
        let trials = 8000;
        for _ in 0..trials {
            let sh0 = s.share(0, &mut rng);
            let sh1 = s.share(12345, &mut rng);
            h0[(sh0[0] as u128 * buckets as u128 / PAPER_PRIME as u128) as usize] += 1;
            h1[(sh1[0] as u128 * buckets as u128 / PAPER_PRIME as u128) as usize] += 1;
        }
        let expected = trials as f64 / buckets as f64;
        for b in 0..buckets {
            assert!((h0[b] as f64 - expected).abs() < 5.0 * expected.sqrt(), "h0[{b}]={}", h0[b]);
            assert!((h1[b] as f64 - expected).abs() < 5.0 * expected.sqrt(), "h1[{b}]={}", h1[b]);
        }
    }

    #[test]
    fn shares_are_additively_homomorphic() {
        let s = scheme(6, 2);
        check("shamir-additive", 50, move |rng| {
            let (a, b) = (s.field.random(rng), s.field.random(rng));
            let sa = s.share(a, rng);
            let sb = s.share(b, rng);
            let sum: Vec<u64> = sa.iter().zip(sb.iter()).map(|(&x, &y)| s.field.add(x, y)).collect();
            let idx = [0, 2, 5];
            let picked: Vec<u64> = idx.iter().map(|&i| sum[i]).collect();
            if s.reconstruct(&idx, &picked) != s.field.add(a, b) {
                return Err("sum share mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn product_shares_reconstruct_at_double_degree() {
        let s = scheme(7, 2);
        check("shamir-mult-degree", 50, move |rng| {
            let (a, b) = (s.field.random(rng), s.field.random(rng));
            let sa = s.share(a, rng);
            let sb = s.share(b, rng);
            let prod: Vec<u64> = sa.iter().zip(sb.iter()).map(|(&x, &y)| s.field.mul(x, y)).collect();
            // Degree 2T = 4 sharing: need 5 shares.
            let idx: Vec<usize> = (0..5).collect();
            let picked: Vec<u64> = idx.iter().map(|&i| prod[i]).collect();
            if s.reconstruct_deg(&idx, &picked, 4) != s.field.mul(a, b) {
                return Err("product mismatch".into());
            }
            // And T+1 shares of the product polynomial are NOT enough.
            let idx3: Vec<usize> = (0..3).collect();
            let picked3: Vec<u64> = idx3.iter().map(|&i| prod[i]).collect();
            if s.reconstruct(&idx3, &picked3) == s.field.mul(a, b) {
                // (possible by chance with prob 1/p — treat as failure)
                return Err("degree-2T product reconstructed at degree T".into());
            }
            Ok(())
        });
    }

    #[test]
    fn share_vec_layout() {
        let s = scheme(4, 1);
        let mut rng = Rng::new(5);
        let secrets = [10u64, 20, 30];
        let shares = s.share_vec(&secrets, &mut rng);
        assert_eq!(shares.len(), 4);
        assert_eq!(shares[0].len(), 3);
        for (j, &sec) in secrets.iter().enumerate() {
            let idx = [1, 3];
            let picked: Vec<u64> = idx.iter().map(|&i| shares[i][j]).collect();
            assert_eq!(s.reconstruct(&idx, &picked), sec);
        }
    }
}
