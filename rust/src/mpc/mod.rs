//! The MPC baseline: BGW-style gradient computation over Shamir shares
//! (paper §5 and Appendix A.5).
//!
//! The same quantization and sigmoid-polynomial front end as
//! CodedPrivateML, but secret sharing is Shamir's scheme: every worker
//! stores a share of the **whole** dataset (size m×d — no 1/K
//! parallelization gain), additions are local, and every multiplication
//! level requires a degree-reduction *resharing round* in which each
//! worker sends a share to every other worker (N·(N−1) messages). Those
//! two facts are exactly why CodedPrivateML wins Figure 2, and this
//! implementation reproduces them faithfully with vectorized resharing
//! (one round per multiplication level, as in the paper's "faster
//! vectorized form").

mod bgw;
mod shamir;

pub use bgw::{BgwConfig, BgwError, BgwGradientProtocol, BgwReport};
pub use shamir::ShamirScheme;
