//! BGW-style private gradient descent (paper Appendix A.5).
//!
//! Front end identical to CodedPrivateML (quantization + sigmoid
//! polynomial); the back end is Shamir sharing with one vectorized
//! degree-reduction (resharing) round per multiplication level. The final
//! X̄ᵀḡ multiplication is reconstructed directly at degree 2T — the
//! standard trick that saves the last resharing round.
//!
//! Simulation notes: all N workers execute identical local computations,
//! so the protocol runs them serially and attributes `serial/N` seconds as
//! per-worker parallel compute, then applies the straggler model as a
//! *max* over workers (BGW waits for everyone — MPC gets no fastest-R
//! discount, which is one of the two reasons CodedPrivateML wins Figure 2;
//! the other is the K-fold smaller per-worker data).

use super::shamir::ShamirScheme;
use crate::cluster::{NetworkModel, StragglerModel};
use crate::coordinator::{IterationMetrics, TimingBreakdown, TrainReport};
use crate::data::Dataset;
use crate::field::PrimeField;
use crate::model::{max_eig_xtx, tr_matvec, LogisticRegression};
use crate::quant::{DatasetQuantizer, Dequantizer, WeightQuantizer};
use crate::sigmoid::fit_sigmoid;
use crate::util::par::Parallelism;
use crate::util::timer::timed;
use crate::util::{Rng, Stopwatch};

#[derive(Debug)]
pub enum BgwError {
    /// Degree-2T reconstruction needs N ≥ 2T+1.
    TooFewWorkers { n: usize, t: usize },
    /// Dataset empty after trimming.
    EmptyData,
}

impl std::fmt::Display for BgwError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BgwError::TooFewWorkers { n, t } => {
                write!(f, "BGW needs N ≥ 2T+1 (N={n}, T={t})")
            }
            BgwError::EmptyData => write!(f, "empty dataset"),
        }
    }
}

impl std::error::Error for BgwError {}

/// Protocol statistics of a BGW run (timing lives in the TrainReport).
#[derive(Debug, Clone, Default)]
pub struct BgwReport {
    pub resharing_rounds: u64,
    pub bytes_worker_to_worker: u64,
    pub bytes_master_to_worker: u64,
    pub bytes_worker_to_master: u64,
}

/// The BGW private-training protocol driver.
pub struct BgwGradientProtocol {
    scheme: ShamirScheme,
    field: PrimeField,
    n: usize,
    t: usize,
    m: usize,
    d: usize,
    r: usize,
    /// Field-quantized sigmoid coefficients.
    coeffs: Vec<u64>,
    /// Per-worker share of the full quantized dataset (m×d each!).
    x_shares: Vec<Vec<u64>>,
    /// Dequantized X̄ and X̄ᵀy at the master (same as CodedPrivateML).
    xbar_real: Vec<f64>,
    xbar_t_y: Vec<f64>,
    y: Vec<f64>,
    pub w: Vec<f64>,
    pub eta: f64,
    wquant: WeightQuantizer,
    dequant: Dequantizer,
    net: NetworkModel,
    straggler: StragglerModel,
    rng: Rng,
    /// Independent stream for straggler delays (never perturbs the
    /// protocol's own randomness — same rationale as the LCC session).
    straggle_rng: Rng,
    // timers
    t_encode: Stopwatch,
    t_comm: Stopwatch,
    t_comp: Stopwatch,
    report: BgwReport,
    /// Precomputed Lagrange-at-0 coefficients for degree-2T reconstruction.
    recon_2t: Vec<u64>,
    /// Precomputed reduction coefficients (degree 2T over 2T+1 workers).
    reduction: Vec<u64>,
    /// Thread budget for the share matmuls.
    par: Parallelism,
}

/// Configuration is intentionally a subset of [`crate::CodedMlConfig`] —
/// same quantization scales so comparisons are apples-to-apples.
pub struct BgwConfig {
    pub n: usize,
    pub t: usize,
    pub r: usize,
    pub p: u64,
    pub lx: u32,
    pub lw: u32,
    pub lc: u32,
    pub fit_range: f64,
    pub eta: Option<f64>,
    pub seed: u64,
    pub net: NetworkModel,
    pub straggler: StragglerModel,
    /// Threads for the per-worker share matmuls (timing attribution is
    /// unchanged: measured serial time is still divided by N).
    pub parallelism: Parallelism,
}

impl Default for BgwConfig {
    fn default() -> Self {
        BgwConfig {
            n: 10,
            t: 1,
            r: 1,
            p: crate::field::PAPER_PRIME,
            lx: 2,
            lw: 4,
            lc: 3,
            fit_range: 5.0,
            eta: None,
            seed: 42,
            net: NetworkModel::default(),
            straggler: StragglerModel::default(),
            parallelism: Parallelism::Serial,
        }
    }
}

impl BgwConfig {
    /// The paper's note: BGW tolerates up to T = ⌊(N−1)/2⌋ collusions.
    pub fn max_privacy(n: usize) -> Self {
        BgwConfig { n, t: (n - 1) / 2, ..Default::default() }
    }
}

impl BgwGradientProtocol {
    /// Share the dataset among the workers (the protocol's expensive
    /// one-time "encode" phase) and set up the iteration machinery.
    pub fn new(cfg: BgwConfig, train: &Dataset) -> Result<Self, BgwError> {
        if cfg.n < 2 * cfg.t + 1 {
            return Err(BgwError::TooFewWorkers { n: cfg.n, t: cfg.t });
        }
        if train.m == 0 {
            return Err(BgwError::EmptyData);
        }
        let field = PrimeField::new(cfg.p);
        let (m, d) = (train.m, train.d);
        let scheme = ShamirScheme::new(field, cfg.n, cfg.t);
        let mut rng = Rng::new(cfg.seed ^ 0xB6);

        let poly = fit_sigmoid(cfg.r as u32, cfg.fit_range, 201);
        let coeffs = poly.field_coeffs(&field, cfg.lx, cfg.lw, cfg.lc);

        let mut t_encode = Stopwatch::new();
        let mut t_comm = Stopwatch::new();
        let mut report = BgwReport::default();

        // Quantize + Shamir-share the whole dataset to every worker.
        let xq = DatasetQuantizer::new(field, cfg.lx);
        let mut xbar = Vec::new();
        let mut x_shares: Vec<Vec<u64>> = Vec::new();
        t_encode.time(|| {
            xbar = xq.quantize(&train.x);
            x_shares = share_matrix(&scheme, &xbar, &mut rng);
        });
        // Master → each worker: the full m×d share.
        let bytes = (m * d * 8) as u64;
        t_comm.add_seconds(cfg.net.fanout_time(cfg.n, bytes));
        report.bytes_master_to_worker += bytes * cfg.n as u64;

        let xbar_real: Vec<f64> = xbar.iter().map(|&q| xq.dequantize_entry(q)).collect();
        let xbar_t_y = tr_matvec(&xbar_real, &train.y, m, d);
        let eta = cfg.eta.unwrap_or_else(|| {
            let l = 0.25 * max_eig_xtx(&xbar_real, m, d, 30) / m as f64;
            if l > 0.0 {
                1.0 / l
            } else {
                1.0
            }
        });

        let recon_2t = scheme.reduction_coeffs(2 * cfg.t);
        let reduction = recon_2t.clone();

        Ok(BgwGradientProtocol {
            scheme,
            field,
            n: cfg.n,
            t: cfg.t,
            m,
            d,
            r: cfg.r,
            coeffs,
            x_shares,
            xbar_real,
            xbar_t_y,
            y: train.y.clone(),
            w: vec![0.0; d],
            eta,
            wquant: WeightQuantizer::new(field, cfg.lw, cfg.r as u32),
            dequant: Dequantizer::new(field, cfg.lx, cfg.lw, cfg.lc, cfg.r as u32),
            net: cfg.net,
            straggler: cfg.straggler,
            straggle_rng: Rng::new(cfg.seed ^ 0x5742_4751_4c45),
            rng,
            t_encode,
            t_comm,
            t_comp: Stopwatch::new(),
            report,
            recon_2t,
            reduction,
            par: cfg.parallelism,
        })
    }

    /// One multi-round BGW iteration; returns decoded real-domain X̄ᵀḡ.
    pub fn step(&mut self) -> Vec<f64> {
        let f = self.field;
        let (n, m, d, r) = (self.n, self.m, self.d, self.r);
        let chunk = crate::compute::safe_chunk_len(f.modulus());

        // (1) Master: quantize + Shamir-share W̄ (encode time).
        let w_shares: Vec<Vec<u64>> = {
            let (wquant, scheme, w, rng) = (&self.wquant, &self.scheme, &self.w, &mut self.rng);
            self.t_encode.time(|| {
                let wq = wquant.quantize(w, rng);
                share_matrix(scheme, &wq, rng)
            })
        };
        let wbytes = (d * r * 8) as u64;
        self.t_comm.add_seconds(self.net.fanout_time(n, wbytes));
        self.report.bytes_master_to_worker += wbytes * n as u64;

        // (2) Each worker: u_j = X_sh · w_sh_j  (degree-2T sharing of X̄w̄_j).
        // Serial-over-workers; attribute serial/N as per-worker time.
        let (u, secs) = {
            let (x_shares, par) = (&self.x_shares, self.par);
            timed(|| {
                let mut u: Vec<Vec<u64>> = Vec::with_capacity(n); // per worker, m×r (row-major)
                for i in 0..n {
                    let xs = &x_shares[i];
                    let ws = &w_shares[i];
                    let mut ui = vec![0u64; m * r];
                    for j in 0..r {
                        let col = crate::compute::matvec_mod_par(&f, xs, ws, m, d, r, j, par);
                        for (row, &v) in col.iter().enumerate() {
                            ui[row * r + j] = v;
                        }
                    }
                    u.push(ui);
                }
                u
            })
        };
        self.account_parallel_compute(secs);

        // (3) Degree reduction of the m·r values (one vectorized round).
        let u = self.reshare_round(u);

        // (4) ḡ on shares: g = c̄₀ + Σ_i c̄_i Π_{j≤i} u_j, reducing degree
        //     after each elementwise product level.
        let ((mut g, mut prod), secs) = {
            let coeffs = &self.coeffs;
            timed(|| {
                let mut g: Vec<Vec<u64>> = (0..n).map(|_| vec![coeffs[0]; m]).collect();
                let prod: Vec<Vec<u64>> = u
                    .iter()
                    .map(|ui| (0..m).map(|row| ui[row * r]).collect())
                    .collect();
                for i in 0..n {
                    for row in 0..m {
                        g[i][row] = f.add(g[i][row], f.mul(coeffs[1], prod[i][row]));
                    }
                }
                (g, prod)
            })
        };
        self.account_parallel_compute(secs);
        for level in 2..=r {
            // prod ∘ u_level — a share×share product: degree 2T, reshare.
            let (_, secs) = timed(|| {
                for i in 0..n {
                    for row in 0..m {
                        prod[i][row] = f.mul(prod[i][row], u[i][row * r + (level - 1)]);
                    }
                }
            });
            self.account_parallel_compute(secs);
            prod = self.reshare_round(prod);
            let (_, secs) = {
                let coeffs = &self.coeffs;
                timed(|| {
                    for i in 0..n {
                        for row in 0..m {
                            g[i][row] = f.add(g[i][row], f.mul(coeffs[level], prod[i][row]));
                        }
                    }
                })
            };
            self.account_parallel_compute(secs);
        }

        // (5) f_sh = X_shᵀ · g_sh — degree 2T; master reconstructs
        //     directly from 2T+1 workers (no final resharing).
        let (f_shares, secs) = {
            let (x_shares, par) = (&self.x_shares, self.par);
            timed(|| {
                let mut f_shares: Vec<Vec<u64>> = Vec::with_capacity(n);
                for i in 0..n {
                    f_shares
                        .push(crate::compute::tr_matvec_mod_par(&f, &x_shares[i], &g[i], m, d, par));
                }
                f_shares
            })
        };
        self.account_parallel_compute(secs);

        let fbytes = (d * 8) as u64;
        self.t_comm.add_seconds(self.net.fanin_time(2 * self.t + 1, fbytes));
        self.report.bytes_worker_to_master += fbytes * (2 * self.t + 1) as u64;

        // Master: reconstruct at degree 2T with precomputed coefficients.
        let (xtg, secs) = {
            let lam = &self.recon_2t;
            timed(|| {
                let mut xtg = vec![0u64; d];
                let mut acc = vec![0u64; d];
                let mut pending = 0usize;
                for (i, l) in lam.iter().enumerate() {
                    for (a, &v) in acc.iter_mut().zip(f_shares[i].iter()) {
                        *a = a.wrapping_add(l * v);
                    }
                    pending += 1;
                    if pending == chunk {
                        for (o, a) in xtg.iter_mut().zip(acc.iter_mut()) {
                            *o = f.add(*o, f.reduce_u64(*a));
                            *a = 0;
                        }
                        pending = 0;
                    }
                }
                if pending > 0 {
                    for (o, a) in xtg.iter_mut().zip(acc.iter()) {
                        *o = f.add(*o, f.reduce_u64(*a));
                    }
                }
                xtg
            })
        };
        self.t_comp.add_seconds(secs);

        // (6) Dequantize + update, identical to CodedPrivateML's master.
        let xtg_real: Vec<f64> = xtg.iter().map(|&q| self.dequant.dequantize_entry(q)).collect();
        for ((w, &xg), &xy) in self.w.iter_mut().zip(xtg_real.iter()).zip(self.xbar_t_y.iter()) {
            *w -= self.eta / m as f64 * (xg - xy);
        }
        xtg_real
    }

    /// One vectorized degree-reduction round over per-worker value vectors.
    ///
    /// Each worker re-shares every value with a fresh degree-T polynomial;
    /// worker j's new share is Σ_i λ_i·subshare_{i→j} over the first 2T+1
    /// senders. Compute is measured (serial/N attributed per worker); the
    /// all-to-all traffic is modeled.
    fn reshare_round(&mut self, values: Vec<Vec<u64>>) -> Vec<Vec<u64>> {
        let f = self.field;
        let n = self.n;
        let len = values[0].len();
        let senders = 2 * self.t + 1;

        let (new_shares, secs) = {
            let (scheme, reduction, rng) = (&self.scheme, &self.reduction, &mut self.rng);
            timed(|| {
                let mut new_shares: Vec<Vec<u64>> = vec![vec![0u64; len]; n];
                // For each sender i among the first 2T+1, share its vector
                // and accumulate λ_i·subshare into every receiver.
                for i in 0..senders {
                    let lam_i = reduction[i];
                    // Fresh degree-T sharing of each value (vectorized).
                    let sub = share_matrix(scheme, &values[i], rng);
                    for j in 0..n {
                        let dst = &mut new_shares[j];
                        for (dv, &sv) in dst.iter_mut().zip(sub[j].iter()) {
                            *dv = f.add(*dv, f.mul(lam_i, sv));
                        }
                    }
                }
                new_shares
            })
        };
        self.account_parallel_compute(secs);

        // Traffic: each of the 2T+1 senders sends N−1 messages of len·8
        // bytes (its own subshare stays local). Senders transmit in
        // parallel; the round takes one sender's fanout time.
        let bytes = (len * 8) as u64;
        self.t_comm.add_seconds(self.net.fanout_time(n - 1, bytes));
        self.report.bytes_worker_to_worker += bytes * (senders as u64) * (n as u64 - 1);
        self.report.resharing_rounds += 1;
        new_shares
    }

    /// Convert measured serial-over-workers seconds into modeled parallel
    /// time: serial/N inflated by the straggler *max* over N workers.
    fn account_parallel_compute(&mut self, serial: f64) {
        let per_worker = serial / self.n as f64;
        let mut worst = per_worker;
        for _ in 0..self.n {
            let delayed = per_worker + self.straggler.sample(&mut self.straggle_rng, per_worker);
            worst = worst.max(delayed);
        }
        self.t_comp.add_seconds(worst);
    }

    /// Train like the CodedPrivateML session (same metrics).
    pub fn train(&mut self, iters: usize, test: Option<&Dataset>) -> TrainReport {
        let mut iterations = Vec::with_capacity(iters);
        for it in 0..iters {
            self.step();
            let train_ds = Dataset::new(
                self.xbar_real.clone(),
                self.y.clone(),
                self.m,
                self.d,
                "quantized-train",
            );
            let model = LogisticRegression::with_weights(self.w.clone());
            iterations.push(IterationMetrics {
                iter: it,
                train_loss: model.loss(&train_ds),
                test_accuracy: test.map(|ts| model.accuracy(ts)),
            });
        }
        TrainReport {
            breakdown: TimingBreakdown {
                encode_s: self.t_encode.seconds(),
                comm_s: self.t_comm.seconds(),
                comp_s: self.t_comp.seconds(),
            },
            decode_s: 0.0,
            iterations,
            weights: self.w.clone(),
            decode_cache: (0, 0),
            decode_cache_evictions: 0,
            coding_backend: "dense",
            recovery_threshold: 2 * self.t + 1,
            bytes_sent: self.report.bytes_master_to_worker,
            bytes_received: self.report.bytes_worker_to_master,
            // BGW is lock-step: no early exit, no failure tolerance.
            worker_failures: 0,
            late_results: 0,
        }
    }

    pub fn protocol_report(&self) -> &BgwReport {
        &self.report
    }

    /// Ground truth for tests: reconstruct the plaintext X̄w̄ᵀ-style value
    /// a set of shares encodes.
    #[cfg(test)]
    fn reconstruct_vec(&self, shares: &[Vec<u64>], deg: usize) -> Vec<u64> {
        let idx: Vec<usize> = (0..deg + 1).collect();
        (0..shares[0].len())
            .map(|e| {
                let picked: Vec<u64> = idx.iter().map(|&i| shares[i][e]).collect();
                self.scheme.reconstruct_deg(&idx, &picked, deg)
            })
            .collect()
    }
}

/// Shamir-share a flat vector of field elements; returns per-worker share
/// vectors. Vectorized: powers of the evaluation points are precomputed
/// once, so sharing costs (T+1)·N muls per element.
fn share_matrix(scheme: &ShamirScheme, values: &[u64], rng: &mut Rng) -> Vec<Vec<u64>> {
    let f = &scheme.field;
    let n = scheme.n();
    let t = scheme.t;
    // powers[i][k] = x_i^k for k in 0..=T
    let powers: Vec<Vec<u64>> = scheme
        .points
        .iter()
        .map(|&x| {
            let mut row = Vec::with_capacity(t + 1);
            let mut acc = 1u64;
            for _ in 0..=t {
                row.push(acc);
                acc = f.mul(acc, x);
            }
            row
        })
        .collect();
    let mut out = vec![vec![0u64; values.len()]; n];
    let mut coeffs = vec![0u64; t]; // random part a_1..a_T
    // Deferred reduction: T+1 products < p² ≤ 2^52 sum safely in u64 for
    // any realistic T (chunked otherwise) — one Barrett reduction per
    // share instead of per term (§Perf).
    let chunk = crate::compute::safe_chunk_len(f.modulus());
    for (e, &s) in values.iter().enumerate() {
        for c in coeffs.iter_mut() {
            *c = f.random(rng);
        }
        for i in 0..n {
            let pw = &powers[i];
            let mut acc = 0u64;
            let mut total = s;
            for (chunk_idx, (&c, &pwk)) in coeffs.iter().zip(pw[1..].iter()).enumerate() {
                acc = acc.wrapping_add(c * pwk);
                // lint: allow(no-hardware-modulo): loop-counter chunking, not field arithmetic
                if (chunk_idx + 1) % chunk == 0 {
                    total = f.add(total, f.reduce_u64(acc));
                    acc = 0;
                }
            }
            out[i][e] = f.add(total, f.reduce_u64(acc));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{NetworkModel, StragglerModel};
    use crate::data::synthetic_3v7;
    use crate::field::PAPER_PRIME;

    fn quiet_cfg(n: usize, t: usize, r: usize) -> BgwConfig {
        BgwConfig {
            n,
            t,
            r,
            net: NetworkModel::free(),
            straggler: StragglerModel::none(),
            ..Default::default()
        }
    }

    #[test]
    fn share_matrix_reconstructs() {
        let f = PrimeField::new(PAPER_PRIME);
        let scheme = ShamirScheme::new(f, 5, 2);
        let mut rng = Rng::new(1);
        let values = [7u64, 0, 123456];
        let shares = share_matrix(&scheme, &values, &mut rng);
        for (e, &v) in values.iter().enumerate() {
            let idx = [0usize, 2, 4];
            let picked: Vec<u64> = idx.iter().map(|&i| shares[i][e]).collect();
            assert_eq!(scheme.reconstruct(&idx, &picked), v);
        }
    }

    #[test]
    fn bgw_gradient_matches_codedprivateml_master_math() {
        // The BGW step with the same seed-independent plaintext inputs
        // must produce the same decoded X̄ᵀḡ as direct plaintext
        // evaluation of the quantized computation with the same W̄ draws.
        // With w = 0 the weight quantization is deterministic (zeros), so
        // the decoded value must be exactly X̄ᵀ(c̄₀·1) dequantized.
        let train = synthetic_3v7(24, 2);
        let mut proto = BgwGradientProtocol::new(quiet_cfg(7, 2, 1), &train).unwrap();
        let xtg = proto.step();
        // Plaintext expectation.
        let f = PrimeField::new(PAPER_PRIME);
        let xq = DatasetQuantizer::new(f, 2);
        let xbar = xq.quantize(&train.x);
        let poly = fit_sigmoid(1, 5.0, 201);
        let coeffs = poly.field_coeffs(&f, 2, 4, 3);
        let g = vec![coeffs[0]; train.m];
        let want_field = crate::compute::tr_matvec_mod(&f, &xbar, &g, train.m, train.d);
        let dq = Dequantizer::new(f, 2, 4, 3, 1);
        for (got, &wq) in xtg.iter().zip(want_field.iter()) {
            let want = dq.dequantize_entry(wq);
            assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        }
    }

    #[test]
    fn bgw_training_converges_like_plaintext() {
        let train = synthetic_3v7(96, 4);
        let test = synthetic_3v7(96, 9);
        let mut proto = BgwGradientProtocol::new(quiet_cfg(7, 2, 1), &train).unwrap();
        let report = proto.train(15, Some(&test));
        assert_eq!(report.iterations.len(), 15);
        let l0 = report.iterations[0].train_loss;
        let lf = report.final_loss().unwrap();
        assert!(lf < l0, "loss {l0} → {lf}");
        assert!(report.final_accuracy().unwrap() > 0.8);
        // One resharing round per iteration at r=1.
        assert_eq!(proto.protocol_report().resharing_rounds, 15);
    }

    #[test]
    fn bgw_r2_uses_more_rounds() {
        let train = synthetic_3v7(16, 5);
        let mut proto = BgwGradientProtocol::new(quiet_cfg(9, 2, 2), &train).unwrap();
        proto.step();
        assert_eq!(proto.protocol_report().resharing_rounds, 2);
    }

    #[test]
    fn rejects_too_few_workers() {
        let train = synthetic_3v7(8, 1);
        assert!(matches!(
            BgwGradientProtocol::new(quiet_cfg(4, 2, 1), &train),
            Err(BgwError::TooFewWorkers { .. })
        ));
    }

    #[test]
    fn worker_storage_is_full_dataset() {
        // The decisive cost asymmetry vs LCC: every worker stores m×d.
        let train = synthetic_3v7(12, 3);
        let proto = BgwGradientProtocol::new(quiet_cfg(5, 1, 1), &train).unwrap();
        for s in &proto.x_shares {
            assert_eq!(s.len(), train.m * train.d);
        }
    }

    #[test]
    fn reshare_preserves_secret_and_reduces_degree() {
        let train = synthetic_3v7(8, 6);
        let mut proto = BgwGradientProtocol::new(quiet_cfg(7, 2, 1), &train).unwrap();
        // Build a degree-2T sharing by multiplying two fresh sharings.
        let f = proto.field;
        let scheme = proto.scheme.clone();
        let mut rng = Rng::new(33);
        let a = [5u64, 1000];
        let b = [3u64, 200000];
        let sa = share_matrix(&scheme, &a, &mut rng);
        let sb = share_matrix(&scheme, &b, &mut rng);
        let prod: Vec<Vec<u64>> = sa
            .iter()
            .zip(sb.iter())
            .map(|(ra, rb)| ra.iter().zip(rb.iter()).map(|(&x, &y)| f.mul(x, y)).collect())
            .collect();
        let reduced = proto.reshare_round(prod);
        // Now reconstructable at degree T (T+1 = 3 shares).
        let got = proto.reconstruct_vec(&reduced, 2);
        assert_eq!(got, vec![15, f.mul(1000, 200000)]);
    }
}
