//! LCC decoder (paper §3.4).
//!
//! Worker i returns h(α_i) = f(X̃_i, W̃_i) ∈ F_p^d where h = f∘(u,v) has
//! degree ≤ (2r+1)(K+T−1). Given any R = deg+1 results, the master
//! interpolates h and reads off the true sub-results h(β_k) = f(X̄_k, W̄).
//!
//! Implementation: for a fixed subset S of responding workers, the map
//! {h(α_i)}_{i∈S} → {h(β_k)}_k is linear — a K×R matrix of Lagrange basis
//! coefficients. Computing it costs O(K·R²) field ops but depends only on
//! S, so it is cached per subset; applying it is a K·R·d dense pass. With
//! straggler patterns repeating across iterations the cache hit rate is
//! high (measured in EXPERIMENTS.md §Perf).

use std::collections::HashMap;

use super::{CodingParams, EvalPoints};
use crate::field::{lagrange_coeffs, PrimeField};
use crate::util::par::{par_ranges, Parallelism};

/// Decoding failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    /// Fewer results than the recovery threshold.
    NotEnoughResults { need: usize, have: usize },
    /// Two results claim the same worker index.
    DuplicateWorker(usize),
    /// A result vector has the wrong length.
    ShapeMismatch { want: usize, got: usize },
    /// Worker index out of range.
    UnknownWorker(usize),
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::NotEnoughResults { need, have } => {
                write!(f, "need {need} results to decode, have {have}")
            }
            DecodeError::DuplicateWorker(w) => write!(f, "duplicate result from worker {w}"),
            DecodeError::ShapeMismatch { want, got } => {
                write!(f, "result length {got}, expected {want}")
            }
            DecodeError::UnknownWorker(w) => write!(f, "worker index {w} out of range"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// A worker's computation result.
#[derive(Debug, Clone)]
pub struct WorkerResult {
    pub worker: usize,
    /// f(X̃_i, W̃_i) ∈ F_p^d.
    pub data: Vec<u64>,
}

/// Decoder with per-subset coefficient cache.
#[derive(Debug)]
pub struct Decoder {
    pub field: PrimeField,
    pub params: CodingParams,
    pub points: EvalPoints,
    /// subset (sorted worker ids) → K rows of R Lagrange coefficients.
    cache: HashMap<Vec<u32>, Vec<Vec<u64>>>,
    hits: u64,
    misses: u64,
    /// Threads for the decode pass, split over output column chunks (the
    /// combination per column is independent, so exact at any setting).
    par: Parallelism,
}

impl Decoder {
    pub fn new(field: PrimeField, params: CodingParams, points: EvalPoints) -> Self {
        Decoder {
            field,
            params,
            points,
            cache: HashMap::new(),
            hits: 0,
            misses: 0,
            par: Parallelism::Serial,
        }
    }

    /// Spread the decode combination across `par` threads.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.par = par;
        self
    }

    /// (cache hits, misses) — perf observability.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Decode the K true sub-results {f(X̄_k, W̄)}_k from worker results.
    /// Exactly the first `recovery_threshold()` results (after validation)
    /// are used — the master never waits for more (§2 "recovery
    /// threshold").
    pub fn decode(&mut self, results: &[WorkerResult], d: usize)
        -> Result<Vec<Vec<u64>>, DecodeError>
    {
        let all: Vec<usize> = (0..self.params.k).collect();
        self.decode_blocks(results, d, &all)
    }

    /// Decode only the requested data blocks (output order follows
    /// `blocks`). The per-subset coefficient cache still holds all K rows
    /// — a mini-batch round skips the dense pass for the other K−b blocks
    /// without evicting anything.
    pub fn decode_blocks(&mut self, results: &[WorkerResult], d: usize, blocks: &[usize])
        -> Result<Vec<Vec<u64>>, DecodeError>
    {
        assert!(
            blocks.iter().all(|&b| b < self.params.k),
            "block index out of range (K = {})",
            self.params.k
        );
        let need = self.params.recovery_threshold();
        if results.len() < need {
            return Err(DecodeError::NotEnoughResults { need, have: results.len() });
        }
        let used = &results[..need];
        let mut seen = vec![false; self.params.n];
        for r in used {
            if r.worker >= self.params.n {
                return Err(DecodeError::UnknownWorker(r.worker));
            }
            if seen[r.worker] {
                return Err(DecodeError::DuplicateWorker(r.worker));
            }
            seen[r.worker] = true;
            if r.data.len() != d {
                return Err(DecodeError::ShapeMismatch { want: d, got: r.data.len() });
            }
        }

        // Cache key: sorted worker ids.
        let mut key: Vec<u32> = used.iter().map(|r| r.worker as u32).collect();
        key.sort_unstable();

        // Order results to match the sorted key so cached coefficients align.
        let mut ordered: Vec<&WorkerResult> = used.iter().collect();
        ordered.sort_unstable_by_key(|r| r.worker);

        if !self.cache.contains_key(&key) {
            let alphas: Vec<u64> = key.iter().map(|&w| self.points.alphas[w as usize]).collect();
            let rows: Vec<Vec<u64>> = self.points.betas[..self.params.k]
                .iter()
                .map(|&b| {
                    lagrange_coeffs(&self.field, &alphas, b)
                        // lint: allow(no-panic-in-library): DuplicateWorker check above guarantees distinct alphas
                        .expect("alphas distinct by construction")
                })
                .collect();
            self.cache.insert(key.clone(), rows);
            self.misses += 1;
        } else {
            self.hits += 1;
        }
        let rows = &self.cache[&key];
        let selected: Vec<&Vec<u64>> = blocks.iter().map(|&b| &rows[b]).collect();

        // h(β_k)[e] = Σ_i λ_i · result_i[e] — a K×R by R×d dense pass
        // (b×R×d when only a batch of blocks is requested). Each output
        // column is independent, so split the d columns into per-thread
        // chunks; within a chunk, accumulate with the deferred Barrett
        // reduction trick from compute::matmul.
        let f = self.field;
        let chunk = crate::compute::safe_chunk_len(f.modulus());
        let col_parts = par_ranges(self.par, d, |_, cols| {
            selected.iter()
                .map(|lam| {
                    let width = cols.len();
                    let mut acc = vec![0u64; width];
                    let mut out_k = vec![0u64; width];
                    let mut pending = 0usize;
                    for (lam_i, r) in lam.iter().zip(ordered.iter()) {
                        let data = &r.data[cols.clone()];
                        for (a, &v) in acc.iter_mut().zip(data.iter()) {
                            *a = a.wrapping_add(lam_i * v);
                        }
                        pending += 1;
                        if pending == chunk {
                            for (o, a) in out_k.iter_mut().zip(acc.iter_mut()) {
                                *o = f.add(*o, f.reduce_u64(*a));
                                *a = 0;
                            }
                            pending = 0;
                        }
                    }
                    if pending > 0 {
                        for (o, a) in out_k.iter_mut().zip(acc.iter()) {
                            *o = f.add(*o, f.reduce_u64(*a));
                        }
                    }
                    out_k
                })
                .collect::<Vec<Vec<u64>>>()
        });
        // Stitch the column chunks back into full-width blocks.
        // (map, not vec![..; n]: cloning an empty Vec drops its capacity.)
        let mut out: Vec<Vec<u64>> = (0..selected.len()).map(|_| Vec::with_capacity(d)).collect();
        for part in col_parts {
            for (k, piece) in part.into_iter().enumerate() {
                out[k].extend(piece);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::Encoder;
    use crate::compute::WorkerComputation;
    use crate::field::{PrimeField, PAPER_PRIME};
    use crate::util::proptest::check;
    use crate::util::Rng;

    /// End-to-end algebraic round trip: encode → worker compute on coded
    /// shares → decode == compute on true blocks. This is THE core
    /// correctness property of CodedPrivateML.
    fn roundtrip(n: usize, k: usize, t: usize, r: usize, rows_per_block: usize, d: usize, seed: u64) {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(n, k, t, r).unwrap();
        let enc = Encoder::new(f, params);
        let mut rng = Rng::new(seed);
        let m = rows_per_block * k;
        // Small-magnitude data so the integer reference stays in range —
        // irrelevant here since we compare field values exactly.
        let xq = f.random_matrix(&mut rng, m, d);
        let wq = f.random_matrix(&mut rng, d, r);
        let coeffs: Vec<u64> = (0..=r).map(|_| f.random(&mut rng)).collect();

        let x_shares = enc.encode_dataset(&xq, m, d, &mut rng);
        let w_shares = enc.encode_weights(&wq, d, r, &mut rng);

        let wc = WorkerComputation::new(f, rows_per_block, d, coeffs.clone());
        let mut results: Vec<WorkerResult> = x_shares
            .iter()
            .zip(w_shares.iter())
            .map(|(xs, ws)| WorkerResult {
                worker: xs.worker,
                data: wc.compute(&xs.data, &ws.data),
            })
            .collect();

        // Straggle: drop a random set of slack workers and shuffle arrival.
        let slack = params.straggler_slack();
        let drop = rng.below_usize(slack + 1);
        rng.shuffle(&mut results);
        results.truncate(n - drop);

        let mut dec = Decoder::new(f, params, enc.points.clone());
        let decoded = dec.decode(&results, d).unwrap();

        // Ground truth: compute on the true blocks.
        let block = rows_per_block * d;
        for kk in 0..k {
            let truth = wc.compute(&xq[kk * block..(kk + 1) * block], &wq);
            assert_eq!(decoded[kk], truth, "block {kk} (n={n},k={k},t={t},r={r})");
        }
    }

    #[test]
    fn encode_compute_decode_roundtrip_r1() {
        roundtrip(10, 3, 1, 1, 2, 4, 1);
        roundtrip(10, 1, 3, 1, 4, 3, 2);
        roundtrip(13, 2, 2, 1, 3, 5, 3);
    }

    #[test]
    fn encode_compute_decode_roundtrip_r2() {
        roundtrip(16, 2, 2, 2, 2, 3, 4);
        roundtrip(11, 2, 1, 2, 3, 4, 5);
    }

    #[test]
    fn roundtrip_paper_cases() {
        // Case 1 / Case 2 at N=10 (scaled rows).
        let c1 = CodingParams::case1(10, 1).unwrap();
        roundtrip(10, c1.k, c1.t, 1, 2, 6, 6);
        let c2 = CodingParams::case2(10, 1).unwrap();
        roundtrip(10, c2.k, c2.t, 1, 2, 6, 7);
    }

    #[test]
    fn roundtrip_property_randomized() {
        check("lcc-roundtrip", 15, |rng| {
            let r = 1 + rng.below_usize(2);
            let k = 1 + rng.below_usize(3);
            let t = 1 + rng.below_usize(2);
            let n = (2 * r + 1) * (k + t - 1) + 1 + rng.below_usize(3);
            let rows = 1 + rng.below_usize(3);
            let d = 1 + rng.below_usize(5);
            roundtrip(n, k, t, r, rows, d, rng.next_u64());
            Ok(())
        });
    }

    #[test]
    fn insufficient_results_error() {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(10, 3, 1, 1).unwrap();
        let enc = Encoder::new(f, params);
        let mut dec = Decoder::new(f, params, enc.points.clone());
        let results: Vec<WorkerResult> = (0..9)
            .map(|w| WorkerResult { worker: w, data: vec![0; 2] })
            .collect();
        assert_eq!(
            dec.decode(&results, 2).unwrap_err(),
            DecodeError::NotEnoughResults { need: 10, have: 9 }
        );
    }

    #[test]
    fn duplicate_and_shape_errors() {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(4, 1, 1, 1).unwrap(); // threshold 4
        let enc = Encoder::new(f, params);
        let mut dec = Decoder::new(f, params, enc.points.clone());
        let mut results: Vec<WorkerResult> = (0..4)
            .map(|w| WorkerResult { worker: w, data: vec![0; 2] })
            .collect();
        results[3].worker = 2;
        assert_eq!(dec.decode(&results, 2).unwrap_err(), DecodeError::DuplicateWorker(2));
        let results: Vec<WorkerResult> = (0..4)
            .map(|w| WorkerResult { worker: w, data: vec![0; 3] })
            .collect();
        assert_eq!(
            dec.decode(&results, 2).unwrap_err(),
            DecodeError::ShapeMismatch { want: 2, got: 3 }
        );
        let mut results: Vec<WorkerResult> = (0..4)
            .map(|w| WorkerResult { worker: w, data: vec![0; 2] })
            .collect();
        results[0].worker = 99;
        assert_eq!(dec.decode(&results, 2).unwrap_err(), DecodeError::UnknownWorker(99));
    }

    #[test]
    fn decode_uses_only_threshold_results() {
        // Extra results beyond R are ignored — even garbage ones.
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(8, 2, 1, 1).unwrap(); // threshold 7
        let enc = Encoder::new(f, params);
        let mut rng = Rng::new(9);
        let (m, d) = (4, 3);
        let xq = f.random_matrix(&mut rng, m, d);
        let wq = f.random_matrix(&mut rng, d, 1);
        let coeffs = vec![f.random(&mut rng), f.random(&mut rng)];
        let xs = enc.encode_dataset(&xq, m, d, &mut rng);
        let ws = enc.encode_weights(&wq, d, 1, &mut rng);
        let wc = WorkerComputation::new(f, 2, d, coeffs);
        let mut results: Vec<WorkerResult> = xs
            .iter()
            .zip(ws.iter())
            .map(|(x, w)| WorkerResult { worker: x.worker, data: wc.compute(&x.data, &w.data) })
            .collect();
        // Corrupt the 8th result; decode must not look at it.
        results[7].data = vec![12345; d];
        let mut dec = Decoder::new(f, params, enc.points.clone());
        let decoded = dec.decode(&results, d).unwrap();
        let block = 2 * d;
        for kk in 0..2 {
            let truth = wc.compute(&xq[kk * block..(kk + 1) * block], &wq);
            assert_eq!(decoded[kk], truth);
        }
    }

    #[test]
    fn cache_hits_on_repeated_subset() {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(5, 1, 1, 1).unwrap(); // threshold 4
        let enc = Encoder::new(f, params);
        let mut dec = Decoder::new(f, params, enc.points.clone());
        let results: Vec<WorkerResult> = (0..4)
            .map(|w| WorkerResult { worker: w, data: vec![1; 2] })
            .collect();
        dec.decode(&results, 2).unwrap();
        dec.decode(&results, 2).unwrap();
        // Different subset → miss.
        let results2: Vec<WorkerResult> = (1..5)
            .map(|w| WorkerResult { worker: w, data: vec![1; 2] })
            .collect();
        dec.decode(&results2, 2).unwrap();
        assert_eq!(dec.cache_stats(), (1, 2));
    }

    #[test]
    fn parallel_decode_is_bit_exact_with_serial() {
        use crate::util::par::Parallelism;
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(10, 3, 1, 1).unwrap();
        let enc = Encoder::new(f, params);
        let mut rng = Rng::new(31);
        let d = 37; // not a multiple of typical chunk splits
        let need = params.recovery_threshold();
        let results: Vec<WorkerResult> = (0..need)
            .map(|w| WorkerResult { worker: w, data: f.random_matrix(&mut rng, d, 1) })
            .collect();
        let mut serial = Decoder::new(f, params, enc.points.clone());
        let want = serial.decode(&results, d).unwrap();
        for threads in [2usize, 3, 8, 64] {
            let mut dec = Decoder::new(f, params, enc.points.clone())
                .with_parallelism(Parallelism::from_count(threads));
            assert_eq!(dec.decode(&results, d).unwrap(), want, "threads={threads}");
        }
    }

    #[test]
    fn decode_blocks_matches_full_decode() {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(13, 3, 1, 1).unwrap(); // threshold 10
        let enc = Encoder::new(f, params);
        let mut rng = Rng::new(77);
        let d = 5;
        let results: Vec<WorkerResult> = (0..params.recovery_threshold())
            .map(|w| WorkerResult { worker: w, data: f.random_matrix(&mut rng, d, 1) })
            .collect();
        let mut dec = Decoder::new(f, params, enc.points.clone());
        let full = dec.decode(&results, d).unwrap();
        // Any batch, any order, must match the corresponding full blocks —
        // and reuse the same cached subset coefficients (1 miss total).
        let batch = dec.decode_blocks(&results, d, &[2, 0]).unwrap();
        assert_eq!(batch[0], full[2]);
        assert_eq!(batch[1], full[0]);
        let single = dec.decode_blocks(&results, d, &[1]).unwrap();
        assert_eq!(single[0], full[1]);
        assert_eq!(dec.cache_stats(), (2, 1));
    }

    #[test]
    fn decode_invariant_to_arrival_order() {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(7, 2, 1, 1).unwrap(); // threshold 7
        let enc = Encoder::new(f, params);
        let mut rng = Rng::new(21);
        let (m, d) = (4, 2);
        let xq = f.random_matrix(&mut rng, m, d);
        let wq = f.random_matrix(&mut rng, d, 1);
        let xs = enc.encode_dataset(&xq, m, d, &mut rng);
        let ws = enc.encode_weights(&wq, d, 1, &mut rng);
        let wc = WorkerComputation::new(f, 2, d, vec![3, 5]);
        let mut results: Vec<WorkerResult> = xs
            .iter()
            .zip(ws.iter())
            .map(|(x, w)| WorkerResult { worker: x.worker, data: wc.compute(&x.data, &w.data) })
            .collect();
        let mut dec = Decoder::new(f, params, enc.points.clone());
        let a = dec.decode(&results, d).unwrap();
        results.reverse();
        let b = dec.decode(&results, d).unwrap();
        assert_eq!(a, b);
    }
}
