//! LCC decoder (paper §3.4).
//!
//! Worker i returns h(α_i) = f(X̃_i, W̃_i) ∈ F_p^d where h = f∘(u,v) has
//! degree ≤ (2r+1)(K+T−1). Given any R = deg+1 results, the master
//! interpolates h and reads off the true sub-results h(β_k) = f(X̄_k, W̄).
//!
//! Implementation: for a fixed subset S of responding workers, the map
//! {h(α_i)}_{i∈S} → {h(β_k)}_k is linear — a K×R matrix of Lagrange basis
//! coefficients. On the dense layout computing it costs O(K·R²) field ops;
//! on a coset layout ([`EvalPoints::ntt_coset`]) the α's are roots of
//! `z^l2 − s^l2`, so the barycentric weights collapse to closed-form
//! products over the *complement* of S — O((K+R)·(l2−R) + K·R) — and yield
//! bit-identical coefficients. Either way the matrix depends only on S, so
//! it is cached per subset (LRU-bounded, see [`Decoder::with_cache_cap`]);
//! applying it is a K·R·d dense pass. With straggler patterns repeating
//! across iterations the cache hit rate is high (measured in
//! EXPERIMENTS.md §Perf).

use std::collections::{HashMap, VecDeque};

use super::{CodingParams, CosetLayout, EvalPoints};
use crate::field::{lagrange_coeffs, simd, PrimeField};
use crate::util::par::{par_ranges, Parallelism};

/// Default bound on the per-subset coefficient cache. Each entry is
/// K·R u64s; straggler patterns in a session cycle through far fewer than
/// this, so the default never evicts in practice while still bounding
/// multi-session memory.
pub const DEFAULT_CACHE_CAP: usize = 256;

/// Decoding failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    /// Fewer results than the recovery threshold.
    NotEnoughResults { need: usize, have: usize },
    /// Two results claim the same worker index.
    DuplicateWorker(usize),
    /// A result vector has the wrong length.
    ShapeMismatch { want: usize, got: usize },
    /// Worker index out of range.
    UnknownWorker(usize),
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::NotEnoughResults { need, have } => {
                write!(f, "need {need} results to decode, have {have}")
            }
            DecodeError::DuplicateWorker(w) => write!(f, "duplicate result from worker {w}"),
            DecodeError::ShapeMismatch { want, got } => {
                write!(f, "result length {got}, expected {want}")
            }
            DecodeError::UnknownWorker(w) => write!(f, "worker index {w} out of range"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// A worker's computation result.
#[derive(Debug, Clone)]
pub struct WorkerResult {
    pub worker: usize,
    /// f(X̃_i, W̃_i) ∈ F_p^d.
    pub data: Vec<u64>,
}

/// Result of a degraded-mode decode ([`Decoder::decode_approx`]).
#[derive(Debug, Clone)]
pub struct ApproxDecode {
    /// One decoded vector per requested block (order follows the `blocks`
    /// argument), same shape as the exact path's output.
    pub blocks: Vec<Vec<u64>>,
    /// RMS least-squares fit residual in centered-lift units, over all
    /// (result, element) pairs. 0.0 when the exact path was taken. Large
    /// residuals mean the available evaluations are not consistent with a
    /// low-degree real polynomial — i.e. the estimate is unreliable (with
    /// T ≥ 1 masks that is the *expected* regime; see the method docs).
    pub residual: f64,
    /// Results actually consumed (R′).
    pub used: usize,
    /// True when ≥ R results were available and the exact decoder ran.
    pub exact: bool,
}

/// Degree cap for the degraded-mode least-squares fit.
const APPROX_DEGREE_CAP: usize = 3;
/// Ridge regularizer added to the normal equations — keeps them SPD (and
/// every elimination pivot nonzero) even for degenerate abscissae.
const APPROX_RIDGE: f64 = 1e-9;

/// Key of one cached coefficient matrix: the decoder's layout
/// fingerprint plus the sorted responding-worker subset. Keying by
/// fingerprint makes entries self-describing — coefficients computed for
/// one modulus + eval-point layout can never be served to another, even
/// if sessions ever share (or swap) cache storage.
type CacheKey = (u64, Vec<u32>);

/// Decoder with per-subset coefficient cache.
#[derive(Debug)]
pub struct Decoder {
    pub field: PrimeField,
    pub params: CodingParams,
    pub points: EvalPoints,
    /// FNV-1a digest of (modulus, α's, β's, coset marker) — the full
    /// identity of the Lagrange coefficient space this decoder works in.
    fingerprint: u64,
    /// (fingerprint, sorted worker ids) → K rows of R Lagrange
    /// coefficients.
    cache: HashMap<CacheKey, Vec<Vec<u64>>>,
    /// Recency order of cached subsets (front = least recently used).
    order: VecDeque<CacheKey>,
    /// Max cached subsets; 0 = unbounded.
    cache_cap: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
    /// Threads for the decode pass, split over output column chunks (the
    /// combination per column is independent, so exact at any setting).
    par: Parallelism,
}

/// One FNV-1a step over a u64 (little-endian bytes).
fn fnv1a(mut h: u64, v: u64) -> u64 {
    for b in v.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

impl Decoder {
    pub fn new(field: PrimeField, params: CodingParams, points: EvalPoints) -> Self {
        // Digest everything the cached coefficients depend on: the field
        // modulus, every evaluation point, and whether the coset
        // (closed-form barycentric) layout is active. Two decoders agree
        // on a fingerprint iff their caches are interchangeable.
        let mut fp = fnv1a(0xcbf2_9ce4_8422_2325, field.modulus());
        fp = fnv1a(fp, points.coset.is_some() as u64);
        for &a in &points.alphas {
            fp = fnv1a(fp, a);
        }
        for &b in &points.betas {
            fp = fnv1a(fp, b);
        }
        Decoder {
            field,
            params,
            points,
            fingerprint: fp,
            cache: HashMap::new(),
            order: VecDeque::new(),
            cache_cap: DEFAULT_CACHE_CAP,
            hits: 0,
            misses: 0,
            evictions: 0,
            par: Parallelism::Serial,
        }
    }

    /// Spread the decode combination across `par` threads.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.par = par;
        self
    }

    /// Bound the subset-coefficient cache to `cap` entries (LRU eviction;
    /// 0 = unbounded). Surfaced as `decode_cache_cap` in the config.
    pub fn with_cache_cap(mut self, cap: usize) -> Self {
        self.cache_cap = cap;
        self
    }

    /// (cache hits, misses) — perf observability.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Subsets evicted from the coefficient cache (LRU, beyond the cap).
    pub fn cache_evictions(&self) -> u64 {
        self.evictions
    }

    /// The modulus + eval-point layout digest this decoder keys its cache
    /// entries with. Two sessions share a fingerprint exactly when their
    /// cached coefficient matrices would be interchangeable.
    pub fn cache_fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Decode the K true sub-results {f(X̄_k, W̄)}_k from worker results.
    /// Exactly the first `recovery_threshold()` results (after validation)
    /// are used — the master never waits for more (§2 "recovery
    /// threshold").
    pub fn decode(&mut self, results: &[WorkerResult], d: usize)
        -> Result<Vec<Vec<u64>>, DecodeError>
    {
        let all: Vec<usize> = (0..self.params.k).collect();
        self.decode_blocks(results, d, &all)
    }

    /// Decode only the requested data blocks (output order follows
    /// `blocks`). The per-subset coefficient cache still holds all K rows
    /// — a mini-batch round skips the dense pass for the other K−b blocks
    /// without evicting anything.
    pub fn decode_blocks(&mut self, results: &[WorkerResult], d: usize, blocks: &[usize])
        -> Result<Vec<Vec<u64>>, DecodeError>
    {
        assert!(
            blocks.iter().all(|&b| b < self.params.k),
            "block index out of range (K = {})",
            self.params.k
        );
        let need = self.params.recovery_threshold();
        if results.len() < need {
            return Err(DecodeError::NotEnoughResults { need, have: results.len() });
        }
        let used = &results[..need];
        let mut seen = vec![false; self.params.n];
        for r in used {
            if r.worker >= self.params.n {
                return Err(DecodeError::UnknownWorker(r.worker));
            }
            if seen[r.worker] {
                return Err(DecodeError::DuplicateWorker(r.worker));
            }
            seen[r.worker] = true;
            if r.data.len() != d {
                return Err(DecodeError::ShapeMismatch { want: d, got: r.data.len() });
            }
        }

        // Cache key: layout fingerprint + sorted worker ids.
        let mut ids: Vec<u32> = used.iter().map(|r| r.worker as u32).collect();
        ids.sort_unstable();
        let key: CacheKey = (self.fingerprint, ids);

        // Order results to match the sorted key so cached coefficients align.
        let mut ordered: Vec<&WorkerResult> = used.iter().collect();
        ordered.sort_unstable_by_key(|r| r.worker);

        if self.cache.contains_key(&key) {
            self.hits += 1;
            // Refresh recency: move the key to the back of the LRU order.
            if let Some(pos) = self.order.iter().position(|k| *k == key) {
                self.order.remove(pos);
                self.order.push_back(key.clone());
            }
        } else {
            let rows = self.subset_rows(&key.1);
            self.cache.insert(key.clone(), rows);
            self.order.push_back(key.clone());
            self.misses += 1;
            if self.cache_cap > 0 && self.cache.len() > self.cache_cap {
                if let Some(old) = self.order.pop_front() {
                    self.cache.remove(&old);
                    self.evictions += 1;
                }
            }
        }
        let rows = &self.cache[&key];
        let selected: Vec<&Vec<u64>> = blocks.iter().map(|&b| &rows[b]).collect();

        // h(β_k)[e] = Σ_i λ_i · result_i[e] — a K×R by R×d dense pass
        // (b×R×d when only a batch of blocks is requested). Each output
        // column is independent, so split the d columns into per-thread
        // chunks; within a chunk, accumulate with the deferred Barrett
        // reduction trick from compute::matmul via the lane kernels.
        let f = self.field;
        let chunk = crate::compute::safe_chunk_len(f.modulus());
        let col_parts = par_ranges(self.par, d, |_, cols| {
            selected.iter()
                .map(|lam| {
                    let width = cols.len();
                    let mut acc = vec![0u64; width];
                    let mut out_k = vec![0u64; width];
                    let mut pending = 0usize;
                    for (lam_i, r) in lam.iter().zip(ordered.iter()) {
                        simd::mac_wrapping(&mut acc, &r.data[cols.clone()], *lam_i);
                        pending += 1;
                        if pending == chunk {
                            simd::fold_reduce(&f, &mut out_k, &mut acc);
                            pending = 0;
                        }
                    }
                    if pending > 0 {
                        simd::fold_reduce(&f, &mut out_k, &mut acc);
                    }
                    out_k
                })
                .collect::<Vec<Vec<u64>>>()
        });
        // Stitch the column chunks back into full-width blocks.
        // (map, not vec![..; n]: cloning an empty Vec drops its capacity.)
        let mut out: Vec<Vec<u64>> = (0..selected.len()).map(|_| Vec::with_capacity(d)).collect();
        for part in col_parts {
            for (k, piece) in part.into_iter().enumerate() {
                out[k].extend(piece);
            }
        }
        Ok(out)
    }

    /// Degraded-mode decode from R′ < R results (least-squares over the
    /// available evaluations), falling through to the exact path whenever
    /// ≥ R results are present.
    ///
    /// **What this is — and is not.** With privacy masks (T ≥ 1) the
    /// coded evaluations are information-theoretically uniform to any
    /// R′ < R subset: no estimator can recover the true sub-results from
    /// too few shares, and this method does not claim to. It is a
    /// *liveness* mechanism in the spirit of Approximated Coded Computing
    /// (arXiv:2406.04747): when the live pool dips below R mid-training,
    /// the session can keep stepping on a bounded surrogate gradient
    /// instead of aborting, then resume exact decoding the moment the
    /// pool heals. The surrogate is a degree-capped polynomial fit in a
    /// *real* surrogate coordinate (worker index mapped into [−1, 1], the
    /// same for the K block targets), on the centered lifts of the
    /// available values, ridge-regularized and clipped to ±`clip`. The
    /// returned [`ApproxDecode::residual`] quantifies how badly the fit
    /// explains the data — callers surface it per-iteration so the
    /// degraded rounds are auditable, and the accompanying weight-clip
    /// keeps a garbage round from destroying the trajectory. Exact rounds
    /// (the common case) are bit-identical to [`Decoder::decode_blocks`].
    ///
    /// `clip` bounds each output's centered magnitude; 0 means "field
    /// half-range" (no extra clipping). Callers are expected to enforce
    /// their R_min floor *before* calling; here only R′ ≥ 1 plus the
    /// usual validation is required.
    pub fn decode_approx(
        &mut self,
        results: &[WorkerResult],
        d: usize,
        blocks: &[usize],
        clip: u64,
    ) -> Result<ApproxDecode, DecodeError> {
        let need = self.params.recovery_threshold();
        if results.len() >= need {
            let out = self.decode_blocks(results, d, blocks)?;
            return Ok(ApproxDecode { blocks: out, residual: 0.0, used: need, exact: true });
        }
        assert!(
            blocks.iter().all(|&b| b < self.params.k),
            "block index out of range (K = {})",
            self.params.k
        );
        if results.is_empty() {
            return Err(DecodeError::NotEnoughResults { need, have: 0 });
        }
        let mut seen = vec![false; self.params.n];
        for r in results {
            if r.worker >= self.params.n {
                return Err(DecodeError::UnknownWorker(r.worker));
            }
            if seen[r.worker] {
                return Err(DecodeError::DuplicateWorker(r.worker));
            }
            seen[r.worker] = true;
            if r.data.len() != d {
                return Err(DecodeError::ShapeMismatch { want: d, got: r.data.len() });
            }
        }

        let rp = results.len();
        let n = self.params.n as f64;
        let cols = rp.saturating_sub(1).min(APPROX_DEGREE_CAP) + 1;
        // Surrogate abscissae: worker / block indices mapped into [−1, 1].
        let u: Vec<f64> = results
            .iter()
            .map(|r| -1.0 + 2.0 * (r.worker as f64 + 0.5) / n)
            .collect();
        // Vandermonde A (R′ × cols), normal matrix M = AᵀA + λI, and the
        // pseudo-inverse apply P = M⁻¹Aᵀ (cols × R′).
        let a: Vec<Vec<f64>> = u
            .iter()
            .map(|&ui| {
                let mut row = Vec::with_capacity(cols);
                let mut pw = 1.0;
                for _ in 0..cols {
                    row.push(pw);
                    pw *= ui;
                }
                row
            })
            .collect();
        let mut m = vec![vec![0.0f64; cols]; cols];
        for i in 0..cols {
            for j in 0..cols {
                m[i][j] = (0..rp).map(|r| a[r][i] * a[r][j]).sum();
            }
            m[i][i] += APPROX_RIDGE;
        }
        let at: Vec<Vec<f64>> = (0..cols).map(|j| (0..rp).map(|i| a[i][j]).collect()).collect();
        let p_mat = solve_spd(m, at);
        // G = E·P: one weight row per requested block; estimate_k = G_k·y.
        let kf = self.params.k as f64;
        let g: Vec<Vec<f64>> = blocks
            .iter()
            .map(|&b| {
                let v = -1.0 + 2.0 * (b as f64 + 0.5) / kf;
                (0..rp)
                    .map(|i| {
                        let mut s = 0.0;
                        let mut pw = 1.0;
                        for row in p_mat.iter() {
                            s += pw * row[i];
                            pw *= v;
                        }
                        s
                    })
                    .collect()
            })
            .collect();

        let f = self.field;
        let p_mod = f.modulus();
        let half = (p_mod - 1) / 2;
        let bound = if clip == 0 { half as f64 } else { clip.min(half) as f64 };
        let mut sq = 0.0f64;
        let mut out: Vec<Vec<u64>> = blocks.iter().map(|_| vec![0u64; d]).collect();
        for e in 0..d {
            // Centered lifts of the available evaluations.
            let y: Vec<f64> = results
                .iter()
                .map(|r| {
                    let v = r.data[e];
                    if v > half {
                        v as f64 - p_mod as f64
                    } else {
                        v as f64
                    }
                })
                .collect();
            // Fit residual: y − A·(P·y), accumulated across elements.
            let c: Vec<f64> = p_mat
                .iter()
                .map(|row| (0..rp).map(|i| row[i] * y[i]).sum())
                .collect();
            for i in 0..rp {
                let fit: f64 = (0..cols).map(|j| a[i][j] * c[j]).sum();
                let res = y[i] - fit;
                sq += res * res;
            }
            for (kk, grow) in g.iter().enumerate() {
                let est: f64 = (0..rp).map(|i| grow[i] * y[i]).sum();
                let est = est.clamp(-bound, bound).round();
                out[kk][e] = if est < 0.0 {
                    p_mod - ((-est) as u64)
                } else {
                    est as u64
                };
                debug_assert!(out[kk][e] < p_mod);
            }
        }
        let residual = (sq / (rp * d) as f64).sqrt();
        Ok(ApproxDecode { blocks: out, residual, used: rp, exact: false })
    }

    /// The K×R coefficient matrix for one sorted worker subset.
    fn subset_rows(&self, key: &[u32]) -> Vec<Vec<u64>> {
        if let Some(layout) = self.points.coset {
            return self.coset_rows(&layout, key);
        }
        let alphas: Vec<u64> = key.iter().map(|&w| self.points.alphas[w as usize]).collect();
        self.points.betas[..self.params.k]
            .iter()
            .map(|&b| {
                lagrange_coeffs(&self.field, &alphas, b)
                    // lint: allow(no-panic-in-library): DuplicateWorker check above guarantees distinct alphas
                    .expect("alphas distinct by construction")
            })
            .collect()
    }

    /// Closed-form barycentric rows on a coset layout. The subset's α's
    /// are roots of P(z) = z^l2 − s^l2 (the full-coset vanishing
    /// polynomial), so with C = the coset indices *outside* the subset:
    ///
    ///   λ_{k,i} = P(β_k) · c_i / (pβ_k · (β_k − α_i) · P'(α_i))
    ///
    /// where c_i = Π_{j∈C}(α_i − α_j), pβ_k = Π_{j∈C}(β_k − α_j), and
    /// P'(α_i) = l2·α_i^(l2−1). P(β_k) = 1 − s^l2 for every k (β^l2 = 1),
    /// and every denominator is provably nonzero (β ∉ coset, α's distinct,
    /// s^l2 ≠ 1), so one batch inversion covers everything. Exact field
    /// arithmetic on the same mathematical value ⇒ bit-identical to the
    /// dense `lagrange_coeffs` rows.
    fn coset_rows(&self, layout: &CosetLayout, key: &[u32]) -> Vec<Vec<u64>> {
        let f = &self.field;
        let l2 = layout.l2;
        let r = key.len();
        let k = self.params.k;
        // Full coset points s·ω₂^j, and which of them the subset uses.
        let mut coset_pts = Vec::with_capacity(l2);
        let mut cur = layout.shift;
        for _ in 0..l2 {
            coset_pts.push(cur);
            cur = f.mul(cur, layout.omega_l2);
        }
        let mut in_subset = vec![false; l2];
        for &w in key {
            in_subset[w as usize] = true;
        }
        let comp: Vec<u64> =
            (0..l2).filter(|&j| !in_subset[j]).map(|j| coset_pts[j]).collect();
        let sel: Vec<u64> = key.iter().map(|&w| coset_pts[w as usize]).collect();
        // c_i and P'(α_i); pβ_k; then one batch inversion.
        let c: Vec<u64> = sel
            .iter()
            .map(|&a| comp.iter().fold(1u64, |acc, &x| f.mul(acc, f.sub(a, x))))
            .collect();
        let l2e = f.reduce_u64(l2 as u64);
        let dp: Vec<u64> =
            sel.iter().map(|&a| f.mul(l2e, f.pow(a, l2 as u64 - 1))).collect();
        let betas = &self.points.betas[..k];
        let pb: Vec<u64> = betas
            .iter()
            .map(|&b| comp.iter().fold(1u64, |acc, &x| f.mul(acc, f.sub(b, x))))
            .collect();
        let num = f.sub(1, f.pow(layout.shift, l2 as u64));
        // Denominators: [pβ_0..pβ_{K−1}] ++ [dp_0..dp_{R−1}] ++
        // [(β_k − α_i) for all k, i].
        let mut denoms = Vec::with_capacity(k + r + k * r);
        denoms.extend(&pb);
        denoms.extend(&dp);
        for &b in betas {
            for &a in &sel {
                denoms.push(f.sub(b, a));
            }
        }
        let invs = f.batch_inv(&denoms);
        let (inv_pb, rest) = invs.split_at(k);
        let (inv_dp, inv_diff) = rest.split_at(r);
        (0..k)
            .map(|kk| {
                let scale = f.mul(num, inv_pb[kk]);
                (0..r)
                    .map(|i| {
                        f.mul(
                            f.mul(scale, c[i]),
                            f.mul(inv_diff[kk * r + i], inv_dp[i]),
                        )
                    })
                    .collect()
            })
            .collect()
    }
}

/// Gauss–Jordan solve of M·X = B for the degraded-mode fit. M is the
/// ridge-regularized normal matrix ((q+1)² with q ≤ 3, SPD by
/// construction — the λI term bounds every pivot away from zero), B holds
/// Aᵀ's rows. Partial pivoting plus a zero-pivot guard keep this total:
/// no division by zero, no panic path.
fn solve_spd(mut m: Vec<Vec<f64>>, mut b: Vec<Vec<f64>>) -> Vec<Vec<f64>> {
    let nn = m.len();
    for col in 0..nn {
        let mut piv = col;
        for r in col + 1..nn {
            if m[r][col].abs() > m[piv][col].abs() {
                piv = r;
            }
        }
        m.swap(col, piv);
        b.swap(col, piv);
        let diag = m[col][col];
        let inv = if diag.abs() > f64::MIN_POSITIVE { 1.0 / diag } else { 0.0 };
        for j in col..nn {
            m[col][j] *= inv;
        }
        for v in b[col].iter_mut() {
            *v *= inv;
        }
        for r in 0..nn {
            if r == col {
                continue;
            }
            let factor = m[r][col];
            if factor == 0.0 {
                continue;
            }
            for j in col..nn {
                let sub = factor * m[col][j];
                m[r][j] -= sub;
            }
            for j in 0..b[r].len() {
                let sub = factor * b[col][j];
                b[r][j] -= sub;
            }
        }
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::Encoder;
    use crate::compute::WorkerComputation;
    use crate::field::{PrimeField, PAPER_PRIME, PRIME_NTT_25, PRIME_NTT_28};
    use crate::util::proptest::check;
    use crate::util::Rng;

    /// End-to-end algebraic round trip: encode → worker compute on coded
    /// shares → decode == compute on true blocks. This is THE core
    /// correctness property of CodedPrivateML.
    fn roundtrip(n: usize, k: usize, t: usize, r: usize, rows_per_block: usize, d: usize, seed: u64) {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(n, k, t, r).unwrap();
        let enc = Encoder::new(f, params);
        let mut rng = Rng::new(seed);
        let m = rows_per_block * k;
        // Small-magnitude data so the integer reference stays in range —
        // irrelevant here since we compare field values exactly.
        let xq = f.random_matrix(&mut rng, m, d);
        let wq = f.random_matrix(&mut rng, d, r);
        let coeffs: Vec<u64> = (0..=r).map(|_| f.random(&mut rng)).collect();

        let x_shares = enc.encode_dataset(&xq, m, d, &mut rng);
        let w_shares = enc.encode_weights(&wq, d, r, &mut rng);

        let wc = WorkerComputation::new(f, rows_per_block, d, coeffs.clone());
        let mut results: Vec<WorkerResult> = x_shares
            .iter()
            .zip(w_shares.iter())
            .map(|(xs, ws)| WorkerResult {
                worker: xs.worker,
                data: wc.compute(&xs.data, &ws.data),
            })
            .collect();

        // Straggle: drop a random set of slack workers and shuffle arrival.
        let slack = params.straggler_slack();
        let drop = rng.below_usize(slack + 1);
        rng.shuffle(&mut results);
        results.truncate(n - drop);

        let mut dec = Decoder::new(f, params, enc.points.clone());
        let decoded = dec.decode(&results, d).unwrap();

        // Ground truth: compute on the true blocks.
        let block = rows_per_block * d;
        for kk in 0..k {
            let truth = wc.compute(&xq[kk * block..(kk + 1) * block], &wq);
            assert_eq!(decoded[kk], truth, "block {kk} (n={n},k={k},t={t},r={r})");
        }
    }

    #[test]
    fn encode_compute_decode_roundtrip_r1() {
        roundtrip(10, 3, 1, 1, 2, 4, 1);
        roundtrip(10, 1, 3, 1, 4, 3, 2);
        roundtrip(13, 2, 2, 1, 3, 5, 3);
    }

    #[test]
    fn encode_compute_decode_roundtrip_r2() {
        roundtrip(16, 2, 2, 2, 2, 3, 4);
        roundtrip(11, 2, 1, 2, 3, 4, 5);
    }

    #[test]
    fn roundtrip_paper_cases() {
        // Case 1 / Case 2 at N=10 (scaled rows).
        let c1 = CodingParams::case1(10, 1).unwrap();
        roundtrip(10, c1.k, c1.t, 1, 2, 6, 6);
        let c2 = CodingParams::case2(10, 1).unwrap();
        roundtrip(10, c2.k, c2.t, 1, 2, 6, 7);
    }

    #[test]
    fn roundtrip_property_randomized() {
        check("lcc-roundtrip", 15, |rng| {
            let r = 1 + rng.below_usize(2);
            let k = 1 + rng.below_usize(3);
            let t = 1 + rng.below_usize(2);
            let n = (2 * r + 1) * (k + t - 1) + 1 + rng.below_usize(3);
            let rows = 1 + rng.below_usize(3);
            let d = 1 + rng.below_usize(5);
            roundtrip(n, k, t, r, rows, d, rng.next_u64());
            Ok(())
        });
    }

    #[test]
    fn insufficient_results_error() {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(10, 3, 1, 1).unwrap();
        let enc = Encoder::new(f, params);
        let mut dec = Decoder::new(f, params, enc.points.clone());
        let results: Vec<WorkerResult> = (0..9)
            .map(|w| WorkerResult { worker: w, data: vec![0; 2] })
            .collect();
        assert_eq!(
            dec.decode(&results, 2).unwrap_err(),
            DecodeError::NotEnoughResults { need: 10, have: 9 }
        );
    }

    #[test]
    fn duplicate_and_shape_errors() {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(4, 1, 1, 1).unwrap(); // threshold 4
        let enc = Encoder::new(f, params);
        let mut dec = Decoder::new(f, params, enc.points.clone());
        let mut results: Vec<WorkerResult> = (0..4)
            .map(|w| WorkerResult { worker: w, data: vec![0; 2] })
            .collect();
        results[3].worker = 2;
        assert_eq!(dec.decode(&results, 2).unwrap_err(), DecodeError::DuplicateWorker(2));
        let results: Vec<WorkerResult> = (0..4)
            .map(|w| WorkerResult { worker: w, data: vec![0; 3] })
            .collect();
        assert_eq!(
            dec.decode(&results, 2).unwrap_err(),
            DecodeError::ShapeMismatch { want: 2, got: 3 }
        );
        let mut results: Vec<WorkerResult> = (0..4)
            .map(|w| WorkerResult { worker: w, data: vec![0; 2] })
            .collect();
        results[0].worker = 99;
        assert_eq!(dec.decode(&results, 2).unwrap_err(), DecodeError::UnknownWorker(99));
    }

    #[test]
    fn decode_uses_only_threshold_results() {
        // Extra results beyond R are ignored — even garbage ones.
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(8, 2, 1, 1).unwrap(); // threshold 7
        let enc = Encoder::new(f, params);
        let mut rng = Rng::new(9);
        let (m, d) = (4, 3);
        let xq = f.random_matrix(&mut rng, m, d);
        let wq = f.random_matrix(&mut rng, d, 1);
        let coeffs = vec![f.random(&mut rng), f.random(&mut rng)];
        let xs = enc.encode_dataset(&xq, m, d, &mut rng);
        let ws = enc.encode_weights(&wq, d, 1, &mut rng);
        let wc = WorkerComputation::new(f, 2, d, coeffs);
        let mut results: Vec<WorkerResult> = xs
            .iter()
            .zip(ws.iter())
            .map(|(x, w)| WorkerResult { worker: x.worker, data: wc.compute(&x.data, &w.data) })
            .collect();
        // Corrupt the 8th result; decode must not look at it.
        results[7].data = vec![12345; d];
        let mut dec = Decoder::new(f, params, enc.points.clone());
        let decoded = dec.decode(&results, d).unwrap();
        let block = 2 * d;
        for kk in 0..2 {
            let truth = wc.compute(&xq[kk * block..(kk + 1) * block], &wq);
            assert_eq!(decoded[kk], truth);
        }
    }

    #[test]
    fn cache_hits_on_repeated_subset() {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(5, 1, 1, 1).unwrap(); // threshold 4
        let enc = Encoder::new(f, params);
        let mut dec = Decoder::new(f, params, enc.points.clone());
        let results: Vec<WorkerResult> = (0..4)
            .map(|w| WorkerResult { worker: w, data: vec![1; 2] })
            .collect();
        dec.decode(&results, 2).unwrap();
        dec.decode(&results, 2).unwrap();
        // Different subset → miss.
        let results2: Vec<WorkerResult> = (1..5)
            .map(|w| WorkerResult { worker: w, data: vec![1; 2] })
            .collect();
        dec.decode(&results2, 2).unwrap();
        assert_eq!(dec.cache_stats(), (1, 2));
        assert_eq!(dec.cache_evictions(), 0);
    }

    #[test]
    fn cache_evicts_least_recently_used_beyond_cap() {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(6, 1, 1, 1).unwrap(); // threshold 4
        let enc = Encoder::new(f, params);
        let mut dec = Decoder::new(f, params, enc.points.clone()).with_cache_cap(2);
        let subset = |ws: [usize; 4]| -> Vec<WorkerResult> {
            ws.iter().map(|&w| WorkerResult { worker: w, data: vec![1; 2] }).collect()
        };
        let a = subset([0, 1, 2, 3]);
        let b = subset([1, 2, 3, 4]);
        let c = subset([2, 3, 4, 5]);
        dec.decode(&a, 2).unwrap(); // miss  {a}
        dec.decode(&b, 2).unwrap(); // miss  {a,b}
        dec.decode(&a, 2).unwrap(); // hit — refreshes a's recency
        dec.decode(&c, 2).unwrap(); // miss, evicts b (LRU)  {a,c}
        dec.decode(&b, 2).unwrap(); // miss again, evicts a  {c,b}
        dec.decode(&c, 2).unwrap(); // hit
        assert_eq!(dec.cache_stats(), (2, 4));
        assert_eq!(dec.cache_evictions(), 2);
    }

    #[test]
    fn zero_cap_means_unbounded() {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(6, 1, 1, 1).unwrap();
        let enc = Encoder::new(f, params);
        let mut dec = Decoder::new(f, params, enc.points.clone()).with_cache_cap(0);
        for start in 0..3usize {
            let results: Vec<WorkerResult> = (start..start + 4)
                .map(|w| WorkerResult { worker: w, data: vec![1; 2] })
                .collect();
            dec.decode(&results, 2).unwrap();
        }
        assert_eq!(dec.cache_stats(), (0, 3));
        assert_eq!(dec.cache_evictions(), 0);
    }

    #[test]
    fn cache_fingerprint_separates_moduli_and_layouts() {
        // Same modulus + same points → same fingerprint (caches are
        // interchangeable); different modulus or a different eval-point
        // layout → different fingerprint (entries can never cross).
        let params = CodingParams::new(10, 3, 1, 1).unwrap();
        let f_paper = PrimeField::new(PAPER_PRIME);
        let f_ntt = PrimeField::new(PRIME_NTT_25);
        let pts_paper = EvalPoints::standard(&f_paper, 3, 1, 10);
        let a = Decoder::new(f_paper, params, pts_paper.clone());
        let b = Decoder::new(f_paper, params, pts_paper);
        assert_eq!(a.cache_fingerprint(), b.cache_fingerprint());
        let c = Decoder::new(f_ntt, params, EvalPoints::standard(&f_ntt, 3, 1, 10));
        assert_ne!(a.cache_fingerprint(), c.cache_fingerprint(), "modulus in the key");
        let coset = Decoder::new(
            f_ntt,
            params,
            EvalPoints::ntt_coset(&f_ntt, 3, 1, 10).unwrap(),
        );
        assert_ne!(
            c.cache_fingerprint(),
            coset.cache_fingerprint(),
            "point layout in the key"
        );
    }

    #[test]
    fn mixed_modulus_decoders_key_cache_entries_apart() {
        // The serve regression shape: two sessions on different moduli
        // decode the same worker subset. Each entry carries its decoder's
        // fingerprint, so the subsets cannot collide even though the
        // sorted worker ids are identical.
        let params = CodingParams::new(5, 1, 1, 1).unwrap(); // threshold 4
        let f_paper = PrimeField::new(PAPER_PRIME);
        let f_ntt = PrimeField::new(PRIME_NTT_25);
        let mut da = Decoder::new(f_paper, params, EvalPoints::standard(&f_paper, 1, 1, 5));
        let mut db = Decoder::new(f_ntt, params, EvalPoints::standard(&f_ntt, 1, 1, 5));
        assert_ne!(da.cache_fingerprint(), db.cache_fingerprint());
        let results: Vec<WorkerResult> = (0..4)
            .map(|w| WorkerResult { worker: w, data: vec![1; 2] })
            .collect();
        let a = da.decode(&results, 2).unwrap();
        let b = db.decode(&results, 2).unwrap();
        // Repeats hit each decoder's own entry — the fingerprint keeps the
        // identically-numbered subsets distinct.
        assert_eq!(da.decode(&results, 2).unwrap(), a);
        assert_eq!(db.decode(&results, 2).unwrap(), b);
        assert_eq!(da.cache_stats(), (1, 1));
        assert_eq!(db.cache_stats(), (1, 1));
    }

    #[test]
    fn parallel_decode_is_bit_exact_with_serial() {
        use crate::util::par::Parallelism;
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(10, 3, 1, 1).unwrap();
        let enc = Encoder::new(f, params);
        let mut rng = Rng::new(31);
        let d = 37; // not a multiple of typical chunk splits
        let need = params.recovery_threshold();
        let results: Vec<WorkerResult> = (0..need)
            .map(|w| WorkerResult { worker: w, data: f.random_matrix(&mut rng, d, 1) })
            .collect();
        let mut serial = Decoder::new(f, params, enc.points.clone());
        let want = serial.decode(&results, d).unwrap();
        for threads in [2usize, 3, 8, 64] {
            let mut dec = Decoder::new(f, params, enc.points.clone())
                .with_parallelism(Parallelism::from_count(threads));
            assert_eq!(dec.decode(&results, d).unwrap(), want, "threads={threads}");
        }
    }

    #[test]
    fn decode_blocks_matches_full_decode() {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(13, 3, 1, 1).unwrap(); // threshold 10
        let enc = Encoder::new(f, params);
        let mut rng = Rng::new(77);
        let d = 5;
        let results: Vec<WorkerResult> = (0..params.recovery_threshold())
            .map(|w| WorkerResult { worker: w, data: f.random_matrix(&mut rng, d, 1) })
            .collect();
        let mut dec = Decoder::new(f, params, enc.points.clone());
        let full = dec.decode(&results, d).unwrap();
        // Any batch, any order, must match the corresponding full blocks —
        // and reuse the same cached subset coefficients (1 miss total).
        let batch = dec.decode_blocks(&results, d, &[2, 0]).unwrap();
        assert_eq!(batch[0], full[2]);
        assert_eq!(batch[1], full[0]);
        let single = dec.decode_blocks(&results, d, &[1]).unwrap();
        assert_eq!(single[0], full[1]);
        assert_eq!(dec.cache_stats(), (2, 1));
    }

    #[test]
    fn decode_invariant_to_arrival_order() {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(7, 2, 1, 1).unwrap(); // threshold 7
        let enc = Encoder::new(f, params);
        let mut rng = Rng::new(21);
        let (m, d) = (4, 2);
        let xq = f.random_matrix(&mut rng, m, d);
        let wq = f.random_matrix(&mut rng, d, 1);
        let xs = enc.encode_dataset(&xq, m, d, &mut rng);
        let ws = enc.encode_weights(&wq, d, 1, &mut rng);
        let wc = WorkerComputation::new(f, 2, d, vec![3, 5]);
        let mut results: Vec<WorkerResult> = xs
            .iter()
            .zip(ws.iter())
            .map(|(x, w)| WorkerResult { worker: x.worker, data: wc.compute(&x.data, &w.data) })
            .collect();
        let mut dec = Decoder::new(f, params, enc.points.clone());
        let a = dec.decode(&results, d).unwrap();
        results.reverse();
        let b = dec.decode(&results, d).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn approx_with_enough_results_delegates_to_exact() {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(10, 3, 1, 1).unwrap();
        let enc = Encoder::new(f, params);
        let mut rng = Rng::new(55);
        let d = 4;
        let results: Vec<WorkerResult> = (0..params.recovery_threshold())
            .map(|w| WorkerResult { worker: w, data: f.random_matrix(&mut rng, d, 1) })
            .collect();
        let all: Vec<usize> = (0..3).collect();
        let mut dec = Decoder::new(f, params, enc.points.clone());
        let exact = dec.decode(&results, d).unwrap();
        let approx = dec.decode_approx(&results, d, &all, 0).unwrap();
        assert!(approx.exact);
        assert_eq!(approx.residual, 0.0);
        assert_eq!(approx.used, params.recovery_threshold());
        assert_eq!(approx.blocks, exact, "≥R results must be bit-identical to decode()");
    }

    #[test]
    fn approx_recovers_constant_signal_from_partial_results() {
        // Every worker reporting the same vector is a degree-0 polynomial
        // in any coordinate system: the fit is exact, the residual ~0, and
        // every block estimate equals the shared value — including
        // negative (centered) values.
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(10, 3, 1, 1).unwrap(); // need 10
        let enc = Encoder::new(f, params);
        let mut dec = Decoder::new(f, params, enc.points.clone());
        let value = vec![5u64, f.from_i64(-3), 17];
        let results: Vec<WorkerResult> = (0..6)
            .map(|w| WorkerResult { worker: w, data: value.clone() })
            .collect();
        let out = dec.decode_approx(&results, 3, &[0, 1, 2], 0).unwrap();
        assert!(!out.exact);
        assert_eq!(out.used, 6);
        assert!(out.residual < 1e-6, "residual {}", out.residual);
        for (kk, block) in out.blocks.iter().enumerate() {
            assert_eq!(block, &value, "block {kk}");
        }
    }

    #[test]
    fn approx_clip_bounds_every_output() {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(10, 2, 1, 1).unwrap();
        let enc = Encoder::new(f, params);
        let mut dec = Decoder::new(f, params, enc.points.clone());
        let results: Vec<WorkerResult> = (0..4)
            .map(|w| WorkerResult { worker: w, data: vec![100_000, f.from_i64(-100_000)] })
            .collect();
        let out = dec.decode_approx(&results, 2, &[0, 1], 10).unwrap();
        let half = (PAPER_PRIME - 1) / 2;
        for block in &out.blocks {
            for &v in block {
                let centered = if v > half { v as i64 - PAPER_PRIME as i64 } else { v as i64 };
                assert!(centered.abs() <= 10, "clip violated: {centered}");
            }
        }
        assert_eq!(out.blocks[0][0], 10);
        assert_eq!(out.blocks[0][1], f.from_i64(-10));
    }

    #[test]
    fn approx_validates_like_exact_decode() {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(10, 2, 1, 1).unwrap();
        let enc = Encoder::new(f, params);
        let mut dec = Decoder::new(f, params, enc.points.clone());
        let blocks = [0usize, 1];
        assert_eq!(
            dec.decode_approx(&[], 2, &blocks, 0).unwrap_err(),
            DecodeError::NotEnoughResults { need: 10, have: 0 }
        );
        let dup = vec![
            WorkerResult { worker: 1, data: vec![1, 2] },
            WorkerResult { worker: 1, data: vec![3, 4] },
        ];
        assert_eq!(
            dec.decode_approx(&dup, 2, &blocks, 0).unwrap_err(),
            DecodeError::DuplicateWorker(1)
        );
        let bad = vec![WorkerResult { worker: 0, data: vec![1] }];
        assert_eq!(
            dec.decode_approx(&bad, 2, &blocks, 0).unwrap_err(),
            DecodeError::ShapeMismatch { want: 2, got: 1 }
        );
        let unk = vec![WorkerResult { worker: 42, data: vec![1, 2] }];
        assert_eq!(
            dec.decode_approx(&unk, 2, &blocks, 0).unwrap_err(),
            DecodeError::UnknownWorker(42)
        );
    }

    #[test]
    fn approx_fits_linear_trend_with_small_residual() {
        // Values linear in the surrogate coordinate u_w = −1 + 2(w+.5)/N:
        // with N = 10, y_w = 2w − 9 = 10·u_w is exactly representable by
        // the degree-capped fit, so estimates interpolate the trend and
        // the residual collapses.
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(10, 2, 1, 1).unwrap();
        let enc = Encoder::new(f, params);
        let mut dec = Decoder::new(f, params, enc.points.clone());
        let results: Vec<WorkerResult> = (0..5)
            .map(|w| WorkerResult {
                worker: w,
                data: vec![f.from_i64(2 * w as i64 - 9)],
            })
            .collect();
        let out = dec.decode_approx(&results, 1, &[0, 1], 0).unwrap();
        assert!(out.residual < 1e-6, "residual {}", out.residual);
        // Block targets v_0 = −0.5, v_1 = 0.5 → estimates 10·v = ∓5.
        assert_eq!(out.blocks[0][0], f.from_i64(-5));
        assert_eq!(out.blocks[1][0], f.from_i64(5));
    }

    #[test]
    fn coset_rows_match_dense_lagrange_all_moduli() {
        // The closed-form barycentric rows must be bit-identical to the
        // O(K·R²) lagrange_coeffs rows for random straggler subsets.
        for &p in &[97u64, PRIME_NTT_25, PRIME_NTT_28] {
            let f = PrimeField::new(p);
            for &(n, k, t) in &[(10usize, 3usize, 1usize), (13, 2, 2), (16, 4, 1)] {
                let params = CodingParams::new(n, k, t, 1).unwrap();
                let pts = EvalPoints::ntt_coset(&f, k, t, n).unwrap();
                let dec = Decoder::new(f, params, pts.clone());
                let need = params.recovery_threshold();
                let mut rng = Rng::new(p.wrapping_mul(31) ^ n as u64);
                for _ in 0..5 {
                    let mut ids: Vec<u32> = (0..n as u32).collect();
                    rng.shuffle(&mut ids);
                    let mut key = ids[..need].to_vec();
                    key.sort_unstable();
                    let layout = pts.coset.unwrap();
                    let fast = dec.coset_rows(&layout, &key);
                    let alphas: Vec<u64> =
                        key.iter().map(|&w| pts.alphas[w as usize]).collect();
                    for (kk, row) in fast.iter().enumerate() {
                        let want =
                            lagrange_coeffs(&f, &alphas, pts.betas[kk]).unwrap();
                        assert_eq!(row, &want, "p={p} n={n} k={kk} key={key:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn ntt_roundtrip_with_stragglers() {
        // Full pipeline on the coset layout: NTT encode → worker compute →
        // barycentric decode equals compute on the true blocks.
        let f = PrimeField::new(PRIME_NTT_25);
        let params = CodingParams::new(13, 2, 2, 1).unwrap(); // threshold 10
        let pts = EvalPoints::ntt_coset(&f, 2, 2, 13).unwrap();
        let enc = Encoder::with_points(f, params, pts).force_ntt();
        let mut rng = Rng::new(40);
        let (rows, d) = (3, 5);
        let m = rows * 2;
        let xq = f.random_matrix(&mut rng, m, d);
        let wq = f.random_matrix(&mut rng, d, 1);
        let coeffs: Vec<u64> = (0..2).map(|_| f.random(&mut rng)).collect();
        let xs = enc.encode_dataset(&xq, m, d, &mut rng);
        let ws = enc.encode_weights(&wq, d, 1, &mut rng);
        let wc = WorkerComputation::new(f, rows, d, coeffs);
        let mut results: Vec<WorkerResult> = xs
            .iter()
            .zip(ws.iter())
            .map(|(x, w)| WorkerResult { worker: x.worker, data: wc.compute(&x.data, &w.data) })
            .collect();
        rng.shuffle(&mut results);
        results.truncate(10); // drop the full straggler slack
        let mut dec = Decoder::new(f, params, enc.points.clone());
        let decoded = dec.decode(&results, d).unwrap();
        let block = rows * d;
        for kk in 0..2 {
            let truth = wc.compute(&xq[kk * block..(kk + 1) * block], &wq);
            assert_eq!(decoded[kk], truth, "block {kk}");
        }
    }
}
