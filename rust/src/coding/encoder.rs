//! LCC encoder (paper §3.2).
//!
//! **Dense path** (any modulus): the encoding matrix U ∈ F_p^{(K+T)×N} has
//! column i equal to the Lagrange basis coefficients of the β points
//! evaluated at α_i (eq. 12), so worker i's share is a fixed linear
//! combination of the K data blocks and T masks:
//! `X̃_i = Σ_j U[j,i]·block_j`. Weight shares exploit that the first K
//! blocks are all W̄ (eq. 14): `Σ_{j<K} U[j,i]·W̄ = s_i·W̄` with the column
//! sums s_i precomputed — an O(K) → O(1) saving per entry that dominates
//! the per-iteration encode cost (EXPERIMENTS.md §Perf). U is built lazily
//! (first dense encode / `u_column` call) so a session on the NTT backend
//! never pays the O((K+T)²·N) setup, and a session sharing one `Encoder`
//! for dataset and weights builds it exactly once.
//!
//! **NTT path** ([`EvalPoints::ntt_coset`] layouts): the share polynomial's
//! values at the β subgroup are converted to coefficients (a size-l1
//! inverse transform when K+T fills the subgroup, else a precomputed
//! (K+T)² basis change), twisted by powers of the coset shift, and
//! evaluated at all α's at once with a size-l2 forward transform —
//! O(l2 log l2) per element column instead of O(N·(K+T)). Both paths
//! evaluate the same polynomial at the same points with exact field
//! arithmetic, so their outputs are bit-identical.

use super::{CodingBackend, CodingParams, CosetLayout, EvalPoints};
use crate::field::{interpolate, lagrange_coeffs, simd, NttPlan, PrimeField};
use crate::util::par::{par_map, par_ranges, Parallelism};
use crate::util::Rng;
use std::sync::OnceLock;

/// Column width of the structure-of-arrays NTT strips: big enough to
/// amortize the butterfly loop overhead, small enough that an l2-row
/// buffer stays cache-resident (256 rows × 512 cols × 8 B = 1 MiB).
const NTT_STRIP: usize = 512;

/// One worker's coded share of the dataset (or of the weights).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EncodedShare {
    /// Worker index (0-based) — identifies the α point.
    pub worker: usize,
    /// Row-major payload.
    pub data: Vec<u64>,
}

/// The dense encoding matrix, built on first use and shared by the
/// dataset and weight encode paths.
#[derive(Debug, Clone)]
struct UMatrix {
    /// U stored column-major: `cols[i]` is worker i's coefficient vector
    /// (length K+T).
    cols: Vec<Vec<u64>>,
    /// `Σ_{j<K} U[j,i]` per worker — the replicated-secret shortcut.
    top_sums: Vec<u64>,
}

/// Precomputed transforms for the coset fast path.
#[derive(Debug, Clone)]
struct NttEncoder {
    layout: CosetLayout,
    /// Values at the full β subgroup → coefficients, when K+T == l1.
    plan_l1: Option<NttPlan>,
    /// Otherwise: `interp[c][j]` maps value at β_j to coefficient c of
    /// the degree-<K+T interpolant (rows 0..K+T; higher rows are zero).
    interp: Option<Vec<Vec<u64>>>,
    /// Coefficients (twisted) → values at the α coset.
    plan_l2: NttPlan,
    /// shift^c for the coefficient twist u(s·z) = Σ (c_t·s^t)·z^t.
    shift_pows: Vec<u64>,
}

impl NttEncoder {
    fn new(f: &PrimeField, layout: &CosetLayout, kt: usize) -> Self {
        let plan_l2 = NttPlan::with_root(*f, layout.l2, layout.omega_l2);
        let (plan_l1, interp) = if kt == layout.l1 {
            (Some(NttPlan::with_root(*f, layout.l1, layout.omega_l1)), None)
        } else {
            let betas: Vec<u64> = (0..kt).map(|j| f.pow(layout.omega_l1, j as u64)).collect();
            let mut rows = vec![vec![0u64; kt]; kt];
            for j in 0..kt {
                let mut unit = vec![0u64; kt];
                unit[j] = 1;
                let coeffs = interpolate(f, &betas, &unit)
                    // lint: allow(no-panic-in-library): coset betas are distinct powers of an order-l1 root
                    .expect("coset betas are distinct");
                for (c, &v) in coeffs.iter().enumerate() {
                    rows[c][j] = v;
                }
            }
            (None, Some(rows))
        };
        let mut shift_pows = Vec::with_capacity(kt);
        let mut s = 1u64;
        for _ in 0..kt {
            shift_pows.push(s);
            s = f.mul(s, layout.shift);
        }
        NttEncoder { layout: *layout, plan_l1, interp, plan_l2, shift_pows }
    }
}

/// Encoder for a fixed (field, params, points) session.
#[derive(Debug, Clone)]
pub struct Encoder {
    pub field: PrimeField,
    pub params: CodingParams,
    pub points: EvalPoints,
    /// Dense U matrix, built lazily (never for a pure-NTT session).
    u: OnceLock<UMatrix>,
    /// Engaged NTT fast path, if the points are a coset layout and the
    /// cost model (or an explicit force) selected it.
    ntt: Option<NttEncoder>,
    /// Threads for the encode fan-out (mask randomness is drawn before
    /// fan-out, so shares are identical at any setting).
    par: Parallelism,
}

impl Encoder {
    pub fn new(field: PrimeField, params: CodingParams) -> Self {
        let points = EvalPoints::standard(&field, params.k, params.t, params.n);
        Self::with_points(field, params, points)
    }

    /// Build for an explicit point layout. Coset layouts engage the NTT
    /// path automatically when the cost model says it beats the dense
    /// combine at this (K, T, N); `force_dense` / `force_ntt` override.
    pub fn with_points(field: PrimeField, params: CodingParams, points: EvalPoints) -> Self {
        assert_eq!(points.betas.len(), params.k + params.t);
        assert_eq!(points.alphas.len(), params.n);
        let kt = params.k + params.t;
        let ntt = points.coset.as_ref().and_then(|layout| {
            if layout.ntt_encode_cost(kt) < CosetLayout::dense_encode_cost(kt, params.n) {
                Some(NttEncoder::new(&field, layout, kt))
            } else {
                None
            }
        });
        Encoder { field, params, points, u: OnceLock::new(), ntt, par: Parallelism::Serial }
    }

    /// Spread the N per-worker share computations across `par` threads.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.par = par;
        self
    }

    /// Use the dense combine even on a coset layout (bit-identical).
    pub fn force_dense(mut self) -> Self {
        self.ntt = None;
        self
    }

    /// Use the NTT path regardless of the cost model. The points must be
    /// a coset layout.
    pub fn force_ntt(mut self) -> Self {
        assert!(
            self.points.coset.is_some(),
            "ntt backend requires EvalPoints::ntt_coset points"
        );
        if let Some(layout) = self.points.coset {
            let kt = self.params.k + self.params.t;
            self.ntt = Some(NttEncoder::new(&self.field, &layout, kt));
        }
        self
    }

    /// Which encode implementation this session runs.
    pub fn backend(&self) -> CodingBackend {
        if self.ntt.is_some() {
            CodingBackend::Ntt
        } else {
            CodingBackend::Dense
        }
    }

    /// The dense encoding matrix, built on first use.
    fn u(&self) -> &UMatrix {
        self.u.get_or_init(|| {
            let cols: Vec<Vec<u64>> = self
                .points
                .alphas
                .iter()
                .map(|&a| {
                    lagrange_coeffs(&self.field, &self.points.betas, a)
                        // lint: allow(no-panic-in-library): EvalPoints constructors guarantee distinct points
                        .expect("eval points are distinct")
                })
                .collect();
            let top_sums = cols
                .iter()
                .map(|col| {
                    col[..self.params.k].iter().fold(0u64, |acc, &c| self.field.add(acc, c))
                })
                .collect();
            UMatrix { cols, top_sums }
        })
    }

    /// Column i of the encoding matrix U (length K+T).
    pub fn u_column(&self, worker: usize) -> &[u64] {
        &self.u().cols[worker]
    }

    /// Encode the quantized dataset X̄ (row-major `m × d`, `m % K == 0`)
    /// into N shares of `m/K × d` each. `rng` supplies the T uniform mask
    /// blocks Z (drawn fresh — encode once per dataset).
    pub fn encode_dataset(&self, xq: &[u64], m: usize, d: usize, rng: &mut Rng) -> Vec<EncodedShare> {
        let (k, t, n) = (self.params.k, self.params.t, self.params.n);
        assert_eq!(xq.len(), m * d);
        // lint: allow(no-hardware-modulo): shape-precondition check, not field arithmetic
        assert!(m % k == 0, "m={m} must be divisible by K={k}");
        let block = m / k * d;
        // Masks are drawn before the fan-out so the RNG stream (and hence
        // every share) is independent of the thread count and backend.
        let masks: Vec<Vec<u64>> = (0..t)
            .map(|_| self.field.random_matrix(rng, m / k, d))
            .collect();
        if let Some(ntt) = &self.ntt {
            let sources: Vec<&[u64]> = (0..k)
                .map(|j| &xq[j * block..(j + 1) * block])
                .chain(masks.iter().map(|m| m.as_slice()))
                .collect();
            return self.ntt_shares(ntt, &sources, block);
        }
        self.u(); // build U before the fan-out, not inside every thread
        par_map(self.par, n, |w| EncodedShare {
            worker: w,
            data: self.combine_blocks(xq, block, &masks, w),
        })
    }

    /// Linear combination `Σ_j U[j,w]·block_j` over K data blocks + T masks.
    ///
    /// Hot loop of the dense Encode column: products of reduced elements
    /// are < p² and we sum K+T of them, so partial sums stay in u64 for
    /// `safe_chunk_len(p)` terms — one lane-kernel fold per chunk of
    /// source blocks instead of a reduction per multiply-add (≈2.5× on
    /// the 24-bit prime; EXPERIMENTS.md §Perf).
    fn combine_blocks(
        &self,
        xq: &[u64],
        block: usize,
        masks: &[Vec<u64>],
        w: usize,
    ) -> Vec<u64> {
        let f = &self.field;
        let k = self.params.k;
        let col = &self.u().cols[w];
        let chunk = crate::compute::safe_chunk_len(f.modulus());
        let mut acc = vec![0u64; block];
        let mut out = vec![0u64; block];
        let mut pending = 0usize;
        let sources = (0..k)
            .map(|j| (col[j], &xq[j * block..(j + 1) * block]))
            .chain(masks.iter().enumerate().map(|(j, m)| (col[k + j], m.as_slice())));
        for (c, src) in sources {
            if c == 0 {
                continue;
            }
            simd::mac_wrapping(&mut acc, src, c);
            pending += 1;
            if pending == chunk {
                simd::fold_reduce(f, &mut out, &mut acc);
                pending = 0;
            }
        }
        if pending > 0 {
            simd::fold_reduce(f, &mut out, &mut acc);
        }
        out
    }

    /// Encode the quantized weight matrix W̄ (row-major `d × r`) into N
    /// shares of the same shape (eq. 14). Fresh masks V each call — the
    /// paper re-encodes every iteration precisely so intermediate weights
    /// stay private.
    pub fn encode_weights(&self, wq: &[u64], d: usize, r: usize, rng: &mut Rng) -> Vec<EncodedShare> {
        let (k, t, n) = (self.params.k, self.params.t, self.params.n);
        assert_eq!(wq.len(), d * r);
        let f = self.field;
        // Fresh masks drawn before fan-out (thread-count independence).
        let masks: Vec<Vec<u64>> = (0..t)
            .map(|_| f.random_matrix(rng, d, r))
            .collect();
        if let Some(ntt) = &self.ntt {
            // The first K blocks are all W̄ (eq. 14).
            let sources: Vec<&[u64]> = (0..k)
                .map(|_| wq)
                .chain(masks.iter().map(|m| m.as_slice()))
                .collect();
            return self.ntt_shares(ntt, &sources, d * r);
        }
        self.u();
        par_map(self.par, n, |w| EncodedShare {
            worker: w,
            data: self.combine_weight_share(wq, &masks, w),
        })
    }

    /// One worker's weight share: s_w·W̄ + Σ_j U[K+j,w]·V_j with deferred
    /// Barrett reduction over 1 data term + T mask terms.
    fn combine_weight_share(&self, wq: &[u64], masks: &[Vec<u64>], w: usize) -> Vec<u64> {
        let f = &self.field;
        let k = self.params.k;
        let chunk = crate::compute::safe_chunk_len(f.modulus());
        let u = self.u();
        let col = &u.cols[w];
        let mut acc = vec![0u64; wq.len()];
        let mut out = vec![0u64; wq.len()];
        simd::mac_wrapping(&mut acc, wq, u.top_sums[w]);
        let mut pending = 1usize;
        for (j, mask) in masks.iter().enumerate() {
            let c = col[k + j];
            if c == 0 {
                continue;
            }
            simd::mac_wrapping(&mut acc, mask, c);
            pending += 1;
            if pending == chunk {
                simd::fold_reduce(f, &mut out, &mut acc);
                pending = 0;
            }
        }
        if pending > 0 {
            simd::fold_reduce(f, &mut out, &mut acc);
        }
        out
    }

    /// NTT fan-out: every worker's share strip drops out of one forward
    /// transform. `sources` are the K+T value blocks (β_j ↦ sources[j]),
    /// each of length `block`; element columns are processed in strips so
    /// the l2-row working set stays in cache, and strips are partitioned
    /// across threads (outputs are disjoint — bit-exact at any setting).
    fn ntt_shares(&self, ntt: &NttEncoder, sources: &[&[u64]], block: usize) -> Vec<EncodedShare> {
        let n = self.params.n;
        let kt = sources.len();
        let f = &self.field;
        let l2 = ntt.layout.l2;
        let chunk = crate::compute::safe_chunk_len(f.modulus());
        let parts: Vec<Vec<Vec<u64>>> = par_ranges(self.par, block, |_, range| {
            let span = range.len();
            let mut out: Vec<Vec<u64>> = (0..n).map(|_| vec![0u64; span]).collect();
            let mut buf = vec![0u64; l2 * NTT_STRIP.min(span.max(1))];
            let mut vals = vec![0u64; kt * NTT_STRIP.min(span.max(1))];
            let mut lo = range.start;
            while lo < range.end {
                let hi = (lo + NTT_STRIP).min(range.end);
                let width = hi - lo;
                let buf = &mut buf[..l2 * width];
                buf.fill(0);
                if let Some(plan) = &ntt.plan_l1 {
                    // K+T fills the l1 subgroup: values → coefficients is
                    // a straight inverse transform.
                    for (j, src) in sources.iter().enumerate() {
                        buf[j * width..(j + 1) * width].copy_from_slice(&src[lo..hi]);
                    }
                    plan.inverse_rows(&mut buf[..ntt.layout.l1 * width], width);
                } else if let Some(interp) = &ntt.interp {
                    // Partial subgroup: (K+T)² basis change into the
                    // coefficient rows, deferred-reduction chunked.
                    let vals = &mut vals[..kt * width];
                    for (j, src) in sources.iter().enumerate() {
                        vals[j * width..(j + 1) * width].copy_from_slice(&src[lo..hi]);
                    }
                    let mut acc = vec![0u64; width];
                    for (c, brow) in interp.iter().enumerate() {
                        let row = &mut buf[c * width..(c + 1) * width];
                        let mut pending = 0usize;
                        for (j, &b) in brow.iter().enumerate() {
                            if b == 0 {
                                continue;
                            }
                            simd::mac_wrapping(&mut acc, &vals[j * width..(j + 1) * width], b);
                            pending += 1;
                            if pending == chunk {
                                simd::fold_reduce(f, row, &mut acc);
                                pending = 0;
                            }
                        }
                        if pending > 0 {
                            simd::fold_reduce(f, row, &mut acc);
                        }
                    }
                }
                // Twist by the coset shift (u(s·z) = Σ c_t·s^t·z^t), then
                // evaluate at the whole α coset in one forward pass.
                for (c, &sp) in ntt.shift_pows.iter().enumerate().skip(1) {
                    simd::scale_mod(f, &mut buf[c * width..(c + 1) * width], sp);
                }
                ntt.plan_l2.forward_rows(buf, width);
                for (w, o) in out.iter_mut().enumerate() {
                    o[lo - range.start..hi - range.start]
                        .copy_from_slice(&buf[w * width..(w + 1) * width]);
                }
                lo = hi;
            }
            out
        });
        let mut data: Vec<Vec<u64>> = (0..n).map(|_| Vec::with_capacity(block)).collect();
        for part in parts {
            for (w, piece) in part.into_iter().enumerate() {
                data[w].extend(piece);
            }
        }
        data.into_iter()
            .enumerate()
            .map(|(worker, data)| EncodedShare { worker, data })
            .collect()
    }

    /// Bytes a dataset share occupies on the wire (u64 per element — the
    /// network model uses this; a production deployment would pack to
    /// ⌈log2 p⌉ bits, tracked as `packed_share_bytes`).
    pub fn share_bytes(&self, m: usize, d: usize) -> u64 {
        (m / self.params.k * d) as u64 * 8
    }

    /// Wire size with bit-packing to the field width.
    pub fn packed_share_bytes(&self, m: usize, d: usize) -> u64 {
        let bits = self.field.bits() as u64;
        ((m / self.params.k * d) as u64 * bits).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{eval_poly, interpolate, PAPER_PRIME, PRIME_NTT_25, PRIME_NTT_28};
    use crate::util::proptest::check;

    fn setup(n: usize, k: usize, t: usize) -> Encoder {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(n, k, t, 1).unwrap();
        Encoder::new(f, params)
    }

    #[test]
    fn share_is_lagrange_polynomial_evaluation() {
        // Reconstruct u(z) from the shares' defining property: the encoder
        // output at worker i must equal the interpolation polynomial
        // through (β_j ↦ block_j / mask_j) evaluated at α_i.
        let enc = setup(10, 2, 1);
        let f = enc.field;
        let mut rng = Rng::new(101);
        let (m, d) = (4, 3); // K=2 blocks of 2×3
        let xq = f.random_matrix(&mut rng, m, d);
        // Deterministic masks via fixed seed: encode twice with same seed.
        let shares = enc.encode_dataset(&xq, m, d, &mut Rng::new(7));
        let shares2 = enc.encode_dataset(&xq, m, d, &mut Rng::new(7));
        assert_eq!(shares, shares2, "deterministic given the rng");
        // Interpolate each entry of the share polynomial from K+T shares…
        // u has degree ≤ K+T-1 = 2, so any 3 α-evaluations determine it;
        // check it passes through the data blocks at β_1, β_2.
        let block = m / 2 * d;
        for e in 0..block {
            let pts: Vec<u64> = enc.points.alphas[..3].to_vec();
            let vals: Vec<u64> = shares[..3].iter().map(|s| s.data[e]).collect();
            let coeffs = interpolate(&f, &pts, &vals).unwrap();
            assert_eq!(eval_poly(&f, &coeffs, enc.points.betas[0]), xq[e]);
            assert_eq!(eval_poly(&f, &coeffs, enc.points.betas[1]), xq[block + e]);
            // And all other shares are consistent evaluations.
            for s in &shares[3..] {
                assert_eq!(
                    eval_poly(&f, &coeffs, enc.points.alphas[s.worker]),
                    s.data[e]
                );
            }
        }
    }

    #[test]
    fn weight_shares_interpolate_to_w_at_all_data_points() {
        let enc = setup(13, 3, 1);
        let f = enc.field;
        let mut rng = Rng::new(55);
        let (d, r) = (5, 1);
        let wq = f.random_matrix(&mut rng, d, r);
        let shares = enc.encode_weights(&wq, d, r, &mut rng);
        for e in 0..d * r {
            let npts = enc.params.k + enc.params.t; // deg v ≤ K+T-1
            let pts: Vec<u64> = enc.points.alphas[..npts].to_vec();
            let vals: Vec<u64> = shares[..npts].iter().map(|s| s.data[e]).collect();
            let coeffs = interpolate(&f, &pts, &vals).unwrap();
            for b in 0..enc.params.k {
                assert_eq!(
                    eval_poly(&f, &coeffs, enc.points.betas[b]),
                    wq[e],
                    "v(β_{b}) must equal W̄ (eq. 14)"
                );
            }
        }
    }

    #[test]
    fn fresh_masks_change_shares_but_not_decode_points() {
        let enc = setup(10, 2, 1);
        let f = enc.field;
        let mut rng = Rng::new(77);
        let wq = f.random_matrix(&mut rng, 4, 1);
        let s1 = enc.encode_weights(&wq, 4, 1, &mut rng);
        let s2 = enc.encode_weights(&wq, 4, 1, &mut rng);
        assert_ne!(s1, s2, "fresh V must produce different shares");
    }

    #[test]
    fn encoding_is_linear_property() {
        // LCC is linear: encode(X + Y) = encode(X) + encode(Y) when the
        // same masks are used (same rng seed).
        let enc = setup(10, 2, 2);
        let f = enc.field;
        check("lcc-linearity", 20, move |rng| {
            let (m, d) = (4, 2);
            let x = f.random_matrix(rng, m, d);
            let y = f.random_matrix(rng, m, d);
            let xy: Vec<u64> = x.iter().zip(y.iter()).map(|(&a, &b)| f.add(a, b)).collect();
            let seed = rng.next_u64();
            let ex = enc.encode_dataset(&x, m, d, &mut Rng::new(seed));
            // Zero masks for y-encoding so sums align: use a *zero* dataset
            // encoding for mask cancellation instead — simpler: encode with
            // same seed and compare against sum with one mask contribution
            // doubled. To keep the property clean, test linearity on the
            // mask-free part by encoding (x, masks M) and (y, masks M) and
            // (x+y, masks 2M): construct via two different seeds is not
            // linear, so here we verify instead:
            //   enc(x, M) + enc(y, M) - enc(x+y, M) = enc(0, M).
            let ey = enc.encode_dataset(&y, m, d, &mut Rng::new(seed));
            let exy = enc.encode_dataset(&xy, m, d, &mut Rng::new(seed));
            let zero = vec![0u64; m * d];
            let e0 = enc.encode_dataset(&zero, m, d, &mut Rng::new(seed));
            for w in 0..enc.params.n {
                for e in 0..ex[w].data.len() {
                    let lhs = f.sub(f.add(ex[w].data[e], ey[w].data[e]), exy[w].data[e]);
                    if lhs != e0[w].data[e] {
                        return Err(format!("worker {w} entry {e}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "divisible by K")]
    fn rejects_ragged_partition() {
        let enc = setup(10, 2, 1);
        let xq = vec![0u64; 5 * 3]; // 5 rows not divisible by K=2
        enc.encode_dataset(&xq, 5, 3, &mut Rng::new(1));
    }

    #[test]
    fn wire_sizes() {
        let enc = setup(10, 2, 1);
        // m=8, d=4 → share 4×4 = 16 elements = 128 bytes raw.
        assert_eq!(enc.share_bytes(8, 4), 128);
        // packed at 24 bits: 16·24/8 = 48 bytes.
        assert_eq!(enc.packed_share_bytes(8, 4), 48);
    }

    #[test]
    fn parallel_encode_is_bit_exact_with_serial() {
        let enc = setup(13, 3, 2);
        let f = enc.field;
        let mut rng = Rng::new(123);
        let (m, d) = (12, 7);
        let xq = f.random_matrix(&mut rng, m, d);
        let wq = f.random_matrix(&mut rng, d, 1);
        let serial_x = enc.encode_dataset(&xq, m, d, &mut Rng::new(5));
        let serial_w = enc.encode_weights(&wq, d, 1, &mut Rng::new(6));
        for threads in [2usize, 4, 32] {
            let penc = setup(13, 3, 2).with_parallelism(Parallelism::from_count(threads));
            assert_eq!(penc.encode_dataset(&xq, m, d, &mut Rng::new(5)), serial_x);
            assert_eq!(penc.encode_weights(&wq, d, 1, &mut Rng::new(6)), serial_w);
        }
    }

    #[test]
    fn top_sums_match_direct_sum() {
        let enc = setup(13, 3, 2);
        let f = enc.field;
        for w in 0..enc.params.n {
            let direct = enc.u_column(w)[..3]
                .iter()
                .fold(0u64, |acc, &c| f.add(acc, c));
            assert_eq!(enc.u().top_sums[w], direct);
        }
    }

    #[test]
    fn backend_selection_rules() {
        // Standard points: always dense, even on an NTT-friendly modulus.
        let f = PrimeField::new(PRIME_NTT_25);
        let params = CodingParams::new(10, 3, 1, 1).unwrap();
        assert_eq!(Encoder::new(f, params).backend(), CodingBackend::Dense);
        // Coset points at the small default shape: cost model says dense.
        let pts = EvalPoints::ntt_coset(&f, 3, 1, 10).unwrap();
        let enc = Encoder::with_points(f, params, pts.clone());
        assert_eq!(enc.backend(), CodingBackend::Dense);
        // …but forcing NTT engages it, and force_dense reverts.
        let enc = Encoder::with_points(f, params, pts).force_ntt();
        assert_eq!(enc.backend(), CodingBackend::Ntt);
        assert_eq!(enc.force_dense().backend(), CodingBackend::Dense);
        // Big shape: auto-selected.
        let params = CodingParams::new(192, 48, 16, 1).unwrap();
        let pts = EvalPoints::ntt_coset(&f, 48, 16, 192).unwrap();
        assert_eq!(Encoder::with_points(f, params, pts).backend(), CodingBackend::Ntt);
    }

    #[test]
    #[should_panic(expected = "ntt backend requires")]
    fn force_ntt_rejects_standard_points() {
        let f = PrimeField::new(PRIME_NTT_25);
        let params = CodingParams::new(10, 3, 1, 1).unwrap();
        let _ = Encoder::new(f, params).force_ntt();
    }

    #[test]
    fn ntt_encode_is_bit_exact_with_dense_all_moduli() {
        // Same coset points, forced dense vs forced NTT, same mask seeds:
        // every share must be bitwise identical. Covers both coefficient
        // recovery paths (K+T == l1 straight iNTT, K+T < l1 basis change)
        // and all NTT-capable moduli, serial and threaded.
        for &p in &[97u64, PRIME_NTT_25, PRIME_NTT_28] {
            for &(n, k, t) in &[(10usize, 3usize, 1usize), (10, 2, 1), (13, 2, 2), (16, 4, 1)] {
                let f = PrimeField::new(p);
                let params = CodingParams::new(n, k, t, 1).unwrap();
                let pts = EvalPoints::ntt_coset(&f, k, t, n).unwrap();
                let dense = Encoder::with_points(f, params, pts.clone()).force_dense();
                let ntt = Encoder::with_points(f, params, pts.clone()).force_ntt();
                let mut rng = Rng::new(p ^ (n as u64) << 8 ^ (k as u64) << 4 ^ t as u64);
                let (m, d) = (3 * k, 5);
                let xq = f.random_matrix(&mut rng, m, d);
                let wq = f.random_matrix(&mut rng, d, 1);
                let want_x = dense.encode_dataset(&xq, m, d, &mut Rng::new(11));
                let want_w = dense.encode_weights(&wq, d, 1, &mut Rng::new(12));
                assert_eq!(ntt.encode_dataset(&xq, m, d, &mut Rng::new(11)), want_x,
                    "dataset p={p} n={n} k={k} t={t}");
                assert_eq!(ntt.encode_weights(&wq, d, 1, &mut Rng::new(12)), want_w,
                    "weights p={p} n={n} k={k} t={t}");
                for threads in [2usize, 4] {
                    let pntt = Encoder::with_points(f, params, pts.clone())
                        .force_ntt()
                        .with_parallelism(Parallelism::from_count(threads));
                    assert_eq!(pntt.encode_dataset(&xq, m, d, &mut Rng::new(11)), want_x,
                        "threads={threads} p={p}");
                    assert_eq!(pntt.encode_weights(&wq, d, 1, &mut Rng::new(12)), want_w,
                        "threads={threads} p={p}");
                }
            }
        }
    }

    #[test]
    fn ntt_shares_are_polynomial_evaluations_at_coset_alphas() {
        // Independent of the dense path: interpolate the NTT-encoded
        // shares directly and check they lie on the degree-<K+T polynomial
        // through the β values.
        let f = PrimeField::new(PRIME_NTT_25);
        let params = CodingParams::new(10, 2, 1, 1).unwrap();
        let pts = EvalPoints::ntt_coset(&f, 2, 1, 10).unwrap();
        let enc = Encoder::with_points(f, params, pts).force_ntt();
        let mut rng = Rng::new(3);
        let (m, d) = (4, 3);
        let xq = f.random_matrix(&mut rng, m, d);
        let shares = enc.encode_dataset(&xq, m, d, &mut Rng::new(9));
        let block = m / 2 * d;
        for e in 0..block {
            let p3: Vec<u64> = enc.points.alphas[..3].to_vec();
            let vals: Vec<u64> = shares[..3].iter().map(|s| s.data[e]).collect();
            let coeffs = interpolate(&f, &p3, &vals).unwrap();
            assert_eq!(eval_poly(&f, &coeffs, enc.points.betas[0]), xq[e]);
            assert_eq!(eval_poly(&f, &coeffs, enc.points.betas[1]), xq[block + e]);
            for s in &shares[3..] {
                assert_eq!(eval_poly(&f, &coeffs, enc.points.alphas[s.worker]), s.data[e]);
            }
        }
    }
}
