//! LCC encoder (paper §3.2).
//!
//! The encoding matrix U ∈ F_p^{(K+T)×N} has column i equal to the Lagrange
//! basis coefficients of the β points evaluated at α_i (eq. 12), so worker
//! i's share is a fixed linear combination of the K data blocks and T
//! masks: `X̃_i = Σ_j U[j,i]·block_j`. Weight shares exploit that the first
//! K blocks are all W̄ (eq. 14): `Σ_{j<K} U[j,i]·W̄ = s_i·W̄` with the column
//! sums s_i precomputed — an O(K) → O(1) saving per entry that dominates
//! the per-iteration encode cost (EXPERIMENTS.md §Perf).

use super::{CodingParams, EvalPoints};
use crate::field::{lagrange_coeffs, PrimeField};
use crate::util::par::{par_map, Parallelism};
use crate::util::Rng;

/// One worker's coded share of the dataset (or of the weights).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EncodedShare {
    /// Worker index (0-based) — identifies the α point.
    pub worker: usize,
    /// Row-major payload.
    pub data: Vec<u64>,
}

/// Encoder for a fixed (field, params, points) session.
#[derive(Debug, Clone)]
pub struct Encoder {
    pub field: PrimeField,
    pub params: CodingParams,
    pub points: EvalPoints,
    /// U, stored column-major: `u[i]` is worker i's coefficient vector
    /// (length K+T).
    u_cols: Vec<Vec<u64>>,
    /// `Σ_{j<K} U[j,i]` per worker — the replicated-secret shortcut.
    top_sums: Vec<u64>,
    /// Threads for the per-worker share columns (mask randomness is drawn
    /// before fan-out, so shares are identical at any setting).
    par: Parallelism,
}

impl Encoder {
    pub fn new(field: PrimeField, params: CodingParams) -> Self {
        let points = EvalPoints::standard(&field, params.k, params.t, params.n);
        Self::with_points(field, params, points)
    }

    pub fn with_points(field: PrimeField, params: CodingParams, points: EvalPoints) -> Self {
        assert_eq!(points.betas.len(), params.k + params.t);
        assert_eq!(points.alphas.len(), params.n);
        let u_cols: Vec<Vec<u64>> = points
            .alphas
            .iter()
            .map(|&a| {
                lagrange_coeffs(&field, &points.betas, a)
                    // lint: allow(no-panic-in-library): EvalPoints::standard guarantees distinct points
                    .expect("standard points are distinct")
            })
            .collect();
        let top_sums = u_cols
            .iter()
            .map(|col| col[..params.k].iter().fold(0u64, |acc, &c| field.add(acc, c)))
            .collect();
        Encoder { field, params, points, u_cols, top_sums, par: Parallelism::Serial }
    }

    /// Spread the N per-worker share computations across `par` threads.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.par = par;
        self
    }

    /// Column i of the encoding matrix U (length K+T).
    pub fn u_column(&self, worker: usize) -> &[u64] {
        &self.u_cols[worker]
    }

    /// Encode the quantized dataset X̄ (row-major `m × d`, `m % K == 0`)
    /// into N shares of `m/K × d` each. `rng` supplies the T uniform mask
    /// blocks Z (drawn fresh — encode once per dataset).
    pub fn encode_dataset(&self, xq: &[u64], m: usize, d: usize, rng: &mut Rng) -> Vec<EncodedShare> {
        let (k, t, n) = (self.params.k, self.params.t, self.params.n);
        assert_eq!(xq.len(), m * d);
        // lint: allow(no-hardware-modulo): shape-precondition check, not field arithmetic
        assert!(m % k == 0, "m={m} must be divisible by K={k}");
        let block = m / k * d;
        // Masks are drawn before the fan-out so the RNG stream (and hence
        // every share) is independent of the thread count.
        let masks: Vec<Vec<u64>> = (0..t)
            .map(|_| self.field.random_matrix(rng, m / k, d))
            .collect();
        par_map(self.par, n, |w| EncodedShare {
            worker: w,
            data: self.combine_blocks(xq, block, &masks, w),
        })
    }

    /// Linear combination `Σ_j U[j,w]·block_j` over K data blocks + T masks.
    ///
    /// Hot loop of the Encode column: products of reduced elements are
    /// < p² ≤ 2^52 and we sum K+T of them, so partial sums stay in u64
    /// for `safe_chunk_len(p)` terms — reduce once per chunk of source
    /// blocks instead of per multiply-add (≈2.5× on the 24-bit prime;
    /// EXPERIMENTS.md §Perf).
    fn combine_blocks(
        &self,
        xq: &[u64],
        block: usize,
        masks: &[Vec<u64>],
        w: usize,
    ) -> Vec<u64> {
        let f = &self.field;
        let p = f.modulus();
        let k = self.params.k;
        let col = &self.u_cols[w];
        let chunk = crate::compute::safe_chunk_len(p);
        let mut acc = vec![0u64; block];
        let mut out = vec![0u64; block];
        let mut pending = 0usize;
        let fold = |acc: &mut Vec<u64>, out: &mut Vec<u64>, pending: &mut usize| {
            for (o, a) in out.iter_mut().zip(acc.iter_mut()) {
                *o = f.add(*o, f.reduce_u64(*a));
                *a = 0;
            }
            *pending = 0;
        };
        let sources = (0..k)
            .map(|j| (col[j], &xq[j * block..(j + 1) * block]))
            .chain(masks.iter().enumerate().map(|(j, m)| (col[k + j], m.as_slice())));
        for (c, src) in sources {
            if c == 0 {
                continue;
            }
            for (a, &s) in acc.iter_mut().zip(src.iter()) {
                *a = a.wrapping_add(c * s);
            }
            pending += 1;
            if pending == chunk {
                fold(&mut acc, &mut out, &mut pending);
            }
        }
        if pending > 0 {
            fold(&mut acc, &mut out, &mut pending);
        }
        out
    }

    /// Encode the quantized weight matrix W̄ (row-major `d × r`) into N
    /// shares of the same shape (eq. 14). Fresh masks V each call — the
    /// paper re-encodes every iteration precisely so intermediate weights
    /// stay private.
    pub fn encode_weights(&self, wq: &[u64], d: usize, r: usize, rng: &mut Rng) -> Vec<EncodedShare> {
        let (t, n) = (self.params.t, self.params.n);
        assert_eq!(wq.len(), d * r);
        let f = self.field;
        // Fresh masks drawn before fan-out (thread-count independence).
        let masks: Vec<Vec<u64>> = (0..t)
            .map(|_| f.random_matrix(rng, d, r))
            .collect();
        par_map(self.par, n, |w| EncodedShare {
            worker: w,
            data: self.combine_weight_share(wq, &masks, w),
        })
    }

    /// One worker's weight share: s_w·W̄ + Σ_j U[K+j,w]·V_j with deferred
    /// Barrett reduction over 1 data term + T mask terms.
    fn combine_weight_share(&self, wq: &[u64], masks: &[Vec<u64>], w: usize) -> Vec<u64> {
        let f = &self.field;
        let k = self.params.k;
        let chunk = crate::compute::safe_chunk_len(f.modulus());
        let col = &self.u_cols[w];
        let s = self.top_sums[w];
        let mut acc: Vec<u64> = wq.iter().map(|&v| s * v).collect();
        let mut out = vec![0u64; wq.len()];
        let mut pending = 1usize;
        for (j, mask) in masks.iter().enumerate() {
            let c = col[k + j];
            if c == 0 {
                continue;
            }
            for (a, &v) in acc.iter_mut().zip(mask.iter()) {
                *a = a.wrapping_add(c * v);
            }
            pending += 1;
            if pending == chunk {
                for (o, a) in out.iter_mut().zip(acc.iter_mut()) {
                    *o = f.add(*o, f.reduce_u64(*a));
                    *a = 0;
                }
                pending = 0;
            }
        }
        if pending > 0 {
            for (o, a) in out.iter_mut().zip(acc.iter()) {
                *o = f.add(*o, f.reduce_u64(*a));
            }
        }
        out
    }

    /// Bytes a dataset share occupies on the wire (u64 per element — the
    /// network model uses this; a production deployment would pack to
    /// ⌈log2 p⌉ bits, tracked as `packed_share_bytes`).
    pub fn share_bytes(&self, m: usize, d: usize) -> u64 {
        (m / self.params.k * d) as u64 * 8
    }

    /// Wire size with bit-packing to the field width.
    pub fn packed_share_bytes(&self, m: usize, d: usize) -> u64 {
        let bits = self.field.bits() as u64;
        ((m / self.params.k * d) as u64 * bits).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{eval_poly, interpolate, PAPER_PRIME};
    use crate::util::proptest::check;

    fn setup(n: usize, k: usize, t: usize) -> Encoder {
        let f = PrimeField::new(PAPER_PRIME);
        let params = CodingParams::new(n, k, t, 1).unwrap();
        Encoder::new(f, params)
    }

    #[test]
    fn share_is_lagrange_polynomial_evaluation() {
        // Reconstruct u(z) from the shares' defining property: the encoder
        // output at worker i must equal the interpolation polynomial
        // through (β_j ↦ block_j / mask_j) evaluated at α_i.
        let enc = setup(10, 2, 1);
        let f = enc.field;
        let mut rng = Rng::new(101);
        let (m, d) = (4, 3); // K=2 blocks of 2×3
        let xq = f.random_matrix(&mut rng, m, d);
        // Deterministic masks via fixed seed: encode twice with same seed.
        let shares = enc.encode_dataset(&xq, m, d, &mut Rng::new(7));
        let shares2 = enc.encode_dataset(&xq, m, d, &mut Rng::new(7));
        assert_eq!(shares, shares2, "deterministic given the rng");
        // Interpolate each entry of the share polynomial from K+T shares…
        // u has degree ≤ K+T-1 = 2, so any 3 α-evaluations determine it;
        // check it passes through the data blocks at β_1, β_2.
        let block = m / 2 * d;
        for e in 0..block {
            let pts: Vec<u64> = enc.points.alphas[..3].to_vec();
            let vals: Vec<u64> = shares[..3].iter().map(|s| s.data[e]).collect();
            let coeffs = interpolate(&f, &pts, &vals).unwrap();
            assert_eq!(eval_poly(&f, &coeffs, enc.points.betas[0]), xq[e]);
            assert_eq!(eval_poly(&f, &coeffs, enc.points.betas[1]), xq[block + e]);
            // And all other shares are consistent evaluations.
            for s in &shares[3..] {
                assert_eq!(
                    eval_poly(&f, &coeffs, enc.points.alphas[s.worker]),
                    s.data[e]
                );
            }
        }
    }

    #[test]
    fn weight_shares_interpolate_to_w_at_all_data_points() {
        let enc = setup(13, 3, 1);
        let f = enc.field;
        let mut rng = Rng::new(55);
        let (d, r) = (5, 1);
        let wq = f.random_matrix(&mut rng, d, r);
        let shares = enc.encode_weights(&wq, d, r, &mut rng);
        for e in 0..d * r {
            let npts = enc.params.k + enc.params.t; // deg v ≤ K+T-1
            let pts: Vec<u64> = enc.points.alphas[..npts].to_vec();
            let vals: Vec<u64> = shares[..npts].iter().map(|s| s.data[e]).collect();
            let coeffs = interpolate(&f, &pts, &vals).unwrap();
            for b in 0..enc.params.k {
                assert_eq!(
                    eval_poly(&f, &coeffs, enc.points.betas[b]),
                    wq[e],
                    "v(β_{b}) must equal W̄ (eq. 14)"
                );
            }
        }
    }

    #[test]
    fn fresh_masks_change_shares_but_not_decode_points() {
        let enc = setup(10, 2, 1);
        let f = enc.field;
        let mut rng = Rng::new(77);
        let wq = f.random_matrix(&mut rng, 4, 1);
        let s1 = enc.encode_weights(&wq, 4, 1, &mut rng);
        let s2 = enc.encode_weights(&wq, 4, 1, &mut rng);
        assert_ne!(s1, s2, "fresh V must produce different shares");
    }

    #[test]
    fn encoding_is_linear_property() {
        // LCC is linear: encode(X + Y) = encode(X) + encode(Y) when the
        // same masks are used (same rng seed).
        let enc = setup(10, 2, 2);
        let f = enc.field;
        check("lcc-linearity", 20, move |rng| {
            let (m, d) = (4, 2);
            let x = f.random_matrix(rng, m, d);
            let y = f.random_matrix(rng, m, d);
            let xy: Vec<u64> = x.iter().zip(y.iter()).map(|(&a, &b)| f.add(a, b)).collect();
            let seed = rng.next_u64();
            let ex = enc.encode_dataset(&x, m, d, &mut Rng::new(seed));
            // Zero masks for y-encoding so sums align: use a *zero* dataset
            // encoding for mask cancellation instead — simpler: encode with
            // same seed and compare against sum with one mask contribution
            // doubled. To keep the property clean, test linearity on the
            // mask-free part by encoding (x, masks M) and (y, masks M) and
            // (x+y, masks 2M): construct via two different seeds is not
            // linear, so here we verify instead:
            //   enc(x, M) + enc(y, M) - enc(x+y, M) = enc(0, M).
            let ey = enc.encode_dataset(&y, m, d, &mut Rng::new(seed));
            let exy = enc.encode_dataset(&xy, m, d, &mut Rng::new(seed));
            let zero = vec![0u64; m * d];
            let e0 = enc.encode_dataset(&zero, m, d, &mut Rng::new(seed));
            for w in 0..enc.params.n {
                for e in 0..ex[w].data.len() {
                    let lhs = f.sub(f.add(ex[w].data[e], ey[w].data[e]), exy[w].data[e]);
                    if lhs != e0[w].data[e] {
                        return Err(format!("worker {w} entry {e}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "divisible by K")]
    fn rejects_ragged_partition() {
        let enc = setup(10, 2, 1);
        let xq = vec![0u64; 5 * 3]; // 5 rows not divisible by K=2
        enc.encode_dataset(&xq, 5, 3, &mut Rng::new(1));
    }

    #[test]
    fn wire_sizes() {
        let enc = setup(10, 2, 1);
        // m=8, d=4 → share 4×4 = 16 elements = 128 bytes raw.
        assert_eq!(enc.share_bytes(8, 4), 128);
        // packed at 24 bits: 16·24/8 = 48 bytes.
        assert_eq!(enc.packed_share_bytes(8, 4), 48);
    }

    #[test]
    fn parallel_encode_is_bit_exact_with_serial() {
        let enc = setup(13, 3, 2);
        let f = enc.field;
        let mut rng = Rng::new(123);
        let (m, d) = (12, 7);
        let xq = f.random_matrix(&mut rng, m, d);
        let wq = f.random_matrix(&mut rng, d, 1);
        let serial_x = enc.encode_dataset(&xq, m, d, &mut Rng::new(5));
        let serial_w = enc.encode_weights(&wq, d, 1, &mut Rng::new(6));
        for threads in [2usize, 4, 32] {
            let penc = setup(13, 3, 2).with_parallelism(Parallelism::from_count(threads));
            assert_eq!(penc.encode_dataset(&xq, m, d, &mut Rng::new(5)), serial_x);
            assert_eq!(penc.encode_weights(&wq, d, 1, &mut Rng::new(6)), serial_w);
        }
    }

    #[test]
    fn top_sums_match_direct_sum() {
        let enc = setup(13, 3, 2);
        let f = enc.field;
        for w in 0..enc.params.n {
            let direct = enc.u_cols[w][..3]
                .iter()
                .fold(0u64, |acc, &c| f.add(acc, c));
            assert_eq!(enc.top_sums[w], direct);
        }
    }
}
