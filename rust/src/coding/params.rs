//! Session parameter algebra (Theorem 1 and §5 "CodedPrivateML parameters").

/// (N, K, T, r) for one CodedPrivateML session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CodingParams {
    /// Number of workers.
    pub n: usize,
    /// Parallelization: dataset split into K blocks, each worker stores a
    /// 1/K fraction (coded).
    pub k: usize,
    /// Privacy threshold: any T colluding workers learn nothing.
    pub t: usize,
    /// Sigmoid polynomial degree.
    pub r: usize,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ParamError {
    /// N < (2r+1)(K+T-1)+1 — not enough workers to decode.
    InsufficientWorkers { need: usize, have: usize },
    /// K, T, r must be ≥ 1.
    Degenerate(&'static str),
}

impl std::fmt::Display for ParamError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParamError::InsufficientWorkers { need, have } => write!(
                f,
                "recovery threshold {need} exceeds worker count {have}: \
                 need N ≥ (2r+1)(K+T-1)+1 (Theorem 1)"
            ),
            ParamError::Degenerate(what) => write!(f, "parameter {what} must be ≥ 1"),
        }
    }
}

impl std::error::Error for ParamError {}

impl CodingParams {
    pub fn new(n: usize, k: usize, t: usize, r: usize) -> Result<Self, ParamError> {
        if k == 0 {
            return Err(ParamError::Degenerate("K"));
        }
        if t == 0 {
            return Err(ParamError::Degenerate("T"));
        }
        if r == 0 {
            return Err(ParamError::Degenerate("r"));
        }
        let p = CodingParams { n, k, t, r };
        let need = p.recovery_threshold();
        if n < need {
            return Err(ParamError::InsufficientWorkers { need, have: n });
        }
        Ok(p)
    }

    /// Minimum number of worker results needed to decode:
    /// (2r+1)(K+T−1)+1 (Theorem 1).
    pub fn recovery_threshold(&self) -> usize {
        (2 * self.r + 1) * (self.k + self.t - 1) + 1
    }

    /// Stragglers tolerated: N − recovery threshold.
    pub fn straggler_slack(&self) -> usize {
        self.n - self.recovery_threshold()
    }

    /// Case 1 (§5): maximum parallelization — K = ⌊(N−1)/(2r+1)⌋, T = 1.
    pub fn case1(n: usize, r: usize) -> Result<Self, ParamError> {
        let k = ((n - 1) / (2 * r + 1)).max(1);
        Self::new(n, k, 1, r)
    }

    /// Case 2 (§5): equal parallelization & privacy — for r=1 the paper's
    /// K = T = ⌊(N+2)/6⌋; generalized to ⌊(N + 2r) / (2(2r+1))⌋ which
    /// reduces to the paper's formula at r=1.
    pub fn case2(n: usize, r: usize) -> Result<Self, ParamError> {
        let kt = ((n + 2 * r) / (2 * (2 * r + 1))).max(1);
        Self::new(n, kt, kt, r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_formula() {
        let p = CodingParams::new(40, 13, 1, 1).unwrap();
        assert_eq!(p.recovery_threshold(), 3 * 13 + 1); // 40
        assert_eq!(p.straggler_slack(), 0);
        let p = CodingParams::new(40, 7, 7, 1).unwrap();
        assert_eq!(p.recovery_threshold(), 3 * 13 + 1);
    }

    #[test]
    fn case1_matches_paper_table() {
        // Paper: K = ⌊(N−1)/3⌋, T = 1 at r=1.
        for (n, k) in [(5usize, 1usize), (10, 3), (25, 8), (40, 13)] {
            let p = CodingParams::case1(n, 1).unwrap();
            assert_eq!((p.k, p.t), (k, 1), "n={n}");
            assert!(p.recovery_threshold() <= n);
        }
    }

    #[test]
    fn case2_matches_paper_formula() {
        // Paper: K = T = ⌊(N+2)/6⌋ at r=1.
        for (n, kt) in [(5usize, 1usize), (10, 2), (25, 4), (40, 7)] {
            let p = CodingParams::case2(n, 1).unwrap();
            assert_eq!((p.k, p.t), (kt, kt), "n={n}");
            assert!(p.recovery_threshold() <= n);
        }
    }

    #[test]
    fn case_selection_valid_for_r2() {
        // r=2 needs N ≥ 6 even at K=T=1 (threshold 5(K+T-1)+1).
        for n in [6usize, 10, 25, 40] {
            let p1 = CodingParams::case1(n, 2).unwrap();
            assert!(p1.recovery_threshold() <= n);
            let p2 = CodingParams::case2(n, 2).unwrap();
            assert!(p2.recovery_threshold() <= n, "n={n} {p2:?}");
        }
        // And below that it reports the right error.
        assert!(matches!(
            CodingParams::case1(5, 2),
            Err(ParamError::InsufficientWorkers { need: 6, have: 5 })
        ));
    }

    #[test]
    fn rejects_insufficient_workers() {
        let err = CodingParams::new(9, 3, 1, 1).unwrap_err();
        assert_eq!(err, ParamError::InsufficientWorkers { need: 10, have: 9 });
    }

    #[test]
    fn rejects_degenerate() {
        assert!(matches!(CodingParams::new(10, 0, 1, 1), Err(ParamError::Degenerate("K"))));
        assert!(matches!(CodingParams::new(10, 1, 0, 1), Err(ParamError::Degenerate("T"))));
        assert!(matches!(CodingParams::new(10, 1, 1, 0), Err(ParamError::Degenerate("r"))));
    }

    #[test]
    fn privacy_parallelism_tradeoff_scales_linearly() {
        // Remark 2: as N grows, K (case 1) and T (case 2) grow linearly.
        let k40 = CodingParams::case1(40, 1).unwrap().k;
        let k80 = CodingParams::case1(80, 1).unwrap().k;
        assert!(k80 >= 2 * k40 - 1);
        let t40 = CodingParams::case2(40, 1).unwrap().t;
        let t80 = CodingParams::case2(80, 1).unwrap().t;
        assert!(t80 >= 2 * t40 - 1);
    }
}
