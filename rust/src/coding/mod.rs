//! Lagrange coded computing (paper §3.2 & §3.4; Yu et al., 2019).
//!
//! The master partitions the quantized dataset into K blocks, picks K+T
//! distinct points β and N distinct points α (disjoint from the β's), and
//! evaluates the degree-(K+T−1) Lagrange polynomial through
//! (β_1..β_K ↦ data blocks, β_{K+1}..β_{K+T} ↦ uniform random masks) at
//! each α_i to obtain worker i's coded share. Any T shares are jointly
//! uniform (the bottom T×T submatrix of the encoding matrix is MDS), so T
//! colluding workers learn nothing; any `(2r+1)(K+T−1)+1` worker *results*
//! determine the composed polynomial h(z) = f(u(z), v(z)) by interpolation,
//! and the true sub-results are its values at the β's.
//!
//! **Eval-point layouts.** The scheme is correct for *any* distinct
//! β ∪ α, so the layout is a free perf knob. [`EvalPoints::standard`] uses
//! 1..K+T+N and pairs with the dense O(N·(K+T)) encode / O(K·R²) decode
//! setup. [`EvalPoints::ntt_coset`] — available when the modulus is
//! NTT-friendly — places the β's on a power-of-two subgroup of roots of
//! unity and the α's on a disjoint coset of a larger subgroup, so encoding
//! becomes O(L log L) butterflies ([`crate::field::ntt`]) and decode rows
//! come from a closed-form barycentric product instead of O(R²) Lagrange
//! sums. Both layouts produce the *same field values* for every share and
//! decoded block given the same points, so the choice is invisible to
//! correctness; which one a session uses is surfaced as the
//! `coding_backend` trace field.

pub mod decoder;
mod encoder;
mod params;

pub use decoder::{ApproxDecode, DecodeError, Decoder, WorkerResult};
pub use encoder::{EncodedShare, Encoder};
pub use params::{CodingParams, ParamError};

use crate::field::{ntt, PrimeField};

/// Which encode/decode implementation a session's point layout enables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodingBackend {
    /// Dense Lagrange combines against the U matrix (any modulus).
    Dense,
    /// Roots-of-unity coset layout with butterfly encode + barycentric
    /// decode rows (NTT-friendly moduli only).
    Ntt,
}

impl CodingBackend {
    /// Stable string used in traces and reports.
    pub fn name(self) -> &'static str {
        match self {
            CodingBackend::Dense => "dense",
            CodingBackend::Ntt => "ntt",
        }
    }
}

/// Backend request in [`crate::coordinator::CodedMlConfig`]: `Auto` picks
/// the NTT layout whenever the modulus supports it *and* the cost model
/// says it wins at the session's (K, T, N); `Dense`/`Ntt` force the choice
/// (forcing `Ntt` on a low-adicity modulus is a config error).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CodingBackendChoice {
    #[default]
    Auto,
    Dense,
    Ntt,
}

impl std::str::FromStr for CodingBackendChoice {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "auto" => Ok(CodingBackendChoice::Auto),
            "dense" => Ok(CodingBackendChoice::Dense),
            "ntt" => Ok(CodingBackendChoice::Ntt),
            _ => Err(format!("bad coding backend '{s}' (auto|dense|ntt)")),
        }
    }
}

impl std::fmt::Display for CodingBackendChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodingBackendChoice::Auto => write!(f, "auto"),
            CodingBackendChoice::Dense => write!(f, "dense"),
            CodingBackendChoice::Ntt => write!(f, "ntt"),
        }
    }
}

/// Roots-of-unity coset geometry behind an NTT point layout.
///
/// β_j = ω₁^j for j < K+T, where ω₁ generates the size-`l1` subgroup
/// (`l1` = next power of two ≥ K+T); α_i = s·ω₂^i for i < N, where ω₂
/// generates the size-`l2` subgroup (`l2` ≥ max(next_pow2(N), l1)) and
/// the shift `s` is a field generator. Since ord(s) = p−1 > l2, s^l2 ≠ 1,
/// so the α coset is disjoint from the β subgroup — the scheme's
/// α ∩ β = ∅ requirement holds structurally.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CosetLayout {
    /// β-side transform length (next power of two ≥ K+T).
    pub l1: usize,
    /// α-side transform length (next power of two ≥ N, and ≥ l1).
    pub l2: usize,
    /// Principal l1-th root of unity, ω₁ = ω₂^(l2/l1).
    pub omega_l1: u64,
    /// Principal l2-th root of unity.
    pub omega_l2: u64,
    /// Coset shift s (the field's smallest generator).
    pub shift: u64,
}

impl CosetLayout {
    /// Estimated field multiplies per encoded element on the NTT path:
    /// coefficient recovery (size-l1 inverse butterflies when K+T fills
    /// the subgroup, else a (K+T)² basis-change pass), the s^t twist, and
    /// the size-l2 forward butterflies — times a constant-factor fudge
    /// for the extra buffer traffic relative to the dense combine's
    /// streaming MACs.
    pub fn ntt_encode_cost(&self, kt: usize) -> usize {
        let interp = if kt == self.l1 {
            self.l1 / 2 * self.l1.trailing_zeros() as usize
        } else {
            kt * kt
        };
        3 * (interp + kt + self.l2 / 2 * self.l2.trailing_zeros() as usize)
    }

    /// Field multiplies per element of the dense U-matrix combine.
    pub fn dense_encode_cost(kt: usize, n: usize) -> usize {
        kt * n
    }
}

/// The β (data/mask) and α (worker) evaluation points for a session.
#[derive(Debug, Clone)]
pub struct EvalPoints {
    pub betas: Vec<u64>,
    pub alphas: Vec<u64>,
    /// Present iff the points were laid out by [`EvalPoints::ntt_coset`];
    /// carries the subgroup geometry the fast paths need.
    pub coset: Option<CosetLayout>,
}

impl EvalPoints {
    /// Standard layout: β = 1..K+T, α = K+T+1..K+T+N. All distinct, and
    /// α ∩ β = ∅ as the scheme requires.
    pub fn standard(field: &PrimeField, k: usize, t: usize, n: usize) -> Self {
        let all = field.distinct_points(k + t + n);
        EvalPoints {
            betas: all[..k + t].to_vec(),
            alphas: all[k + t..].to_vec(),
            coset: None,
        }
    }

    /// Roots-of-unity coset layout, if the modulus has enough 2-adicity
    /// for the α-side transform length (`None` otherwise — e.g. the
    /// paper's 24-bit prime, whose p−1 has 2-adicity 1).
    pub fn ntt_coset(field: &PrimeField, k: usize, t: usize, n: usize) -> Option<Self> {
        let kt = k + t;
        if kt == 0 || n == 0 {
            return None;
        }
        let l1 = kt.next_power_of_two();
        let l2 = n.next_power_of_two().max(l1);
        if ntt::two_adicity(field.modulus()) < l2.trailing_zeros() {
            return None;
        }
        let p = field.modulus();
        let g = ntt::generator(field);
        let omega_l2 = field.pow(g, (p - 1) / l2 as u64);
        let omega_l1 = field.pow(omega_l2, (l2 / l1) as u64);
        let betas = (0..kt).map(|j| field.pow(omega_l1, j as u64)).collect();
        let alphas = (0..n)
            .map(|i| field.mul(g, field.pow(omega_l2, i as u64)))
            .collect();
        Some(EvalPoints {
            betas,
            alphas,
            coset: Some(CosetLayout { l1, l2, omega_l1, omega_l2, shift: g }),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{PAPER_PRIME, PRIME_NTT_25, PRIME_NTT_28};

    #[test]
    fn standard_points_disjoint() {
        let f = PrimeField::new(PAPER_PRIME);
        let pts = EvalPoints::standard(&f, 4, 2, 10);
        assert_eq!(pts.betas.len(), 6);
        assert_eq!(pts.alphas.len(), 10);
        assert!(pts.coset.is_none());
        for a in &pts.alphas {
            assert!(!pts.betas.contains(a));
        }
    }

    #[test]
    fn ntt_coset_points_distinct_and_disjoint() {
        // Including the acceptance shape K=48, T=16, N=192 (l1=64, l2=256).
        for &(p, k, t, n) in &[
            (PRIME_NTT_25, 3usize, 1usize, 10usize),
            (PRIME_NTT_25, 48, 16, 192),
            (PRIME_NTT_28, 7, 7, 42),
            (97, 2, 1, 8), // tiny field, 2-adicity 5
        ] {
            let f = PrimeField::new(p);
            let pts = EvalPoints::ntt_coset(&f, k, t, n).unwrap();
            assert_eq!(pts.betas.len(), k + t);
            assert_eq!(pts.alphas.len(), n);
            let mut all = pts.betas.clone();
            all.extend(&pts.alphas);
            all.sort_unstable();
            let before = all.len();
            all.dedup();
            assert_eq!(all.len(), before, "p={p} k={k} t={t} n={n}");
        }
    }

    #[test]
    fn ntt_coset_layout_geometry() {
        let f = PrimeField::new(PRIME_NTT_25);
        let pts = EvalPoints::ntt_coset(&f, 48, 16, 192).unwrap();
        let c = pts.coset.unwrap();
        assert_eq!((c.l1, c.l2), (64, 256));
        // ω's have exact order l1 / l2; the shift escapes the subgroup.
        assert_eq!(f.pow(c.omega_l1, c.l1 as u64), 1);
        assert_ne!(f.pow(c.omega_l1, c.l1 as u64 / 2), 1);
        assert_eq!(f.pow(c.omega_l2, c.l2 as u64), 1);
        assert_ne!(f.pow(c.omega_l2, c.l2 as u64 / 2), 1);
        assert_ne!(f.pow(c.shift, c.l2 as u64), 1);
        // βs sit in the l1-subgroup, αs in the shifted l2-coset.
        for &b in &pts.betas {
            assert_eq!(f.pow(b, c.l1 as u64), 1);
        }
        for &a in &pts.alphas {
            assert_eq!(f.pow(a, c.l2 as u64), f.pow(c.shift, c.l2 as u64));
        }
    }

    #[test]
    fn ntt_coset_unavailable_on_low_adicity_moduli() {
        let f = PrimeField::new(PAPER_PRIME);
        assert!(EvalPoints::ntt_coset(&f, 3, 1, 10).is_none());
        // 97 supports up to length 32 = 2^5 only.
        let f = PrimeField::new(97);
        assert!(EvalPoints::ntt_coset(&f, 2, 1, 33).is_none());
    }

    #[test]
    fn cost_model_prefers_ntt_at_large_shapes_only() {
        let f = PrimeField::new(PRIME_NTT_25);
        // Paper default 10/3/1: dense wins.
        let small = EvalPoints::ntt_coset(&f, 3, 1, 10).unwrap().coset.unwrap();
        assert!(small.ntt_encode_cost(4) >= CosetLayout::dense_encode_cost(4, 10));
        // Acceptance shape 48/16/192: NTT wins.
        let big = EvalPoints::ntt_coset(&f, 48, 16, 192).unwrap().coset.unwrap();
        assert!(big.ntt_encode_cost(64) < CosetLayout::dense_encode_cost(64, 192));
    }

    #[test]
    fn backend_choice_parses_and_displays() {
        for (s, v) in [
            ("auto", CodingBackendChoice::Auto),
            ("dense", CodingBackendChoice::Dense),
            ("ntt", CodingBackendChoice::Ntt),
        ] {
            assert_eq!(s.parse::<CodingBackendChoice>().unwrap(), v);
            assert_eq!(v.to_string(), s);
        }
        assert!("fft".parse::<CodingBackendChoice>().is_err());
        assert_eq!(CodingBackendChoice::default(), CodingBackendChoice::Auto);
        assert_eq!(CodingBackend::Dense.name(), "dense");
        assert_eq!(CodingBackend::Ntt.name(), "ntt");
    }
}
