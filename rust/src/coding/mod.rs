//! Lagrange coded computing (paper §3.2 & §3.4; Yu et al., 2019).
//!
//! The master partitions the quantized dataset into K blocks, picks K+T
//! distinct points β and N distinct points α (disjoint from the β's), and
//! evaluates the degree-(K+T−1) Lagrange polynomial through
//! (β_1..β_K ↦ data blocks, β_{K+1}..β_{K+T} ↦ uniform random masks) at
//! each α_i to obtain worker i's coded share. Any T shares are jointly
//! uniform (the bottom T×T submatrix of the encoding matrix is MDS), so T
//! colluding workers learn nothing; any `(2r+1)(K+T−1)+1` worker *results*
//! determine the composed polynomial h(z) = f(u(z), v(z)) by interpolation,
//! and the true sub-results are its values at the β's.

pub mod decoder;
mod encoder;
mod params;

pub use decoder::{DecodeError, Decoder, WorkerResult};
pub use encoder::{EncodedShare, Encoder};
pub use params::{CodingParams, ParamError};

use crate::field::PrimeField;

/// The β (data/mask) and α (worker) evaluation points for a session.
#[derive(Debug, Clone)]
pub struct EvalPoints {
    pub betas: Vec<u64>,
    pub alphas: Vec<u64>,
}

impl EvalPoints {
    /// Standard layout: β = 1..K+T, α = K+T+1..K+T+N. All distinct, and
    /// α ∩ β = ∅ as the scheme requires.
    pub fn standard(field: &PrimeField, k: usize, t: usize, n: usize) -> Self {
        let all = field.distinct_points(k + t + n);
        EvalPoints {
            betas: all[..k + t].to_vec(),
            alphas: all[k + t..].to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::PAPER_PRIME;

    #[test]
    fn standard_points_disjoint() {
        let f = PrimeField::new(PAPER_PRIME);
        let pts = EvalPoints::standard(&f, 4, 2, 10);
        assert_eq!(pts.betas.len(), 6);
        assert_eq!(pts.alphas.len(), 10);
        for a in &pts.alphas {
            assert!(!pts.betas.contains(a));
        }
    }
}
