//! `codedml` binary: the CLI (`train`, `mpc`, `reproduce`, ...) and the
//! TCP worker-process mode (`codedml --worker --listen <addr>`), which is
//! how `--transport tcp` masters get their remote workers.
fn main() { std::process::exit(codedml::cli::run()); }
