fn main() { std::process::exit(codedml::cli::run()); }
