//! A miniature Rust lexer for the in-repo linter (`codedml lint`).
//!
//! Full parsing (syn) is unavailable offline and unnecessary: every rule in
//! [`crate::analysis::rules`] operates on *scrubbed* source lines — the
//! original text with comments and string/char-literal contents blanked
//! out — plus two bits of context the scrubber recovers:
//!
//! 1. **test regions**: lines covered by a `#[cfg(test)]` or `#[test]`
//!    attribute (through the matching close brace, or the terminating `;`
//!    for brace-less items), so rules never fire on test code;
//! 2. **allow comments**: `// lint: allow(<rule-id>): <justification>`
//!    suppresses `<rule-id>` on its own line (and, when the comment stands
//!    alone, on the next line). A justification is mandatory — an allow
//!    without one does not suppress and is itself reported.
//!
//! The scrubber is a character-level state machine that understands line
//! comments, nested block comments, string literals with escapes, raw
//! strings (`r"…"`, `r#"…"#`, any hash depth, plus `b`/`br` forms), and
//! char literals vs. lifetimes (`'%'` is a literal, `'a` in `Vec<&'a T>`
//! is not). Masked characters become spaces, so line numbers and column
//! positions survive scrubbing.

/// One `// lint: allow(...)` annotation found in a comment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allow {
    /// Rule id inside the parentheses.
    pub rule: String,
    /// Whether a non-empty justification followed the closing paren.
    pub justified: bool,
}

/// One scrubbed source line.
#[derive(Debug, Clone)]
pub struct ScrubbedLine {
    /// The line with comments and literal contents replaced by spaces.
    pub code: String,
    /// True when the line sits inside a `#[cfg(test)]`/`#[test]` region.
    pub in_test: bool,
    /// Allow annotations that apply to this line.
    pub allows: Vec<Allow>,
}

impl ScrubbedLine {
    /// Does an allow with a justification cover `rule` on this line?
    pub fn allowed(&self, rule: &str) -> bool {
        self.allows.iter().any(|a| a.justified && a.rule == rule)
    }

    /// True when the scrubbed line carries no code at all.
    pub fn is_blank(&self) -> bool {
        self.code.trim().is_empty()
    }
}

/// A whole scrubbed file: path (relative to the scan root, `/`-separated)
/// plus per-line scrub results.
#[derive(Debug, Clone)]
pub struct ScrubbedFile {
    pub path: String,
    pub lines: Vec<ScrubbedLine>,
}

impl ScrubbedFile {
    /// Scrub `source` under the given tree-relative `path`.
    pub fn new(path: &str, source: &str) -> ScrubbedFile {
        let (masked, comments) = scrub(source);
        let masked_lines: Vec<&str> = split_keepempty(&masked);
        let comment_lines: Vec<&str> = split_keepempty(&comments);
        let test_lines = test_regions(&masked);

        let mut lines: Vec<ScrubbedLine> = masked_lines
            .iter()
            .enumerate()
            .map(|(i, code)| ScrubbedLine {
                code: (*code).to_string(),
                in_test: test_lines.get(i).copied().unwrap_or(false),
                allows: parse_allows(comment_lines.get(i).copied().unwrap_or("")),
            })
            .collect();

        // An allow on a comment-only line also covers the next line.
        for i in 0..lines.len() {
            if lines[i].is_blank() && !lines[i].allows.is_empty() && i + 1 < lines.len() {
                let carried = lines[i].allows.clone();
                lines[i + 1].allows.extend(carried);
            }
        }

        ScrubbedFile { path: path.to_string(), lines }
    }

    /// The scrubbed file as one string (line numbers preserved).
    pub fn masked_text(&self) -> String {
        let mut out = String::new();
        for l in &self.lines {
            out.push_str(&l.code);
            out.push('\n');
        }
        out
    }
}

/// Split on `\n` keeping a final empty segment out (files end with `\n`).
fn split_keepempty(s: &str) -> Vec<&str> {
    let mut v: Vec<&str> = s.split('\n').collect();
    if v.last().is_some_and(|l| l.is_empty()) {
        v.pop();
    }
    v
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Code,
    LineComment,
    Block(u32),
    Str,
    RawStr(u32),
    Char,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Scrub `source` into (masked code, comment text). Both outputs have the
/// same line structure as the input; non-code (resp. non-comment) chars
/// are spaces.
fn scrub(source: &str) -> (String, String) {
    let chars: Vec<char> = source.chars().collect();
    let n = chars.len();
    let mut code = String::with_capacity(n);
    let mut comment = String::with_capacity(n);
    let mut state = State::Code;
    let mut i = 0usize;

    // Push one char into (code?, comment?) keeping newlines in both.
    let push = |code: &mut String, comment: &mut String, c: char, is_code: bool, is_comment: bool| {
        if c == '\n' {
            code.push('\n');
            comment.push('\n');
            return;
        }
        code.push(if is_code { c } else { ' ' });
        comment.push(if is_comment { c } else { ' ' });
    };

    while i < n {
        let c = chars[i];
        match state {
            State::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    state = State::LineComment;
                    push(&mut code, &mut comment, c, false, true);
                    i += 1;
                    push(&mut code, &mut comment, chars[i], false, true);
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::Block(1);
                    push(&mut code, &mut comment, c, false, true);
                    i += 1;
                    push(&mut code, &mut comment, chars[i], false, true);
                } else if c == '"' {
                    state = State::Str;
                    push(&mut code, &mut comment, c, false, false);
                } else if (c == 'r' || c == 'b')
                    && (i == 0 || !is_ident(chars[i - 1]))
                    && raw_str_hashes(&chars, i).is_some()
                {
                    // r"…" / r#"…"# / b"…" / br#"…"# — consume the prefix
                    // through the opening quote.
                    let (hashes, quote_at) = raw_str_hashes(&chars, i).unwrap_or((0, i));
                    while i <= quote_at {
                        push(&mut code, &mut comment, chars[i], false, false);
                        i += 1;
                    }
                    i -= 1; // outer loop will advance
                    state = if hashes == u32::MAX { State::Str } else { State::RawStr(hashes) };
                } else if c == '\'' {
                    // Char literal or lifetime?
                    let next = chars.get(i + 1).copied();
                    let after = chars.get(i + 2).copied();
                    if next == Some('\\') || (next.is_some() && after == Some('\'')) {
                        state = State::Char;
                        push(&mut code, &mut comment, c, false, false);
                    } else {
                        // Lifetime — plain code.
                        push(&mut code, &mut comment, c, true, false);
                    }
                } else {
                    push(&mut code, &mut comment, c, true, false);
                }
            }
            State::LineComment => {
                if c == '\n' {
                    state = State::Code;
                }
                push(&mut code, &mut comment, c, false, true);
            }
            State::Block(depth) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    push(&mut code, &mut comment, c, false, true);
                    i += 1;
                    push(&mut code, &mut comment, chars[i], false, true);
                    state = if depth == 1 { State::Code } else { State::Block(depth - 1) };
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    push(&mut code, &mut comment, c, false, true);
                    i += 1;
                    push(&mut code, &mut comment, chars[i], false, true);
                    state = State::Block(depth + 1);
                } else {
                    push(&mut code, &mut comment, c, false, true);
                }
            }
            State::Str => {
                if c == '\\' && i + 1 < n {
                    push(&mut code, &mut comment, c, false, false);
                    i += 1;
                    push(&mut code, &mut comment, chars[i], false, false);
                } else {
                    if c == '"' {
                        state = State::Code;
                    }
                    push(&mut code, &mut comment, c, false, false);
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && closes_raw(&chars, i, hashes) {
                    push(&mut code, &mut comment, c, false, false);
                    for _ in 0..hashes {
                        i += 1;
                        push(&mut code, &mut comment, chars[i], false, false);
                    }
                    state = State::Code;
                } else {
                    push(&mut code, &mut comment, c, false, false);
                }
            }
            State::Char => {
                if c == '\\' && i + 1 < n {
                    push(&mut code, &mut comment, c, false, false);
                    i += 1;
                    push(&mut code, &mut comment, chars[i], false, false);
                } else {
                    if c == '\'' {
                        state = State::Code;
                    }
                    push(&mut code, &mut comment, c, false, false);
                }
            }
        }
        i += 1;
    }
    (code, comment)
}

/// At index `i` of an `r`/`b` character: if this starts a string-literal
/// prefix, return `(hash_count, index_of_opening_quote)`. A plain `b"…"`
/// (no `r`) is reported with hash count `u32::MAX` meaning "treat as a
/// normal escaped string".
fn raw_str_hashes(chars: &[char], i: usize) -> Option<(u32, usize)> {
    let mut j = i;
    let mut raw = false;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) == Some(&'r') {
        raw = true;
        j += 1;
    }
    if j == i {
        return None; // neither b nor r consumed
    }
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) != Some(&'"') {
        return None;
    }
    if !raw {
        if hashes != 0 {
            return None; // b#"…" is not a thing
        }
        return Some((u32::MAX, j));
    }
    Some((hashes, j))
}

/// Does the `"` at `i` close a raw string with `hashes` trailing hashes?
fn closes_raw(chars: &[char], i: usize, hashes: u32) -> bool {
    (1..=hashes as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

/// Mark the lines covered by `#[cfg(test)]` / `#[test]` attributes in the
/// masked text: from the attribute through the matching `}` of the first
/// block it opens — or only through the first `;` when the item is
/// brace-less (`#[cfg(test)] use …;`).
fn test_regions(masked: &str) -> Vec<bool> {
    let line_count = split_keepempty(masked).len();
    let mut in_test = vec![false; line_count];
    let bytes: Vec<char> = masked.chars().collect();
    // line index of each char
    let mut line_of = Vec::with_capacity(bytes.len());
    let mut ln = 0usize;
    for &c in &bytes {
        line_of.push(ln);
        if c == '\n' {
            ln += 1;
        }
    }
    let text: String = masked.to_string();
    for pat in ["#[cfg(test)]", "#[test]"] {
        let mut from = 0usize;
        while let Some(off) = text[from..].find(pat) {
            let start = from + off;
            let start_char = text[..start].chars().count();
            let mut j = start_char + pat.chars().count();
            // Scan forward for the first `{`; a `;` first means a
            // brace-less item — mark through it and stop.
            let mut open = None;
            while j < bytes.len() {
                match bytes[j] {
                    '{' => {
                        open = Some(j);
                        break;
                    }
                    ';' => break,
                    _ => j += 1,
                }
            }
            let end_char = match open {
                None => j.min(bytes.len().saturating_sub(1)),
                Some(o) => {
                    let mut depth = 0i64;
                    let mut k = o;
                    loop {
                        match bytes.get(k) {
                            Some('{') => depth += 1,
                            Some('}') => {
                                depth -= 1;
                                if depth == 0 {
                                    break;
                                }
                            }
                            None => break,
                            _ => {}
                        }
                        k += 1;
                    }
                    k.min(bytes.len().saturating_sub(1))
                }
            };
            for idx in start_char..=end_char.min(line_of.len().saturating_sub(1)) {
                in_test[line_of[idx]] = true;
            }
            from = start + pat.len();
        }
    }
    in_test
}

/// Parse every `lint: allow(<rule>)` annotation out of one line's comment
/// text. Justification = any non-empty text after the closing paren
/// (leading `:`, `-`, `—` separators stripped).
fn parse_allows(comment: &str) -> Vec<Allow> {
    const MARK: &str = "lint: allow(";
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(off) = comment[from..].find(MARK) {
        let at = from + off + MARK.len();
        let Some(close) = comment[at..].find(')') else {
            break;
        };
        let rule = comment[at..at + close].trim().to_string();
        let rest = comment[at + close + 1..]
            .trim_start_matches([':', '-', '—', ' '])
            .trim();
        if !rule.is_empty() {
            out.push(Allow { rule, justified: !rest.is_empty() });
        }
        from = at + close + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scrub_lines(src: &str) -> Vec<ScrubbedLine> {
        ScrubbedFile::new("x.rs", src).lines
    }

    /// Satellite requirement: table-driven scrubbing cases. Each row is
    /// (source, line index, expectation about `%` surviving in code).
    #[test]
    fn percent_in_literals_and_comments_is_masked() {
        let cases: &[(&str, bool)] = &[
            // (source line, does masked code still contain '%')
            ("let r = x % p;", true),
            ("let s = \"100 % done\";", false),
            ("// x % p is forbidden here", false),
            ("/// docs: use `x % p` nowhere", false),
            ("//! module docs with a % sign", false),
            ("/* block % comment */ let y = 1;", false),
            ("let c = '%';", false),
            ("let s = r\"raw % string\";", false),
            ("let s = r#\"hash % raw\"#;", false),
            ("let s = b\"byte % string\";", false),
            ("let m = format!(\"{:>8.2}%\", v);", false),
            ("let escaped = \"q\\\" % still string\";", false),
        ];
        for (src, expect_percent) in cases {
            let lines = scrub_lines(&format!("{src}\n"));
            assert_eq!(
                lines[0].code.contains('%'),
                *expect_percent,
                "source: {src}\nmasked: {}",
                lines[0].code
            );
        }
    }

    #[test]
    fn masking_preserves_line_and_column_positions() {
        let src = "let a = 1; // trailing\nlet b = \"xx\";\n";
        let lines = scrub_lines(src);
        assert_eq!(lines.len(), 2);
        assert!(lines[0].code.starts_with("let a = 1; "));
        assert_eq!(lines[0].code.chars().count(), "let a = 1; // trailing".chars().count());
        assert!(lines[1].code.contains("let b ="));
        assert!(!lines[1].code.contains("xx"));
    }

    #[test]
    fn cfg_test_mod_is_marked_through_matching_brace() {
        let src = "\
fn library() { let x = 1 % 2; }

#[cfg(test)]
mod tests {
    fn helper() { let y = 3 % 4; }

    #[test]
    fn t() { assert!(helper() > 0); }
}

fn library_after() { }
";
        let lines = scrub_lines(src);
        assert!(!lines[0].in_test, "library code before the test mod");
        for i in 2..=8 {
            assert!(lines[i].in_test, "line {} should be test code", i + 1);
        }
        assert!(!lines[10].in_test, "library code after the test mod");
    }

    #[test]
    fn cfg_test_braceless_item_marks_only_through_semicolon() {
        let src = "#[cfg(test)]\nuse crate::data::Dataset;\nfn lib() {}\n";
        let lines = scrub_lines(src);
        assert!(lines[0].in_test);
        assert!(lines[1].in_test);
        assert!(!lines[2].in_test, "item after the `;` is not test code");
    }

    #[test]
    fn test_attribute_marks_single_fn() {
        let src = "#[test]\nfn t() {\n    body();\n}\nfn lib() {}\n";
        let lines = scrub_lines(src);
        assert!(lines[0].in_test && lines[1].in_test && lines[2].in_test && lines[3].in_test);
        assert!(!lines[4].in_test);
    }

    #[test]
    fn nested_block_comments_unwind_fully() {
        let src = "/* outer /* inner % */ still comment % */ let x = 5 % 3;\n";
        let lines = scrub_lines(src);
        assert!(lines[0].code.contains("let x = 5 % 3;"));
        assert_eq!(lines[0].code.matches('%').count(), 1);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x } // then % here\n";
        let lines = scrub_lines(src);
        assert!(lines[0].code.contains("fn f<'a>(x: &'a str)"));
        assert!(!lines[0].code.contains('%'));
    }

    #[test]
    fn allow_with_justification_covers_line() {
        let src = "let r = x % p; // lint: allow(no-hardware-modulo): divrem oracle\n";
        let lines = scrub_lines(src);
        assert!(lines[0].allowed("no-hardware-modulo"));
        assert!(!lines[0].allowed("no-stray-io"));
    }

    #[test]
    fn allow_without_justification_does_not_suppress() {
        let src = "let r = x % p; // lint: allow(no-hardware-modulo)\n";
        let lines = scrub_lines(src);
        assert!(!lines[0].allowed("no-hardware-modulo"));
        assert_eq!(lines[0].allows.len(), 1);
        assert!(!lines[0].allows[0].justified);
    }

    #[test]
    fn standalone_allow_comment_covers_next_line() {
        let src = "// lint: allow(no-stray-io): boot diagnostics predate the tracer\nprintln!(\"hi\");\n";
        let lines = scrub_lines(src);
        assert!(lines[1].allowed("no-stray-io"));
    }

    #[test]
    fn allow_inside_string_is_ignored() {
        let src = "let s = \"lint: allow(no-stray-io): nope\";\nprintln!(\"x\");\n";
        let lines = scrub_lines(src);
        assert!(lines[0].allows.is_empty());
        assert!(!lines[1].allowed("no-stray-io"));
    }
}
