//! The seven invariant rules behind `codedml lint`.
//!
//! Each rule guards an invariant the compiler cannot see but the paper's
//! guarantees rely on (see `docs/ARCHITECTURE.md`, "Machine-checked
//! invariants"). Rules operate on scrubbed sources from
//! [`crate::analysis::lexer`]: comments and literals are already masked
//! and test regions marked, so the checks here are straight substring
//! scans plus a module-reference graph walk for the privacy boundary.

use std::collections::BTreeSet;

use super::lexer::ScrubbedFile;
use super::report::Finding;
use super::SourceTree;

/// Static description of one rule, for docs and the JSON report.
#[derive(Debug, Clone, Copy)]
pub struct RuleInfo {
    pub id: &'static str,
    pub summary: &'static str,
}

pub const NO_HARDWARE_MODULO: &str = "no-hardware-modulo";
pub const NO_PLAINTEXT_TO_WORKERS: &str = "no-plaintext-to-workers";
pub const NO_PANIC_IN_LIBRARY: &str = "no-panic-in-library";
pub const NO_STRAY_IO: &str = "no-stray-io";
pub const NO_WALLCLOCK: &str = "no-wallclock-nondeterminism";
pub const CANONICAL_DEBUG_ASSERTS: &str = "canonical-field-debug-asserts";
pub const NO_CROSS_SESSION_STATE: &str = "no-cross-session-state";
/// Pseudo-rule for `lint: allow(...)` annotations that are malformed
/// (no justification) or name an unknown rule. Not suppressible.
pub const MALFORMED_ALLOW: &str = "malformed-allow";

pub const RULES: [RuleInfo; 7] = [
    RuleInfo {
        id: NO_HARDWARE_MODULO,
        summary: "no hardware `%` on field values in field/, compute/, coding/, mpc/",
    },
    RuleInfo {
        id: NO_PLAINTEXT_TO_WORKERS,
        summary: "cluster/worker.rs and everything it reaches must not touch data::",
    },
    RuleInfo {
        id: NO_PANIC_IN_LIBRARY,
        summary: "no unwrap()/expect()/panic! in cluster/, coordinator/, coding/, serve/",
    },
    RuleInfo {
        id: NO_STRAY_IO,
        summary: "no println!/eprintln! in library code; route through the tracer",
    },
    RuleInfo {
        id: NO_WALLCLOCK,
        summary: "Instant::now/SystemTime confined to util/timer.rs and cluster/netmodel.rs",
    },
    RuleInfo {
        id: CANONICAL_DEBUG_ASSERTS,
        summary: "pub field-element returns in field/prime.rs carry debug_assert!(out < p)",
    },
    RuleInfo {
        id: NO_CROSS_SESSION_STATE,
        summary: "serve/ never absorbs a StepResult directly; results route through \
                  the cluster's session-checked collects",
    },
];

/// Run every rule over the tree; findings come back sorted and deduped.
pub fn run_all(tree: &SourceTree) -> Vec<Finding> {
    let mut out = Vec::new();
    no_hardware_modulo(tree, &mut out);
    no_plaintext_to_workers(tree, &mut out);
    no_panic_in_library(tree, &mut out);
    no_stray_io(tree, &mut out);
    no_wallclock(tree, &mut out);
    canonical_field_debug_asserts(tree, &mut out);
    no_cross_session_state(tree, &mut out);
    malformed_allows(tree, &mut out);
    super::report::sort_findings(&mut out);
    out.dedup();
    out
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn under(path: &str, dirs: &[&str]) -> bool {
    dirs.iter().any(|d| path.starts_with(d))
}

// ---------------------------------------------------------------------------
// Rule 1: no-hardware-modulo
// ---------------------------------------------------------------------------

/// Hot-path modules must reduce via Barrett (`field::PrimeField`), never
/// the hardware `%`/`%=` operators — PR 1's entire win. Literals and
/// comments are already masked, so any surviving `%` is the operator.
fn no_hardware_modulo(tree: &SourceTree, out: &mut Vec<Finding>) {
    const SCOPE: [&str; 4] = ["field/", "compute/", "coding/", "mpc/"];
    for file in &tree.files {
        if !under(&file.path, &SCOPE) {
            continue;
        }
        for (i, line) in file.lines.iter().enumerate() {
            if line.in_test || line.allowed(NO_HARDWARE_MODULO) {
                continue;
            }
            if line.code.contains('%') {
                out.push(Finding::new(
                    &file.path,
                    i + 1,
                    NO_HARDWARE_MODULO,
                    "hardware `%` in a field hot path; reduce via field::PrimeField (Barrett)"
                        .to_string(),
                ));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Rule 2: no-plaintext-to-workers
// ---------------------------------------------------------------------------

/// `super::` from inside `path` resolves relative to this directory.
fn super_dir(path: &str) -> String {
    parent_of(&self_dir(path))
}

/// `self::` (and `mod x;` declarations) resolve relative to this directory.
fn self_dir(path: &str) -> String {
    if path == "lib.rs" || path == "main.rs" {
        return String::new();
    }
    if let Some(stripped) = path.strip_suffix("/mod.rs") {
        return stripped.to_string();
    }
    path.strip_suffix(".rs").unwrap_or(path).to_string()
}

fn parent_of(dir: &str) -> String {
    match dir.rfind('/') {
        Some(i) => dir[..i].to_string(),
        None => String::new(),
    }
}

/// Collect `::`-separated path segments starting at `s`.
fn collect_segments(s: &str) -> Vec<String> {
    let mut segs = Vec::new();
    let mut rest = s;
    loop {
        let end = rest.find(|c: char| !is_ident(c)).unwrap_or(rest.len());
        if end == 0 {
            break;
        }
        segs.push(rest[..end].to_string());
        rest = &rest[end..];
        match rest.strip_prefix("::") {
            Some(r) => rest = r,
            None => break,
        }
    }
    segs
}

/// Module references on one scrubbed line: `(base_dir, segments)` pairs
/// from `crate::`/`super::`/`self::` paths plus `mod x;` declarations.
fn refs_in_line(path: &str, code: &str) -> Vec<(String, Vec<String>)> {
    let mut refs = Vec::new();
    for (marker, base) in [
        ("crate::", String::new()),
        ("super::", super_dir(path)),
        ("self::", self_dir(path)),
    ] {
        let mut from = 0usize;
        while let Some(off) = code[from..].find(marker) {
            let at = from + off;
            let preceded_by_ident =
                code[..at].chars().next_back().is_some_and(is_ident);
            if !preceded_by_ident {
                let segs = collect_segments(&code[at + marker.len()..]);
                if !segs.is_empty() {
                    refs.push((base.clone(), segs));
                }
            }
            from = at + marker.len();
        }
    }
    // `mod x;` pulls in a child module file.
    let t = code.trim();
    let after_vis = t
        .strip_prefix("pub")
        .map(|r| {
            let r = r.trim_start();
            match r.strip_prefix('(') {
                Some(rest) => rest.split_once(')').map(|(_, tail)| tail.trim_start()).unwrap_or(r),
                None => r,
            }
        })
        .unwrap_or(t);
    if let Some(rest) = after_vis.strip_prefix("mod ") {
        if let Some(name) = rest.strip_suffix(';') {
            let name = name.trim();
            if !name.is_empty() && name.chars().all(is_ident) {
                refs.push((self_dir(path), vec![name.to_string()]));
            }
        }
    }
    refs
}

/// Longest-prefix resolution of a module path to a file in the tree.
fn resolve(tree: &SourceTree, base: &str, segs: &[String]) -> Option<String> {
    for j in (1..=segs.len()).rev() {
        let mut p = base.to_string();
        for s in &segs[..j] {
            if !p.is_empty() {
                p.push('/');
            }
            p.push_str(s);
        }
        for cand in [format!("{p}.rs"), format!("{p}/mod.rs")] {
            if tree.file(&cand).is_some() {
                return Some(cand);
            }
        }
    }
    None
}

/// The T-collusion privacy boundary (paper §III): the worker module and
/// every module it can reach must never reference `crate::data` — workers
/// only ever observe Lagrange-encoded shares, never plaintext rows.
fn no_plaintext_to_workers(tree: &SourceTree, out: &mut Vec<Finding>) {
    const START: &str = "cluster/worker.rs";
    if tree.file(START).is_none() {
        return;
    }
    let mut queue = vec![START.to_string()];
    let mut visited: BTreeSet<String> = queue.iter().cloned().collect();
    while let Some(path) = queue.pop() {
        let Some(file) = tree.file(&path) else { continue };
        for (i, line) in file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            for (base, segs) in refs_in_line(&path, &line.code) {
                let names_data = base.is_empty() && segs.first().map(String::as_str) == Some("data");
                let resolved = resolve(tree, &base, &segs);
                let resolves_into_data = resolved
                    .as_deref()
                    .is_some_and(|t| t.starts_with("data/") || t == "data.rs");
                if names_data || resolves_into_data {
                    if !line.allowed(NO_PLAINTEXT_TO_WORKERS) {
                        out.push(Finding::new(
                            &path,
                            i + 1,
                            NO_PLAINTEXT_TO_WORKERS,
                            format!(
                                "references data::{} but is reachable from {START}; \
                                 workers may only observe encoded shares",
                                segs.get(1).map(String::as_str).unwrap_or("*"),
                            ),
                        ));
                    }
                } else if let Some(target) = resolved {
                    if visited.insert(target.clone()) {
                        queue.push(target);
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Rule 3: no-panic-in-library
// ---------------------------------------------------------------------------

/// Long-running training infrastructure must degrade through `Result` /
/// `TrainReport::worker_failures`, not abort: no `.unwrap()`, `.expect(`
/// or `panic!` in non-test code of cluster/, coordinator/, coding/.
fn no_panic_in_library(tree: &SourceTree, out: &mut Vec<Finding>) {
    const SCOPE: [&str; 4] = ["cluster/", "coordinator/", "coding/", "serve/"];
    const PATTERNS: [&str; 3] = [".unwrap()", ".expect(", "panic!"];
    for file in &tree.files {
        if !under(&file.path, &SCOPE) {
            continue;
        }
        for (i, line) in file.lines.iter().enumerate() {
            if line.in_test || line.allowed(NO_PANIC_IN_LIBRARY) {
                continue;
            }
            for pat in PATTERNS {
                if line.code.contains(pat) {
                    out.push(Finding::new(
                        &file.path,
                        i + 1,
                        NO_PANIC_IN_LIBRARY,
                        format!(
                            "`{pat}` in library code; surface the error through \
                             Result / worker_failures instead"
                        ),
                    ));
                    break;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Rule 4: no-stray-io
// ---------------------------------------------------------------------------

/// All diagnostics route through `coordinator::trace`; ad-hoc prints in
/// library code bypass the structured event stream (PR 3 cleanup).
fn no_stray_io(tree: &SourceTree, out: &mut Vec<Finding>) {
    for file in &tree.files {
        if file.path == "cli.rs" || file.path == "main.rs" || file.path.starts_with("bin/") {
            continue;
        }
        for (i, line) in file.lines.iter().enumerate() {
            if line.in_test || line.allowed(NO_STRAY_IO) {
                continue;
            }
            let mac = if line.code.contains("eprintln!") {
                Some("eprintln!")
            } else if line.code.contains("println!") {
                Some("println!")
            } else {
                None
            };
            if let Some(mac) = mac {
                out.push(Finding::new(
                    &file.path,
                    i + 1,
                    NO_STRAY_IO,
                    format!("`{mac}` in library code; emit a tracer event instead"),
                ));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Rule 5: no-wallclock-nondeterminism
// ---------------------------------------------------------------------------

/// "Bit-identical at every thread count" only holds if wall-clock reads
/// stay behind `util::timer` (measurement) and `cluster::netmodel`
/// (simulated delays). Everything else must be deterministic.
fn no_wallclock(tree: &SourceTree, out: &mut Vec<Finding>) {
    const EXEMPT: [&str; 2] = ["util/timer.rs", "cluster/netmodel.rs"];
    for file in &tree.files {
        if EXEMPT.contains(&file.path.as_str()) {
            continue;
        }
        for (i, line) in file.lines.iter().enumerate() {
            if line.in_test || line.allowed(NO_WALLCLOCK) {
                continue;
            }
            let hit = if line.code.contains("Instant::now") {
                Some("Instant::now")
            } else if line.code.contains("SystemTime") {
                Some("SystemTime")
            } else {
                None
            };
            if let Some(hit) = hit {
                out.push(Finding::new(
                    &file.path,
                    i + 1,
                    NO_WALLCLOCK,
                    format!(
                        "`{hit}` outside util/timer.rs and cluster/netmodel.rs; \
                         use util::timer::timed or the netmodel"
                    ),
                ));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Rule 6: canonical-field-debug-asserts
// ---------------------------------------------------------------------------

/// Byte index → 0-based line number map for a masked file text.
fn line_map(text: &str) -> Vec<usize> {
    let mut map = Vec::with_capacity(text.len());
    let mut line = 0usize;
    for b in text.bytes() {
        map.push(line);
        if b == b'\n' {
            line += 1;
        }
    }
    map
}

/// Barrett reduction is bit-exact only on canonical inputs, so every
/// public field-element producer in `field/prime.rs` (a `pub fn`
/// returning `u64`) must end in `debug_assert!(out < self.p)`. Checked
/// structurally: the brace-matched body must contain a `debug_assert!`
/// and a `< self.p` (or `< p`) comparison.
fn canonical_field_debug_asserts(tree: &SourceTree, out: &mut Vec<Finding>) {
    let Some(file) = tree.file("field/prime.rs") else { return };
    check_field_asserts(file, out);
}

fn check_field_asserts(file: &ScrubbedFile, out: &mut Vec<Finding>) {
    let text = file.masked_text();
    let lines = line_map(&text);
    let bytes = text.as_bytes();
    let mut from = 0usize;
    while let Some(off) = text[from..].find("pub fn ") {
        let at = from + off;
        from = at + "pub fn ".len();
        let lineno = lines[at];
        let line = &file.lines[lineno];
        if line.in_test {
            continue;
        }
        let name: String = text[at + "pub fn ".len()..].chars().take_while(|&c| is_ident(c)).collect();
        // Signature runs to the body `{` or a trait-style `;`.
        let mut j = at;
        while j < bytes.len() && bytes[j] != b'{' && bytes[j] != b';' {
            j += 1;
        }
        if j >= bytes.len() || bytes[j] == b';' {
            continue;
        }
        if !text[at..j].contains("-> u64") {
            continue;
        }
        // Brace-match the body.
        let open = j;
        let mut depth = 0i64;
        while j < bytes.len() {
            match bytes[j] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        let body = &text[open..j.min(text.len())];
        let ok = body.contains("debug_assert!") && (body.contains("< self.p") || body.contains("< p"));
        if !ok && !line.allowed(CANONICAL_DEBUG_ASSERTS) {
            out.push(Finding::new(
                &file.path,
                lineno + 1,
                CANONICAL_DEBUG_ASSERTS,
                format!(
                    "pub fn `{name}` returns a field element without a \
                     canonicality debug_assert!(out < p)"
                ),
            ));
        }
    }
}

// ---------------------------------------------------------------------------
// Rule 7: no-cross-session-state
// ---------------------------------------------------------------------------

/// The serve layer's isolation invariant hangs on routing: a worker
/// result must only ever enter a round through the cluster's
/// session-checked collect paths (`collect_deadline_for` /
/// `collect_resume`), which verify the frame's session id and park or
/// reject mismatches. Calling `Round::absorb` directly from scheduler
/// code would bypass that check and let one session's result corrupt a
/// sibling's decode, so any `.absorb(` in `serve/` is a finding.
fn no_cross_session_state(tree: &SourceTree, out: &mut Vec<Finding>) {
    const SCOPE: [&str; 1] = ["serve/"];
    for file in &tree.files {
        if !under(&file.path, &SCOPE) {
            continue;
        }
        for (i, line) in file.lines.iter().enumerate() {
            if line.in_test || line.allowed(NO_CROSS_SESSION_STATE) {
                continue;
            }
            if line.code.contains(".absorb(") {
                out.push(Finding::new(
                    &file.path,
                    i + 1,
                    NO_CROSS_SESSION_STATE,
                    "direct Round::absorb in serve code bypasses session-id routing; \
                     collect through the cluster's session-checked paths"
                        .to_string(),
                ));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Allow hygiene
// ---------------------------------------------------------------------------

/// Unjustified allows do not suppress and are themselves findings, as are
/// allows naming a rule id that does not exist.
fn malformed_allows(tree: &SourceTree, out: &mut Vec<Finding>) {
    for file in &tree.files {
        for (i, line) in file.lines.iter().enumerate() {
            for allow in &line.allows {
                if !allow.justified {
                    out.push(Finding::new(
                        &file.path,
                        i + 1,
                        MALFORMED_ALLOW,
                        format!(
                            "allow({}) lacks a justification; write \
                             `// lint: allow({}): <reason>`",
                            allow.rule, allow.rule
                        ),
                    ));
                } else if !RULES.iter().any(|r| r.id == allow.rule) {
                    out.push(Finding::new(
                        &file.path,
                        i + 1,
                        MALFORMED_ALLOW,
                        format!("allow({}) names an unknown rule id", allow.rule),
                    ));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tree(files: &[(&str, &str)]) -> SourceTree {
        SourceTree::from_sources(files)
    }

    fn ids(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn modulo_flagged_only_in_scope_dirs() {
        let t = tree(&[
            ("field/ops.rs", "pub fn r(x: u64, p: u64) -> u64 { x % p }\n"),
            ("util/stats.rs", "pub fn pct(a: usize, b: usize) -> usize { a % b }\n"),
        ]);
        let fs = run_all(&t);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].file, "field/ops.rs");
        assert_eq!(fs[0].rule, NO_HARDWARE_MODULO);
    }

    #[test]
    fn modulo_in_test_block_or_allowed_is_clean() {
        let src = "\
pub fn ok(x: u64, p: u64) -> u64 {
    x.wrapping_sub(p)
}

pub fn oracle(x: u64, p: u64) -> u64 {
    x % p // lint: allow(no-hardware-modulo): divrem reference oracle
}

#[cfg(test)]
mod tests {
    #[test]
    fn t() { assert_eq!(7 % 5, 2); }
}
";
        let t = tree(&[("compute/matvec.rs", src)]);
        assert!(run_all(&t).is_empty(), "{:?}", run_all(&t));
    }

    #[test]
    fn privacy_rule_follows_module_graph() {
        let t = tree(&[
            ("cluster/worker.rs", "use crate::cluster::round::Round;\n"),
            ("cluster/round.rs", "use crate::data::Dataset;\npub struct R;\n"),
            ("cluster/mod.rs", "pub mod round;\npub mod worker;\n"),
            ("data/mod.rs", "pub struct Dataset;\n"),
            // Not reachable from the worker: allowed to use data.
            ("coordinator/session.rs", "use crate::data::Dataset;\n"),
        ]);
        let fs = run_all(&t);
        assert_eq!(ids(&fs), vec![NO_PLAINTEXT_TO_WORKERS]);
        assert_eq!(fs[0].file, "cluster/round.rs");
        assert_eq!(fs[0].line, 1);
    }

    #[test]
    fn privacy_rule_direct_import() {
        let t = tree(&[(
            "cluster/worker.rs",
            "use crate::data::Dataset;\npub fn w(_d: &Dataset) {}\n",
        )]);
        let fs = run_all(&t);
        assert_eq!(ids(&fs), vec![NO_PLAINTEXT_TO_WORKERS]);
    }

    #[test]
    fn panic_rule_scoped_and_allowable() {
        let t = tree(&[
            ("coding/combine.rs", "pub fn f(v: Option<u32>) -> u32 { v.unwrap() }\n"),
            ("util/rng.rs", "pub fn g(v: Option<u32>) -> u32 { v.unwrap() }\n"),
            (
                "coding/encoder.rs",
                "pub fn h(v: Option<u32>) -> u32 { v.expect(\"inv\") } // lint: allow(no-panic-in-library): invariant by construction\n",
            ),
        ]);
        let fs = run_all(&t);
        assert_eq!(ids(&fs), vec![NO_PANIC_IN_LIBRARY]);
        assert_eq!(fs[0].file, "coding/combine.rs");
    }

    #[test]
    fn stray_io_exempts_cli() {
        let t = tree(&[
            ("cli.rs", "pub fn main2() { println!(\"ok\"); }\n"),
            ("coordinator/session.rs", "pub fn s() { eprintln!(\"warn\"); }\n"),
        ]);
        let fs = run_all(&t);
        assert_eq!(ids(&fs), vec![NO_STRAY_IO]);
        assert_eq!(fs[0].file, "coordinator/session.rs");
    }

    #[test]
    fn wallclock_confined_to_timer_and_netmodel() {
        let t = tree(&[
            ("util/timer.rs", "pub fn now() { let _ = std::time::Instant::now(); }\n"),
            ("cluster/netmodel.rs", "pub fn d() { let _ = std::time::Instant::now(); }\n"),
            ("cluster/round.rs", "pub fn r() { let _ = std::time::Instant::now(); }\n"),
        ]);
        let fs = run_all(&t);
        assert_eq!(ids(&fs), vec![NO_WALLCLOCK]);
        assert_eq!(fs[0].file, "cluster/round.rs");
    }

    #[test]
    fn field_debug_assert_rule() {
        let good = "\
pub fn add(&self, a: u64, b: u64) -> u64 {
    let s = a + b;
    let out = if s >= self.p { s - self.p } else { s };
    debug_assert!(out < self.p);
    out
}
";
        let bad = "\
pub fn add(&self, a: u64, b: u64) -> u64 {
    a + b
}
";
        let fs = run_all(&tree(&[("field/prime.rs", good)]));
        assert!(fs.is_empty(), "{fs:?}");
        let fs = run_all(&tree(&[("field/prime.rs", bad)]));
        assert_eq!(ids(&fs), vec![CANONICAL_DEBUG_ASSERTS]);
        assert!(fs[0].message.contains("`add`"));
    }

    #[test]
    fn field_debug_assert_rule_ignores_non_field_returns() {
        let src = "pub fn bits(&self) -> u32 { 26 }\npub fn check(&self) -> bool { true }\n";
        assert!(run_all(&tree(&[("field/prime.rs", src)])).is_empty());
    }

    #[test]
    fn unjustified_allow_is_reported_and_does_not_suppress() {
        let t = tree(&[(
            "field/ops.rs",
            "pub fn r(x: u64, p: u64) -> u64 { x % p } // lint: allow(no-hardware-modulo)\n",
        )]);
        let mut got = ids(&run_all(&t));
        got.sort_unstable();
        assert_eq!(got, vec![MALFORMED_ALLOW, NO_HARDWARE_MODULO]);
    }

    #[test]
    fn cross_session_rule_scoped_to_serve() {
        let t = tree(&[
            (
                "serve/scheduler.rs",
                "pub fn collect(r: &mut Round, res: StepResult) { r.absorb(res); }\n",
            ),
            // The cluster layer owns the session-checked absorb path.
            (
                "cluster/mod.rs",
                "pub fn park(r: &mut Round, res: StepResult) { r.absorb(res); }\n",
            ),
        ]);
        let fs = run_all(&t);
        assert_eq!(ids(&fs), vec![NO_CROSS_SESSION_STATE]);
        assert_eq!(fs[0].file, "serve/scheduler.rs");
    }

    #[test]
    fn cross_session_rule_exempts_tests_and_allows() {
        let src = "\
pub fn route(r: &mut Round) { let _ = r; }

#[cfg(test)]
mod tests {
    #[test]
    fn t(r: &mut super::Round, res: StepResult) { r.absorb(res); }
}
";
        assert!(run_all(&tree(&[("serve/scheduler.rs", src)])).is_empty());
    }

    #[test]
    fn unknown_rule_id_in_allow_is_reported() {
        let t = tree(&[(
            "util/rng.rs",
            "pub fn f() {} // lint: allow(no-such-rule): because\n",
        )]);
        assert_eq!(ids(&run_all(&t)), vec![MALFORMED_ALLOW]);
    }
}
