//! `cpml-lint`: in-repo static analysis for invariants the compiler
//! cannot see.
//!
//! CodedPrivateML's guarantees rest on cross-cutting source-level rules:
//! canonical field elements for bit-exact Barrett reduction, a privacy
//! boundary that keeps plaintext datasets away from worker code, no
//! nondeterminism or aborts inside the training loop. This module walks
//! `rust/src`, scrubs each file with a comment/string-aware mini-lexer
//! (no external parser), and runs seven rules over the result — see
//! `rules::RULES` and the "Machine-checked invariants" section of
//! `docs/ARCHITECTURE.md`.
//!
//! Entry points: `cargo run -- lint [--json]` (see `crate::cli`) and the
//! tier-1 test `rust/tests/lint.rs`, which requires a clean tree and
//! checks each fixture under `rust/tests/fixtures/lint/` trips exactly
//! its own rule.

pub mod lexer;
pub mod report;
pub mod rules;

use std::io;
use std::path::Path;

pub use lexer::ScrubbedFile;
pub use report::{report_json, sort_findings, Finding};
pub use rules::{RuleInfo, RULES};

/// A scrubbed snapshot of every `.rs` file under one root, with
/// `/`-separated paths relative to that root, in sorted order.
#[derive(Debug, Clone)]
pub struct SourceTree {
    pub files: Vec<ScrubbedFile>,
}

impl SourceTree {
    /// Walk `root` recursively, scrubbing every `.rs` file. Hidden
    /// directories and `target/` are skipped. Paths come back sorted so
    /// findings are deterministic across platforms.
    pub fn scan(root: &Path) -> io::Result<SourceTree> {
        let mut paths = Vec::new();
        walk(root, root, &mut paths)?;
        paths.sort();
        let mut files = Vec::with_capacity(paths.len());
        for rel in &paths {
            let source = std::fs::read_to_string(root.join(rel.replace('/', std::path::MAIN_SEPARATOR_STR)))?;
            files.push(ScrubbedFile::new(rel, &source));
        }
        Ok(SourceTree { files })
    }

    /// Build a tree from in-memory `(path, source)` pairs — for tests.
    pub fn from_sources(pairs: &[(&str, &str)]) -> SourceTree {
        let mut files: Vec<ScrubbedFile> =
            pairs.iter().map(|(p, s)| ScrubbedFile::new(p, s)).collect();
        files.sort_by(|a, b| a.path.cmp(&b.path));
        SourceTree { files }
    }

    /// Look up a file by tree-relative path.
    pub fn file(&self, path: &str) -> Option<&ScrubbedFile> {
        self.files.iter().find(|f| f.path == path)
    }
}

fn walk(root: &Path, dir: &Path, out: &mut Vec<String>) -> io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().into_owned();
        if path.is_dir() {
            if name.starts_with('.') || name == "target" {
                continue;
            }
            walk(root, &path, out)?;
        } else if name.ends_with(".rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}

/// Run every rule over a scrubbed tree. Findings are sorted and deduped;
/// an empty vec means the tree is clean.
pub fn lint(tree: &SourceTree) -> Vec<Finding> {
    rules::run_all(tree)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_sources_sorts_and_indexes() {
        let t = SourceTree::from_sources(&[("b.rs", "fn b() {}\n"), ("a.rs", "fn a() {}\n")]);
        assert_eq!(t.files[0].path, "a.rs");
        assert!(t.file("b.rs").is_some());
        assert!(t.file("c.rs").is_none());
    }

    #[test]
    fn scan_walks_the_real_source_tree() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust").join("src");
        let tree = SourceTree::scan(&root).expect("scan rust/src");
        assert!(tree.file("lib.rs").is_some());
        assert!(tree.file("analysis/mod.rs").is_some());
        assert!(tree.file("field/prime.rs").is_some());
    }

    #[test]
    fn the_repo_tree_is_lint_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust").join("src");
        let tree = SourceTree::scan(&root).expect("scan rust/src");
        let findings = lint(&tree);
        assert!(
            findings.is_empty(),
            "lint findings in the tree:\n{}",
            findings.iter().map(|f| format!("  {f}\n")).collect::<String>()
        );
    }
}
