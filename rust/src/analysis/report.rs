//! Finding type and report emission for the in-repo linter.
//!
//! Human output is one line per finding, `file:line rule-id message`,
//! matching compiler-style diagnostics so editors can jump to the site.
//! Machine output (`--json`) is a `LINT_REPORT.json` document with the
//! full finding list plus per-rule counts, built on `util::json`.

use std::fmt;

use crate::util::json::{obj, Json};

/// One rule violation at a specific source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Tree-relative `/`-separated path, e.g. `cluster/worker.rs`.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule id, e.g. `no-hardware-modulo`.
    pub rule: &'static str,
    /// Human-readable explanation of the violation.
    pub message: String,
}

impl Finding {
    pub fn new(file: &str, line: usize, rule: &'static str, message: String) -> Finding {
        Finding { file: file.to_string(), line, rule, message }
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{} {} {}", self.file, self.line, self.rule, self.message)
    }
}

/// Sort findings for deterministic output: by file, then line, then rule.
pub fn sort_findings(findings: &mut [Finding]) {
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
}

/// Build the `LINT_REPORT.json` document: per-rule counts (every known
/// rule id appears, zero or not), the total, and the finding list.
pub fn report_json(rule_ids: &[&str], findings: &[Finding]) -> Json {
    let mut by_rule: Vec<(&str, Json)> = Vec::new();
    for id in rule_ids {
        let n = findings.iter().filter(|f| f.rule == *id).count();
        by_rule.push((id, Json::Num(n as f64)));
    }
    // Findings may carry ids outside the registry (e.g. malformed-allow);
    // count those too so totals always reconcile.
    for f in findings {
        if !rule_ids.contains(&f.rule) && !by_rule.iter().any(|(id, _)| *id == f.rule) {
            let n = findings.iter().filter(|g| g.rule == f.rule).count();
            by_rule.push((f.rule, Json::Num(n as f64)));
        }
    }
    let list: Vec<Json> = findings
        .iter()
        .map(|f| {
            obj(&[
                ("file", Json::Str(f.file.clone())),
                ("line", Json::Num(f.line as f64)),
                ("rule", Json::Str(f.rule.to_string())),
                ("message", Json::Str(f.message.clone())),
            ])
        })
        .collect();
    obj(&[
        ("total", Json::Num(findings.len() as f64)),
        ("by_rule", obj(&by_rule)),
        ("findings", Json::Arr(list)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Finding> {
        vec![
            Finding::new("b.rs", 2, "no-stray-io", "println! in library code".into()),
            Finding::new("a.rs", 9, "no-hardware-modulo", "hardware % on field values".into()),
            Finding::new("a.rs", 3, "no-stray-io", "eprintln! in library code".into()),
        ]
    }

    #[test]
    fn display_is_compiler_style() {
        let f = &sample()[0];
        assert_eq!(format!("{f}"), "b.rs:2 no-stray-io println! in library code");
    }

    #[test]
    fn sorting_is_by_file_then_line() {
        let mut fs = sample();
        sort_findings(&mut fs);
        let order: Vec<(String, usize)> = fs.iter().map(|f| (f.file.clone(), f.line)).collect();
        assert_eq!(
            order,
            vec![("a.rs".into(), 3), ("a.rs".into(), 9), ("b.rs".into(), 2)]
        );
    }

    #[test]
    fn json_report_counts_per_rule() {
        let fs = sample();
        let j = report_json(&["no-hardware-modulo", "no-stray-io", "no-panic-in-library"], &fs);
        assert_eq!(j.get("total").unwrap().as_u64(), Some(3));
        let by_rule = j.get("by_rule").unwrap();
        assert_eq!(by_rule.get("no-stray-io").unwrap().as_u64(), Some(2));
        assert_eq!(by_rule.get("no-hardware-modulo").unwrap().as_u64(), Some(1));
        assert_eq!(by_rule.get("no-panic-in-library").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("findings").unwrap().as_arr().unwrap().len(), 3);
        // Round-trips through the parser.
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn json_report_counts_unregistered_rules() {
        let fs = vec![Finding::new("x.rs", 1, "malformed-allow", "missing justification".into())];
        let j = report_json(&["no-stray-io"], &fs);
        assert_eq!(j.get("by_rule").unwrap().get("malformed-allow").unwrap().as_u64(), Some(1));
    }
}
