//! The session scheduler: one shared pool, N interleaved training jobs.
//!
//! State machine per job: `Ready → Inflight → {Ready, Done, Failed}`.
//! The run loop alternates two moves until every job is `Done` or
//! `Failed`:
//!
//! 1. **Dispatch** — every `Ready` job's next round is encoded and sent
//!    to the pool, lowest virtual time first (weighted fair queueing:
//!    a job's virtual time advances by `1/priority` per round, ties
//!    break on session id). Dispatch never blocks, so all live jobs
//!    keep rounds in flight concurrently.
//! 2. **Collect** — the oldest in-flight round is collected to
//!    completion. Results for *other* sessions that arrive meanwhile are
//!    parked by the cluster and drained when their own round collects;
//!    a result whose session id matches no registered session is
//!    rejected and counted (`ServeReport::misrouted`).
//!
//! Healing is pool-aware: reviving a shared worker tears down every
//! session's engine on it, so after a revive the scheduler re-attaches
//! and re-loads **all** live jobs that span the worker (shipping the
//! exact encoded shares kept from construction — never re-encoded) and
//! re-dispatches the in-flight weights of each affected round. One job's
//! failure is never fatal to its siblings: it lands in that session's
//! [`SessionSummary::error`] and the run keeps going.

use std::collections::VecDeque;

use crate::cluster::{Cluster, ClusterError, Round, TransportKind};
use crate::coordinator::{
    CodedMlSession, IterationMetrics, ModelKind, ServeReport, SessionSummary,
};
use crate::data::{synthetic_3v7, synthetic_planted_linear};
use crate::util::timer::Deadline;

use super::spec::ServeSpec;
use super::AnySession;

/// Pool-level failures. Per-job failures never surface here — they land
/// in the job's [`SessionSummary::error`] instead.
#[derive(Debug)]
pub enum ServeError {
    /// The spec is unusable (bad shapes, pool/transport mismatch, a
    /// session that cannot be built).
    Spec(String),
    /// The shared pool itself could not be brought up or torn down.
    Cluster(ClusterError),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Spec(msg) => write!(f, "serve spec: {msg}"),
            ServeError::Cluster(e) => write!(f, "pool: {e}"),
        }
    }
}

impl std::error::Error for ServeError {}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum JobState {
    /// Next round may be dispatched.
    Ready,
    /// A round is on the workers, awaiting collection.
    Inflight,
    Done,
    Failed,
}

/// One scheduled job: the session plus everything pool healing needs —
/// its worker specs (chaos flags cleared as workers are revived) and the
/// exact encoded shares to re-ship.
struct Job {
    name: String,
    session: AnySession,
    session_id: u64,
    priority: u64,
    /// Weighted-fair-queueing clock: advances by `1/priority` per
    /// dispatched round.
    vtime: f64,
    specs: Vec<crate::cluster::WorkerSpec>,
    x_shares: Vec<Vec<u64>>,
    y_shares: Option<Vec<Vec<u64>>>,
    iters: usize,
    metrics: Vec<IterationMetrics>,
    error: Option<String>,
    state: JobState,
}

/// Multiplexes N concurrent [`AnySession`]s over one shared
/// [`Cluster`]. Build with [`Scheduler::new`], drive with
/// [`Scheduler::run`].
pub struct Scheduler {
    cluster: Cluster,
    jobs: Vec<Job>,
    pool_workers: usize,
    /// Per-worker revive budget (max `max_respawns` over the jobs; the
    /// pool is shared, so the most tolerant job sets the ceiling).
    respawn_budget: u32,
    respawns: u64,
    respawns_by_worker: Vec<u32>,
    /// Session id of every dispatched round, in dispatch order — the
    /// observable fair-share schedule.
    dispatch_log: Vec<u64>,
    /// Per-round misroute counts accumulated as rounds retire.
    misrouted_rounds: u64,
}

impl Scheduler {
    /// Build every session detached, spawn the shared pool, and attach
    /// + load each session onto it. The pool is as wide as the widest
    /// job; narrower jobs span a prefix of it
    /// ([`Cluster::set_session_workers`]).
    pub fn new(spec: ServeSpec) -> Result<Scheduler, ServeError> {
        let mut jobs = Vec::with_capacity(spec.jobs.len());
        for (i, js) in spec.jobs.iter().enumerate() {
            let sid = (i + 1) as u64;
            let bad = |e: &dyn std::fmt::Display| {
                ServeError::Spec(format!("session '{}': {e}", js.name))
            };
            let (session, specs, x_shares, y_shares) = match js.cfg.model {
                ModelKind::Logistic => {
                    let ds = synthetic_3v7(js.m, js.data_seed);
                    let parts = CodedMlSession::new_detached(js.cfg.clone(), &ds, sid)
                        .map_err(|e| bad(&e))?;
                    (
                        AnySession::Logistic(Box::new(parts.session)),
                        parts.specs,
                        parts.x_shares,
                        parts.y_shares,
                    )
                }
                ModelKind::Linear => {
                    let (ds, _) = synthetic_planted_linear(js.m, js.d, js.data_seed);
                    let parts =
                        CodedMlSession::new_linear_detached(js.cfg.clone(), &ds, sid)
                            .map_err(|e| bad(&e))?;
                    (
                        AnySession::Linear(Box::new(parts.session)),
                        parts.specs,
                        parts.x_shares,
                        parts.y_shares,
                    )
                }
            };
            jobs.push(Job {
                name: js.name.clone(),
                session,
                session_id: sid,
                priority: js.cfg.priority,
                vtime: 0.0,
                specs,
                x_shares,
                y_shares,
                iters: js.cfg.iters,
                metrics: Vec::new(),
                error: None,
                state: JobState::Ready,
            });
        }

        // The pool spans the widest job; worker w's spawn spec is
        // borrowed from any job covering w (attachment below rebuilds
        // every covering job's engine on it anyway).
        let pool = jobs.iter().map(|j| j.specs.len()).max().unwrap_or(0);
        let mut pool_specs = Vec::with_capacity(pool);
        for w in 0..pool {
            match jobs.iter().find(|j| j.specs.len() > w) {
                Some(j) => pool_specs.push(j.specs[w].clone()),
                None => return Err(ServeError::Spec(format!("no job covers worker {w}"))),
            }
        }
        if spec.transport.kind == TransportKind::Tcp
            && spec.transport.tcp.workers.len() != pool
        {
            return Err(ServeError::Spec(format!(
                "tcp pool of {pool} workers needs {pool} addresses in \
                 'tcp_workers', got {}",
                spec.transport.tcp.workers.len()
            )));
        }
        let respawn_budget = spec.jobs.iter().map(|j| j.cfg.max_respawns).max().unwrap_or(0);

        let mut cluster =
            Cluster::connect(pool_specs, &spec.transport).map_err(ServeError::Cluster)?;
        for job in &jobs {
            cluster.register_session(job.session_id);
            cluster.set_session_workers(job.session_id, job.specs.len());
            for sp in &job.specs {
                // A worker unreachable at attach time stays marked down
                // and is charged a failure each round — same contract as
                // a dedicated cluster.
                let _ = cluster.attach_worker(sp);
            }
            cluster
                .load_data_for(job.session_id, job.x_shares.clone(), job.y_shares.clone())
                .map_err(ServeError::Cluster)?;
        }

        Ok(Scheduler {
            cluster,
            jobs,
            pool_workers: pool,
            respawn_budget,
            respawns: 0,
            respawns_by_worker: vec![0; pool],
            dispatch_log: Vec::new(),
            misrouted_rounds: 0,
        })
    }

    /// Shared pool width.
    pub fn pool_workers(&self) -> usize {
        self.pool_workers
    }

    /// Session id of every dispatched round, in dispatch order.
    pub fn dispatch_log(&self) -> &[u64] {
        &self.dispatch_log
    }

    /// Drive every job to `Done` (or `Failed`) and assemble the
    /// [`ServeReport`]. Consumes the per-round metrics, so call once.
    pub fn run(&mut self) -> Result<ServeReport, ServeError> {
        let Scheduler {
            cluster,
            jobs,
            respawn_budget,
            respawns,
            respawns_by_worker,
            dispatch_log,
            misrouted_rounds,
            ..
        } = self;
        let pool_workers = self.pool_workers;
        let mut queue: VecDeque<usize> = VecDeque::new();
        loop {
            // (1) Dispatch wave: offer a slot to every ready job, lowest
            // virtual time first (ties on session id). All live jobs end
            // up with rounds in flight at once — that concurrency is the
            // whole point of sharing the pool.
            loop {
                let next = (0..jobs.len())
                    .filter(|&i| jobs[i].state == JobState::Ready)
                    .min_by(|&a, &b| {
                        jobs[a]
                            .vtime
                            .total_cmp(&jobs[b].vtime)
                            .then(jobs[a].session_id.cmp(&jobs[b].session_id))
                    });
                let ci = match next {
                    Some(ci) => ci,
                    None => break,
                };
                match jobs[ci].session.begin_round(cluster) {
                    Ok(()) => {
                        jobs[ci].state = JobState::Inflight;
                        jobs[ci].vtime += 1.0 / jobs[ci].priority as f64;
                        dispatch_log.push(jobs[ci].session_id);
                        queue.push_back(ci);
                    }
                    Err(e) => {
                        jobs[ci].error = Some(e.to_string());
                        jobs[ci].state = JobState::Failed;
                    }
                }
            }
            if queue.is_empty() {
                // Nothing dispatched and nothing ready: every job is
                // done or failed.
                break;
            }

            // (2) Collect wave: retire every in-flight round, oldest
            // dispatch first. Traffic for rounds deeper in the queue is
            // parked by the cluster while an earlier one collects.
            while let Some(ci) = queue.pop_front() {
                let mut round = match jobs[ci].session.collect_round(cluster) {
                    Ok(r) => r,
                    Err(e) => {
                        jobs[ci].error = Some(e.to_string());
                        jobs[ci].state = JobState::Failed;
                        continue;
                    }
                };

                // (3) While short of R, heal failed shared workers
                // (within budget) and resume collecting the reopened
                // round.
                let mut aborted = false;
                while !round.ok() {
                    if !heal_pass(
                        cluster,
                        jobs,
                        ci,
                        &mut round,
                        *respawn_budget,
                        respawns,
                        respawns_by_worker,
                    ) {
                        break;
                    }
                    let dl = jobs[ci].session.last_deadline_ms();
                    if let Err(e) =
                        cluster.collect_resume(&mut round, &Deadline::after_ms(dl))
                    {
                        jobs[ci].error = Some(format!("collect resume: {e}"));
                        jobs[ci].state = JobState::Failed;
                        aborted = true;
                        break;
                    }
                }
                *misrouted_rounds += round.misrouted;
                if aborted {
                    continue;
                }

                // (4) Decode + apply; record the round's metrics.
                match jobs[ci].session.finish_round(cluster, round) {
                    Ok(_) => {
                        let m = IterationMetrics {
                            iter: jobs[ci].metrics.len(),
                            train_loss: jobs[ci].session.train_loss(),
                            test_accuracy: None,
                        };
                        jobs[ci].metrics.push(m);
                        jobs[ci].state = if jobs[ci].metrics.len() >= jobs[ci].iters {
                            JobState::Done
                        } else {
                            JobState::Ready
                        };
                    }
                    Err(e) => {
                        jobs[ci].error = Some(e.to_string());
                        jobs[ci].state = JobState::Failed;
                    }
                }
            }
        }

        let (wire_sent, wire_received) = cluster.wire_bytes();
        let mut sessions = Vec::with_capacity(jobs.len());
        for job in jobs.iter_mut() {
            let metrics = std::mem::take(&mut job.metrics);
            sessions.push(SessionSummary {
                name: job.name.clone(),
                session_id: job.session_id,
                priority: job.priority,
                objective: job.session.config().model.to_string(),
                error: job.error.clone(),
                report: job.session.report(metrics),
            });
        }
        Ok(ServeReport {
            transport: cluster.transport_name().to_string(),
            pool_workers,
            wire_sent,
            wire_received,
            misrouted: cluster.misrouted() + *misrouted_rounds,
            respawns: *respawns,
            sessions,
        })
    }
}

/// Revive the collecting round's failed workers (within the per-worker
/// budget) and rebuild every live sibling's engine on each revived
/// worker. Returns whether at least one failure was healed — i.e.
/// whether the round reopened and collection should resume.
fn heal_pass(
    cluster: &mut Cluster,
    jobs: &mut [Job],
    ci: usize,
    round: &mut Round,
    budget: u32,
    respawns: &mut u64,
    respawns_by_worker: &mut [u32],
) -> bool {
    if budget == 0 {
        return false;
    }
    let mut failed: Vec<usize> = round.failures.iter().map(|&(w, _)| w).collect();
    failed.sort_unstable();
    failed.dedup();
    let mut healed_any = false;
    for w in failed {
        if w >= respawns_by_worker.len()
            || respawns_by_worker[w] >= budget
            || w >= jobs[ci].specs.len()
        {
            continue;
        }
        // A revived worker comes back healthy: clear the chaos flag so
        // the replacement engine (and any later revive) doesn't re-fail.
        jobs[ci].specs[w].fail_from_iter = None;
        let spec = jobs[ci].specs[w].clone();
        let x = jobs[ci].x_shares[w].clone();
        let y = jobs[ci].y_shares.as_ref().map(|ys| ys[w].clone());
        if cluster.revive(&spec, x, y).is_err() {
            // Still unreachable; the failure stands and the job's
            // degrade-or-abort ladder decides.
            continue;
        }
        *respawns += 1;
        respawns_by_worker[w] += 1;
        // The revive rebuilt worker w with only the collecting session's
        // engine. Re-attach and re-load every other live job spanning w
        // (the exact shares kept from construction — never re-encoded),
        // and re-send in-flight weights so their open rounds still
        // complete.
        for j in 0..jobs.len() {
            if j == ci
                || jobs[j].specs.len() <= w
                || matches!(jobs[j].state, JobState::Done | JobState::Failed)
            {
                continue;
            }
            jobs[j].specs[w].fail_from_iter = None;
            let sp = jobs[j].specs[w].clone();
            if cluster.attach_worker(&sp).is_err() {
                continue;
            }
            let xj = jobs[j].x_shares[w].clone();
            let yj = jobs[j].y_shares.as_ref().map(|ys| ys[w].clone());
            let _ = cluster.load_worker(w, jobs[j].session_id, xj, yj);
            if jobs[j].state == JobState::Inflight {
                let _ = jobs[j].session.redispatch(cluster, w);
            }
        }
        // Only reopen the round once the replacement actually has this
        // iteration's weights; otherwise the failure stands.
        if jobs[ci].session.redispatch(cluster, w).is_ok() && round.heal(w) {
            healed_any = true;
        }
    }
    healed_any
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet(extra: &str) -> String {
        // Deterministic, fast sessions: no modeled stragglers/network
        // noise beyond the defaults, tiny iteration counts.
        format!(
            r#"{{ "sessions": [
                {{ "name": "log", "m": 60, "data_seed": 3,
                   "config": {{ "n": 8, "k": 2, "t": 1, "iters": 3 {extra} }} }},
                {{ "name": "lin", "m": 60, "d": 4, "data_seed": 9,
                   "config": {{ "model": "linear", "n": 6, "k": 1, "t": 1,
                                "iters": 3 }} }}
            ] }}"#
        )
    }

    #[test]
    fn two_heterogeneous_jobs_complete_with_clean_routing() {
        let spec = ServeSpec::from_json(&quiet("")).unwrap();
        let mut sched = Scheduler::new(spec).unwrap();
        assert_eq!(sched.pool_workers(), 8);
        let rep = sched.run().unwrap();
        assert_eq!(rep.sessions.len(), 2);
        for s in &rep.sessions {
            assert_eq!(s.error, None, "session '{}' failed", s.name);
            assert_eq!(s.report.iterations.len(), 3);
        }
        assert_eq!(rep.misrouted, 0, "session routing must be airtight");
        assert_eq!(rep.transport, "memory");
        // Both sessions' rounds actually interleaved.
        let log = sched.dispatch_log();
        assert_eq!(log.iter().filter(|&&s| s == 1).count(), 3);
        assert_eq!(log.iter().filter(|&&s| s == 2).count(), 3);
    }

    #[test]
    fn priority_orders_dispatch_within_each_wave() {
        // Give the *second* session (higher id — it loses every id
        // tie-break) the higher priority; once virtual times diverge it
        // must be offered slots first.
        let spec = ServeSpec::from_json(
            r#"{ "sessions": [
                { "name": "slowpoke", "m": 60, "data_seed": 3,
                  "config": { "n": 6, "k": 1, "t": 1, "iters": 3 } },
                { "name": "vip", "m": 60, "data_seed": 5,
                  "config": { "n": 6, "k": 1, "t": 1, "iters": 3,
                              "priority": 4 } }
            ] }"#,
        )
        .unwrap();
        let mut sched = Scheduler::new(spec).unwrap();
        sched.run().unwrap();
        let log = sched.dispatch_log().to_vec();
        assert_eq!(log.len(), 6);
        // Wave 1: both at vtime 0 — id order. Every later wave: the
        // priority-4 job's clock (1/4 per round) trails the
        // priority-1 job's, so it dispatches first.
        assert_eq!(&log[..2], &[1, 2]);
        for pair in log[2..].chunks(2) {
            assert_eq!(pair, &[2, 1], "full log: {log:?}");
        }
    }

    #[test]
    fn one_jobs_failure_never_takes_down_its_sibling() {
        // Session 1 loses more workers than its threshold can absorb
        // (n=8, k=2, t=1 ⇒ R=7; 3 dead leaves 5 usable) with no respawn
        // budget: it must fail; its sibling must finish clean.
        let spec = ServeSpec::from_json(
            r#"{ "sessions": [
                { "name": "doomed", "m": 60, "data_seed": 3,
                  "config": { "n": 8, "k": 2, "t": 1, "iters": 3,
                              "chaos_failures": 3, "chaos_from_iter": 1 } },
                { "name": "survivor", "m": 60, "data_seed": 5,
                  "config": { "n": 8, "k": 2, "t": 1, "iters": 3 } }
            ] }"#,
        )
        .unwrap();
        let mut sched = Scheduler::new(spec).unwrap();
        let rep = sched.run().unwrap();
        let doomed = &rep.sessions[0];
        let survivor = &rep.sessions[1];
        let msg = doomed.error.as_deref().unwrap_or("");
        assert!(msg.contains("produced results"), "expected threshold abort, got '{msg}'");
        assert_eq!(survivor.error, None);
        assert_eq!(survivor.report.iterations.len(), 3);
        assert_eq!(rep.misrouted, 0);
    }
}
