//! The `codedml serve --sessions <spec.json>` input format.
//!
//! A serve spec is one JSON object describing the shared pool and the
//! jobs multiplexed over it:
//!
//! ```json
//! {
//!   "transport": "memory",
//!   "sessions": [
//!     { "name": "mnist-3v7", "m": 120, "data_seed": 7,
//!       "config": { "n": 8, "k": 2, "t": 1, "iters": 5 } },
//!     { "name": "planted-linear", "m": 120, "d": 4, "data_seed": 11,
//!       "config": { "model": "linear", "n": 6, "k": 2, "t": 1,
//!                   "iters": 5, "priority": 2 } }
//!   ]
//! }
//! ```
//!
//! The transport is a property of the *pool*, not of any one job — a
//! session config that tries to set `transport`/`tcp_workers` is
//! rejected. Nested `"config"` objects otherwise take every key
//! [`CodedMlConfig::apply_json`] knows, with `"model": "linear"` also
//! switching the base defaults to [`CodedMlConfig::linear`].

use crate::cluster::{TransportConfig, TransportKind};
use crate::coordinator::CodedMlConfig;
use crate::util::json::Json;

/// One job of a serve run: dataset shape + full session config.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    /// Training rows (trimmed to a multiple of K by the session).
    pub m: usize,
    /// Feature count — only used by the linear objective's planted
    /// dataset; the logistic 3-vs-7 dataset fixes its own width.
    pub d: usize,
    /// Seed of the synthetic dataset (independent of `cfg.seed`, which
    /// drives masks/quantization/stragglers).
    pub data_seed: u64,
    pub cfg: CodedMlConfig,
}

/// A parsed serve spec: the pool transport plus the jobs to multiplex.
#[derive(Debug, Clone)]
pub struct ServeSpec {
    pub transport: TransportConfig,
    pub jobs: Vec<JobSpec>,
}

impl ServeSpec {
    /// Parse a spec from JSON text. Unknown keys are rejected at both
    /// levels — a typoed knob silently ignored is a misconfigured
    /// experiment.
    pub fn from_json(text: &str) -> Result<ServeSpec, String> {
        let root = Json::parse(text).map_err(|e| e.to_string())?;
        let obj = root.as_obj().ok_or("serve spec must be a JSON object")?;
        let mut transport = TransportConfig::default();
        let mut sessions: Option<&[Json]> = None;
        for (key, val) in obj {
            match key.as_str() {
                "transport" => {
                    transport.kind = val
                        .as_str()
                        .ok_or("transport: want string")?
                        .parse::<TransportKind>()?
                }
                "tcp_workers" => {
                    let arr = val.as_arr().ok_or("tcp_workers: want array of strings")?;
                    let mut workers = Vec::with_capacity(arr.len());
                    for v in arr {
                        workers.push(
                            v.as_str().ok_or("tcp_workers: want array of strings")?.to_string(),
                        );
                    }
                    transport.tcp.workers = workers;
                }
                "sessions" => {
                    sessions = Some(val.as_arr().ok_or("sessions: want an array")?)
                }
                other => return Err(format!("unknown serve spec key '{other}'")),
            }
        }
        let sessions = sessions.ok_or("serve spec needs a 'sessions' array")?;
        if sessions.is_empty() {
            return Err("serve spec needs at least one session".to_string());
        }
        let mut jobs = Vec::with_capacity(sessions.len());
        for (i, s) in sessions.iter().enumerate() {
            let job = parse_job(s, i).map_err(|e| format!("sessions[{i}]: {e}"))?;
            if jobs.iter().any(|j: &JobSpec| j.name == job.name) {
                return Err(format!("sessions[{i}]: duplicate session name '{}'", job.name));
            }
            jobs.push(job);
        }
        Ok(ServeSpec { transport, jobs })
    }
}

fn parse_job(s: &Json, index: usize) -> Result<JobSpec, String> {
    let obj = s.as_obj().ok_or("want an object")?;
    let mut name = format!("session-{}", index + 1);
    let mut m = 120usize;
    let mut d = 4usize;
    let mut data_seed = 7u64;
    let mut config_text: Option<String> = None;
    for (key, val) in obj {
        match key.as_str() {
            "name" => name = val.as_str().ok_or("name: want string")?.to_string(),
            "m" => m = val.as_usize().ok_or("m: want integer")?,
            "d" => d = val.as_usize().ok_or("d: want integer")?,
            "data_seed" => data_seed = val.as_u64().ok_or("data_seed: want integer")?,
            "config" => {
                let cobj = val.as_obj().ok_or("config: want an object")?;
                if let Some(forbidden) = cobj.keys().find(|k| {
                    *k == "transport" || *k == "tcp_workers" || k.starts_with("connect_")
                }) {
                    return Err(format!(
                        "config key '{forbidden}': per-session transport is owned \
                         by the pool; set it at the spec top level"
                    ));
                }
                config_text = Some(val.to_string());
            }
            other => return Err(format!("unknown session key '{other}'")),
        }
    }
    // "model": "linear" switches the base defaults too (larger prime,
    // linear quantization scales) — exactly what `codedml train` does.
    let linear_base = config_text
        .as_deref()
        .and_then(|t| Json::parse(t).ok())
        .and_then(|c| c.get("model").and_then(|v| v.as_str().map(|s| s == "linear")))
        .unwrap_or(false);
    let mut cfg =
        if linear_base { CodedMlConfig::linear() } else { CodedMlConfig::default() };
    if let Some(text) = &config_text {
        cfg.apply_json(text)?;
    }
    if cfg.approx_decode {
        return Err(
            "approx_decode is not supported under serve: a degraded round's \
             output depends on which subset arrived, so pool interleaving \
             could change the trajectory and break the bit-identical \
             isolation invariant"
                .to_string(),
        );
    }
    Ok(JobSpec { name, m, d, data_seed, cfg })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ModelKind;

    #[test]
    fn parses_two_heterogeneous_sessions() {
        let spec = ServeSpec::from_json(
            r#"{
                "transport": "memory",
                "sessions": [
                    { "name": "log", "m": 60, "data_seed": 3,
                      "config": { "n": 8, "k": 2, "t": 1, "iters": 4 } },
                    { "name": "lin", "m": 80, "d": 5, "data_seed": 9,
                      "config": { "model": "linear", "n": 6, "k": 2, "t": 1,
                                  "priority": 2 } }
                ]
            }"#,
        )
        .unwrap();
        assert_eq!(spec.transport.kind, TransportKind::Memory);
        assert_eq!(spec.jobs.len(), 2);
        assert_eq!(spec.jobs[0].name, "log");
        assert_eq!(spec.jobs[0].m, 60);
        assert_eq!(spec.jobs[0].cfg.n, 8);
        assert_eq!(spec.jobs[0].cfg.model, ModelKind::Logistic);
        assert_eq!(spec.jobs[1].cfg.model, ModelKind::Linear);
        // Linear base defaults engaged, then overridden keys applied.
        assert_eq!(spec.jobs[1].cfg.p, crate::field::PRIME_26);
        assert_eq!(spec.jobs[1].cfg.priority, 2);
        assert_eq!(spec.jobs[1].d, 5);
    }

    #[test]
    fn default_names_are_positional() {
        let spec = ServeSpec::from_json(
            r#"{ "sessions": [ { "config": { "iters": 1 } }, {} ] }"#,
        )
        .unwrap();
        assert_eq!(spec.jobs[0].name, "session-1");
        assert_eq!(spec.jobs[1].name, "session-2");
    }

    #[test]
    fn rejects_per_session_transport() {
        let err = ServeSpec::from_json(
            r#"{ "sessions": [ { "config": { "transport": "tcp" } } ] }"#,
        )
        .unwrap_err();
        assert!(err.contains("owned by the pool"), "{err}");
        let err = ServeSpec::from_json(
            r#"{ "sessions": [ { "config": { "tcp_workers": ["x:1"] } } ] }"#,
        )
        .unwrap_err();
        assert!(err.contains("owned by the pool"), "{err}");
    }

    #[test]
    fn rejects_approx_decode() {
        let err = ServeSpec::from_json(
            r#"{ "sessions": [ { "config": { "approx_decode": true } } ] }"#,
        )
        .unwrap_err();
        assert!(err.contains("isolation invariant"), "{err}");
    }

    #[test]
    fn rejects_unknown_and_malformed_keys() {
        assert!(ServeSpec::from_json(r#"{ "sesions": [] }"#).is_err());
        assert!(ServeSpec::from_json(r#"{ "sessions": [ { "mm": 3 } ] }"#).is_err());
        assert!(ServeSpec::from_json(r#"{ "sessions": [] }"#).is_err());
        assert!(ServeSpec::from_json(r#"[1, 2]"#).is_err());
        let err = ServeSpec::from_json(
            r#"{ "sessions": [ { "name": "a" }, { "name": "a" } ] }"#,
        )
        .unwrap_err();
        assert!(err.contains("duplicate"), "{err}");
    }

    #[test]
    fn tcp_spec_carries_worker_addresses() {
        let spec = ServeSpec::from_json(
            r#"{ "transport": "tcp",
                 "tcp_workers": ["127.0.0.1:9001", "127.0.0.1:9002"],
                 "sessions": [ { "config": { "n": 2, "k": 1, "t": 1 } } ] }"#,
        )
        .unwrap();
        assert_eq!(spec.transport.kind, TransportKind::Tcp);
        assert_eq!(spec.transport.tcp.workers.len(), 2);
    }
}
