//! Multi-session serving: N concurrent training jobs over one pool.
//!
//! A dedicated [`CodedMlSession`] owns its cluster outright. The serve
//! layer breaks that coupling: the [`Scheduler`] owns a single
//! [`crate::cluster::Cluster`] (either transport) and multiplexes any
//! number of concurrent sessions over it, each encoded and secret-shared
//! independently (possibly with different K/T/N, moduli, and even
//! objectives) and addressed on the wire by its `session_id`.
//!
//! The invariant the whole layer is built around: **a session's
//! trajectory under the scheduler is bit-identical to running alone on a
//! dedicated cluster**. LCC decoding is exact on *any* fastest-R subset,
//! so interleaving — which only perturbs arrival order — can never change
//! a decoded gradient; session-scoped routing (results carry their
//! session id, mismatches are parked or rejected, never absorbed) keeps
//! one job's rounds out of another's decoder; and pool heals re-ship the
//! exact encoded shares kept from construction, never re-encode.
//! `rust/tests/serve.rs` asserts the invariant on both transports, at
//! several thread counts, and under chaos churn.
//!
//! Scheduling is weighted fair queueing over round slots: among
//! simultaneously-ready sessions, dispatch goes to the lowest virtual
//! time first, and a session's virtual time advances by `1/priority` per
//! round (config key `priority`). Dispatch is pipelined — every ready
//! session's round goes to the workers before the scheduler blocks
//! collecting the oldest one — so heterogeneous jobs genuinely overlap on
//! the shared pool (`rust/benches/serve.rs` measures the win).

mod scheduler;
mod spec;

pub use scheduler::{Scheduler, ServeError};
pub use spec::{JobSpec, ServeSpec};

use crate::cluster::{Cluster, Round};
use crate::coordinator::{
    CodedMlConfig, CodedMlSession, IterationMetrics, LinearObjective, LogisticObjective,
    TrainError, TrainReport,
};

/// A scheduler-driven session of either objective. The scheduler is
/// deliberately objective-agnostic: everything it needs is the detached
/// round API, which both instantiations share.
pub enum AnySession {
    Logistic(Box<CodedMlSession<LogisticObjective>>),
    Linear(Box<CodedMlSession<LinearObjective>>),
}

macro_rules! delegate {
    ($self:ident, $s:ident => $body:expr) => {
        match $self {
            AnySession::Logistic($s) => $body,
            AnySession::Linear($s) => $body,
        }
    };
}

impl AnySession {
    /// Encode this iteration's weights and dispatch them to the pool
    /// under this session's id.
    pub fn begin_round(&mut self, cluster: &mut Cluster) -> Result<(), TrainError> {
        delegate!(self, s => s.begin_round(cluster))
    }

    /// Stream this session's results until the fastest R land (or its
    /// deadline fires). Other sessions' traffic is parked by the cluster.
    pub fn collect_round(&mut self, cluster: &mut Cluster) -> Result<Round, TrainError> {
        delegate!(self, s => s.collect_round(cluster))
    }

    /// Account, decode, and apply the collected round.
    pub fn finish_round(
        &mut self,
        cluster: &mut Cluster,
        round: Round,
    ) -> Result<Vec<f64>, TrainError> {
        delegate!(self, s => s.finish_round(cluster, round))
    }

    /// Re-send the in-flight round's kept weights to one (just-revived)
    /// worker.
    pub fn redispatch(&mut self, cluster: &mut Cluster, worker: usize) -> Result<(), String> {
        delegate!(self, s => s.redispatch(cluster, worker))
    }

    pub fn train_loss(&self) -> f64 {
        delegate!(self, s => s.train_loss())
    }

    pub fn session_id(&self) -> u64 {
        delegate!(self, s => s.session_id())
    }

    pub fn config(&self) -> &CodedMlConfig {
        delegate!(self, s => s.config())
    }

    pub fn current_iter(&self) -> u64 {
        delegate!(self, s => s.current_iter())
    }

    /// Deadline the in-flight round was collected under (for heal
    /// resumes).
    pub fn last_deadline_ms(&self) -> u64 {
        delegate!(self, s => s.last_deadline_ms())
    }

    /// Assemble the session's [`TrainReport`] from the metrics the
    /// scheduler recorded round by round.
    pub fn report(&mut self, iterations: Vec<IterationMetrics>) -> TrainReport {
        delegate!(self, s => s.report(iterations))
    }
}

impl std::fmt::Debug for AnySession {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        delegate!(self, s => write!(f, "AnySession({s:?})"))
    }
}
