//! `codedml` command-line interface.
//!
//! ```text
//! codedml train       [--model logistic|linear --n 10 --k 3 --t 1 --r 1
//!                      --case 1|2 --iters 25 --m 600 --d 784 --dup
//!                      --batch-blocks 0 --backend native|xla --seed 42
//!                      --threads serial|auto|<n> --config cfg.json --json out.json
//!                      --coding-backend auto|dense|ntt --decode-cache-cap 256
//!                      --transport memory|tcp --workers host:port,host:port,...
//!                      --connect-timeout-ms 5000 --connect-retries 3
//!                      --connect-backoff-ms 100 --round-deadline-ms 0
//!                      --approx-decode --approx-r-min 0 --max-respawns 0
//!                      --adaptive-deadline]
//! codedml serve       --sessions spec.json [--report-json out.json]
//!                     multiplex several training sessions over one shared
//!                     worker pool (see `serve` module docs for the spec
//!                     format and the bit-identical isolation invariant)
//! codedml --worker    [--listen 127.0.0.1:0]   run one TCP worker process:
//!                     bind, print "worker listening on <addr>", serve
//!                     master connections until a Shutdown frame (a lost
//!                     master — or a supervisor redial — can reconnect)
//! codedml mpc         [--n 10 --t 4 --iters 25 --m 600 --d 784
//!                      --threads serial|auto|<n>]
//! codedml reproduce   <fig2|table1..6|fig3|fig4|fig5|linear|all>
//!                     [--scale 0.05 --iters 25 --json out.json --backend ...]
//! codedml budget      [--m 12396 --k 13 --lx 2 --lw 4 --lc 3 --r 1 --p ...]
//! codedml artifacts   [--dir artifacts]
//! codedml lint        [--json [path] --root rust/src]
//! codedml list
//! ```
//!
//! `--model linear` trains coded linear regression (paper Remark 1) on a
//! planted synthetic task — defaults shift to m=240, d=8, l_x=4, l_w=6,
//! the 26-bit prime — and reports the recovery error ‖w − w*‖.
//!
//! `--threads` bounds the thread pool used by the Lagrange encode, the
//! per-worker matmuls, and the decode (`serial` = 1 thread, the default;
//! `auto` = one per core; `<n>` = exactly n). Results are bit-identical at
//! every setting — only wall-clock time changes.
//!
//! `--transport tcp --workers a:p,b:p,...` points the master at N running
//! `codedml --worker` processes (one address per worker id, in order);
//! `--workers` alone implies `--transport tcp`. Decoded gradients are
//! bit-identical to the in-memory backend — only the wire changes.

use std::path::PathBuf;

use crate::cluster::{NetworkModel, StragglerModel, TransportKind};
use crate::coordinator::{CodedMlConfig, CodedMlSession, ModelKind};
use crate::data::{paper_dataset, synthetic_3v7, synthetic_planted_linear};
use crate::mpc::{BgwConfig, BgwGradientProtocol};
use crate::quant::OverflowBudget;
use crate::reproduce::{self, run_experiment, ExpParams};
use crate::runtime::{BackendKind, XlaRuntime};
use crate::util::args::Args;
use crate::util::json::Json;

const USAGE: &str = "usage: codedml <train|serve|mpc|reproduce|budget|artifacts|lint|list> [options]
       codedml --worker [--listen <addr>]
  train      run one CodedPrivateML training session
  serve      multiplex several training sessions over one shared worker
             pool (--sessions spec.json; --report-json writes the
             per-session ServeReport)
  mpc        run the BGW MPC baseline
  reproduce  regenerate a paper table/figure (or 'all')
  budget     overflow-budget analysis for a parameter set
  artifacts  inspect the AOT artifact manifest
  lint       run the in-repo invariant linter over rust/src
             (--json [path] writes LINT_REPORT.json)
  list       list reproducible experiments
  --worker   run one TCP worker process: bind --listen (default
             127.0.0.1:0), print the bound address, serve master
             connections (see train --transport tcp) until a Shutdown
             frame arrives; dropped connections return to accept so a
             supervising master can redial

common options:
  --model logistic|linear     coded objective to train (default logistic;
                              linear = paper Remark 1 on a planted task)
  --threads serial|auto|<n>   thread pool for encode/compute/decode hot
                              paths (default serial; results are identical
                              at every setting, only wall-clock changes)
  --transport memory|tcp      cluster transport (default memory; tcp needs
                              --workers with one host:port per worker)
  --workers a:p,b:p,...       worker addresses, index = worker id
                              (implies --transport tcp)
  --coding-backend auto|dense|ntt
                              Lagrange encode/decode path (default auto:
                              roots-of-unity NTT coset when the modulus
                              supports it and it wins at this (K,T,N);
                              ntt on a low-adicity modulus is an error)
  --decode-cache-cap <n>      max cached decoder subsets, LRU-evicted
                              (default 256; 0 = unbounded)
  --round-deadline-ms <ms>    per-round collection deadline (default 0 =
                              wait forever); silent workers are charged a
                              failure when it fires
  --approx-decode             degraded mode: least-squares approximate
                              decode instead of aborting when a round
                              ends below the recovery threshold
  --approx-r-min <n>          abort anyway below this many usable results
                              (default 0 = auto, K+T)
  --max-respawns <n>          per-worker heal budget: revive failed
                              workers (TCP redial / in-memory respawn and
                              share re-ship; default 0 = off)
  --adaptive-deadline         tighten the round deadline to mean + 4 sigma
                              of observed round times
  --report-json <path>        write the run's full report (train: the
                              TrainReport; serve: the ServeReport) as JSON";

/// Entry point; returns the process exit code.
pub fn run() -> i32 {
    let args = Args::from_env();
    match dispatch(&args) {
        Ok(()) => {
            let unknown = args.unknown_options();
            if !unknown.is_empty() {
                eprintln!("warning: unused option(s): --{}", unknown.join(", --"));
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn dispatch(args: &Args) -> Result<(), String> {
    // Worker mode first: `codedml --worker` has no subcommand and must
    // stay minimal — a remote host runs exactly this plus a port.
    if args.flag("worker") {
        return cmd_worker(args);
    }
    match args.positional.first().map(String::as_str) {
        Some("train") => cmd_train(args),
        Some("serve") => cmd_serve(args),
        Some("mpc") => cmd_mpc(args),
        Some("reproduce") => cmd_reproduce(args),
        Some("budget") => cmd_budget(args),
        Some("artifacts") => cmd_artifacts(args),
        Some("lint") => cmd_lint(args),
        Some("list") => {
            for e in reproduce::EXPERIMENTS {
                println!("{:<8} {:<18} {}", e.id, e.paper_ref, e.what);
            }
            Ok(())
        }
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

/// `codedml --worker [--listen <addr>]`: bind, announce the bound address
/// on stdout (the conformance suite and scripts parse this line — the OS
/// picks the port when `--listen` ends in `:0`), serve exactly one master
/// connection, exit. Worker processes hold only their own coded share;
/// the privacy boundary (`no-plaintext-to-workers`) is unchanged.
fn cmd_worker(args: &Args) -> Result<(), String> {
    use std::io::Write as _;
    let listen = args.get("listen").unwrap_or("127.0.0.1:0");
    let listener = std::net::TcpListener::bind(listen)
        .map_err(|e| format!("bind {listen}: {e}"))?;
    let addr = listener
        .local_addr()
        .map_err(|e| format!("local addr: {e}"))?;
    println!("worker listening on {addr}");
    let _ = std::io::stdout().flush();
    // Serve connections until a master sends an explicit Shutdown frame.
    // A dropped connection (master crash, supervisor-initiated redial
    // after this worker was charged a failure) returns to accept() so the
    // worker can be re-admitted without restarting the process.
    loop {
        let (stream, peer) = listener.accept().map_err(|e| format!("accept: {e}"))?;
        eprintln!("master connected from {peer}");
        match crate::cluster::transport::tcp::serve(stream) {
            Ok(true) => return Ok(()),
            Ok(false) => eprintln!("master disconnected; awaiting reconnect"),
            Err(e) => eprintln!("connection error: {e}; awaiting reconnect"),
        }
    }
}

fn parse_backend(args: &Args) -> Result<BackendKind, String> {
    match args.get("backend") {
        None => Ok(BackendKind::Native),
        Some(s) => s.parse(),
    }
}

fn maybe_write_json(args: &Args, json: &Json) -> Result<(), String> {
    if let Some(path) = args.get("json") {
        std::fs::write(path, json.to_string()).map_err(|e| format!("write {path}: {e}"))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// `--report-json <path>`: the machine-readable twin of the printed
/// summary, uniform across `train` (TrainReport) and `serve`
/// (ServeReport). Distinct from `--json`, whose payload varies per
/// subcommand (reproduce emits experiment outputs, lint a findings map).
fn maybe_write_report_json(args: &Args, json: &Json) -> Result<(), String> {
    if let Some(path) = args.get("report-json") {
        std::fs::write(path, json.to_string()).map_err(|e| format!("write {path}: {e}"))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// `codedml serve --sessions <spec.json>`: build the scheduler from the
/// spec, drive every session to completion over the shared pool, print
/// one line per session plus pool totals. Per-session failures are
/// reported but only fail the command if *no* session completed.
fn cmd_serve(args: &Args) -> Result<(), String> {
    let path = args
        .get("sessions")
        .ok_or("serve needs --sessions <spec.json> (see `codedml` usage)")?;
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    let spec = crate::serve::ServeSpec::from_json(&text)?;
    let njobs = spec.jobs.len();
    let mut sched = crate::serve::Scheduler::new(spec).map_err(|e| e.to_string())?;
    println!(
        "serve: {njobs} session(s) over a shared {}-worker pool",
        sched.pool_workers()
    );
    let report = sched.run().map_err(|e| e.to_string())?;
    for s in &report.sessions {
        match &s.error {
            Some(e) => println!(
                "session '{}' (id {}, {}): FAILED after {} round(s): {e}",
                s.name,
                s.session_id,
                s.objective,
                s.report.iterations.len()
            ),
            None => println!(
                "session '{}' (id {}, {}, priority {}): {} round(s), final loss {:.5}",
                s.name,
                s.session_id,
                s.objective,
                s.priority,
                s.report.iterations.len(),
                s.report.iterations.last().map(|it| it.train_loss).unwrap_or(f64::NAN)
            ),
        }
    }
    println!(
        "pool: transport {}, {} worker(s); wire {} B sent / {} B received; \
         {} respawn(s); {} misrouted result(s)",
        report.transport,
        report.pool_workers,
        report.wire_sent,
        report.wire_received,
        report.respawns,
        report.misrouted
    );
    maybe_write_report_json(args, &report.to_json())?;
    maybe_write_json(args, &report.to_json())?;
    if report.misrouted > 0 {
        return Err(format!(
            "{} result(s) crossed a session boundary — routing bug",
            report.misrouted
        ));
    }
    if report.sessions.iter().all(|s| s.error.is_some()) {
        return Err("every session failed".to_string());
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<(), String> {
    let n = args.get_usize("n", 10)?;
    let r = args.get_usize("r", 1)?;
    let mut cfg = match args.get("case") {
        Some("1") => CodedMlConfig::case1(n, r).map_err(|e| e.to_string())?,
        Some("2") => CodedMlConfig::case2(n, r).map_err(|e| e.to_string())?,
        Some(other) => return Err(format!("--case must be 1 or 2, got {other}")),
        None => CodedMlConfig {
            n,
            k: args.get_usize("k", 3)?,
            t: args.get_usize("t", 1)?,
            r,
            ..Default::default()
        },
    };
    if let Some(model) = args.get("model") {
        cfg.model = model.parse()?;
    }
    if cfg.model == ModelKind::Linear {
        // Shift to the linear-tuned scale defaults (CodedMlConfig::linear);
        // explicit --p/--lx/--lw/--lc below still win. Note this applies to
        // the --model flag only — a --config file selecting "model":
        // "linear" is taken as a complete specification of its scales.
        let (n, k, t, r) = (cfg.n, cfg.k, cfg.t, cfg.r);
        cfg = CodedMlConfig { n, k, t, r, ..CodedMlConfig::linear() };
    }
    cfg.iters = args.get_usize("iters", 25)?;
    cfg.seed = args.get_u64("seed", 42)?;
    cfg.backend = parse_backend(args)?;
    if let Some(p) = args.get("p") {
        cfg.p = p.parse().map_err(|_| "--p: bad integer")?;
    }
    cfg.lx = args.get_usize("lx", cfg.lx as usize)? as u32;
    cfg.lw = args.get_usize("lw", cfg.lw as usize)? as u32;
    cfg.lc = args.get_usize("lc", cfg.lc as usize)? as u32;
    if let Some(eta) = args.get("eta") {
        cfg.eta = Some(eta.parse().map_err(|_| "--eta: bad number")?);
    }
    if args.flag("no-straggle") {
        cfg.straggler = StragglerModel::none();
    }
    if args.flag("free-net") {
        cfg.net = NetworkModel::free();
    }
    cfg.chaos_failures = args.get_usize("chaos-failures", 0)?;
    cfg.chaos_from_iter = args.get_u64("chaos-from-iter", 0)?;
    cfg.chaos_slow_workers = args.get_usize("chaos-slow-workers", 0)?;
    cfg.chaos_slow_ms = args.get_u64("chaos-slow-ms", 0)?;
    cfg.batch_blocks = args.get_usize("batch-blocks", 0)?;
    cfg.strict_budget = args.flag("strict-budget");
    if let Some(t) = args.get("threads") {
        cfg.parallelism = t.parse().map_err(|e: String| e)?;
    }
    if let Some(b) = args.get("coding-backend") {
        cfg.coding_backend = b.parse().map_err(|e: String| e)?;
    }
    cfg.decode_cache_cap = args.get_usize("decode-cache-cap", cfg.decode_cache_cap)?;
    if let Some(t) = args.get("transport") {
        cfg.transport.kind = t.parse().map_err(|e: String| e)?;
    }
    if let Some(ws) = args.get("workers") {
        cfg.transport.tcp.workers = ws
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        if args.get("transport").is_none() {
            cfg.transport.kind = TransportKind::Tcp;
        }
    }
    cfg.transport.tcp.connect_timeout_ms =
        args.get_u64("connect-timeout-ms", cfg.transport.tcp.connect_timeout_ms)?;
    cfg.transport.tcp.connect_retries =
        args.get_u64("connect-retries", cfg.transport.tcp.connect_retries as u64)? as u32;
    cfg.transport.tcp.connect_backoff_ms =
        args.get_u64("connect-backoff-ms", cfg.transport.tcp.connect_backoff_ms)?;
    cfg.round_deadline_ms = args.get_u64("round-deadline-ms", cfg.round_deadline_ms)?;
    if args.flag("approx-decode") {
        cfg.approx_decode = true;
    }
    cfg.approx_r_min = args.get_usize("approx-r-min", cfg.approx_r_min)?;
    cfg.max_respawns = args.get_u64("max-respawns", cfg.max_respawns as u64)? as u32;
    if args.flag("adaptive-deadline") {
        cfg.adaptive_deadline = true;
    }
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        cfg.apply_json(&text)?;
    }
    if let Some(dir) = args.get("artifact-dir") {
        cfg.artifact_dir = PathBuf::from(dir);
    }

    match cfg.model {
        ModelKind::Logistic => train_logistic(args, cfg),
        ModelKind::Linear => train_linear(args, cfg),
    }
}

fn train_banner(cfg: &CodedMlConfig, m: usize, d: usize) {
    println!(
        "CodedPrivateML ({}): N={} K={} T={} r={} p={} backend={:?} m={} d={} iters={} threads={}",
        cfg.model, cfg.n, cfg.k, cfg.t, cfg.r, cfg.p, cfg.backend, m, d, cfg.iters, cfg.parallelism
    );
}

fn print_report(report: &crate::coordinator::TrainReport) {
    println!("{}", reproduce::TABLE_HEADER);
    println!("{}", report.breakdown.row("CodedPrivateML"));
    println!(
        "coding backend {}; decode cache: {} hits / {} misses / {} evicted; \
         bytes sent {}, received {}; worker failures {}, late results drained {}",
        report.coding_backend,
        report.decode_cache.0,
        report.decode_cache.1,
        report.decode_cache_evictions,
        report.bytes_sent,
        report.bytes_received,
        report.worker_failures,
        report.late_results
    );
    if report.respawns > 0 || report.deadline_expired_rounds > 0 || report.approx_rounds > 0 {
        println!(
            "fault tolerance: {} respawn(s); {} deadline-expired round(s); \
             {} round(s) decoded approximately (max residual {:.3e})",
            report.respawns,
            report.deadline_expired_rounds,
            report.approx_rounds,
            report.max_approx_residual
        );
    }
}

fn save_model(
    args: &Args,
    name: &str,
    report: &crate::coordinator::TrainReport,
    source: &str,
    iters: usize,
) -> Result<(), String> {
    if let Some(path) = args.get("save-model") {
        crate::model::SavedModel::new(name, report.weights.clone())
            .with_meta("iters", iters)
            .with_meta("source", source)
            .with_meta("final_accuracy", format!("{:?}", report.final_accuracy()))
            .save(std::path::Path::new(path))
            .map_err(|e| e.to_string())?;
        eprintln!("saved model to {path}");
    }
    Ok(())
}

fn train_logistic(args: &Args, cfg: CodedMlConfig) -> Result<(), String> {
    let m = args.get_usize("m", 600)?;
    let d = args.get_usize("d", 784)?;
    let test_m = args.get_usize("test-m", (m / 6).max(30))?;
    let (mut train, mut test) = paper_dataset(m, test_m, cfg.seed);
    if d == 2 * train.d || args.flag("dup") {
        train = train.duplicate_features();
        test = test.duplicate_features();
    } else if d != train.d {
        return Err(format!("--d must be {} or {} (use --dup)", train.d, 2 * train.d));
    }

    let iters = cfg.iters;
    train_banner(&cfg, train.m, train.d);
    let mut sess = CodedMlSession::new(cfg, &train).map_err(|e| e.to_string())?;
    if let Some(w) = sess.budget_warning() {
        eprintln!("warning: {w}");
    }
    println!(
        "recovery threshold {} (straggler slack {})",
        sess.params().recovery_threshold(),
        sess.params().straggler_slack()
    );
    if let Some(path) = args.get("trace") {
        sess.set_tracer(
            crate::coordinator::Tracer::file(std::path::Path::new(path))
                .map_err(|e| format!("trace {path}: {e}"))?,
        );
        eprintln!("tracing to {path}");
    }
    let report = sess.train(iters, Some(&test)).map_err(|e| e.to_string())?;
    save_model(args, "logistic", &report, &train.source, iters)?;
    for it in &report.iterations {
        println!(
            "iter {:>3}  loss {:.5}  acc {:.4}",
            it.iter,
            it.train_loss,
            it.test_accuracy.unwrap_or(f64::NAN)
        );
    }
    print_report(&report);
    maybe_write_report_json(args, &report.to_json())?;
    maybe_write_json(args, &report.to_json())
}

fn train_linear(args: &Args, cfg: CodedMlConfig) -> Result<(), String> {
    let m = args.get_usize("m", 240)?;
    let d = args.get_usize("d", 8)?;
    let (train, w_star) = synthetic_planted_linear(m, d, cfg.seed);

    let iters = cfg.iters;
    train_banner(&cfg, train.m, train.d);
    let mut sess = CodedMlSession::new_linear(cfg, &train).map_err(|e| e.to_string())?;
    if let Some(w) = sess.budget_warning() {
        eprintln!("warning: {w}");
    }
    println!(
        "recovery threshold {} (straggler slack {})",
        sess.params().recovery_threshold(),
        sess.params().straggler_slack()
    );
    if let Some(path) = args.get("trace") {
        sess.set_tracer(
            crate::coordinator::Tracer::file(std::path::Path::new(path))
                .map_err(|e| format!("trace {path}: {e}"))?,
        );
        eprintln!("tracing to {path}");
    }
    let report = sess.train(iters, None).map_err(|e| e.to_string())?;
    save_model(args, "linear", &report, &train.source, iters)?;
    for it in &report.iterations {
        println!("iter {:>3}  mse {:.6}", it.iter, it.train_loss);
    }
    let err = crate::model::LinearRegression::with_weights(report.weights.clone())
        .distance_to(&w_star);
    println!("planted-model recovery error ‖w − w*‖ = {err:.4}");
    print_report(&report);
    maybe_write_report_json(args, &report.to_json())?;
    maybe_write_json(args, &report.to_json())
}

fn cmd_mpc(args: &Args) -> Result<(), String> {
    let n = args.get_usize("n", 10)?;
    let cfg = BgwConfig {
        n,
        t: args.get_usize("t", ((n - 1) / 2).max(1))?,
        r: args.get_usize("r", 1)?,
        seed: args.get_u64("seed", 42)?,
        net: if args.flag("free-net") { NetworkModel::free() } else { NetworkModel::default() },
        straggler: if args.flag("no-straggle") {
            StragglerModel::none()
        } else {
            StragglerModel::default()
        },
        parallelism: match args.get("threads") {
            Some(t) => t.parse().map_err(|e: String| e)?,
            None => Default::default(),
        },
        ..Default::default()
    };
    let m = args.get_usize("m", 600)?;
    let iters = args.get_usize("iters", 25)?;
    let (train, test) = paper_dataset(m, (m / 6).max(30), cfg.seed);
    println!("BGW MPC baseline: N={} T={} m={} d={} iters={}", cfg.n, cfg.t, train.m, train.d, iters);
    let mut proto = BgwGradientProtocol::new(cfg, &train).map_err(|e| e.to_string())?;
    let report = proto.train(iters, Some(&test));
    for it in &report.iterations {
        println!(
            "iter {:>3}  loss {:.5}  acc {:.4}",
            it.iter,
            it.train_loss,
            it.test_accuracy.unwrap_or(f64::NAN)
        );
    }
    println!("{}", reproduce::TABLE_HEADER);
    println!("{}", report.breakdown.row("MPC approach"));
    println!(
        "resharing rounds {}, worker↔worker bytes {}",
        proto.protocol_report().resharing_rounds,
        proto.protocol_report().bytes_worker_to_worker
    );
    maybe_write_json(args, &report.to_json())
}

fn cmd_reproduce(args: &Args) -> Result<(), String> {
    let target = args
        .positional
        .get(1)
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let params = ExpParams {
        scale: args.get_f64("scale", 0.05)?,
        iters: args.get_usize("iters", 25)?,
        seed: args.get_u64("seed", 42)?,
        backend: parse_backend(args)?,
        straggler: if args.flag("no-straggle") {
            StragglerModel::none()
        } else {
            StragglerModel::default()
        },
        net: NetworkModel::default(),
        ..Default::default()
    };
    let ids: Vec<&str> = if target == "all" {
        reproduce::list()
    } else {
        vec![Box::leak(target.into_boxed_str())]
    };
    let mut outputs = Vec::new();
    for id in ids {
        eprintln!("running {id} (scale {}, {} iters)...", params.scale, params.iters);
        let out = run_experiment(id, &params)?;
        println!("{}", out.text);
        outputs.push(out.json);
    }
    maybe_write_json(args, &Json::Arr(outputs))
}

fn cmd_budget(args: &Args) -> Result<(), String> {
    let budget = OverflowBudget {
        p: args.get_u64("p", crate::field::PAPER_PRIME)?,
        max_abs_x: args.get_f64("max-x", 1.0)?,
        rows_per_block: args.get_usize("m", 12396)? / args.get_usize("k", 13)?.max(1),
        lx: args.get_usize("lx", 2)? as u32,
        lw: args.get_usize("lw", 4)? as u32,
        lc: args.get_usize("lc", 3)? as u32,
        r: args.get_usize("r", 1)? as u32,
        max_abs_g: args.get_f64("max-g", 2.0)?,
    };
    let rep = budget.analyze();
    println!("overflow budget analysis");
    println!("  worst-case decoded magnitude : {:.4e}", rep.worst_case);
    println!("  field limit (p-1)/2          : {:.4e}", rep.limit);
    println!("  utilization                  : {:.3}", rep.utilization);
    println!("  verdict                      : {}", if rep.ok() { "OK" } else { "OVERFLOW RISK" });
    println!(
        "  max rows/block at 90% headroom: {}",
        budget.max_block_rows(0.9)
    );
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<(), String> {
    let dir = PathBuf::from(args.get("dir").unwrap_or("artifacts"));
    let rt = XlaRuntime::new(&dir).map_err(|e| e.to_string())?;
    println!("{} artifact(s) in {}", rt.manifest().entries.len(), dir.display());
    for e in &rt.manifest().entries {
        println!(
            "  {:<28} kind={:?} rows={} d={} r={} p={}",
            e.name, e.kind, e.rows, e.d, e.r, e.p
        );
    }
    // Smoke-execute the smallest worker artifact to prove the PJRT path.
    // Non-fatal: a PJRT-less build (no `pjrt` feature) can still list
    // manifests; it just cannot execute them.
    if let Some(e) = rt.manifest().find_worker(32, 64, 1, 15485863) {
        let f = crate::field::PrimeField::new(e.p);
        let mut rng = crate::util::Rng::new(1);
        let x = f.random_matrix(&mut rng, e.rows, e.d);
        let w = f.random_matrix(&mut rng, e.d, e.r);
        let c: Vec<u64> = (0..=e.r).map(|_| f.random(&mut rng)).collect();
        match rt.worker_f(&x, &w, &c, e.rows, e.d, e.p) {
            Ok(out) => println!(
                "smoke-executed {}: output[0..4] = {:?}",
                e.name,
                &out[..4.min(out.len())]
            ),
            Err(err) => eprintln!("warning: smoke execution skipped: {err}"),
        }
    }
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<(), String> {
    use crate::analysis::{self, SourceTree};
    // Resolve the source root: explicit --root, else rust/src relative to
    // the current directory, else relative to the build-time manifest dir
    // (covers `cargo run` from a subdirectory).
    let root = match args.get("root") {
        Some(r) => PathBuf::from(r),
        None => {
            let cwd_rel = PathBuf::from("rust").join("src");
            if cwd_rel.is_dir() {
                cwd_rel
            } else {
                PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust").join("src")
            }
        }
    };
    let tree = SourceTree::scan(&root).map_err(|e| format!("scan {}: {e}", root.display()))?;
    let findings = analysis::lint(&tree);
    for f in &findings {
        println!("{f}");
    }
    // `--json` alone writes LINT_REPORT.json; `--json <path>` picks the path.
    let json_path = args
        .get("json")
        .map(str::to_string)
        .or_else(|| args.flag("json").then(|| "LINT_REPORT.json".to_string()));
    if let Some(path) = json_path {
        let ids: Vec<&str> = analysis::RULES.iter().map(|r| r.id).collect();
        let doc = analysis::report_json(&ids, &findings);
        std::fs::write(&path, doc.to_string()).map_err(|e| format!("write {path}: {e}"))?;
        eprintln!("wrote {path}");
    }
    if findings.is_empty() {
        println!(
            "lint: {} file(s) clean across {} rule(s)",
            tree.files.len(),
            analysis::RULES.len()
        );
        Ok(())
    } else {
        Err(format!("{} lint finding(s)", findings.len()))
    }
}

// Keep synthetic_3v7 linked for the doc-examples that reference it.
#[allow(unused)]
fn _doc_anchor() {
    let _ = synthetic_3v7;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
    }

    #[test]
    fn no_subcommand_prints_usage_ok() {
        assert!(dispatch(&args("")).is_ok());
    }

    #[test]
    fn list_ok() {
        assert!(dispatch(&args("list")).is_ok());
    }

    #[test]
    fn budget_ok() {
        assert!(dispatch(&args("budget --m 1200 --k 3")).is_ok());
    }

    #[test]
    fn train_micro_run() {
        assert!(dispatch(&args(
            "train --n 10 --k 3 --t 1 --iters 2 --m 120 --no-straggle --free-net"
        ))
        .is_ok());
    }

    #[test]
    fn mpc_micro_run() {
        assert!(dispatch(&args("mpc --n 5 --t 1 --iters 1 --m 60 --no-straggle --free-net")).is_ok());
    }

    #[test]
    fn reproduce_rejects_unknown() {
        let err = dispatch(&args("reproduce fig99 --scale 0.008 --iters 1")).unwrap_err();
        assert!(err.contains("unknown experiment"));
    }

    #[test]
    fn train_rejects_bad_case() {
        let err = dispatch(&args("train --case 5")).unwrap_err();
        assert!(err.contains("case"));
    }

    #[test]
    fn train_micro_run_linear() {
        assert!(dispatch(&args(
            "train --model linear --n 10 --k 3 --t 1 --iters 2 --m 60 --d 6 \
             --no-straggle --free-net"
        ))
        .is_ok());
    }

    #[test]
    fn train_rejects_bad_model() {
        let err = dispatch(&args("train --model svm")).unwrap_err();
        assert!(err.contains("unknown model"), "{err}");
    }

    #[test]
    fn train_micro_run_mini_batch() {
        assert!(dispatch(&args(
            "train --n 10 --k 3 --t 1 --iters 2 --m 120 --batch-blocks 1 \
             --no-straggle --free-net"
        ))
        .is_ok());
    }

    #[test]
    fn train_micro_run_degraded_mode() {
        // R = 10 with zero slack: two chaos deaths at iteration 1 push
        // the second round below threshold; --approx-decode keeps it
        // alive instead of erroring out.
        assert!(dispatch(&args(
            "train --n 10 --k 3 --t 1 --iters 2 --m 120 --chaos-failures 2 \
             --chaos-from-iter 1 --approx-decode --no-straggle --free-net"
        ))
        .is_ok());
    }

    #[test]
    fn train_micro_run_supervised_respawn() {
        assert!(dispatch(&args(
            "train --n 10 --k 3 --t 1 --iters 2 --m 120 --chaos-failures 1 \
             --chaos-from-iter 1 --max-respawns 1 --no-straggle --free-net"
        ))
        .is_ok());
    }

    #[test]
    fn train_micro_run_parallel() {
        assert!(dispatch(&args(
            "train --n 10 --k 3 --t 1 --iters 1 --m 120 --threads 2 --no-straggle --free-net"
        ))
        .is_ok());
    }

    #[test]
    fn lint_clean_tree_ok() {
        assert!(dispatch(&args("lint")).is_ok());
    }

    #[test]
    fn lint_writes_json_report() {
        let path = std::env::temp_dir().join("codedml_lint_report_test.json");
        let cmd = format!("lint --json {}", path.display());
        assert!(dispatch(&args(&cmd)).is_ok());
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("total").unwrap().as_u64(), Some(0));
        assert!(doc.get("by_rule").unwrap().get("no-hardware-modulo").is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn lint_rejects_missing_root() {
        let err = dispatch(&args("lint --root does/not/exist")).unwrap_err();
        assert!(err.contains("scan"), "{err}");
    }

    #[test]
    fn train_micro_run_forced_ntt() {
        // 23068673 = 11·2^21 + 1 hosts the (K+T=4, N=10) coset easily.
        assert!(dispatch(&args(
            "train --n 10 --k 3 --t 1 --iters 2 --m 120 --p 23068673 \
             --coding-backend ntt --no-straggle --free-net"
        ))
        .is_ok());
    }

    #[test]
    fn train_rejects_ntt_on_low_adicity_prime() {
        // The paper's 24-bit prime has 2-adicity 1 — no coset to be had.
        let err = dispatch(&args(
            "train --n 10 --k 3 --t 1 --iters 1 --m 120 \
             --coding-backend ntt --no-straggle --free-net"
        ))
        .unwrap_err();
        assert!(err.contains("2-adicity"), "{err}");
    }

    #[test]
    fn train_rejects_bad_coding_backend() {
        let err = dispatch(&args("train --coding-backend fft")).unwrap_err();
        assert!(err.contains("bad coding backend"), "{err}");
    }

    #[test]
    fn train_rejects_bad_threads() {
        let err = dispatch(&args("train --threads lots")).unwrap_err();
        assert!(err.contains("thread count"), "{err}");
    }

    #[test]
    fn train_rejects_bad_transport() {
        let err = dispatch(&args("train --transport pigeon")).unwrap_err();
        assert!(err.contains("bad transport"), "{err}");
    }

    #[test]
    fn train_rejects_tcp_address_count_mismatch() {
        // Validation fails before any connection is attempted, so the
        // bogus address is never dialed.
        let err = dispatch(&args(
            "train --n 4 --k 1 --t 1 --iters 1 --m 40 --transport tcp \
             --workers 127.0.0.1:1 --no-straggle --free-net"
        ))
        .unwrap_err();
        assert!(err.contains("worker addresses"), "{err}");
    }

    #[test]
    fn workers_flag_implies_tcp_transport() {
        // Same mismatch error without an explicit --transport: proof the
        // comma list flipped the transport kind to tcp.
        let err = dispatch(&args(
            "train --n 4 --k 1 --t 1 --iters 1 --m 40 \
             --workers 127.0.0.1:1,127.0.0.1:2 --no-straggle --free-net"
        ))
        .unwrap_err();
        assert!(err.contains("worker addresses"), "{err}");
    }

    #[test]
    fn worker_mode_rejects_bad_listen_addr() {
        let err = dispatch(&args("--worker --listen not-an-address")).unwrap_err();
        assert!(err.contains("bind"), "{err}");
    }

    #[test]
    fn serve_requires_sessions_flag() {
        let err = dispatch(&args("serve")).unwrap_err();
        assert!(err.contains("--sessions"), "{err}");
    }

    #[test]
    fn serve_rejects_missing_spec_file() {
        let err = dispatch(&args("serve --sessions does/not/exist.json")).unwrap_err();
        assert!(err.contains("read"), "{err}");
    }

    #[test]
    fn serve_micro_run_writes_report_json() {
        let spec_path = std::env::temp_dir().join("codedml_cli_serve_spec.json");
        let report_path = std::env::temp_dir().join("codedml_cli_serve_report.json");
        std::fs::write(
            &spec_path,
            r#"{ "sessions": [
                { "name": "log", "m": 60, "data_seed": 3,
                  "config": { "n": 8, "k": 2, "t": 1, "iters": 2 } },
                { "name": "lin", "m": 60, "d": 4, "data_seed": 5,
                  "config": { "model": "linear", "n": 6, "k": 1, "t": 1,
                              "iters": 2, "priority": 2 } }
            ] }"#,
        )
        .unwrap();
        let cmd = format!(
            "serve --sessions {} --report-json {}",
            spec_path.display(),
            report_path.display()
        );
        assert!(dispatch(&args(&cmd)).is_ok());
        let doc = Json::parse(&std::fs::read_to_string(&report_path).unwrap()).unwrap();
        assert_eq!(doc.get("misrouted").unwrap().as_u64(), Some(0));
        let sessions = doc.get("sessions").unwrap().as_arr().unwrap();
        assert_eq!(sessions.len(), 2);
        for s in sessions {
            assert_eq!(s.get("error"), Some(&Json::Null));
            let curve = s.get("report").unwrap().get("loss_curve").unwrap();
            assert_eq!(curve.as_arr().unwrap().len(), 2);
        }
        let _ = std::fs::remove_file(&spec_path);
        let _ = std::fs::remove_file(&report_path);
    }

    #[test]
    fn train_report_json_writes_train_report() {
        let path = std::env::temp_dir().join("codedml_cli_train_report.json");
        let cmd = format!(
            "train --n 10 --k 3 --t 1 --iters 1 --m 120 --no-straggle --free-net \
             --report-json {}",
            path.display()
        );
        assert!(dispatch(&args(&cmd)).is_ok());
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("loss_curve").unwrap().as_arr().unwrap().len(), 1);
        let _ = std::fs::remove_file(&path);
    }
}
