//! The worker-side computation (paper eq. 17 & 20), native backend.
//!
//! Each worker evaluates, entirely in F_p,
//!
//! ```text
//!   f(X̃, W̃) = X̃ᵀ · ḡ(X̃, W̃),    ḡ = Σ_{i=0}^{r} c̄_i ⊙ Π_{j≤i} (X̃ · w̃_j)
//! ```
//!
//! a degree-(2r+1) polynomial in its inputs. The same structure is used on
//! true data, Shamir shares, and Lagrange-coded data — that indifference is
//! what makes LCC decoding work.
//!
//! This module is the **native** implementation: portable rust, bit-exact
//! with the Pallas/XLA artifact (the python test-suite checks the kernel
//! against `ref.py`, and `rust/tests/backend_equiv.rs` checks the artifact
//! against this module). It is also the fallback for shapes missing from
//! the AOT manifest.

mod matmul;

pub use matmul::{
    matvec_mod, matvec_mod_par, safe_chunk_len, tr_matvec_mod, tr_matvec_mod_par,
};

use crate::field::PrimeField;
use crate::util::par::Parallelism;

/// Parameters of the worker computation.
#[derive(Debug, Clone)]
pub struct WorkerComputation {
    pub field: PrimeField,
    /// Rows of the (coded) data block this worker holds.
    pub rows: usize,
    /// Feature dimension d.
    pub d: usize,
    /// Sigmoid polynomial degree r (number of weight quantizations).
    pub r: usize,
    /// Field-quantized polynomial coefficients c̄_0..c̄_r.
    pub coeffs: Vec<u64>,
    /// Intra-worker thread count for the matmul row blocks (bit-exact at
    /// any setting; see [`crate::util::par`]).
    pub par: Parallelism,
}

impl WorkerComputation {
    pub fn new(field: PrimeField, rows: usize, d: usize, coeffs: Vec<u64>) -> Self {
        assert!(coeffs.len() >= 2, "need at least a degree-1 polynomial");
        let r = coeffs.len() - 1;
        WorkerComputation { field, rows, d, r, coeffs, par: Parallelism::Serial }
    }

    /// Split the matmul row blocks across `par` threads.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.par = par;
        self
    }

    /// Evaluate ḡ(X̃, W̃) — one field element per row.
    ///
    /// `x` is row-major rows×d; `w` is row-major d×r (column j = j-th
    /// weight quantization).
    pub fn g_bar(&self, x: &[u64], w: &[u64]) -> Vec<u64> {
        let f = &self.field;
        assert_eq!(x.len(), self.rows * self.d);
        assert_eq!(w.len(), self.d * self.r);
        // u_j = X̃ · w̃_j for each j — computed as one pass per column,
        // rows split across the worker's thread budget.
        let mut dots: Vec<Vec<u64>> = Vec::with_capacity(self.r);
        for j in 0..self.r {
            dots.push(matvec_mod_par(f, x, w, self.rows, self.d, self.r, j, self.par));
        }
        // ḡ = c̄_0 + Σ_i c̄_i · Π_{j<i} dots[j]  (elementwise over rows)
        let mut g = vec![self.coeffs[0]; self.rows];
        let mut prod = vec![1u64; self.rows];
        for i in 1..=self.r {
            let d_i = &dots[i - 1];
            let ci = self.coeffs[i];
            for row in 0..self.rows {
                prod[row] = f.mul(prod[row], d_i[row]);
                g[row] = f.add(g[row], f.mul(ci, prod[row]));
            }
        }
        g
    }

    /// The full worker function f(X̃, W̃) = X̃ᵀ ḡ(X̃, W̃) ∈ F_p^d.
    pub fn compute(&self, x: &[u64], w: &[u64]) -> Vec<u64> {
        let g = self.g_bar(x, w);
        tr_matvec_mod_par(&self.field, x, &g, self.rows, self.d, self.par)
    }

    /// Total degree of f in its inputs — determines the recovery threshold.
    pub fn degree(&self) -> usize {
        2 * self.r + 1
    }

    /// Field multiplications per evaluation (cost model for the scheduler).
    pub fn flop_estimate(&self) -> u64 {
        // r row-dots + transpose-dot + elementwise polynomial.
        (self.r as u64 + 1) * (self.rows as u64) * (self.d as u64)
            + 2 * (self.r as u64) * (self.rows as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{PrimeField, PAPER_PRIME};
    use crate::quant::{phi, phi_inv};
    use crate::util::proptest::check;

    fn field() -> PrimeField {
        PrimeField::new(PAPER_PRIME)
    }

    /// Slow reference: compute f over signed integers (no modular
    /// arithmetic) then embed. Valid while magnitudes stay small.
    fn reference_f(
        f: &PrimeField,
        x: &[i64],
        w: &[i64],
        coeffs: &[i64],
        rows: usize,
        d: usize,
        r: usize,
    ) -> Vec<u64> {
        let mut g = vec![0i128; rows];
        for row in 0..rows {
            let mut dots = vec![0i128; r];
            for j in 0..r {
                for k in 0..d {
                    dots[j] += x[row * d + k] as i128 * w[k * r + j] as i128;
                }
            }
            let mut acc = coeffs[0] as i128;
            let mut prod = 1i128;
            for i in 1..=r {
                prod *= dots[i - 1];
                acc += coeffs[i] as i128 * prod;
            }
            g[row] = acc;
        }
        let mut out = vec![0i128; d];
        for row in 0..rows {
            for k in 0..d {
                out[k] += x[row * d + k] as i128 * g[row];
            }
        }
        out.iter()
            .map(|&v| {
                let m = v.rem_euclid(f.modulus() as i128);
                m as u64
            })
            .collect()
    }

    #[test]
    fn matches_integer_reference_small() {
        let f = field();
        check("worker-f-vs-int-ref", 50, move |rng| {
            let rows = 1 + rng.below_usize(6);
            let d = 1 + rng.below_usize(8);
            let r = 1 + rng.below_usize(2);
            let xi: Vec<i64> = (0..rows * d).map(|_| rng.below(9) as i64 - 4).collect();
            let wi: Vec<i64> = (0..d * r).map(|_| rng.below(9) as i64 - 4).collect();
            let ci: Vec<i64> = (0..=r).map(|_| rng.below(9) as i64 - 4).collect();
            let x: Vec<u64> = xi.iter().map(|&v| phi(&f, v)).collect();
            let w: Vec<u64> = wi.iter().map(|&v| phi(&f, v)).collect();
            let c: Vec<u64> = ci.iter().map(|&v| phi(&f, v)).collect();
            let wc = WorkerComputation::new(f, rows, d, c);
            let got = wc.compute(&x, &w);
            let want = reference_f(&f, &xi, &wi, &ci, rows, d, r);
            if got != want {
                return Err(format!(
                    "rows={rows} d={d} r={r}: {got:?} vs {want:?}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn g_bar_constant_when_higher_coeffs_zero() {
        let f = field();
        let rows = 4;
        let d = 3;
        let c = vec![phi(&f, 7), 0];
        let wc = WorkerComputation::new(f, rows, d, c);
        let x = vec![1u64; rows * d];
        let w = vec![2u64; d];
        assert_eq!(wc.g_bar(&x, &w), vec![7u64; rows]);
    }

    #[test]
    fn degree_and_threshold_algebra() {
        let f = field();
        let wc = WorkerComputation::new(f, 8, 4, vec![1, 2]);
        assert_eq!(wc.degree(), 3); // r=1 → 2r+1 = 3
        let wc2 = WorkerComputation::new(f, 8, 4, vec![1, 2, 3]);
        assert_eq!(wc2.degree(), 5);
    }

    #[test]
    fn compute_linear_case_is_xt_c0_plus_c1_xw() {
        // r=1: f = X̄ᵀ(c0·1 + c1·(X̄w)) — verify against direct formula.
        let f = field();
        let rows = 3;
        let d = 2;
        let x_i = [1i64, 2, 3, -1, 0, 2];
        let w_i = [2i64, -3];
        let (c0, c1) = (5i64, 2i64);
        let x: Vec<u64> = x_i.iter().map(|&v| phi(&f, v)).collect();
        let w: Vec<u64> = w_i.iter().map(|&v| phi(&f, v)).collect();
        let wc = WorkerComputation::new(f, rows, d, vec![phi(&f, c0), phi(&f, c1)]);
        let out = wc.compute(&x, &w);
        // Manual: Xw = [1·2+2·-3, 3·2+(-1)(-3), 0·2+2·-3] = [-4, 9, -6]
        // g = 5 + 2·Xw = [-3, 23, -7]
        // Xᵀg = [1·-3+3·23+0·-7, 2·-3+(-1)·23+2·-7] = [66, -43]
        assert_eq!(phi_inv(&f, out[0]), 66);
        assert_eq!(phi_inv(&f, out[1]), -43);
    }

    #[test]
    #[should_panic(expected = "degree-1")]
    fn rejects_degree_zero() {
        WorkerComputation::new(field(), 1, 1, vec![1]);
    }

    #[test]
    fn flop_estimate_monotone_in_shape() {
        let f = field();
        let small = WorkerComputation::new(f, 10, 10, vec![1, 2]).flop_estimate();
        let big = WorkerComputation::new(f, 20, 10, vec![1, 2]).flop_estimate();
        assert!(big > small);
    }
}
