//! Modular matrix–vector kernels.
//!
//! Accumulation strategy: products of two reduced elements are < p² and
//! p ≤ 2^31, so partial sums stay in u64 for `safe_chunk_len(p)` terms;
//! we reduce mod p once per chunk instead of per multiply–add, and the
//! per-chunk fold itself is a Barrett reduction
//! ([`PrimeField::reduce_u64`]) rather than a hardware divide. For the
//! paper's 24-bit prime that is one mul-high every 2^16 terms — the inner
//! loop is pure integer MACs, which is what makes the native backend
//! competitive with the XLA artifact (see EXPERIMENTS.md §Perf).
//!
//! The `_par` variants split the row range over a scoped thread pool
//! ([`crate::util::par`]); outputs are per-row (or merged with exact field
//! adds), so results are bit-identical at every thread count.

use crate::field::{simd, PrimeField};
use crate::util::par::{par_ranges, Parallelism};

/// Number of p²-bounded terms that can accumulate in a u64 without
/// overflow: floor((2^64 − 1) / (p−1)²) bounded to ≥ 1.
pub fn safe_chunk_len(p: u64) -> usize {
    let p2 = (p - 1) as u128 * (p - 1) as u128;
    let max = u64::MAX as u128 / p2;
    max.max(1).min(usize::MAX as u128) as usize
}

/// Inner kernel of [`matvec_mod`] over a row range.
fn matvec_rows(
    f: &PrimeField,
    x: &[u64],
    w: &[u64],
    row_range: std::ops::Range<usize>,
    d: usize,
    stride: usize,
    col: usize,
) -> Vec<u64> {
    let chunk = safe_chunk_len(f.modulus());
    // Strided weight columns are gathered once per range so the chunk dot
    // runs over two contiguous slices (lane-kernel friendly).
    let gathered: Option<Vec<u64>> =
        (stride != 1).then(|| (0..d).map(|k| w[k * stride + col]).collect());
    let wcol: &[u64] = gathered.as_deref().unwrap_or(&w[..d]);
    let mut out = Vec::with_capacity(row_range.len());
    for row in row_range {
        let xrow = &x[row * d..(row + 1) * d];
        let mut acc: u64 = 0;
        let mut k = 0;
        while k < d {
            let end = (k + chunk).min(d);
            let partial = simd::dot_wrapping(&xrow[k..end], &wcol[k..end]);
            acc = f.add(acc, f.reduce_u64(partial));
            k = end;
        }
        out.push(acc);
    }
    out
}

/// `out[i] = Σ_k x[i,k] · w[k*stride + col] mod p` — multiply the row-major
/// `rows × d` matrix by column `col` of a row-major `d × stride` matrix.
pub fn matvec_mod(
    f: &PrimeField,
    x: &[u64],
    w: &[u64],
    rows: usize,
    d: usize,
    stride: usize,
    col: usize,
) -> Vec<u64> {
    matvec_mod_par(f, x, w, rows, d, stride, col, Parallelism::Serial)
}

/// [`matvec_mod`] with the row range split across `par` threads. Each
/// output row is computed independently, so the result is bit-identical
/// to the serial kernel.
#[allow(clippy::too_many_arguments)]
pub fn matvec_mod_par(
    f: &PrimeField,
    x: &[u64],
    w: &[u64],
    rows: usize,
    d: usize,
    stride: usize,
    col: usize,
    par: Parallelism,
) -> Vec<u64> {
    assert_eq!(x.len(), rows * d);
    assert!(w.len() >= d * stride);
    assert!(col < stride);
    par_ranges(par, rows, |_, range| matvec_rows(f, x, w, range, d, stride, col)).concat()
}

/// Inner kernel of [`tr_matvec_mod`] over a row range; returns a fully
/// reduced length-`d` partial.
fn tr_matvec_rows(
    f: &PrimeField,
    x: &[u64],
    g: &[u64],
    row_range: std::ops::Range<usize>,
    d: usize,
) -> Vec<u64> {
    let chunk = safe_chunk_len(f.modulus());
    let mut acc = vec![0u64; d];
    let mut out = vec![0u64; d];
    let mut pending = 0usize;
    for row in row_range {
        let gi = g[row];
        simd::mac_wrapping(&mut acc, &x[row * d..(row + 1) * d], gi);
        pending += 1;
        if pending == chunk {
            simd::fold_reduce(f, &mut out, &mut acc);
            pending = 0;
        }
    }
    if pending > 0 {
        simd::fold_reduce(f, &mut out, &mut acc);
    }
    out
}

/// `out[j] = Σ_i x[i,j] · g[i] mod p` — Xᵀ·g without materializing the
/// transpose: row-major streaming with per-column u64 accumulators and a
/// chunked Barrett reduction every `safe_chunk_len` rows.
pub fn tr_matvec_mod(f: &PrimeField, x: &[u64], g: &[u64], rows: usize, d: usize) -> Vec<u64> {
    tr_matvec_mod_par(f, x, g, rows, d, Parallelism::Serial)
}

/// [`tr_matvec_mod`] with the row range split across `par` threads; the
/// per-thread partials (already reduced) are merged with exact field adds,
/// so the result is bit-identical to the serial kernel.
pub fn tr_matvec_mod_par(
    f: &PrimeField,
    x: &[u64],
    g: &[u64],
    rows: usize,
    d: usize,
    par: Parallelism,
) -> Vec<u64> {
    assert_eq!(x.len(), rows * d);
    assert_eq!(g.len(), rows);
    let partials = par_ranges(par, rows, |_, range| tr_matvec_rows(f, x, g, range, d));
    partials
        .into_iter()
        .reduce(|mut merged, part| {
            for (m, v) in merged.iter_mut().zip(part) {
                *m = f.add(*m, v);
            }
            merged
        })
        .unwrap_or_else(|| vec![0u64; d])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{PrimeField, PAPER_PRIME, PRIME_26, PRIME_31, PRIME_NTT_25, PRIME_NTT_28};
    use crate::util::proptest::check;

    #[test]
    fn chunk_len_bounds() {
        // 24-bit prime: (p-1)^2 ≈ 2^48 → chunk ≈ 2^16.
        let c24 = safe_chunk_len(PAPER_PRIME);
        assert!(c24 >= 1 << 15 && c24 <= 1 << 17, "c24={c24}");
        // 31-bit: (p-1)^2 ≈ 2^62 → chunk among {4, 5, ...} small.
        let c31 = safe_chunk_len(PRIME_31);
        assert!(c31 >= 4 && c31 < 16, "c31={c31}");
        assert!(safe_chunk_len(3) >= 1);
    }

    fn naive_matvec(p: u64, x: &[u64], wcol: &[u64], rows: usize, d: usize) -> Vec<u64> {
        (0..rows)
            .map(|i| {
                let mut acc = 0u128;
                for k in 0..d {
                    acc += x[i * d + k] as u128 * wcol[k] as u128;
                }
                (acc % p as u128) as u64
            })
            .collect()
    }

    #[test]
    fn matvec_matches_naive_all_primes() {
        for &p in &[PAPER_PRIME, PRIME_NTT_25, PRIME_26, PRIME_NTT_28, PRIME_31, 97] {
            let f = PrimeField::new(p);
            check(&format!("matvec-{p}"), 30, move |rng| {
                let rows = 1 + rng.below_usize(8);
                let d = 1 + rng.below_usize(50);
                let x = f.random_matrix(rng, rows, d);
                let w = f.random_matrix(rng, d, 1);
                let got = matvec_mod(&f, &x, &w, rows, d, 1, 0);
                let want = naive_matvec(p, &x, &w, rows, d);
                if got != want {
                    return Err(format!("p={p} rows={rows} d={d}"));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn matvec_strided_column_selection() {
        let f = PrimeField::new(97);
        // 2×2 X, W has 3 columns; pick column 2.
        let x = vec![1, 2, 3, 4];
        let w = vec![
            10, 20, 30, // row 0 of W
            40, 50, 60, // row 1
        ];
        let got = matvec_mod(&f, &x, &w, 2, 2, 3, 2);
        // col2 = [30, 60]: [1·30+2·60, 3·30+4·60] = [150, 330] mod 97 = [53, 39]
        assert_eq!(got, vec![53, 39]);
    }

    #[test]
    fn tr_matvec_matches_naive() {
        for &p in &[PAPER_PRIME, PRIME_NTT_25, PRIME_NTT_28, PRIME_31] {
            let f = PrimeField::new(p);
            check(&format!("tr-matvec-{p}"), 30, move |rng| {
                let rows = 1 + rng.below_usize(40);
                let d = 1 + rng.below_usize(12);
                let x = f.random_matrix(rng, rows, d);
                let g = f.random_matrix(rng, rows, 1);
                let got = tr_matvec_mod(&f, &x, &g, rows, d);
                let mut want = vec![0u128; d];
                for i in 0..rows {
                    for j in 0..d {
                        want[j] += x[i * d + j] as u128 * g[i] as u128;
                    }
                }
                let want: Vec<u64> = want.iter().map(|&v| (v % p as u128) as u64).collect();
                if got != want {
                    return Err(format!("p={p} rows={rows} d={d}"));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn tr_matvec_exercises_chunk_boundary() {
        // Force multiple reduction chunks with the 31-bit prime (chunk ~4–8)
        // and rows larger than several chunks.
        let f = PrimeField::new(PRIME_31);
        let rows = 61; // not a multiple of the chunk length
        let d = 3;
        let x: Vec<u64> = (0..rows * d).map(|i| (f.modulus() - 1) - i as u64).collect();
        let g: Vec<u64> = (0..rows).map(|i| (f.modulus() - 1) - (7 * i) as u64).collect();
        let got = tr_matvec_mod(&f, &x, &g, rows, d);
        let mut want = vec![0u128; d];
        for i in 0..rows {
            for j in 0..d {
                want[j] += x[i * d + j] as u128 * g[i] as u128;
            }
        }
        let want: Vec<u64> = want.iter().map(|&v| (v % f.modulus() as u128) as u64).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn empty_dims_are_safe() {
        let f = PrimeField::new(97);
        assert_eq!(tr_matvec_mod(&f, &[], &[], 0, 0), Vec::<u64>::new());
        assert_eq!(matvec_mod(&f, &[], &[1], 0, 1, 1, 0), Vec::<u64>::new());
        let par = Parallelism::from_count(4);
        assert_eq!(tr_matvec_mod_par(&f, &[], &[], 0, 0, par), Vec::<u64>::new());
        assert_eq!(matvec_mod_par(&f, &[], &[1], 0, 1, 1, 0, par), Vec::<u64>::new());
    }

    #[test]
    fn parallel_kernels_are_bit_exact_with_serial() {
        for &p in &[PAPER_PRIME, PRIME_31] {
            let f = PrimeField::new(p);
            check(&format!("par-matmul-{p}"), 20, move |rng| {
                let rows = 1 + rng.below_usize(70);
                let d = 1 + rng.below_usize(20);
                let x = f.random_matrix(rng, rows, d);
                let w = f.random_matrix(rng, d, 1);
                let g = f.random_matrix(rng, rows, 1);
                let serial_mv = matvec_mod(&f, &x, &w, rows, d, 1, 0);
                let serial_tr = tr_matvec_mod(&f, &x, &g, rows, d);
                for threads in [2usize, 3, 8, 128] {
                    let par = Parallelism::from_count(threads);
                    if matvec_mod_par(&f, &x, &w, rows, d, 1, 0, par) != serial_mv {
                        return Err(format!("matvec p={p} rows={rows} threads={threads}"));
                    }
                    if tr_matvec_mod_par(&f, &x, &g, rows, d, par) != serial_tr {
                        return Err(format!("tr_matvec p={p} rows={rows} threads={threads}"));
                    }
                }
                Ok(())
            });
        }
    }
}
