//! Chebyshev-node polynomial fit of the sigmoid — an alternative to the
//! paper's least-squares fit (§3.3).
//!
//! Least squares minimizes *average* error over the fit interval;
//! interpolating at Chebyshev nodes approaches the minimax (worst-case)
//! fit. For the degree-1 sigmoid approximation the worst case sits at the
//! interval ends where LSQ error peaks (~0.16 over [-5,5]) — a
//! worst-case-minded deployment may prefer trading RMS for max error.
//! Exposed as `FitMethod::Chebyshev` in the session config; the ablation
//! harness compares both.

use super::sigmoid;

/// Interpolate the sigmoid at the r+1 Chebyshev nodes of [-range, range],
/// returning ascending monomial coefficients.
pub fn fit_sigmoid_chebyshev(r: u32, range: f64) -> Vec<f64> {
    let n = r as usize + 1;
    // Chebyshev nodes x_k = cos((2k+1)π / 2n) scaled to the interval.
    let nodes: Vec<f64> = (0..n)
        .map(|k| range * ((2 * k + 1) as f64 * std::f64::consts::PI / (2 * n) as f64).cos())
        .collect();
    let values: Vec<f64> = nodes.iter().map(|&x| sigmoid(x)).collect();
    // Newton divided differences → monomial coefficients (n ≤ 5, exact
    // enough in f64).
    let mut dd = values.clone();
    for level in 1..n {
        for i in (level..n).rev() {
            dd[i] = (dd[i] - dd[i - 1]) / (nodes[i] - nodes[i - level]);
        }
    }
    let mut coeffs = vec![0.0f64; n];
    for i in (0..n).rev() {
        // coeffs = coeffs·(x − nodes[i]) + dd[i]
        let mut next = vec![0.0f64; n];
        for k in (0..n - 1).rev() {
            next[k + 1] += coeffs[k];
        }
        for k in 0..n {
            next[k] -= coeffs[k] * nodes[i];
        }
        next[0] += dd[i];
        coeffs = next;
    }
    coeffs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sigmoid::{eval_real_poly, fit_sigmoid};

    fn max_err(coeffs: &[f64], range: f64) -> f64 {
        let mut worst = 0.0f64;
        for i in 0..=1000 {
            let z = -range + 2.0 * range * i as f64 / 1000.0;
            worst = worst.max((eval_real_poly(coeffs, z) - sigmoid(z)).abs());
        }
        worst
    }

    #[test]
    fn degree1_chebyshev_is_sane() {
        let c = fit_sigmoid_chebyshev(1, 5.0);
        assert_eq!(c.len(), 2);
        assert!((c[0] - 0.5).abs() < 0.02, "c0={}", c[0]);
        assert!(c[1] > 0.05 && c[1] < 0.25, "c1={}", c[1]);
    }

    #[test]
    fn interpolates_exactly_at_nodes() {
        let r = 3u32;
        let range = 4.0;
        let c = fit_sigmoid_chebyshev(r, range);
        let n = r as usize + 1;
        for k in 0..n {
            let x = range * ((2 * k + 1) as f64 * std::f64::consts::PI / (2 * n) as f64).cos();
            assert!(
                (eval_real_poly(&c, x) - sigmoid(x)).abs() < 1e-12,
                "node {k}"
            );
        }
    }

    #[test]
    fn both_fits_have_comparable_worst_case() {
        // Chebyshev interpolation bounds the minimax blow-up; for the
        // near-linear sigmoid the two fits land in the same error regime
        // (ratio < 2 either way) — the ablation harness reports both.
        for r in [1u32, 3] {
            let cheb = max_err(&fit_sigmoid_chebyshev(r, 5.0), 5.0);
            let lsq = max_err(&fit_sigmoid(r, 5.0, 401).coeffs, 5.0);
            assert!(cheb < 2.0 * lsq, "r={r}: cheb={cheb} lsq={lsq}");
            assert!(lsq < 2.0 * cheb, "r={r}: cheb={cheb} lsq={lsq}");
        }
    }

    #[test]
    fn error_decreases_with_degree() {
        let e1 = max_err(&fit_sigmoid_chebyshev(1, 4.0), 4.0);
        let e3 = max_err(&fit_sigmoid_chebyshev(3, 4.0), 4.0);
        assert!(e3 < e1, "{e3} vs {e1}");
    }
}
