//! Dense least squares for polynomial fitting.
//!
//! Degree ≤ 4 and a few hundred sample points — normal equations with
//! Gaussian elimination (partial pivoting) are more than accurate enough
//! and keep this dependency-free.

/// Solve `A x = b` for square `A` (row-major n×n) by Gaussian elimination
/// with partial pivoting. Returns None if singular to working precision.
pub fn solve_linear(a: &[f64], b: &[f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    let mut m = a.to_vec();
    let mut rhs = b.to_vec();
    for col in 0..n {
        // Pivot.
        let mut pivot = col;
        let mut best = m[col * n + col].abs();
        for row in col + 1..n {
            let v = m[row * n + col].abs();
            if v > best {
                best = v;
                pivot = row;
            }
        }
        if best < 1e-12 {
            return None;
        }
        if pivot != col {
            for k in 0..n {
                m.swap(col * n + k, pivot * n + k);
            }
            rhs.swap(col, pivot);
        }
        // Eliminate below.
        let diag = m[col * n + col];
        for row in col + 1..n {
            let factor = m[row * n + col] / diag;
            if factor == 0.0 {
                continue;
            }
            for k in col..n {
                m[row * n + k] -= factor * m[col * n + k];
            }
            rhs[row] -= factor * rhs[col];
        }
    }
    // Back substitution.
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut acc = rhs[row];
        for k in row + 1..n {
            acc -= m[row * n + k] * x[k];
        }
        x[row] = acc / m[row * n + row];
    }
    Some(x)
}

/// Least-squares fit of a degree-`deg` polynomial to samples `(xs, ys)`.
/// Returns ascending coefficients. Uses the normal equations
/// (VᵀV)c = Vᵀy on the Vandermonde matrix V.
pub fn polyfit(xs: &[f64], ys: &[f64], deg: usize) -> Option<Vec<f64>> {
    assert_eq!(xs.len(), ys.len());
    let n = deg + 1;
    if xs.len() < n {
        return None;
    }
    // Accumulate VᵀV (Hankel structure: entries depend on power sums).
    let mut power_sums = vec![0.0f64; 2 * deg + 1];
    for &x in xs {
        let mut p = 1.0;
        for s in power_sums.iter_mut() {
            *s += p;
            p *= x;
        }
    }
    let mut vtv = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            vtv[i * n + j] = power_sums[i + j];
        }
    }
    let mut vty = vec![0.0f64; n];
    for (&x, &y) in xs.iter().zip(ys.iter()) {
        let mut p = 1.0;
        for entry in vty.iter_mut() {
            *entry += p * y;
            p *= x;
        }
    }
    solve_linear(&vtv, &vty, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sigmoid::eval_real_poly;
    use crate::util::proptest::check;

    #[test]
    fn solves_identity() {
        let a = [1.0, 0.0, 0.0, 1.0];
        let b = [3.0, -4.0];
        assert_eq!(solve_linear(&a, &b, 2).unwrap(), vec![3.0, -4.0]);
    }

    #[test]
    fn solves_known_system() {
        // 2x + y = 5; x - y = 1  →  x = 2, y = 1
        let a = [2.0, 1.0, 1.0, -1.0];
        let b = [5.0, 1.0];
        let x = solve_linear(&a, &b, 2).unwrap();
        assert!((x[0] - 2.0).abs() < 1e-12);
        assert!((x[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_singular() {
        let a = [1.0, 2.0, 2.0, 4.0];
        assert!(solve_linear(&a, &[1.0, 2.0], 2).is_none());
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        // [[0,1],[1,0]] x = [2,3] → x = [3,2]
        let a = [0.0, 1.0, 1.0, 0.0];
        let x = solve_linear(&a, &[2.0, 3.0], 2).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn polyfit_recovers_exact_polynomials() {
        check("polyfit-exact", 50, |rng| {
            let deg = rng.below_usize(4);
            let coeffs: Vec<f64> = (0..=deg).map(|_| rng.range_f64(-2.0, 2.0)).collect();
            let xs: Vec<f64> = (0..40).map(|i| -2.0 + i as f64 * 0.1).collect();
            let ys: Vec<f64> = xs.iter().map(|&x| eval_real_poly(&coeffs, x)).collect();
            let fit = polyfit(&xs, &ys, deg).ok_or("fit failed")?;
            for (a, b) in fit.iter().zip(coeffs.iter()) {
                if (a - b).abs() > 1e-8 {
                    return Err(format!("{fit:?} vs {coeffs:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn polyfit_requires_enough_points() {
        assert!(polyfit(&[1.0], &[1.0], 1).is_none());
    }

    #[test]
    fn polyfit_overdetermined_minimizes_residual() {
        // Fit a line to noisy-ish data; residual of LSQ fit must be ≤
        // residual of nearby perturbed lines.
        let xs: Vec<f64> = (0..50).map(|i| i as f64 * 0.1).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| 1.0 + 0.5 * x + (x * 3.0).sin() * 0.01).collect();
        let fit = polyfit(&xs, &ys, 1).unwrap();
        let res = |c: &[f64]| -> f64 {
            xs.iter()
                .zip(ys.iter())
                .map(|(&x, &y)| (eval_real_poly(c, x) - y).powi(2))
                .sum()
        };
        let base = res(&fit);
        for delta in [[0.01, 0.0], [0.0, 0.01], [-0.01, 0.0], [0.0, -0.01]] {
            let perturbed = [fit[0] + delta[0], fit[1] + delta[1]];
            assert!(res(&perturbed) >= base - 1e-12);
        }
    }
}
