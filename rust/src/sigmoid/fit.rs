//! Sigmoid → polynomial fit and its field-quantized form.

use super::{eval_real_poly, lsq::polyfit, sigmoid};
use crate::field::PrimeField;
use crate::quant::{phi, round_half_up};

/// A fitted degree-r polynomial approximation ĝ of the sigmoid over
/// [-range, range], plus the field-quantized coefficients the workers use.
#[derive(Debug, Clone)]
pub struct SigmoidPoly {
    /// Real coefficients c_0..c_r (ascending), eq. (15).
    pub coeffs: Vec<f64>,
    /// Fit interval half-width R.
    pub range: f64,
    /// Degree r.
    pub r: u32,
}

/// Quality report of the fit.
#[derive(Debug, Clone, Copy)]
pub struct FitReport {
    pub max_err: f64,
    pub rms_err: f64,
}

/// Fit a degree-`r` polynomial to the sigmoid over `[-range, range]` with
/// `samples` equispaced points (least squares, paper §3.3).
pub fn fit_sigmoid(r: u32, range: f64, samples: usize) -> SigmoidPoly {
    assert!(r >= 1 && samples > r as usize);
    let xs: Vec<f64> = (0..samples)
        .map(|i| -range + 2.0 * range * i as f64 / (samples - 1) as f64)
        .collect();
    let ys: Vec<f64> = xs.iter().map(|&x| sigmoid(x)).collect();
    let coeffs = polyfit(&xs, &ys, r as usize).expect("sigmoid fit is well-conditioned");
    SigmoidPoly { coeffs, range, r }
}

impl SigmoidPoly {
    /// ĝ(z).
    #[inline]
    pub fn eval(&self, z: f64) -> f64 {
        eval_real_poly(&self.coeffs, z)
    }

    /// Fit quality over the fit interval.
    pub fn report(&self, samples: usize) -> FitReport {
        let mut max_err = 0.0f64;
        let mut sq = 0.0f64;
        for i in 0..samples {
            let z = -self.range + 2.0 * self.range * i as f64 / (samples - 1) as f64;
            let e = (self.eval(z) - sigmoid(z)).abs();
            max_err = max_err.max(e);
            sq += e * e;
        }
        FitReport { max_err, rms_err: (sq / samples as f64).sqrt() }
    }

    /// Field-quantized coefficients for the worker computation.
    ///
    /// Term i of ḡ = Σ_i c̄_i Π_{j≤i}(X̄ w̄_j) carries data scale
    /// 2^{i(l_x+l_w)}; to make all terms addable at the common scale
    /// 2^{l_c + r(l_x+l_w)} the coefficient is stored as
    ///   c̄_i = Round(2^{l_c + (r-i)(l_x+l_w)} · c_i)  ∈ F_p.
    /// l_c = 0 reproduces the paper's eq. (24) scale; l_c > 0 preserves
    /// precision of the top coefficient (DESIGN.md §Numeric design).
    pub fn field_coeffs(&self, field: &PrimeField, lx: u32, lw: u32, lc: u32) -> Vec<u64> {
        let r = self.r;
        self.coeffs
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                let shift = lc + (r - i as u32) * (lx + lw);
                phi(field, round_half_up((1u64 << shift) as f64 * c))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{PrimeField, PAPER_PRIME};
    use crate::quant::phi_inv;

    #[test]
    fn degree1_fit_is_sane() {
        let p = fit_sigmoid(1, 5.0, 201);
        assert_eq!(p.coeffs.len(), 2);
        // Sigmoid symmetric around (0, 0.5): intercept 0.5, positive slope.
        assert!((p.coeffs[0] - 0.5).abs() < 1e-6, "c0={}", p.coeffs[0]);
        assert!(p.coeffs[1] > 0.1 && p.coeffs[1] < 0.25, "c1={}", p.coeffs[1]);
        let rep = p.report(400);
        // A degree-1 LSQ fit over [-5,5] has max error ≈ 0.16 at the ends.
        assert!(rep.max_err < 0.2, "max_err={}", rep.max_err);
    }

    #[test]
    fn degree2_fit_degenerates_to_degree1() {
        // Sigmoid minus 1/2 is odd, so the z^2 coefficient vanishes on a
        // symmetric interval.
        let p = fit_sigmoid(2, 5.0, 201);
        assert!(p.coeffs[2].abs() < 1e-6, "c2={}", p.coeffs[2]);
    }

    #[test]
    fn degree3_fit_is_more_accurate_than_degree1() {
        let p1 = fit_sigmoid(1, 5.0, 201);
        let p3 = fit_sigmoid(3, 5.0, 201);
        assert!(p3.report(400).max_err < p1.report(400).max_err);
    }

    #[test]
    fn fit_error_shrinks_with_degree_weierstrass() {
        // Lemma 1's asymptotic-unbiasedness argument: ε(r) → 0.
        let errs: Vec<f64> = [1u32, 3]
            .iter()
            .map(|&r| fit_sigmoid(r, 4.0, 301).report(500).rms_err)
            .collect();
        assert!(errs[1] < errs[0] * 0.6, "errs={errs:?}");
    }

    #[test]
    fn field_coeffs_scale_correctly() {
        let f = PrimeField::new(PAPER_PRIME);
        let p = fit_sigmoid(1, 5.0, 201);
        let (lx, lw, lc) = (2, 4, 3);
        let fc = p.field_coeffs(&f, lx, lw, lc);
        // c̄_0 = Round(2^{3+6}·c_0), c̄_1 = Round(2^3·c_1)
        assert_eq!(phi_inv(&f, fc[0]), round_half_up(512.0 * p.coeffs[0]));
        assert_eq!(phi_inv(&f, fc[1]), round_half_up(8.0 * p.coeffs[1]));
        // With l_c = 3 the top coefficient survives quantization.
        assert!(phi_inv(&f, fc[1]) >= 1);
    }

    #[test]
    fn paper_lc0_truncates_top_coefficient() {
        // Documents the failure mode our l_c generalization fixes: the
        // paper's implicit l_c=0 rounds c_1 ≈ 0.15 to 0.
        let f = PrimeField::new(PAPER_PRIME);
        let p = fit_sigmoid(1, 5.0, 201);
        let fc = p.field_coeffs(&f, 2, 4, 0);
        assert_eq!(phi_inv(&f, fc[1]), 0);
    }
}
