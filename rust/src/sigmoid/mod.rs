//! Polynomial approximation of the sigmoid (paper §3.3, eq. 15).
//!
//! The coefficients are obtained "by fitting the sigmoid function via least
//! squares estimation" over a bounded activation range [-R, R] (the
//! convergence proof constrains ‖w‖ ≤ R via Lemma 1's Weierstrass
//! argument). This module provides the least-squares fit (normal equations
//! + Gaussian elimination — the problem is tiny, degree ≤ 4), evaluation,
//! and the field-quantized coefficient vector used by the workers.

mod chebyshev;
mod fit;
mod lsq;

pub use chebyshev::fit_sigmoid_chebyshev;
pub use fit::{fit_sigmoid, FitReport, SigmoidPoly};
pub use lsq::{polyfit, solve_linear};

/// Which fitting strategy produces ĝ (paper: least squares; Chebyshev is
/// the worst-case-minded alternative, see [`fit_sigmoid_chebyshev`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FitMethod {
    LeastSquares,
    Chebyshev,
}

impl std::str::FromStr for FitMethod {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "lsq" | "least-squares" => Ok(FitMethod::LeastSquares),
            "chebyshev" => Ok(FitMethod::Chebyshev),
            other => Err(format!("unknown fit method '{other}' (lsq|chebyshev)")),
        }
    }
}

impl std::fmt::Display for FitMethod {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FitMethod::LeastSquares => "lsq",
            FitMethod::Chebyshev => "chebyshev",
        })
    }
}

/// Fit with the chosen method.
pub fn fit_sigmoid_with(method: FitMethod, r: u32, range: f64) -> SigmoidPoly {
    match method {
        FitMethod::LeastSquares => fit_sigmoid(r, range, 201),
        FitMethod::Chebyshev => SigmoidPoly {
            coeffs: fit_sigmoid_chebyshev(r, range),
            range,
            r,
        },
    }
}

/// The sigmoid g(z) = 1 / (1 + e^{-z}) (paper eq. 2).
#[inline]
pub fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// Evaluate a real polynomial (ascending coefficients) by Horner.
#[inline]
pub fn eval_real_poly(coeffs: &[f64], z: f64) -> f64 {
    coeffs.iter().rev().fold(0.0, |acc, &c| acc * z + c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_basic_values() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
        assert!((sigmoid(100.0) - 1.0).abs() < 1e-12);
        assert!(sigmoid(-100.0).abs() < 1e-12);
        // Symmetry g(-z) = 1 - g(z).
        for z in [-3.0, -0.7, 0.1, 2.5] {
            assert!((sigmoid(-z) - (1.0 - sigmoid(z))).abs() < 1e-14);
        }
    }

    #[test]
    fn sigmoid_numerically_stable_extremes() {
        assert!(sigmoid(-800.0) >= 0.0);
        assert!(sigmoid(800.0) <= 1.0);
        assert!(!sigmoid(-800.0).is_nan());
    }

    #[test]
    fn horner_eval() {
        // 1 - 2z + 3z^2 at z = 2 → 1 - 4 + 12 = 9
        assert_eq!(eval_real_poly(&[1.0, -2.0, 3.0], 2.0), 9.0);
        assert_eq!(eval_real_poly(&[], 5.0), 0.0);
    }
}
