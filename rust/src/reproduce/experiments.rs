//! The ten experiments: Figure 2, Tables 1–3, Figures 3–4 (convergence),
//! Figure 5 and Tables 4–6 (smaller dataset).

use super::runner::{run_cpml, run_mpc, run_plaintext, ExpParams, TABLE_HEADER};
use crate::util::json::{obj, Json};

/// Descriptor for one paper artifact.
pub struct Experiment {
    pub id: &'static str,
    pub paper_ref: &'static str,
    pub what: &'static str,
}

pub const EXPERIMENTS: &[Experiment] = &[
    Experiment { id: "fig2", paper_ref: "Figure 2", what: "training time vs N, d=1568: MPC vs CPML Case 1/2" },
    Experiment { id: "table1", paper_ref: "Table 1", what: "runtime breakdown, N=40, d=1568" },
    Experiment { id: "table2", paper_ref: "Table 2", what: "runtime breakdown, N=10, d=1568" },
    Experiment { id: "table3", paper_ref: "Table 3", what: "runtime breakdown, N=25, d=1568" },
    Experiment { id: "fig3", paper_ref: "Figure 3", what: "test accuracy vs iteration: CPML vs conventional LR" },
    Experiment { id: "fig4", paper_ref: "Figure 4 (A.6.2)", what: "cross-entropy vs iteration: CPML vs conventional LR" },
    Experiment { id: "fig5", paper_ref: "Figure 5 (A.6.3)", what: "training time vs N, d=784" },
    Experiment { id: "table4", paper_ref: "Table 4", what: "runtime breakdown, N=10, d=784" },
    Experiment { id: "table5", paper_ref: "Table 5", what: "runtime breakdown, N=25, d=784" },
    Experiment { id: "table6", paper_ref: "Table 6", what: "runtime breakdown, N=40, d=784" },
    Experiment {
        id: "ablation-r",
        paper_ref: "beyond paper",
        what: "sigmoid degree r ∈ {1, 2}: accuracy vs recovery threshold",
    },
    Experiment {
        id: "ablation-lc",
        paper_ref: "beyond paper",
        what: "coefficient scale l_c ∈ {0(paper), 1, 3, 5}: accuracy + budget",
    },
    Experiment {
        id: "ablation-straggler",
        paper_ref: "beyond paper",
        what: "straggler intensity vs fastest-R benefit (slack sweep)",
    },
    Experiment {
        id: "ablation-wire",
        paper_ref: "beyond paper",
        what: "raw u64 vs bit-packed wire framing: comm time and bytes",
    },
    Experiment {
        id: "linear",
        paper_ref: "Remark 1",
        what: "coded linear regression on a planted model vs plaintext GD",
    },
    Experiment {
        id: "degraded",
        paper_ref: "beyond paper",
        what: "fault tolerance: supervised respawn vs approximate-decode degraded mode",
    },
];

/// Rendered experiment: human-readable text + machine-readable JSON.
#[derive(Debug)]
pub struct ExperimentOutput {
    pub id: String,
    pub text: String,
    pub json: Json,
}

/// The paper's numbers for speedup-shape comparison (total seconds).
/// (paper Table 1–6 totals; used only to report expected *shape*.)
fn paper_totals(d: usize, n: usize) -> Option<(f64, f64, f64)> {
    // (MPC, CPML case1, CPML case2)
    match (d, n) {
        (1568, 10) => Some((1001.53, 303.13, 465.52)),
        (1568, 25) => Some((1818.63, 144.77, 295.68)),
        (1568, 40) => Some((4304.60, 126.20, 222.50)),
        (784, 10) => Some((204.86, 62.23, 96.70)),
        (784, 25) => Some((484.09, 38.87, 72.39)),
        (784, 40) => Some((1194.12, 45.58, 76.81)),
        _ => None,
    }
}

fn breakdown_table(n: usize, d: usize, params: &ExpParams) -> Result<(String, Json), String> {
    let mpc = run_mpc(n, params, false)?;
    let c1 = run_cpml(n, 1, params, false)?;
    let c2 = run_cpml(n, 2, params, false)?;
    let mut text = String::new();
    text.push_str(&format!(
        "Breakdown of the total run time with N={n} workers, d={d}, m≈{}×paper, {} iterations\n",
        params.scale, params.iters
    ));
    text.push_str(TABLE_HEADER);
    text.push('\n');
    for row in [&mpc, &c1, &c2] {
        text.push_str(&row.table_row());
        text.push('\n');
    }
    let speed1 = mpc.total_s / c1.total_s;
    let speed2 = mpc.total_s / c2.total_s;
    text.push_str(&format!(
        "speedup vs MPC: Case 1 {speed1:.1}x, Case 2 {speed2:.1}x\n"
    ));
    if let Some((pm, p1, p2)) = paper_totals(d, n) {
        text.push_str(&format!(
            "paper shape at this (N, d): MPC/Case1 {:.1}x, MPC/Case2 {:.1}x \
             (absolute seconds not comparable — simulated testbed)\n",
            pm / p1,
            pm / p2
        ));
    }
    let json = obj(&[
        ("n", Json::Num(n as f64)),
        ("d", Json::Num(d as f64)),
        ("mpc", mpc.report.to_json()),
        ("cpml_case1", c1.report.to_json()),
        ("cpml_case2", c2.report.to_json()),
        ("speedup_case1", Json::Num(speed1)),
        ("speedup_case2", Json::Num(speed2)),
    ]);
    Ok((text, json))
}

fn training_time_figure(d: usize, params: &ExpParams) -> Result<(String, Json), String> {
    let ns = [5usize, 10, 25, 40];
    let mut text = String::new();
    text.push_str(&format!(
        "Total training time vs N (d={d}, m≈{}×paper, {} iters)\n",
        params.scale, params.iters
    ));
    text.push_str("|   N | MPC total (s) | CPML Case 1 (s) | CPML Case 2 (s) | speedup C1 | speedup C2 |\n");
    text.push_str("|-----|---------------|-----------------|-----------------|------------|------------|\n");
    let mut rows = Vec::new();
    for &n in &ns {
        let mpc = run_mpc(n, params, false)?;
        let c1 = run_cpml(n, 1, params, false)?;
        let c2 = run_cpml(n, 2, params, false)?;
        text.push_str(&format!(
            "| {n:>3} | {:>13.2} | {:>15.2} | {:>15.2} | {:>9.1}x | {:>9.1}x |\n",
            mpc.total_s,
            c1.total_s,
            c2.total_s,
            mpc.total_s / c1.total_s,
            mpc.total_s / c2.total_s
        ));
        rows.push(obj(&[
            ("n", Json::Num(n as f64)),
            ("mpc_total", Json::Num(mpc.total_s)),
            ("cpml1_total", Json::Num(c1.total_s)),
            ("cpml2_total", Json::Num(c2.total_s)),
        ]));
    }
    text.push_str(
        "expected shape (paper): MPC grows with N; CPML shrinks (Case 1 below Case 2); \
         speedup expands with N.\n",
    );
    Ok((text, Json::Arr(rows)))
}

fn ascii_curve(label: &str, values: &[f64], lo: f64, hi: f64) -> String {
    let width = 50usize;
    let mut out = format!("{label}\n");
    for (i, &v) in values.iter().enumerate() {
        let frac = ((v - lo) / (hi - lo)).clamp(0.0, 1.0);
        let bars = (frac * width as f64).round() as usize;
        out.push_str(&format!("iter {i:>2} {v:>8.4} |{}\n", "#".repeat(bars)));
    }
    out
}

fn convergence_figures(accuracy: bool, params: &ExpParams) -> Result<(String, Json), String> {
    // CPML Case 2, N=40 per the paper's Figure 3/4 caption. (Ablations
    // showed the accuracy gap vs conventional LR is dominated by the
    // degree-1 sigmoid approximation, not quantization: raising l_c or
    // l_w moves the final accuracy by <0.3% — see EXPERIMENTS.md.)
    let cpml = cpml_with(params, 40, |_| {})?;
    let (plain_loss, plain_acc) = run_plaintext(params);
    let mut text = String::new();
    if accuracy {
        let cpml_acc: Vec<f64> = cpml
            .iterations
            .iter()
            .map(|m| m.test_accuracy.unwrap_or(f64::NAN))
            .collect();
        text.push_str(&format!(
            "Test accuracy vs iteration (CPML Case 2, N=40, degree-1 sigmoid)\n\
             final: CPML {:.2}%  conventional LR {:.2}%  (paper: 95.04% vs 95.98%)\n\n",
            100.0 * cpml_acc.last().unwrap(),
            100.0 * plain_acc.last().unwrap()
        ));
        text.push_str(&ascii_curve("CodedPrivateML accuracy", &cpml_acc, 0.4, 1.0));
        text.push('\n');
        text.push_str(&ascii_curve("Conventional LR accuracy", &plain_acc, 0.4, 1.0));
        let json = obj(&[
            ("cpml_accuracy", Json::Arr(cpml_acc.iter().map(|&v| Json::Num(v)).collect())),
            ("plain_accuracy", Json::Arr(plain_acc.iter().map(|&v| Json::Num(v)).collect())),
        ]);
        Ok((text, json))
    } else {
        let cpml_loss: Vec<f64> = cpml.iterations.iter().map(|m| m.train_loss).collect();
        let hi = cpml_loss
            .first()
            .copied()
            .unwrap_or(0.7)
            .max(plain_loss.first().copied().unwrap_or(0.7));
        text.push_str(&format!(
            "Cross-entropy vs iteration (CPML Case 2, N=40)\n\
             final: CPML {:.4}  conventional LR {:.4}\n\n",
            cpml_loss.last().unwrap(),
            plain_loss.last().unwrap()
        ));
        text.push_str(&ascii_curve("CodedPrivateML loss", &cpml_loss, 0.0, hi));
        text.push('\n');
        text.push_str(&ascii_curve("Conventional LR loss", &plain_loss, 0.0, hi));
        let json = obj(&[
            ("cpml_loss", Json::Arr(cpml_loss.iter().map(|&v| Json::Num(v)).collect())),
            ("plain_loss", Json::Arr(plain_loss.iter().map(|&v| Json::Num(v)).collect())),
        ]);
        Ok((text, json))
    }
}

fn cpml_with(
    params: &ExpParams,
    n: usize,
    tweak: impl FnOnce(&mut crate::coordinator::CodedMlConfig),
) -> Result<crate::coordinator::TrainReport, String> {
    use crate::coordinator::{CodedMlConfig, CodedMlSession};
    let mut cfg = CodedMlConfig::case2(n, 1).map_err(|e| e.to_string())?;
    cfg.iters = params.iters;
    cfg.seed = params.seed;
    cfg.backend = params.backend;
    cfg.straggler = params.straggler;
    cfg.net = params.net;
    cfg.p = params.p;
    cfg.strict_budget = true; // a wrapped gradient is a wrong experiment
    tweak(&mut cfg);
    let (train, test) = params.dataset();
    let mut sess = CodedMlSession::new(cfg, &train).map_err(|e| e.to_string())?;
    sess.train(params.iters, Some(&test)).map_err(|e| e.to_string())
}

/// Ablation: sigmoid polynomial degree r. r=2 costs a much larger
/// recovery threshold ((2r+1) factor) for marginal accuracy — the reason
/// the paper settles on r=1.
fn ablation_r(params: &ExpParams) -> Result<(String, Json), String> {
    use crate::coding::CodingParams;
    let n = 25;
    let mut text = String::from("| r | (K, T) | recovery threshold | final acc | total (s) |\n");
    text.push_str("|---|--------|--------------------|-----------|-----------|\n");
    let mut rows = Vec::new();
    for r in [1usize, 2] {
        let p = CodingParams::case2(n, r).map_err(|e| e.to_string())?;
        let rep = cpml_with(params, n, |cfg| {
            cfg.r = r;
            cfg.k = p.k;
            cfg.t = p.t;
            // r=2 doubles the dequantization scale bits — the overflow
            // budget only closes with coarser per-factor scales. Apply
            // the same scales to r=1 so the comparison is fair.
            cfg.lx = 1;
            cfg.lw = 2;
            cfg.lc = 2;
        })?;
        let acc = rep.final_accuracy().unwrap_or(f64::NAN);
        text.push_str(&format!(
            "| {r} | ({}, {}) | {:>18} | {:>8.4} | {:>9.2} |\n",
            p.k,
            p.t,
            rep.recovery_threshold,
            acc,
            rep.breakdown.total()
        ));
        rows.push(obj(&[
            ("r", Json::Num(r as f64)),
            ("threshold", Json::Num(rep.recovery_threshold as f64)),
            ("accuracy", Json::Num(acc)),
            ("total_s", Json::Num(rep.breakdown.total())),
        ]));
    }
    text.push_str("shape: r=2 buys little accuracy at this activation range but slashes K and T.\n");
    Ok((text, Json::Arr(rows)))
}

/// Ablation: coefficient scale l_c. l_c = 0 is the paper's implicit
/// choice — it truncates the degree-1 slope coefficient to 0 and training
/// stalls, which is why this repo generalizes the dequantization scale.
fn ablation_lc(params: &ExpParams) -> Result<(String, Json), String> {
    let n = 10;
    let mut text = String::from("| l_c | final loss | final acc | note |\n|-----|------------|-----------|------|\n");
    let mut rows = Vec::new();
    for lc in [0u32, 1, 3, 5] {
        let rep = cpml_with(params, n, |cfg| cfg.lc = lc)?;
        let loss = rep.final_loss().unwrap_or(f64::NAN);
        let acc = rep.final_accuracy().unwrap_or(f64::NAN);
        let note = if lc == 0 { "paper's formula: slope c̄₁ rounds to 0" } else { "" };
        text.push_str(&format!("| {lc:>3} | {loss:>10.5} | {acc:>9.4} | {note} |\n"));
        rows.push(obj(&[
            ("lc", Json::Num(lc as f64)),
            ("loss", Json::Num(loss)),
            ("accuracy", Json::Num(acc)),
        ]));
    }
    Ok((text, Json::Arr(rows)))
}

/// Ablation: straggler intensity. The fastest-R discount keeps the
/// modeled iteration time near the straggle-free baseline until the
/// slack (N − R) is exhausted.
fn ablation_straggler(params: &ExpParams) -> Result<(String, Json), String> {
    use crate::cluster::StragglerModel;
    let n = 25; // case 2 at N=25: threshold 22, slack 3
    let mut text =
        String::from("| straggle mean (xcompute) | comp time (s) | vs none |\n|--------------------------|---------------|--------|\n");
    let mut rows = Vec::new();
    let mut base = None;
    for rate in [f64::INFINITY, 5.0, 1.0, 0.25] {
        let rep = cpml_with(params, n, |cfg| {
            cfg.straggler = StragglerModel { shift: 0.0, rate, relative: true };
        })?;
        let comp = rep.breakdown.comp_s;
        let b = *base.get_or_insert(comp);
        let mean = if rate.is_finite() { format!("{:.2}", 1.0 / rate) } else { "0".into() };
        text.push_str(&format!(
            "| {mean:>24} | {comp:>13.3} | {:>5.2}x |\n",
            comp / b
        ));
        rows.push(obj(&[
            ("mean_rel_delay", Json::Num(if rate.is_finite() { 1.0 / rate } else { 0.0 })),
            ("comp_s", Json::Num(comp)),
        ]));
    }
    text.push_str("shape: waiting only for the fastest R absorbs the straggler tail.\n");
    Ok((text, Json::Arr(rows)))
}

/// Ablation: wire framing. Bit-packing field elements to ⌈log₂ p⌉ bits
/// shrinks the dominant one-time dataset broadcast (and every message)
/// by 64/26 ≈ 2.46x at the harness prime, without touching the math.
fn ablation_wire(params: &ExpParams) -> Result<(String, Json), String> {
    let n = 10;
    let mut text = String::from(
        "| framing | comm (s) | bytes sent | final loss |\n|---------|----------|------------|------------|\n",
    );
    let mut rows = Vec::new();
    let mut losses = Vec::new();
    for packed in [false, true] {
        let rep = cpml_with(params, n, |cfg| cfg.packed_wire = packed)?;
        let label = if packed { "packed" } else { "raw u64" };
        let loss = rep.final_loss().unwrap_or(f64::NAN);
        losses.push(loss);
        text.push_str(&format!(
            "| {label:<7} | {:>8.3} | {:>10} | {loss:>10.5} |\n",
            rep.breakdown.comm_s, rep.bytes_sent
        ));
        rows.push(obj(&[
            ("packed", Json::Bool(packed)),
            ("comm_s", Json::Num(rep.breakdown.comm_s)),
            ("bytes_sent", Json::Num(rep.bytes_sent as f64)),
            ("loss", Json::Num(loss)),
        ]));
    }
    if (losses[0] - losses[1]).abs() > 1e-12 {
        return Err("wire framing changed the training outcome".into());
    }
    text.push_str("framing is transparent to the protocol (identical loss).\n");
    Ok((text, Json::Arr(rows)))
}

/// Remark 1: coded linear regression on a planted model. Trains the
/// coded session and plaintext gradient descent on the same data and
/// compares final MSE and recovery error ‖w − w*‖.
fn linear_regression_exp(params: &ExpParams) -> Result<(String, Json), String> {
    use crate::coordinator::{CodedMlConfig, CodedMlSession};
    use crate::data::synthetic_planted_linear;
    use crate::model::LinearRegression;

    let (m, d) = (120usize, 8usize);
    let (train, w_star) = synthetic_planted_linear(m, d, params.seed);
    let iters = params.iters.max(10);
    let cfg = CodedMlConfig {
        n: 10,
        k: 3,
        t: 1,
        iters,
        seed: params.seed,
        backend: params.backend,
        straggler: params.straggler,
        net: params.net,
        strict_budget: true, // a wrapped gradient is a wrong experiment
        ..CodedMlConfig::linear()
    };
    let mut sess = CodedMlSession::new_linear(cfg, &train).map_err(|e| e.to_string())?;
    let report = sess.train(iters, None).map_err(|e| e.to_string())?;
    let coded_err = LinearRegression::with_weights(report.weights.clone()).distance_to(&w_star);

    let mut plain = LinearRegression::new(d);
    let eta = plain.lipschitz_lr(&train.x, m, d);
    for _ in 0..iters {
        plain.step(&train.x, &train.y, m, d, eta);
    }
    let plain_err = plain.distance_to(&w_star);
    let plain_loss = plain.loss(&train.x, &train.y, m, d);
    let coded_loss = report.final_loss().unwrap_or(f64::NAN);

    let mut text = format!(
        "Coded linear regression (Remark 1): planted y = X·w*, m={m}, d={d}, {iters} iters\n"
    );
    text.push_str("| trainer            | final MSE | ‖w − w*‖ |\n");
    text.push_str("|--------------------|-----------|----------|\n");
    text.push_str(&format!("| CodedPrivateML     | {coded_loss:>9.6} | {coded_err:>8.4} |\n"));
    text.push_str(&format!("| plaintext GD       | {plain_loss:>9.6} | {plain_err:>8.4} |\n"));
    text.push_str(
        "shape: the identity activation makes the coded gradient exactly unbiased — \
         both trainers recover the planted model; the gap is quantization noise.\n",
    );
    let json = obj(&[
        ("coded_loss", Json::Num(coded_loss)),
        ("coded_err", Json::Num(coded_err)),
        ("plain_loss", Json::Num(plain_loss)),
        ("plain_err", Json::Num(plain_err)),
        ("loss_curve", report.to_json().get("loss_curve").cloned().unwrap_or(Json::Null)),
    ]);
    Ok((text, json))
}

/// Fault-tolerance experiment (beyond paper): the same training task run
/// three ways on a zero-slack pool (Case 2 at N=10 → R = N), where any
/// worker loss leaves rounds short of the recovery threshold:
/// fault-free; with one chaos death healed by the supervisor (respawn +
/// share re-ship + mid-round re-dispatch, which must reproduce the
/// fault-free trajectory bit for bit); and with two chaos deaths pushed
/// into approximate-decode degraded mode (training stays alive, but with
/// T ≥ 1 the missing evaluations are cryptographically unrecoverable —
/// the surfaced residual is the honesty metric, not an accuracy claim).
fn degraded_mode_exp(params: &ExpParams) -> Result<(String, Json), String> {
    let n = 10;
    let clean = cpml_with(params, n, |_| {})?;
    let healed = cpml_with(params, n, |cfg| {
        cfg.chaos_failures = 1;
        cfg.chaos_from_iter = 1;
        cfg.max_respawns = 2;
    })?;
    let degraded = cpml_with(params, n, |cfg| {
        cfg.chaos_failures = 2;
        cfg.chaos_from_iter = 1;
        cfg.approx_decode = true;
    })?;
    if healed.weights != clean.weights {
        return Err(
            "supervised respawn must reproduce the fault-free trajectory bit for bit".into(),
        );
    }
    let mut text = format!(
        "Fault tolerance on a zero-slack pool (Case 2, N={n}, R = N): \
         fault-free vs healed vs degraded\n"
    );
    text.push_str(
        "| run                  | final acc | failures | respawns | approx rounds | max residual |\n",
    );
    text.push_str(
        "|----------------------|-----------|----------|----------|---------------|--------------|\n",
    );
    let mut rows = Vec::new();
    for (label, rep) in [
        ("fault-free", &clean),
        ("supervised respawn", &healed),
        ("degraded (approx)", &degraded),
    ] {
        let acc = rep.final_accuracy().unwrap_or(f64::NAN);
        text.push_str(&format!(
            "| {label:<20} | {acc:>9.4} | {:>8} | {:>8} | {:>13} | {:>12.3e} |\n",
            rep.worker_failures, rep.respawns, rep.approx_rounds, rep.max_approx_residual
        ));
        rows.push(obj(&[
            ("run", Json::Str(label.into())),
            ("accuracy", Json::Num(acc)),
            ("worker_failures", Json::Num(rep.worker_failures as f64)),
            ("respawns", Json::Num(rep.respawns as f64)),
            ("approx_rounds", Json::Num(rep.approx_rounds as f64)),
            ("max_approx_residual", Json::Num(rep.max_approx_residual)),
        ]));
    }
    text.push_str(
        "shape: healing restores the exact trajectory (identical weights, asserted); \
         degraded mode trades correctness for liveness and says so via the residual.\n",
    );
    Ok((text, Json::Arr(rows)))
}

/// Run one experiment by id.
pub fn run_experiment(id: &str, params: &ExpParams) -> Result<ExperimentOutput, String> {
    let mut params = params.clone();
    let (text, json) = match id {
        "fig2" => training_time_figure(1568, &params)?,
        "table1" => breakdown_table(40, 1568, &params)?,
        "table2" => breakdown_table(10, 1568, &params)?,
        "table3" => breakdown_table(25, 1568, &params)?,
        "fig3" => {
            params.d = 784; // accuracy experiments use the raw 3-vs-7 task
            convergence_figures(true, &params)?
        }
        "fig4" => {
            params.d = 784;
            convergence_figures(false, &params)?
        }
        "fig5" => {
            params.d = 784;
            training_time_figure(784, &params)?
        }
        "table4" => {
            params.d = 784;
            breakdown_table(10, 784, &params)?
        }
        "table5" => {
            params.d = 784;
            breakdown_table(25, 784, &params)?
        }
        "table6" => {
            params.d = 784;
            breakdown_table(40, 784, &params)?
        }
        "ablation-r" => {
            params.d = 784;
            ablation_r(&params)?
        }
        "ablation-lc" => {
            params.d = 784;
            ablation_lc(&params)?
        }
        "ablation-straggler" => {
            params.d = 784;
            ablation_straggler(&params)?
        }
        "ablation-wire" => {
            params.d = 784;
            ablation_wire(&params)?
        }
        "linear" => linear_regression_exp(&params)?,
        "degraded" => {
            params.d = 784;
            degraded_mode_exp(&params)?
        }
        other => {
            return Err(format!(
                "unknown experiment '{other}'; available: {}",
                EXPERIMENTS.iter().map(|e| e.id).collect::<Vec<_>>().join(", ")
            ))
        }
    };
    let exp = EXPERIMENTS.iter().find(|e| e.id == id).unwrap();
    let mut full = format!("=== {} — {} ===\n{}\n", exp.paper_ref, exp.what, text);
    full.push('\n');
    Ok(ExperimentOutput {
        id: id.to_string(),
        text: full,
        json: obj(&[("id", Json::Str(id.into())), ("data", json)]),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{NetworkModel, StragglerModel};

    fn micro() -> ExpParams {
        ExpParams {
            scale: 0.008,
            iters: 2,
            straggler: StragglerModel::none(),
            net: NetworkModel::default(),
            ..Default::default()
        }
    }

    #[test]
    fn experiment_list_covers_all_paper_artifacts() {
        let ids = super::super::list();
        for want in ["fig2", "fig3", "fig4", "fig5", "table1", "table2", "table3", "table4", "table5", "table6"] {
            assert!(ids.contains(&want), "missing {want}");
        }
    }

    #[test]
    fn unknown_experiment_errors_helpfully() {
        let err = run_experiment("fig9", &micro()).unwrap_err();
        assert!(err.contains("unknown experiment"), "{err}");
        assert!(err.contains("fig2"));
    }

    #[test]
    fn table_breakdown_runs_at_micro_scale() {
        let out = run_experiment("table2", &micro()).unwrap();
        assert!(out.text.contains("MPC approach"));
        assert!(out.text.contains("CodedPrivateML (Case 1)"));
        assert!(out.text.contains("speedup vs MPC"));
        assert!(out.json.get("data").unwrap().get("speedup_case1").is_some());
    }

    #[test]
    fn fig3_runs_at_micro_scale() {
        let mut p = micro();
        p.iters = 3;
        let out = run_experiment("fig3", &p).unwrap();
        assert!(out.text.contains("Test accuracy"));
        let data = out.json.get("data").unwrap();
        assert_eq!(data.get("cpml_accuracy").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn linear_experiment_runs_at_micro_scale() {
        let out = run_experiment("linear", &micro()).unwrap();
        assert!(out.text.contains("CodedPrivateML"));
        assert!(out.text.contains("plaintext GD"));
        let data = out.json.get("data").unwrap();
        assert!(data.get("coded_err").unwrap().as_f64().is_some());
        assert!(data.get("plain_err").unwrap().as_f64().is_some());
    }

    #[test]
    fn degraded_experiment_runs_at_micro_scale() {
        let out = run_experiment("degraded", &micro()).unwrap();
        assert!(out.text.contains("supervised respawn"), "{}", out.text);
        assert!(out.text.contains("degraded (approx)"), "{}", out.text);
        let rows = out.json.get("data").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[1].get("respawns").unwrap().as_u64(), Some(1));
        assert!(rows[2].get("approx_rounds").unwrap().as_u64().unwrap() >= 1);
        assert_eq!(rows[0].get("worker_failures").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn ascii_curve_monotone_bars() {
        let s = ascii_curve("x", &[0.0, 0.5, 1.0], 0.0, 1.0);
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[1].ends_with('|'));
        assert!(lines[3].matches('#').count() > lines[2].matches('#').count());
    }
}
