//! Shared machinery for the experiment harness: one CodedPrivateML run or
//! one MPC run at given (N, case, dataset) → a comparable row.

use crate::cluster::{NetworkModel, StragglerModel};
use crate::coordinator::{CodedMlConfig, CodedMlSession, TrainReport};
use crate::data::{paper_dataset, Dataset};
use crate::mpc::{BgwConfig, BgwGradientProtocol};
use crate::runtime::BackendKind;

/// Parameters common to one experiment run.
#[derive(Debug, Clone)]
pub struct ExpParams {
    /// Field prime. The harness defaults to the 26-bit PRIME_26 rather
    /// than the paper's 24-bit prime: our l_c=3 coefficient scale (which
    /// fixes the paper's leading-coefficient truncation, DESIGN.md
    /// §Numeric design) costs 8× overflow budget, and the N=5 / K=1
    /// corner of Figure 2 would exceed the 24-bit budget at paper scale.
    /// 26 bits restores the margin and is still i64-dot-safe (`codedml
    /// budget` shows the numbers).
    pub p: u64,
    /// Fraction of the paper's m = 12396 to actually run (memory/time on
    /// a single host; shapes are m-independent).
    pub scale: f64,
    /// Feature dimension: 1568 (§5) or 784 (A.6.3).
    pub d: usize,
    /// Training iterations (paper: 25).
    pub iters: usize,
    pub seed: u64,
    pub backend: BackendKind,
    /// Straggling for CPML's fastest-R collection.
    pub straggler: StragglerModel,
    pub net: NetworkModel,
}

impl Default for ExpParams {
    fn default() -> Self {
        ExpParams {
            p: crate::field::PRIME_26,
            scale: 0.05,
            d: 1568,
            iters: 25,
            seed: 42,
            backend: BackendKind::Native,
            straggler: StragglerModel::default(),
            net: NetworkModel::default(),
        }
    }
}

impl ExpParams {
    /// The paper's m scaled down (and the matching train/test datasets).
    pub fn dataset(&self) -> (Dataset, Dataset) {
        let m = ((12396.0 * self.scale) as usize).max(60);
        let test_m = (m / 6).max(30);
        let (train, test) = paper_dataset(m, test_m, self.seed);
        if self.d == 1568 {
            (train.duplicate_features(), test.duplicate_features())
        } else {
            (train, test)
        }
    }
}

/// One protocol run distilled to a table row.
#[derive(Debug, Clone)]
pub struct RunRow {
    pub label: String,
    pub encode_s: f64,
    pub comm_s: f64,
    pub comp_s: f64,
    pub total_s: f64,
    pub final_loss: f64,
    pub final_accuracy: Option<f64>,
    pub report: TrainReport,
}

impl RunRow {
    fn from_report(label: String, report: TrainReport) -> RunRow {
        RunRow {
            label,
            encode_s: report.breakdown.encode_s,
            comm_s: report.breakdown.comm_s,
            comp_s: report.breakdown.comp_s,
            total_s: report.breakdown.total(),
            final_loss: report.final_loss().unwrap_or(f64::NAN),
            final_accuracy: report.final_accuracy(),
            report,
        }
    }

    /// Paper-style table row.
    pub fn table_row(&self) -> String {
        format!(
            "| {label:<24} | {e:>8.2} | {c:>8.2} | {p:>8.2} | {t:>9.2} |",
            label = self.label,
            e = self.encode_s,
            c = self.comm_s,
            p = self.comp_s,
            t = self.total_s,
        )
    }
}

/// Run CodedPrivateML at (n, case) and return the row. `case` ∈ {1, 2}
/// (§5: max parallelization vs equal parallelization/privacy).
pub fn run_cpml(
    n: usize,
    case: u8,
    params: &ExpParams,
    with_accuracy: bool,
) -> Result<RunRow, String> {
    let mut cfg = match case {
        1 => CodedMlConfig::case1(n, 1).map_err(|e| e.to_string())?,
        2 => CodedMlConfig::case2(n, 1).map_err(|e| e.to_string())?,
        other => return Err(format!("case must be 1 or 2, got {other}")),
    };
    cfg.iters = params.iters;
    cfg.seed = params.seed;
    cfg.backend = params.backend;
    cfg.straggler = params.straggler;
    cfg.net = params.net;
    cfg.p = params.p;
    cfg.strict_budget = true; // a wrapped gradient is a wrong experiment
    let (train, test) = params.dataset();
    let mut sess = CodedMlSession::new(cfg, &train).map_err(|e| e.to_string())?;
    let report = sess
        .train(params.iters, if with_accuracy { Some(&test) } else { None })
        .map_err(|e| e.to_string())?;
    Ok(RunRow::from_report(format!("CodedPrivateML (Case {case})"), report))
}

/// Run the BGW MPC baseline at n workers (T = ⌊(N−1)/2⌋, the protocol's
/// natural maximum — matching the paper's baseline).
pub fn run_mpc(n: usize, params: &ExpParams, with_accuracy: bool) -> Result<RunRow, String> {
    let cfg = BgwConfig {
        n,
        t: ((n - 1) / 2).max(1),
        p: params.p,
        seed: params.seed,
        net: params.net,
        straggler: params.straggler,
        ..Default::default()
    };
    let (train, test) = params.dataset();
    let mut proto = BgwGradientProtocol::new(cfg, &train).map_err(|e| e.to_string())?;
    let report = proto.train(params.iters, if with_accuracy { Some(&test) } else { None });
    Ok(RunRow::from_report("MPC approach".to_string(), report))
}

/// Plaintext baseline (conventional LR, Figures 3–4).
pub fn run_plaintext(params: &ExpParams) -> (Vec<f64>, Vec<f64>) {
    use crate::model::LogisticRegression;
    let (train, test) = params.dataset();
    let mut lr = LogisticRegression::new(train.d);
    let eta = lr.lipschitz_lr(&train);
    let mut losses = Vec::with_capacity(params.iters);
    let mut accs = Vec::with_capacity(params.iters);
    for _ in 0..params.iters {
        lr.step(&train, eta);
        losses.push(lr.loss(&train));
        accs.push(lr.accuracy(&test));
    }
    (losses, accs)
}

pub const TABLE_HEADER: &str = "| Protocol                 |  Encode  |   Comm.  |   Comp.  | Total run |\n\
                                |--------------------------|----------|----------|----------|-----------|";

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ExpParams {
        ExpParams {
            scale: 0.01,
            d: 784,
            iters: 2,
            straggler: StragglerModel::none(),
            net: NetworkModel::free(),
            ..Default::default()
        }
    }

    #[test]
    fn cpml_row_runs() {
        let row = run_cpml(10, 1, &tiny(), true).unwrap();
        assert!(row.total_s > 0.0);
        assert!(row.final_accuracy.is_some());
        assert!(row.label.contains("Case 1"));
        assert!(row.table_row().contains("CodedPrivateML"));
    }

    #[test]
    fn mpc_row_runs() {
        let row = run_mpc(5, &tiny(), false).unwrap();
        assert!(row.total_s > 0.0);
        assert!(row.final_accuracy.is_none());
    }

    #[test]
    fn dataset_scaling_and_duplication() {
        let p = ExpParams { scale: 0.02, d: 1568, ..tiny() };
        let (train, _) = p.dataset();
        assert_eq!(train.d, 1568);
        assert!(train.m >= 60);
        let p = ExpParams { scale: 0.02, d: 784, ..tiny() };
        let (train, _) = p.dataset();
        assert_eq!(train.d, 784);
    }

    #[test]
    fn invalid_case_rejected() {
        assert!(run_cpml(10, 3, &tiny(), false).is_err());
    }

    #[test]
    fn plaintext_baseline_learns() {
        let (losses, accs) = run_plaintext(&ExpParams { iters: 10, ..tiny() });
        assert_eq!(losses.len(), 10);
        assert!(losses[9] < losses[0]);
        assert!(accs[9] > 0.8);
    }
}
