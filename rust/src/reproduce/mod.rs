//! Experiment harness — regenerates every table and figure of the paper's
//! evaluation (§5 and Appendix A.6). See DESIGN.md §Experiment index.
//!
//! Absolute seconds differ from the paper (their testbed is 40 EC2
//! machines; ours is one host simulating them — DESIGN.md
//! §Substitutions), so each experiment reports the *shape* the paper
//! claims alongside the measured numbers: who wins, by what factor, and
//! how the curves move with N. `--scale` shrinks m for quick runs;
//! EXPERIMENTS.md records a full run.

mod experiments;
mod runner;

pub use experiments::{run_experiment, ExperimentOutput, EXPERIMENTS};
pub use runner::{run_cpml, run_mpc, run_plaintext, ExpParams, RunRow, TABLE_HEADER};

/// All experiment ids, in paper order.
pub fn list() -> Vec<&'static str> {
    EXPERIMENTS.iter().map(|e| e.id).collect()
}
