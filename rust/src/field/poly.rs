//! Polynomial evaluation and Lagrange interpolation over F_p.
//!
//! The decode step of CodedPrivateML interpolates the degree-
//! `(2r+1)(K+T-1)` polynomial `h(z) = f(u(z), v(z))` from the evaluations
//! `h(α_i)` returned by the fastest workers, then evaluates it at the
//! dataset points `β_k` (§3.4). Because `h` is vector-valued (one scalar
//! polynomial per gradient coordinate), interpolation is expressed as a
//! *coefficient vector*: `h(β) = Σ_i λ_i · h(α_i)` with the λ_i computed
//! once per (worker subset, β) pair — turning decode into a dense
//! matrix-vector product.

use super::prime::PrimeField;

/// Error from interpolation setup.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum InterpolationError {
    /// Two evaluation points coincide.
    DuplicatePoint(u64),
    /// Need at least one point.
    Empty,
}

impl std::fmt::Display for InterpolationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InterpolationError::DuplicatePoint(x) => {
                write!(f, "duplicate interpolation point {x}")
            }
            InterpolationError::Empty => write!(f, "no interpolation points"),
        }
    }
}

impl std::error::Error for InterpolationError {}

/// Evaluate a polynomial given coefficients `[c_0, c_1, ...]` (ascending)
/// at `z` via Horner's rule.
pub fn eval_poly(f: &PrimeField, coeffs: &[u64], z: u64) -> u64 {
    let mut acc = 0u64;
    for &c in coeffs.iter().rev() {
        acc = f.add(f.mul(acc, z), c);
    }
    acc
}

/// Lagrange basis coefficients λ_i for evaluating at `target`, given
/// interpolation points `points`:  L(target) = Σ λ_i · values_i where
/// λ_i = Π_{j≠i} (target − x_j) / (x_i − x_j).
///
/// Uses the product formula with batch inversion: O(n) inversions total.
pub fn lagrange_coeffs(
    f: &PrimeField,
    points: &[u64],
    target: u64,
) -> Result<Vec<u64>, InterpolationError> {
    let n = points.len();
    if n == 0 {
        return Err(InterpolationError::Empty);
    }
    // Detect duplicates (n is small — tens of workers — so O(n^2) is fine
    // and avoids allocating a hash set).
    for i in 0..n {
        for j in i + 1..n {
            if points[i] == points[j] {
                return Err(InterpolationError::DuplicatePoint(points[i]));
            }
        }
    }
    // If target coincides with a point, the basis is an indicator.
    if let Some(k) = points.iter().position(|&x| x == target) {
        let mut out = vec![0u64; n];
        out[k] = 1;
        return Ok(out);
    }
    // full = Π_j (target − x_j)
    let diffs_t: Vec<u64> = points.iter().map(|&x| f.sub(target, x)).collect();
    let mut full = 1u64;
    for &d in &diffs_t {
        full = f.mul(full, d);
    }
    // denom_i = (target − x_i) · Π_{j≠i} (x_i − x_j)
    let mut denoms = Vec::with_capacity(n);
    for i in 0..n {
        let mut d = diffs_t[i];
        for j in 0..n {
            if j != i {
                d = f.mul(d, f.sub(points[i], points[j]));
            }
        }
        denoms.push(d);
    }
    let inv_denoms = f.batch_inv(&denoms);
    Ok(inv_denoms.iter().map(|&inv_d| f.mul(full, inv_d)).collect())
}

/// Evaluate the interpolating polynomial through `(points_i, values_i)` at
/// `target` directly.
pub fn lagrange_basis_at(
    f: &PrimeField,
    points: &[u64],
    values: &[u64],
    target: u64,
) -> Result<u64, InterpolationError> {
    assert_eq!(points.len(), values.len());
    let lam = lagrange_coeffs(f, points, target)?;
    let mut acc = 0u64;
    for (l, v) in lam.iter().zip(values.iter()) {
        acc = f.add(acc, f.mul(*l, *v));
    }
    Ok(acc)
}

/// Full interpolation: recover the coefficient vector (ascending, length n)
/// of the unique degree-< n polynomial through the given points. O(n^2).
///
/// The training loop never needs explicit coefficients (it uses
/// [`lagrange_coeffs`]); this is used by tests and the privacy audit to
/// verify degrees.
pub fn interpolate(
    f: &PrimeField,
    points: &[u64],
    values: &[u64],
) -> Result<Vec<u64>, InterpolationError> {
    assert_eq!(points.len(), values.len());
    let n = points.len();
    if n == 0 {
        return Err(InterpolationError::Empty);
    }
    for i in 0..n {
        for j in i + 1..n {
            if points[i] == points[j] {
                return Err(InterpolationError::DuplicatePoint(points[i]));
            }
        }
    }
    // Newton's divided differences in F_p.
    let mut coef = values.to_vec(); // divided-difference table, in place
    for level in 1..n {
        for i in (level..n).rev() {
            let num = f.sub(coef[i], coef[i - 1]);
            let den = f.sub(points[i], points[i - level]);
            coef[i] = f.mul(num, f.inv(den));
        }
    }
    // Expand Newton form to monomial coefficients.
    let mut out = vec![0u64; n];
    for i in (0..n).rev() {
        // out = out * (z - x_i) + coef[i]
        let mut next = vec![0u64; n];
        for k in (0..n - 1).rev() {
            // shift: next[k+1] += out[k]
            next[k + 1] = f.add(next[k + 1], out[k]);
        }
        for k in 0..n {
            let minus = f.mul(out[k], points[i]);
            next[k] = f.sub(next[k], minus);
        }
        next[0] = f.add(next[0], coef[i]);
        out = next;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::PAPER_PRIME;
    use crate::util::proptest::check;
    use crate::util::Rng;

    fn field() -> PrimeField {
        PrimeField::new(PAPER_PRIME)
    }

    #[test]
    fn eval_poly_horner() {
        let f = field();
        // 3 + 2z + z^2 at z=5 → 3 + 10 + 25 = 38
        assert_eq!(eval_poly(&f, &[3, 2, 1], 5), 38);
        assert_eq!(eval_poly(&f, &[], 5), 0);
        assert_eq!(eval_poly(&f, &[7], 12345), 7);
    }

    #[test]
    fn interpolation_recovers_random_polynomials() {
        let f = field();
        check("interp-roundtrip", 100, move |rng| {
            let deg = rng.below_usize(12);
            let coeffs: Vec<u64> = (0..=deg).map(|_| f.random(rng)).collect();
            let n = deg + 1;
            let points = f.distinct_points(n + rng.below_usize(4));
            let values: Vec<u64> = points.iter().map(|&x| eval_poly(&f, &coeffs, x)).collect();
            // Interpolate from exactly n points.
            let got = interpolate(&f, &points[..n], &values[..n]).unwrap();
            if got != coeffs {
                return Err(format!("coeffs mismatch: {got:?} vs {coeffs:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn lagrange_coeffs_match_direct_eval() {
        let f = field();
        check("lagrange-eval", 100, move |rng| {
            let n = 1 + rng.below_usize(16);
            let coeffs: Vec<u64> = (0..n).map(|_| f.random(rng)).collect();
            let points = f.distinct_points(n);
            let values: Vec<u64> = points.iter().map(|&x| eval_poly(&f, &coeffs, x)).collect();
            let target = f.random(rng);
            let via_basis = lagrange_basis_at(&f, &points, &values, target).unwrap();
            let direct = eval_poly(&f, &coeffs, target);
            if via_basis != direct {
                return Err(format!("{via_basis} != {direct} (n={n}, target={target})"));
            }
            Ok(())
        });
    }

    #[test]
    fn basis_at_interpolation_point_is_indicator() {
        let f = field();
        let points = f.distinct_points(6);
        let lam = lagrange_coeffs(&f, &points, points[3]).unwrap();
        assert_eq!(lam, vec![0, 0, 0, 1, 0, 0]);
    }

    #[test]
    fn basis_sums_to_one() {
        // Σ_i L_i(z) = 1 for any z (interpolating the constant 1).
        let f = field();
        check("basis-partition-of-unity", 50, move |rng| {
            let n = 1 + rng.below_usize(20);
            let points = f.distinct_points(n);
            let target = f.random(rng);
            let lam = lagrange_coeffs(&f, &points, target).unwrap();
            let sum = lam.iter().fold(0u64, |acc, &l| f.add(acc, l));
            if sum != 1 {
                return Err(format!("sum={sum}"));
            }
            Ok(())
        });
    }

    #[test]
    fn duplicate_points_rejected() {
        let f = field();
        let err = lagrange_coeffs(&f, &[1, 2, 2], 5).unwrap_err();
        assert_eq!(err, InterpolationError::DuplicatePoint(2));
        let err = interpolate(&f, &[3, 3], &[1, 2]).unwrap_err();
        assert_eq!(err, InterpolationError::DuplicatePoint(3));
    }

    #[test]
    fn empty_rejected() {
        let f = field();
        assert_eq!(lagrange_coeffs(&f, &[], 5).unwrap_err(), InterpolationError::Empty);
        assert_eq!(interpolate(&f, &[], &[]).unwrap_err(), InterpolationError::Empty);
    }

    #[test]
    fn degree_of_product_polynomial() {
        // Sanity for the recovery-threshold algebra: if u and v have degree
        // K+T-1, then f(u,v) with deg(f)=2r+1 has degree (2r+1)(K+T-1).
        // Emulate with scalar polynomials: h(z) = u(z)^2 · v(z).
        let f = field();
        let mut rng = Rng::new(77);
        let kt = 4; // K+T-1 = 3
        let u: Vec<u64> = (0..kt).map(|_| f.random(&mut rng)).collect();
        let v: Vec<u64> = (0..kt).map(|_| f.random(&mut rng)).collect();
        let deg_h = 3 * (kt - 1);
        let points = f.distinct_points(deg_h + 1);
        let values: Vec<u64> = points
            .iter()
            .map(|&z| {
                let uz = eval_poly(&f, &u, z);
                let vz = eval_poly(&f, &v, z);
                f.mul(f.mul(uz, uz), vz)
            })
            .collect();
        let coeffs = interpolate(&f, &points, &values).unwrap();
        // Highest coefficient index with nonzero value == deg_h (generic).
        let top = coeffs.iter().rposition(|&c| c != 0).unwrap();
        assert_eq!(top, deg_h);
        // And evaluation matches everywhere else.
        for z in 100..110u64 {
            let uz = eval_poly(&f, &u, z);
            let vz = eval_poly(&f, &v, z);
            assert_eq!(eval_poly(&f, &coeffs, z), f.mul(f.mul(uz, uz), vz));
        }
    }
}
