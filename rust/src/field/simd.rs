//! Portable lane-oriented kernels for the field hot loops.
//!
//! Every dense inner loop in the crate — the Lagrange encode/decode
//! combines, the worker matmul chunk folds, the NTT butterflies — bottoms
//! out in one of five primitives defined here. Each primitive has two
//! implementations with identical semantics:
//!
//! * [`lanes`]: u64x4-style unrolled loops. Four independent accumulators
//!   / four independent element streams per iteration give the
//!   autovectorizer straight-line code it can lower to SIMD on any target
//!   (no intrinsics, no `std::simd` — the crate stays stable-Rust and
//!   dependency-free).
//! * [`scalar`]: the plain one-element-at-a-time oracles, compiled
//!   unconditionally so property tests can compare against them.
//!
//! The crate-wide dispatch is `cfg`-gated on the `scalar_kernels` cargo
//! feature (lanes by default; `--features scalar_kernels` forces the
//! oracles everywhere — useful for bisecting a perf regression down to
//! codegen vs algorithm).
//!
//! Bit-exactness: the wrapping accumulators are sums in Z/2^64, which is
//! commutative and associative, so splitting one running sum into four and
//! re-merging cannot change the value. Everything else is exact field
//! arithmetic. The property tests at the bottom pin lanes == scalar for
//! every supported modulus.

use super::prime::PrimeField;

/// Lane width the unrolled kernels target (matches AVX2 u64x4 / NEON 2×2).
pub const LANES: usize = 4;

#[cfg(not(feature = "scalar_kernels"))]
use lanes as imp;
#[cfg(feature = "scalar_kernels")]
use scalar as imp;

/// `acc[i] += c·src[i]` in Z/2^64 (deferred-reduction multiply-accumulate).
/// Caller guarantees `c` and `src` are reduced, so each product is < p²
/// and the *caller's* chunking keeps the sums from wrapping meaningfully.
#[inline]
pub fn mac_wrapping(acc: &mut [u64], src: &[u64], c: u64) {
    imp::mac_wrapping(acc, src, c)
}

/// Fold the deferred accumulators into canonical outputs:
/// `out[i] = out[i] + reduce(acc[i]) mod p; acc[i] = 0`.
#[inline]
pub fn fold_reduce(f: &PrimeField, out: &mut [u64], acc: &mut [u64]) {
    imp::fold_reduce(f, out, acc)
}

/// Wrapping dot product `Σ_i x[i]·w[i]` in Z/2^64 (one chunk of a
/// deferred-reduction dot; caller reduces the result).
#[inline]
pub fn dot_wrapping(x: &[u64], w: &[u64]) -> u64 {
    imp::dot_wrapping(x, w)
}

/// `xs[i] = c·xs[i] mod p` (NTT twist rows, inverse-transform scaling).
#[inline]
pub fn scale_mod(f: &PrimeField, xs: &mut [u64], c: u64) {
    imp::scale_mod(f, xs, c)
}

/// Radix-2 DIT butterfly across two equal-length rows with twiddle `w`:
/// `(a[i], b[i]) ← (a[i] + w·b[i], a[i] − w·b[i]) mod p`.
#[inline]
pub fn butterfly(f: &PrimeField, a: &mut [u64], b: &mut [u64], w: u64) {
    imp::butterfly(f, a, b, w)
}

/// Four-accumulator / four-stream unrolled kernels (the default).
pub mod lanes {
    use super::{PrimeField, LANES};

    #[inline]
    pub fn mac_wrapping(acc: &mut [u64], src: &[u64], c: u64) {
        debug_assert_eq!(acc.len(), src.len());
        let n = acc.len();
        let head = n & !(LANES - 1);
        let (a4, a1) = acc.split_at_mut(head);
        let (s4, s1) = src.split_at(head);
        for (a, s) in a4.chunks_exact_mut(LANES).zip(s4.chunks_exact(LANES)) {
            a[0] = a[0].wrapping_add(c * s[0]);
            a[1] = a[1].wrapping_add(c * s[1]);
            a[2] = a[2].wrapping_add(c * s[2]);
            a[3] = a[3].wrapping_add(c * s[3]);
        }
        for (a, &s) in a1.iter_mut().zip(s1.iter()) {
            *a = a.wrapping_add(c * s);
        }
    }

    #[inline]
    pub fn fold_reduce(f: &PrimeField, out: &mut [u64], acc: &mut [u64]) {
        debug_assert_eq!(out.len(), acc.len());
        let n = out.len();
        let head = n & !(LANES - 1);
        let (o4, o1) = out.split_at_mut(head);
        let (a4, a1) = acc.split_at_mut(head);
        for (o, a) in o4.chunks_exact_mut(LANES).zip(a4.chunks_exact_mut(LANES)) {
            o[0] = f.add(o[0], f.reduce_u64(a[0]));
            o[1] = f.add(o[1], f.reduce_u64(a[1]));
            o[2] = f.add(o[2], f.reduce_u64(a[2]));
            o[3] = f.add(o[3], f.reduce_u64(a[3]));
            a[0] = 0;
            a[1] = 0;
            a[2] = 0;
            a[3] = 0;
        }
        for (o, a) in o1.iter_mut().zip(a1.iter_mut()) {
            *o = f.add(*o, f.reduce_u64(*a));
            *a = 0;
        }
    }

    #[inline]
    pub fn dot_wrapping(x: &[u64], w: &[u64]) -> u64 {
        debug_assert_eq!(x.len(), w.len());
        let n = x.len();
        let head = n & !(LANES - 1);
        let mut a = [0u64; LANES];
        for (xs, ws) in x[..head].chunks_exact(LANES).zip(w[..head].chunks_exact(LANES)) {
            a[0] = a[0].wrapping_add(xs[0] * ws[0]);
            a[1] = a[1].wrapping_add(xs[1] * ws[1]);
            a[2] = a[2].wrapping_add(xs[2] * ws[2]);
            a[3] = a[3].wrapping_add(xs[3] * ws[3]);
        }
        // Z/2^64 addition is associative+commutative: merging the four
        // lanes gives exactly the sequential sum.
        let mut acc = a[0]
            .wrapping_add(a[1])
            .wrapping_add(a[2])
            .wrapping_add(a[3]);
        for (&xv, &wv) in x[head..].iter().zip(w[head..].iter()) {
            acc = acc.wrapping_add(xv * wv);
        }
        acc
    }

    #[inline]
    pub fn scale_mod(f: &PrimeField, xs: &mut [u64], c: u64) {
        let n = xs.len();
        let head = n & !(LANES - 1);
        let (x4, x1) = xs.split_at_mut(head);
        for x in x4.chunks_exact_mut(LANES) {
            x[0] = f.mul(x[0], c);
            x[1] = f.mul(x[1], c);
            x[2] = f.mul(x[2], c);
            x[3] = f.mul(x[3], c);
        }
        for x in x1.iter_mut() {
            *x = f.mul(*x, c);
        }
    }

    #[inline]
    pub fn butterfly(f: &PrimeField, a: &mut [u64], b: &mut [u64], w: u64) {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let head = n & !(LANES - 1);
        let (a4, a1) = a.split_at_mut(head);
        let (b4, b1) = b.split_at_mut(head);
        for (av, bv) in a4.chunks_exact_mut(LANES).zip(b4.chunks_exact_mut(LANES)) {
            let t0 = f.mul(w, bv[0]);
            let t1 = f.mul(w, bv[1]);
            let t2 = f.mul(w, bv[2]);
            let t3 = f.mul(w, bv[3]);
            bv[0] = f.sub(av[0], t0);
            bv[1] = f.sub(av[1], t1);
            bv[2] = f.sub(av[2], t2);
            bv[3] = f.sub(av[3], t3);
            av[0] = f.add(av[0], t0);
            av[1] = f.add(av[1], t1);
            av[2] = f.add(av[2], t2);
            av[3] = f.add(av[3], t3);
        }
        for (av, bv) in a1.iter_mut().zip(b1.iter_mut()) {
            let t = f.mul(w, *bv);
            *bv = f.sub(*av, t);
            *av = f.add(*av, t);
        }
    }
}

/// One-element-at-a-time oracles (always compiled; the property tests pin
/// [`lanes`] against these, and `--features scalar_kernels` swaps them in
/// crate-wide).
pub mod scalar {
    use super::PrimeField;

    #[inline]
    pub fn mac_wrapping(acc: &mut [u64], src: &[u64], c: u64) {
        debug_assert_eq!(acc.len(), src.len());
        for (a, &s) in acc.iter_mut().zip(src.iter()) {
            *a = a.wrapping_add(c * s);
        }
    }

    #[inline]
    pub fn fold_reduce(f: &PrimeField, out: &mut [u64], acc: &mut [u64]) {
        debug_assert_eq!(out.len(), acc.len());
        for (o, a) in out.iter_mut().zip(acc.iter_mut()) {
            *o = f.add(*o, f.reduce_u64(*a));
            *a = 0;
        }
    }

    #[inline]
    pub fn dot_wrapping(x: &[u64], w: &[u64]) -> u64 {
        debug_assert_eq!(x.len(), w.len());
        let mut acc = 0u64;
        for (&xv, &wv) in x.iter().zip(w.iter()) {
            acc = acc.wrapping_add(xv * wv);
        }
        acc
    }

    #[inline]
    pub fn scale_mod(f: &PrimeField, xs: &mut [u64], c: u64) {
        for x in xs.iter_mut() {
            *x = f.mul(*x, c);
        }
    }

    #[inline]
    pub fn butterfly(f: &PrimeField, a: &mut [u64], b: &mut [u64], w: u64) {
        debug_assert_eq!(a.len(), b.len());
        for (av, bv) in a.iter_mut().zip(b.iter_mut()) {
            let t = f.mul(w, *bv);
            *bv = f.sub(*av, t);
            *av = f.add(*av, t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{PAPER_PRIME, PRIME_26, PRIME_31, PRIME_NTT_25, PRIME_NTT_28};
    use crate::util::proptest::check;
    use crate::util::Rng;

    const MODULI: &[u64] =
        &[3, 5, 97, PAPER_PRIME, PRIME_NTT_25, PRIME_26, PRIME_NTT_28, PRIME_31];

    fn rand_vec(f: &PrimeField, rng: &mut Rng, n: usize) -> Vec<u64> {
        (0..n).map(|_| f.random(rng)).collect()
    }

    #[test]
    fn lanes_match_scalar_all_moduli() {
        // All five kernels, every supported modulus, lengths that cross the
        // 4-lane boundary in every residue class (0..=9 covers tails 0..3).
        for &p in MODULI {
            let f = PrimeField::new(p);
            check(&format!("simd-lanes-{p}"), 25, move |rng| {
                let n = rng.below_usize(10) + rng.below_usize(30);
                let c = f.random(rng);
                let w = f.random(rng);
                let src = rand_vec(&f, rng, n);
                let ws = rand_vec(&f, rng, n);
                let acc0 = rand_vec(&f, rng, n);
                let out0 = rand_vec(&f, rng, n);

                let (mut a1, mut a2) = (acc0.clone(), acc0.clone());
                lanes::mac_wrapping(&mut a1, &src, c);
                scalar::mac_wrapping(&mut a2, &src, c);
                if a1 != a2 {
                    return Err(format!("mac_wrapping p={p} n={n}"));
                }

                let (mut o1, mut o2) = (out0.clone(), out0.clone());
                let (mut f1, mut f2) = (a1.clone(), a1.clone());
                lanes::fold_reduce(&f, &mut o1, &mut f1);
                scalar::fold_reduce(&f, &mut o2, &mut f2);
                if o1 != o2 || f1 != f2 || f1.iter().any(|&v| v != 0) {
                    return Err(format!("fold_reduce p={p} n={n}"));
                }

                if lanes::dot_wrapping(&src, &ws) != scalar::dot_wrapping(&src, &ws) {
                    return Err(format!("dot_wrapping p={p} n={n}"));
                }

                let (mut s1, mut s2) = (src.clone(), src.clone());
                lanes::scale_mod(&f, &mut s1, c);
                scalar::scale_mod(&f, &mut s2, c);
                if s1 != s2 {
                    return Err(format!("scale_mod p={p} n={n}"));
                }

                let (mut ba1, mut bb1) = (src.clone(), ws.clone());
                let (mut ba2, mut bb2) = (src.clone(), ws.clone());
                lanes::butterfly(&f, &mut ba1, &mut bb1, w);
                scalar::butterfly(&f, &mut ba2, &mut bb2, w);
                if ba1 != ba2 || bb1 != bb2 {
                    return Err(format!("butterfly p={p} n={n}"));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn dispatch_matches_scalar() {
        // Whatever the feature flags selected, the public entry points must
        // agree with the scalar oracles.
        let f = PrimeField::new(PRIME_NTT_25);
        let mut rng = Rng::new(7);
        let x = rand_vec(&f, &mut rng, 23);
        let w = rand_vec(&f, &mut rng, 23);
        assert_eq!(dot_wrapping(&x, &w), scalar::dot_wrapping(&x, &w));
        let (mut a, mut b) = (x.clone(), w.clone());
        let (mut a2, mut b2) = (x.clone(), w.clone());
        butterfly(&f, &mut a, &mut b, 12345);
        scalar::butterfly(&f, &mut a2, &mut b2, 12345);
        assert_eq!((a, b), (a2, b2));
    }

    #[test]
    fn mac_then_fold_is_exact_linear_combination() {
        // MAC + fold over one safe chunk equals the mod-p linear
        // combination computed in u128 — the contract the encoder/decoder
        // combines rely on.
        for &p in &[PAPER_PRIME, PRIME_NTT_25, PRIME_31] {
            let f = PrimeField::new(p);
            let chunk = crate::compute::safe_chunk_len(p);
            let mut rng = Rng::new(p ^ 0xA5);
            let n = 17;
            let terms = chunk.min(64);
            let mut acc = vec![0u64; n];
            let mut out = vec![0u64; n];
            let mut want = vec![0u128; n];
            for _ in 0..terms {
                let c = f.random(&mut rng);
                let src = rand_vec(&f, &mut rng, n);
                mac_wrapping(&mut acc, &src, c);
                for (wv, &s) in want.iter_mut().zip(src.iter()) {
                    *wv += c as u128 * s as u128;
                }
            }
            fold_reduce(&f, &mut out, &mut acc);
            let want: Vec<u64> = want.iter().map(|&v| (v % p as u128) as u64).collect();
            assert_eq!(out, want, "p={p}");
        }
    }
}
