//! F_p scalar arithmetic.
//!
//! Elements are `u64` in `[0, p)`. The modulus is a runtime value (one
//! training session may use the paper's 24-bit prime while a headroom
//! experiment uses a 31-bit one), so `PrimeField` is a small copyable
//! context passed where needed rather than a const generic.
//!
//! # Barrett reduction
//!
//! Every reduction goes through a precomputed Barrett context instead of a
//! hardware divide: with `μ = ⌊2^64 / p⌋` computed once in [`PrimeField::new`],
//! `x mod p` for any `u64` x is
//!
//! ```text
//!   q = (x·μ) >> 64        (one 64×64→128 multiply, keep the high half)
//!   r = x − q·p            (r ∈ [0, 2p) — see proof below)
//!   if r ≥ p { r −= p }
//! ```
//!
//! Writing `2^64 = μ·p + ρ` with `0 ≤ ρ < p`, we get
//! `x·μ/2^64 = x/p − x·ρ/(p·2^64)` and the subtracted term is `< 1` for all
//! `x < 2^64`, so `⌊x/p⌋ − 1 ≤ q ≤ ⌊x/p⌋` and a single conditional subtract
//! finishes the job. One mul-high + one mul + one subtract replaces the
//! 20–40 cycle `div` the old `%` emitted — this is the inner loop of every
//! encode/compute/decode path, so it matters (see `rust/benches/field_ops.rs`
//! for the measured before/after).

use crate::util::Rng;

/// Arithmetic context for the prime field F_p with a precomputed Barrett
/// constant. Cheap to copy (three words) — pass it by value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrimeField {
    p: u64,
    /// Barrett constant ⌊2^64 / p⌋.
    mu: u64,
    /// 2^64 mod p — folds the high half of a u128 into the low in
    /// [`PrimeField::reduce_u128`].
    r64: u64,
}

impl PrimeField {
    /// Largest modulus (in bits) for which the XLA int64 path may skip
    /// intermediate reductions: products are < 2^(2·bits) and we accumulate
    /// up to 2048 of them, so 2·bits + 11 ≤ 63 → bits ≤ 26.
    pub const MAX_XLA_BITS: u32 = 26;

    /// Create a field context. `p` must be an odd prime > 2; this is
    /// checked (trial division — our moduli are ≤ 31 bits so this is cheap
    /// and only runs at configuration time).
    pub fn new(p: u64) -> Self {
        assert!(p > 2 && is_prime(p), "modulus {p} is not an odd prime");
        assert!(p < (1 << 31), "modulus {p} too large (max 31 bits)");
        // Barrett context: μ = ⌊2^64/p⌋ (fits u64 for p ≥ 3) and
        // ρ = 2^64 mod p = 2^64 − μ·p.
        let mu = ((1u128 << 64) / p as u128) as u64;
        let r64 = ((1u128 << 64) - mu as u128 * p as u128) as u64;
        debug_assert!((r64 as u128) < p as u128);
        PrimeField { p, mu, r64 }
    }

    #[inline(always)]
    // lint: allow(canonical-field-debug-asserts): returns the modulus itself, not a field element
    pub fn modulus(&self) -> u64 {
        self.p
    }

    /// Number of bits in the modulus.
    pub fn bits(&self) -> u32 {
        64 - self.p.leading_zeros()
    }

    /// True if the i64 XLA dot-product path is safe for `dot_len`-element
    /// dots without intermediate reduction.
    pub fn check_dot_safe(&self, dot_len: usize) -> bool {
        // sum of dot_len products each < p^2 must stay below 2^63.
        let p2 = (self.p as u128) * (self.p as u128);
        p2.checked_mul(dot_len as u128)
            .map(|v| v < (1u128 << 63))
            .unwrap_or(false)
    }

    /// Barrett-reduce any `u64` into `[0, p)`: mul-high + multiply +
    /// at most one conditional subtract — no hardware division.
    #[inline(always)]
    pub fn reduce_u64(&self, x: u64) -> u64 {
        let q = ((x as u128 * self.mu as u128) >> 64) as u64;
        // q ≤ ⌊x/p⌋, so q·p ≤ x (no underflow) and r < 2p (see module docs).
        let r = x - q.wrapping_mul(self.p);
        let out = if r >= self.p { r - self.p } else { r };
        debug_assert!(out < self.p);
        out
    }

    /// Reduce a `u128` into `[0, p)`. The common case (value < 2^64, e.g.
    /// any product of two reduced elements) is a single Barrett pass; wider
    /// values fold the high half through `2^64 mod p` first.
    #[inline(always)]
    pub fn reduce_u128(&self, x: u128) -> u64 {
        let out = if x < (1u128 << 64) {
            self.reduce_u64(x as u64)
        } else {
            let hi = self.reduce_u64((x >> 64) as u64);
            let lo = self.reduce_u64(x as u64);
            // x ≡ hi·(2^64 mod p) + lo; hi·r64 < p² < 2^62 fits u64.
            self.add(self.reduce_u64(hi * self.r64), lo)
        };
        debug_assert!(out < self.p);
        out
    }

    /// Division-based `u64` reduction — the pre-Barrett path, kept as the
    /// correctness oracle for property tests and the baseline for
    /// `rust/benches/field_ops.rs`.
    #[inline(always)]
    pub fn reduce_u64_divrem(&self, x: u64) -> u64 {
        let out = x % self.p; // lint: allow(no-hardware-modulo): division-based oracle the Barrett path is tested against
        debug_assert!(out < self.p);
        out
    }

    /// Division-based multiply (baseline twin of [`PrimeField::mul`]).
    #[inline(always)]
    pub fn mul_divrem(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.p && b < self.p);
        let out = (a * b) % self.p; // lint: allow(no-hardware-modulo): division-based oracle the Barrett path is tested against
        debug_assert!(out < self.p);
        out
    }

    /// Reduce a signed integer into `[0, p)` (two's-complement embedding φ).
    #[inline(always)]
    pub fn from_i64(&self, x: i64) -> u64 {
        let out = x.rem_euclid(self.p as i64) as u64;
        debug_assert!(out < self.p);
        out
    }

    /// Map back to a signed representative in `(-(p-1)/2, (p-1)/2]` (φ⁻¹).
    #[inline(always)]
    pub fn to_i64(&self, x: u64) -> i64 {
        debug_assert!(x < self.p);
        if x <= (self.p - 1) / 2 {
            x as i64
        } else {
            x as i64 - self.p as i64
        }
    }

    #[inline(always)]
    pub fn add(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.p && b < self.p);
        let s = a + b;
        let out = if s >= self.p { s - self.p } else { s };
        debug_assert!(out < self.p);
        out
    }

    #[inline(always)]
    pub fn sub(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.p && b < self.p);
        let out = if a >= b { a - b } else { a + self.p - b };
        debug_assert!(out < self.p);
        out
    }

    #[inline(always)]
    pub fn neg(&self, a: u64) -> u64 {
        debug_assert!(a < self.p);
        let out = if a == 0 { 0 } else { self.p - a };
        debug_assert!(out < self.p);
        out
    }

    #[inline(always)]
    pub fn mul(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.p && b < self.p);
        // p < 2^31 so the product fits in u64 without u128; Barrett-reduce.
        let out = self.reduce_u64(a * b);
        debug_assert!(out < self.p);
        out
    }

    /// Modular exponentiation (square-and-multiply).
    pub fn pow(&self, mut base: u64, mut exp: u64) -> u64 {
        debug_assert!(base < self.p);
        let mut acc = 1u64;
        while exp > 0 {
            if exp & 1 == 1 {
                acc = self.mul(acc, base);
            }
            base = self.mul(base, base);
            exp >>= 1;
        }
        debug_assert!(acc < self.p);
        acc
    }

    /// Multiplicative inverse via Fermat's little theorem. Panics on 0.
    #[inline]
    pub fn inv(&self, a: u64) -> u64 {
        assert!(a != 0, "division by zero in F_{}", self.p);
        let out = self.pow(a, self.p - 2);
        debug_assert!(out < self.p);
        out
    }

    /// Batch inversion (Montgomery's trick): one `inv` + 3(n-1) muls.
    /// All inputs must be nonzero.
    pub fn batch_inv(&self, xs: &[u64]) -> Vec<u64> {
        if xs.is_empty() {
            return Vec::new();
        }
        let n = xs.len();
        let mut prefix = vec![0u64; n];
        let mut acc = 1u64;
        for (i, &x) in xs.iter().enumerate() {
            assert!(x != 0, "batch_inv: zero at index {i}");
            prefix[i] = acc;
            acc = self.mul(acc, x);
        }
        let mut inv_acc = self.inv(acc);
        let mut out = vec![0u64; n];
        for i in (0..n).rev() {
            out[i] = self.mul(inv_acc, prefix[i]);
            inv_acc = self.mul(inv_acc, xs[i]);
        }
        out
    }

    /// Uniformly random field element.
    #[inline]
    pub fn random(&self, rng: &mut Rng) -> u64 {
        let out = rng.field_element(self.p);
        debug_assert!(out < self.p);
        out
    }

    /// Uniformly random matrix (row-major `rows × cols`).
    pub fn random_matrix(&self, rng: &mut Rng, rows: usize, cols: usize) -> Vec<u64> {
        (0..rows * cols).map(|_| self.random(rng)).collect()
    }

    /// `count` distinct evaluation points. CodedPrivateML needs K+T betas
    /// plus N alphas, all distinct; we simply use 1..=count (p is vastly
    /// larger than any N+K+T we run).
    pub fn distinct_points(&self, count: usize) -> Vec<u64> {
        assert!((count as u64) < self.p, "not enough field elements");
        (1..=count as u64).collect()
    }
}

/// Deterministic Miller–Rabin for u64 (valid for all 64-bit inputs with
/// this witness set).
pub fn is_prime(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    for &sp in &[2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        if n == sp {
            return true;
        }
        if n % sp == 0 { // lint: allow(no-hardware-modulo): primality trial division, config-time only
            return false;
        }
    }
    let mut d = n - 1;
    let mut s = 0u32;
    while d % 2 == 0 { // lint: allow(no-hardware-modulo): Miller-Rabin setup, config-time only
        d /= 2;
        s += 1;
    }
    'witness: for &a in &[2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        // lint: allow(no-hardware-modulo): Miller-Rabin witness arithmetic, config-time only
        let mut x = pow_mod(a % n, d, n);
        if x == 1 || x == n - 1 {
            continue;
        }
        for _ in 0..s - 1 {
            x = mul_mod(x, x, n);
            if x == n - 1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

fn mul_mod(a: u64, b: u64, m: u64) -> u64 {
    // lint: allow(no-hardware-modulo): Miller-Rabin witness arithmetic, config-time only
    ((a as u128 * b as u128) % m as u128) as u64
}

fn pow_mod(mut b: u64, mut e: u64, m: u64) -> u64 {
    let mut acc = 1u64;
    b %= m; // lint: allow(no-hardware-modulo): Miller-Rabin witness arithmetic, config-time only
    while e > 0 {
        if e & 1 == 1 {
            acc = mul_mod(acc, b, m);
        }
        b = mul_mod(b, b, m);
        e >>= 1;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{PAPER_PRIME, PRIME_26, PRIME_31, PRIME_NTT_25, PRIME_NTT_28};
    use crate::util::proptest::check;

    #[test]
    fn named_primes_are_prime() {
        assert!(is_prime(PAPER_PRIME));
        assert!(is_prime(PRIME_26));
        assert!(is_prime(PRIME_31));
        // NTT-friendly moduli: prime, and of the claimed c·2^e + 1 shape.
        assert!(is_prime(PRIME_NTT_25));
        assert_eq!(PRIME_NTT_25, 11 * (1 << 21) + 1);
        assert_eq!(PrimeField::new(PRIME_NTT_25).bits(), 25);
        assert!(is_prime(PRIME_NTT_28));
        assert_eq!(PRIME_NTT_28, 5 * (1 << 25) + 1);
        assert_eq!(PrimeField::new(PRIME_NTT_28).bits(), 28);
        // Bit widths are what the overflow analysis assumes. (The paper
        // calls 15485863 "the largest prime with 24 bits", which is
        // actually the 1,000,000th prime — e.g. 15485867 is a larger
        // 24-bit prime — but we keep the paper's value for fidelity.)
        assert_eq!(PrimeField::new(PAPER_PRIME).bits(), 24);
        assert_eq!(PrimeField::new(PRIME_26).bits(), 26);
        assert!(is_prime(15_485_867), "the paper's maximality claim is wrong");
        // PRIME_26 *is* maximal below 2^26.
        for q in PRIME_26 + 1..1u64 << 26 {
            assert!(!is_prime(q), "{q} is a larger 26-bit prime");
        }
    }

    #[test]
    #[should_panic(expected = "not an odd prime")]
    fn rejects_composite_modulus() {
        PrimeField::new(15_485_862);
    }

    #[test]
    fn dot_safety_boundaries() {
        let f24 = PrimeField::new(PAPER_PRIME);
        let f26 = PrimeField::new(PRIME_26);
        let f31 = PrimeField::new(PRIME_31);
        assert!(f24.check_dot_safe(2048));
        assert!(f26.check_dot_safe(2048));
        assert!(!f31.check_dot_safe(2048));
        assert!(f31.check_dot_safe(1));
    }

    #[test]
    fn phi_round_trip() {
        let f = PrimeField::new(PAPER_PRIME);
        for x in [-1000i64, -1, 0, 1, 42, 7_000_000, -7_000_000] {
            assert_eq!(f.to_i64(f.from_i64(x)), x, "x={x}");
        }
    }

    #[test]
    fn field_axioms_property() {
        let f = PrimeField::new(PAPER_PRIME);
        check("field-axioms", 500, move |rng| {
            let a = f.random(rng);
            let b = f.random(rng);
            let c = f.random(rng);
            // commutativity
            if f.add(a, b) != f.add(b, a) {
                return Err("add not commutative".into());
            }
            if f.mul(a, b) != f.mul(b, a) {
                return Err("mul not commutative".into());
            }
            // associativity
            if f.add(f.add(a, b), c) != f.add(a, f.add(b, c)) {
                return Err("add not associative".into());
            }
            if f.mul(f.mul(a, b), c) != f.mul(a, f.mul(b, c)) {
                return Err("mul not associative".into());
            }
            // distributivity
            if f.mul(a, f.add(b, c)) != f.add(f.mul(a, b), f.mul(a, c)) {
                return Err("not distributive".into());
            }
            // inverses
            if f.add(a, f.neg(a)) != 0 {
                return Err("additive inverse broken".into());
            }
            if a != 0 && f.mul(a, f.inv(a)) != 1 {
                return Err("multiplicative inverse broken".into());
            }
            // sub consistency
            if f.sub(a, b) != f.add(a, f.neg(b)) {
                return Err("sub != add(neg)".into());
            }
            Ok(())
        });
    }

    /// The acceptance gate for the Barrett core: over every supported
    /// modulus, the mul-high path is bit-exact with the division path for
    /// random operands, the full u64/u128 reduction range, and the edge
    /// values around 0, p, 2p, and the type maxima.
    #[test]
    fn barrett_matches_division_all_moduli() {
        for &p in &[3u64, 5, 97, PAPER_PRIME, PRIME_NTT_25, PRIME_26, PRIME_NTT_28, PRIME_31] {
            let f = PrimeField::new(p);
            // Deterministic edge cases first.
            let edges = [
                0u64,
                1,
                p - 1,
                p,
                p + 1,
                2 * p - 1,
                2 * p,
                (p - 1) * (p - 1),
                u64::MAX,
                u64::MAX - 1,
            ];
            for &x in &edges {
                assert_eq!(f.reduce_u64(x), f.reduce_u64_divrem(x), "p={p} x={x}");
            }
            for &x in &[0u128, 1 << 64, u128::MAX, (u64::MAX as u128) + 1] {
                assert_eq!(f.reduce_u128(x), (x % p as u128) as u64, "p={p} x={x}");
            }
            // Randomized sweep.
            check(&format!("barrett-vs-div-{p}"), 500, move |rng| {
                let x = rng.next_u64();
                if f.reduce_u64(x) != f.reduce_u64_divrem(x) {
                    return Err(format!("reduce_u64({x}) mismatch"));
                }
                let wide = (rng.next_u64() as u128) << 64 | rng.next_u64() as u128;
                if f.reduce_u128(wide) != (wide % p as u128) as u64 {
                    return Err(format!("reduce_u128({wide}) mismatch"));
                }
                let (a, b) = (f.random(rng), f.random(rng));
                if f.mul(a, b) != f.mul_divrem(a, b) {
                    return Err(format!("mul({a},{b}) mismatch"));
                }
                Ok(())
            });
        }
    }

    /// Runtime twin of the `canonical-field-debug-asserts` lint rule:
    /// every field-op output is canonical (`< p`) for every supported
    /// modulus, across random operands and the full reduction range.
    #[test]
    fn all_ops_output_canonical() {
        for &p in &[3u64, 5, 97, PAPER_PRIME, PRIME_NTT_25, PRIME_26, PRIME_NTT_28, PRIME_31] {
            let f = PrimeField::new(p);
            check(&format!("canonical-outputs-{p}"), 300, move |rng| {
                let a = f.random(rng);
                let b = f.random(rng);
                let outputs = [
                    ("random", a),
                    ("add", f.add(a, b)),
                    ("sub", f.sub(a, b)),
                    ("neg", f.neg(a)),
                    ("mul", f.mul(a, b)),
                    ("pow", f.pow(a, rng.next_u64() & 0xffff)),
                    ("reduce_u64", f.reduce_u64(rng.next_u64())),
                    ("reduce_u64_divrem", f.reduce_u64_divrem(rng.next_u64())),
                    ("mul_divrem", f.mul_divrem(a, b)),
                    (
                        "reduce_u128",
                        f.reduce_u128((rng.next_u64() as u128) << 64 | rng.next_u64() as u128),
                    ),
                    ("from_i64", f.from_i64(rng.next_u64() as i64)),
                    ("inv", if a == 0 { 0 } else { f.inv(a) }),
                ];
                for (name, out) in outputs {
                    if out >= p {
                        return Err(format!("{name} output {out} not canonical for p={p}"));
                    }
                }
                Ok(())
            });
        }
    }

    #[test]
    fn barrett_constants_satisfy_invariants() {
        for &p in &[3u64, 97, PAPER_PRIME, PRIME_NTT_25, PRIME_26, PRIME_NTT_28, PRIME_31] {
            let f = PrimeField::new(p);
            // 2^64 = μ·p + ρ with ρ < p, reconstructed exactly.
            let mu = ((1u128 << 64) / p as u128) as u64;
            let rho = ((1u128 << 64) - mu as u128 * p as u128) as u64;
            assert!(rho < p, "p={p}");
            assert_eq!(f.reduce_u64(u64::MAX), u64::MAX % p);
            assert_eq!(f.reduce_u128(1u128 << 64), rho % p);
        }
    }

    #[test]
    fn pow_matches_repeated_mul() {
        let f = PrimeField::new(97);
        for base in 0..97u64 {
            let mut acc = 1u64;
            for e in 0..10u64 {
                assert_eq!(f.pow(base, e), acc);
                acc = f.mul(acc, base);
            }
        }
    }

    #[test]
    fn fermat_little_theorem() {
        let f = PrimeField::new(PAPER_PRIME);
        let mut rng = Rng::new(5);
        for _ in 0..20 {
            let a = 1 + rng.below(f.modulus() - 1);
            assert_eq!(f.pow(a, f.modulus() - 1), 1);
        }
    }

    #[test]
    fn batch_inv_matches_single() {
        let f = PrimeField::new(PAPER_PRIME);
        check("batch-inv", 50, move |rng| {
            let n = 1 + rng.below_usize(64);
            let xs: Vec<u64> = (0..n).map(|_| 1 + rng.below(f.modulus() - 1)).collect();
            let batch = f.batch_inv(&xs);
            for (i, (&x, &bx)) in xs.iter().zip(batch.iter()).enumerate() {
                if f.inv(x) != bx {
                    return Err(format!("mismatch at {i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn batch_inv_empty_ok() {
        let f = PrimeField::new(97);
        assert!(f.batch_inv(&[]).is_empty());
    }

    #[test]
    #[should_panic(expected = "zero at index")]
    fn batch_inv_rejects_zero() {
        let f = PrimeField::new(97);
        f.batch_inv(&[3, 0, 5]);
    }

    #[test]
    fn distinct_points_are_distinct_nonzero() {
        let f = PrimeField::new(97);
        let pts = f.distinct_points(40);
        assert_eq!(pts.len(), 40);
        let mut sorted = pts.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40);
        assert!(pts.iter().all(|&x| x != 0 && x < 97));
    }

    #[test]
    fn random_matrix_shape_and_range() {
        let f = PrimeField::new(PAPER_PRIME);
        let mut rng = Rng::new(9);
        let m = f.random_matrix(&mut rng, 7, 11);
        assert_eq!(m.len(), 77);
        assert!(m.iter().all(|&x| x < f.modulus()));
    }
}
