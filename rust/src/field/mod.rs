//! Prime-field arithmetic and polynomial algebra over F_p.
//!
//! Everything CodedPrivateML computes on the workers lives in F_p for a
//! prime `p` small enough that products of two elements fit in an i64 dot
//! product without intermediate reduction (see `PrimeField::MAX_XLA_BITS`).
//! The paper's default is p = 15485863, the largest 24-bit prime.

pub mod ntt;
mod poly;
mod prime;
pub mod simd;

pub use ntt::NttPlan;
pub use poly::{
    eval_poly, interpolate, lagrange_basis_at, lagrange_coeffs, InterpolationError,
};
pub use prime::PrimeField;

/// The paper's field: largest prime below 2^24 used in its 64-bit
/// implementation (§5, "CodedPrivateML parameters").
pub const PAPER_PRIME: u64 = 15_485_863;

/// NTT-friendly 25-bit prime `11·2^21 + 1`: nearly the paper prime's
/// dynamic range and overflow budget, but with 2-adicity 21 the coding
/// layer can place evaluation points on roots-of-unity cosets and run
/// quasi-linear encode/decode (see [`ntt`] and `coding::EvalPoints`).
pub const PRIME_NTT_25: u64 = 23_068_673;

/// A larger 26-bit prime giving ~4x more dynamic range at decode while still
/// safe for i64 accumulation over ≤ 2048-column dot products (see
/// `PrimeField::check_dot_safe`). Used by the d=1568 paper-scale configs.
pub const PRIME_26: u64 = 67_108_859;

/// NTT-friendly 28-bit prime `5·2^25 + 1` (2-adicity 25): the headroom
/// choice when both a bigger overflow budget and fast transforms are
/// wanted.
pub const PRIME_NTT_28: u64 = 167_772_161;

/// 31-bit prime for native-backend headroom experiments (not XLA-safe for
/// long dots; `check_dot_safe` enforces the limit).
pub const PRIME_31: u64 = 2_147_483_647;
