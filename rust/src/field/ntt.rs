//! Radix-2 number-theoretic transform over F_p.
//!
//! For an NTT-friendly modulus — `p = c·2^e + 1` with `e` large enough —
//! the multiplicative group contains a 2^e-element subgroup of roots of
//! unity, so evaluating a polynomial on a power-of-two subgroup (or a coset
//! of one) is an O(L log L) butterfly network instead of an O(L²) dense
//! pass. The coding layer uses this to make Lagrange encode/decode
//! quasi-linear (see [`crate::coding::EvalPoints::ntt_coset`]); moduli
//! whose 2-adicity is too small (the paper's 24-bit prime has
//! `p − 1 = 2·7742931`) simply never get a plan and fall back to the dense
//! path.
//!
//! The transforms are row-oriented (structure-of-arrays): one [`NttPlan`]
//! transforms `n` *rows* of `width` elements at a time, so the butterflies
//! run over contiguous strips and vectorize ([`super::simd::butterfly`]).
//! All arithmetic is exact canonical field arithmetic — a transform
//! followed by its inverse is the identity bit-for-bit, and evaluation
//! results agree exactly with the dense Lagrange/Horner oracles.

use super::prime::PrimeField;
use super::simd;

/// The 2-adicity of `p − 1`: the largest `e` with `2^e | p − 1`, i.e. the
/// largest power-of-two transform length the field supports.
pub fn two_adicity(p: u64) -> u32 {
    (p - 1).trailing_zeros()
}

/// Distinct prime factors of `m` by trial division (config-time only:
/// `m < 2^31`, so at most ~46k divisions once per plan/layout).
fn distinct_prime_factors(mut m: u64) -> Vec<u64> {
    let mut factors = Vec::new();
    let mut d = 2u64;
    while d * d <= m {
        // lint: allow(no-hardware-modulo): config-time factoring of p−1, not a field hot loop
        if m % d == 0 {
            factors.push(d);
            // lint: allow(no-hardware-modulo): config-time factoring of p−1, not a field hot loop
            while m % d == 0 {
                m /= d;
            }
        }
        d += 1;
    }
    if m > 1 {
        factors.push(m);
    }
    factors
}

/// Smallest generator of F_p^× (deterministic, so every component that
/// derives points from it — plans, coset layouts — agrees on the choice).
pub fn generator(f: &PrimeField) -> u64 {
    let p = f.modulus();
    let factors = distinct_prime_factors(p - 1);
    let mut g = 2u64;
    loop {
        assert!(g < p, "no generator found for p={p} (not prime?)");
        if factors.iter().all(|&q| f.pow(g, (p - 1) / q) != 1) {
            return g;
        }
        g += 1;
    }
}

/// A principal `n`-th root of unity (`n` a power of two), if the field has
/// one: `g^((p−1)/n)` for the smallest generator `g`.
pub fn root_of_unity(f: &PrimeField, n: usize) -> Option<u64> {
    if n == 0 || !n.is_power_of_two() {
        return None;
    }
    if n == 1 {
        return Some(1);
    }
    if two_adicity(f.modulus()) < n.trailing_zeros() {
        return None;
    }
    let p = f.modulus();
    Some(f.pow(generator(f), (p - 1) / n as u64))
}

/// A size-`n` radix-2 transform plan: bit-reversal schedule plus per-stage
/// twiddle tables for the forward and inverse directions.
#[derive(Debug, Clone)]
pub struct NttPlan {
    f: PrimeField,
    n: usize,
    root: u64,
    /// `fwd[s][k] = (root^(n/2^(s+1)))^k` — twiddles for the stage whose
    /// butterfly span is `2^(s+1)` rows.
    fwd: Vec<Vec<u64>>,
    inv: Vec<Vec<u64>>,
    inv_n: u64,
}

impl NttPlan {
    /// Plan a size-`n` transform, if the field supports one (`n` a power of
    /// two dividing `p − 1` through the 2-part).
    pub fn new(f: PrimeField, n: usize) -> Option<Self> {
        root_of_unity(&f, n).map(|root| Self::with_root(f, n, root))
    }

    /// Plan around an explicitly chosen `n`-th root (the coding layer picks
    /// roots once per session so β/α layouts and plans stay consistent).
    /// Asserts the root really has order `n`.
    pub fn with_root(f: PrimeField, n: usize, root: u64) -> Self {
        assert!(n >= 1 && n.is_power_of_two(), "NTT size {n} must be a power of two");
        assert_eq!(f.pow(root, n as u64), 1, "root^n must be 1");
        if n > 1 {
            assert_ne!(f.pow(root, n as u64 / 2), 1, "root must have order exactly n");
        }
        let stages = n.trailing_zeros();
        let root_inv = if n == 1 { 1 } else { f.inv(root) };
        let mut fwd = Vec::with_capacity(stages as usize);
        let mut inv = Vec::with_capacity(stages as usize);
        for s in 0..stages {
            let half = 1usize << s;
            let step = (n >> (s + 1)) as u64;
            let w = f.pow(root, step);
            let wi = f.pow(root_inv, step);
            let mut tw = Vec::with_capacity(half);
            let mut ti = Vec::with_capacity(half);
            let (mut cw, mut ci) = (1u64, 1u64);
            for _ in 0..half {
                tw.push(cw);
                ti.push(ci);
                cw = f.mul(cw, w);
                ci = f.mul(ci, wi);
            }
            fwd.push(tw);
            inv.push(ti);
        }
        let inv_n = if n == 1 { 1 } else { f.inv(n as u64) };
        NttPlan { f, n, root, fwd, inv, inv_n }
    }

    /// Transform length.
    pub fn size(&self) -> usize {
        self.n
    }

    /// The principal root this plan evaluates at: output row `i` holds the
    /// input polynomial evaluated at `root^i`.
    pub fn root(&self) -> u64 {
        self.root
    }

    /// Forward transform of `n` rows × `width` columns, in place (`buf`
    /// row-major, rows = polynomial coefficients by ascending degree).
    /// Each column independently becomes its evaluations at `root^i`.
    pub fn forward_rows(&self, buf: &mut [u64], width: usize) {
        self.transform_rows(buf, width, &self.fwd);
    }

    /// Inverse transform (interpolation back to coefficient rows).
    pub fn inverse_rows(&self, buf: &mut [u64], width: usize) {
        self.transform_rows(buf, width, &self.inv);
        if self.n > 1 {
            simd::scale_mod(&self.f, buf, self.inv_n);
        }
    }

    fn transform_rows(&self, buf: &mut [u64], width: usize, stages: &[Vec<u64>]) {
        assert_eq!(buf.len(), self.n * width, "buffer must be n rows × width");
        let n = self.n;
        if width == 0 || n <= 1 {
            return;
        }
        // Bit-reversal row permutation (decimation in time).
        let mut j = 0usize;
        for i in 1..n {
            let mut bit = n >> 1;
            while j & bit != 0 {
                j ^= bit;
                bit >>= 1;
            }
            j |= bit;
            if i < j {
                let (a, b) = two_rows(buf, i, j, width);
                a.swap_with_slice(b);
            }
        }
        // Butterfly stages over whole rows at a time.
        let f = &self.f;
        for tw in stages {
            let half = tw.len();
            let span = half * 2;
            for block in 0..n / span {
                let base = block * span;
                for (k, &w) in tw.iter().enumerate() {
                    let (a, b) = two_rows(buf, base + k, base + k + half, width);
                    simd::butterfly(f, a, b, w);
                }
            }
        }
    }
}

/// Two disjoint mutable row views (`i < j`) of a row-major buffer.
fn two_rows(buf: &mut [u64], i: usize, j: usize, width: usize) -> (&mut [u64], &mut [u64]) {
    debug_assert!(i < j);
    let (lo, hi) = buf.split_at_mut(j * width);
    (&mut lo[i * width..(i + 1) * width], &mut hi[..width])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{eval_poly, PAPER_PRIME, PRIME_NTT_25, PRIME_NTT_28};
    use crate::util::Rng;

    #[test]
    fn adicity_of_supported_moduli() {
        assert_eq!(two_adicity(PAPER_PRIME), 1);
        assert_eq!(two_adicity(crate::field::PRIME_26), 1);
        assert_eq!(two_adicity(crate::field::PRIME_31), 1);
        assert_eq!(two_adicity(97), 5); // 96 = 2^5·3
        assert_eq!(two_adicity(PRIME_NTT_25), 21); // 11·2^21 + 1
        assert_eq!(two_adicity(PRIME_NTT_28), 25); // 5·2^25 + 1
    }

    #[test]
    fn smallest_generators() {
        assert_eq!(generator(&PrimeField::new(97)), 5);
        assert_eq!(generator(&PrimeField::new(PRIME_NTT_25)), 3);
        assert_eq!(generator(&PrimeField::new(PRIME_NTT_28)), 3);
    }

    #[test]
    fn root_orders() {
        for &(p, n) in &[(97u64, 32usize), (PRIME_NTT_25, 1 << 10), (PRIME_NTT_28, 1 << 12)] {
            let f = PrimeField::new(p);
            let w = root_of_unity(&f, n).unwrap();
            assert_eq!(f.pow(w, n as u64), 1);
            assert_ne!(f.pow(w, n as u64 / 2), 1, "order must be exactly n");
        }
        // Low-adicity moduli reject transforms beyond their 2-part.
        assert!(root_of_unity(&PrimeField::new(PAPER_PRIME), 4).is_none());
        assert!(NttPlan::new(PrimeField::new(PAPER_PRIME), 4).is_none());
        assert!(root_of_unity(&PrimeField::new(97), 64).is_none());
        assert!(root_of_unity(&PrimeField::new(97), 12).is_none(), "non power of two");
    }

    #[test]
    fn forward_matches_dense_evaluation() {
        // NTT output row i must equal the per-column polynomial evaluated
        // at root^i — pinned against the Horner oracle, several widths.
        for &p in &[97u64, PRIME_NTT_25, PRIME_NTT_28] {
            let f = PrimeField::new(p);
            for &(n, width) in &[(1usize, 3usize), (2, 1), (8, 3), (16, 5), (32, 1)] {
                if two_adicity(p) < n.trailing_zeros() {
                    continue;
                }
                let plan = NttPlan::new(f, n).unwrap();
                let mut rng = Rng::new((p ^ n as u64) * 31 + width as u64);
                let coeffs = f.random_matrix(&mut rng, n, width);
                let mut buf = coeffs.clone();
                plan.forward_rows(&mut buf, width);
                for col in 0..width {
                    let poly: Vec<u64> = (0..n).map(|r| coeffs[r * width + col]).collect();
                    for i in 0..n {
                        let x = f.pow(plan.root(), i as u64);
                        assert_eq!(
                            buf[i * width + col],
                            eval_poly(&f, &poly, x),
                            "p={p} n={n} width={width} row={i} col={col}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn inverse_round_trips_bitwise() {
        for &p in &[97u64, PRIME_NTT_25] {
            let f = PrimeField::new(p);
            for n in [1usize, 2, 4, 16, 32] {
                if two_adicity(p) < n.trailing_zeros() {
                    continue;
                }
                let plan = NttPlan::new(f, n).unwrap();
                let mut rng = Rng::new(p + n as u64);
                let orig = f.random_matrix(&mut rng, n, 7);
                let mut buf = orig.clone();
                plan.forward_rows(&mut buf, 7);
                plan.inverse_rows(&mut buf, 7);
                assert_eq!(buf, orig, "p={p} n={n}");
            }
        }
    }

    #[test]
    fn rows_transform_equals_column_at_a_time() {
        // The SoA strip transform is just n independent column transforms.
        let f = PrimeField::new(PRIME_NTT_25);
        let plan = NttPlan::new(f, 16).unwrap();
        let mut rng = Rng::new(9);
        let width = 5;
        let data = f.random_matrix(&mut rng, 16, width);
        let mut wide = data.clone();
        plan.forward_rows(&mut wide, width);
        for col in 0..width {
            let mut one: Vec<u64> = (0..16).map(|r| data[r * width + col]).collect();
            plan.forward_rows(&mut one, 1);
            for r in 0..16 {
                assert_eq!(wide[r * width + col], one[r], "col={col} row={r}");
            }
        }
    }

    #[test]
    fn with_root_agrees_with_new() {
        let f = PrimeField::new(PRIME_NTT_25);
        let w = root_of_unity(&f, 64).unwrap();
        let a = NttPlan::new(f, 64).unwrap();
        let b = NttPlan::with_root(f, 64, w);
        let mut rng = Rng::new(4);
        let data = f.random_matrix(&mut rng, 64, 2);
        let (mut x, mut y) = (data.clone(), data);
        a.forward_rows(&mut x, 2);
        b.forward_rows(&mut y, 2);
        assert_eq!(x, y);
    }

    #[test]
    #[should_panic(expected = "order exactly n")]
    fn with_root_rejects_wrong_order() {
        let f = PrimeField::new(97);
        // 1 is a 2nd root of unity of the wrong order.
        NttPlan::with_root(f, 2, 1);
    }
}
