//! Worker compute backend selection.
//!
//! The coordinator ships workers a `Send`-able spec; each worker thread
//! materializes its backend locally (the XLA runtime is intentionally
//! thread-local, see [`super::client`]). Both backends are bit-exact —
//! `rust/tests/backend_equiv.rs` asserts equality on every manifest shape.

use std::path::PathBuf;

use super::client::{XlaLiteral, XlaRuntime, XlaRuntimeError, PJRT_AVAILABLE};
use crate::compute::WorkerComputation;
use crate::field::PrimeField;
use crate::util::par::Parallelism;

/// Which implementation executes f(X̃, W̃) on workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-rust modular kernels (any shape).
    Native,
    /// AOT JAX/Pallas artifact via PJRT (shapes in the manifest).
    Xla,
}

impl std::str::FromStr for BackendKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "native" => Ok(BackendKind::Native),
            "xla" => Ok(BackendKind::Xla),
            other => Err(format!("unknown backend '{other}' (native|xla)")),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        })
    }
}

/// A worker's compute engine. Constructed inside the worker thread.
pub enum WorkerBackend {
    Native(WorkerComputation),
    Xla {
        runtime: Box<XlaRuntime>,
        field: PrimeField,
        rows: usize,
        d: usize,
        coeffs: Vec<u64>,
        /// The data share marshalled once (X̃ is iteration-invariant);
        /// set by [`WorkerBackend::prepare_data`].
        x_literal: std::cell::RefCell<Option<XlaLiteral>>,
    },
}

impl WorkerBackend {
    /// Build a backend for a (rows × d) coded block with the given
    /// field-quantized sigmoid coefficients. `par` bounds the intra-worker
    /// thread count of the native kernels (the XLA runtime manages its own).
    pub fn create(
        kind: BackendKind,
        artifact_dir: &PathBuf,
        field: PrimeField,
        rows: usize,
        d: usize,
        coeffs: Vec<u64>,
        par: Parallelism,
    ) -> Result<Self, XlaRuntimeError> {
        match kind {
            BackendKind::Native => Ok(WorkerBackend::Native(
                WorkerComputation::new(field, rows, d, coeffs).with_parallelism(par),
            )),
            BackendKind::Xla => {
                // Fail fast before touching the artifact dir: no manifest
                // state can make a PJRT-less build execute XLA.
                if !PJRT_AVAILABLE {
                    return Err(super::client::pjrt_unavailable());
                }
                let runtime = Box::new(XlaRuntime::new(artifact_dir)?);
                // Fail fast if the shape is missing from the manifest.
                let r = coeffs.len() - 1;
                runtime
                    .manifest()
                    .find_worker(rows, d, r, field.modulus())
                    .ok_or(XlaRuntimeError::NoArtifact { what: "worker_f", rows, d, r })?;
                Ok(WorkerBackend::Xla {
                    runtime,
                    field,
                    rows,
                    d,
                    coeffs,
                    x_literal: std::cell::RefCell::new(None),
                })
            }
        }
    }

    /// One-time data delivery hook: the XLA backend marshals the share
    /// into a literal here so the per-iteration path only marshals W̃.
    pub fn prepare_data(&self, x: &[u64]) -> Result<(), XlaRuntimeError> {
        if let WorkerBackend::Xla { rows, d, x_literal, .. } = self {
            *x_literal.borrow_mut() = Some(XlaRuntime::matrix_literal(x, *rows, *d)?);
        }
        Ok(())
    }

    /// Evaluate f(X̃, W̃).
    pub fn compute(&self, x: &[u64], w: &[u64]) -> Result<Vec<u64>, XlaRuntimeError> {
        match self {
            WorkerBackend::Native(wc) => Ok(wc.compute(x, w)),
            WorkerBackend::Xla { runtime, field, rows, d, coeffs, x_literal } => {
                if x_literal.borrow().is_none() {
                    self.prepare_data(x)?;
                }
                let lit = x_literal.borrow();
                runtime.worker_f_literal(
                    lit.as_ref().unwrap(),
                    w,
                    coeffs,
                    *rows,
                    *d,
                    field.modulus(),
                )
            }
        }
    }

    pub fn kind(&self) -> BackendKind {
        match self {
            WorkerBackend::Native(_) => BackendKind::Native,
            WorkerBackend::Xla { .. } => BackendKind::Xla,
        }
    }
}

impl std::fmt::Debug for WorkerBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WorkerBackend::Native(_) => write!(f, "WorkerBackend::Native"),
            WorkerBackend::Xla { rows, d, .. } => {
                write!(f, "WorkerBackend::Xla({rows}x{d})")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::PAPER_PRIME;

    #[test]
    fn backend_kind_parses() {
        assert_eq!("native".parse::<BackendKind>().unwrap(), BackendKind::Native);
        assert_eq!("xla".parse::<BackendKind>().unwrap(), BackendKind::Xla);
        assert!("tpu".parse::<BackendKind>().is_err());
    }

    #[test]
    fn native_backend_computes() {
        let f = PrimeField::new(PAPER_PRIME);
        let be = WorkerBackend::create(
            BackendKind::Native,
            &PathBuf::from("/nonexistent"), // unused for native
            f,
            2,
            3,
            vec![1, 2],
            Parallelism::Serial,
        )
        .unwrap();
        assert_eq!(be.kind(), BackendKind::Native);
        let out = be.compute(&[1, 2, 3, 4, 5, 6], &[1, 1, 1]).unwrap();
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn xla_backend_missing_dir_errors() {
        let f = PrimeField::new(PAPER_PRIME);
        let err = WorkerBackend::create(
            BackendKind::Xla,
            &PathBuf::from("/nonexistent"),
            f,
            2,
            3,
            vec![1, 2],
            Parallelism::Serial,
        )
        .unwrap_err();
        if PJRT_AVAILABLE {
            // With PJRT compiled in, the artifact dir is consulted first.
            assert!(matches!(err, XlaRuntimeError::Manifest(_)));
        } else {
            // Without it, no artifact state matters: fail fast and say why.
            assert!(matches!(err, XlaRuntimeError::Xla(_)), "{err}");
        }
    }
}
