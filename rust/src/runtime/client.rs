//! PJRT execution of AOT artifacts.
//!
//! One `XlaRuntime` owns a PJRT CPU client, the parsed manifest, and an
//! executable cache (each `.hlo.txt` is parsed + compiled at most once per
//! process). `XlaRuntime` is deliberately **not** `Send` — the underlying
//! PJRT client is `Rc`-based — so each simulated worker thread that wants
//! the XLA backend constructs its own runtime from a cheap
//! [`super::backend::WorkerBackend`] spec, mirroring how real workers each
//! own their accelerator runtime.
//!
//! The PJRT path is compiled only with the `pjrt` cargo feature, which
//! requires a vendored `xla` crate (this offline build has none). Without
//! the feature, [`XlaRuntime`] still loads and indexes artifact manifests
//! (so `codedml artifacts` works), but every execute path returns
//! [`XlaRuntimeError::Xla`] and [`PJRT_AVAILABLE`] is `false`; the
//! [`super::backend::WorkerBackend`] uses that constant to fail fast at
//! worker spawn instead of mid-training.

use std::path::{Path, PathBuf};

use super::manifest::{Manifest, ManifestError};

/// Whether this build carries the PJRT execution path (`pjrt` feature).
pub const PJRT_AVAILABLE: bool = cfg!(feature = "pjrt");

/// The one shared "not compiled in" error (stub execute paths and the
/// backend's fail-fast check both return it).
pub(crate) fn pjrt_unavailable() -> XlaRuntimeError {
    XlaRuntimeError::Xla(
        "PJRT execution not compiled into this build (enable the `pjrt` \
         feature with a vendored `xla` crate); use --backend native"
            .into(),
    )
}

#[derive(Debug)]
pub enum XlaRuntimeError {
    Manifest(ManifestError),
    /// No artifact for the requested shape.
    NoArtifact { what: &'static str, rows: usize, d: usize, r: usize },
    /// Error from the PJRT layer (client, compile, execute) — or, in a
    /// build without the `pjrt` feature, "not compiled in".
    Xla(String),
    /// Result had an unexpected shape or type.
    BadResult(String),
}

impl std::fmt::Display for XlaRuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            XlaRuntimeError::Manifest(e) => write!(f, "{e}"),
            XlaRuntimeError::NoArtifact { what, rows, d, r } => write!(
                f,
                "no {what} artifact for rows={rows} d={d} r={r}; \
                 add the shape to python/compile/shapes.py and re-run `make artifacts`, \
                 or use the native backend"
            ),
            XlaRuntimeError::Xla(e) => write!(f, "xla: {e}"),
            XlaRuntimeError::BadResult(e) => write!(f, "bad result: {e}"),
        }
    }
}

impl std::error::Error for XlaRuntimeError {}

impl From<ManifestError> for XlaRuntimeError {
    fn from(e: ManifestError) -> Self {
        XlaRuntimeError::Manifest(e)
    }
}

#[cfg(feature = "pjrt")]
mod imp {
    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::rc::Rc;

    use super::*;

    /// The device-buffer handle type workers cache their data share in.
    pub type XlaLiteral = xla::Literal;

    fn xerr(e: xla::Error) -> XlaRuntimeError {
        XlaRuntimeError::Xla(e.to_string())
    }

    /// PJRT CPU runtime with executable cache.
    pub struct XlaRuntime {
        client: xla::PjRtClient,
        manifest: Manifest,
        dir: PathBuf,
        cache: RefCell<HashMap<PathBuf, Rc<xla::PjRtLoadedExecutable>>>,
        compiles: RefCell<u64>,
    }

    impl XlaRuntime {
        /// Create a runtime over an artifact directory (reads manifest.json).
        pub fn new(artifact_dir: &Path) -> Result<Self, XlaRuntimeError> {
            let manifest = Manifest::load(artifact_dir)?;
            let client = xla::PjRtClient::cpu().map_err(xerr)?;
            Ok(XlaRuntime {
                client,
                manifest,
                dir: artifact_dir.to_path_buf(),
                cache: RefCell::new(HashMap::new()),
                compiles: RefCell::new(0),
            })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn artifact_dir(&self) -> &Path {
            &self.dir
        }

        /// Number of PJRT compilations performed (observability: the request
        /// path must not recompile — see EXPERIMENTS.md §Perf).
        pub fn compile_count(&self) -> u64 {
            *self.compiles.borrow()
        }

        fn executable(&self, path: &Path) -> Result<Rc<xla::PjRtLoadedExecutable>, XlaRuntimeError> {
            if let Some(exe) = self.cache.borrow().get(path) {
                return Ok(exe.clone());
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| XlaRuntimeError::BadResult("non-utf8 path".into()))?,
            )
            .map_err(xerr)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = Rc::new(self.client.compile(&comp).map_err(xerr)?);
            *self.compiles.borrow_mut() += 1;
            self.cache.borrow_mut().insert(path.to_path_buf(), exe.clone());
            Ok(exe)
        }

        /// Execute the worker computation f(X̃, W̃) via the AOT artifact for
        /// (rows, d, r, p). Field elements in/out as `u64 < p`.
        pub fn worker_f(
            &self,
            x: &[u64],
            w: &[u64],
            coeffs: &[u64],
            rows: usize,
            d: usize,
            p: u64,
        ) -> Result<Vec<u64>, XlaRuntimeError> {
            let lx = Self::matrix_literal(x, rows, d)?;
            self.worker_f_literal(&lx, w, coeffs, rows, d, p)
        }

        /// Convert a field matrix into a device-ready literal. Workers call
        /// this once on their (iteration-invariant) data share and reuse it —
        /// the per-iteration hot path then only marshals the small W̃ panel
        /// (EXPERIMENTS.md §Perf).
        pub fn matrix_literal(x: &[u64], rows: usize, d: usize) -> Result<XlaLiteral, XlaRuntimeError> {
            assert_eq!(x.len(), rows * d);
            let xi: Vec<i64> = x.iter().map(|&v| v as i64).collect();
            xla::Literal::vec1(&xi)
                .reshape(&[rows as i64, d as i64])
                .map_err(xerr)
        }

        /// `worker_f` with a pre-marshalled X̃ literal.
        pub fn worker_f_literal(
            &self,
            lx: &XlaLiteral,
            w: &[u64],
            coeffs: &[u64],
            rows: usize,
            d: usize,
            p: u64,
        ) -> Result<Vec<u64>, XlaRuntimeError> {
            let r = coeffs.len() - 1;
            let entry = self
                .manifest
                .find_worker(rows, d, r, p)
                .ok_or(XlaRuntimeError::NoArtifact { what: "worker_f", rows, d, r })?;
            let exe = self.executable(&entry.path.clone())?;

            let wi: Vec<i64> = w.iter().map(|&v| v as i64).collect();
            let ci: Vec<i64> = coeffs.iter().map(|&v| v as i64).collect();
            let lw = xla::Literal::vec1(&wi)
                .reshape(&[d as i64, r as i64])
                .map_err(xerr)?;
            let lc = xla::Literal::vec1(&ci);

            let result = exe.execute::<&xla::Literal>(&[lx, &lw, &lc]).map_err(xerr)?[0][0]
                .to_literal_sync()
                .map_err(xerr)?;
            let out = result.to_tuple1().map_err(xerr)?;
            let vals: Vec<i64> = out.to_vec().map_err(xerr)?;
            if vals.len() != d {
                return Err(XlaRuntimeError::BadResult(format!(
                    "worker_f returned {} values, expected {d}",
                    vals.len()
                )));
            }
            Ok(vals.into_iter().map(|v| v as u64).collect())
        }

        /// Execute one plaintext LR gradient step via artifact; returns
        /// (updated weights, loss).
        pub fn lr_step(
            &self,
            x: &[f64],
            y: &[f64],
            w: &[f64],
            eta: f64,
            m: usize,
            d: usize,
        ) -> Result<(Vec<f64>, f64), XlaRuntimeError> {
            let entry = self
                .manifest
                .find_lr_step(m, d)
                .ok_or(XlaRuntimeError::NoArtifact { what: "lr_step", rows: m, d, r: 0 })?;
            let exe = self.executable(&entry.path.clone())?;

            let lx = xla::Literal::vec1(x)
                .reshape(&[m as i64, d as i64])
                .map_err(xerr)?;
            let ly = xla::Literal::vec1(y);
            let lw = xla::Literal::vec1(w);
            let le = xla::Literal::scalar(eta);

            let result = exe.execute::<xla::Literal>(&[lx, ly, lw, le]).map_err(xerr)?[0][0]
                .to_literal_sync()
                .map_err(xerr)?;
            let (w_out, loss) = result.to_tuple2().map_err(xerr)?;
            let w_new: Vec<f64> = w_out.to_vec().map_err(xerr)?;
            let loss: f64 = loss.get_first_element().map_err(xerr)?;
            if w_new.len() != d {
                return Err(XlaRuntimeError::BadResult(format!(
                    "lr_step returned {} weights, expected {d}",
                    w_new.len()
                )));
            }
            Ok((w_new, loss))
        }
    }

    impl std::fmt::Debug for XlaRuntime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("XlaRuntime")
                .field("dir", &self.dir)
                .field("artifacts", &self.manifest.entries.len())
                .field("compiled", &self.cache.borrow().len())
                .finish()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::*;

    /// Placeholder for the device-buffer type when PJRT is compiled out.
    /// Never constructed — every path that would produce one errors first.
    #[derive(Debug, Clone)]
    pub struct XlaLiteral;

    /// Manifest-only runtime: artifact inspection works, execution does not.
    pub struct XlaRuntime {
        manifest: Manifest,
        dir: PathBuf,
    }

    fn unavailable<T>() -> Result<T, XlaRuntimeError> {
        Err(super::pjrt_unavailable())
    }

    impl XlaRuntime {
        /// Load the artifact manifest. Succeeds so `codedml artifacts` can
        /// inspect manifests even in a PJRT-less build; execution errors.
        pub fn new(artifact_dir: &Path) -> Result<Self, XlaRuntimeError> {
            let manifest = Manifest::load(artifact_dir)?;
            Ok(XlaRuntime { manifest, dir: artifact_dir.to_path_buf() })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn artifact_dir(&self) -> &Path {
            &self.dir
        }

        /// Always 0 — nothing compiles in a PJRT-less build.
        pub fn compile_count(&self) -> u64 {
            0
        }

        pub fn worker_f(
            &self,
            _x: &[u64],
            _w: &[u64],
            _coeffs: &[u64],
            _rows: usize,
            _d: usize,
            _p: u64,
        ) -> Result<Vec<u64>, XlaRuntimeError> {
            unavailable()
        }

        pub fn matrix_literal(
            _x: &[u64],
            _rows: usize,
            _d: usize,
        ) -> Result<XlaLiteral, XlaRuntimeError> {
            unavailable()
        }

        pub fn worker_f_literal(
            &self,
            _lx: &XlaLiteral,
            _w: &[u64],
            _coeffs: &[u64],
            _rows: usize,
            _d: usize,
            _p: u64,
        ) -> Result<Vec<u64>, XlaRuntimeError> {
            unavailable()
        }

        pub fn lr_step(
            &self,
            _x: &[f64],
            _y: &[f64],
            _w: &[f64],
            _eta: f64,
            _m: usize,
            _d: usize,
        ) -> Result<(Vec<f64>, f64), XlaRuntimeError> {
            unavailable()
        }
    }

    impl std::fmt::Debug for XlaRuntime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("XlaRuntime")
                .field("dir", &self.dir)
                .field("artifacts", &self.manifest.entries.len())
                .field("pjrt", &"not compiled in")
                .finish()
        }
    }
}

pub use imp::{XlaLiteral, XlaRuntime};
