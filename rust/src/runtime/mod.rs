//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them on
//! the request path, with zero Python.
//!
//! - `manifest` — parses `artifacts/manifest.json` written by
//!   `python/compile/aot.py` and indexes artifacts by shape.
//! - `client` — wraps the PJRT layer behind the `pjrt` cargo feature:
//!   client → HLO-text parse → compile → execute, with an executable
//!   cache so each artifact is compiled once per process. Without the
//!   feature ([`PJRT_AVAILABLE`] = false) it is manifest-only.
//! - `backend` — the [`WorkerBackend`] the coordinator dispatches through:
//!   `Native` (pure rust, any shape) or `Xla` (artifact, shapes in the
//!   manifest), both bit-exact.

mod backend;
mod client;
mod manifest;

pub use backend::{BackendKind, WorkerBackend};
pub use client::{XlaRuntime, XlaRuntimeError, PJRT_AVAILABLE};
pub use manifest::{ArtifactEntry, ArtifactKind, Manifest, ManifestError};
