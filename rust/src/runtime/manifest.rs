//! Artifact manifest — the contract between `python/compile/aot.py` and
//! the rust runtime.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// What a given artifact computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// f(X̃, W̃) over F_p (the CodedPrivateML worker step).
    WorkerF,
    /// Plaintext logistic-regression GD step (f64).
    LrStep,
}

/// One manifest entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    pub kind: ArtifactKind,
    pub name: String,
    /// Path to the `.hlo.txt`, resolved relative to the manifest.
    pub path: PathBuf,
    /// worker_f: coded block rows (m/K); lr_step: batch rows m.
    pub rows: usize,
    pub d: usize,
    /// worker_f only: sigmoid degree.
    pub r: usize,
    /// worker_f only: field prime baked into the kernel.
    pub p: u64,
}

#[derive(Debug)]
pub enum ManifestError {
    Io(std::io::Error),
    Parse(String),
    MissingField { entry: usize, field: &'static str },
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Io(e) => write!(f, "manifest io: {e}"),
            ManifestError::Parse(e) => write!(f, "manifest parse: {e}"),
            ManifestError::MissingField { entry, field } => {
                write!(f, "manifest entry {entry}: missing/invalid '{field}'")
            }
        }
    }
}

impl std::error::Error for ManifestError {}

/// Parsed manifest with shape indexes.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: Vec<ArtifactEntry>,
    /// (rows, d, r, p) → entry index, for worker_f lookups.
    worker_index: HashMap<(usize, usize, usize, u64), usize>,
    /// (m, d) → entry index, for lr_step lookups.
    lr_index: HashMap<(usize, usize), usize>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(ManifestError::Io)?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; artifact paths resolve against `dir`.
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest, ManifestError> {
        let root = Json::parse(text).map_err(|e| ManifestError::Parse(e.to_string()))?;
        let arts = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| ManifestError::Parse("no 'artifacts' array".into()))?;
        let mut m = Manifest::default();
        for (i, a) in arts.iter().enumerate() {
            let kind = match a.get("kind").and_then(Json::as_str) {
                Some("worker_f") => ArtifactKind::WorkerF,
                Some("lr_step") => ArtifactKind::LrStep,
                _ => return Err(ManifestError::MissingField { entry: i, field: "kind" }),
            };
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or(ManifestError::MissingField { entry: i, field: "name" })?
                .to_string();
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or(ManifestError::MissingField { entry: i, field: "file" })?;
            let d = a
                .get("d")
                .and_then(Json::as_usize)
                .ok_or(ManifestError::MissingField { entry: i, field: "d" })?;
            let entry = match kind {
                ArtifactKind::WorkerF => ArtifactEntry {
                    kind,
                    name,
                    path: dir.join(file),
                    rows: a
                        .get("rows")
                        .and_then(Json::as_usize)
                        .ok_or(ManifestError::MissingField { entry: i, field: "rows" })?,
                    d,
                    r: a
                        .get("r")
                        .and_then(Json::as_usize)
                        .ok_or(ManifestError::MissingField { entry: i, field: "r" })?,
                    p: a
                        .get("p")
                        .and_then(Json::as_u64)
                        .ok_or(ManifestError::MissingField { entry: i, field: "p" })?,
                },
                ArtifactKind::LrStep => ArtifactEntry {
                    kind,
                    name,
                    path: dir.join(file),
                    rows: a
                        .get("m")
                        .and_then(Json::as_usize)
                        .ok_or(ManifestError::MissingField { entry: i, field: "m" })?,
                    d,
                    r: 0,
                    p: 0,
                },
            };
            let idx = m.entries.len();
            match kind {
                ArtifactKind::WorkerF => {
                    m.worker_index.insert((entry.rows, entry.d, entry.r, entry.p), idx);
                }
                ArtifactKind::LrStep => {
                    m.lr_index.insert((entry.rows, entry.d), idx);
                }
            }
            m.entries.push(entry);
        }
        Ok(m)
    }

    /// worker_f artifact for an exact (rows, d, r, p) shape.
    pub fn find_worker(&self, rows: usize, d: usize, r: usize, p: u64) -> Option<&ArtifactEntry> {
        self.worker_index.get(&(rows, d, r, p)).map(|&i| &self.entries[i])
    }

    /// lr_step artifact for (m, d).
    pub fn find_lr_step(&self, m: usize, d: usize) -> Option<&ArtifactEntry> {
        self.lr_index.get(&(m, d)).map(|&i| &self.entries[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "prime": 15485863,
      "artifacts": [
        {"kind": "worker_f", "name": "w1", "file": "w1.hlo.txt",
         "rows": 64, "d": 784, "r": 1, "p": 15485863, "block_rows": 32},
        {"kind": "lr_step", "name": "l1", "file": "l1.hlo.txt",
         "m": 256, "d": 784}
      ]
    }"#;

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        assert_eq!(m.entries.len(), 2);
        let w = m.find_worker(64, 784, 1, 15485863).unwrap();
        assert_eq!(w.kind, ArtifactKind::WorkerF);
        assert_eq!(w.path, Path::new("/art/w1.hlo.txt"));
        assert!(m.find_worker(64, 784, 2, 15485863).is_none());
        let l = m.find_lr_step(256, 784).unwrap();
        assert_eq!(l.kind, ArtifactKind::LrStep);
        assert!(m.find_lr_step(256, 10).is_none());
    }

    #[test]
    fn missing_field_reported() {
        let bad = r#"{"artifacts": [{"kind": "worker_f", "name": "x", "file": "f"}]}"#;
        let err = Manifest::parse(bad, Path::new(".")).unwrap_err();
        assert!(matches!(err, ManifestError::MissingField { field: "d", .. }), "{err}");
    }

    #[test]
    fn rejects_bad_kind_and_garbage() {
        let bad = r#"{"artifacts": [{"kind": "nope", "name": "x", "file": "f", "d": 1}]}"#;
        assert!(matches!(
            Manifest::parse(bad, Path::new(".")),
            Err(ManifestError::MissingField { field: "kind", .. })
        ));
        assert!(matches!(
            Manifest::parse("not json", Path::new(".")),
            Err(ManifestError::Parse(_))
        ));
        assert!(matches!(
            Manifest::parse("{}", Path::new(".")),
            Err(ManifestError::Parse(_))
        ));
    }

    #[test]
    fn loads_real_manifest_when_built() {
        // Integration with the actual `make artifacts` output, when present.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.find_worker(64, 784, 1, 15485863).is_some());
        for e in &m.entries {
            assert!(e.path.exists(), "missing artifact file {:?}", e.path);
        }
    }
}
