//! Summary statistics used by benches and the experiment harness.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0.0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Exact percentile by sorting a copy (`q` in [0,1]); linear interpolation.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q));
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let frac = pos - lo as f64;
        s[lo] * (1.0 - frac) + s[hi] * frac
    }
}

/// min/max (NaN-free input assumed).
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    xs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &x| {
        (lo.min(x), hi.max(x))
    })
}

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        // Sample sd of this classic set is ~2.138.
        assert!((stddev(&xs) - 2.1380899).abs() < 1e-5);
    }

    #[test]
    fn empty_slices_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 1.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.5, -3.0, 4.5, 10.0, 0.25];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.stddev() - stddev(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), xs.len() as u64);
    }

    #[test]
    fn min_max_works() {
        let (lo, hi) = min_max(&[3.0, -1.0, 7.5]);
        assert_eq!(lo, -1.0);
        assert_eq!(hi, 7.5);
    }
}
