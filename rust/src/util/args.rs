//! Tiny CLI argument parser (`--key value`, `--flag`, positionals).

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Keys consumed via get/flag — for unknown-option detection.
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse a raw argv slice (without the program name). `--key value`
    /// pairs become options; `--key` followed by another `--` or at the
    /// end becomes a flag; everything else is positional.
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let next_is_value = argv
                    .get(i + 1)
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false);
                if next_is_value {
                    out.options.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    out.flags.push(key.to_string());
                    i += 1;
                }
            } else {
                out.positional.push(a.clone());
                i += 1;
            }
        }
        out
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.seen.borrow_mut().push(key.to_string());
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.seen.borrow_mut().push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Typed getters with defaults and error messages.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected number, got '{v}'")),
        }
    }

    /// Options that were provided but never consumed — typos.
    pub fn unknown_options(&self) -> Vec<String> {
        let seen = self.seen.borrow();
        self.options
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !seen.contains(k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let argv: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&argv)
    }

    #[test]
    fn parses_mixed() {
        let a = parse("reproduce fig2 --scale 0.1 --verbose --n 40");
        assert_eq!(a.positional, vec!["reproduce", "fig2"]);
        assert_eq!(a.get("scale"), Some("0.1"));
        assert_eq!(a.get("n"), Some("40"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = parse("--n 40 --eta 0.25");
        assert_eq!(a.get_usize("n", 1).unwrap(), 40);
        assert_eq!(a.get_f64("eta", 1.0).unwrap(), 0.25);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(a.get_usize("eta", 0).is_err());
    }

    #[test]
    fn unknown_options_detects_typos() {
        let a = parse("--itres 5 --n 3");
        let _ = a.get_usize("n", 1);
        let _ = a.get_usize("iters", 25);
        assert_eq!(a.unknown_options(), vec!["itres".to_string()]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--json");
        assert!(a.flag("json"));
    }
}
