//! Small self-contained utilities: deterministic RNG, minimal JSON,
//! timing/statistics helpers, and a property-testing harness.
//!
//! This build runs fully offline against a small vendored crate set, so the
//! usual ecosystem crates (rand, serde, proptest, criterion) are hand-rolled
//! here at the scale this project needs.

pub mod args;
pub mod bitpack;
pub mod json;
pub mod par;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;

pub use par::Parallelism;
pub use rng::Rng;
pub use timer::Stopwatch;
