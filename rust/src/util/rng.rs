//! Deterministic pseudo-random number generation.
//!
//! xoshiro256** seeded via splitmix64 — the standard construction from
//! Blackman & Vigna. Deterministic across platforms, which the test suite
//! and the reproduce harness rely on (every experiment records its seed).
//!
//! Note on privacy: the *protocol-level* masks (`Z`, `V`, Shamir
//! coefficients) must be uniform over F_p; [`Rng::field_element`] uses
//! rejection sampling so the distribution is exactly uniform, not merely
//! approximately. A deployment would back this with an OS CSPRNG; the
//! information-theoretic argument only needs uniformity + independence,
//! which the simulation preserves.

/// splitmix64: used to expand a 64-bit seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a sub-component (worker id,
    /// iteration, ...). Streams seeded from distinct labels are
    /// statistically independent for our purposes.
    pub fn fork(&mut self, label: u64) -> Rng {
        let base = self.next_u64();
        Rng::new(base ^ label.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform u64 in `[0, bound)` via Lemire-style rejection; exact.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Rejection sampling on the top bits to avoid modulo bias.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let wide = (r as u128) * (bound as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Uniform element of F_p (exactly uniform by rejection in `below`).
    #[inline]
    pub fn field_element(&mut self, p: u64) -> u64 {
        self.below(p)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Exponential with the given rate (mean = 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / rate
    }

    /// Bernoulli(prob).
    #[inline]
    pub fn bernoulli(&mut self, prob: f64) -> bool {
        self.f64() < prob
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k ≤ n), sorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_is_in_range_and_hits_all_small_values() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval_with_plausible_mean() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.gaussian();
            s1 += g;
            s2 += g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::new(13);
        let n = 50_000;
        let rate = 2.5;
        let mean: f64 = (0..n).map(|_| rng.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut rng = Rng::new(19);
        for _ in 0..50 {
            let k = rng.below_usize(20) + 1;
            let s = rng.sample_indices(40, k);
            assert_eq!(s.len(), k);
            for w in s.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(s.iter().all(|&i| i < 40));
        }
    }

    #[test]
    fn field_element_uniformity_chi_square() {
        // Coarse uniformity check over a small "field".
        let p = 97u64;
        let mut rng = Rng::new(23);
        let n = 97_000usize;
        let mut counts = vec![0usize; p as usize];
        for _ in 0..n {
            counts[rng.field_element(p) as usize] += 1;
        }
        let expected = n as f64 / p as f64;
        let chi2: f64 = counts
            .iter()
            .map(|&c| {
                let d = c as f64 - expected;
                d * d / expected
            })
            .sum();
        // 96 dof; mean 96, sd ~13.9. Allow 5 sigma.
        assert!(chi2 < 96.0 + 5.0 * 13.9, "chi2={chi2}");
    }
}
