//! Minimal JSON parser + emitter.
//!
//! Used for the artifact manifest written by `python/compile/aot.py`, for
//! experiment configs, and for machine-readable results from the reproduce
//! harness. Supports the full JSON grammar except `\u` surrogate pairs
//! beyond the BMP (sufficient for our ASCII manifests); numbers are f64.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap so emission is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` convenience: None if not an object or key missing.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.emit(&mut out);
        out
    }

    fn emit(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => emit_string(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.emit(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    emit_string(k, out);
                    out.push(':');
                    v.emit(out);
                }
                out.push('}');
            }
        }
    }
}

/// Build an object from pairs — `obj(&[("a", Json::Num(1.0))])`.
pub fn obj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(
        pairs
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect(),
    )
}

fn emit_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy one UTF-8 char.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.25e2").unwrap(), Json::Num(-325.0));
        assert_eq!(
            Json::parse(r#""hi\nthere""#).unwrap(),
            Json::Str("hi\nthere".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn round_trips() {
        let cases = [
            r#"{"m":12396,"d":1568,"name":"worker_f","interp":true}"#,
            r#"[1,2.5,"x",null,[],{}]"#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let emitted = v.to_string();
            assert_eq!(Json::parse(&emitted).unwrap(), v, "case {c}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "tru", "{\"a\" 1}", "1 2", "\"unterminated"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn integer_accessors() {
        let v = Json::parse(r#"{"n": 15485863, "x": 1.5, "neg": -2}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(15485863));
        assert_eq!(v.get("x").unwrap().as_u64(), None);
        assert_eq!(v.get("neg").unwrap().as_u64(), None);
        assert_eq!(v.get("n").unwrap().as_usize(), Some(15485863));
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn emits_control_chars_escaped() {
        let s = Json::Str("\u{0001}".into()).to_string();
        assert_eq!(s, "\"\\u0001\"");
    }
}
