//! Wall-clock timing helpers used by the coordinator's per-phase breakdown
//! (the Encode / Comm. / Comp. columns of Tables 1–6) and by the bench
//! harness.

use std::time::{Duration, Instant};

/// A resettable stopwatch accumulating named spans.
#[derive(Debug, Clone, Default)]
pub struct Stopwatch {
    total: Duration,
    started: Option<InstantWrap>,
}

// Instant is not Default; wrap it so Stopwatch can derive Default.
#[derive(Debug, Clone, Copy)]
struct InstantWrap(Instant);

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start (or restart) the current span.
    pub fn start(&mut self) {
        self.started = Some(InstantWrap(Instant::now()));
    }

    /// Stop the current span, folding it into the total. No-op if stopped.
    pub fn stop(&mut self) {
        if let Some(InstantWrap(t0)) = self.started.take() {
            self.total += t0.elapsed();
        }
    }

    /// Time a closure, accumulating its duration.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.total += t0.elapsed();
        out
    }

    /// Accumulated seconds (running span excluded).
    pub fn seconds(&self) -> f64 {
        self.total.as_secs_f64()
    }

    /// Add an externally measured duration (e.g. modeled network time).
    pub fn add_seconds(&mut self, s: f64) {
        self.total += Duration::from_secs_f64(s.max(0.0));
    }

    pub fn reset(&mut self) {
        self.total = Duration::ZERO;
        self.started = None;
    }
}

/// Measure a closure once, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// A wall-clock budget for a blocking operation — the round engine's
/// per-round deadline (`--round-deadline-ms`). Wall-clock access is
/// confined to this module (`no-wallclock-nondeterminism`), so callers
/// carry a `Deadline` value instead of touching `Instant` themselves.
///
/// `Deadline::none()` never expires: `remaining()` is `None` and blocking
/// receives degrade to plain blocking receives.
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    end: Option<Instant>,
}

impl Deadline {
    /// A deadline that never fires.
    pub fn none() -> Self {
        Deadline { end: None }
    }

    /// Expire `ms` milliseconds from now; `ms == 0` means no deadline.
    pub fn after_ms(ms: u64) -> Self {
        if ms == 0 {
            Deadline::none()
        } else {
            Deadline { end: Some(Instant::now() + Duration::from_millis(ms)) }
        }
    }

    /// Is this the never-expiring deadline?
    pub fn is_none(&self) -> bool {
        self.end.is_none()
    }

    /// Time left before expiry (`None` = unbounded, zero = expired).
    pub fn remaining(&self) -> Option<Duration> {
        self.end.map(|e| e.saturating_duration_since(Instant::now()))
    }

    /// Has the budget run out? (Never true for [`Deadline::none`].)
    pub fn expired(&self) -> bool {
        self.remaining().is_some_and(|r| r.is_zero())
    }
}

/// Run a closure repeatedly for at least `min_seconds` (and at least
/// `min_iters` times), returning the mean seconds per call. Used by the
/// hand-rolled bench harness (criterion is unavailable offline).
pub fn bench_seconds(min_seconds: f64, min_iters: u32, mut f: impl FnMut()) -> f64 {
    // Warmup.
    f();
    let mut iters = 0u32;
    let t0 = Instant::now();
    loop {
        f();
        iters += 1;
        let elapsed = t0.elapsed().as_secs_f64();
        if iters >= min_iters && elapsed >= min_seconds {
            return elapsed / iters as f64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        assert!(sw.seconds() >= 0.009, "got {}", sw.seconds());
    }

    #[test]
    fn stopwatch_start_stop() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(Duration::from_millis(3));
        sw.stop();
        sw.stop(); // idempotent
        assert!(sw.seconds() >= 0.002);
        sw.reset();
        assert_eq!(sw.seconds(), 0.0);
    }

    #[test]
    fn add_seconds_folds_in() {
        let mut sw = Stopwatch::new();
        sw.add_seconds(1.5);
        sw.add_seconds(-3.0); // clamped to 0
        assert!((sw.seconds() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 42);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
