//! Minimal fork–join parallelism on `std::thread::scope` (rayon is
//! unavailable offline).
//!
//! The three embarrassingly-parallel stages of the pipeline — Lagrange
//! encoding across workers, per-worker matmuls across row blocks, and
//! decoding across output chunks — all reduce to "split an index range
//! into contiguous chunks and run them on scoped threads". [`par_ranges`]
//! is that primitive; [`par_map`] is the per-index convenience on top.
//!
//! **Bit-exactness.** Every call site partitions *independent* outputs
//! (rows, workers, columns) or merges per-chunk partials with field adds,
//! which are associative and exact — so results are identical for every
//! [`Parallelism`] setting. `rust/tests/end_to_end.rs` asserts this on a
//! full training run; mask/quantization randomness is always drawn
//! *before* fan-out so RNG streams never depend on the thread count.

use std::num::NonZeroUsize;
use std::ops::Range;

/// Degree of parallelism for the coding/compute hot paths.
///
/// Surfaced as the `parallelism` key of the JSON config and the
/// `--threads serial|auto|<n>` CLI option ([`crate::coordinator::CodedMlConfig`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Parallelism {
    /// Single-threaded (the deterministic-overhead-free default).
    #[default]
    Serial,
    /// One thread per available core (`std::thread::available_parallelism`).
    Auto,
    /// Exactly this many threads.
    Threads(NonZeroUsize),
}

impl Parallelism {
    /// Resolve to a concrete thread count (≥ 1).
    pub fn threads(self) -> usize {
        match self {
            Parallelism::Serial => 1,
            Parallelism::Auto => std::thread::available_parallelism()
                .map(NonZeroUsize::get)
                .unwrap_or(1),
            Parallelism::Threads(n) => n.get(),
        }
    }

    /// From a plain count: 0 → `Auto`, 1 → `Serial`, n → `Threads(n)`.
    pub fn from_count(n: usize) -> Self {
        match NonZeroUsize::new(n) {
            None => Parallelism::Auto,
            Some(nz) if nz.get() == 1 => Parallelism::Serial,
            Some(nz) => Parallelism::Threads(nz),
        }
    }
}

impl std::str::FromStr for Parallelism {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "serial" => Ok(Parallelism::Serial),
            "auto" => Ok(Parallelism::Auto),
            _ => s
                .parse::<usize>()
                .map(Parallelism::from_count)
                .map_err(|_| format!("bad thread count '{s}' (serial|auto|<n>)")),
        }
    }
}

impl std::fmt::Display for Parallelism {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Parallelism::Serial => write!(f, "serial"),
            Parallelism::Auto => write!(f, "auto"),
            Parallelism::Threads(n) => write!(f, "{n}"),
        }
    }
}

/// Split `0..len` into at most `par.threads()` contiguous chunks and run
/// `f(chunk_index, range)` on scoped threads, returning the results in
/// chunk order. With one thread (or `len ≤ 1`) this is a direct call — no
/// spawn overhead on the serial path.
pub fn par_ranges<U, F>(par: Parallelism, len: usize, f: F) -> Vec<U>
where
    U: Send,
    F: Fn(usize, Range<usize>) -> U + Sync,
{
    let threads = par.threads().min(len).max(1);
    if threads <= 1 {
        return vec![f(0, 0..len)];
    }
    let chunk = len.div_ceil(threads);
    std::thread::scope(|scope| {
        let f = &f;
        let handles: Vec<_> = (0..threads)
            .map(|i| {
                let start = (i * chunk).min(len);
                let end = ((i + 1) * chunk).min(len);
                scope.spawn(move || f(i, start..end))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("parallel chunk panicked"))
            .collect()
    })
}

/// Parallel index map: `(0..n).map(f)` with the iterations spread over
/// [`par_ranges`] chunks; results come back in index order.
pub fn par_map<U, F>(par: Parallelism, n: usize, f: F) -> Vec<U>
where
    U: Send,
    F: Fn(usize) -> U + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    par_ranges(par, n, |_, range| range.map(&f).collect::<Vec<U>>())
        .into_iter()
        .flatten()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_settings() -> Vec<Parallelism> {
        vec![
            Parallelism::Serial,
            Parallelism::Auto,
            Parallelism::from_count(2),
            Parallelism::from_count(3),
            Parallelism::from_count(64), // more threads than work
        ]
    }

    #[test]
    fn par_map_matches_serial_map_for_every_setting() {
        let want: Vec<usize> = (0..97).map(|i| i * i).collect();
        for par in all_settings() {
            let got = par_map(par, 97, |i| i * i);
            assert_eq!(got, want, "par={par}");
        }
    }

    #[test]
    fn par_ranges_covers_exactly_once_in_order() {
        for par in all_settings() {
            for len in [0usize, 1, 2, 5, 64, 65] {
                let chunks = par_ranges(par, len, |_, r| r.collect::<Vec<usize>>());
                let flat: Vec<usize> = chunks.into_iter().flatten().collect();
                assert_eq!(flat, (0..len).collect::<Vec<_>>(), "par={par} len={len}");
            }
        }
    }

    #[test]
    fn empty_input_is_safe() {
        assert!(par_map(Parallelism::Auto, 0, |i| i).is_empty());
    }

    #[test]
    fn parsing_and_display_round_trip() {
        assert_eq!("serial".parse::<Parallelism>().unwrap(), Parallelism::Serial);
        assert_eq!("auto".parse::<Parallelism>().unwrap(), Parallelism::Auto);
        assert_eq!("0".parse::<Parallelism>().unwrap(), Parallelism::Auto);
        assert_eq!("1".parse::<Parallelism>().unwrap(), Parallelism::Serial);
        assert_eq!(
            "8".parse::<Parallelism>().unwrap(),
            Parallelism::Threads(NonZeroUsize::new(8).unwrap())
        );
        assert!("eight".parse::<Parallelism>().is_err());
        assert_eq!(Parallelism::from_count(8).to_string(), "8");
        assert_eq!(Parallelism::default(), Parallelism::Serial);
    }

    #[test]
    fn thread_counts_resolve() {
        assert_eq!(Parallelism::Serial.threads(), 1);
        assert!(Parallelism::Auto.threads() >= 1);
        assert_eq!(Parallelism::from_count(5).threads(), 5);
    }
}
