//! Bit-packing of field elements for the wire.
//!
//! A share of F_p elements occupies ⌈log₂ p⌉ bits each when packed —
//! 24 bits instead of 64 for the paper's prime, a 2.67x communication
//! saving the modeled network can account for (`CodedMlConfig.packed_wire`).
//! The codec is exact and round-trips any element < 2^width.

/// Pack `values` (< 2^width each) into a little-endian bitstream.
pub fn pack(values: &[u64], width: u32) -> Vec<u8> {
    assert!((1..=64).contains(&width));
    let total_bits = values.len() * width as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &v in values {
        debug_assert!(width == 64 || v < (1u64 << width), "value {v} exceeds {width} bits");
        let mut remaining = width;
        let mut val = v;
        while remaining > 0 {
            let byte = bitpos / 8;
            let off = (bitpos % 8) as u32;
            let take = (8 - off).min(remaining);
            out[byte] |= ((val & ((1u64 << take) - 1)) as u8) << off;
            val >>= take;
            bitpos += take as usize;
            remaining -= take;
        }
    }
    out
}

/// Unpack `count` width-bit values from a bitstream produced by [`pack`].
pub fn unpack(bytes: &[u8], width: u32, count: usize) -> Vec<u64> {
    assert!((1..=64).contains(&width));
    let needed_bits = count * width as usize;
    assert!(
        bytes.len() * 8 >= needed_bits,
        "buffer too short: {} bits < {needed_bits}",
        bytes.len() * 8
    );
    let mut out = Vec::with_capacity(count);
    let mut bitpos = 0usize;
    for _ in 0..count {
        let mut v = 0u64;
        let mut got = 0u32;
        while got < width {
            let byte = bitpos / 8;
            let off = (bitpos % 8) as u32;
            let take = (8 - off).min(width - got);
            let bits = ((bytes[byte] >> off) as u64) & ((1u64 << take) - 1);
            v |= bits << got;
            got += take;
            bitpos += take as usize;
        }
        out.push(v);
    }
    out
}

/// Bytes needed to pack `count` width-bit values.
pub fn packed_len(count: usize, width: u32) -> usize {
    (count * width as usize).div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn round_trips_random_widths() {
        check("bitpack-roundtrip", 100, |rng| {
            let width = 1 + rng.below(63) as u32;
            let n = rng.below_usize(50);
            let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
            let values: Vec<u64> = (0..n).map(|_| rng.next_u64() & mask).collect();
            let packed = pack(&values, width);
            if packed.len() != packed_len(n, width) {
                return Err("length mismatch".into());
            }
            let back = unpack(&packed, width, n);
            if back != values {
                return Err(format!("w={width} n={n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn paper_prime_packs_to_24_bits() {
        let p = crate::field::PAPER_PRIME;
        let values: Vec<u64> = vec![0, 1, p - 1, p / 2];
        let packed = pack(&values, 24);
        assert_eq!(packed.len(), 12); // 4 × 24 bits = 96 bits = 12 bytes
        assert_eq!(unpack(&packed, 24, 4), values);
    }

    #[test]
    fn width_64_round_trips_extremes() {
        let values = [u64::MAX, 0, 1 << 63];
        let packed = pack(&values, 64);
        assert_eq!(unpack(&packed, 64, 3), values);
    }

    #[test]
    fn empty_is_fine() {
        assert!(pack(&[], 24).is_empty());
        assert!(unpack(&[], 24, 0).is_empty());
    }

    #[test]
    #[should_panic(expected = "buffer too short")]
    fn unpack_checks_length() {
        unpack(&[0u8; 2], 24, 2);
    }

    #[test]
    fn cross_byte_boundaries_exact() {
        // width 5: values straddle byte boundaries in every position.
        let values: Vec<u64> = (0..32).map(|i| i % 32).collect();
        let packed = pack(&values, 5);
        assert_eq!(packed.len(), 20); // 160 bits
        assert_eq!(unpack(&packed, 5, 32), values);
    }
}
