//! # CodedPrivateML
//!
//! A reproduction of *CodedPrivateML: A Fast and Privacy-Preserving
//! Framework for Distributed Machine Learning* (So, Güler, Avestimehr,
//! Mohassel, 2019) as a three-layer rust + JAX + Pallas stack.
//!
//! The library trains a logistic (or linear) regression model on a
//! master + N workers cluster while keeping both the dataset and the
//! per-iteration model weights information-theoretically private against
//! any T colluding workers:
//!
//! 1. [`quant`] — stochastic quantization between ℝ and the prime field F_p,
//! 2. [`coding`] — Lagrange coded computing (LCC) secret sharing,
//! 3. [`sigmoid`] — polynomial approximation of the sigmoid so the worker
//!    computation is a polynomial the master can decode by interpolation,
//! 4. [`coordinator`] — the Algorithm-1 training loop over a simulated
//!    [`cluster`] with straggler injection and a network cost model,
//! 5. [`mpc`] — the BGW/Shamir baseline the paper compares against,
//! 6. [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas worker
//!    kernel (`artifacts/*.hlo.txt`), with a bit-exact native fallback in
//!    [`compute`],
//! 7. [`serve`] — multi-session serving: a weighted-fair scheduler
//!    multiplexing concurrent training jobs over one shared worker pool,
//!    each job's trajectory bit-identical to a dedicated run.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.
//!
//! The source tree itself is machine-checked by [`analysis`] — an
//! in-repo linter (`cargo run -- lint`) enforcing the field, privacy,
//! and determinism invariants listed in `docs/ARCHITECTURE.md`.

pub mod analysis;
pub mod cli;
pub mod cluster;
pub mod coding;
pub mod compute;
pub mod coordinator;
pub mod data;
pub mod field;
pub mod model;
pub mod mpc;
pub mod quant;
pub mod reproduce;
pub mod runtime;
pub mod serve;
pub mod sigmoid;
pub mod util;

pub use coordinator::{CodedMlConfig, CodedMlSession, TrainReport};
pub use field::PrimeField;
