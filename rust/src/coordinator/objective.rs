//! Pluggable coded objectives.
//!
//! The round engine in [`super::CodedMlSession`] is algorithm-agnostic:
//! it quantizes weights, encodes, dispatches, collects the fastest R
//! results, and decodes. Everything specific to *what* the workers
//! compute lives behind [`CodedObjective`]:
//!
//! - how many independent weight quantizations a round sends (the worker
//!   polynomial degree r for logistic, 1 for linear),
//! - which worker op runs and with which field coefficients,
//! - whether coded labels ship to the workers (linear: ỹ enters the
//!   worker polynomial; logistic: the master holds y and subtracts X̄ᵀy
//!   after decoding),
//! - how decoded blocks assemble into a real-domain gradient,
//! - loss / accuracy / default step size.
//!
//! [`LogisticObjective`] is paper Algorithm 1; [`LinearObjective`] is
//! Remark 1 — the identity "activation" is already a polynomial, so the
//! gradient estimator is exactly unbiased with no sigmoid-fit error term.

use super::config::CodedMlConfig;
use crate::cluster::WorkerOp;
use crate::coding::Encoder;
use crate::data::Dataset;
use crate::model::{max_eig_xtx, tr_matvec, LinearRegression, LogisticRegression};
use crate::quant::{phi, round_half_up, phi_inv, Dequantizer};
use crate::sigmoid::SigmoidPoly;
use crate::util::Rng;

/// The algorithm-specific half of a CodedPrivateML session. One instance
/// is built per session (it may precompute per-block master-side terms);
/// the engine drives it once per round.
pub trait CodedObjective: Send {
    /// Short identifier ("logistic" | "linear") for reports and models.
    fn name(&self) -> &'static str;

    /// Columns of W̄ dispatched each round — the number of independent
    /// stochastic weight quantizations the worker polynomial consumes.
    fn weight_draws(&self) -> usize;

    /// Which computation the workers run on their coded share.
    fn worker_op(&self) -> WorkerOp;

    /// Field-quantized polynomial coefficients delivered to every worker
    /// (the sigmoid fit for logistic; a degree-1 placeholder for linear,
    /// whose op ignores them).
    fn worker_coeffs(&self) -> Vec<u64>;

    /// Coded label shares (one per worker) for ops whose worker polynomial
    /// consumes ỹ; `None` when the master keeps the labels to itself.
    fn label_shares(&self, encoder: &Encoder, rng: &mut Rng) -> Option<Vec<Vec<u64>>>;

    /// Assemble this round's real-domain gradient from the decoded field
    /// blocks `(block index, f(X̄_k, W̄))`, normalized by the batch's row
    /// count. The engine applies `w ← w − η·gradient`.
    fn gradient(&self, blocks: &[(usize, Vec<u64>)]) -> Vec<f64>;

    /// Training loss of `w` on the quantized dataset view `x` (the
    /// quantity the paper's convergence theorem is stated on).
    fn loss(&self, w: &[f64], x: &[f64], m: usize, d: usize) -> f64;

    /// Held-out accuracy, when the objective has a notion of it.
    fn accuracy(&self, w: &[f64], test: &Dataset) -> Option<f64>;

    /// Step size η = 1/L from the objective's Lipschitz constant.
    fn default_eta(&self, x: &[f64], m: usize, d: usize) -> f64;
}

/// Paper Algorithm 1: logistic regression with a degree-r polynomial
/// sigmoid. Workers return X̃ᵀḡ(X̃, W̃); the master subtracts its locally
/// held X̄ᵀy after decoding (eq. 19).
pub struct LogisticObjective {
    poly: SigmoidPoly,
    field_coeffs: Vec<u64>,
    dequant: Dequantizer,
    r: usize,
    /// X̄_kᵀ y_k per row block — the batch-local label term of eq. 19.
    xty_blocks: Vec<Vec<f64>>,
    y: Vec<f64>,
    rows: usize,
    d: usize,
}

impl LogisticObjective {
    pub fn new(
        cfg: &CodedMlConfig,
        xbar_real: &[f64],
        y: &[f64],
        m: usize,
        d: usize,
        k: usize,
    ) -> Self {
        let field = cfg.field();
        let poly = crate::sigmoid::fit_sigmoid_with(cfg.fit_method, cfg.r as u32, cfg.fit_range);
        let field_coeffs = poly.field_coeffs(&field, cfg.lx, cfg.lw, cfg.lc);
        let rows = m / k;
        let xty_blocks = (0..k)
            .map(|b| {
                tr_matvec(
                    &xbar_real[b * rows * d..(b + 1) * rows * d],
                    &y[b * rows..(b + 1) * rows],
                    rows,
                    d,
                )
            })
            .collect();
        LogisticObjective {
            poly,
            field_coeffs,
            dequant: Dequantizer::new(field, cfg.lx, cfg.lw, cfg.lc, cfg.r as u32),
            r: cfg.r,
            xty_blocks,
            y: y.to_vec(),
            rows,
            d,
        }
    }

    /// The fitted sigmoid polynomial (diagnostics / ablations).
    pub fn sigmoid_poly(&self) -> &SigmoidPoly {
        &self.poly
    }
}

impl CodedObjective for LogisticObjective {
    fn name(&self) -> &'static str {
        "logistic"
    }

    fn weight_draws(&self) -> usize {
        self.r
    }

    fn worker_op(&self) -> WorkerOp {
        WorkerOp::Logistic
    }

    fn worker_coeffs(&self) -> Vec<u64> {
        self.field_coeffs.clone()
    }

    fn label_shares(&self, _encoder: &Encoder, _rng: &mut Rng) -> Option<Vec<Vec<u64>>> {
        None
    }

    fn gradient(&self, blocks: &[(usize, Vec<u64>)]) -> Vec<f64> {
        let mut g = vec![0.0f64; self.d];
        for (b, data) in blocks {
            let xty = &self.xty_blocks[*b];
            for ((gi, &q), &t) in g.iter_mut().zip(data.iter()).zip(xty.iter()) {
                *gi += self.dequant.dequantize_entry(q) - t;
            }
        }
        let batch_rows = (blocks.len() * self.rows) as f64;
        for gi in g.iter_mut() {
            *gi /= batch_rows;
        }
        g
    }

    fn loss(&self, w: &[f64], x: &[f64], m: usize, d: usize) -> f64 {
        let ds = Dataset::new(x.to_vec(), self.y.clone(), m, d, "quantized-train");
        LogisticRegression::with_weights(w.to_vec()).loss(&ds)
    }

    fn accuracy(&self, w: &[f64], test: &Dataset) -> Option<f64> {
        Some(LogisticRegression::with_weights(w.to_vec()).accuracy(test))
    }

    fn default_eta(&self, x: &[f64], m: usize, d: usize) -> f64 {
        // η = 1/L (Lemma 2, scaled by 1/m like the cost).
        let l = 0.25 * max_eig_xtx(x, m, d, 30) / m as f64;
        if l > 0.0 {
            1.0 / l
        } else {
            1.0
        }
    }
}

/// Remark 1: linear regression. Workers hold coded labels ỹ and return
/// X̃ᵀ(X̃w̃ − ỹ) — a degree-3 polynomial, the same recovery threshold as
/// logistic at r = 1 — so the decoded blocks *are* the (unnormalized)
/// sub-gradients; no master-side label term.
pub struct LinearObjective {
    dequant: Dequantizer,
    /// Labels quantized at scale 2^(l_x+l_w) so ȳ matches X̄w̄'s scale.
    ybar: Vec<u64>,
    /// The real values ȳ represents — the regression view the loss and
    /// convergence checks are stated on.
    ybar_real: Vec<f64>,
    m: usize,
    rows: usize,
    d: usize,
}

impl LinearObjective {
    pub fn new(cfg: &CodedMlConfig, y: &[f64], m: usize, d: usize, k: usize) -> Self {
        let field = cfg.field();
        // X̄w̄ carries scale l_x + l_w, so the labels quantize at l_y =
        // l_x + l_w and f = X̄ᵀ(X̄w̄ − ȳ) dequantizes at l_x + (l_x + l_w)
        // — exactly the logistic scale with l_c = 0, r = 1.
        let ly = cfg.lx + cfg.lw;
        let scale = (1u64 << ly) as f64;
        let ybar: Vec<u64> = y
            .iter()
            .map(|&v| phi(&field, round_half_up(scale * v)))
            .collect();
        let ybar_real: Vec<f64> = ybar.iter().map(|&q| phi_inv(&field, q) as f64 / scale).collect();
        LinearObjective {
            dequant: Dequantizer::new(field, cfg.lx, cfg.lw, 0, 1),
            ybar,
            ybar_real,
            m,
            rows: m / k,
            d,
        }
    }

    /// The dequantized label vector (tests compare decoded gradients
    /// against plaintext gradients on exactly this view).
    pub fn labels_real(&self) -> &[f64] {
        &self.ybar_real
    }
}

impl CodedObjective for LinearObjective {
    fn name(&self) -> &'static str {
        "linear"
    }

    fn weight_draws(&self) -> usize {
        1
    }

    fn worker_op(&self) -> WorkerOp {
        WorkerOp::Linear
    }

    fn worker_coeffs(&self) -> Vec<u64> {
        // The Linear op never evaluates these; the backend constructor
        // just needs a well-formed degree-1 coefficient vector.
        vec![0, 1]
    }

    fn label_shares(&self, encoder: &Encoder, rng: &mut Rng) -> Option<Vec<Vec<u64>>> {
        Some(
            encoder
                .encode_dataset(&self.ybar, self.m, 1, rng)
                .into_iter()
                .map(|s| s.data)
                .collect(),
        )
    }

    fn gradient(&self, blocks: &[(usize, Vec<u64>)]) -> Vec<f64> {
        let mut g = vec![0.0f64; self.d];
        for (_, data) in blocks {
            for (gi, &q) in g.iter_mut().zip(data.iter()) {
                *gi += self.dequant.dequantize_entry(q);
            }
        }
        let batch_rows = (blocks.len() * self.rows) as f64;
        for gi in g.iter_mut() {
            *gi /= batch_rows;
        }
        g
    }

    fn loss(&self, w: &[f64], x: &[f64], m: usize, d: usize) -> f64 {
        LinearRegression::with_weights(w.to_vec()).loss(x, &self.ybar_real, m, d)
    }

    fn accuracy(&self, _w: &[f64], _test: &Dataset) -> Option<f64> {
        None // 0/1 accuracy is not defined for regression targets
    }

    fn default_eta(&self, x: &[f64], m: usize, d: usize) -> f64 {
        let l = max_eig_xtx(x, m, d, 30) / m as f64;
        if l > 0.0 {
            1.0 / l
        } else {
            1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::PAPER_PRIME;

    fn cfg() -> CodedMlConfig {
        CodedMlConfig { p: PAPER_PRIME, lx: 4, lw: 6, ..Default::default() }
    }

    #[test]
    fn linear_label_quantization_round_trips_on_grid() {
        // Values on the 2^-(lx+lw) grid are represented exactly.
        let y = [0.5, -1.25, 0.0, 2.0];
        let obj = LinearObjective::new(&cfg(), &y, 4, 2, 2);
        assert_eq!(obj.labels_real(), &y);
    }

    #[test]
    fn linear_gradient_sums_and_normalizes_blocks() {
        let cfg = cfg();
        let f = cfg.field();
        let obj = LinearObjective::new(&cfg, &[0.0; 8], 8, 2, 2); // rows = 4
        // Decoded entries represent integers at scale 2^(2lx+lw) = 2^14.
        let one = phi(&f, 1 << 14); // represents 1.0
        let blocks = vec![(0usize, vec![one, 0]), (1usize, vec![one, one])];
        let g = obj.gradient(&blocks);
        // Batch rows = 2 blocks × 4 rows; sums are [2.0, 1.0].
        assert_eq!(g, vec![2.0 / 8.0, 1.0 / 8.0]);
        // A single-block batch normalizes by that block's rows only.
        let g1 = obj.gradient(&blocks[1..]);
        assert_eq!(g1, vec![0.25, 0.25]);
    }

    #[test]
    fn logistic_gradient_subtracts_batch_local_label_term() {
        let cfg = CodedMlConfig::default(); // lx=2, lw=4, lc=3, r=1
        let f = cfg.field();
        // Two blocks of one row each: X̄ = [[1, 0], [0, 1]], y = [1, 0].
        let xbar_real = [1.0, 0.0, 0.0, 1.0];
        let y = [1.0, 0.0];
        let obj = LogisticObjective::new(&cfg, &xbar_real, &y, 2, 2, 2);
        let l = crate::quant::dequant_scale_bits(cfg.lx, cfg.lw, cfg.lc, cfg.r as u32);
        let half = phi(&f, (1i64 << l) / 2); // decoded entry representing 0.5
        // Block 0 decodes to [0.5, 0]; block 1 to [0, 0.5].
        let blocks = vec![(0usize, vec![half, 0]), (1usize, vec![0, half])];
        let g = obj.gradient(&blocks);
        // X̄ᵀy per block: block 0 → [1, 0], block 1 → [0, 0].
        // g = ([0.5-1, 0] + [0, 0.5-0]) / 2 rows = [-0.25, 0.25].
        assert_eq!(g, vec![-0.25, 0.25]);
        // Single-block batch uses only that block's label term.
        let g0 = obj.gradient(&blocks[..1]);
        assert_eq!(g0, vec![-0.5, 0.0]);
    }

    #[test]
    fn objective_names_and_draws() {
        let lin = LinearObjective::new(&cfg(), &[0.0; 4], 4, 2, 2);
        assert_eq!(lin.name(), "linear");
        assert_eq!(lin.weight_draws(), 1);
        assert_eq!(lin.worker_op(), WorkerOp::Linear);
        let mut cfg2 = CodedMlConfig::default();
        cfg2.r = 2;
        cfg2.n = 11;
        cfg2.k = 2;
        let log = LogisticObjective::new(&cfg2, &[0.0; 8], &[0.0; 4], 4, 2, 2);
        assert_eq!(log.name(), "logistic");
        assert_eq!(log.weight_draws(), 2);
        assert_eq!(log.worker_op(), WorkerOp::Logistic);
        assert_eq!(log.worker_coeffs().len(), 3); // degree-2 polynomial
    }
}
