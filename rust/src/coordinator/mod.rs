//! The CodedPrivateML master (paper Algorithm 1, Remark 1).
//!
//! Orchestrates the full training loop over the simulated [`crate::cluster`]
//! as a streaming round engine: quantize → Lagrange-encode → dispatch →
//! consume results as they arrive and stop at the fastest R →
//! interpolation-decode → dequantize → gradient update, with the
//! encode/comm/comp timing breakdown the paper reports in Tables 1–6.
//! Everything algorithm-specific (worker polynomial, gradient assembly,
//! loss) is behind the [`CodedObjective`] trait — logistic regression is
//! Algorithm 1, linear regression is Remark 1.

mod config;
mod objective;
mod report;
mod session;
mod trace;

pub use config::{CodedMlConfig, CompMode, ConfigError, ModelKind};
pub use objective::{CodedObjective, LinearObjective, LogisticObjective};
pub use report::{IterationMetrics, ServeReport, SessionSummary, TimingBreakdown, TrainReport};
pub use session::{CodedMlSession, DetachedSession, TrainError};
pub use trace::Tracer;
