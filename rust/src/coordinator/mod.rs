//! The CodedPrivateML master (paper Algorithm 1).
//!
//! Orchestrates the full training loop over the simulated [`crate::cluster`]:
//! quantize → Lagrange-encode → dispatch → collect the fastest R results →
//! interpolation-decode → dequantize → gradient update, with the
//! encode/comm/comp timing breakdown the paper reports in Tables 1–6.

mod config;
mod report;
mod session;
mod trace;

pub use config::{CodedMlConfig, CompMode, ConfigError};
pub use report::{IterationMetrics, TimingBreakdown, TrainReport};
pub use session::{CodedMlSession, TrainError};
pub use trace::Tracer;
