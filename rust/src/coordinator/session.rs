//! The training session: a streaming round engine (dispatch → consume
//! results as they arrive → decode the fastest R) driving a pluggable
//! [`CodedObjective`] — paper Algorithm 1 when the objective is logistic,
//! Remark 1 when it is linear.

use super::config::{CodedMlConfig, CompMode, ConfigError};
use super::objective::{CodedObjective, LinearObjective, LogisticObjective};
use super::report::{IterationMetrics, TimingBreakdown, TrainReport};
use crate::cluster::{Cluster, ClusterError, DeadlineController, Round, Supervisor, WorkerSpec};
use crate::coding::decoder::WorkerResult;
use crate::coding::{
    CodingBackend, CodingBackendChoice, CodingParams, DecodeError, Decoder, Encoder, EvalPoints,
};
use crate::data::Dataset;
use crate::field::PrimeField;
use crate::model::matvec;
use crate::quant::{DatasetQuantizer, WeightQuantizer};
use crate::util::timer::Deadline;
use crate::util::{Rng, Stopwatch};

/// Errors surfaced during training.
#[derive(Debug)]
pub enum TrainError {
    Config(ConfigError),
    Cluster(ClusterError),
    Decode(DecodeError),
    /// More workers failed than the straggler slack allows.
    TooManyFailures { ok: usize, need: usize },
    /// [`CodedMlSession::step`] was called on a detached session — one
    /// built for the serve scheduler, which owns the shared cluster and
    /// drives rounds through `begin_round`/`collect_round`/`finish_round`.
    Detached,
}

impl std::fmt::Display for TrainError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TrainError::Config(e) => write!(f, "{e}"),
            TrainError::Cluster(e) => write!(f, "{e}"),
            TrainError::Decode(e) => write!(f, "{e}"),
            TrainError::TooManyFailures { ok, need } => {
                write!(f, "only {ok} workers produced results, need {need}")
            }
            TrainError::Detached => {
                write!(f, "session is detached; drive it through the serve scheduler")
            }
        }
    }
}

impl std::error::Error for TrainError {}

impl From<ConfigError> for TrainError {
    fn from(e: ConfigError) -> Self {
        TrainError::Config(e)
    }
}
impl From<ClusterError> for TrainError {
    fn from(e: ClusterError) -> Self {
        TrainError::Cluster(e)
    }
}
impl From<DecodeError> for TrainError {
    fn from(e: DecodeError) -> Self {
        TrainError::Decode(e)
    }
}

/// A live CodedPrivateML training session: cluster spawned, dataset
/// encoded and secret-shared, ready to iterate. Generic over the
/// [`CodedObjective`] being trained; [`CodedMlSession::new`] builds the
/// paper's logistic session, [`CodedMlSession::new_linear`] the Remark-1
/// linear-regression one.
pub struct CodedMlSession<O: CodedObjective = LogisticObjective> {
    cfg: CodedMlConfig,
    field: PrimeField,
    params: CodingParams,
    encoder: Encoder,
    decoder: Decoder,
    /// The dedicated cluster. `None` for a detached session — one driven
    /// over a *shared* pool by [`crate::serve::Scheduler`], which owns the
    /// cluster and passes it into `begin_round`/`collect_round`/
    /// `finish_round` explicitly.
    cluster: Option<Cluster>,
    /// Session id stamped into every frame this session sends (and
    /// checked on every result it absorbs). 0 for a dedicated session;
    /// unique per job under the serve scheduler.
    session_id: u64,
    objective: O,
    wquant: WeightQuantizer,
    /// Quantized dataset (field form, kept for ground-truth tests).
    pub xbar: Vec<u64>,
    /// Dequantized dataset — the X̄ the convergence theorem is stated on.
    xbar_real: Vec<f64>,
    /// Current weights (real domain).
    pub w: Vec<f64>,
    pub eta: f64,
    m: usize,
    d: usize,
    rows: usize,
    rng: Rng,
    /// Independent stream for the *modeled* straggler delays so the timing
    /// simulation never perturbs masks or stochastic quantization. (The
    /// decoded subset is whatever actually arrived first — LCC decoding is
    /// exact for any subset, so the training trajectory is invariant;
    /// tested below and in rust/tests/round_engine.rs.)
    straggle_rng: Rng,
    // timers
    t_encode: Stopwatch,
    t_comm: Stopwatch,
    t_comp: Stopwatch,
    t_decode: Stopwatch,
    bytes_sent: u64,
    bytes_received: u64,
    iter: u64,
    /// Failed worker steps observed (surfaced in [`TrainReport`] and as
    /// `worker_failure` tracer events).
    failures: u64,
    /// Stale results drained by later rounds without decoding.
    late: u64,
    /// Worker supervision (revive + re-dispatch), present when
    /// `cfg.max_respawns > 0`. Owns clones of the specs and encoded
    /// shares so a revived worker is handed exactly its predecessor's
    /// data — never re-encoded, so exact decodes stay bit-identical.
    supervisor: Option<Supervisor>,
    /// Per-round deadline policy (static and/or adaptive).
    deadline_ctl: DeadlineController,
    /// Keep a copy of each round's dispatched weight shares so a heal can
    /// re-dispatch them mid-round. On for supervised dedicated sessions
    /// and always on for detached (scheduler-driven) ones.
    keep_weights: bool,
    /// The kept weight shares of the in-flight round (index = worker).
    inflight_w: Option<Vec<Vec<u64>>>,
    /// Deadline the in-flight round was collected under (ms), for resume
    /// and tracing.
    last_deadline_ms: u64,
    /// Clip bound handed to approximate decodes: tracked from the exact
    /// decodes actually seen (2× the largest centered lift), so a
    /// degraded round cannot produce estimates wildly outside the
    /// gradient range the run has exhibited.
    approx_clip: u64,
    approx_rounds: u64,
    max_approx_residual: f64,
    deadline_expired_rounds: u64,
    /// Overflow-budget warning from configuration time, surfaced through
    /// [`CodedMlSession::budget_warning`] instead of printed (the library
    /// never writes to stdio; the CLI decides what to show).
    budget_warning: Option<String>,
    tracer: super::trace::Tracer,
}

/// A session built for the serve scheduler: detached from any cluster,
/// plus everything the scheduler needs to attach it to the shared pool —
/// the per-worker specs (stamped with the session id) and the encoded
/// dataset shares, kept verbatim so pool heals re-ship the exact bytes.
pub struct DetachedSession<O: CodedObjective> {
    pub session: CodedMlSession<O>,
    pub specs: Vec<WorkerSpec>,
    pub x_shares: Vec<Vec<u64>>,
    pub y_shares: Option<Vec<Vec<u64>>>,
}

impl CodedMlSession<LogisticObjective> {
    /// Build the paper's logistic session: fit the sigmoid polynomial,
    /// quantize + encode + secret-share the dataset, spawn the cluster.
    /// The dataset is trimmed to a multiple of K rows.
    pub fn new(cfg: CodedMlConfig, train: &Dataset) -> Result<Self, TrainError> {
        Self::build(cfg, train, |cfg, xbar_real, y, m, d, k| {
            Ok(LogisticObjective::new(cfg, xbar_real, y, m, d, k))
        })
    }

    /// [`CodedMlSession::new`] without a cluster: encode and secret-share
    /// but leave attachment to the serve scheduler's shared pool.
    pub fn new_detached(
        cfg: CodedMlConfig,
        train: &Dataset,
        session_id: u64,
    ) -> Result<DetachedSession<LogisticObjective>, TrainError> {
        Self::build_parts(cfg, train, session_id, |cfg, xbar_real, y, m, d, k| {
            Ok(LogisticObjective::new(cfg, xbar_real, y, m, d, k))
        })
    }

    /// The sigmoid polynomial in use (diagnostics / ablations).
    pub fn sigmoid_poly(&self) -> &crate::sigmoid::SigmoidPoly {
        self.objective.sigmoid_poly()
    }
}

impl CodedMlSession<LinearObjective> {
    /// Build a coded linear-regression session (Remark 1): the labels are
    /// quantized at scale 2^(l_x+l_w) and secret-shared to the workers
    /// alongside X̃, and the worker op becomes X̃ᵀ(X̃w̃ − ỹ) — degree 3,
    /// so the recovery threshold matches logistic at r = 1 (enforced).
    pub fn new_linear(cfg: CodedMlConfig, train: &Dataset) -> Result<Self, TrainError> {
        Self::build(cfg, train, |cfg, _xbar_real, y, m, d, k| {
            if cfg.r != 1 {
                return Err(TrainError::Config(ConfigError::BadShape(format!(
                    "linear regression is a degree-3 worker polynomial (r = 1); got r = {}",
                    cfg.r
                ))));
            }
            Ok(LinearObjective::new(cfg, y, m, d, k))
        })
    }

    /// [`CodedMlSession::new_linear`] without a cluster: encode and
    /// secret-share but leave attachment to the serve scheduler's pool.
    pub fn new_linear_detached(
        cfg: CodedMlConfig,
        train: &Dataset,
        session_id: u64,
    ) -> Result<DetachedSession<LinearObjective>, TrainError> {
        Self::build_parts(cfg, train, session_id, |cfg, _xbar_real, y, m, d, k| {
            if cfg.r != 1 {
                return Err(TrainError::Config(ConfigError::BadShape(format!(
                    "linear regression is a degree-3 worker polynomial (r = 1); got r = {}",
                    cfg.r
                ))));
            }
            Ok(LinearObjective::new(cfg, y, m, d, k))
        })
    }

    /// The dequantized label view ȳ that the coded gradient targets.
    pub fn labels_real(&self) -> &[f64] {
        self.objective.labels_real()
    }
}

impl<O: CodedObjective> CodedMlSession<O> {
    fn build(
        cfg: CodedMlConfig,
        train: &Dataset,
        make_objective: impl FnOnce(
            &CodedMlConfig,
            &[f64],
            &[f64],
            usize,
            usize,
            usize,
        ) -> Result<O, TrainError>,
    ) -> Result<Self, TrainError> {
        let parts = Self::build_parts(cfg, train, 0, make_objective)?;
        let DetachedSession { mut session, specs, x_shares, y_shares } = parts;
        // Supervision needs the specs and the exact encoded shares kept
        // around so a revived worker can be re-shipped its predecessor's
        // data verbatim (re-encoding would draw fresh masks and break
        // bit-identical trajectories). Clone only when it is enabled.
        let sup_specs = (session.cfg.max_respawns > 0).then(|| specs.clone());
        let mut cluster = Cluster::connect(specs, &session.cfg.transport)?;
        session.supervisor = sup_specs.map(|sp| {
            Supervisor::new(sp, x_shares.clone(), y_shares.clone(), session.cfg.max_respawns)
        });
        session.keep_weights = session.supervisor.is_some();
        cluster.load_data(x_shares, y_shares)?;
        session.cluster = Some(cluster);
        Ok(session)
    }

    /// Everything [`CodedMlSession::build`] does except spawning a
    /// cluster: the session comes back detached, alongside its worker
    /// specs and encoded shares, for the serve scheduler to attach to a
    /// shared pool. A detached session keeps its dispatched weights every
    /// round (the scheduler re-dispatches them on pool heals) and never
    /// owns a [`Supervisor`] — healing shared workers is the scheduler's
    /// job, since a revive tears down every session's engine on that
    /// worker.
    fn build_parts(
        cfg: CodedMlConfig,
        train: &Dataset,
        session_id: u64,
        make_objective: impl FnOnce(
            &CodedMlConfig,
            &[f64],
            &[f64],
            usize,
            usize,
            usize,
        ) -> Result<O, TrainError>,
    ) -> Result<DetachedSession<O>, TrainError> {
        let params = cfg.coding_params()?;
        let field = cfg.field();
        let ds = train.take_rows_multiple_of(train.m, params.k);
        let (m, d) = (ds.m, ds.d);
        let rows = m / params.k;

        // Budget check (warn or error per config). The warning is kept on
        // the session rather than printed — stdio belongs to the CLI.
        let rep = cfg.validate(m, ds.max_abs_x())?;
        let budget_warning = (!rep.ok()).then(|| {
            format!(
                "overflow budget utilization {:.2} > 1 — decoded gradients \
                 may wrap; consider k>{}, smaller l_c, or a larger prime",
                rep.utilization, params.k
            )
        });

        let mut rng = Rng::new(cfg.seed);
        let straggle_rng = Rng::new(cfg.seed ^ 0x5742_4751_4c45);

        let mut t_encode = Stopwatch::new();
        let mut t_comm = Stopwatch::new();

        // One encoder for the whole session — the dataset and the
        // per-iteration weight encodes share its eval points and its
        // lazily-built U matrix (or NTT plans), instead of each building
        // their own as earlier revisions did.
        let encoder = Self::make_encoder(&cfg, field, params)?;
        let decoder = Decoder::new(field, params, encoder.points.clone())
            .with_cache_cap(cfg.decode_cache_cap)
            .with_parallelism(cfg.parallelism);

        // Quantize + encode + secret-share the dataset (one-time).
        let xq = DatasetQuantizer::new(field, cfg.lx);
        let (xbar, shares) = t_encode.time(|| {
            let xbar = xq.quantize(&ds.x);
            let shares = encoder.encode_dataset(&xbar, m, d, &mut rng);
            (xbar, shares)
        });

        // Real-domain views the master needs.
        let xbar_real: Vec<f64> = xbar.iter().map(|&q| xq.dequantize_entry(q)).collect();
        let objective = make_objective(&cfg, &xbar_real, &ds.y, m, d, params.k)?;

        // Coded labels (linear only) — encode time + one more broadcast.
        let y_shares = t_encode.time(|| objective.label_shares(&encoder, &mut rng));

        // Model the dataset broadcast (optionally bit-packed on the wire).
        let mut share_bytes = if cfg.packed_wire {
            encoder.packed_share_bytes(m, d)
        } else {
            encoder.share_bytes(m, d)
        };
        if y_shares.is_some() {
            share_bytes += if cfg.packed_wire {
                encoder.packed_share_bytes(m, 1)
            } else {
                encoder.share_bytes(m, 1)
            };
        }
        t_comm.add_seconds(cfg.net.fanout_time(params.n, share_bytes));
        let bytes_sent = share_bytes * params.n as u64;

        // Spawn workers & deliver shares.
        let coeffs = objective.worker_coeffs();
        let op = objective.worker_op();
        let specs: Vec<WorkerSpec> = (0..params.n)
            .map(|id| WorkerSpec {
                id,
                session: session_id,
                kind: cfg.backend,
                artifact_dir: cfg.artifact_dir.clone(),
                field,
                rows,
                d,
                coeffs: coeffs.clone(),
                op,
                // Chaos hooks: the first `chaos_failures` workers die at
                // `chaos_from_iter`; the `chaos_slow_workers` workers from
                // `chaos_slow_from` drag every step by `chaos_slow_ms` (the
                // round engine must leave them behind, not wait —
                // resilience tests; the serve bench offsets the span so
                // concurrent sessions straggle on disjoint workers).
                fail_from_iter: (id < cfg.chaos_failures).then_some(cfg.chaos_from_iter),
                slow_ms: if id >= cfg.chaos_slow_from
                    && id < cfg.chaos_slow_from + cfg.chaos_slow_workers
                {
                    cfg.chaos_slow_ms
                } else {
                    0
                },
                par: cfg.parallelism,
            })
            .collect();
        let x_data: Vec<Vec<u64>> = shares.into_iter().map(|s| s.data).collect();

        let eta = cfg
            .eta
            .unwrap_or_else(|| objective.default_eta(&xbar_real, m, d));
        let wquant = WeightQuantizer::new(field, cfg.lw, objective.weight_draws() as u32);
        let deadline_ctl = DeadlineController::new(cfg.round_deadline_ms, cfg.adaptive_deadline);

        let session = CodedMlSession {
            cfg,
            field,
            params,
            encoder,
            decoder,
            cluster: None,
            session_id,
            objective,
            wquant,
            xbar,
            xbar_real,
            w: vec![0.0; d],
            eta,
            m,
            d,
            rows,
            rng,
            straggle_rng,
            t_encode,
            t_comm,
            t_comp: Stopwatch::new(),
            t_decode: Stopwatch::new(),
            bytes_sent,
            bytes_received: 0,
            iter: 0,
            failures: 0,
            late: 0,
            supervisor: None,
            deadline_ctl,
            keep_weights: true,
            inflight_w: None,
            last_deadline_ms: 0,
            approx_clip: (field.modulus() - 1) / 2,
            approx_rounds: 0,
            max_approx_residual: 0.0,
            deadline_expired_rounds: 0,
            budget_warning,
            tracer: super::trace::Tracer::disabled(),
        };
        Ok(DetachedSession { session, specs, x_shares: x_data, y_shares })
    }

    /// Resolve eval points + backend for `cfg.coding_backend`: `Dense`
    /// keeps the standard point grid; `Ntt` demands the roots-of-unity
    /// coset (a config error on low-adicity moduli); `Auto` takes the
    /// coset only when the encoder's cost model actually elects the NTT
    /// path for it, so Auto on small shapes behaves exactly like Dense.
    fn make_encoder(
        cfg: &CodedMlConfig,
        field: PrimeField,
        params: CodingParams,
    ) -> Result<Encoder, TrainError> {
        let base = |points: EvalPoints| {
            Encoder::with_points(field, params, points).with_parallelism(cfg.parallelism)
        };
        let standard = || EvalPoints::standard(&field, params.k, params.t, params.n);
        let ntt_points = EvalPoints::ntt_coset(&field, params.k, params.t, params.n);
        Ok(match cfg.coding_backend {
            CodingBackendChoice::Dense => base(standard()).force_dense(),
            CodingBackendChoice::Ntt => {
                let points = ntt_points.ok_or_else(|| {
                    let l2 = params
                        .n
                        .next_power_of_two()
                        .max((params.k + params.t).next_power_of_two());
                    ConfigError::BadShape(format!(
                        "coding_backend=ntt needs {l2} | p−1; p = {} has too \
                         little 2-adicity (try an NTT-friendly prime such as \
                         {} or {})",
                        field.modulus(),
                        crate::field::PRIME_NTT_25,
                        crate::field::PRIME_NTT_28,
                    ))
                })?;
                base(points).force_ntt()
            }
            CodingBackendChoice::Auto => match ntt_points {
                Some(points) => {
                    let enc = base(points);
                    if enc.backend() == CodingBackend::Ntt {
                        enc
                    } else {
                        base(standard())
                    }
                }
                None => base(standard()),
            },
        })
    }

    /// The encode/decode backend this session resolved to.
    pub fn coding_backend(&self) -> CodingBackend {
        self.encoder.backend()
    }

    /// Attach a tracer (JSONL per-phase events; see [`super::Tracer`]).
    pub fn set_tracer(&mut self, tracer: super::trace::Tracer) {
        self.tracer = tracer;
    }

    /// Access collected in-memory trace events (tests/diagnostics).
    pub fn tracer(&self) -> &super::trace::Tracer {
        &self.tracer
    }

    pub fn params(&self) -> CodingParams {
        self.params
    }

    /// The objective being trained.
    pub fn objective(&self) -> &O {
        &self.objective
    }

    /// (worker failures, late results drained) so far — the round
    /// engine's resilience counters, also carried by [`TrainReport`].
    pub fn round_stats(&self) -> (u64, u64) {
        (self.failures, self.late)
    }

    /// (approx rounds, max approx residual, respawns, deadline-expired
    /// rounds) so far — the supervision/degradation counters, also
    /// carried by [`TrainReport`].
    pub fn fault_stats(&self) -> (u64, f64, u64, u64) {
        (
            self.approx_rounds,
            self.max_approx_residual,
            self.supervisor.as_ref().map(|s| s.respawns).unwrap_or(0),
            self.deadline_expired_rounds,
        )
    }

    /// Overflow-budget warning raised at configuration time, if any.
    /// The session never prints; callers decide whether to surface this.
    pub fn budget_warning(&self) -> Option<&str> {
        self.budget_warning.as_deref()
    }

    /// Cumulative `(sent, received)` bytes actually moved by the cluster
    /// transport, in frame-layout units on both backends — distinct from
    /// [`TrainReport`]'s *modeled* byte counts, which account the paper's
    /// protocol (optionally bit-packed) rather than this build's wire.
    pub fn transport_bytes(&self) -> (u64, u64) {
        self.cluster.as_ref().map(Cluster::wire_bytes).unwrap_or((0, 0))
    }

    /// This session's routing id (0 for dedicated sessions).
    pub fn session_id(&self) -> u64 {
        self.session_id
    }

    /// Deadline (ms) the in-flight round was collected under — the serve
    /// scheduler resumes a healed round under the same budget. 0 = none.
    pub fn last_deadline_ms(&self) -> u64 {
        self.last_deadline_ms
    }

    /// The configuration the session was built with.
    pub fn config(&self) -> &CodedMlConfig {
        &self.cfg
    }

    /// The iteration the next round will run (rounds completed so far).
    pub fn current_iter(&self) -> u64 {
        self.iter
    }

    /// Wire size of `count` field elements under the configured framing
    /// (raw u64 or bit-packed to the field width — util::bitpack).
    fn wire_bytes(&self, count: usize) -> u64 {
        if self.cfg.packed_wire {
            crate::util::bitpack::packed_len(count, self.field.bits()) as u64
        } else {
            (count * 8) as u64
        }
    }

    pub fn dims(&self) -> (usize, usize) {
        (self.m, self.d)
    }

    /// The row blocks iteration `iter` decodes and applies: all K when
    /// `batch_blocks` is 0 (full batch), else a `batch_blocks`-wide window
    /// rotating over the K blocks each round.
    fn batch_for(&self, iter: u64) -> Vec<usize> {
        let k = self.params.k;
        let b = if self.cfg.batch_blocks == 0 { k } else { self.cfg.batch_blocks.min(k) };
        let start = (iter as usize * b) % k;
        (0..b).map(|i| (start + i) % k).collect()
    }

    /// One round of the streaming engine; returns the real-domain gradient
    /// it applied (before the weight update) for inspection:
    ///
    /// 1. quantize + encode the weights, dispatch to all N workers;
    /// 2. consume [`crate::cluster::StepResult`]s in actual arrival order
    ///    and return from collection as soon as the fastest R usable
    ///    results land ([`Cluster::collect_first`]) — late results are
    ///    drained by *later* rounds and never decoded;
    /// 3. feed the fastest-R subset straight into the per-subset-cached
    ///    decoder (only this round's batch blocks), assemble the
    ///    objective's gradient, update the weights.
    pub fn step(&mut self) -> Result<Vec<f64>, TrainError> {
        let mut cluster = self.cluster.take().ok_or(TrainError::Detached)?;
        let out = self.step_on(&mut cluster);
        self.cluster = Some(cluster);
        out
    }

    /// [`CodedMlSession::step`] against an explicit cluster — the
    /// composition of [`begin_round`](Self::begin_round),
    /// [`collect_round`](Self::collect_round), the dedicated-mode
    /// supervision pass, and [`finish_round`](Self::finish_round).
    fn step_on(&mut self, cluster: &mut Cluster) -> Result<Vec<f64>, TrainError> {
        self.begin_round(cluster)?;
        let mut round = self.collect_round(cluster)?;

        // Supervision: revive this round's failed workers within the
        // respawn budget. A mid-round heal re-dispatches the weights
        // and reopens the round, and collection resumes under a
        // fresh deadline — unless the controller pre-armed degraded
        // mode after a streak of expired rounds.
        if let Some(mut sup) = self.supervisor.take() {
            let w_kept = self.inflight_w.take();
            sup.observe_round(&round);
            let w_ref: &[Vec<u64>] = w_kept.as_deref().unwrap_or(&[]);
            let outcomes = sup.heal(cluster, &mut round, w_ref);
            if self.tracer.enabled() {
                use crate::util::json::Json;
                for o in &outcomes {
                    self.tracer.event(
                        "worker.respawn",
                        self.iter,
                        &[
                            ("worker", Json::Num(o.worker as f64)),
                            ("attempt", Json::Num(o.respawn as f64)),
                            ("ok", Json::Bool(o.result.is_ok())),
                            ("redispatched", Json::Bool(o.redispatched)),
                        ],
                    );
                }
            }
            let reopened = outcomes.iter().any(|o| o.redispatched);
            if reopened && !round.ok() && !self.deadline_ctl.pre_arm_approx() {
                cluster.collect_resume(&mut round, &Deadline::after_ms(self.last_deadline_ms))?;
            }
            self.supervisor = Some(sup);
        }

        self.finish_round(cluster, round)
    }

    /// Phases 1–2 of a round: quantize + encode this iteration's weights
    /// (consuming the session RNG exactly as a dedicated run would) and
    /// dispatch them to all N workers under this session's id. The serve
    /// scheduler calls this directly; [`step`](Self::step) composes it
    /// with the other round phases.
    pub fn begin_round(&mut self, cluster: &mut Cluster) -> Result<(), TrainError> {
        let (n, d) = (self.params.n, self.d);
        let draws = self.objective.weight_draws();

        // (1) Quantize weights (independent stochastic draws) + encode
        //     with fresh masks — both count as encode time.
        let w_shares = {
            let rng = &mut self.rng;
            let (wquant, encoder, w) = (&self.wquant, &self.encoder, &self.w);
            self.t_encode.time(|| {
                let wq = wquant.quantize(w, rng);
                encoder.encode_weights(&wq, d, draws, rng)
            })
        };

        // (2) Master → workers: W̃ shares. A heal may need to re-dispatch
        //     this iteration's weights to a revived worker mid-round;
        //     keep a copy only when someone can ask for that.
        let wbytes = self.wire_bytes(d * draws);
        self.t_comm.add_seconds(self.cfg.net.fanout_time(n, wbytes));
        self.bytes_sent += wbytes * n as u64;
        let w_data: Vec<Vec<u64>> = w_shares.into_iter().map(|s| s.data).collect();
        self.inflight_w = self.keep_weights.then(|| w_data.clone());
        cluster.dispatch_for(self.session_id, self.iter, w_data)?;
        Ok(())
    }

    /// Phase 3: stream arrivals for this session until the fastest R
    /// usable results land, or the round deadline (static and/or
    /// adaptive) fires — whichever comes first. An expired deadline
    /// charges every silent worker a round failure instead of blocking
    /// forever. Results for other sessions sharing the pool are parked by
    /// the cluster, never absorbed here.
    pub fn collect_round(&mut self, cluster: &mut Cluster) -> Result<Round, TrainError> {
        let need = self.params.recovery_threshold();
        let deadline_ms = self.deadline_ctl.next_deadline_ms();
        self.last_deadline_ms = deadline_ms;
        let round = cluster.collect_deadline_for(
            self.session_id,
            need,
            self.iter,
            &Deadline::after_ms(deadline_ms),
        )?;
        Ok(round)
    }

    /// Re-send the in-flight round's kept weights to one worker (the
    /// serve scheduler's heal path after reviving a shared worker).
    /// No-op when no round is in flight. A send failure re-marks the
    /// worker down; the round then charges it as a failure.
    pub fn redispatch(&mut self, cluster: &mut Cluster, worker: usize) -> Result<(), String> {
        match self.inflight_w.as_ref().and_then(|ws| ws.get(worker)) {
            Some(w) => cluster.dispatch_to_for(self.session_id, worker, self.iter, w.clone()),
            None => Ok(()),
        }
    }

    /// Phases 4–6: account the collected round (failures, deadlines,
    /// modeled timing, wire bytes), run the degrade-or-abort ladder,
    /// decode this round's batch blocks, and apply the gradient update.
    pub fn finish_round(
        &mut self,
        cluster: &mut Cluster,
        round: Round,
    ) -> Result<Vec<f64>, TrainError> {
        let need = self.params.recovery_threshold();
        let (n, d) = (self.params.n, self.d);
        self.late += round.late_drained as u64;
        // A failure is a failure whichever round's drain observed it —
        // stale Errs (late_failures) still count and still trace, and so
        // do failures that a mid-round heal later recovered from.
        self.failures +=
            (round.failures.len() + round.late_failures.len() + round.healed.len()) as u64;
        if self.tracer.enabled() {
            use crate::util::json::Json;
            for (worker, error) in round
                .failures
                .iter()
                .chain(round.late_failures.iter())
                .chain(round.healed.iter())
            {
                self.tracer.event(
                    "worker_failure",
                    self.iter,
                    &[
                        ("worker", Json::Num(*worker as f64)),
                        ("error", Json::Str(error.clone())),
                    ],
                );
            }
        }
        if round.deadline_expired {
            self.deadline_expired_rounds += 1;
            if self.tracer.enabled() {
                use crate::util::json::Json;
                self.tracer.event(
                    "round.deadline",
                    self.iter,
                    &[
                        ("deadline_ms", Json::Num(self.last_deadline_ms as f64)),
                        ("results", Json::Num(round.results.len() as f64)),
                        ("need", Json::Num(need as f64)),
                        ("pre_armed", Json::Bool(self.deadline_ctl.pre_arm_approx())),
                    ],
                );
            }
        }

        // Degrade-or-abort ladder: a round short of R either falls back
        // to approximate decoding (when enabled and at least
        // max(approx_r_min, K+T) usable results arrived) or aborts with a
        // structured error.
        let usable = round.results.len();
        let r_min = self.cfg.approx_r_min.max(self.params.k + self.params.t);
        let use_approx = !round.ok() && self.cfg.approx_decode && usable >= r_min;
        if !round.ok() && !use_approx {
            return Err(TrainError::TooManyFailures { ok: usable, need });
        }

        // Modeled parallel time (the paper's N-independent-machines
        // semantics): the R-th order statistic over the healthy workers of
        // (compute + sampled straggle). The early exit leaves the
        // stragglers' computes unmeasured; the coded blocks are
        // equal-sized, so approximate those with the collected mean.
        let mean_compute = round.results.iter().map(|r| r.compute_secs).sum::<f64>()
            / round.results.len() as f64;
        let healthy = n - round.failures.len();
        let mut arrivals: Vec<f64> = (0..healthy)
            .map(|i| {
                let compute = round
                    .results
                    .get(i)
                    .map(|r| r.compute_secs)
                    .unwrap_or(mean_compute);
                compute + self.cfg.straggler.sample(&mut self.straggle_rng, compute)
            })
            .collect();
        arrivals.sort_by(f64::total_cmp);
        let iter_comp = match self.cfg.comp_mode {
            // Degraded rounds can leave fewer than R healthy workers; the
            // R-th order statistic then degenerates to the slowest
            // arrival actually observed.
            CompMode::ModeledParallel => {
                let idx = (need - 1).min(arrivals.len().saturating_sub(1));
                arrivals.get(idx).copied().unwrap_or(round.wall_secs)
            }
            CompMode::Wall => round.wall_secs,
        };
        self.t_comp.add_seconds(iter_comp);
        let (wire_sent, wire_received) = cluster.wire_bytes();
        if self.tracer.enabled() {
            use crate::util::json::Json;
            let used: Vec<Json> = round
                .results
                .iter()
                .map(|r| Json::Num(r.worker as f64))
                .collect();
            self.tracer.event(
                "collect",
                self.iter,
                &[
                    ("comp_modeled_s", Json::Num(iter_comp)),
                    ("wall_s", Json::Num(round.wall_secs)),
                    ("fastest", Json::Arr(used)),
                    ("late", Json::Num(round.late_drained as f64)),
                    ("failed", Json::Num(round.failures.len() as f64)),
                    ("transport", Json::Str(cluster.transport_name().to_string())),
                    ("wire_sent", Json::Num(wire_sent as f64)),
                    ("wire_received", Json::Num(wire_received as f64)),
                ],
            );
        }

        // (4) Workers → master: the result vectors that actually arrived
        //     (exactly R on a full round, R′ < R on a degraded one).
        let got = round.results.len();
        let rbytes = self.wire_bytes(d);
        self.t_comm.add_seconds(self.cfg.net.fanin_time(got, rbytes));
        self.bytes_received += rbytes * got as u64;

        // (5) Decode this round's batch blocks and assemble the gradient
        //     (per-block dequantization keeps the overflow budget at m/K
        //     rows — DESIGN.md §Numeric design).
        // `Round::absorb` only admits Ok results, but stay defensive: an
        // Err here is counted as a failure (and traced) rather than
        // panicking; if that leaves fewer than R results the decoder
        // reports the shortfall as a DecodeError.
        let mut worker_results: Vec<WorkerResult> = Vec::with_capacity(round.results.len());
        for res in round.results {
            match res.data {
                Ok(data) => worker_results.push(WorkerResult { worker: res.worker, data }),
                Err(error) => {
                    self.failures += 1;
                    if self.tracer.enabled() {
                        use crate::util::json::Json;
                        self.tracer.event(
                            "worker_failure",
                            self.iter,
                            &[
                                ("worker", Json::Num(res.worker as f64)),
                                ("error", Json::Str(error)),
                            ],
                        );
                    }
                }
            }
        }
        let batch = self.batch_for(self.iter);
        let decoded = if use_approx {
            // Degraded mode: least-squares fit over the R′ < R available
            // evaluations. This is a liveness heuristic, not recovery —
            // with T ≥ 1 the missing information is cryptographically
            // gone — so the fit residual is surfaced for auditability
            // and the estimates are clipped to the range exact decodes
            // have exhibited.
            let clip = self.approx_clip;
            let decoder = &mut self.decoder;
            let approx = self
                .t_decode
                .time(|| decoder.decode_approx(&worker_results, d, &batch, clip))?;
            self.approx_rounds += 1;
            if approx.residual > self.max_approx_residual {
                self.max_approx_residual = approx.residual;
            }
            if self.tracer.enabled() {
                use crate::util::json::Json;
                self.tracer.event(
                    "decode.approx",
                    self.iter,
                    &[
                        ("r_prime", Json::Num(approx.used as f64)),
                        ("need", Json::Num(need as f64)),
                        ("residual", Json::Num(approx.residual)),
                        ("clip", Json::Num(clip as f64)),
                    ],
                );
            }
            approx.blocks
        } else {
            let decoder = &mut self.decoder;
            let decoded = self
                .t_decode
                .time(|| decoder.decode_blocks(&worker_results, d, &batch))?;
            // Keep the degraded-mode clip bound tracking reality: 2× the
            // largest centered lift the exact decodes have produced.
            if self.cfg.approx_decode {
                let p = self.field.modulus();
                let half = (p - 1) / 2;
                let max_lift = decoded
                    .iter()
                    .flat_map(|b| b.iter())
                    .map(|&v| if v > half { p - v } else { v })
                    .max()
                    .unwrap_or(0);
                self.approx_clip = max_lift.saturating_mul(2).clamp(1, half);
            }
            decoded
        };
        let blocks: Vec<(usize, Vec<u64>)> = batch.into_iter().zip(decoded).collect();
        let grad = self.objective.gradient(&blocks);

        // (6) Gradient update: w ← w − η·∇ (eq. 19 for logistic).
        for (w, &g) in self.w.iter_mut().zip(grad.iter()) {
            *w -= self.eta * g;
        }

        if self.tracer.enabled() {
            use crate::util::json::Json;
            self.tracer.event(
                "step",
                self.iter,
                &[
                    ("encode_total_s", Json::Num(self.t_encode.seconds())),
                    ("comm_total_s", Json::Num(self.t_comm.seconds())),
                    ("decode_total_s", Json::Num(self.t_decode.seconds())),
                    (
                        "coding_backend",
                        Json::Str(self.encoder.backend().name().to_string()),
                    ),
                ],
            );
        }
        // Feed the controller: observed wall time sharpens the next
        // adaptive deadline; an expiry extends the pre-arm streak.
        self.deadline_ctl.observe(round.wall_secs, round.deadline_expired);
        self.inflight_w = None;
        self.iter += 1;
        Ok(grad)
    }

    /// Loss of the current weights on the quantized training set (the
    /// quantity Theorem 1 bounds; objective-specific: cross-entropy for
    /// logistic, MSE for linear).
    pub fn train_loss(&self) -> f64 {
        self.objective.loss(&self.w, &self.xbar_real, self.m, self.d)
    }

    /// Accuracy of the current weights on a held-out set, when the
    /// objective defines one (regression objectives return None).
    pub fn accuracy(&self, test: &Dataset) -> Option<f64> {
        self.objective.accuracy(&self.w, test)
    }

    /// Run `iters` iterations, recording loss (and accuracy when a test
    /// set is given) each iteration.
    pub fn train(&mut self, iters: usize, test: Option<&Dataset>) -> Result<TrainReport, TrainError> {
        let mut iterations = Vec::with_capacity(iters);
        for it in 0..iters {
            self.step()?;
            iterations.push(IterationMetrics {
                iter: it,
                train_loss: self.train_loss(),
                test_accuracy: test.and_then(|ts| self.accuracy(ts)),
            });
        }
        Ok(self.report(iterations))
    }

    /// Estimated activation input range actually seen (diagnostics for
    /// choosing `fit_range`).
    pub fn activation_range(&self) -> (f64, f64) {
        let z = matvec(&self.xbar_real, &self.w, self.m, self.d);
        crate::util::stats::min_max(&z)
    }

    /// Assemble the [`TrainReport`] for the rounds run so far. [`train`]
    /// calls this with the metrics it recorded; the serve scheduler
    /// records per-iteration metrics itself and calls this at the end.
    ///
    /// [`train`]: Self::train
    pub fn report(&mut self, iterations: Vec<IterationMetrics>) -> TrainReport {
        TrainReport {
            breakdown: TimingBreakdown {
                encode_s: self.t_encode.seconds(),
                comm_s: self.t_comm.seconds(),
                // Master decode counts as computation.
                comp_s: self.t_comp.seconds() + self.t_decode.seconds(),
            },
            decode_s: self.t_decode.seconds(),
            iterations,
            weights: self.w.clone(),
            decode_cache: self.decoder.cache_stats(),
            decode_cache_evictions: self.decoder.cache_evictions(),
            coding_backend: self.encoder.backend().name(),
            recovery_threshold: self.params.recovery_threshold(),
            bytes_sent: self.bytes_sent,
            bytes_received: self.bytes_received,
            worker_failures: self.failures,
            late_results: self.late,
            approx_rounds: self.approx_rounds,
            max_approx_residual: self.max_approx_residual,
            respawns: self.supervisor.as_ref().map(|s| s.respawns).unwrap_or(0),
            deadline_expired_rounds: self.deadline_expired_rounds,
        }
    }
}

impl<O: CodedObjective> std::fmt::Debug for CodedMlSession<O> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CodedMlSession")
            .field("objective", &self.objective.name())
            .field("params", &self.params)
            .field("m", &self.m)
            .field("d", &self.d)
            .field("rows", &self.rows)
            .field("iter", &self.iter)
            .field("backend", &self.cfg.backend)
            .field("field", &self.field.modulus())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{NetworkModel, StragglerModel};
    use crate::data::{synthetic_3v7, synthetic_planted_linear};
    use crate::model::{tr_matvec, LinearRegression};

    fn quick_cfg(n: usize, k: usize, t: usize) -> CodedMlConfig {
        CodedMlConfig {
            n,
            k,
            t,
            straggler: StragglerModel::none(),
            net: NetworkModel::free(),
            ..Default::default()
        }
    }

    fn linear_cfg(n: usize, k: usize, t: usize) -> CodedMlConfig {
        CodedMlConfig {
            n,
            k,
            t,
            straggler: StragglerModel::none(),
            net: NetworkModel::free(),
            ..CodedMlConfig::linear()
        }
    }

    #[test]
    fn session_trains_and_loss_decreases() {
        let train = synthetic_3v7(120, 1);
        let test = synthetic_3v7(60, 2);
        let mut sess = CodedMlSession::new(quick_cfg(10, 3, 1), &train).unwrap();
        let l0 = sess.train_loss();
        let report = sess.train(10, Some(&test)).unwrap();
        let lf = report.final_loss().unwrap();
        assert!(lf < l0 * 0.8, "loss {l0} → {lf}");
        assert!(report.final_accuracy().unwrap() > 0.8);
        assert_eq!(report.iterations.len(), 10);
        assert_eq!(report.recovery_threshold, 10);
        assert!(report.breakdown.encode_s > 0.0);
        assert!(report.breakdown.comp_s > 0.0);
        assert_eq!(report.worker_failures, 0);
    }

    #[test]
    fn private_training_matches_quantized_plaintext_gradient() {
        // One step of CodedPrivateML must equal the plaintext update
        // computed with the same quantized data and the same stochastic
        // weight draws — here w₀ = 0 makes the quantization of w
        // deterministic (all zeros), so the check is exact-in-expectation
        // with zero variance at step 1.
        let train = synthetic_3v7(60, 3);
        let cfg = quick_cfg(10, 3, 1);
        let mut sess = CodedMlSession::new(cfg.clone(), &train).unwrap();
        let eta = sess.eta;
        let grad = sess.step().unwrap();

        // Plaintext: with w=0 every w̄ column is 0, so X̄w̄ = 0 and
        // ḡ = ĝ(0) entrywise; the applied gradient is (X̄ᵀḡ − X̄ᵀy)/m.
        let g0 = sess.sigmoid_poly().eval(0.0);
        let ds = train.take_rows_multiple_of(60, 3);
        let xq = crate::quant::DatasetQuantizer::new(cfg.field(), cfg.lx);
        let xbar = xq.quantize(&ds.x);
        let xbar_real: Vec<f64> = xbar.iter().map(|&q| xq.dequantize_entry(q)).collect();
        let ones_g: Vec<f64> = vec![g0; ds.m];
        let xtg = tr_matvec(&xbar_real, &ones_g, ds.m, ds.d);
        let xty = tr_matvec(&xbar_real, &ds.y, ds.m, ds.d);
        let expect: Vec<f64> = xtg
            .iter()
            .zip(xty.iter())
            .map(|(&a, &b)| (a - b) / ds.m as f64)
            .collect();
        for (a, b) in grad.iter().zip(expect.iter()) {
            // c̄₀ rounding introduces ≤ 2^-(lc + r(lx+lw)) per-row error,
            // times Σ|X̄|/m per column; keep a generous bound.
            assert!((a - b).abs() < 1.0 / ds.m as f64 + b.abs() * 0.01, "{a} vs {b}");
        }
        // And the weight moved in the -gradient direction: w = −η·∇.
        let manual: Vec<f64> = expect.iter().map(|&g| -eta * g).collect();
        for (a, b) in sess.w.iter().zip(manual.iter()) {
            assert!((a - b).abs() < 1e-3 + b.abs() * 0.02, "{a} vs {b}");
        }
    }

    #[test]
    fn straggling_does_not_change_results_only_timing() {
        let train = synthetic_3v7(60, 5);
        let mut cfg_a = quick_cfg(12, 3, 1);
        cfg_a.iters = 3;
        let mut cfg_b = cfg_a.clone();
        cfg_b.straggler = StragglerModel { shift: 0.5, rate: 2.0, relative: true };
        // Same seed → same masks/quantizations; decode is exact for any
        // arrival subset, so only the modeled timing may differ.
        let mut sa = CodedMlSession::new(cfg_a, &train).unwrap();
        let mut sb = CodedMlSession::new(cfg_b, &train).unwrap();
        let ra = sa.train(3, None).unwrap();
        let rb = sb.train(3, None).unwrap();
        for (wa, wb) in ra.weights.iter().zip(rb.weights.iter()) {
            assert!((wa - wb).abs() < 1e-12, "{wa} vs {wb}");
        }
    }

    #[test]
    fn tracer_records_phases() {
        let train = synthetic_3v7(60, 25);
        let mut sess = CodedMlSession::new(quick_cfg(10, 3, 1), &train).unwrap();
        sess.set_tracer(crate::coordinator::Tracer::memory());
        sess.step().unwrap();
        sess.step().unwrap();
        let events = sess.tracer().events();
        // Two iterations × (collect + step); no failures, so no
        // worker_failure events.
        assert_eq!(events.len(), 4);
        assert_eq!(events[0].get("event").unwrap().as_str(), Some("collect"));
        let fastest = events[0].get("fastest").unwrap().as_arr().unwrap();
        assert_eq!(fastest.len(), 10, "threshold-many workers recorded");
        assert_eq!(events[0].get("failed").unwrap().as_u64(), Some(0));
        assert_eq!(events[0].get("late").unwrap().as_u64(), Some(0));
        assert!(events[1].get("encode_total_s").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn packed_wire_reduces_bytes_not_results() {
        let train = synthetic_3v7(60, 23);
        let raw_cfg = quick_cfg(10, 3, 1);
        let mut packed_cfg = raw_cfg.clone();
        packed_cfg.packed_wire = true;
        let mut raw = CodedMlSession::new(raw_cfg, &train).unwrap();
        let mut packed = CodedMlSession::new(packed_cfg, &train).unwrap();
        let r_raw = raw.train(3, None).unwrap();
        let r_packed = packed.train(3, None).unwrap();
        assert_eq!(r_raw.weights, r_packed.weights, "framing must not change math");
        // 24-bit prime packs 64-bit words 8/3x smaller (± rounding).
        let ratio = r_raw.bytes_sent as f64 / r_packed.bytes_sent as f64;
        assert!((ratio - 64.0 / 24.0).abs() < 0.05, "ratio={ratio}");
    }

    #[test]
    fn chebyshev_fit_session_trains() {
        let train = synthetic_3v7(120, 24);
        let mut cfg = quick_cfg(10, 3, 1);
        cfg.fit_method = crate::sigmoid::FitMethod::Chebyshev;
        let mut sess = CodedMlSession::new(cfg, &train).unwrap();
        let report = sess.train(10, None).unwrap();
        assert!(report.final_loss().unwrap() < report.iterations[0].train_loss);
    }

    #[test]
    fn degree2_session_trains() {
        // r=2: two independent weight quantizations, degree-5 worker
        // polynomial, recovery threshold 5(K+T-1)+1.
        let train = synthetic_3v7(120, 21);
        let test = synthetic_3v7(120, 22);
        let cfg = CodedMlConfig {
            n: 11,
            k: 2,
            t: 1,
            r: 2,
            p: crate::field::PRIME_26, // r=2 scale needs the bigger budget
            straggler: StragglerModel::none(),
            net: NetworkModel::free(),
            ..Default::default()
        };
        let mut sess = CodedMlSession::new(cfg, &train).unwrap();
        assert_eq!(sess.params().recovery_threshold(), 11);
        let report = sess.train(12, Some(&test)).unwrap();
        assert!(report.final_accuracy().unwrap() > 0.8, "{report:?}");
        assert!(report.final_loss().unwrap() < report.iterations[0].train_loss);
    }

    #[test]
    fn report_accounts_bytes() {
        let train = synthetic_3v7(40, 7);
        let mut sess = CodedMlSession::new(quick_cfg(10, 2, 1), &train).unwrap();
        let rep = sess.train(2, None).unwrap();
        let (m, d) = sess.dims();
        // dataset: N shares of (m/K)·d u64 + 2 iterations of N·d·r u64.
        let expect_sent = (10 * (m / 2) * d * 8 + 2 * 10 * d * 8) as u64;
        assert_eq!(rep.bytes_sent, expect_sent);
        // received: 2 iterations × threshold(=7? K+T-1=2 → 3·2+1=7) × d.
        assert_eq!(rep.recovery_threshold, 7);
        assert_eq!(rep.bytes_received, (2 * 7 * d * 8) as u64);
    }

    #[test]
    fn linear_session_recovers_planted_model() {
        // Remark 1 end to end: coded linear regression on a planted task
        // converges to w*, with an MSE curve that never increases (the
        // identity activation makes the estimator exactly unbiased; the
        // tolerance absorbs stochastic weight-quantization noise).
        let (train, w_star) = synthetic_planted_linear(120, 8, 31);
        let mut sess = CodedMlSession::new_linear(linear_cfg(10, 3, 1), &train).unwrap();
        assert_eq!(sess.params().recovery_threshold(), 10);
        let l0 = sess.train_loss();
        let report = sess.train(30, None).unwrap();
        let losses: Vec<f64> = report.iterations.iter().map(|m| m.train_loss).collect();
        for w in losses.windows(2) {
            // 1e-3 absorbs the stochastic-quantization noise floor at the
            // bottom of the curve (~½L‖ε‖² with ‖ε‖ ~ √d·2^-l_w).
            assert!(w[1] <= w[0] + 1e-3, "loss bump {} → {}", w[0], w[1]);
        }
        assert!(losses[0] <= l0, "first step must improve on w = 0");
        assert!(*losses.last().unwrap() < 0.05 * l0, "final loss {losses:?}");
        let err = LinearRegression::with_weights(report.weights.clone()).distance_to(&w_star);
        assert!(err < 0.15, "‖w − w*‖ = {err}");
        // Regression has no 0/1 accuracy.
        let (test, _) = synthetic_planted_linear(30, 8, 32);
        assert_eq!(sess.accuracy(&test), None);
    }

    #[test]
    fn linear_first_step_is_exact_plaintext_gradient() {
        // With w₀ = 0 the stochastic weight quantization is exact, the
        // worker polynomial is −X̃ᵀỹ, and the decode is integer-exact —
        // so the coded gradient must equal the plaintext gradient on the
        // quantized views to f64 round-off.
        let (train, _) = synthetic_planted_linear(60, 6, 7);
        let cfg = linear_cfg(10, 3, 1);
        let mut sess = CodedMlSession::new_linear(cfg.clone(), &train).unwrap();
        let grad = sess.step().unwrap();

        let ds = train.take_rows_multiple_of(60, 3);
        let xq = crate::quant::DatasetQuantizer::new(cfg.field(), cfg.lx);
        let xbar_real: Vec<f64> = xq
            .quantize(&ds.x)
            .iter()
            .map(|&q| xq.dequantize_entry(q))
            .collect();
        let plain = LinearRegression::new(ds.d);
        let want = plain.gradient(&xbar_real, sess.labels_real(), ds.m, ds.d);
        for (a, b) in grad.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn linear_rejects_higher_degree() {
        let (train, _) = synthetic_planted_linear(60, 4, 9);
        let mut cfg = linear_cfg(16, 2, 1);
        cfg.r = 2;
        let err = CodedMlSession::new_linear(cfg, &train).unwrap_err();
        assert!(err.to_string().contains("r = 1"), "{err}");
    }

    #[test]
    fn mini_batch_rotation_trains_and_rotates() {
        let train = synthetic_3v7(120, 13);
        let mut cfg = quick_cfg(10, 3, 1);
        cfg.batch_blocks = 1;
        let mut sess = CodedMlSession::new(cfg, &train).unwrap();
        // The rotating window visits every block in turn.
        assert_eq!(sess.batch_for(0), vec![0]);
        assert_eq!(sess.batch_for(1), vec![1]);
        assert_eq!(sess.batch_for(2), vec![2]);
        assert_eq!(sess.batch_for(3), vec![0]);
        sess.eta *= 0.5; // mini-batch steps are noisier; damp the default 1/L
        let l0 = sess.train_loss();
        let report = sess.train(12, None).unwrap();
        assert!(report.final_loss().unwrap() < l0 * 0.9, "{report:?}");
    }

    #[test]
    fn mini_batch_window_wider_than_one() {
        let train = synthetic_3v7(120, 14);
        let mut cfg = quick_cfg(10, 3, 1);
        cfg.batch_blocks = 2;
        let sess = CodedMlSession::new(cfg, &train).unwrap();
        assert_eq!(sess.batch_for(0), vec![0, 1]);
        assert_eq!(sess.batch_for(1), vec![2, 0]);
        assert_eq!(sess.batch_for(2), vec![1, 2]);
    }

    #[test]
    fn approx_decode_keeps_training_alive_below_threshold() {
        // n = 10, K = 3, T = 1 → R = 10: zero slack, so two chaos deaths
        // from iteration 1 leave every later round short. With degraded
        // mode on, training must keep going (approximately) instead of
        // aborting; the residual must be surfaced.
        let train = synthetic_3v7(120, 41);
        let mut cfg = quick_cfg(10, 3, 1);
        cfg.chaos_failures = 2;
        cfg.chaos_from_iter = 1;
        cfg.approx_decode = true;
        let mut sess = CodedMlSession::new(cfg, &train).unwrap();
        sess.set_tracer(crate::coordinator::Tracer::memory());
        let report = sess.train(4, None).unwrap();
        assert_eq!(report.approx_rounds, 3, "rounds 1..3 degrade");
        assert!(report.worker_failures > 0);
        assert!(report.max_approx_residual > 0.0, "masked shares cannot fit exactly");
        assert!(report.final_loss().unwrap().is_finite());
        let approx_events: Vec<_> = sess
            .tracer()
            .events()
            .iter()
            .filter(|e| e.get("event").and_then(|v| v.as_str()) == Some("decode.approx"))
            .collect();
        assert_eq!(approx_events.len(), 3);
        assert!(approx_events[0].get("residual").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(approx_events[0].get("r_prime").unwrap().as_u64(), Some(8));
    }

    #[test]
    fn approx_decode_respects_r_min_floor() {
        // 7 of 10 workers die → 3 usable < K + T = 4: even with degraded
        // mode on, the session must abort with the structured error.
        let train = synthetic_3v7(120, 42);
        let mut cfg = quick_cfg(10, 3, 1);
        cfg.chaos_failures = 7;
        cfg.chaos_from_iter = 0;
        cfg.approx_decode = true;
        let mut sess = CodedMlSession::new(cfg, &train).unwrap();
        match sess.step() {
            Err(TrainError::TooManyFailures { ok, need }) => {
                assert_eq!((ok, need), (3, 10));
            }
            other => panic!("expected TooManyFailures, got {other:?}"),
        }
    }

    #[test]
    fn supervised_respawn_restores_bit_identical_trajectory() {
        // One worker dies at iteration 1; the supervisor revives an
        // in-memory replacement mid-round, re-ships the original encoded
        // share, and re-dispatches the weights. Every decode then runs on
        // the exact path with the same data a fault-free run would use,
        // so the weights must match bit for bit.
        let train = synthetic_3v7(120, 43);
        let clean_cfg = quick_cfg(10, 3, 1);
        let mut chaos_cfg = clean_cfg.clone();
        chaos_cfg.chaos_failures = 1;
        chaos_cfg.chaos_from_iter = 1;
        chaos_cfg.max_respawns = 2;
        let mut clean = CodedMlSession::new(clean_cfg, &train).unwrap();
        let mut healed = CodedMlSession::new(chaos_cfg, &train).unwrap();
        let r_clean = clean.train(5, None).unwrap();
        let r_healed = healed.train(5, None).unwrap();
        assert_eq!(r_clean.weights, r_healed.weights, "exact decode ⇒ bit-identical");
        assert_eq!(r_healed.approx_rounds, 0);
        assert_eq!(r_healed.respawns, 1);
        assert!(r_healed.worker_failures >= 1, "the death was still recorded");
        assert_eq!(r_clean.respawns, 0);
    }

    #[test]
    fn round_deadline_degrades_instead_of_waiting() {
        // 3 real-slow workers on a pool with slack 2: the round needs one
        // of them, so without a deadline every iteration waits the full
        // 400 ms. With a 100 ms deadline the stalled workers are charged
        // failures and the round degrades to approximate decoding.
        let train = synthetic_3v7(120, 44);
        let mut cfg = quick_cfg(12, 3, 1); // R = 10
        cfg.chaos_slow_workers = 3;
        cfg.chaos_slow_ms = 400;
        cfg.round_deadline_ms = 100;
        cfg.approx_decode = true;
        let mut sess = CodedMlSession::new(cfg, &train).unwrap();
        let report = sess.train(2, None).unwrap();
        assert_eq!(report.deadline_expired_rounds, 2);
        assert_eq!(report.approx_rounds, 2);
        assert!(report.worker_failures >= 2, "stalled workers charged as failures");
        assert!(report.final_loss().unwrap().is_finite());
    }

    #[test]
    fn linear_regression_threshold_reuse() {
        // CodingParams algebra is shared; the Linear op is exercised in
        // cluster::worker tests and linear_session_recovers_planted_model.
        let p = CodingParams::new(10, 3, 1, 1).unwrap();
        assert_eq!(p.recovery_threshold(), 10);
    }
}
