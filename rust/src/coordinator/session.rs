//! The training session — paper Algorithm 1 end to end.

use std::time::Instant;

use super::config::{CodedMlConfig, CompMode, ConfigError};
use super::report::{IterationMetrics, TimingBreakdown, TrainReport};
use crate::cluster::{Cluster, ClusterError, StepResult, WorkerSpec};
use crate::cluster::worker::WorkerOp;
use crate::coding::{CodingParams, DecodeError, Decoder, Encoder};
use crate::coding::decoder::WorkerResult;
use crate::data::Dataset;
use crate::field::PrimeField;
use crate::model::{matvec, max_eig_xtx, tr_matvec, LogisticRegression};
use crate::quant::{DatasetQuantizer, Dequantizer, WeightQuantizer};
use crate::sigmoid::{fit_sigmoid_with, SigmoidPoly};
use crate::util::{Rng, Stopwatch};

/// Errors surfaced during training.
#[derive(Debug)]
pub enum TrainError {
    Config(ConfigError),
    Cluster(ClusterError),
    Decode(DecodeError),
    /// More workers failed than the straggler slack allows.
    TooManyFailures { ok: usize, need: usize },
}

impl std::fmt::Display for TrainError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TrainError::Config(e) => write!(f, "{e}"),
            TrainError::Cluster(e) => write!(f, "{e}"),
            TrainError::Decode(e) => write!(f, "{e}"),
            TrainError::TooManyFailures { ok, need } => {
                write!(f, "only {ok} workers produced results, need {need}")
            }
        }
    }
}

impl std::error::Error for TrainError {}

impl From<ConfigError> for TrainError {
    fn from(e: ConfigError) -> Self {
        TrainError::Config(e)
    }
}
impl From<ClusterError> for TrainError {
    fn from(e: ClusterError) -> Self {
        TrainError::Cluster(e)
    }
}
impl From<DecodeError> for TrainError {
    fn from(e: DecodeError) -> Self {
        TrainError::Decode(e)
    }
}

/// A live CodedPrivateML training session: cluster spawned, dataset
/// encoded and secret-shared, ready to iterate.
pub struct CodedMlSession {
    cfg: CodedMlConfig,
    field: PrimeField,
    params: CodingParams,
    encoder: Encoder,
    decoder: Decoder,
    cluster: Cluster,
    poly: SigmoidPoly,
    wquant: WeightQuantizer,
    dequant: Dequantizer,
    /// Quantized dataset (field form, kept for ground-truth tests).
    pub xbar: Vec<u64>,
    /// Dequantized dataset — the X̄ the convergence theorem is stated on.
    xbar_real: Vec<f64>,
    /// X̄ᵀy, precomputed (the master holds y; eq. 19 subtracts it after
    /// decoding X̄ᵀḡ).
    xbar_t_y: Vec<f64>,
    y: Vec<f64>,
    /// Current weights (real domain).
    pub w: Vec<f64>,
    pub eta: f64,
    m: usize,
    d: usize,
    rows: usize,
    rng: Rng,
    /// Independent stream for straggler delays so the timing simulation
    /// never perturbs masks or stochastic quantization (the fastest-R
    /// *subset* may differ, but LCC decoding is exact for any subset, so
    /// the training trajectory is invariant — tested below).
    straggle_rng: Rng,
    // timers
    t_encode: Stopwatch,
    t_comm: Stopwatch,
    t_comp: Stopwatch,
    t_decode: Stopwatch,
    bytes_sent: u64,
    bytes_received: u64,
    iter: u64,
    tracer: super::trace::Tracer,
}

impl CodedMlSession {
    /// Build the session: fit the sigmoid polynomial, quantize + encode +
    /// secret-share the dataset, spawn the cluster. The dataset is trimmed
    /// to a multiple of K rows.
    pub fn new(cfg: CodedMlConfig, train: &Dataset) -> Result<Self, TrainError> {
        let params = cfg.coding_params()?;
        let field = cfg.field();
        let ds = train.take_rows_multiple_of(train.m, params.k);
        let (m, d) = (ds.m, ds.d);
        let rows = m / params.k;

        // Budget check (warn or error per config).
        let rep = cfg.validate(m, ds.max_abs_x())?;
        if !rep.ok() {
            eprintln!(
                "warning: overflow budget utilization {:.2} > 1 — decoded \
                 gradients may wrap; consider k>{}, smaller l_c, or a larger prime",
                rep.utilization, params.k
            );
        }

        // Sigmoid polynomial (real + field forms).
        let poly = fit_sigmoid_with(cfg.fit_method, cfg.r as u32, cfg.fit_range);
        let field_coeffs = poly.field_coeffs(&field, cfg.lx, cfg.lw, cfg.lc);

        let mut rng = Rng::new(cfg.seed);
        let straggle_rng = Rng::new(cfg.seed ^ 0x5742_4751_4c45);

        let mut t_encode = Stopwatch::new();
        let mut t_comm = Stopwatch::new();

        // Quantize + encode + secret-share the dataset (one-time).
        let xq = DatasetQuantizer::new(field, cfg.lx);
        let (xbar, shares) = {
            let mut out = None;
            t_encode.time(|| {
                let xbar = xq.quantize(&ds.x);
                let encoder = Encoder::new(field, params).with_parallelism(cfg.parallelism);
                let shares = encoder.encode_dataset(&xbar, m, d, &mut rng);
                out = Some((xbar, shares));
            });
            out.unwrap()
        };
        let encoder = Encoder::new(field, params).with_parallelism(cfg.parallelism);
        let decoder = Decoder::new(field, params, encoder.points.clone())
            .with_parallelism(cfg.parallelism);

        // Model the dataset broadcast (optionally bit-packed on the wire).
        let share_bytes = if cfg.packed_wire {
            encoder.packed_share_bytes(m, d)
        } else {
            encoder.share_bytes(m, d)
        };
        t_comm.add_seconds(cfg.net.fanout_time(params.n, share_bytes));
        let bytes_sent = share_bytes * params.n as u64;

        // Spawn workers & deliver shares.
        let specs: Vec<WorkerSpec> = (0..params.n)
            .map(|id| WorkerSpec {
                id,
                kind: cfg.backend,
                artifact_dir: cfg.artifact_dir.clone(),
                field,
                rows,
                d,
                coeffs: field_coeffs.clone(),
                op: WorkerOp::Logistic,
                // Chaos hook: the first `chaos_failures` workers die at
                // `chaos_from_iter` (resilience tests).
                fail_from_iter: (id < cfg.chaos_failures).then_some(cfg.chaos_from_iter),
                par: cfg.parallelism,
            })
            .collect();
        let cluster = Cluster::spawn(specs)?;
        cluster.load_data(shares.into_iter().map(|s| s.data).collect(), None)?;

        // Real-domain views the master needs.
        let xbar_real: Vec<f64> = xbar.iter().map(|&q| xq.dequantize_entry(q)).collect();
        let xbar_t_y = tr_matvec(&xbar_real, &ds.y, m, d);

        // Step size: η = 1/L (Lemma 2, scaled by 1/m like the cost).
        let eta = cfg.eta.unwrap_or_else(|| {
            let l = 0.25 * max_eig_xtx(&xbar_real, m, d, 30) / m as f64;
            if l > 0.0 {
                1.0 / l
            } else {
                1.0
            }
        });

        let wquant = WeightQuantizer::new(field, cfg.lw, cfg.r as u32);
        let dequant = Dequantizer::new(field, cfg.lx, cfg.lw, cfg.lc, cfg.r as u32);

        Ok(CodedMlSession {
            cfg,
            field,
            params,
            encoder,
            decoder,
            cluster,
            poly,
            wquant,
            dequant,
            xbar,
            xbar_real,
            xbar_t_y,
            y: ds.y.clone(),
            w: vec![0.0; d],
            eta,
            m,
            d,
            rows,
            rng,
            straggle_rng,
            t_encode,
            t_comm,
            t_comp: Stopwatch::new(),
            t_decode: Stopwatch::new(),
            bytes_sent,
            bytes_received: 0,
            iter: 0,
            tracer: super::trace::Tracer::disabled(),
        })
    }

    /// Attach a tracer (JSONL per-phase events; see [`super::Tracer`]).
    pub fn set_tracer(&mut self, tracer: super::trace::Tracer) {
        self.tracer = tracer;
    }

    /// Access collected in-memory trace events (tests/diagnostics).
    pub fn tracer(&self) -> &super::trace::Tracer {
        &self.tracer
    }

    pub fn params(&self) -> CodingParams {
        self.params
    }

    /// Wire size of `count` field elements under the configured framing
    /// (raw u64 or bit-packed to the field width — util::bitpack).
    fn wire_bytes(&self, count: usize) -> u64 {
        if self.cfg.packed_wire {
            crate::util::bitpack::packed_len(count, self.field.bits()) as u64
        } else {
            (count * 8) as u64
        }
    }

    pub fn dims(&self) -> (usize, usize) {
        (self.m, self.d)
    }

    /// The sigmoid polynomial in use (diagnostics / ablations).
    pub fn sigmoid_poly(&self) -> &SigmoidPoly {
        &self.poly
    }

    /// One full Algorithm-1 iteration; returns the decoded real-domain
    /// X̄ᵀḡ (before the gradient update) for inspection.
    pub fn step(&mut self) -> Result<Vec<f64>, TrainError> {
        let need = self.params.recovery_threshold();
        let (n, d, r) = (self.params.n, self.d, self.cfg.r);

        // (1) Quantize weights (r independent stochastic draws) + encode
        //     with fresh masks — both count as encode time.
        let w_shares = {
            let mut out = None;
            let rng = &mut self.rng;
            let (wquant, encoder, w) = (&self.wquant, &self.encoder, &self.w);
            self.t_encode.time(|| {
                let wq = wquant.quantize(w, rng);
                out = Some(encoder.encode_weights(&wq, d, r, rng));
            });
            out.unwrap()
        };

        // (2) Master → workers: W̃ shares.
        let wbytes = self.wire_bytes(d * r);
        self.t_comm.add_seconds(self.cfg.net.fanout_time(n, wbytes));
        self.bytes_sent += wbytes * n as u64;
        self.cluster
            .dispatch(self.iter, w_shares.into_iter().map(|s| s.data).collect())?;

        // (3) Collect everyone, model arrival = compute + straggle, keep
        //     the fastest R.
        let t_wall = Instant::now();
        let mut results = self.cluster.collect_all(self.iter)?;
        let wall = t_wall.elapsed().as_secs_f64();

        let mut arrivals: Vec<(f64, StepResult)> = results
            .drain(..)
            .filter_map(|res| match &res.data {
                Ok(_) => {
                    let delay = self.cfg.straggler.sample(&mut self.straggle_rng, res.compute_secs);
                    Some((res.compute_secs + delay, res))
                }
                Err(msg) => {
                    eprintln!("worker {} failed: {msg}", res.worker);
                    None
                }
            })
            .collect();
        if arrivals.len() < need {
            return Err(TrainError::TooManyFailures { ok: arrivals.len(), need });
        }
        arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        arrivals.truncate(need);

        let iter_comp = match self.cfg.comp_mode {
            CompMode::ModeledParallel => arrivals.last().unwrap().0,
            CompMode::Wall => wall,
        };
        self.t_comp.add_seconds(iter_comp);
        if self.tracer.enabled() {
            use crate::util::json::Json;
            let used: Vec<Json> = arrivals
                .iter()
                .map(|(_, r)| Json::Num(r.worker as f64))
                .collect();
            self.tracer.event(
                "collect",
                self.iter,
                &[
                    ("comp_modeled_s", Json::Num(iter_comp)),
                    ("wall_s", Json::Num(wall)),
                    ("fastest", Json::Arr(used)),
                ],
            );
        }

        // (4) Workers → master: R result vectors.
        let rbytes = self.wire_bytes(d);
        self.t_comm.add_seconds(self.cfg.net.fanin_time(need, rbytes));
        self.bytes_received += rbytes * need as u64;

        // (5) Decode the K sub-gradients and dequantize per block
        //     (per-block dequantization keeps the overflow budget at m/K
        //     rows — DESIGN.md §Numeric design).
        let worker_results: Vec<WorkerResult> = arrivals
            .into_iter()
            .map(|(_, res)| WorkerResult { worker: res.worker, data: res.data.unwrap() })
            .collect();
        let mut xtg_real = vec![0.0f64; d];
        {
            let decoder = &mut self.decoder;
            let dequant = &self.dequant;
            let mut decoded = None;
            self.t_decode.time(|| {
                decoded = Some(decoder.decode(&worker_results, d));
            });
            let blocks = decoded.unwrap()?;
            for block in blocks {
                for (acc, &q) in xtg_real.iter_mut().zip(block.iter()) {
                    *acc += dequant.dequantize_entry(q);
                }
            }
        }

        // (6) Gradient update (eq. 19): w ← w − η/m (X̄ᵀḡ − X̄ᵀy).
        for ((w, &xtg), &xty) in self.w.iter_mut().zip(xtg_real.iter()).zip(self.xbar_t_y.iter()) {
            *w -= self.eta / self.m as f64 * (xtg - xty);
        }

        if self.tracer.enabled() {
            use crate::util::json::Json;
            self.tracer.event(
                "step",
                self.iter,
                &[
                    ("encode_total_s", Json::Num(self.t_encode.seconds())),
                    ("comm_total_s", Json::Num(self.t_comm.seconds())),
                    ("decode_total_s", Json::Num(self.t_decode.seconds())),
                ],
            );
        }
        self.iter += 1;
        Ok(xtg_real)
    }

    /// Cross-entropy of the current weights on the quantized training set
    /// (the quantity Theorem 1 bounds).
    pub fn train_loss(&self) -> f64 {
        let ds = Dataset::new(
            self.xbar_real.clone(),
            self.y.clone(),
            self.m,
            self.d,
            "quantized-train",
        );
        LogisticRegression::with_weights(self.w.clone()).loss(&ds)
    }

    /// Accuracy of the current weights on a held-out set.
    pub fn accuracy(&self, test: &Dataset) -> f64 {
        LogisticRegression::with_weights(self.w.clone()).accuracy(test)
    }

    /// Run `iters` iterations, recording loss (and accuracy when a test
    /// set is given) each iteration.
    pub fn train(&mut self, iters: usize, test: Option<&Dataset>) -> Result<TrainReport, TrainError> {
        let mut iterations = Vec::with_capacity(iters);
        for it in 0..iters {
            self.step()?;
            iterations.push(IterationMetrics {
                iter: it,
                train_loss: self.train_loss(),
                test_accuracy: test.map(|ts| self.accuracy(ts)),
            });
        }
        Ok(self.report(iterations))
    }

    /// Estimated sigmoid input range actually seen (diagnostics for
    /// choosing `fit_range`).
    pub fn activation_range(&self) -> (f64, f64) {
        let z = matvec(&self.xbar_real, &self.w, self.m, self.d);
        crate::util::stats::min_max(&z)
    }

    fn report(&mut self, iterations: Vec<IterationMetrics>) -> TrainReport {
        TrainReport {
            breakdown: TimingBreakdown {
                encode_s: self.t_encode.seconds(),
                comm_s: self.t_comm.seconds(),
                // Master decode counts as computation.
                comp_s: self.t_comp.seconds() + self.t_decode.seconds(),
            },
            decode_s: self.t_decode.seconds(),
            iterations,
            weights: self.w.clone(),
            decode_cache: self.decoder.cache_stats(),
            recovery_threshold: self.params.recovery_threshold(),
            bytes_sent: self.bytes_sent,
            bytes_received: self.bytes_received,
        }
    }
}

impl std::fmt::Debug for CodedMlSession {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CodedMlSession")
            .field("params", &self.params)
            .field("m", &self.m)
            .field("d", &self.d)
            .field("rows", &self.rows)
            .field("iter", &self.iter)
            .field("backend", &self.cfg.backend)
            .field("field", &self.field.modulus())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{NetworkModel, StragglerModel};
    use crate::data::synthetic_3v7;

    fn quick_cfg(n: usize, k: usize, t: usize) -> CodedMlConfig {
        CodedMlConfig {
            n,
            k,
            t,
            straggler: StragglerModel::none(),
            net: NetworkModel::free(),
            ..Default::default()
        }
    }

    #[test]
    fn session_trains_and_loss_decreases() {
        let train = synthetic_3v7(120, 1);
        let test = synthetic_3v7(60, 2);
        let mut sess = CodedMlSession::new(quick_cfg(10, 3, 1), &train).unwrap();
        let l0 = sess.train_loss();
        let report = sess.train(10, Some(&test)).unwrap();
        let lf = report.final_loss().unwrap();
        assert!(lf < l0 * 0.8, "loss {l0} → {lf}");
        assert!(report.final_accuracy().unwrap() > 0.8);
        assert_eq!(report.iterations.len(), 10);
        assert_eq!(report.recovery_threshold, 10);
        assert!(report.breakdown.encode_s > 0.0);
        assert!(report.breakdown.comp_s > 0.0);
    }

    #[test]
    fn private_training_matches_quantized_plaintext_gradient() {
        // One step of CodedPrivateML must equal the plaintext update
        // computed with the same quantized data and the same stochastic
        // weight draws — here w₀ = 0 makes the quantization of w
        // deterministic (all zeros), so the check is exact-in-expectation
        // with zero variance at step 1.
        let train = synthetic_3v7(60, 3);
        let cfg = quick_cfg(10, 3, 1);
        let mut sess = CodedMlSession::new(cfg.clone(), &train).unwrap();
        let eta = sess.eta;
        let xtg = sess.step().unwrap();

        // Plaintext: with w=0 every w̄ column is 0, so X̄w̄ = 0 and
        // ḡ = c̄₀/2^l — i.e. ĝ(0) after dequantization.
        let g0 = sess.sigmoid_poly().eval(0.0);
        // decoded X̄ᵀḡ ≈ X̄ᵀ·(ḡ(0)·1) entrywise (exactly: quantized c̄₀).
        let ds = train.take_rows_multiple_of(60, 3);
        let xq = crate::quant::DatasetQuantizer::new(cfg.field(), cfg.lx);
        let xbar = xq.quantize(&ds.x);
        let xbar_real: Vec<f64> = xbar.iter().map(|&q| xq.dequantize_entry(q)).collect();
        let ones_g: Vec<f64> = vec![g0; ds.m];
        let expect = crate::model::tr_matvec(&xbar_real, &ones_g, ds.m, ds.d);
        for (a, b) in xtg.iter().zip(expect.iter()) {
            // c̄₀ rounding introduces ≤ 2^-(lc + r(lx+lw)) per-row error,
            // times Σ|X̄| per column; keep a generous bound.
            assert!((a - b).abs() < 1.0 + b.abs() * 0.01, "{a} vs {b}");
        }
        // And the weight moved in the -gradient direction.
        let grad_dir: Vec<f64> = sess.w.clone();
        let manual: Vec<f64> = {
            let xty = crate::model::tr_matvec(&xbar_real, &ds.y, ds.m, ds.d);
            expect
                .iter()
                .zip(xty.iter())
                .map(|(&xg, &xy)| -eta / ds.m as f64 * (xg - xy))
                .collect()
        };
        for (a, b) in grad_dir.iter().zip(manual.iter()) {
            assert!((a - b).abs() < 1e-3 + b.abs() * 0.02, "{a} vs {b}");
        }
    }

    #[test]
    fn straggling_does_not_change_results_only_timing() {
        let train = synthetic_3v7(60, 5);
        let mut cfg_a = quick_cfg(12, 3, 1);
        cfg_a.iters = 3;
        let mut cfg_b = cfg_a.clone();
        cfg_b.straggler = StragglerModel { shift: 0.5, rate: 2.0, relative: true };
        // Same seed → same masks/quantizations; decode is exact either way.
        let mut sa = CodedMlSession::new(cfg_a, &train).unwrap();
        let mut sb = CodedMlSession::new(cfg_b, &train).unwrap();
        let ra = sa.train(3, None).unwrap();
        let rb = sb.train(3, None).unwrap();
        for (wa, wb) in ra.weights.iter().zip(rb.weights.iter()) {
            assert!((wa - wb).abs() < 1e-12, "{wa} vs {wb}");
        }
    }

    #[test]
    fn tracer_records_phases() {
        let train = synthetic_3v7(60, 25);
        let mut sess = CodedMlSession::new(quick_cfg(10, 3, 1), &train).unwrap();
        sess.set_tracer(crate::coordinator::Tracer::memory());
        sess.step().unwrap();
        sess.step().unwrap();
        let events = sess.tracer().events();
        // Two iterations × (collect + step).
        assert_eq!(events.len(), 4);
        assert_eq!(events[0].get("event").unwrap().as_str(), Some("collect"));
        let fastest = events[0].get("fastest").unwrap().as_arr().unwrap();
        assert_eq!(fastest.len(), 10, "threshold-many workers recorded");
        assert!(events[1].get("encode_total_s").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn packed_wire_reduces_bytes_not_results() {
        let train = synthetic_3v7(60, 23);
        let raw_cfg = quick_cfg(10, 3, 1);
        let mut packed_cfg = raw_cfg.clone();
        packed_cfg.packed_wire = true;
        let mut raw = CodedMlSession::new(raw_cfg, &train).unwrap();
        let mut packed = CodedMlSession::new(packed_cfg, &train).unwrap();
        let r_raw = raw.train(3, None).unwrap();
        let r_packed = packed.train(3, None).unwrap();
        assert_eq!(r_raw.weights, r_packed.weights, "framing must not change math");
        // 24-bit prime packs 64-bit words 8/3x smaller (± rounding).
        let ratio = r_raw.bytes_sent as f64 / r_packed.bytes_sent as f64;
        assert!((ratio - 64.0 / 24.0).abs() < 0.05, "ratio={ratio}");
    }

    #[test]
    fn chebyshev_fit_session_trains() {
        let train = synthetic_3v7(120, 24);
        let mut cfg = quick_cfg(10, 3, 1);
        cfg.fit_method = crate::sigmoid::FitMethod::Chebyshev;
        let mut sess = CodedMlSession::new(cfg, &train).unwrap();
        let report = sess.train(10, None).unwrap();
        assert!(report.final_loss().unwrap() < report.iterations[0].train_loss);
    }

    #[test]
    fn degree2_session_trains() {
        // r=2: two independent weight quantizations, degree-5 worker
        // polynomial, recovery threshold 5(K+T-1)+1.
        let train = synthetic_3v7(120, 21);
        let test = synthetic_3v7(120, 22);
        let cfg = CodedMlConfig {
            n: 11,
            k: 2,
            t: 1,
            r: 2,
            p: crate::field::PRIME_26, // r=2 scale needs the bigger budget
            straggler: StragglerModel::none(),
            net: NetworkModel::free(),
            ..Default::default()
        };
        let mut sess = CodedMlSession::new(cfg, &train).unwrap();
        assert_eq!(sess.params().recovery_threshold(), 11);
        let report = sess.train(12, Some(&test)).unwrap();
        assert!(report.final_accuracy().unwrap() > 0.8, "{report:?}");
        assert!(report.final_loss().unwrap() < report.iterations[0].train_loss);
    }

    #[test]
    fn report_accounts_bytes() {
        let train = synthetic_3v7(40, 7);
        let mut sess = CodedMlSession::new(quick_cfg(10, 2, 1), &train).unwrap();
        let rep = sess.train(2, None).unwrap();
        let (m, d) = sess.dims();
        // dataset: N shares of (m/K)·d u64 + 2 iterations of N·d·r u64.
        let expect_sent = (10 * (m / 2) * d * 8 + 2 * 10 * d * 8) as u64;
        assert_eq!(rep.bytes_sent, expect_sent);
        // received: 2 iterations × threshold(=7? K+T-1=2 → 3·2+1=7) × d.
        assert_eq!(rep.recovery_threshold, 7);
        assert_eq!(rep.bytes_received, (2 * 7 * d * 8) as u64);
    }

    #[test]
    fn linear_regression_threshold_reuse() {
        // CodingParams algebra is shared; the Linear op is exercised in
        // cluster::worker tests and examples/linear_regression.rs.
        let p = CodingParams::new(10, 3, 1, 1).unwrap();
        assert_eq!(p.recovery_threshold(), 10);
    }
}
