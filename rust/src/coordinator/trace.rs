//! Structured session tracing: one JSONL event per phase per iteration,
//! for post-hoc analysis (`codedml train --trace run.jsonl`). This is the
//! observability a deployment needs to see *where* an iteration went slow
//! (encode vs dispatch vs straggle vs decode) without attaching a profiler.
//!
//! The per-iteration `collect` event also records the transport backend
//! and its cumulative `wire_sent`/`wire_received` byte counters, and every
//! worker loss — chaos-injected faults and real TCP disconnects alike —
//! surfaces as a `worker_failure` event with the worker id and reason.

use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::Path;

use crate::util::json::{obj, Json};

/// A sink for trace events (JSONL file, or in-memory for tests).
#[derive(Debug)]
pub enum TraceSink {
    Disabled,
    File(BufWriter<File>),
    Memory(Vec<Json>),
}

/// Session tracer.
#[derive(Debug)]
pub struct Tracer {
    sink: TraceSink,
}

impl Tracer {
    pub fn disabled() -> Self {
        Tracer { sink: TraceSink::Disabled }
    }

    pub fn memory() -> Self {
        Tracer { sink: TraceSink::Memory(Vec::new()) }
    }

    pub fn file(path: &Path) -> std::io::Result<Self> {
        Ok(Tracer { sink: TraceSink::File(BufWriter::new(File::create(path)?)) })
    }

    pub fn enabled(&self) -> bool {
        !matches!(self.sink, TraceSink::Disabled)
    }

    /// Emit one event.
    pub fn event(&mut self, kind: &str, iter: u64, fields: &[(&str, Json)]) {
        if let TraceSink::Disabled = self.sink {
            return;
        }
        let mut all = vec![
            ("event", Json::Str(kind.to_string())),
            ("iter", Json::Num(iter as f64)),
        ];
        all.extend(fields.iter().cloned());
        let record = obj(&all);
        match &mut self.sink {
            TraceSink::Disabled => {}
            TraceSink::File(w) => {
                let _ = writeln!(w, "{}", record.to_string());
            }
            TraceSink::Memory(v) => v.push(record),
        }
    }

    /// In-memory events (tests).
    pub fn events(&self) -> &[Json] {
        match &self.sink {
            TraceSink::Memory(v) => v,
            _ => &[],
        }
    }

    pub fn flush(&mut self) {
        if let TraceSink::File(w) = &mut self.sink {
            let _ = w.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_is_free_and_empty() {
        let mut t = Tracer::disabled();
        assert!(!t.enabled());
        t.event("step", 0, &[("x", Json::Num(1.0))]);
        assert!(t.events().is_empty());
    }

    #[test]
    fn memory_collects_events() {
        let mut t = Tracer::memory();
        t.event("encode", 3, &[("seconds", Json::Num(0.5))]);
        t.event("decode", 3, &[("blocks", Json::Num(4.0))]);
        assert_eq!(t.events().len(), 2);
        assert_eq!(t.events()[0].get("event").unwrap().as_str(), Some("encode"));
        assert_eq!(t.events()[1].get("iter").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn file_sink_writes_jsonl() {
        let path = std::env::temp_dir().join(format!("trace_{}.jsonl", std::process::id()));
        {
            let mut t = Tracer::file(&path).unwrap();
            t.event("step", 0, &[("comp_s", Json::Num(0.25))]);
            t.event("step", 1, &[("comp_s", Json::Num(0.5))]);
            t.flush();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in lines {
            let v = Json::parse(l).unwrap();
            assert!(v.get("event").is_some());
        }
        std::fs::remove_file(&path).unwrap();
    }
}
