//! Session configuration.

use std::path::PathBuf;

use crate::cluster::{NetworkModel, StragglerModel, TransportConfig, TransportKind};
use crate::coding::{CodingBackendChoice, CodingParams, ParamError};
use crate::field::{PrimeField, PAPER_PRIME};
use crate::quant::{BudgetReport, OverflowBudget};
use crate::runtime::BackendKind;
use crate::util::json::{obj, Json};
use crate::util::par::Parallelism;

/// How per-iteration computation time is attributed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompMode {
    /// R-th order statistic over the healthy workers of (compute +
    /// sampled straggle) — the paper's N-independent-machines semantics
    /// (default). Computes the early exit never measured are approximated
    /// by the collected subset's mean (equal-sized coded blocks).
    ModeledParallel,
    /// Wall-clock time from dispatch to the R-th arrival on this host
    /// (deflated by thread oversubscription; for debugging only).
    Wall,
}

impl std::str::FromStr for CompMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "modeled" => Ok(CompMode::ModeledParallel),
            "wall" => Ok(CompMode::Wall),
            other => Err(format!("unknown comp mode '{other}' (modeled|wall)")),
        }
    }
}

impl std::fmt::Display for CompMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            CompMode::ModeledParallel => "modeled",
            CompMode::Wall => "wall",
        })
    }
}

/// Which coded objective the session trains (see
/// [`crate::coordinator::CodedObjective`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ModelKind {
    /// Algorithm 1: logistic regression with a polynomial sigmoid.
    #[default]
    Logistic,
    /// Remark 1: linear regression — identity "activation", coded labels.
    Linear,
}

impl std::str::FromStr for ModelKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "logistic" => Ok(ModelKind::Logistic),
            "linear" => Ok(ModelKind::Linear),
            other => Err(format!("unknown model '{other}' (logistic|linear)")),
        }
    }
}

impl std::fmt::Display for ModelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ModelKind::Logistic => "logistic",
            ModelKind::Linear => "linear",
        })
    }
}

#[derive(Debug)]
pub enum ConfigError {
    Params(ParamError),
    /// Overflow budget exceeded and `strict_budget` set.
    Budget(BudgetReport),
    /// m not usable with K.
    BadShape(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Params(e) => write!(f, "{e}"),
            ConfigError::Budget(rep) => write!(
                f,
                "overflow budget exceeded: worst case {:.3e} > limit {:.3e} \
                 (utilization {:.2}); lower l_c/l_x/l_w, raise K, or use a larger prime",
                rep.worst_case, rep.limit, rep.utilization
            ),
            ConfigError::BadShape(s) => write!(f, "{s}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl From<ParamError> for ConfigError {
    fn from(e: ParamError) -> Self {
        ConfigError::Params(e)
    }
}

/// Everything a CodedPrivateML training session needs.
#[derive(Debug, Clone, PartialEq)]
pub struct CodedMlConfig {
    /// Workers.
    pub n: usize,
    /// Parallelization (dataset blocks).
    pub k: usize,
    /// Privacy threshold.
    pub t: usize,
    /// Sigmoid polynomial degree.
    pub r: usize,
    /// Field prime.
    pub p: u64,
    /// Dataset scale bits (paper: 2).
    pub lx: u32,
    /// Weight scale bits (paper: 4).
    pub lw: u32,
    /// Coefficient scale bits (our generalization; 0 = paper formula).
    pub lc: u32,
    /// Sigmoid fit half-range R.
    pub fit_range: f64,
    /// Training iterations (paper: 25).
    pub iters: usize,
    /// Step size; None → 1/L from Lemma 2.
    pub eta: Option<f64>,
    /// Worker compute backend.
    pub backend: BackendKind,
    pub artifact_dir: PathBuf,
    /// RNG seed (masks, stochastic quantization, stragglers).
    pub seed: u64,
    pub net: NetworkModel,
    pub straggler: StragglerModel,
    pub comp_mode: CompMode,
    /// Error (true) or warn (false) when the overflow budget is exceeded.
    pub strict_budget: bool,
    /// Fault injection: this many workers fail permanently...
    pub chaos_failures: usize,
    /// ...starting at this iteration. Training survives while the healthy
    /// count stays ≥ the recovery threshold.
    pub chaos_from_iter: u64,
    /// Account wire traffic at ⌈log₂ p⌉ bits/element (util::bitpack)
    /// instead of raw u64 — a 2.67x comm saving at the 24-bit prime.
    pub packed_wire: bool,
    /// How the sigmoid polynomial is fitted (least squares vs Chebyshev).
    pub fit_method: crate::sigmoid::FitMethod,
    /// Thread budget for the encode / worker-matmul / decode hot paths
    /// (CLI `--threads`, JSON `parallelism`). Results are bit-identical at
    /// every setting — see [`crate::util::par`]; only wall-clock changes.
    pub parallelism: Parallelism,
    /// Which coded objective trains (CLI `--model`, JSON `model`).
    pub model: ModelKind,
    /// Mini-batch: decode and apply only this many of the K row blocks per
    /// round, rotating the window each iteration (0 = full batch). The
    /// workers' cost is unchanged — the coded shares mix all blocks — but
    /// the master's decode pass and the gradient shrink to the batch.
    pub batch_blocks: usize,
    /// Chaos hook: this many workers run with an extra per-step sleep...
    pub chaos_slow_workers: usize,
    /// ...of this many milliseconds (real slow machines; the streaming
    /// round engine must leave them behind, not wait).
    pub chaos_slow_ms: u64,
    /// First worker id of the slow span: workers in
    /// `[chaos_slow_from, chaos_slow_from + chaos_slow_workers)` sleep.
    /// Default 0 keeps the historical prefix placement; the serve bench
    /// uses it to give each session a disjoint slow set. JSON
    /// `chaos_slow_from`.
    pub chaos_slow_from: usize,
    /// Which transport the cluster runs on (CLI `--transport`/`--workers`,
    /// JSON `transport`/`tcp_workers`/`connect_*`). Memory spawns threads
    /// in-process; Tcp connects to running `codedml --worker` processes.
    pub transport: TransportConfig,
    /// Eval-point layout / encode-decode implementation (CLI
    /// `--coding-backend`, JSON `coding_backend`). `Auto` engages the NTT
    /// coset layout when the modulus supports it and the cost model says
    /// it wins; forcing `Ntt` on a low-adicity modulus is a config error.
    pub coding_backend: CodingBackendChoice,
    /// Max cached decoder subsets (LRU; 0 = unbounded). CLI
    /// `--decode-cache-cap`, JSON `decode_cache_cap`.
    pub decode_cache_cap: usize,
    /// Per-round collection deadline in milliseconds (0 = wait forever,
    /// the pre-supervision behavior). When it fires, workers that have
    /// neither answered nor failed are charged a round failure and the
    /// round proceeds with whatever arrived — feeding the supervision /
    /// degraded-decode ladder. CLI `--round-deadline-ms`.
    pub round_deadline_ms: u64,
    /// Degraded mode: when a round ends with fewer than R usable results,
    /// fall back to least-squares approximate decoding
    /// ([`crate::coding::Decoder::decode_approx`]) instead of aborting.
    /// The per-iteration fit residual is surfaced via tracer events and
    /// [`super::report::TrainReport::max_approx_residual`]. CLI
    /// `--approx-decode`.
    pub approx_decode: bool,
    /// Hard floor for degraded mode: abort (structured error) when fewer
    /// than this many usable results remain. 0 = auto (K + T). The
    /// effective floor is always at least K + T. CLI `--approx-r-min`.
    pub approx_r_min: usize,
    /// Per-worker heal budget: how many times the supervisor may revive a
    /// failed worker (TCP redial / in-memory respawn + share re-ship).
    /// 0 disables supervision entirely. CLI `--max-respawns`.
    pub max_respawns: u32,
    /// Let the [`crate::cluster::DeadlineController`] tighten the round
    /// deadline to mean + 4σ of observed round wall times (never above
    /// `round_deadline_ms` when that is set). CLI `--adaptive-deadline`.
    pub adaptive_deadline: bool,
    /// Fair-share weight under the serve scheduler (JSON `priority`): a
    /// session's virtual time advances by 1/priority per round, so a
    /// priority-2 job is offered roughly twice the round slots of a
    /// priority-1 one when both are ready. Ignored (and harmless) for
    /// dedicated single-session runs. Must be ≥ 1.
    pub priority: u64,
}

impl Default for CodedMlConfig {
    fn default() -> Self {
        CodedMlConfig {
            n: 10,
            k: 3,
            t: 1,
            r: 1,
            p: PAPER_PRIME,
            lx: 2,
            lw: 4,
            lc: 3,
            fit_range: 5.0,
            iters: 25,
            eta: None,
            backend: BackendKind::Native,
            artifact_dir: PathBuf::from("artifacts"),
            seed: 42,
            net: NetworkModel::default(),
            straggler: StragglerModel::default(),
            comp_mode: CompMode::ModeledParallel,
            strict_budget: false,
            chaos_failures: 0,
            chaos_from_iter: 0,
            packed_wire: false,
            fit_method: crate::sigmoid::FitMethod::LeastSquares,
            parallelism: Parallelism::Serial,
            model: ModelKind::Logistic,
            batch_blocks: 0,
            chaos_slow_workers: 0,
            chaos_slow_ms: 0,
            chaos_slow_from: 0,
            transport: TransportConfig::default(),
            coding_backend: CodingBackendChoice::Auto,
            decode_cache_cap: crate::coding::decoder::DEFAULT_CACHE_CAP,
            round_deadline_ms: 0,
            approx_decode: false,
            approx_r_min: 0,
            max_respawns: 0,
            adaptive_deadline: false,
            priority: 1,
        }
    }
}

impl CodedMlConfig {
    /// Case 1 (§5): maximum parallelization.
    pub fn case1(n: usize, r: usize) -> Result<Self, ConfigError> {
        let p = CodingParams::case1(n, r)?;
        Ok(CodedMlConfig { n, k: p.k, t: p.t, r, ..Default::default() })
    }

    /// Case 2 (§5): equal parallelization and privacy.
    pub fn case2(n: usize, r: usize) -> Result<Self, ConfigError> {
        let p = CodingParams::case2(n, r)?;
        Ok(CodedMlConfig { n, k: p.k, t: p.t, r, ..Default::default() })
    }

    /// Defaults tuned for the Remark-1 linear-regression objective:
    /// `l_x = 4, l_w = 6, l_c = 0` with the 26-bit prime so
    /// `X̄ᵀ(X̄w̄ − ȳ)` keeps generous field headroom on the planted task.
    /// This is the single source of the linear scale choices (CLI,
    /// reproduce harness, examples, and tests all start here). A JSON
    /// config that merely flips `"model": "linear"` does NOT shift these —
    /// a config file is a complete specification and should set the scales
    /// it wants.
    pub fn linear() -> Self {
        CodedMlConfig {
            p: crate::field::PRIME_26,
            lx: 4,
            lw: 6,
            lc: 0,
            model: ModelKind::Linear,
            ..Default::default()
        }
    }

    pub fn coding_params(&self) -> Result<CodingParams, ConfigError> {
        Ok(CodingParams::new(self.n, self.k, self.t, self.r)?)
    }

    pub fn field(&self) -> PrimeField {
        PrimeField::new(self.p)
    }

    /// Validate against a dataset; returns the budget report.
    pub fn validate(&self, m: usize, max_abs_x: f64) -> Result<BudgetReport, ConfigError> {
        self.coding_params()?;
        if m / self.k == 0 {
            return Err(ConfigError::BadShape(format!(
                "m={m} too small for K={}",
                self.k
            )));
        }
        if self.batch_blocks > self.k {
            return Err(ConfigError::BadShape(format!(
                "batch_blocks={} exceeds K={}",
                self.batch_blocks, self.k
            )));
        }
        if self.approx_r_min > self.n {
            return Err(ConfigError::BadShape(format!(
                "approx_r_min={} exceeds n={} (no round can ever reach it)",
                self.approx_r_min, self.n
            )));
        }
        if self.transport.kind == TransportKind::Tcp
            && self.transport.tcp.workers.len() != self.n
        {
            return Err(ConfigError::BadShape(format!(
                "tcp transport needs {} worker addresses (one per worker), got {}",
                self.n,
                self.transport.tcp.workers.len()
            )));
        }
        let field = self.field();
        if !field.check_dot_safe(self.d_hint_or(m)) {
            // d unknown here; checked again in session with the real d.
        }
        let rep = OverflowBudget::for_field(
            &field,
            max_abs_x,
            m / self.k,
            self.lx,
            self.lw,
            self.lc,
            self.r as u32,
        );
        if !rep.ok() && self.strict_budget {
            return Err(ConfigError::Budget(rep));
        }
        Ok(rep)
    }

    fn d_hint_or(&self, fallback: usize) -> usize {
        fallback
    }

    /// Parse overrides from a JSON config file (the CLI's `--config`).
    /// Unknown keys are rejected to catch typos.
    pub fn apply_json(&mut self, text: &str) -> Result<(), String> {
        let root = Json::parse(text).map_err(|e| e.to_string())?;
        let obj = root.as_obj().ok_or("config must be a JSON object")?;
        for (key, val) in obj {
            match key.as_str() {
                "n" => self.n = val.as_usize().ok_or("n: want integer")?,
                "k" => self.k = val.as_usize().ok_or("k: want integer")?,
                "t" => self.t = val.as_usize().ok_or("t: want integer")?,
                "r" => self.r = val.as_usize().ok_or("r: want integer")?,
                "p" => self.p = val.as_u64().ok_or("p: want integer")?,
                "lx" => self.lx = val.as_u64().ok_or("lx: want integer")? as u32,
                "lw" => self.lw = val.as_u64().ok_or("lw: want integer")? as u32,
                "lc" => self.lc = val.as_u64().ok_or("lc: want integer")? as u32,
                "fit_range" => self.fit_range = val.as_f64().ok_or("fit_range: want number")?,
                "iters" => self.iters = val.as_usize().ok_or("iters: want integer")?,
                "eta" => self.eta = Some(val.as_f64().ok_or("eta: want number")?),
                "seed" => self.seed = val.as_u64().ok_or("seed: want integer")?,
                "backend" => {
                    self.backend = val
                        .as_str()
                        .ok_or("backend: want string")?
                        .parse()
                        .map_err(|e: String| e)?
                }
                "artifact_dir" => {
                    self.artifact_dir =
                        PathBuf::from(val.as_str().ok_or("artifact_dir: want string")?)
                }
                "bandwidth" => {
                    self.net.bandwidth = val.as_f64().ok_or("bandwidth: want number")?
                }
                "latency" => self.net.latency = val.as_f64().ok_or("latency: want number")?,
                "straggler_rate" => {
                    // null = no exponential tail (rate λ = ∞, which plain
                    // JSON cannot carry as a number).
                    self.straggler.rate = if matches!(val, Json::Null) {
                        f64::INFINITY
                    } else {
                        val.as_f64().ok_or("straggler_rate: want number or null")?
                    }
                }
                "straggler_shift" => {
                    self.straggler.shift = val.as_f64().ok_or("straggler_shift: want number")?
                }
                "strict_budget" => {
                    self.strict_budget = val.as_bool().ok_or("strict_budget: want bool")?
                }
                "packed_wire" => {
                    self.packed_wire = val.as_bool().ok_or("packed_wire: want bool")?
                }
                "parallelism" => {
                    self.parallelism = if let Some(s) = val.as_str() {
                        s.parse().map_err(|e: String| e)?
                    } else if let Some(n) = val.as_u64() {
                        Parallelism::from_count(n as usize)
                    } else {
                        return Err("parallelism: want integer or 'serial'/'auto'".into());
                    }
                }
                "fit_method" => {
                    self.fit_method = val
                        .as_str()
                        .ok_or("fit_method: want string")?
                        .parse()
                        .map_err(|e: String| e)?
                }
                "model" => {
                    self.model = val
                        .as_str()
                        .ok_or("model: want string")?
                        .parse()
                        .map_err(|e: String| e)?
                }
                "comp_mode" => {
                    self.comp_mode = val
                        .as_str()
                        .ok_or("comp_mode: want string")?
                        .parse()
                        .map_err(|e: String| e)?
                }
                "straggler_relative" => {
                    self.straggler.relative =
                        val.as_bool().ok_or("straggler_relative: want bool")?
                }
                "batch_blocks" => {
                    self.batch_blocks = val.as_usize().ok_or("batch_blocks: want integer")?
                }
                "chaos_failures" => {
                    self.chaos_failures = val.as_usize().ok_or("chaos_failures: want integer")?
                }
                "chaos_from_iter" => {
                    self.chaos_from_iter = val.as_u64().ok_or("chaos_from_iter: want integer")?
                }
                "chaos_slow_workers" => {
                    self.chaos_slow_workers =
                        val.as_usize().ok_or("chaos_slow_workers: want integer")?
                }
                "chaos_slow_ms" => {
                    self.chaos_slow_ms = val.as_u64().ok_or("chaos_slow_ms: want integer")?
                }
                "chaos_slow_from" => {
                    self.chaos_slow_from =
                        val.as_usize().ok_or("chaos_slow_from: want integer")?
                }
                "transport" => {
                    self.transport.kind = val
                        .as_str()
                        .ok_or("transport: want string")?
                        .parse()
                        .map_err(|e: String| e)?
                }
                "tcp_workers" => {
                    let arr = val.as_arr().ok_or("tcp_workers: want array of strings")?;
                    let mut workers = Vec::with_capacity(arr.len());
                    for v in arr {
                        workers.push(
                            v.as_str()
                                .ok_or("tcp_workers: want array of strings")?
                                .to_string(),
                        );
                    }
                    self.transport.tcp.workers = workers;
                }
                "connect_timeout_ms" => {
                    self.transport.tcp.connect_timeout_ms =
                        val.as_u64().ok_or("connect_timeout_ms: want integer")?
                }
                "connect_retries" => {
                    self.transport.tcp.connect_retries =
                        val.as_u64().ok_or("connect_retries: want integer")? as u32
                }
                "connect_backoff_ms" => {
                    self.transport.tcp.connect_backoff_ms =
                        val.as_u64().ok_or("connect_backoff_ms: want integer")?
                }
                "coding_backend" => {
                    self.coding_backend = val
                        .as_str()
                        .ok_or("coding_backend: want string")?
                        .parse()
                        .map_err(|e: String| e)?
                }
                "decode_cache_cap" => {
                    self.decode_cache_cap =
                        val.as_usize().ok_or("decode_cache_cap: want integer")?
                }
                "round_deadline_ms" => {
                    self.round_deadline_ms =
                        val.as_u64().ok_or("round_deadline_ms: want integer")?
                }
                "approx_decode" => {
                    self.approx_decode = val.as_bool().ok_or("approx_decode: want bool")?
                }
                "approx_r_min" => {
                    self.approx_r_min = val.as_usize().ok_or("approx_r_min: want integer")?
                }
                "max_respawns" => {
                    self.max_respawns =
                        val.as_u64().ok_or("max_respawns: want integer")? as u32
                }
                "adaptive_deadline" => {
                    self.adaptive_deadline =
                        val.as_bool().ok_or("adaptive_deadline: want bool")?
                }
                "priority" => {
                    let p = val.as_u64().ok_or("priority: want integer")?;
                    if p == 0 {
                        return Err("priority: must be >= 1".into());
                    }
                    self.priority = p;
                }
                other => return Err(format!("unknown config key '{other}'")),
            }
        }
        Ok(())
    }

    /// Serialize to the same JSON dialect [`Self::apply_json`] parses —
    /// `apply_json(&cfg.to_json().to_string())` on a default config
    /// reconstructs `cfg` exactly (round-trip tested below).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("n", Json::Num(self.n as f64)),
            ("k", Json::Num(self.k as f64)),
            ("t", Json::Num(self.t as f64)),
            ("r", Json::Num(self.r as f64)),
            ("p", Json::Num(self.p as f64)),
            ("lx", Json::Num(self.lx as f64)),
            ("lw", Json::Num(self.lw as f64)),
            ("lc", Json::Num(self.lc as f64)),
            ("fit_range", Json::Num(self.fit_range)),
            ("iters", Json::Num(self.iters as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("backend", Json::Str(self.backend.to_string())),
            (
                "artifact_dir",
                Json::Str(self.artifact_dir.to_string_lossy().into_owned()),
            ),
            ("bandwidth", Json::Num(self.net.bandwidth)),
            ("latency", Json::Num(self.net.latency)),
            (
                "straggler_rate",
                if self.straggler.rate.is_finite() {
                    Json::Num(self.straggler.rate)
                } else {
                    Json::Null
                },
            ),
            ("straggler_shift", Json::Num(self.straggler.shift)),
            ("straggler_relative", Json::Bool(self.straggler.relative)),
            ("comp_mode", Json::Str(self.comp_mode.to_string())),
            ("strict_budget", Json::Bool(self.strict_budget)),
            ("packed_wire", Json::Bool(self.packed_wire)),
            ("parallelism", Json::Str(self.parallelism.to_string())),
            ("fit_method", Json::Str(self.fit_method.to_string())),
            ("model", Json::Str(self.model.to_string())),
            ("batch_blocks", Json::Num(self.batch_blocks as f64)),
            ("chaos_failures", Json::Num(self.chaos_failures as f64)),
            ("chaos_from_iter", Json::Num(self.chaos_from_iter as f64)),
            ("chaos_slow_workers", Json::Num(self.chaos_slow_workers as f64)),
            ("chaos_slow_ms", Json::Num(self.chaos_slow_ms as f64)),
            ("chaos_slow_from", Json::Num(self.chaos_slow_from as f64)),
            ("transport", Json::Str(self.transport.kind.to_string())),
            (
                "tcp_workers",
                Json::Arr(
                    self.transport
                        .tcp
                        .workers
                        .iter()
                        .map(|w| Json::Str(w.clone()))
                        .collect(),
                ),
            ),
            (
                "connect_timeout_ms",
                Json::Num(self.transport.tcp.connect_timeout_ms as f64),
            ),
            (
                "connect_retries",
                Json::Num(self.transport.tcp.connect_retries as f64),
            ),
            (
                "connect_backoff_ms",
                Json::Num(self.transport.tcp.connect_backoff_ms as f64),
            ),
            ("coding_backend", Json::Str(self.coding_backend.to_string())),
            ("decode_cache_cap", Json::Num(self.decode_cache_cap as f64)),
            ("round_deadline_ms", Json::Num(self.round_deadline_ms as f64)),
            ("approx_decode", Json::Bool(self.approx_decode)),
            ("approx_r_min", Json::Num(self.approx_r_min as f64)),
            ("max_respawns", Json::Num(self.max_respawns as f64)),
            ("adaptive_deadline", Json::Bool(self.adaptive_deadline)),
            ("priority", Json::Num(self.priority as f64)),
        ];
        if let Some(eta) = self.eta {
            fields.push(("eta", Json::Num(eta)));
        }
        obj(&fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        let cfg = CodedMlConfig::default();
        cfg.coding_params().unwrap();
        cfg.validate(300, 1.0).unwrap();
    }

    #[test]
    fn case_constructors_match_paper() {
        let c1 = CodedMlConfig::case1(40, 1).unwrap();
        assert_eq!((c1.k, c1.t), (13, 1));
        let c2 = CodedMlConfig::case2(40, 1).unwrap();
        assert_eq!((c2.k, c2.t), (7, 7));
    }

    #[test]
    fn strict_budget_rejects_overflow() {
        let mut cfg = CodedMlConfig::default();
        cfg.strict_budget = true;
        cfg.k = 3;
        cfg.lc = 8;
        // Huge block with big scales: must error.
        let err = cfg.validate(120_000, 1.0).unwrap_err();
        assert!(matches!(err, ConfigError::Budget(_)), "{err}");
        // Non-strict only warns (returns report).
        cfg.strict_budget = false;
        let rep = cfg.validate(120_000, 1.0).unwrap();
        assert!(!rep.ok());
    }

    #[test]
    fn json_overrides_apply() {
        let mut cfg = CodedMlConfig::default();
        cfg.apply_json(
            r#"{"n": 16, "k": 4, "t": 1, "iters": 7, "backend": "native",
                "eta": 0.5, "bandwidth": 1e9, "strict_budget": true,
                "parallelism": "auto"}"#,
        )
        .unwrap();
        assert_eq!(cfg.n, 16);
        assert_eq!(cfg.k, 4);
        assert_eq!(cfg.iters, 7);
        assert_eq!(cfg.eta, Some(0.5));
        assert_eq!(cfg.net.bandwidth, 1e9);
        assert!(cfg.strict_budget);
        assert_eq!(cfg.parallelism, Parallelism::Auto);
    }

    #[test]
    fn json_parallelism_accepts_counts_and_rejects_garbage() {
        let mut cfg = CodedMlConfig::default();
        cfg.apply_json(r#"{"parallelism": 4}"#).unwrap();
        assert_eq!(cfg.parallelism, Parallelism::from_count(4));
        cfg.apply_json(r#"{"parallelism": 0}"#).unwrap();
        assert_eq!(cfg.parallelism, Parallelism::Auto);
        cfg.apply_json(r#"{"parallelism": 1}"#).unwrap();
        assert_eq!(cfg.parallelism, Parallelism::Serial);
        assert!(cfg.apply_json(r#"{"parallelism": "many"}"#).is_err());
        assert!(cfg.apply_json(r#"{"parallelism": true}"#).is_err());
    }

    #[test]
    fn model_kind_string_round_trip() {
        for m in [ModelKind::Logistic, ModelKind::Linear] {
            assert_eq!(m.to_string().parse::<ModelKind>().unwrap(), m);
        }
        assert!("perceptron".parse::<ModelKind>().is_err());
        assert_eq!(ModelKind::default(), ModelKind::Logistic);
    }

    #[test]
    fn json_model_key_applies() {
        let mut cfg = CodedMlConfig::default();
        cfg.apply_json(r#"{"model": "linear", "batch_blocks": 2}"#).unwrap();
        assert_eq!(cfg.model, ModelKind::Linear);
        assert_eq!(cfg.batch_blocks, 2);
        assert!(cfg.apply_json(r#"{"model": "svm"}"#).is_err());
    }

    #[test]
    fn config_json_round_trips_exactly() {
        let cfg = CodedMlConfig {
            n: 16,
            k: 4,
            t: 2,
            r: 2,
            p: crate::field::PRIME_26,
            lx: 3,
            lw: 5,
            lc: 1,
            fit_range: 4.0,
            iters: 7,
            eta: Some(0.125),
            seed: 99,
            backend: BackendKind::Xla,
            artifact_dir: PathBuf::from("elsewhere"),
            net: NetworkModel { bandwidth: 2e9, latency: 1e-3 },
            straggler: StragglerModel { shift: 0.25, rate: 3.0, relative: false },
            comp_mode: CompMode::Wall,
            strict_budget: true,
            chaos_failures: 2,
            chaos_from_iter: 5,
            packed_wire: true,
            fit_method: crate::sigmoid::FitMethod::Chebyshev,
            parallelism: Parallelism::from_count(4),
            model: ModelKind::Linear,
            batch_blocks: 3,
            chaos_slow_workers: 1,
            chaos_slow_ms: 40,
            chaos_slow_from: 2,
            transport: TransportConfig {
                kind: TransportKind::Tcp,
                tcp: crate::cluster::transport::TcpConfig {
                    workers: vec!["10.0.0.1:7000".into(), "10.0.0.2:7000".into()],
                    connect_timeout_ms: 750,
                    connect_retries: 5,
                    connect_backoff_ms: 25,
                },
            },
            coding_backend: CodingBackendChoice::Ntt,
            decode_cache_cap: 64,
            round_deadline_ms: 250,
            approx_decode: true,
            approx_r_min: 6,
            max_respawns: 2,
            adaptive_deadline: true,
            priority: 3,
        };
        let text = cfg.to_json().to_string();
        let mut restored = CodedMlConfig::default();
        restored.apply_json(&text).unwrap();
        assert_eq!(restored, cfg);
    }

    #[test]
    fn config_json_round_trips_infinite_straggler_rate() {
        let cfg = CodedMlConfig { straggler: StragglerModel::none(), ..Default::default() };
        let text = cfg.to_json().to_string();
        let mut restored = CodedMlConfig::default();
        restored.apply_json(&text).unwrap();
        assert_eq!(restored, cfg);
    }

    #[test]
    fn batch_blocks_bounded_by_k() {
        let cfg = CodedMlConfig { batch_blocks: 5, ..Default::default() }; // K=3
        match cfg.validate(300, 1.0) {
            Err(ConfigError::BadShape(msg)) => assert!(msg.contains("batch_blocks"), "{msg}"),
            other => panic!("expected BadShape, got {other:?}"),
        }
        let cfg = CodedMlConfig { batch_blocks: 3, ..Default::default() };
        cfg.validate(300, 1.0).unwrap();
    }

    #[test]
    fn json_transport_keys_apply_in_any_order() {
        // Keys reach apply_json alphabetically (BTreeMap-backed object), so
        // the tcp knobs land before "transport" — the flat layout makes
        // that ordering irrelevant.
        let mut cfg = CodedMlConfig::default();
        cfg.apply_json(
            r#"{"transport": "tcp",
                "tcp_workers": ["127.0.0.1:7001", "127.0.0.1:7002"],
                "connect_timeout_ms": 900, "connect_retries": 1,
                "connect_backoff_ms": 10}"#,
        )
        .unwrap();
        assert_eq!(cfg.transport.kind, TransportKind::Tcp);
        assert_eq!(
            cfg.transport.tcp.workers,
            vec!["127.0.0.1:7001".to_string(), "127.0.0.1:7002".to_string()]
        );
        assert_eq!(cfg.transport.tcp.connect_timeout_ms, 900);
        assert_eq!(cfg.transport.tcp.connect_retries, 1);
        assert_eq!(cfg.transport.tcp.connect_backoff_ms, 10);
        assert!(cfg.apply_json(r#"{"transport": "carrier-pigeon"}"#).is_err());
        assert!(cfg.apply_json(r#"{"tcp_workers": [1, 2]}"#).is_err());
    }

    #[test]
    fn validate_requires_one_address_per_worker_on_tcp() {
        let mut cfg = CodedMlConfig::default(); // n = 10
        cfg.transport.kind = TransportKind::Tcp;
        cfg.transport.tcp.workers = vec!["127.0.0.1:7001".into(); 3];
        match cfg.validate(300, 1.0) {
            Err(ConfigError::BadShape(msg)) => {
                assert!(msg.contains("10 worker addresses"), "{msg}");
            }
            other => panic!("expected BadShape, got {other:?}"),
        }
        cfg.transport.tcp.workers = vec!["127.0.0.1:7001".into(); 10];
        cfg.validate(300, 1.0).unwrap();
    }

    #[test]
    fn json_coding_backend_and_cache_cap_apply() {
        let mut cfg = CodedMlConfig::default();
        assert_eq!(cfg.coding_backend, CodingBackendChoice::Auto);
        cfg.apply_json(r#"{"coding_backend": "ntt", "decode_cache_cap": 8}"#).unwrap();
        assert_eq!(cfg.coding_backend, CodingBackendChoice::Ntt);
        assert_eq!(cfg.decode_cache_cap, 8);
        cfg.apply_json(r#"{"coding_backend": "dense"}"#).unwrap();
        assert_eq!(cfg.coding_backend, CodingBackendChoice::Dense);
        assert!(cfg.apply_json(r#"{"coding_backend": "fft"}"#).is_err());
        assert!(cfg.apply_json(r#"{"decode_cache_cap": "lots"}"#).is_err());
    }

    #[test]
    fn json_fault_tolerance_keys_apply() {
        let mut cfg = CodedMlConfig::default();
        cfg.apply_json(
            r#"{"round_deadline_ms": 150, "approx_decode": true,
                "approx_r_min": 5, "max_respawns": 3,
                "adaptive_deadline": true}"#,
        )
        .unwrap();
        assert_eq!(cfg.round_deadline_ms, 150);
        assert!(cfg.approx_decode);
        assert_eq!(cfg.approx_r_min, 5);
        assert_eq!(cfg.max_respawns, 3);
        assert!(cfg.adaptive_deadline);
        assert!(cfg.apply_json(r#"{"approx_decode": "yes"}"#).is_err());
    }

    #[test]
    fn approx_r_min_bounded_by_n() {
        let cfg = CodedMlConfig { approx_r_min: 11, ..Default::default() }; // n = 10
        match cfg.validate(300, 1.0) {
            Err(ConfigError::BadShape(msg)) => assert!(msg.contains("approx_r_min"), "{msg}"),
            other => panic!("expected BadShape, got {other:?}"),
        }
        let cfg = CodedMlConfig { approx_r_min: 10, ..Default::default() };
        cfg.validate(300, 1.0).unwrap();
    }

    #[test]
    fn json_priority_applies_and_rejects_zero() {
        let mut cfg = CodedMlConfig::default();
        assert_eq!(cfg.priority, 1);
        cfg.apply_json(r#"{"priority": 4}"#).unwrap();
        assert_eq!(cfg.priority, 4);
        assert!(cfg.apply_json(r#"{"priority": 0}"#).is_err());
        assert!(cfg.apply_json(r#"{"priority": "high"}"#).is_err());
    }

    #[test]
    fn json_unknown_key_rejected() {
        let mut cfg = CodedMlConfig::default();
        let err = cfg.apply_json(r#"{"worker_count": 3}"#).unwrap_err();
        assert!(err.contains("unknown config key"), "{err}");
    }

    #[test]
    fn bad_shape_detected() {
        let cfg = CodedMlConfig { k: 50, ..Default::default() };
        // k=50 with n=10 violates threshold first.
        assert!(matches!(cfg.validate(30, 1.0), Err(ConfigError::Params(_))));
    }
}
