//! Session configuration.

use std::path::PathBuf;

use crate::cluster::{NetworkModel, StragglerModel};
use crate::coding::{CodingParams, ParamError};
use crate::field::{PrimeField, PAPER_PRIME};
use crate::quant::{BudgetReport, OverflowBudget};
use crate::runtime::BackendKind;
use crate::util::json::Json;
use crate::util::par::Parallelism;

/// How per-iteration computation time is attributed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompMode {
    /// R-th order statistic of per-worker (measured compute + straggle) —
    /// the paper's N-independent-machines semantics (default).
    ModeledParallel,
    /// Wall-clock time from dispatch to the R-th arrival on this host
    /// (deflated by thread oversubscription; for debugging only).
    Wall,
}

#[derive(Debug)]
pub enum ConfigError {
    Params(ParamError),
    /// Overflow budget exceeded and `strict_budget` set.
    Budget(BudgetReport),
    /// m not usable with K.
    BadShape(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Params(e) => write!(f, "{e}"),
            ConfigError::Budget(rep) => write!(
                f,
                "overflow budget exceeded: worst case {:.3e} > limit {:.3e} \
                 (utilization {:.2}); lower l_c/l_x/l_w, raise K, or use a larger prime",
                rep.worst_case, rep.limit, rep.utilization
            ),
            ConfigError::BadShape(s) => write!(f, "{s}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl From<ParamError> for ConfigError {
    fn from(e: ParamError) -> Self {
        ConfigError::Params(e)
    }
}

/// Everything a CodedPrivateML training session needs.
#[derive(Debug, Clone)]
pub struct CodedMlConfig {
    /// Workers.
    pub n: usize,
    /// Parallelization (dataset blocks).
    pub k: usize,
    /// Privacy threshold.
    pub t: usize,
    /// Sigmoid polynomial degree.
    pub r: usize,
    /// Field prime.
    pub p: u64,
    /// Dataset scale bits (paper: 2).
    pub lx: u32,
    /// Weight scale bits (paper: 4).
    pub lw: u32,
    /// Coefficient scale bits (our generalization; 0 = paper formula).
    pub lc: u32,
    /// Sigmoid fit half-range R.
    pub fit_range: f64,
    /// Training iterations (paper: 25).
    pub iters: usize,
    /// Step size; None → 1/L from Lemma 2.
    pub eta: Option<f64>,
    /// Worker compute backend.
    pub backend: BackendKind,
    pub artifact_dir: PathBuf,
    /// RNG seed (masks, stochastic quantization, stragglers).
    pub seed: u64,
    pub net: NetworkModel,
    pub straggler: StragglerModel,
    pub comp_mode: CompMode,
    /// Error (true) or warn (false) when the overflow budget is exceeded.
    pub strict_budget: bool,
    /// Fault injection: this many workers fail permanently...
    pub chaos_failures: usize,
    /// ...starting at this iteration. Training survives while the healthy
    /// count stays ≥ the recovery threshold.
    pub chaos_from_iter: u64,
    /// Account wire traffic at ⌈log₂ p⌉ bits/element (util::bitpack)
    /// instead of raw u64 — a 2.67x comm saving at the 24-bit prime.
    pub packed_wire: bool,
    /// How the sigmoid polynomial is fitted (least squares vs Chebyshev).
    pub fit_method: crate::sigmoid::FitMethod,
    /// Thread budget for the encode / worker-matmul / decode hot paths
    /// (CLI `--threads`, JSON `parallelism`). Results are bit-identical at
    /// every setting — see [`crate::util::par`]; only wall-clock changes.
    pub parallelism: Parallelism,
}

impl Default for CodedMlConfig {
    fn default() -> Self {
        CodedMlConfig {
            n: 10,
            k: 3,
            t: 1,
            r: 1,
            p: PAPER_PRIME,
            lx: 2,
            lw: 4,
            lc: 3,
            fit_range: 5.0,
            iters: 25,
            eta: None,
            backend: BackendKind::Native,
            artifact_dir: PathBuf::from("artifacts"),
            seed: 42,
            net: NetworkModel::default(),
            straggler: StragglerModel::default(),
            comp_mode: CompMode::ModeledParallel,
            strict_budget: false,
            chaos_failures: 0,
            chaos_from_iter: 0,
            packed_wire: false,
            fit_method: crate::sigmoid::FitMethod::LeastSquares,
            parallelism: Parallelism::Serial,
        }
    }
}

impl CodedMlConfig {
    /// Case 1 (§5): maximum parallelization.
    pub fn case1(n: usize, r: usize) -> Result<Self, ConfigError> {
        let p = CodingParams::case1(n, r)?;
        Ok(CodedMlConfig { n, k: p.k, t: p.t, r, ..Default::default() })
    }

    /// Case 2 (§5): equal parallelization and privacy.
    pub fn case2(n: usize, r: usize) -> Result<Self, ConfigError> {
        let p = CodingParams::case2(n, r)?;
        Ok(CodedMlConfig { n, k: p.k, t: p.t, r, ..Default::default() })
    }

    pub fn coding_params(&self) -> Result<CodingParams, ConfigError> {
        Ok(CodingParams::new(self.n, self.k, self.t, self.r)?)
    }

    pub fn field(&self) -> PrimeField {
        PrimeField::new(self.p)
    }

    /// Validate against a dataset; returns the budget report.
    pub fn validate(&self, m: usize, max_abs_x: f64) -> Result<BudgetReport, ConfigError> {
        self.coding_params()?;
        if m / self.k == 0 {
            return Err(ConfigError::BadShape(format!(
                "m={m} too small for K={}",
                self.k
            )));
        }
        let field = self.field();
        if !field.check_dot_safe(self.d_hint_or(m)) {
            // d unknown here; checked again in session with the real d.
        }
        let rep = OverflowBudget::for_field(
            &field,
            max_abs_x,
            m / self.k,
            self.lx,
            self.lw,
            self.lc,
            self.r as u32,
        );
        if !rep.ok() && self.strict_budget {
            return Err(ConfigError::Budget(rep));
        }
        Ok(rep)
    }

    fn d_hint_or(&self, fallback: usize) -> usize {
        fallback
    }

    /// Parse overrides from a JSON config file (the CLI's `--config`).
    /// Unknown keys are rejected to catch typos.
    pub fn apply_json(&mut self, text: &str) -> Result<(), String> {
        let root = Json::parse(text).map_err(|e| e.to_string())?;
        let obj = root.as_obj().ok_or("config must be a JSON object")?;
        for (key, val) in obj {
            match key.as_str() {
                "n" => self.n = val.as_usize().ok_or("n: want integer")?,
                "k" => self.k = val.as_usize().ok_or("k: want integer")?,
                "t" => self.t = val.as_usize().ok_or("t: want integer")?,
                "r" => self.r = val.as_usize().ok_or("r: want integer")?,
                "p" => self.p = val.as_u64().ok_or("p: want integer")?,
                "lx" => self.lx = val.as_u64().ok_or("lx: want integer")? as u32,
                "lw" => self.lw = val.as_u64().ok_or("lw: want integer")? as u32,
                "lc" => self.lc = val.as_u64().ok_or("lc: want integer")? as u32,
                "fit_range" => self.fit_range = val.as_f64().ok_or("fit_range: want number")?,
                "iters" => self.iters = val.as_usize().ok_or("iters: want integer")?,
                "eta" => self.eta = Some(val.as_f64().ok_or("eta: want number")?),
                "seed" => self.seed = val.as_u64().ok_or("seed: want integer")?,
                "backend" => {
                    self.backend = val
                        .as_str()
                        .ok_or("backend: want string")?
                        .parse()
                        .map_err(|e: String| e)?
                }
                "artifact_dir" => {
                    self.artifact_dir =
                        PathBuf::from(val.as_str().ok_or("artifact_dir: want string")?)
                }
                "bandwidth" => {
                    self.net.bandwidth = val.as_f64().ok_or("bandwidth: want number")?
                }
                "latency" => self.net.latency = val.as_f64().ok_or("latency: want number")?,
                "straggler_rate" => {
                    self.straggler.rate = val.as_f64().ok_or("straggler_rate: want number")?
                }
                "straggler_shift" => {
                    self.straggler.shift = val.as_f64().ok_or("straggler_shift: want number")?
                }
                "strict_budget" => {
                    self.strict_budget = val.as_bool().ok_or("strict_budget: want bool")?
                }
                "packed_wire" => {
                    self.packed_wire = val.as_bool().ok_or("packed_wire: want bool")?
                }
                "parallelism" => {
                    self.parallelism = if let Some(s) = val.as_str() {
                        s.parse().map_err(|e: String| e)?
                    } else if let Some(n) = val.as_u64() {
                        Parallelism::from_count(n as usize)
                    } else {
                        return Err("parallelism: want integer or 'serial'/'auto'".into());
                    }
                }
                "fit_method" => {
                    self.fit_method = val
                        .as_str()
                        .ok_or("fit_method: want string")?
                        .parse()
                        .map_err(|e: String| e)?
                }
                other => return Err(format!("unknown config key '{other}'")),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        let cfg = CodedMlConfig::default();
        cfg.coding_params().unwrap();
        cfg.validate(300, 1.0).unwrap();
    }

    #[test]
    fn case_constructors_match_paper() {
        let c1 = CodedMlConfig::case1(40, 1).unwrap();
        assert_eq!((c1.k, c1.t), (13, 1));
        let c2 = CodedMlConfig::case2(40, 1).unwrap();
        assert_eq!((c2.k, c2.t), (7, 7));
    }

    #[test]
    fn strict_budget_rejects_overflow() {
        let mut cfg = CodedMlConfig::default();
        cfg.strict_budget = true;
        cfg.k = 3;
        cfg.lc = 8;
        // Huge block with big scales: must error.
        let err = cfg.validate(120_000, 1.0).unwrap_err();
        assert!(matches!(err, ConfigError::Budget(_)), "{err}");
        // Non-strict only warns (returns report).
        cfg.strict_budget = false;
        let rep = cfg.validate(120_000, 1.0).unwrap();
        assert!(!rep.ok());
    }

    #[test]
    fn json_overrides_apply() {
        let mut cfg = CodedMlConfig::default();
        cfg.apply_json(
            r#"{"n": 16, "k": 4, "t": 1, "iters": 7, "backend": "native",
                "eta": 0.5, "bandwidth": 1e9, "strict_budget": true,
                "parallelism": "auto"}"#,
        )
        .unwrap();
        assert_eq!(cfg.n, 16);
        assert_eq!(cfg.k, 4);
        assert_eq!(cfg.iters, 7);
        assert_eq!(cfg.eta, Some(0.5));
        assert_eq!(cfg.net.bandwidth, 1e9);
        assert!(cfg.strict_budget);
        assert_eq!(cfg.parallelism, Parallelism::Auto);
    }

    #[test]
    fn json_parallelism_accepts_counts_and_rejects_garbage() {
        let mut cfg = CodedMlConfig::default();
        cfg.apply_json(r#"{"parallelism": 4}"#).unwrap();
        assert_eq!(cfg.parallelism, Parallelism::from_count(4));
        cfg.apply_json(r#"{"parallelism": 0}"#).unwrap();
        assert_eq!(cfg.parallelism, Parallelism::Auto);
        cfg.apply_json(r#"{"parallelism": 1}"#).unwrap();
        assert_eq!(cfg.parallelism, Parallelism::Serial);
        assert!(cfg.apply_json(r#"{"parallelism": "many"}"#).is_err());
        assert!(cfg.apply_json(r#"{"parallelism": true}"#).is_err());
    }

    #[test]
    fn json_unknown_key_rejected() {
        let mut cfg = CodedMlConfig::default();
        let err = cfg.apply_json(r#"{"worker_count": 3}"#).unwrap_err();
        assert!(err.contains("unknown config key"), "{err}");
    }

    #[test]
    fn bad_shape_detected() {
        let cfg = CodedMlConfig { k: 50, ..Default::default() };
        // k=50 with n=10 violates threshold first.
        assert!(matches!(cfg.validate(30, 1.0), Err(ConfigError::Params(_))));
    }
}
