//! Training reports: the paper's timing breakdown plus convergence curves.

use crate::util::json::{obj, Json};

/// The Encode / Comm. / Comp. / Total columns of Tables 1–6.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TimingBreakdown {
    /// Dataset + per-iteration weight encoding and secret sharing (s).
    pub encode_s: f64,
    /// Modeled network time, master↔workers (s).
    pub comm_s: f64,
    /// Worker computation (modeled parallel) + master decode (s).
    pub comp_s: f64,
}

impl TimingBreakdown {
    pub fn total(&self) -> f64 {
        self.encode_s + self.comm_s + self.comp_s
    }

    /// A paper-style table row.
    pub fn row(&self, label: &str) -> String {
        format!(
            "| {label:<24} | {:>8.2} | {:>8.2} | {:>8.2} | {:>9.2} |",
            self.encode_s,
            self.comm_s,
            self.comp_s,
            self.total()
        )
    }
}

/// Per-iteration convergence metrics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct IterationMetrics {
    pub iter: usize,
    /// Cross-entropy on the (quantized) training set.
    pub train_loss: f64,
    /// Test accuracy, if a test set was supplied.
    pub test_accuracy: Option<f64>,
}

/// The outcome of a training run.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub breakdown: TimingBreakdown,
    /// Master-side decode seconds (included in `breakdown.comp_s`).
    pub decode_s: f64,
    pub iterations: Vec<IterationMetrics>,
    /// Final weights (real domain).
    pub weights: Vec<f64>,
    /// Decoder cache (hits, misses).
    pub decode_cache: (u64, u64),
    /// Subsets evicted from the decoder's bounded LRU cache.
    pub decode_cache_evictions: u64,
    /// Encode/decode backend that ran ("dense" or "ntt").
    pub coding_backend: &'static str,
    /// Recovery threshold used.
    pub recovery_threshold: usize,
    /// Bytes moved master→workers and workers→master (modeled).
    pub bytes_sent: u64,
    pub bytes_received: u64,
    /// Worker step failures observed across the run (each also emits a
    /// `worker_failure` tracer event). Training survives while the usable
    /// count stays ≥ the recovery threshold.
    pub worker_failures: u64,
    /// Results that arrived after their round had already completed and
    /// were drained without decoding (the early-exit engine's discards).
    pub late_results: u64,
    /// Rounds decoded in degraded (approximate least-squares) mode
    /// because fewer than R usable results arrived before the deadline.
    pub approx_rounds: u64,
    /// Largest RMS fit residual any approximate decode reported
    /// (centered-lift units; 0.0 when every round decoded exactly).
    pub max_approx_residual: f64,
    /// Failed workers the supervisor successfully revived (TCP redial or
    /// in-memory respawn, plus share re-ship).
    pub respawns: u64,
    /// Rounds whose collection deadline fired before R results arrived.
    pub deadline_expired_rounds: u64,
}

impl TrainReport {
    pub fn final_loss(&self) -> Option<f64> {
        self.iterations.last().map(|m| m.train_loss)
    }

    pub fn final_accuracy(&self) -> Option<f64> {
        self.iterations.last().and_then(|m| m.test_accuracy)
    }

    /// Machine-readable JSON (consumed by the reproduce harness).
    pub fn to_json(&self) -> Json {
        obj(&[
            ("encode_s", Json::Num(self.breakdown.encode_s)),
            ("comm_s", Json::Num(self.breakdown.comm_s)),
            ("comp_s", Json::Num(self.breakdown.comp_s)),
            ("total_s", Json::Num(self.breakdown.total())),
            ("decode_s", Json::Num(self.decode_s)),
            ("decode_cache_hits", Json::Num(self.decode_cache.0 as f64)),
            ("decode_cache_misses", Json::Num(self.decode_cache.1 as f64)),
            (
                "decode_cache_evictions",
                Json::Num(self.decode_cache_evictions as f64),
            ),
            ("coding_backend", Json::Str(self.coding_backend.to_string())),
            ("recovery_threshold", Json::Num(self.recovery_threshold as f64)),
            ("bytes_sent", Json::Num(self.bytes_sent as f64)),
            ("bytes_received", Json::Num(self.bytes_received as f64)),
            ("worker_failures", Json::Num(self.worker_failures as f64)),
            ("late_results", Json::Num(self.late_results as f64)),
            ("approx_rounds", Json::Num(self.approx_rounds as f64)),
            ("max_approx_residual", Json::Num(self.max_approx_residual)),
            ("respawns", Json::Num(self.respawns as f64)),
            (
                "deadline_expired_rounds",
                Json::Num(self.deadline_expired_rounds as f64),
            ),
            (
                "loss_curve",
                Json::Arr(self.iterations.iter().map(|m| Json::Num(m.train_loss)).collect()),
            ),
            (
                "accuracy_curve",
                Json::Arr(
                    self.iterations
                        .iter()
                        .map(|m| m.test_accuracy.map(Json::Num).unwrap_or(Json::Null))
                        .collect(),
                ),
            ),
        ])
    }
}

/// One session's slice of a serve run: identity + scheduling config on
/// top of its ordinary [`TrainReport`].
#[derive(Debug, Clone, Default)]
pub struct SessionSummary {
    /// Job name from the serve spec.
    pub name: String,
    /// Routing id the scheduler assigned (unique within the run).
    pub session_id: u64,
    /// Fair-share weight the scheduler honored.
    pub priority: u64,
    /// Objective trained ("logistic" / "linear").
    pub objective: String,
    /// Why the session stopped early, if it did. Sessions fail
    /// independently under the scheduler: one job's abort never takes the
    /// run (or its siblings) down, it just lands here.
    pub error: Option<String>,
    pub report: TrainReport,
}

/// The outcome of a `codedml serve` run: N concurrent sessions
/// multiplexed over one shared worker pool.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    /// Transport backend the pool ran on ("memory" / "tcp").
    pub transport: String,
    /// Shared pool size (max worker count over the sessions).
    pub pool_workers: usize,
    /// Pool-level wire bytes actually moved (frame-layout units; the
    /// per-session modeled bytes live in each session's report).
    pub wire_sent: u64,
    pub wire_received: u64,
    /// Results rejected because their session id matched no registered
    /// session — any nonzero value is a routing bug.
    pub misrouted: u64,
    /// Shared workers the scheduler revived across the run.
    pub respawns: u64,
    pub sessions: Vec<SessionSummary>,
}

impl ServeReport {
    /// Machine-readable JSON (written by `codedml serve --report-json`).
    pub fn to_json(&self) -> Json {
        obj(&[
            ("transport", Json::Str(self.transport.clone())),
            ("pool_workers", Json::Num(self.pool_workers as f64)),
            ("wire_sent", Json::Num(self.wire_sent as f64)),
            ("wire_received", Json::Num(self.wire_received as f64)),
            ("misrouted", Json::Num(self.misrouted as f64)),
            ("respawns", Json::Num(self.respawns as f64)),
            (
                "sessions",
                Json::Arr(
                    self.sessions
                        .iter()
                        .map(|s| {
                            obj(&[
                                ("name", Json::Str(s.name.clone())),
                                ("session_id", Json::Num(s.session_id as f64)),
                                ("priority", Json::Num(s.priority as f64)),
                                ("objective", Json::Str(s.objective.clone())),
                                (
                                    "error",
                                    s.error
                                        .clone()
                                        .map(Json::Str)
                                        .unwrap_or(Json::Null),
                                ),
                                ("report", s.report.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_report_json_nests_sessions() {
        let rep = ServeReport {
            transport: "memory".to_string(),
            pool_workers: 10,
            wire_sent: 100,
            wire_received: 50,
            misrouted: 0,
            respawns: 1,
            sessions: vec![SessionSummary {
                name: "job-a".to_string(),
                session_id: 1,
                priority: 2,
                objective: "logistic".to_string(),
                error: None,
                report: TrainReport {
                    iterations: vec![IterationMetrics {
                        iter: 0,
                        train_loss: 0.5,
                        test_accuracy: None,
                    }],
                    ..Default::default()
                },
            }],
        };
        let parsed = Json::parse(&rep.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("pool_workers").unwrap().as_u64(), Some(10));
        assert_eq!(parsed.get("misrouted").unwrap().as_u64(), Some(0));
        let sessions = parsed.get("sessions").unwrap().as_arr().unwrap();
        assert_eq!(sessions.len(), 1);
        assert_eq!(sessions[0].get("priority").unwrap().as_u64(), Some(2));
        assert_eq!(sessions[0].get("error"), Some(&Json::Null));
        let inner = sessions[0].get("report").unwrap();
        let curve = inner.get("loss_curve").unwrap().as_arr().unwrap();
        assert_eq!(curve.len(), 1);
    }

    #[test]
    fn total_is_sum() {
        let b = TimingBreakdown { encode_s: 1.0, comm_s: 2.0, comp_s: 3.5 };
        assert_eq!(b.total(), 6.5);
        let row = b.row("CodedPrivateML (Case 1)");
        assert!(row.contains("6.50"), "{row}");
    }

    #[test]
    fn report_json_round_trips() {
        let rep = TrainReport {
            breakdown: TimingBreakdown { encode_s: 1.0, comm_s: 0.5, comp_s: 2.0 },
            iterations: vec![
                IterationMetrics { iter: 0, train_loss: 0.6, test_accuracy: Some(0.8) },
                IterationMetrics { iter: 1, train_loss: 0.4, test_accuracy: None },
            ],
            recovery_threshold: 10,
            ..Default::default()
        };
        let j = rep.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("total_s").unwrap().as_f64(), Some(3.5));
        assert_eq!(parsed.get("worker_failures").unwrap().as_u64(), Some(0));
        assert_eq!(parsed.get("late_results").unwrap().as_u64(), Some(0));
        assert_eq!(parsed.get("approx_rounds").unwrap().as_u64(), Some(0));
        assert_eq!(parsed.get("max_approx_residual").unwrap().as_f64(), Some(0.0));
        assert_eq!(parsed.get("respawns").unwrap().as_u64(), Some(0));
        assert_eq!(parsed.get("deadline_expired_rounds").unwrap().as_u64(), Some(0));
        let curve = parsed.get("loss_curve").unwrap().as_arr().unwrap();
        assert_eq!(curve.len(), 2);
        assert_eq!(parsed.get("accuracy_curve").unwrap().as_arr().unwrap()[1], Json::Null);
    }

    #[test]
    fn final_metrics() {
        let mut rep = TrainReport::default();
        assert_eq!(rep.final_loss(), None);
        rep.iterations.push(IterationMetrics { iter: 0, train_loss: 0.3, test_accuracy: Some(0.9) });
        assert_eq!(rep.final_loss(), Some(0.3));
        assert_eq!(rep.final_accuracy(), Some(0.9));
    }
}
