//! The TCP transport: one process per worker over `std::net` sockets.
//!
//! Master side — [`TcpTransport::connect`] dials `cfg.workers[i]` for
//! worker `i` (timeout + retry + backoff), performs the Hello → Ready
//! handshake, then moves each connection's read half into a reader thread
//! that funnels decoded [`WorkerFrame::Result`]s into one shared event
//! channel — preserving the "results in actual arrival order" contract
//! the round engine is built on. A worker that cannot be dialed is marked
//! *down* rather than aborting the cluster: the round engine counts it as
//! failed every iteration, which is exactly how `TrainReport::worker_failures`
//! learns about it. A backend build failure reported in Ready aborts
//! connect, mirroring the in-memory spawn semantics.
//!
//! Worker side — [`serve`] runs the read-dispatch-reply loop on an
//! accepted connection; the CLI's `--worker --listen <addr>` mode binds,
//! accepts once, and calls it. All prints stay in the CLI layer.
//!
//! Failure policy: any IO error, decode error, or protocol violation on a
//! connection downgrades that one worker to [`TransportEvent::Down`] —
//! never a panic, never an error for the whole transport (the
//! `no-panic-in-library` lint checks the first half of that sentence).

use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Duration;

use super::frame::{
    frame_len, read_frame, write_frame, HelloSpec, MasterFrame, WorkerFrame,
};
use super::{TcpConfig, Transport, TransportEvent};
use crate::cluster::worker::{ClusterError, StepResult, WorkerEngine, WorkerOp, WorkerSpec};
use crate::field::PrimeField;
use crate::runtime::BackendKind;
use crate::util::par::Parallelism;
use crate::util::rng::Rng;
use crate::util::timer::Deadline;

// --- WorkerSpec ↔ HelloSpec (the only code that needs the wire codes) ---

fn backend_code(kind: BackendKind) -> u8 {
    match kind {
        BackendKind::Native => 0,
        BackendKind::Xla => 1,
    }
}

fn op_code(op: WorkerOp) -> u8 {
    match op {
        WorkerOp::Logistic => 0,
        WorkerOp::Linear => 1,
    }
}

fn par_code(par: Parallelism) -> u32 {
    match par {
        Parallelism::Auto => 0,
        Parallelism::Serial => 1,
        Parallelism::Threads(n) => n.get() as u32,
    }
}

fn hello_from_spec(spec: &WorkerSpec) -> HelloSpec {
    HelloSpec {
        id: spec.id as u32,
        session: spec.session,
        backend: backend_code(spec.kind),
        op: op_code(spec.op),
        par: par_code(spec.par),
        p: spec.field.modulus(),
        rows: spec.rows as u32,
        d: spec.d as u32,
        fail_from_iter: spec.fail_from_iter,
        slow_ms: spec.slow_ms,
        coeffs: spec.coeffs.clone(),
        artifact_dir: spec.artifact_dir.to_string_lossy().into_owned(),
    }
}

fn spec_from_hello(h: HelloSpec) -> Result<WorkerSpec, String> {
    let kind = match h.backend {
        0 => BackendKind::Native,
        1 => BackendKind::Xla,
        other => return Err(format!("bad backend code {other}")),
    };
    let op = match h.op {
        0 => WorkerOp::Logistic,
        1 => WorkerOp::Linear,
        other => return Err(format!("bad op code {other}")),
    };
    Ok(WorkerSpec {
        id: h.id as usize,
        session: h.session,
        kind,
        artifact_dir: PathBuf::from(h.artifact_dir),
        field: PrimeField::new(h.p),
        rows: h.rows as usize,
        d: h.d as usize,
        coeffs: h.coeffs,
        op,
        fail_from_iter: h.fail_from_iter,
        slow_ms: h.slow_ms,
        par: Parallelism::from_count(h.par as usize),
    })
}

// --------------------------- master side ---------------------------------

/// TCP transport backend (master side).
pub struct TcpTransport {
    /// Write half per worker; `None` once the worker is down.
    streams: Vec<Option<TcpStream>>,
    /// Events arrive tagged with the connection generation that produced
    /// them; [`Transport::recv_deadline`] drops `Down` events from
    /// generations a [`Transport::reconnect`] has since replaced.
    events_rx: mpsc::Receiver<(u64, TransportEvent)>,
    /// Kept so reconnects can hand fresh reader threads a sender.
    events_tx: mpsc::Sender<(u64, TransportEvent)>,
    /// Current connection generation per worker (starts at 0, bumps on
    /// every reconnect).
    conn_gen: Vec<u64>,
    /// Dial/handshake knobs, kept for redials.
    cfg: TcpConfig,
    readers: Vec<JoinHandle<()>>,
    sent: u64,
    received: Arc<AtomicU64>,
}

fn resolve(addr: &str) -> Result<SocketAddr, String> {
    addr.to_socket_addrs()
        .map_err(|e| format!("resolve {addr}: {e}"))?
        .next()
        .ok_or_else(|| format!("resolve {addr}: no addresses"))
}

/// FNV-1a over the address string: a deterministic per-address seed so
/// each worker's jitter stream is decorrelated from its neighbors'
/// without any wall-clock entropy (`no-wallclock-nondeterminism`).
fn addr_seed(addr: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in addr.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Dial with retry and capped exponential backoff plus ±50% jitter. Each
/// attempt gets its own connect timeout. The jitter decorrelates N
/// workers redialing a restarted peer (no thundering herd) while staying
/// deterministic per address — the sleep sequence is a pure function of
/// `(addr, cfg)`.
fn dial(addr: &str, cfg: &TcpConfig) -> Result<TcpStream, String> {
    let target = resolve(addr)?;
    let timeout = Duration::from_millis(cfg.connect_timeout_ms.max(1));
    let mut rng = Rng::new(addr_seed(addr));
    let mut last = String::new();
    for attempt in 0..=cfg.connect_retries {
        if attempt > 0 {
            // Base doubles per attempt, capped at 8× the configured
            // backoff; actual sleep is uniform in [base/2, 3·base/2).
            let base = cfg
                .connect_backoff_ms
                .saturating_mul(1u64 << (attempt - 1).min(3));
            let sleep = base / 2 + rng.below(base.max(1));
            std::thread::sleep(Duration::from_millis(sleep));
        }
        match TcpStream::connect_timeout(&target, timeout) {
            Ok(s) => return Ok(s),
            Err(e) => last = format!("connect {addr}: {e}"),
        }
    }
    Err(format!("{last} (after {} attempts)", cfg.connect_retries + 1))
}

fn reader_loop(
    worker: usize,
    gen: u64,
    stream: TcpStream,
    tx: mpsc::Sender<(u64, TransportEvent)>,
    received: Arc<AtomicU64>,
) {
    let mut r = BufReader::new(stream);
    loop {
        match read_frame(&mut r) {
            Ok(None) => {
                let _ = tx.send((
                    gen,
                    TransportEvent::Down { worker, error: "connection closed".to_string() },
                ));
                return;
            }
            Ok(Some((op, payload))) => {
                received.fetch_add(frame_len(payload.len()) as u64, Ordering::Relaxed);
                match WorkerFrame::decode(op, &payload) {
                    Ok(WorkerFrame::Result(res)) => {
                        if res.worker != worker {
                            let _ = tx.send((
                                gen,
                                TransportEvent::Down {
                                    worker,
                                    error: format!(
                                        "protocol: result for worker {} on connection {worker}",
                                        res.worker
                                    ),
                                },
                            ));
                            return;
                        }
                        if tx.send((gen, TransportEvent::Result(res))).is_err() {
                            return; // master gone
                        }
                    }
                    Ok(WorkerFrame::Ready { .. }) => {
                        let _ = tx.send((
                            gen,
                            TransportEvent::Down {
                                worker,
                                error: "protocol: Ready after handshake".to_string(),
                            },
                        ));
                        return;
                    }
                    Err(e) => {
                        let _ = tx.send((
                            gen,
                            TransportEvent::Down { worker, error: format!("bad frame: {e}") },
                        ));
                        return;
                    }
                }
            }
            Err(e) => {
                let _ = tx.send((
                    gen,
                    TransportEvent::Down { worker, error: format!("read: {e}") },
                ));
                return;
            }
        }
    }
}

impl TcpTransport {
    /// Connect to `cfg.workers[i]` for each spec and handshake. Returns the
    /// transport plus per-worker down reasons: a worker that cannot be
    /// dialed (refused, timeout, handshake IO error) is `Some(reason)` and
    /// participates in no round — the cluster counts it failed each
    /// iteration. Only a *Ready-reported backend build error* aborts, to
    /// match [`super::ChannelTransport::spawn`] fail-fast behavior.
    pub fn connect(
        specs: &[WorkerSpec],
        cfg: &TcpConfig,
    ) -> Result<(Self, Vec<Option<String>>), ClusterError> {
        assert_eq!(
            specs.len(),
            cfg.workers.len(),
            "one worker address per spec (got {} specs, {} addresses)",
            specs.len(),
            cfg.workers.len()
        );
        let (events_tx, events_rx) = mpsc::channel();
        let received = Arc::new(AtomicU64::new(0));
        let mut streams: Vec<Option<TcpStream>> = Vec::with_capacity(specs.len());
        let mut down: Vec<Option<String>> = vec![None; specs.len()];
        let mut readers = Vec::new();
        let mut sent = 0u64;
        let timeout = Duration::from_millis(cfg.connect_timeout_ms.max(1));

        for (i, spec) in specs.iter().enumerate() {
            match Self::handshake(i, spec, cfg, timeout, &received, &mut sent) {
                Ok(stream) => {
                    match stream.try_clone() {
                        Ok(read_half) => {
                            let tx = events_tx.clone();
                            let rcv = Arc::clone(&received);
                            match std::thread::Builder::new()
                                .name(format!("tcp-reader-{i}"))
                                .spawn(move || reader_loop(i, 0, read_half, tx, rcv))
                            {
                                Ok(j) => {
                                    readers.push(j);
                                    streams.push(Some(stream));
                                }
                                Err(e) => {
                                    down[i] = Some(format!("spawn reader: {e}"));
                                    streams.push(None);
                                }
                            }
                        }
                        Err(e) => {
                            down[i] = Some(format!("clone stream: {e}"));
                            streams.push(None);
                        }
                    }
                }
                Err(HandshakeError::Backend(e)) => {
                    // Fail fast like the in-memory spawn: a present, healthy
                    // worker whose backend cannot build is a config error,
                    // not a transient network fault.
                    return Err(ClusterError::Backend(format!("worker {i}: {e}")));
                }
                Err(HandshakeError::Unreachable(e)) => {
                    down[i] = Some(e);
                    streams.push(None);
                }
            }
        }
        let conn_gen = vec![0u64; specs.len()];
        Ok((
            TcpTransport {
                streams,
                events_rx,
                events_tx,
                conn_gen,
                cfg: cfg.clone(),
                readers,
                sent,
                received,
            },
            down,
        ))
    }

    fn handshake(
        i: usize,
        spec: &WorkerSpec,
        cfg: &TcpConfig,
        timeout: Duration,
        received: &Arc<AtomicU64>,
        sent: &mut u64,
    ) -> Result<TcpStream, HandshakeError> {
        let mut stream = dial(&cfg.workers[i], cfg).map_err(HandshakeError::Unreachable)?;
        let _ = stream.set_nodelay(true);
        let (op, payload) = MasterFrame::Hello(hello_from_spec(spec)).encode();
        let n = write_frame(&mut stream, op, &payload)
            .map_err(|e| HandshakeError::Unreachable(format!("send hello: {e}")))?;
        *sent += n as u64;
        stream
            .set_read_timeout(Some(timeout))
            .map_err(|e| HandshakeError::Unreachable(format!("set timeout: {e}")))?;
        let reply = read_frame(&mut (&stream))
            .map_err(|e| HandshakeError::Unreachable(format!("read ready: {e}")))?;
        let (rop, rpayload) = match reply {
            Some(f) => f,
            None => return Err(HandshakeError::Unreachable("closed during handshake".into())),
        };
        received.fetch_add(frame_len(rpayload.len()) as u64, Ordering::Relaxed);
        match WorkerFrame::decode(rop, &rpayload) {
            Ok(WorkerFrame::Ready { error: None }) => {}
            Ok(WorkerFrame::Ready { error: Some(e) }) => {
                return Err(HandshakeError::Backend(e));
            }
            Ok(WorkerFrame::Result(_)) => {
                return Err(HandshakeError::Unreachable(
                    "protocol: Result before Ready".into(),
                ));
            }
            Err(e) => {
                return Err(HandshakeError::Unreachable(format!("bad ready frame: {e}")));
            }
        }
        stream
            .set_read_timeout(None)
            .map_err(|e| HandshakeError::Unreachable(format!("clear timeout: {e}")))?;
        Ok(stream)
    }

    fn send_frame(&mut self, worker: usize, f: &MasterFrame) -> Result<(), String> {
        let stream = match self.streams[worker].as_mut() {
            Some(s) => s,
            None => return Err("worker down".to_string()),
        };
        let (op, payload) = f.encode();
        match write_frame(stream, op, &payload) {
            Ok(n) => {
                self.sent += n as u64;
                Ok(())
            }
            Err(e) => {
                // The write half is broken; shut the socket down fully so the
                // reader thread (which holds a dup of the fd) sees EOF and
                // surfaces Down instead of blocking forever.
                if let Some(s) = self.streams[worker].take() {
                    let _ = s.shutdown(Shutdown::Both);
                }
                Err(format!("send: {e}"))
            }
        }
    }

    fn stop(&mut self) {
        for s in self.streams.iter_mut() {
            if let Some(stream) = s.take() {
                let (op, payload) = MasterFrame::Shutdown.encode();
                let _ = write_frame(&mut (&stream), op, &payload);
                // Both halves, so our reader thread sees EOF immediately and
                // the join below can never hang.
                let _ = stream.shutdown(Shutdown::Both);
            }
        }
        for j in self.readers.drain(..) {
            let _ = j.join();
        }
    }
}

enum HandshakeError {
    /// Worker absent/unresponsive — mark down, keep the cluster.
    Unreachable(String),
    /// Worker present but its backend failed to build — abort connect.
    Backend(String),
}

impl Transport for TcpTransport {
    fn n(&self) -> usize {
        self.streams.len()
    }

    fn name(&self) -> &'static str {
        "tcp"
    }

    fn send_load(
        &mut self,
        worker: usize,
        session: u64,
        x: Vec<u64>,
        y: Option<Vec<u64>>,
    ) -> Result<(), String> {
        self.send_frame(worker, &MasterFrame::LoadData { session, x, y })
    }

    fn send_step(
        &mut self,
        worker: usize,
        session: u64,
        iter: u64,
        w: Vec<u64>,
    ) -> Result<(), String> {
        self.send_frame(worker, &MasterFrame::Step { session, iter, w })
    }

    fn send_attach(&mut self, worker: usize, spec: &WorkerSpec) -> Result<(), String> {
        // A non-handshake Hello: the worker builds the engine silently (a
        // second Ready would read as a protocol violation on our reader);
        // attach failures surface as Err results on that session's steps.
        self.send_frame(worker, &MasterFrame::Hello(hello_from_spec(spec)))
    }

    fn recv_deadline(
        &mut self,
        deadline: &Deadline,
    ) -> Result<Option<TransportEvent>, ClusterError> {
        loop {
            let (gen, ev) = match deadline.remaining() {
                None => self
                    .events_rx
                    .recv()
                    .map_err(|_| ClusterError::Channel("tcp events"))?,
                Some(left) => match self.events_rx.recv_timeout(left) {
                    Ok(pair) => pair,
                    Err(mpsc::RecvTimeoutError::Timeout) => return Ok(None),
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        return Err(ClusterError::Channel("tcp events"))
                    }
                },
            };
            // A Down from a connection that reconnect() has since replaced
            // describes the *old* socket — swallowing it keeps a revived
            // worker from being immediately re-marked dead. Results are
            // never filtered: a value computed on the old connection is
            // still a genuine (deterministic) worker result, and the round
            // engine's iteration tags handle staleness.
            if let TransportEvent::Down { worker, .. } = &ev {
                if gen < self.conn_gen[*worker] {
                    continue;
                }
            }
            return Ok(Some(ev));
        }
    }

    fn reconnect(&mut self, spec: &WorkerSpec) -> Result<(), String> {
        let i = spec.id;
        if i >= self.streams.len() {
            return Err(format!("no worker slot {i}"));
        }
        // Retire any half-dead connection first so its reader unblocks and
        // its Down lands in a now-stale generation.
        if let Some(s) = self.streams[i].take() {
            let _ = s.shutdown(Shutdown::Both);
        }
        self.conn_gen[i] += 1;
        let gen = self.conn_gen[i];
        let timeout = Duration::from_millis(self.cfg.connect_timeout_ms.max(1));
        let cfg = self.cfg.clone();
        let stream = match Self::handshake(i, spec, &cfg, timeout, &self.received, &mut self.sent)
        {
            Ok(s) => s,
            Err(HandshakeError::Backend(e)) => return Err(format!("backend: {e}")),
            Err(HandshakeError::Unreachable(e)) => return Err(e),
        };
        let read_half = stream.try_clone().map_err(|e| format!("clone stream: {e}"))?;
        let tx = self.events_tx.clone();
        let rcv = Arc::clone(&self.received);
        let j = std::thread::Builder::new()
            .name(format!("tcp-reader-{i}-g{gen}"))
            .spawn(move || reader_loop(i, gen, read_half, tx, rcv))
            .map_err(|e| format!("spawn reader: {e}"))?;
        self.readers.push(j);
        self.streams[i] = Some(stream);
        Ok(())
    }

    fn shutdown(&mut self) {
        self.stop();
    }

    fn bytes(&self) -> (u64, u64) {
        (self.sent, self.received.load(Ordering::Relaxed))
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.stop();
    }
}

// --------------------------- worker side ---------------------------------

fn reply(w: &mut BufWriter<TcpStream>, f: &WorkerFrame) -> Result<(), String> {
    let (op, payload) = f.encode();
    write_frame(w, op, &payload).map_err(|e| format!("send {e}"))?;
    w.flush().map_err(|e| format!("flush: {e}"))
}

/// Run the worker side of the protocol on an accepted connection until the
/// master shuts down or disconnects. Used by the CLI's
/// `--worker --listen <addr>` mode; prints nothing (the CLI owns all I/O).
///
/// Returns `Ok(true)` only on an explicit Shutdown frame — the master
/// really is done and the worker process should exit. `Ok(false)` means
/// the connection ended some other way (master disconnect, backend build
/// failure reported via Ready); the CLI keeps listening so a supervising
/// master can redial and the worker rejoins the pool. `Err` is reserved
/// for transport/protocol breakage on this one connection.
pub fn serve(stream: TcpStream) -> Result<bool, String> {
    let _ = stream.set_nodelay(true);
    let read_half = stream.try_clone().map_err(|e| format!("clone stream: {e}"))?;
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);
    // One engine per attached session. The first Hello is the handshake
    // (answered with Ready); later Hellos attach more sessions *silently*
    // — the master's reader treats any Ready after the handshake as a
    // protocol violation, so attach failures poison only that session's
    // steps (Err results) instead of being acknowledged.
    let mut engines: std::collections::HashMap<u64, WorkerEngine> =
        std::collections::HashMap::new();
    let mut attach_errors: std::collections::HashMap<u64, String> =
        std::collections::HashMap::new();
    let mut worker_id: Option<usize> = None;
    loop {
        let (op, payload) = match read_frame(&mut reader) {
            Ok(Some(f)) => f,
            Ok(None) => return Ok(false), // master disconnected
            Err(e) => return Err(format!("read: {e}")),
        };
        let frame = MasterFrame::decode(op, &payload).map_err(|e| format!("decode: {e}"))?;
        match frame {
            MasterFrame::Hello(h) => {
                let session = h.session;
                let handshake = worker_id.is_none();
                let built = spec_from_hello(h).and_then(|s| {
                    let id = s.id;
                    WorkerEngine::new(s).map(|e| (id, e))
                });
                match built {
                    Ok((id, e)) => {
                        if handshake {
                            worker_id = Some(id);
                            reply(&mut writer, &WorkerFrame::Ready { error: None })?;
                        }
                        engines.insert(session, e);
                        attach_errors.remove(&session);
                    }
                    Err(e) => {
                        if handshake {
                            reply(&mut writer, &WorkerFrame::Ready { error: Some(e) })?;
                            return Ok(false);
                        }
                        attach_errors.insert(session, e);
                    }
                }
            }
            MasterFrame::LoadData { session, x, y } => {
                if worker_id.is_none() {
                    return Err("protocol: LoadData before Hello".to_string());
                }
                if let Some(en) = engines.get_mut(&session) {
                    en.load(x, y);
                }
                // No engine: the attach failed — the error surfaces on
                // this session's next Step.
            }
            MasterFrame::Step { session, iter, w } => {
                let id = match worker_id {
                    Some(id) => id,
                    None => return Err("protocol: Step before Hello".to_string()),
                };
                let res = match engines.get(&session) {
                    Some(en) => en.step(iter, &w),
                    None => StepResult {
                        worker: id,
                        session,
                        iter,
                        data: Err(match attach_errors.get(&session) {
                            Some(e) => format!("attach failed: {e}"),
                            None => format!("no engine for session {session}"),
                        }),
                        compute_secs: 0.0,
                    },
                };
                reply(&mut writer, &WorkerFrame::Result(res))?;
            }
            MasterFrame::Shutdown => return Ok(true),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::PAPER_PRIME;

    fn spec() -> WorkerSpec {
        WorkerSpec {
            id: 3,
            session: 7,
            kind: BackendKind::Native,
            artifact_dir: PathBuf::from("artifacts"),
            field: PrimeField::new(PAPER_PRIME),
            rows: 2,
            d: 3,
            coeffs: vec![3, 7],
            op: WorkerOp::Logistic,
            fail_from_iter: Some(5),
            slow_ms: 2,
            par: Parallelism::from_count(2),
        }
    }

    #[test]
    fn spec_round_trips_through_hello() {
        let s = spec();
        let got = spec_from_hello(hello_from_spec(&s)).unwrap();
        assert_eq!(got.id, s.id);
        assert_eq!(got.session, s.session);
        assert_eq!(got.kind, s.kind);
        assert_eq!(got.artifact_dir, s.artifact_dir);
        assert_eq!(got.field.modulus(), s.field.modulus());
        assert_eq!(got.rows, s.rows);
        assert_eq!(got.d, s.d);
        assert_eq!(got.coeffs, s.coeffs);
        assert_eq!(got.op, s.op);
        assert_eq!(got.fail_from_iter, s.fail_from_iter);
        assert_eq!(got.slow_ms, s.slow_ms);
        assert_eq!(got.par, s.par);
    }

    #[test]
    fn par_codes_cover_all_variants() {
        for par in [
            Parallelism::Auto,
            Parallelism::Serial,
            Parallelism::from_count(7),
        ] {
            assert_eq!(Parallelism::from_count(par_code(par) as usize), par);
        }
    }

    #[test]
    fn bad_hello_codes_are_typed_errors() {
        let mut h = hello_from_spec(&spec());
        h.backend = 9;
        assert!(spec_from_hello(h).unwrap_err().contains("bad backend code"));
        let mut h = hello_from_spec(&spec());
        h.op = 9;
        assert!(spec_from_hello(h).unwrap_err().contains("bad op code"));
    }

    #[test]
    fn serve_speaks_the_full_protocol_in_process() {
        use crate::compute::WorkerComputation;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            serve(stream).unwrap();
        });

        let mut s = spec();
        s.id = 0;
        s.session = 0;
        s.fail_from_iter = None;
        s.slow_ms = 0;
        let f = s.field;
        let (rows, d) = (s.rows, s.d);
        let cfg = TcpConfig { workers: vec![addr], ..TcpConfig::default() };
        let (mut t, down) = TcpTransport::connect(&[s], &cfg).unwrap();
        assert_eq!(down, vec![None]);
        assert_eq!(t.n(), 1);
        assert_eq!(t.name(), "tcp");

        let x: Vec<u64> = (1..=(rows * d) as u64).collect();
        let w = vec![2u64, 4, 6];
        t.send_load(0, 0, x.clone(), None).unwrap();
        t.send_step(0, 0, 9, w.clone()).unwrap();
        match t.recv().unwrap() {
            TransportEvent::Result(res) => {
                assert_eq!(res.worker, 0);
                assert_eq!(res.session, 0);
                assert_eq!(res.iter, 9);
                let wc = WorkerComputation::new(f, rows, d, vec![3, 7]);
                assert_eq!(res.data.unwrap(), wc.compute(&x, &w));
            }
            other => panic!("expected Result, got {other:?}"),
        }
        let (sent, received) = t.bytes();
        assert!(sent > 0 && received > 0, "handshake + step must be charged");
        t.shutdown();
        server.join().unwrap();
    }

    #[test]
    fn serve_attaches_second_session_silently() {
        use crate::compute::WorkerComputation;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            serve(stream).unwrap();
        });

        let mut s = spec();
        s.id = 0;
        s.session = 0;
        s.fail_from_iter = None;
        s.slow_ms = 0;
        let f = s.field;
        let (rows, d) = (s.rows, s.d);
        let cfg = TcpConfig { workers: vec![addr], ..TcpConfig::default() };
        let (mut t, down) = TcpTransport::connect(&[s.clone()], &cfg).unwrap();
        assert_eq!(down, vec![None]);

        // Attach a second session on the same connection: no Ready comes
        // back (the reader would treat one as a protocol violation), and
        // both sessions answer steps tagged with their own ids and data.
        let mut s2 = s.clone();
        s2.session = 5;
        t.send_attach(0, &s2).unwrap();

        let x0: Vec<u64> = (1..=(rows * d) as u64).collect();
        let x5: Vec<u64> = (2..=(rows * d) as u64 + 1).collect();
        let w = vec![2u64, 4, 6];
        t.send_load(0, 0, x0.clone(), None).unwrap();
        t.send_load(0, 5, x5.clone(), None).unwrap();
        t.send_step(0, 5, 1, w.clone()).unwrap();
        t.send_step(0, 0, 1, w.clone()).unwrap();
        let wc = WorkerComputation::new(f, rows, d, vec![3, 7]);
        let mut got = Vec::new();
        for _ in 0..2 {
            match t.recv().unwrap() {
                TransportEvent::Result(res) => got.push(res),
                other => panic!("expected Result, got {other:?}"),
            }
        }
        got.sort_by_key(|r| r.session);
        assert_eq!(got[0].session, 0);
        assert_eq!(got[0].data.as_ref().unwrap(), &wc.compute(&x0, &w));
        assert_eq!(got[1].session, 5);
        assert_eq!(got[1].data.as_ref().unwrap(), &wc.compute(&x5, &w));
        t.shutdown();
        server.join().unwrap();
    }

    #[test]
    fn reconnect_redials_and_suppresses_stale_down() {
        use crate::compute::WorkerComputation;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        // Worker side: keep accepting until an explicit Shutdown, exactly
        // like the CLI's `--worker` loop — this is what lets a supervising
        // master redial after a connection dies.
        let server = std::thread::spawn(move || loop {
            let (stream, _) = listener.accept().unwrap();
            if serve(stream).unwrap_or(false) {
                return;
            }
        });

        let mut s = spec();
        s.id = 0;
        s.session = 0;
        s.fail_from_iter = None;
        s.slow_ms = 0;
        let f = s.field;
        let (rows, d) = (s.rows, s.d);
        let wc = WorkerComputation::new(f, rows, d, s.coeffs.clone());
        let cfg = TcpConfig { workers: vec![addr], ..TcpConfig::default() };
        let (mut t, down) = TcpTransport::connect(&[s.clone()], &cfg).unwrap();
        assert_eq!(down, vec![None]);

        let x: Vec<u64> = (1..=(rows * d) as u64).collect();
        let w = vec![2u64, 4, 6];
        t.send_load(0, 0, x.clone(), None).unwrap();

        // Reconnect replaces the live connection (the worker loops back to
        // accept), bumps the generation, and the old reader's Down must
        // not surface afterwards.
        t.reconnect(&s).unwrap();
        t.send_load(0, 0, x.clone(), None).unwrap();
        t.send_step(0, 0, 1, w.clone()).unwrap();
        match t
            .recv_deadline(&Deadline::after_ms(5000))
            .unwrap()
            .expect("result before deadline")
        {
            TransportEvent::Result(res) => {
                assert_eq!((res.worker, res.iter), (0, 1));
                assert_eq!(res.data.unwrap(), wc.compute(&x, &w));
            }
            TransportEvent::Down { error, .. } => {
                panic!("stale Down leaked through reconnect: {error}")
            }
        }
        t.shutdown();
        server.join().unwrap();
    }

    #[test]
    fn addr_seed_is_deterministic_and_decorrelated() {
        assert_eq!(addr_seed("127.0.0.1:4001"), addr_seed("127.0.0.1:4001"));
        assert_ne!(addr_seed("127.0.0.1:4001"), addr_seed("127.0.0.1:4002"));
    }

    #[test]
    fn dial_unreachable_reports_attempts() {
        // Bind a listener, note the port, drop it: connecting now is
        // refused immediately (loopback), exercising the retry loop.
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let cfg = TcpConfig {
            workers: vec![addr.clone()],
            connect_timeout_ms: 200,
            connect_retries: 2,
            connect_backoff_ms: 1,
        };
        let err = dial(&addr, &cfg).unwrap_err();
        assert!(err.contains("after 3 attempts"), "{err}");
    }
}
