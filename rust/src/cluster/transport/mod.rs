//! Pluggable master ↔ worker transports.
//!
//! [`super::Cluster`] talks to its N workers exclusively through the
//! [`Transport`] trait: deliver a coded data share once, deliver coded
//! weights every iteration, and stream back [`StepResult`]s in actual
//! arrival order. Two backends implement it:
//!
//! * [`ChannelTransport`] (default) — one OS thread per worker sharing an
//!   in-process mpsc channel. This is the original simulated cluster;
//!   every existing test runs on it unchanged.
//! * [`TcpTransport`] — one OS *process* per worker (`codedml --worker
//!   --listen <addr>`), length-prefixed frames over `std::net` sockets
//!   (layout in [`frame`]), connect with configurable
//!   timeout/retry/backoff, and disconnects surfaced as
//!   [`TransportEvent::Down`] rather than panics.
//!
//! Both backends charge the *same* per-message byte costs (the frame
//! layout is the accounting unit even in memory — see
//! [`frame::frame_len`]), so `BENCH_transport` speedup rows compare like
//! with like and decoded gradients are bit-identical across backends:
//! LCC decoding is exact on any fastest-R subset, and the transports only
//! reorder arrivals, never values.

pub mod channel;
pub mod frame;
pub mod tcp;

pub use channel::ChannelTransport;
pub use tcp::TcpTransport;

use crate::cluster::worker::{ClusterError, StepResult, WorkerSpec};
use crate::util::timer::Deadline;

/// One message from the worker side of a transport.
#[derive(Debug)]
pub enum TransportEvent {
    /// A worker finished (or failed) a step.
    Result(StepResult),
    /// The transport lost a worker for good: connection closed, protocol
    /// violation, or undecodable frame. The worker sends nothing further;
    /// [`super::Cluster::collect_first`] converts this into a per-round
    /// failure so it lands in `TrainReport::worker_failures`.
    Down { worker: usize, error: String },
}

/// The seam between the round engine and the wire.
///
/// Sends are per-worker and a send error means *that worker* is gone
/// (the cluster marks it down and keeps going); [`Transport::recv`]
/// errors only when the whole transport is broken. Implementations must
/// never panic on peer misbehavior — malformed input becomes
/// [`TransportEvent::Down`].
pub trait Transport: Send {
    /// Number of workers this transport was built with (live or not).
    fn n(&self) -> usize;

    /// Backend name for traces and benches ("memory" / "tcp").
    fn name(&self) -> &'static str;

    /// Deliver worker `worker`'s coded data share for `session` (labels
    /// only for the Linear op). `Err` = that worker is unreachable.
    fn send_load(
        &mut self,
        worker: usize,
        session: u64,
        x: Vec<u64>,
        y: Option<Vec<u64>>,
    ) -> Result<(), String>;

    /// Deliver coded weights for iteration `iter` of `session` to worker
    /// `worker`.
    fn send_step(
        &mut self,
        worker: usize,
        session: u64,
        iter: u64,
        w: Vec<u64>,
    ) -> Result<(), String>;

    /// Build an engine for `spec`'s session on an already-connected
    /// worker, leaving every other session's engine on that worker
    /// intact. This is how the serve scheduler multiplexes jobs over one
    /// pool; `spec.id` names the worker. `Err` = that worker is
    /// unreachable.
    fn send_attach(&mut self, worker: usize, spec: &WorkerSpec) -> Result<(), String>;

    /// Block for the next worker event, whichever worker it comes from.
    fn recv(&mut self) -> Result<TransportEvent, ClusterError> {
        match self.recv_deadline(&Deadline::none())? {
            Some(ev) => Ok(ev),
            // Unreachable by the recv_deadline contract (an unbounded
            // deadline never times out) — surfaced as a transport error
            // rather than a panic (`no-panic-in-library`).
            None => Err(ClusterError::Channel("unbounded recv returned empty")),
        }
    }

    /// Block for the next worker event or until `deadline` expires.
    /// `Ok(None)` = the deadline fired with nothing to deliver; a
    /// [`Deadline::none`] never yields `Ok(None)`. This is what turns a
    /// silently-stalled worker (hung socket, no FIN) into a counted
    /// failure instead of a master hang.
    fn recv_deadline(
        &mut self,
        deadline: &Deadline,
    ) -> Result<Option<TransportEvent>, ClusterError>;

    /// Re-admit a lost worker: the TCP backend redials `spec.id`'s address
    /// (fresh Hello handshake, new reader thread, stale events from the
    /// dead connection suppressed); the in-memory backend spawns a
    /// replacement thread. `Err` = still unreachable — the caller keeps
    /// the worker marked down and may retry on a later round.
    fn reconnect(&mut self, spec: &WorkerSpec) -> Result<(), String>;

    /// Tear down: best-effort notify workers, release connections, join
    /// any internal threads. Must be idempotent (called from both
    /// [`super::Cluster`]'s `Drop` and backend `Drop`s).
    fn shutdown(&mut self);

    /// Cumulative `(sent, received)` wire bytes, counted in frame-layout
    /// units on both backends.
    fn bytes(&self) -> (u64, u64);
}

/// Which backend a [`super::Cluster`] should run on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// In-process threads + channels (the simulated cluster).
    #[default]
    Memory,
    /// One process per worker over loopback/LAN sockets.
    Tcp,
}

impl std::str::FromStr for TransportKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "memory" => Ok(TransportKind::Memory),
            "tcp" => Ok(TransportKind::Tcp),
            other => Err(format!("bad transport '{other}' (memory|tcp)")),
        }
    }
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportKind::Memory => write!(f, "memory"),
            TransportKind::Tcp => write!(f, "tcp"),
        }
    }
}

/// TCP backend knobs. `workers[i]` is the `host:port` the master connects
/// to for worker id `i`; a refused/timed-out connect is retried
/// `connect_retries` times with `connect_backoff_ms` sleeps and then the
/// worker is marked down (reported per-iteration in
/// `TrainReport::worker_failures`, not a panic or abort).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TcpConfig {
    /// One `host:port` per worker, index = worker id.
    pub workers: Vec<String>,
    /// Per-attempt connect (and handshake-read) timeout.
    pub connect_timeout_ms: u64,
    /// Extra attempts after the first connect failure.
    pub connect_retries: u32,
    /// Sleep between connect attempts.
    pub connect_backoff_ms: u64,
}

impl Default for TcpConfig {
    fn default() -> Self {
        TcpConfig {
            workers: Vec::new(),
            connect_timeout_ms: 5000,
            connect_retries: 3,
            connect_backoff_ms: 100,
        }
    }
}

/// Transport selection + backend knobs, carried by
/// [`crate::coordinator::CodedMlConfig`]. Flat (kind beside the TCP
/// knobs) so JSON keys apply independently in any order.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TransportConfig {
    pub kind: TransportKind,
    pub tcp: TcpConfig,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_parses_and_displays() {
        assert_eq!("memory".parse::<TransportKind>().unwrap(), TransportKind::Memory);
        assert_eq!("tcp".parse::<TransportKind>().unwrap(), TransportKind::Tcp);
        assert!("udp".parse::<TransportKind>().unwrap_err().contains("bad transport"));
        assert_eq!(TransportKind::Memory.to_string(), "memory");
        assert_eq!(TransportKind::Tcp.to_string(), "tcp");
        assert_eq!(TransportKind::default(), TransportKind::Memory);
    }

    #[test]
    fn tcp_config_defaults_are_reasonable() {
        let cfg = TcpConfig::default();
        assert!(cfg.workers.is_empty());
        assert!(cfg.connect_timeout_ms > 0);
        assert!(cfg.connect_backoff_ms > 0);
    }
}
