//! Length-prefixed wire frames for the TCP transport.
//!
//! Every message crossing a worker connection is one frame:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  "CPML"
//! 4       2     version (little-endian u16, currently 2)
//! 6       1     opcode  (1=Hello 2=LoadData 3=Step 4=Shutdown 5=Ready 6=Result)
//! 7       1     reserved (0)
//! 8       4     payload length (little-endian u32, ≤ MAX_PAYLOAD)
//! 12      len   payload
//! ```
//!
//! All integers are little-endian; `Vec<u64>` payloads are a u32 count
//! followed by the raw words; strings are a u32 byte length followed by
//! UTF-8. Decoding is total: truncated, oversized, wrong-magic,
//! wrong-version and malformed frames come back as a typed [`WireError`],
//! never a panic (fuzzed in the tests below). The same byte layout is the
//! unit of the transport's byte accounting — the in-memory backend charges
//! [`frame_len`]-computed sizes without serializing, so the two backends
//! report identical per-message costs.

use std::io::{Read, Write};

use crate::cluster::worker::StepResult;

/// Frame magic: "CPML".
pub const MAGIC: [u8; 4] = *b"CPML";
/// Protocol version carried in every header. Version 2 added session ids
/// to Hello/LoadData/Step/Result so one worker process can serve several
/// concurrent training sessions without mixing their traffic.
pub const VERSION: u16 = 2;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 12;
/// Hard cap on a single payload (1 GiB) — anything larger is a corrupt or
/// hostile header, rejected before allocation.
pub const MAX_PAYLOAD: u32 = 1 << 30;

/// Opcodes. Master → worker: Hello, LoadData, Step, Shutdown.
/// Worker → master: Ready, Result.
pub mod opcode {
    pub const HELLO: u8 = 1;
    pub const LOAD_DATA: u8 = 2;
    pub const STEP: u8 = 3;
    pub const SHUTDOWN: u8 = 4;
    pub const READY: u8 = 5;
    pub const RESULT: u8 = 6;
}

/// Typed decode/IO failures. Every malformed input maps to one of these.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Header did not start with "CPML".
    BadMagic([u8; 4]),
    /// Version field differs from [`VERSION`].
    BadVersion(u16),
    /// Opcode outside the known table.
    BadOpcode(u8),
    /// Declared payload length exceeds [`MAX_PAYLOAD`].
    Oversized(u32),
    /// The stream ended mid-frame (or a payload field overran its frame).
    Truncated,
    /// Structurally valid frame whose payload failed to parse.
    BadPayload(String),
    /// Underlying socket/file error.
    Io(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:?}"),
            WireError::BadVersion(v) => {
                write!(f, "unsupported frame version {v} (want {VERSION})")
            }
            WireError::BadOpcode(op) => write!(f, "unknown opcode {op}"),
            WireError::Oversized(len) => {
                write!(f, "payload length {len} exceeds cap {MAX_PAYLOAD}")
            }
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::BadPayload(e) => write!(f, "bad payload: {e}"),
            WireError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

fn io_err(e: std::io::Error) -> WireError {
    if e.kind() == std::io::ErrorKind::UnexpectedEof {
        WireError::Truncated
    } else {
        WireError::Io(e.to_string())
    }
}

/// Write one frame; returns the total bytes put on the wire
/// (header + payload).
pub fn write_frame(w: &mut impl Write, op: u8, payload: &[u8]) -> Result<usize, WireError> {
    if payload.len() as u64 > MAX_PAYLOAD as u64 {
        return Err(WireError::Oversized(payload.len() as u32));
    }
    let mut header = [0u8; HEADER_LEN];
    header[..4].copy_from_slice(&MAGIC);
    header[4..6].copy_from_slice(&VERSION.to_le_bytes());
    header[6] = op;
    header[7] = 0;
    header[8..12].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&header).map_err(io_err)?;
    w.write_all(payload).map_err(io_err)?;
    Ok(HEADER_LEN + payload.len())
}

/// Read one frame. `Ok(None)` means the peer closed the connection cleanly
/// (EOF before any header byte) — every other shortfall is
/// [`WireError::Truncated`].
pub fn read_frame(r: &mut impl Read) -> Result<Option<(u8, Vec<u8>)>, WireError> {
    let mut header = [0u8; HEADER_LEN];
    let mut got = 0usize;
    while got < HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => return Err(WireError::Truncated),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(io_err(e)),
        }
    }
    if header[..4] != MAGIC {
        let mut m = [0u8; 4];
        m.copy_from_slice(&header[..4]);
        return Err(WireError::BadMagic(m));
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if version != VERSION {
        return Err(WireError::BadVersion(version));
    }
    let op = header[6];
    if !(opcode::HELLO..=opcode::RESULT).contains(&op) {
        return Err(WireError::BadOpcode(op));
    }
    let len = u32::from_le_bytes([header[8], header[9], header[10], header[11]]);
    if len > MAX_PAYLOAD {
        return Err(WireError::Oversized(len));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload).map_err(io_err)?;
    Ok(Some((op, payload)))
}

// ---------------------------------------------------------------------------
// Payload primitives
// ---------------------------------------------------------------------------

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        if self.remaining() < 1 {
            return Err(WireError::Truncated);
        }
        let v = self.buf[self.pos];
        self.pos += 1;
        Ok(v)
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        if self.remaining() < 4 {
            return Err(WireError::Truncated);
        }
        let mut b = [0u8; 4];
        b.copy_from_slice(&self.buf[self.pos..self.pos + 4]);
        self.pos += 4;
        Ok(u32::from_le_bytes(b))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        if self.remaining() < 8 {
            return Err(WireError::Truncated);
        }
        let mut b = [0u8; 8];
        b.copy_from_slice(&self.buf[self.pos..self.pos + 8]);
        self.pos += 8;
        Ok(u64::from_le_bytes(b))
    }

    /// Length-checked before allocation: a corrupt count cannot trigger a
    /// huge `Vec` reservation.
    fn vec_u64(&mut self) -> Result<Vec<u64>, WireError> {
        let n = self.u32()? as usize;
        if self.remaining() < n * 8 {
            return Err(WireError::Truncated);
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(out)
    }

    fn string(&mut self) -> Result<String, WireError> {
        let n = self.u32()? as usize;
        if self.remaining() < n {
            return Err(WireError::Truncated);
        }
        let bytes = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        String::from_utf8(bytes.to_vec())
            .map_err(|e| WireError::BadPayload(format!("string not UTF-8: {e}")))
    }

    fn finish(self) -> Result<(), WireError> {
        if self.remaining() == 0 {
            Ok(())
        } else {
            Err(WireError::BadPayload(format!(
                "{} trailing byte(s) after payload",
                self.remaining()
            )))
        }
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_vec_u64(out: &mut Vec<u8>, v: &[u64]) {
    put_u32(out, v.len() as u32);
    for &x in v {
        put_u64(out, x);
    }
}

fn put_string(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

// ---------------------------------------------------------------------------
// Wire-length arithmetic (byte accounting without serializing)
// ---------------------------------------------------------------------------

/// Bytes a `Vec<u64>` of `n` words occupies in a payload.
pub fn vec_u64_len(n: usize) -> usize {
    4 + 8 * n
}

/// Bytes a string occupies in a payload.
pub fn string_len(s: &str) -> usize {
    4 + s.len()
}

/// Total frame size for a payload of `payload_len` bytes.
pub fn frame_len(payload_len: usize) -> usize {
    HEADER_LEN + payload_len
}

/// Payload size of a [`MasterFrame::LoadData`] carrying `x` words and
/// optionally `y` words (8-byte session id + presence flag up front).
pub fn load_data_payload_len(x: usize, y: Option<usize>) -> usize {
    8 + 1 + vec_u64_len(x) + y.map(vec_u64_len).unwrap_or(0)
}

/// Payload size of a [`MasterFrame::Step`] carrying `w` words (session +
/// iteration ids up front).
pub fn step_payload_len(w: usize) -> usize {
    8 + 8 + vec_u64_len(w)
}

/// Payload size of a [`WorkerFrame::Result`] for `res`.
pub fn result_payload_len(res: &StepResult) -> usize {
    let body = match &res.data {
        Ok(v) => vec_u64_len(v.len()),
        Err(e) => string_len(e),
    };
    4 + 8 + 8 + 1 + body + 8
}

/// Payload size of a [`MasterFrame::Hello`] whose artifact dir is
/// `artifact_dir_len` bytes and whose coefficient vector holds `coeffs`
/// words. Fixed fields: id(4) + session(8) + backend(1) + op(1) + par(4)
/// + p(8) + rows(4) + d(4) + fail flag(1) + fail iter(8) + slow_ms(8).
pub fn hello_payload_len(artifact_dir_len: usize, coeffs: usize) -> usize {
    51 + vec_u64_len(coeffs) + 4 + artifact_dir_len
}

// ---------------------------------------------------------------------------
// Master → worker frames
// ---------------------------------------------------------------------------

/// Everything a remote worker needs to build its engine — the wire image
/// of a [`crate::cluster::WorkerSpec`] in primitive fields (conversion to
/// and from the spec lives in `transport::tcp`, next to the only code that
/// needs it).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HelloSpec {
    pub id: u32,
    /// Session the engine computes for. The first Hello on a connection
    /// is the handshake; later Hellos attach additional sessions.
    pub session: u64,
    /// 0 = native, 1 = xla.
    pub backend: u8,
    /// 0 = logistic, 1 = linear.
    pub op: u8,
    /// 0 = auto, 1 = serial, n = exactly n threads
    /// ([`crate::util::par::Parallelism::from_count`]).
    pub par: u32,
    pub p: u64,
    pub rows: u32,
    pub d: u32,
    pub fail_from_iter: Option<u64>,
    pub slow_ms: u64,
    pub coeffs: Vec<u64>,
    pub artifact_dir: String,
}

/// Frames the master sends.
#[derive(Debug, Clone, PartialEq)]
pub enum MasterFrame {
    Hello(HelloSpec),
    LoadData { session: u64, x: Vec<u64>, y: Option<Vec<u64>> },
    Step { session: u64, iter: u64, w: Vec<u64> },
    Shutdown,
}

impl MasterFrame {
    /// `(opcode, payload)` for [`write_frame`].
    pub fn encode(&self) -> (u8, Vec<u8>) {
        match self {
            MasterFrame::Hello(h) => {
                let mut out = Vec::new();
                put_u32(&mut out, h.id);
                put_u64(&mut out, h.session);
                out.push(h.backend);
                out.push(h.op);
                put_u32(&mut out, h.par);
                put_u64(&mut out, h.p);
                put_u32(&mut out, h.rows);
                put_u32(&mut out, h.d);
                match h.fail_from_iter {
                    Some(it) => {
                        out.push(1);
                        put_u64(&mut out, it);
                    }
                    None => {
                        out.push(0);
                        put_u64(&mut out, 0);
                    }
                }
                put_u64(&mut out, h.slow_ms);
                put_vec_u64(&mut out, &h.coeffs);
                put_string(&mut out, &h.artifact_dir);
                (opcode::HELLO, out)
            }
            MasterFrame::LoadData { session, x, y } => {
                let mut out = Vec::new();
                put_u64(&mut out, *session);
                match y {
                    Some(ys) => {
                        out.push(1);
                        put_vec_u64(&mut out, x);
                        put_vec_u64(&mut out, ys);
                    }
                    None => {
                        out.push(0);
                        put_vec_u64(&mut out, x);
                    }
                }
                (opcode::LOAD_DATA, out)
            }
            MasterFrame::Step { session, iter, w } => {
                let mut out = Vec::new();
                put_u64(&mut out, *session);
                put_u64(&mut out, *iter);
                put_vec_u64(&mut out, w);
                (opcode::STEP, out)
            }
            MasterFrame::Shutdown => (opcode::SHUTDOWN, Vec::new()),
        }
    }

    pub fn decode(op: u8, payload: &[u8]) -> Result<MasterFrame, WireError> {
        let mut r = Reader::new(payload);
        let frame = match op {
            opcode::HELLO => {
                let id = r.u32()?;
                let session = r.u64()?;
                let backend = r.u8()?;
                let op_code = r.u8()?;
                let par = r.u32()?;
                let p = r.u64()?;
                let rows = r.u32()?;
                let d = r.u32()?;
                let has_fail = r.u8()?;
                let fail_at = r.u64()?;
                let slow_ms = r.u64()?;
                let coeffs = r.vec_u64()?;
                let artifact_dir = r.string()?;
                MasterFrame::Hello(HelloSpec {
                    id,
                    session,
                    backend,
                    op: op_code,
                    par,
                    p,
                    rows,
                    d,
                    fail_from_iter: (has_fail != 0).then_some(fail_at),
                    slow_ms,
                    coeffs,
                    artifact_dir,
                })
            }
            opcode::LOAD_DATA => {
                let session = r.u64()?;
                let has_y = r.u8()?;
                let x = r.vec_u64()?;
                let y = if has_y != 0 { Some(r.vec_u64()?) } else { None };
                MasterFrame::LoadData { session, x, y }
            }
            opcode::STEP => {
                let session = r.u64()?;
                let iter = r.u64()?;
                let w = r.vec_u64()?;
                MasterFrame::Step { session, iter, w }
            }
            opcode::SHUTDOWN => MasterFrame::Shutdown,
            other => return Err(WireError::BadOpcode(other)),
        };
        r.finish()?;
        Ok(frame)
    }
}

// ---------------------------------------------------------------------------
// Worker → master frames
// ---------------------------------------------------------------------------

/// Frames a worker sends.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkerFrame {
    /// Handshake reply to Hello: `error` is `Some` when the backend failed
    /// to build (the master aborts connect, mirroring the in-memory
    /// spawn-fails-fast semantics).
    Ready { error: Option<String> },
    Result(StepResult),
}

impl WorkerFrame {
    /// `(opcode, payload)` for [`write_frame`].
    pub fn encode(&self) -> (u8, Vec<u8>) {
        match self {
            WorkerFrame::Ready { error } => {
                let mut out = Vec::new();
                match error {
                    Some(e) => {
                        out.push(1);
                        put_string(&mut out, e);
                    }
                    None => out.push(0),
                }
                (opcode::READY, out)
            }
            WorkerFrame::Result(res) => {
                let mut out = Vec::new();
                put_u32(&mut out, res.worker as u32);
                put_u64(&mut out, res.session);
                put_u64(&mut out, res.iter);
                match &res.data {
                    Ok(v) => {
                        out.push(1);
                        put_vec_u64(&mut out, v);
                    }
                    Err(e) => {
                        out.push(0);
                        put_string(&mut out, e);
                    }
                }
                put_u64(&mut out, res.compute_secs.to_bits());
                (opcode::RESULT, out)
            }
        }
    }

    pub fn decode(op: u8, payload: &[u8]) -> Result<WorkerFrame, WireError> {
        let mut r = Reader::new(payload);
        let frame = match op {
            opcode::READY => {
                let has_err = r.u8()?;
                let error = if has_err != 0 { Some(r.string()?) } else { None };
                WorkerFrame::Ready { error }
            }
            opcode::RESULT => {
                let worker = r.u32()? as usize;
                let session = r.u64()?;
                let iter = r.u64()?;
                let ok = r.u8()?;
                let data = if ok != 0 { Ok(r.vec_u64()?) } else { Err(r.string()?) };
                let compute_secs = f64::from_bits(r.u64()?);
                WorkerFrame::Result(StepResult { worker, session, iter, data, compute_secs })
            }
            other => return Err(WireError::BadOpcode(other)),
        };
        r.finish()?;
        Ok(frame)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn round_trip_master(f: MasterFrame) {
        let (op, payload) = f.encode();
        let mut wire = Vec::new();
        let n = write_frame(&mut wire, op, &payload).unwrap();
        assert_eq!(n, wire.len());
        assert_eq!(n, frame_len(payload.len()));
        let (rop, rpayload) = read_frame(&mut wire.as_slice()).unwrap().unwrap();
        assert_eq!((rop, &rpayload), (op, &payload));
        assert_eq!(MasterFrame::decode(rop, &rpayload).unwrap(), f);
    }

    fn round_trip_worker(f: WorkerFrame) {
        let (op, payload) = f.encode();
        let mut wire = Vec::new();
        write_frame(&mut wire, op, &payload).unwrap();
        let (rop, rpayload) = read_frame(&mut wire.as_slice()).unwrap().unwrap();
        assert_eq!(WorkerFrame::decode(rop, &rpayload).unwrap(), f);
    }

    fn sample_hello(rng: &mut Rng) -> HelloSpec {
        HelloSpec {
            id: rng.below(64) as u32,
            session: rng.below(8),
            backend: rng.below(2) as u8,
            op: rng.below(2) as u8,
            par: rng.below(9) as u32,
            p: rng.next_u64() | 1,
            rows: 1 + rng.below(1000) as u32,
            d: 1 + rng.below(1000) as u32,
            fail_from_iter: rng.bernoulli(0.5).then(|| rng.below(100)),
            slow_ms: rng.below(1000),
            coeffs: (0..rng.below_usize(5)).map(|_| rng.next_u64()).collect(),
            artifact_dir: "artifacts/λ-dir".to_string(),
        }
    }

    #[test]
    fn master_frames_round_trip() {
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            round_trip_master(MasterFrame::Hello(sample_hello(&mut rng)));
            let x: Vec<u64> = (0..rng.below_usize(64)).map(|_| rng.next_u64()).collect();
            let y = rng
                .bernoulli(0.5)
                .then(|| (0..rng.below_usize(16)).map(|_| rng.next_u64()).collect());
            round_trip_master(MasterFrame::LoadData { session: rng.below(4), x, y });
            round_trip_master(MasterFrame::Step {
                session: rng.below(4),
                iter: rng.next_u64(),
                w: (0..rng.below_usize(64)).map(|_| rng.next_u64()).collect(),
            });
        }
        round_trip_master(MasterFrame::Shutdown);
        round_trip_master(MasterFrame::LoadData { session: 0, x: vec![], y: Some(vec![]) });
    }

    #[test]
    fn worker_frames_round_trip_both_result_arms() {
        let mut rng = Rng::new(8);
        round_trip_worker(WorkerFrame::Ready { error: None });
        round_trip_worker(WorkerFrame::Ready { error: Some("no artifact".into()) });
        for _ in 0..50 {
            let data = if rng.bernoulli(0.5) {
                Ok((0..rng.below_usize(64)).map(|_| rng.next_u64()).collect())
            } else {
                Err("injected fault".to_string())
            };
            round_trip_worker(WorkerFrame::Result(StepResult {
                worker: rng.below_usize(64),
                session: rng.below(16),
                iter: rng.next_u64(),
                data,
                compute_secs: rng.f64(),
            }));
        }
    }

    #[test]
    fn wire_length_helpers_match_encoders() {
        let mut rng = Rng::new(9);
        for _ in 0..20 {
            let x: Vec<u64> = (0..rng.below_usize(40)).map(|_| rng.next_u64()).collect();
            let y: Option<Vec<u64>> = rng
                .bernoulli(0.5)
                .then(|| (0..rng.below_usize(40)).map(|_| rng.next_u64()).collect());
            let (_, p) =
                MasterFrame::LoadData { session: 1, x: x.clone(), y: y.clone() }.encode();
            assert_eq!(p.len(), load_data_payload_len(x.len(), y.as_ref().map(Vec::len)));

            let w: Vec<u64> = (0..rng.below_usize(40)).map(|_| rng.next_u64()).collect();
            let (_, p) = MasterFrame::Step { session: 1, iter: 3, w: w.clone() }.encode();
            assert_eq!(p.len(), step_payload_len(w.len()));

            let hello = sample_hello(&mut rng);
            let (_, p) = MasterFrame::Hello(hello.clone()).encode();
            assert_eq!(
                p.len(),
                hello_payload_len(hello.artifact_dir.len(), hello.coeffs.len())
            );

            let res = StepResult {
                worker: 2,
                session: 6,
                iter: 5,
                data: if rng.bernoulli(0.5) {
                    Ok(w.clone())
                } else {
                    Err("boom with ünicode".to_string())
                },
                compute_secs: 0.25,
            };
            let (_, p) = WorkerFrame::Result(res.clone()).encode();
            assert_eq!(p.len(), result_payload_len(&res));
        }
    }

    #[test]
    fn rejects_bad_magic_version_opcode_oversize() {
        let (op, payload) = MasterFrame::Shutdown.encode();
        let mut wire = Vec::new();
        write_frame(&mut wire, op, &payload).unwrap();

        let mut bad = wire.clone();
        bad[0] = b'X';
        assert!(matches!(
            read_frame(&mut bad.as_slice()),
            Err(WireError::BadMagic(_))
        ));

        let mut bad = wire.clone();
        bad[4] = 99;
        assert_eq!(read_frame(&mut bad.as_slice()), Err(WireError::BadVersion(99)));

        let mut bad = wire.clone();
        bad[6] = 42;
        assert_eq!(read_frame(&mut bad.as_slice()), Err(WireError::BadOpcode(42)));

        let mut bad = wire;
        bad[8..12].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert_eq!(
            read_frame(&mut bad.as_slice()),
            Err(WireError::Oversized(MAX_PAYLOAD + 1))
        );
    }

    #[test]
    fn truncation_at_every_cut_is_typed_not_a_panic() {
        let (op, payload) =
            MasterFrame::Step { session: 0, iter: 9, w: vec![1, 2, 3] }.encode();
        let mut wire = Vec::new();
        write_frame(&mut wire, op, &payload).unwrap();
        for cut in 0..wire.len() {
            let mut cursor: &[u8] = &wire[..cut];
            let got = read_frame(&mut cursor);
            if cut == 0 {
                assert_eq!(got, Ok(None), "EOF at a frame boundary is a clean close");
            } else {
                assert_eq!(got, Err(WireError::Truncated), "cut at {cut}");
            }
        }
    }

    #[test]
    fn fuzz_random_corruption_never_panics() {
        // Fuzz-style: take valid frames, flip random bytes/lengths, and
        // require every outcome to be Ok or a typed WireError — decoding
        // must be total.
        let mut rng = Rng::new(0xF0055_u64);
        let frames: Vec<Vec<u8>> = {
            let mut out = Vec::new();
            let (op, p) = MasterFrame::Hello(sample_hello(&mut rng)).encode();
            let mut w = Vec::new();
            write_frame(&mut w, op, &p).unwrap();
            out.push(w);
            let (op, p) = MasterFrame::LoadData {
                session: 2,
                x: vec![5; 12],
                y: Some(vec![7; 12]),
            }
            .encode();
            let mut w = Vec::new();
            write_frame(&mut w, op, &p).unwrap();
            out.push(w);
            let (op, p) = WorkerFrame::Result(StepResult {
                worker: 1,
                session: 0,
                iter: 2,
                data: Ok(vec![3; 9]),
                compute_secs: 0.5,
            })
            .encode();
            let mut w = Vec::new();
            write_frame(&mut w, op, &p).unwrap();
            out.push(w);
            out
        };
        for _ in 0..2000 {
            let mut wire = frames[rng.below_usize(frames.len())].clone();
            for _ in 0..=rng.below_usize(4) {
                let at = rng.below_usize(wire.len());
                wire[at] = rng.next_u64() as u8;
            }
            if rng.bernoulli(0.3) {
                wire.truncate(rng.below_usize(wire.len() + 1));
            }
            match read_frame(&mut wire.as_slice()) {
                Ok(Some((op, payload))) => {
                    // Whichever direction claims the opcode, decoding must
                    // return, not panic.
                    let _ = MasterFrame::decode(op, &payload);
                    let _ = WorkerFrame::decode(op, &payload);
                }
                Ok(None) | Err(_) => {}
            }
        }
    }
}
