//! The in-memory transport: one OS thread per worker, one shared results
//! channel.
//!
//! This is the original simulated cluster, now behind the
//! [`Transport`] trait. Messages never serialize — they move through
//! `std::sync::mpsc` by value — but every send/receive is charged the
//! byte size the equivalent TCP frame would occupy
//! ([`frame::frame_len`] over the payload-length helpers), so byte
//! accounting is backend-independent and the TCP bench compares real
//! wire costs against the same denominator.

use std::collections::HashMap;
use std::sync::mpsc;
use std::thread::JoinHandle;

use super::frame;
use super::{Transport, TransportEvent};
use crate::cluster::worker::{ClusterError, StepResult, WorkerEngine, WorkerSpec};
use crate::util::timer::Deadline;

/// Master → worker messages (the in-memory mirror of
/// [`frame::MasterFrame`], minus Hello: the spec rides into the thread at
/// spawn).
enum ToWorker {
    /// Build an engine for one more session on this worker (the serve
    /// scheduler sharing a pool between jobs).
    Attach(Box<WorkerSpec>),
    /// One-time delivery of one session's coded dataset share (and labels
    /// for Linear).
    LoadData { session: u64, x: Vec<u64>, y: Option<Vec<u64>> },
    /// Per-iteration coded weights for one session.
    Step { session: u64, iter: u64, w: Vec<u64> },
    Shutdown,
}

struct WorkerHandle {
    tx: mpsc::Sender<ToWorker>,
    join: Option<JoinHandle<()>>,
}

/// In-process transport backend (the default).
pub struct ChannelTransport {
    workers: Vec<WorkerHandle>,
    results_rx: mpsc::Receiver<StepResult>,
    /// Kept so [`Transport::reconnect`] can hand replacement threads a
    /// sender for the shared results channel.
    results_tx: mpsc::Sender<StepResult>,
    sent: u64,
    received: u64,
}

fn worker_thread(
    spec: WorkerSpec,
    rx: mpsc::Receiver<ToWorker>,
    tx: mpsc::Sender<StepResult>,
    ready: mpsc::Sender<Result<(), String>>,
) {
    let id = spec.id;
    let first_session = spec.session;
    // One engine per attached session; the spawn spec's session is the
    // first. An attach failure poisons only that session's steps (the
    // master sees Err results on it), never the whole worker.
    let mut engines: HashMap<u64, WorkerEngine> = HashMap::new();
    let mut attach_errors: HashMap<u64, String> = HashMap::new();
    match WorkerEngine::new(spec) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            engines.insert(first_session, e);
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    }
    while let Ok(msg) = rx.recv() {
        match msg {
            ToWorker::Attach(spec) => {
                let session = spec.session;
                match WorkerEngine::new(*spec) {
                    Ok(e) => {
                        engines.insert(session, e);
                        attach_errors.remove(&session);
                    }
                    Err(e) => {
                        attach_errors.insert(session, e);
                    }
                }
            }
            ToWorker::LoadData { session, x, y } => {
                if let Some(en) = engines.get_mut(&session) {
                    en.load(x, y);
                }
            }
            ToWorker::Step { session, iter, w } => {
                let res = match engines.get(&session) {
                    Some(en) => en.step(iter, &w),
                    None => StepResult {
                        worker: id,
                        session,
                        iter,
                        data: Err(match attach_errors.get(&session) {
                            Some(e) => format!("attach failed: {e}"),
                            None => format!("no engine for session {session}"),
                        }),
                        compute_secs: 0.0,
                    },
                };
                if tx.send(res).is_err() {
                    return; // master gone
                }
            }
            ToWorker::Shutdown => return,
        }
    }
}

impl ChannelTransport {
    /// Spawn one thread per spec. Fails if any backend fails to build —
    /// same fail-fast semantics the TCP handshake mirrors.
    pub fn spawn(specs: Vec<WorkerSpec>) -> Result<Self, ClusterError> {
        let (results_tx, results_rx) = mpsc::channel();
        let mut workers = Vec::with_capacity(specs.len());
        let mut readies = Vec::with_capacity(specs.len());
        for spec in specs {
            let (tx, rx) = mpsc::channel();
            let (ready_tx, ready_rx) = mpsc::channel();
            let rtx = results_tx.clone();
            let join = std::thread::Builder::new()
                .name(format!("worker-{}", spec.id))
                .spawn(move || worker_thread(spec, rx, rtx, ready_tx))
                .map_err(|e| ClusterError::Spawn(e.to_string()))?;
            workers.push(WorkerHandle { tx, join: Some(join) });
            readies.push(ready_rx);
        }
        for (i, ready) in readies.iter().enumerate() {
            match ready.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => return Err(ClusterError::Backend(format!("worker {i}: {e}"))),
                Err(_) => return Err(ClusterError::WorkerLost(i)),
            }
        }
        Ok(ChannelTransport { workers, results_rx, results_tx, sent: 0, received: 0 })
    }

    fn stop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(ToWorker::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}

impl Transport for ChannelTransport {
    fn n(&self) -> usize {
        self.workers.len()
    }

    fn name(&self) -> &'static str {
        "memory"
    }

    fn send_load(
        &mut self,
        worker: usize,
        session: u64,
        x: Vec<u64>,
        y: Option<Vec<u64>>,
    ) -> Result<(), String> {
        let cost = frame::frame_len(frame::load_data_payload_len(
            x.len(),
            y.as_ref().map(Vec::len),
        )) as u64;
        self.workers[worker]
            .tx
            .send(ToWorker::LoadData { session, x, y })
            .map_err(|_| "worker channel closed".to_string())?;
        self.sent += cost;
        Ok(())
    }

    fn send_step(
        &mut self,
        worker: usize,
        session: u64,
        iter: u64,
        w: Vec<u64>,
    ) -> Result<(), String> {
        let cost = frame::frame_len(frame::step_payload_len(w.len())) as u64;
        self.workers[worker]
            .tx
            .send(ToWorker::Step { session, iter, w })
            .map_err(|_| "worker channel closed".to_string())?;
        self.sent += cost;
        Ok(())
    }

    fn send_attach(&mut self, worker: usize, spec: &WorkerSpec) -> Result<(), String> {
        let cost = frame::frame_len(frame::hello_payload_len(
            spec.artifact_dir.as_os_str().len(),
            spec.coeffs.len(),
        )) as u64;
        self.workers[worker]
            .tx
            .send(ToWorker::Attach(Box::new(spec.clone())))
            .map_err(|_| "worker channel closed".to_string())?;
        self.sent += cost;
        Ok(())
    }

    fn recv_deadline(
        &mut self,
        deadline: &Deadline,
    ) -> Result<Option<TransportEvent>, ClusterError> {
        let res = match deadline.remaining() {
            None => self
                .results_rx
                .recv()
                .map_err(|_| ClusterError::Channel("results"))?,
            Some(left) => match self.results_rx.recv_timeout(left) {
                Ok(r) => r,
                Err(mpsc::RecvTimeoutError::Timeout) => return Ok(None),
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(ClusterError::Channel("results"))
                }
            },
        };
        self.received += frame::frame_len(frame::result_payload_len(&res)) as u64;
        Ok(Some(TransportEvent::Result(res)))
    }

    fn reconnect(&mut self, spec: &WorkerSpec) -> Result<(), String> {
        let worker = spec.id;
        if worker >= self.workers.len() {
            return Err(format!("no worker slot {worker}"));
        }
        let (tx, rx) = mpsc::channel();
        let (ready_tx, ready_rx) = mpsc::channel();
        let rtx = self.results_tx.clone();
        let spec = spec.clone();
        let join = std::thread::Builder::new()
            .name(format!("worker-{worker}-respawn"))
            .spawn(move || worker_thread(spec, rx, rtx, ready_tx))
            .map_err(|e| format!("spawn replacement: {e}"))?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(format!("replacement backend: {e}")),
            Err(_) => return Err("replacement died before ready".to_string()),
        }
        // Retire the old handle: best-effort shutdown, then detach. Any
        // results the old thread already sent drain as late/stale through
        // the round engine; nothing new reaches it once its command
        // channel drops here.
        let old = std::mem::replace(
            &mut self.workers[worker],
            WorkerHandle { tx, join: Some(join) },
        );
        let _ = old.tx.send(ToWorker::Shutdown);
        drop(old);
        Ok(())
    }

    fn shutdown(&mut self) {
        self.stop();
    }

    fn bytes(&self) -> (u64, u64) {
        (self.sent, self.received)
    }
}

impl Drop for ChannelTransport {
    fn drop(&mut self) {
        self.stop();
    }
}
