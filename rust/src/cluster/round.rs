//! One iteration's streaming collection state.
//!
//! The master consumes [`StepResult`]s in the order they actually arrive
//! on the shared results channel and declares the round complete as soon
//! as the fastest `need` usable results have landed — it never waits for
//! the remaining `N − need` workers. Anything still in flight from an
//! earlier iteration is drained and discarded here (counted, never
//! decoded), which is what lets a permanently slow worker fall behind
//! without ever blocking or corrupting later iterations.

use super::worker::StepResult;

/// Collection state for a single iteration.
#[derive(Debug)]
pub struct Round {
    /// Session this round collects for. Results stamped with any other
    /// session id are rejected (counted in `misrouted`) — interleaved
    /// jobs sharing one pool must never leak results into each other.
    pub session: u64,
    /// Iteration this round collects for; results tagged with an earlier
    /// iteration are stale leftovers and are dropped.
    pub iter: u64,
    /// Results required before the round completes (the LCC recovery
    /// threshold R — decoding needs exactly this many).
    pub need: usize,
    /// Workers that were dispatched this iteration (each sends exactly
    /// one result, so `need` can be declared unreachable once
    /// `results + failures == expected`).
    expected: usize,
    /// Usable results in arrival order; capped at `need`.
    pub results: Vec<StepResult>,
    /// `(worker, error)` for every failure observed this round.
    pub failures: Vec<(usize, String)>,
    /// Stale usable results from previous iterations drained while
    /// collecting.
    pub late_drained: usize,
    /// Stale *failures* drained while collecting — an Err that lands after
    /// its own round completed must still reach the failure counters, but
    /// must not feed this round's completion accounting.
    pub late_failures: Vec<(usize, String)>,
    /// Failures that were subsequently healed by the supervisor: the
    /// worker was revived and re-dispatched *within this round*, so its
    /// original failure no longer blocks completion accounting — but it
    /// still happened and still reaches `TrainReport::worker_failures`.
    pub healed: Vec<(usize, String)>,
    /// Results rejected because their session id did not match this
    /// round's. They never touch completion accounting or the decoder.
    pub misrouted: u64,
    /// Set when collection stopped because the per-round deadline
    /// (`--round-deadline-ms`) expired with workers still outstanding;
    /// each outstanding worker also gets a synthesized failure entry.
    pub deadline_expired: bool,
    /// Dispatch→completion wall time, filled in by the collector.
    pub wall_secs: f64,
}

impl Round {
    pub fn new(iter: u64, need: usize, expected: usize) -> Self {
        Round::for_session(0, iter, need, expected)
    }

    /// A round scoped to one session of a shared pool. [`Round::new`] is
    /// the dedicated-cluster special case (session 0).
    pub fn for_session(session: u64, iter: u64, need: usize, expected: usize) -> Self {
        assert!(need <= expected, "need {need} results from {expected} workers");
        Round {
            session,
            iter,
            need,
            expected,
            results: Vec::with_capacity(need),
            failures: Vec::new(),
            late_drained: 0,
            late_failures: Vec::new(),
            healed: Vec::new(),
            misrouted: 0,
            deadline_expired: false,
            wall_secs: 0.0,
        }
    }

    /// The supervisor revived `worker` and re-dispatched this iteration's
    /// weights to it: move its failure out of the completion accounting
    /// (into `healed`) so the round can wait for the replacement's result.
    /// Returns false (and changes nothing) if `worker` has no recorded
    /// failure this round.
    pub fn heal(&mut self, worker: usize) -> bool {
        match self.failures.iter().position(|(w, _)| *w == worker) {
            Some(at) => {
                let entry = self.failures.remove(at);
                self.healed.push(entry);
                true
            }
            None => false,
        }
    }

    /// Feed one raw channel message. Results for earlier iterations are
    /// counted as late and dropped; results for this iteration land in
    /// `results` or `failures`.
    pub fn absorb(&mut self, res: StepResult) {
        if res.session != self.session {
            // A result from another session must never be decoded here —
            // not even as a late drain. Reject and count.
            self.misrouted += 1;
            return;
        }
        if res.iter != self.iter {
            if res.iter > self.iter {
                // A result tagged for a *future* iteration means dispatch
                // and collection got out of sync. Surface it through the
                // failure channel instead of aborting the training loop.
                self.late_failures.push((
                    res.worker,
                    format!("result tagged for future iteration {}", res.iter),
                ));
                return;
            }
            match res.data {
                Ok(_) => self.late_drained += 1,
                Err(msg) => self.late_failures.push((res.worker, msg)),
            }
            return;
        }
        match res.data {
            // A second usable result from a worker already on the books
            // (a heal re-dispatch racing the old incarnation's in-flight
            // answer) would make the decoder see a duplicate eval point;
            // keep the first arrival, drain the echo.
            Ok(_) if self.results.iter().any(|r| r.worker == res.worker) => {
                self.late_drained += 1
            }
            Ok(_) if self.results.len() < self.need => self.results.push(res),
            // A usable result past the threshold (only possible when the
            // caller keeps feeding a completed round) is as good as late.
            Ok(_) => self.late_drained += 1,
            Err(ref msg) => {
                let msg = msg.clone();
                self.failures.push((res.worker, msg));
            }
        }
    }

    /// The round is over: either enough usable results arrived, or every
    /// dispatched worker has been accounted for and `need` is unreachable.
    pub fn complete(&self) -> bool {
        self.results.len() >= self.need
            || self.results.len() + self.failures.len() >= self.expected
    }

    /// Did the round actually reach the threshold?
    pub fn ok(&self) -> bool {
        self.results.len() >= self.need
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ok_result(worker: usize, iter: u64) -> StepResult {
        StepResult { worker, session: 0, iter, data: Ok(vec![worker as u64]), compute_secs: 0.001 }
    }

    fn err_result(worker: usize, iter: u64) -> StepResult {
        StepResult { worker, session: 0, iter, data: Err("boom".into()), compute_secs: 0.0 }
    }

    #[test]
    fn completes_at_need_without_waiting_for_all() {
        let mut r = Round::new(3, 2, 5);
        r.absorb(ok_result(4, 3));
        assert!(!r.complete());
        r.absorb(ok_result(1, 3));
        assert!(r.complete() && r.ok());
        assert_eq!(r.results.len(), 2);
        // Arrival order is preserved — the decoder gets the fastest subset.
        assert_eq!(r.results[0].worker, 4);
        assert_eq!(r.results[1].worker, 1);
    }

    #[test]
    fn stale_results_are_counted_not_used() {
        let mut r = Round::new(5, 2, 4);
        r.absorb(ok_result(0, 4)); // leftover from iteration 4
        r.absorb(err_result(1, 3)); // stale failure: still surfaced…
        assert_eq!(r.late_drained, 1);
        assert_eq!(r.late_failures, vec![(1, "boom".to_string())]);
        // …but never feeds this round's completion accounting.
        assert!(r.results.is_empty() && r.failures.is_empty());
        r.absorb(ok_result(2, 5));
        r.absorb(ok_result(3, 5));
        assert!(r.complete() && r.ok());
    }

    #[test]
    fn completes_unreachable_when_failures_exhaust_workers() {
        let mut r = Round::new(0, 3, 4);
        r.absorb(ok_result(0, 0));
        r.absorb(err_result(1, 0));
        r.absorb(err_result(2, 0));
        assert!(!r.complete());
        r.absorb(err_result(3, 0));
        assert!(r.complete(), "all four workers accounted for");
        assert!(!r.ok(), "threshold 3 unreachable with one usable result");
        assert_eq!(r.failures.len(), 3);
    }

    #[test]
    fn future_iteration_result_surfaces_as_failure_not_abort() {
        let mut r = Round::new(2, 1, 3);
        r.absorb(ok_result(0, 7)); // tagged for iteration 7 while collecting 2
        assert!(r.results.is_empty(), "future result must not be decoded");
        assert_eq!(r.late_failures.len(), 1);
        assert!(r.late_failures[0].1.contains("future iteration 7"));
        r.absorb(ok_result(1, 2));
        assert!(r.complete() && r.ok());
    }

    #[test]
    fn duplicate_worker_result_is_drained_first_arrival_wins() {
        let mut r = Round::new(0, 2, 3);
        r.absorb(StepResult {
            worker: 1,
            session: 0,
            iter: 0,
            data: Ok(vec![10]),
            compute_secs: 0.001,
        });
        // The same worker answers again (old incarnation's in-flight
        // result racing a heal re-dispatch): drained, not decoded twice.
        r.absorb(StepResult {
            worker: 1,
            session: 0,
            iter: 0,
            data: Ok(vec![99]),
            compute_secs: 0.001,
        });
        assert_eq!(r.results.len(), 1);
        assert_eq!(r.results[0].data, Ok(vec![10]), "first arrival wins");
        assert_eq!(r.late_drained, 1);
        assert!(!r.complete(), "the echo must not count toward need");
        r.absorb(ok_result(2, 0));
        assert!(r.complete() && r.ok());
    }

    #[test]
    fn extra_results_past_need_are_dropped() {
        let mut r = Round::new(0, 1, 3);
        r.absorb(ok_result(0, 0));
        r.absorb(ok_result(1, 0));
        assert_eq!(r.results.len(), 1);
        assert_eq!(r.late_drained, 1);
    }

    #[test]
    fn cross_session_result_is_rejected_and_counted() {
        let mut r = Round::for_session(7, 0, 1, 2);
        let mut foreign = ok_result(0, 0);
        foreign.session = 3;
        r.absorb(foreign);
        assert!(r.results.is_empty(), "foreign session must not be decoded");
        assert_eq!(r.misrouted, 1);
        assert!(!r.complete(), "misroutes never feed completion accounting");
        let mut own = ok_result(1, 0);
        own.session = 7;
        r.absorb(own);
        assert!(r.complete() && r.ok());
    }

    #[test]
    fn heal_reopens_completion_and_keeps_failure_recorded() {
        let mut r = Round::new(0, 2, 3);
        r.absorb(ok_result(0, 0));
        r.absorb(err_result(1, 0));
        r.absorb(err_result(2, 0));
        assert!(r.complete() && !r.ok(), "threshold unreachable");
        // Supervisor revives worker 1 and re-dispatches: its failure moves
        // aside so the round can wait for the replacement's result.
        assert!(r.heal(1));
        assert!(!r.complete(), "healed round waits for the replacement");
        assert_eq!(r.healed, vec![(1, "boom".to_string())]);
        assert_eq!(r.failures.len(), 1);
        // Healing a worker with no recorded failure is a no-op.
        assert!(!r.heal(0));
        r.absorb(ok_result(1, 0));
        assert!(r.complete() && r.ok(), "replacement result completes the round");
    }
}
