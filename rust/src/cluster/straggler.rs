//! Straggler injection.
//!
//! Coded computing exists because of stragglers; to exercise the
//! fastest-R collection path we add a per-(worker, iteration) delay drawn
//! from the shifted-exponential model used throughout the coded-computing
//! literature (Lee et al. 2018): delay = shift + Exp(rate), optionally
//! scaled by the worker's compute time (slow *machines* rather than slow
//! packets).

use crate::util::Rng;

/// Shifted-exponential straggler model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StragglerModel {
    /// Deterministic extra seconds every worker pays.
    pub shift: f64,
    /// Exponential rate λ; mean extra delay is 1/λ. `f64::INFINITY`
    /// disables the random part.
    pub rate: f64,
    /// If true, the sampled delay multiplies the worker's compute time
    /// (delay_fraction) instead of being absolute seconds.
    pub relative: bool,
}

impl Default for StragglerModel {
    fn default() -> Self {
        // Mild relative straggling: mean 20% compute-time inflation.
        StragglerModel { shift: 0.0, rate: 5.0, relative: true }
    }
}

impl StragglerModel {
    /// No straggling at all.
    pub fn none() -> Self {
        StragglerModel { shift: 0.0, rate: f64::INFINITY, relative: false }
    }

    /// Sample this worker's extra delay given its measured compute time.
    pub fn sample(&self, rng: &mut Rng, compute_secs: f64) -> f64 {
        let tail = if self.rate.is_finite() {
            rng.exponential(self.rate)
        } else {
            0.0
        };
        if self.relative {
            (self.shift + tail) * compute_secs
        } else {
            self.shift + tail
        }
    }
}

/// Streaming mean/variance (Welford) over observed per-round wall times.
///
/// This is the measurement half of the adaptive controller
/// ([`super::supervisor::DeadlineController`]): each completed round
/// records its wall time here, and the next round's deadline is chosen
/// from the running mean + a few standard deviations — so the deadline
/// tracks the cluster actually being observed instead of a static guess.
#[derive(Debug, Clone, Default)]
pub struct ArrivalStats {
    count: u64,
    mean: f64,
    m2: f64,
}

impl ArrivalStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold in one observed round wall time (seconds).
    pub fn record(&mut self, secs: f64) {
        self.count += 1;
        let delta = secs - self.mean;
        self.mean += delta / self.count as f64;
        self.m2 += delta * (secs - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample standard deviation; 0 until two observations exist.
    pub fn std_dev(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            (self.m2 / (self.count - 1) as f64).sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_stats_match_closed_form() {
        let mut s = ArrivalStats::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.std_dev(), 0.0);
        for v in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.record(v);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12, "mean={}", s.mean());
        // Sample variance of the classic example set is 32/7.
        let want = (32.0f64 / 7.0).sqrt();
        assert!((s.std_dev() - want).abs() < 1e-12, "sd={}", s.std_dev());
    }

    #[test]
    fn none_is_zero() {
        let mut rng = Rng::new(1);
        let m = StragglerModel::none();
        for _ in 0..10 {
            assert_eq!(m.sample(&mut rng, 1.0), 0.0);
        }
    }

    #[test]
    fn absolute_mean_matches_rate() {
        let mut rng = Rng::new(2);
        let m = StragglerModel { shift: 0.1, rate: 10.0, relative: false };
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| m.sample(&mut rng, 123.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.2).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn relative_scales_with_compute() {
        let mut rng = Rng::new(3);
        let m = StragglerModel { shift: 0.5, rate: f64::INFINITY, relative: true };
        assert!((m.sample(&mut rng, 2.0) - 1.0).abs() < 1e-12);
        assert!((m.sample(&mut rng, 4.0) - 2.0).abs() < 1e-12);
    }
}
