//! Worker threads and the cluster handle.
//!
//! Each worker owns its backend (constructed in-thread — the XLA runtime
//! is thread-local by design) and its coded data share, mirroring the
//! paper's protocol where X̃_i is sent once and W̃_i^(t) every iteration.

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::round::Round;
use crate::runtime::{BackendKind, WorkerBackend};
use crate::field::PrimeField;
use crate::util::par::Parallelism;
use crate::util::timer::timed;
use std::path::PathBuf;

/// What the worker computes each step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerOp {
    /// Logistic: f = X̃ᵀ ḡ(X̃, W̃) with the polynomial coefficients.
    Logistic,
    /// Linear (Remark 1): f = X̃ᵀ (X̃·w̃ − ỹ) — needs the coded labels.
    Linear,
}

/// `Send`-able recipe for building a worker.
#[derive(Debug, Clone)]
pub struct WorkerSpec {
    pub id: usize,
    pub kind: BackendKind,
    pub artifact_dir: PathBuf,
    pub field: PrimeField,
    /// Coded block height m/K.
    pub rows: usize,
    pub d: usize,
    /// Field-quantized sigmoid coefficients (len r+1); ignored for Linear.
    pub coeffs: Vec<u64>,
    pub op: WorkerOp,
    /// Chaos hook: fail every step with iter ≥ this (crash-style fault
    /// injection for resilience tests; None = healthy).
    pub fail_from_iter: Option<u64>,
    /// Chaos hook: extra sleep per step (a permanently slow machine).
    /// The streaming round engine leaves such a worker behind — its
    /// results arrive late and are drained, never decoded.
    pub slow_ms: u64,
    /// Intra-worker thread budget for the native matmul kernels (results
    /// are bit-exact at any setting; see [`crate::util::par`]).
    pub par: Parallelism,
}

enum ToWorker {
    /// One-time delivery of the coded dataset share (and labels for Linear).
    LoadData { x: Vec<u64>, y: Option<Vec<u64>> },
    /// Per-iteration coded weights.
    Step { iter: u64, w: Vec<u64> },
    Shutdown,
}

/// A worker's per-step result.
#[derive(Debug, Clone)]
pub struct StepResult {
    pub worker: usize,
    pub iter: u64,
    /// f(X̃_i, W̃_i) — or an error message if the backend failed.
    pub data: Result<Vec<u64>, String>,
    /// Measured compute seconds on the worker.
    pub compute_secs: f64,
}

#[derive(Debug)]
pub enum ClusterError {
    /// A worker thread disconnected unexpectedly.
    WorkerLost(usize),
    /// Backend construction failed on a worker.
    Backend(String),
    /// Channel failure.
    Channel(&'static str),
    /// The OS refused to spawn a worker thread.
    Spawn(String),
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::WorkerLost(w) => write!(f, "worker {w} disconnected"),
            ClusterError::Backend(e) => write!(f, "backend: {e}"),
            ClusterError::Channel(what) => write!(f, "channel failure: {what}"),
            ClusterError::Spawn(e) => write!(f, "spawn worker thread: {e}"),
        }
    }
}

impl std::error::Error for ClusterError {}

struct WorkerHandle {
    tx: mpsc::Sender<ToWorker>,
    join: Option<JoinHandle<()>>,
}

/// Handle to N running workers.
pub struct Cluster {
    workers: Vec<WorkerHandle>,
    results_rx: mpsc::Receiver<StepResult>,
}

fn worker_main(
    spec: WorkerSpec,
    rx: mpsc::Receiver<ToWorker>,
    tx: mpsc::Sender<StepResult>,
    ready: mpsc::Sender<Result<(), String>>,
) {
    let backend = match WorkerBackend::create(
        spec.kind,
        &spec.artifact_dir,
        spec.field,
        spec.rows,
        spec.d,
        spec.coeffs.clone(),
        spec.par,
    ) {
        Ok(b) => {
            let _ = ready.send(Ok(()));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(e.to_string()));
            return;
        }
    };
    let mut x_share: Vec<u64> = Vec::new();
    let mut y_share: Option<Vec<u64>> = None;
    // A failed share-marshal poisons every subsequent step: the error is
    // carried into each StepResult rather than printed, so the master's
    // failure accounting (TrainReport::worker_failures) sees it.
    let mut data_error: Option<String> = None;
    let f = spec.field;
    while let Ok(msg) = rx.recv() {
        match msg {
            ToWorker::LoadData { x, y } => {
                x_share = x;
                y_share = y;
                // XLA backend: marshal the share once, off the hot path.
                data_error = backend
                    .prepare_data(&x_share)
                    .err()
                    .map(|e| format!("prepare_data: {e}"));
            }
            ToWorker::Step { iter, w } => {
                if spec.fail_from_iter.map(|from| iter >= from).unwrap_or(false) {
                    let _ = tx.send(StepResult {
                        worker: spec.id,
                        iter,
                        data: Err("injected fault".to_string()),
                        compute_secs: 0.0,
                    });
                    continue;
                }
                if let Some(e) = &data_error {
                    let _ = tx.send(StepResult {
                        worker: spec.id,
                        iter,
                        data: Err(e.clone()),
                        compute_secs: 0.0,
                    });
                    continue;
                }
                let (data, compute_secs) = timed(|| {
                    let data = match spec.op {
                        WorkerOp::Logistic => {
                            backend.compute(&x_share, &w).map_err(|e| e.to_string())
                        }
                        WorkerOp::Linear => Ok(linear_f(
                            &f,
                            &x_share,
                            &w,
                            y_share.as_deref(),
                            spec.rows,
                            spec.d,
                            spec.par,
                        )),
                    };
                    // A chaos-slowed worker sleeps inside the measured span
                    // so its compute time reflects the injected lag.
                    if spec.slow_ms > 0 {
                        std::thread::sleep(Duration::from_millis(spec.slow_ms));
                    }
                    data
                });
                if tx
                    .send(StepResult { worker: spec.id, iter, data, compute_secs })
                    .is_err()
                {
                    return; // master gone
                }
            }
            ToWorker::Shutdown => return,
        }
    }
}

/// Linear-regression worker computation: X̃ᵀ(X̃·w̃ − ỹ) over F_p
/// (Remark 1; native only — the logistic path is the artifact-backed one).
fn linear_f(
    f: &PrimeField,
    x: &[u64],
    w: &[u64],
    y: Option<&[u64]>,
    rows: usize,
    d: usize,
    par: Parallelism,
) -> Vec<u64> {
    use crate::compute::{matvec_mod_par, tr_matvec_mod_par};
    let xw = matvec_mod_par(f, x, w, rows, d, 1, 0, par);
    let resid: Vec<u64> = match y {
        Some(ys) => xw.iter().zip(ys.iter()).map(|(&a, &b)| f.sub(a, b)).collect(),
        None => xw,
    };
    tr_matvec_mod_par(f, x, &resid, rows, d, par)
}

impl Cluster {
    /// Spawn one thread per spec. Fails if any backend fails to build.
    pub fn spawn(specs: Vec<WorkerSpec>) -> Result<Self, ClusterError> {
        let (results_tx, results_rx) = mpsc::channel();
        let mut workers = Vec::with_capacity(specs.len());
        let mut readies = Vec::with_capacity(specs.len());
        for spec in specs {
            let (tx, rx) = mpsc::channel();
            let (ready_tx, ready_rx) = mpsc::channel();
            let rtx = results_tx.clone();
            let join = std::thread::Builder::new()
                .name(format!("worker-{}", spec.id))
                .spawn(move || worker_main(spec, rx, rtx, ready_tx))
                .map_err(|e| ClusterError::Spawn(e.to_string()))?;
            workers.push(WorkerHandle { tx, join: Some(join) });
            readies.push(ready_rx);
        }
        for (i, ready) in readies.iter().enumerate() {
            match ready.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => return Err(ClusterError::Backend(format!("worker {i}: {e}"))),
                Err(_) => return Err(ClusterError::WorkerLost(i)),
            }
        }
        Ok(Cluster { workers, results_rx })
    }

    pub fn n(&self) -> usize {
        self.workers.len()
    }

    /// Deliver coded dataset shares (index = worker id). `y_shares` only
    /// for the Linear op.
    pub fn load_data(
        &self,
        x_shares: Vec<Vec<u64>>,
        mut y_shares: Option<Vec<Vec<u64>>>,
    ) -> Result<(), ClusterError> {
        assert_eq!(x_shares.len(), self.workers.len());
        for (i, x) in x_shares.into_iter().enumerate() {
            let y = y_shares.as_mut().map(|ys| std::mem::take(&mut ys[i]));
            self.workers[i]
                .tx
                .send(ToWorker::LoadData { x, y })
                .map_err(|_| ClusterError::WorkerLost(i))?;
        }
        Ok(())
    }

    /// Send coded weights for iteration `iter` to every worker.
    pub fn dispatch(&self, iter: u64, w_shares: Vec<Vec<u64>>) -> Result<(), ClusterError> {
        assert_eq!(w_shares.len(), self.workers.len());
        for (i, w) in w_shares.into_iter().enumerate() {
            self.workers[i]
                .tx
                .send(ToWorker::Step { iter, w })
                .map_err(|_| ClusterError::WorkerLost(i))?;
        }
        Ok(())
    }

    /// Stream results for `iter` off the shared channel and return as soon
    /// as the fastest `need` usable ones have arrived — the master never
    /// waits for stragglers past the recovery threshold. Stale results
    /// from earlier iterations are drained (and counted on the returned
    /// [`Round`]) without blocking; failures are collected so the caller
    /// can tell "threshold unreachable" from "still in flight". Passing
    /// `need = n()` degenerates to a full collection.
    pub fn collect_first(&self, need: usize, iter: u64) -> Result<Round, ClusterError> {
        let (collected, wall_secs) = timed(|| -> Result<Round, ClusterError> {
            let mut round = Round::new(iter, need, self.workers.len());
            while !round.complete() {
                let res = self
                    .results_rx
                    .recv()
                    .map_err(|_| ClusterError::Channel("results"))?;
                round.absorb(res);
            }
            Ok(round)
        });
        let mut round = collected?;
        round.wall_secs = wall_secs;
        Ok(round)
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(ToWorker::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::WorkerComputation;
    use crate::field::{PrimeField, PAPER_PRIME};
    use std::time::Instant;

    fn specs(n: usize, rows: usize, d: usize, op: WorkerOp) -> Vec<WorkerSpec> {
        let f = PrimeField::new(PAPER_PRIME);
        (0..n)
            .map(|id| WorkerSpec {
                id,
                kind: BackendKind::Native,
                artifact_dir: PathBuf::from("artifacts"),
                field: f,
                rows,
                d,
                coeffs: vec![3, 7],
                op,
                fail_from_iter: None,
                slow_ms: 0,
                par: Parallelism::Serial,
            })
            .collect()
    }

    #[test]
    fn cluster_computes_logistic_on_all_workers() {
        let f = PrimeField::new(PAPER_PRIME);
        let (n, rows, d) = (4, 2, 3);
        let cluster = Cluster::spawn(specs(n, rows, d, WorkerOp::Logistic)).unwrap();
        let x_shares: Vec<Vec<u64>> = (0..n)
            .map(|i| (0..rows * d).map(|e| (i * 10 + e) as u64 % PAPER_PRIME).collect())
            .collect();
        cluster.load_data(x_shares.clone(), None).unwrap();
        let w = vec![2u64, 4, 6];
        cluster
            .dispatch(0, (0..n).map(|_| w.clone()).collect())
            .unwrap();
        let round = cluster.collect_first(n, 0).unwrap();
        assert!(round.ok());
        assert_eq!(round.late_drained, 0);
        let mut results = round.results;
        results.sort_by_key(|r| r.worker);
        assert_eq!(results.len(), n);
        let wc = WorkerComputation::new(f, rows, d, vec![3, 7]);
        for (i, res) in results.iter().enumerate() {
            assert_eq!(res.iter, 0);
            assert!(res.compute_secs >= 0.0);
            assert_eq!(res.data.as_ref().unwrap(), &wc.compute(&x_shares[i], &w));
        }
    }

    #[test]
    fn cluster_streams_multiple_iterations() {
        let n = 3;
        let cluster = Cluster::spawn(specs(n, 2, 2, WorkerOp::Logistic)).unwrap();
        cluster
            .load_data(vec![vec![1, 2, 3, 4]; n], None)
            .unwrap();
        for iter in 0..5u64 {
            cluster
                .dispatch(iter, vec![vec![iter + 1, iter + 2]; n])
                .unwrap();
            let round = cluster.collect_first(n, iter).unwrap();
            assert_eq!(round.results.len(), n);
            assert!(round.results.iter().all(|r| r.iter == iter));
        }
    }

    #[test]
    fn early_exit_leaves_slow_worker_behind_without_deadlock() {
        // Worker 2 sleeps 60 ms per step; the master collects the fastest
        // 2-of-3 each iteration and must never block on it. Its stale
        // results surface as late drains once they do arrive.
        let mut s = specs(3, 2, 2, WorkerOp::Logistic);
        s[2].slow_ms = 60;
        let cluster = Cluster::spawn(s).unwrap();
        cluster.load_data(vec![vec![1, 2, 3, 4]; 3], None).unwrap();

        cluster.dispatch(0, vec![vec![1, 2]; 3]).unwrap();
        let t0 = Instant::now();
        let round0 = cluster.collect_first(2, 0).unwrap();
        assert!(round0.ok());
        assert!(
            t0.elapsed() < Duration::from_millis(50),
            "early exit must not wait for the slow worker"
        );
        assert!(round0.results.iter().all(|r| r.worker != 2));

        // Let the slow iter-0 result land, then run the next iteration:
        // it must be drained as late, not decoded into iteration 1.
        std::thread::sleep(Duration::from_millis(150));
        cluster.dispatch(1, vec![vec![3, 4]; 3]).unwrap();
        let round1 = cluster.collect_first(2, 1).unwrap();
        assert!(round1.ok());
        assert_eq!(round1.late_drained, 1, "slow iter-0 result drained");
        assert!(round1.results.iter().all(|r| r.iter == 1));
    }

    #[test]
    fn collect_first_full_need_equals_full_collection() {
        let n = 4;
        let cluster = Cluster::spawn(specs(n, 2, 2, WorkerOp::Logistic)).unwrap();
        cluster.load_data(vec![vec![1, 2, 3, 4]; n], None).unwrap();
        cluster.dispatch(0, vec![vec![5, 6]; n]).unwrap();
        let round = cluster.collect_first(n, 0).unwrap();
        let mut workers: Vec<usize> = round.results.iter().map(|r| r.worker).collect();
        workers.sort_unstable();
        assert_eq!(workers, vec![0, 1, 2, 3]);
    }

    #[test]
    fn linear_op_computes_residual_gradient() {
        let f = PrimeField::new(PAPER_PRIME);
        let (rows, d) = (2, 2);
        let cluster = Cluster::spawn(specs(1, rows, d, WorkerOp::Linear)).unwrap();
        let x = vec![1u64, 2, 3, 4];
        let y = vec![5u64, 6];
        cluster
            .load_data(vec![x.clone()], Some(vec![y.clone()]))
            .unwrap();
        cluster.dispatch(0, vec![vec![1, 1]]).unwrap();
        let round = cluster.collect_first(1, 0).unwrap();
        let got = round.results[0].data.as_ref().unwrap().clone();
        // Xw = [3, 7]; resid = [-2, 1]; Xᵀresid = [1·-2+3·1, 2·-2+4·1] = [1, 0]
        assert_eq!(got, vec![f.from_i64(1), f.from_i64(0)]);
    }

    #[test]
    fn xla_backend_failure_surfaces_at_spawn() {
        let mut s = specs(2, 2, 3, WorkerOp::Logistic);
        for spec in s.iter_mut() {
            spec.kind = BackendKind::Xla;
            spec.artifact_dir = PathBuf::from("/definitely/not/here");
        }
        match Cluster::spawn(s) {
            Err(ClusterError::Backend(_)) => {}
            Err(other) => panic!("wrong error: {other:?}"),
            Ok(_) => panic!("spawn should fail"),
        }
    }
}
