//! The worker engine and the transport-generic cluster handle.
//!
//! [`WorkerEngine`] is the compute side of one worker — it owns the
//! backend (constructed where the worker runs; the XLA runtime is
//! thread-local by design) and the coded data share, mirroring the
//! paper's protocol where X̃_i is sent once and W̃_i^(t) every iteration.
//! The engine is transport-agnostic: the in-memory backend runs it on a
//! thread fed by a channel, the TCP backend runs it in a separate
//! `codedml --worker` process fed by socket frames
//! ([`super::transport`]).
//!
//! [`Cluster`] is the master-side handle: it drives a
//! [`Transport`] and keeps per-worker *down* state so a lost worker
//! becomes per-round failures (counted by the session into
//! `TrainReport::worker_failures`) instead of an abort.

use std::collections::{HashMap, VecDeque};
use std::time::Duration;

use super::round::Round;
use super::transport::{
    ChannelTransport, TcpTransport, Transport, TransportConfig, TransportEvent, TransportKind,
};
use crate::field::PrimeField;
use crate::runtime::{BackendKind, WorkerBackend};
use crate::util::par::Parallelism;
use crate::util::timer::{timed, Deadline};
use std::path::PathBuf;

/// What the worker computes each step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerOp {
    /// Logistic: f = X̃ᵀ ḡ(X̃, W̃) with the polynomial coefficients.
    Logistic,
    /// Linear (Remark 1): f = X̃ᵀ (X̃·w̃ − ỹ) — needs the coded labels.
    Linear,
}

/// `Send`-able recipe for building a worker. For the TCP backend this is
/// what the Hello frame carries (in primitive form; see
/// [`super::transport::frame::HelloSpec`]).
#[derive(Debug, Clone)]
pub struct WorkerSpec {
    pub id: usize,
    /// Owning session (0 for a dedicated single-job cluster). The engine
    /// stamps it into every [`StepResult`] so the master can route
    /// interleaved rounds from concurrent sessions without mixing them.
    pub session: u64,
    pub kind: BackendKind,
    pub artifact_dir: PathBuf,
    pub field: PrimeField,
    /// Coded block height m/K.
    pub rows: usize,
    pub d: usize,
    /// Field-quantized sigmoid coefficients (len r+1); ignored for Linear.
    pub coeffs: Vec<u64>,
    pub op: WorkerOp,
    /// Chaos hook: fail every step with iter ≥ this (crash-style fault
    /// injection for resilience tests; None = healthy).
    pub fail_from_iter: Option<u64>,
    /// Chaos hook: extra sleep per step (a permanently slow machine).
    /// The streaming round engine leaves such a worker behind — its
    /// results arrive late and are drained, never decoded.
    pub slow_ms: u64,
    /// Intra-worker thread budget for the native matmul kernels (results
    /// are bit-exact at any setting; see [`crate::util::par`]).
    pub par: Parallelism,
}

/// A worker's per-step result.
#[derive(Debug, Clone, PartialEq)]
pub struct StepResult {
    pub worker: usize,
    /// Session this result belongs to. Routing rejects mismatches: a
    /// result is only absorbed into a round with the same session id.
    pub session: u64,
    pub iter: u64,
    /// f(X̃_i, W̃_i) — or an error message if the backend failed.
    pub data: Result<Vec<u64>, String>,
    /// Measured compute seconds on the worker.
    pub compute_secs: f64,
}

#[derive(Debug)]
pub enum ClusterError {
    /// A worker disconnected unexpectedly.
    WorkerLost(usize),
    /// Backend construction failed on a worker.
    Backend(String),
    /// Channel failure.
    Channel(&'static str),
    /// The OS refused to spawn a worker thread.
    Spawn(String),
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::WorkerLost(w) => write!(f, "worker {w} disconnected"),
            ClusterError::Backend(e) => write!(f, "backend: {e}"),
            ClusterError::Channel(what) => write!(f, "channel failure: {what}"),
            ClusterError::Spawn(e) => write!(f, "spawn worker thread: {e}"),
        }
    }
}

impl std::error::Error for ClusterError {}

/// One worker's compute state: backend + coded share + chaos hooks.
///
/// Lives wherever the transport puts the worker (thread or process) and
/// is driven by exactly three operations: build, load, step.
pub struct WorkerEngine {
    id: usize,
    session: u64,
    op: WorkerOp,
    field: PrimeField,
    rows: usize,
    d: usize,
    par: Parallelism,
    fail_from_iter: Option<u64>,
    slow_ms: u64,
    backend: WorkerBackend,
    x_share: Vec<u64>,
    y_share: Option<Vec<u64>>,
    /// A failed share-marshal poisons every subsequent step: the error is
    /// carried into each StepResult rather than printed, so the master's
    /// failure accounting (TrainReport::worker_failures) sees it.
    data_error: Option<String>,
}

impl WorkerEngine {
    /// Build the backend for `spec`. The error string travels back to the
    /// master over the transport's ready/Ready handshake.
    pub fn new(spec: WorkerSpec) -> Result<Self, String> {
        let backend = WorkerBackend::create(
            spec.kind,
            &spec.artifact_dir,
            spec.field,
            spec.rows,
            spec.d,
            spec.coeffs.clone(),
            spec.par,
        )
        .map_err(|e| e.to_string())?;
        Ok(WorkerEngine {
            id: spec.id,
            session: spec.session,
            op: spec.op,
            field: spec.field,
            rows: spec.rows,
            d: spec.d,
            par: spec.par,
            fail_from_iter: spec.fail_from_iter,
            slow_ms: spec.slow_ms,
            backend,
            x_share: Vec::new(),
            y_share: None,
            data_error: None,
        })
    }

    /// One-time delivery of the coded dataset share (labels only for
    /// Linear).
    pub fn load(&mut self, x: Vec<u64>, y: Option<Vec<u64>>) {
        self.x_share = x;
        self.y_share = y;
        // XLA backend: marshal the share once, off the hot path.
        self.data_error = self
            .backend
            .prepare_data(&self.x_share)
            .err()
            .map(|e| format!("prepare_data: {e}"));
    }

    /// Compute one step. Infallible by construction: every failure mode
    /// (chaos injection, poisoned data, backend error) is carried inside
    /// the [`StepResult`] so the master's round accounting sees it.
    pub fn step(&self, iter: u64, w: &[u64]) -> StepResult {
        if self.fail_from_iter.map(|from| iter >= from).unwrap_or(false) {
            return StepResult {
                worker: self.id,
                session: self.session,
                iter,
                data: Err("injected fault".to_string()),
                compute_secs: 0.0,
            };
        }
        if let Some(e) = &self.data_error {
            return StepResult {
                worker: self.id,
                session: self.session,
                iter,
                data: Err(e.clone()),
                compute_secs: 0.0,
            };
        }
        let (data, compute_secs) = timed(|| {
            let data = match self.op {
                WorkerOp::Logistic => self
                    .backend
                    .compute(&self.x_share, w)
                    .map_err(|e| e.to_string()),
                WorkerOp::Linear => Ok(linear_f(
                    &self.field,
                    &self.x_share,
                    w,
                    self.y_share.as_deref(),
                    self.rows,
                    self.d,
                    self.par,
                )),
            };
            // A chaos-slowed worker sleeps inside the measured span so its
            // compute time reflects the injected lag.
            if self.slow_ms > 0 {
                std::thread::sleep(Duration::from_millis(self.slow_ms));
            }
            data
        });
        StepResult { worker: self.id, session: self.session, iter, data, compute_secs }
    }

    /// Session this engine computes for.
    pub fn session(&self) -> u64 {
        self.session
    }
}

/// Linear-regression worker computation: X̃ᵀ(X̃·w̃ − ỹ) over F_p
/// (Remark 1; native only — the logistic path is the artifact-backed one).
fn linear_f(
    f: &PrimeField,
    x: &[u64],
    w: &[u64],
    y: Option<&[u64]>,
    rows: usize,
    d: usize,
    par: Parallelism,
) -> Vec<u64> {
    use crate::compute::{matvec_mod_par, tr_matvec_mod_par};
    let xw = matvec_mod_par(f, x, w, rows, d, 1, 0, par);
    let resid: Vec<u64> = match y {
        Some(ys) => xw.iter().zip(ys.iter()).map(|(&a, &b)| f.sub(a, b)).collect(),
        None => xw,
    };
    tr_matvec_mod_par(f, x, &resid, rows, d, par)
}

/// Handle to N workers behind a [`Transport`].
///
/// The cluster tracks which workers are *down* (unreachable at connect,
/// or lost mid-training). A down worker is skipped on sends and counted
/// as one failure per round in [`Cluster::collect_first`] — training
/// survives as long as the fastest-R threshold stays reachable.
pub struct Cluster {
    transport: Box<dyn Transport>,
    /// `Some(reason)` once worker i is unreachable for good.
    down: Vec<Option<String>>,
    /// Session-scoped routing: results that arrive for a *registered*
    /// session other than the round being collected are parked here and
    /// drained first on that session's next collect. Key presence is the
    /// registration; a dedicated cluster registers only session 0.
    pending: HashMap<u64, VecDeque<StepResult>>,
    /// Results whose session id matched no registered session: rejected,
    /// never decoded, counted here (and on the round that saw them).
    misrouted: u64,
    /// Per-session worker span: session s drives workers `0..widths[s]`
    /// of the shared pool. Absent means the full pool — the dedicated
    /// single-session case and any serve job as wide as the pool.
    widths: HashMap<u64, usize>,
}

impl Cluster {
    /// Spawn the default in-memory backend: one thread per spec. Fails if
    /// any backend fails to build.
    pub fn spawn(specs: Vec<WorkerSpec>) -> Result<Self, ClusterError> {
        Cluster::connect(specs, &TransportConfig::default())
    }

    /// Build a cluster on the configured transport. Memory spawns threads
    /// in-process; TCP connects to already-running `codedml --worker`
    /// processes at `cfg.tcp.workers[i]` (worker i), marking unreachable
    /// ones down rather than failing the build.
    pub fn connect(specs: Vec<WorkerSpec>, cfg: &TransportConfig) -> Result<Self, ClusterError> {
        match cfg.kind {
            TransportKind::Memory => {
                let n = specs.len();
                let session = specs.first().map(|s| s.session).unwrap_or(0);
                let transport = ChannelTransport::spawn(specs)?;
                Ok(Cluster::wrap(Box::new(transport), vec![None; n], session))
            }
            TransportKind::Tcp => {
                if cfg.tcp.workers.len() != specs.len() {
                    return Err(ClusterError::Backend(format!(
                        "tcp transport needs {} worker addresses, got {}",
                        specs.len(),
                        cfg.tcp.workers.len()
                    )));
                }
                let session = specs.first().map(|s| s.session).unwrap_or(0);
                let (transport, down) = TcpTransport::connect(&specs, &cfg.tcp)?;
                Ok(Cluster::wrap(Box::new(transport), down, session))
            }
        }
    }

    fn wrap(transport: Box<dyn Transport>, down: Vec<Option<String>>, session: u64) -> Self {
        let mut pending = HashMap::new();
        pending.insert(session, VecDeque::new());
        Cluster { transport, down, pending, misrouted: 0, widths: HashMap::new() }
    }

    /// Register an additional session id with the routing table. Results
    /// carrying a registered session are buffered across interleaved
    /// collects instead of rejected. The session of the specs the cluster
    /// was built with is registered implicitly.
    pub fn register_session(&mut self, session: u64) {
        self.pending.entry(session).or_default();
    }

    /// Total results rejected because their session id matched no
    /// registered session.
    pub fn misrouted(&self) -> u64 {
        self.misrouted
    }

    /// Declare that `session` drives only the first `workers` workers of
    /// the pool. Its dispatch/load calls then take exactly that many
    /// shares, its rounds expect that many answers, and deaths outside
    /// the span are never charged to it. Unset sessions span the pool.
    pub fn set_session_workers(&mut self, session: u64, workers: usize) {
        assert!(
            workers >= 1 && workers <= self.transport.n(),
            "session {session} wants {workers} workers from a pool of {}",
            self.transport.n()
        );
        self.widths.insert(session, workers);
    }

    /// Worker span of `session` (pool-wide when never narrowed).
    fn width(&self, session: u64) -> usize {
        self.widths.get(&session).copied().unwrap_or(self.transport.n())
    }

    /// Build an engine for `spec`'s session on an already-connected
    /// worker (the serve scheduler's way of sharing one pool between
    /// jobs). A send failure marks the worker down.
    pub fn attach_worker(&mut self, spec: &WorkerSpec) -> Result<(), String> {
        let w = spec.id;
        if let Some(e) = &self.down[w] {
            return Err(format!("worker down: {e}"));
        }
        if let Err(e) = self.transport.send_attach(w, spec) {
            self.down[w] = Some(e.clone());
            return Err(e);
        }
        Ok(())
    }

    /// Ship one session's coded data share to one worker (serve-side
    /// sibling of [`Cluster::revive`]'s re-ship, used after
    /// [`Cluster::attach_worker`]).
    pub fn load_worker(
        &mut self,
        worker: usize,
        session: u64,
        x: Vec<u64>,
        y: Option<Vec<u64>>,
    ) -> Result<(), String> {
        if let Some(e) = &self.down[worker] {
            return Err(format!("worker down: {e}"));
        }
        if let Err(e) = self.transport.send_load(worker, session, x, y) {
            self.down[worker] = Some(e.clone());
            return Err(e);
        }
        Ok(())
    }

    pub fn n(&self) -> usize {
        self.transport.n()
    }

    /// Transport backend name ("memory" / "tcp") for traces and benches.
    pub fn transport_name(&self) -> &'static str {
        self.transport.name()
    }

    /// Cumulative `(sent, received)` wire bytes. Both backends count in
    /// frame-layout units, so the numbers are comparable across
    /// transports.
    pub fn wire_bytes(&self) -> (u64, u64) {
        self.transport.bytes()
    }

    /// Workers currently marked down, with reasons.
    pub fn down_workers(&self) -> Vec<(usize, String)> {
        self.down
            .iter()
            .enumerate()
            .filter_map(|(i, e)| e.as_ref().map(|e| (i, e.clone())))
            .collect()
    }

    /// Deliver coded dataset shares (index = worker id). `y_shares` only
    /// for the Linear op. A send failure marks that worker down; it will
    /// be counted failed each round.
    pub fn load_data(
        &mut self,
        x_shares: Vec<Vec<u64>>,
        y_shares: Option<Vec<Vec<u64>>>,
    ) -> Result<(), ClusterError> {
        self.load_data_for(0, x_shares, y_shares)
    }

    /// [`Cluster::load_data`] addressed to one session's engines.
    pub fn load_data_for(
        &mut self,
        session: u64,
        x_shares: Vec<Vec<u64>>,
        mut y_shares: Option<Vec<Vec<u64>>>,
    ) -> Result<(), ClusterError> {
        assert_eq!(x_shares.len(), self.width(session));
        for (i, x) in x_shares.into_iter().enumerate() {
            if self.down[i].is_some() {
                continue;
            }
            let y = y_shares.as_mut().map(|ys| std::mem::take(&mut ys[i]));
            if let Err(e) = self.transport.send_load(i, session, x, y) {
                self.down[i] = Some(e);
            }
        }
        Ok(())
    }

    /// Send coded weights for iteration `iter` to every live worker.
    pub fn dispatch(&mut self, iter: u64, w_shares: Vec<Vec<u64>>) -> Result<(), ClusterError> {
        self.dispatch_for(0, iter, w_shares)
    }

    /// [`Cluster::dispatch`] addressed to one session's engines.
    pub fn dispatch_for(
        &mut self,
        session: u64,
        iter: u64,
        w_shares: Vec<Vec<u64>>,
    ) -> Result<(), ClusterError> {
        assert_eq!(w_shares.len(), self.width(session));
        for (i, w) in w_shares.into_iter().enumerate() {
            if self.down[i].is_some() {
                continue;
            }
            if let Err(e) = self.transport.send_step(i, session, iter, w) {
                self.down[i] = Some(e);
            }
        }
        Ok(())
    }

    /// Stream results for `iter` off the transport and return as soon as
    /// the fastest `need` usable ones have arrived — the master never
    /// waits for stragglers past the recovery threshold. Stale results
    /// from earlier iterations are drained (and counted on the returned
    /// [`Round`]) without blocking; failures are collected so the caller
    /// can tell "threshold unreachable" from "still in flight". Workers
    /// already down contribute one failure up front, and a transport
    /// `Down` event mid-round converts to a failure the same way — so
    /// `collect_first` terminates (never deadlocks) whenever every live
    /// worker eventually answers or dies. Passing `need = n()` degenerates
    /// to a full collection.
    pub fn collect_first(&mut self, need: usize, iter: u64) -> Result<Round, ClusterError> {
        self.collect_deadline(need, iter, &Deadline::none())
    }

    /// [`Cluster::collect_first`] scoped to one session's results.
    pub fn collect_first_for(
        &mut self,
        session: u64,
        need: usize,
        iter: u64,
    ) -> Result<Round, ClusterError> {
        self.collect_deadline_for(session, need, iter, &Deadline::none())
    }

    /// [`Cluster::collect_first`] with a wall-clock budget: when `deadline`
    /// expires first, every still-outstanding worker is charged a
    /// synthesized `"round deadline expired"` failure, the round's
    /// `deadline_expired` flag is set, and the (now complete) round is
    /// returned — a silently-stalled worker becomes a counted failure
    /// instead of a master hang. [`Deadline::none`] restores the
    /// unbounded behavior exactly.
    pub fn collect_deadline(
        &mut self,
        need: usize,
        iter: u64,
        deadline: &Deadline,
    ) -> Result<Round, ClusterError> {
        self.collect_deadline_for(0, need, iter, deadline)
    }

    /// [`Cluster::collect_deadline`] scoped to one session: only results
    /// stamped with `session` enter the round; results for other
    /// registered sessions are parked (drained on their own collect), and
    /// unknown session ids are rejected and counted.
    pub fn collect_deadline_for(
        &mut self,
        session: u64,
        need: usize,
        iter: u64,
        deadline: &Deadline,
    ) -> Result<Round, ClusterError> {
        let n = self.width(session);
        let mut round = Round::for_session(session, iter, need, n);
        for w in 0..n {
            if let Some(e) = &self.down[w] {
                round.absorb(StepResult {
                    worker: w,
                    session,
                    iter,
                    data: Err(format!("worker down: {e}")),
                    compute_secs: 0.0,
                });
            }
        }
        self.collect_resume(&mut round, deadline)?;
        Ok(round)
    }

    /// Continue collecting into an existing round until it completes or
    /// `deadline` expires. Used for the initial collection and again by
    /// the supervisor after it heals failures mid-round (revive +
    /// re-dispatch): healed workers reopen the round, and this waits for
    /// their replacement results. Wall time accumulates across resumes.
    pub fn collect_resume(
        &mut self,
        round: &mut Round,
        deadline: &Deadline,
    ) -> Result<(), ClusterError> {
        let (res, wall_secs) = timed(|| -> Result<(), ClusterError> {
            // Results for this session that arrived while another
            // session's round was being collected were parked — they are
            // the oldest traffic, so feed them in first.
            if let Some(buf) = self.pending.get_mut(&round.session) {
                while !round.complete() {
                    match buf.pop_front() {
                        Some(res) => round.absorb(res),
                        None => break,
                    }
                }
            }
            while !round.complete() {
                match self.transport.recv_deadline(deadline)? {
                    Some(TransportEvent::Result(res)) => {
                        if res.session == round.session {
                            round.absorb(res);
                        } else if let Some(buf) = self.pending.get_mut(&res.session) {
                            buf.push_back(res);
                        } else {
                            // Unknown session id: reject, never decode.
                            self.misrouted += 1;
                            round.misrouted += 1;
                        }
                    }
                    Some(TransportEvent::Down { worker, error }) => {
                        // First notice of this death: count it against the
                        // current round — unless the dead worker sits
                        // outside this session's span, in which case only
                        // the down mark is set and the sessions that do
                        // drive it get charged via their own up-front down
                        // scans. (Subsequent rounds of *this* session
                        // charge in-span deaths the same way.)
                        if self.down[worker].is_none() {
                            self.down[worker] = Some(error.clone());
                            if worker < self.width(round.session) {
                                round.absorb(StepResult {
                                    worker,
                                    session: round.session,
                                    iter: round.iter,
                                    data: Err(format!("worker down: {error}")),
                                    compute_secs: 0.0,
                                });
                            }
                        }
                    }
                    None => {
                        // Deadline expired. Charge every outstanding worker
                        // one synthesized failure so the round completes
                        // and the caller can decide: heal, degrade to
                        // approximate decode, or abort.
                        round.deadline_expired = true;
                        for w in self.outstanding(round) {
                            round.absorb(StepResult {
                                worker: w,
                                session: round.session,
                                iter: round.iter,
                                data: Err("round deadline expired".to_string()),
                                compute_secs: 0.0,
                            });
                        }
                        return Ok(());
                    }
                }
            }
            Ok(())
        });
        round.wall_secs += wall_secs;
        res
    }

    /// Workers of the round's session span with no entry yet in this
    /// round's accounting (no result, no live failure, no healed failure).
    fn outstanding(&self, round: &Round) -> Vec<usize> {
        let n = self.width(round.session);
        let mut seen = vec![false; n];
        for r in &round.results {
            if r.worker < n {
                seen[r.worker] = true;
            }
        }
        for (w, _) in round.failures.iter().chain(round.healed.iter()) {
            if *w < n {
                seen[*w] = true;
            }
        }
        (0..n).filter(|&w| !seen[w]).collect()
    }

    /// Re-admit a down (or stalled) worker: reconnect its transport slot,
    /// clear the down mark, and re-ship its coded data share. On failure
    /// the worker stays down and the error says why — the supervisor may
    /// retry on a later round.
    pub fn revive(
        &mut self,
        spec: &WorkerSpec,
        x: Vec<u64>,
        y: Option<Vec<u64>>,
    ) -> Result<(), String> {
        let w = spec.id;
        assert!(w < self.down.len(), "worker id {w} out of range");
        self.transport.reconnect(spec)?;
        self.down[w] = None;
        if let Err(e) = self.transport.send_load(w, spec.session, x, y) {
            self.down[w] = Some(e.clone());
            return Err(e);
        }
        Ok(())
    }

    /// Send iteration `iter`'s coded weights to one worker (used to bring
    /// a freshly revived worker into the current round). A send failure
    /// re-marks it down.
    pub fn dispatch_to(&mut self, worker: usize, iter: u64, w: Vec<u64>) -> Result<(), String> {
        self.dispatch_to_for(0, worker, iter, w)
    }

    /// [`Cluster::dispatch_to`] addressed to one session's engine.
    pub fn dispatch_to_for(
        &mut self,
        session: u64,
        worker: usize,
        iter: u64,
        w: Vec<u64>,
    ) -> Result<(), String> {
        if let Some(e) = &self.down[worker] {
            return Err(format!("worker down: {e}"));
        }
        if let Err(e) = self.transport.send_step(worker, session, iter, w) {
            self.down[worker] = Some(e.clone());
            return Err(e);
        }
        Ok(())
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.transport.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::WorkerComputation;
    use crate::field::{PrimeField, PAPER_PRIME};
    use std::time::Instant;

    fn specs(n: usize, rows: usize, d: usize, op: WorkerOp) -> Vec<WorkerSpec> {
        let f = PrimeField::new(PAPER_PRIME);
        (0..n)
            .map(|id| WorkerSpec {
                id,
                session: 0,
                kind: BackendKind::Native,
                artifact_dir: PathBuf::from("artifacts"),
                field: f,
                rows,
                d,
                coeffs: vec![3, 7],
                op,
                fail_from_iter: None,
                slow_ms: 0,
                par: Parallelism::Serial,
            })
            .collect()
    }

    #[test]
    fn cluster_computes_logistic_on_all_workers() {
        let f = PrimeField::new(PAPER_PRIME);
        let (n, rows, d) = (4, 2, 3);
        let mut cluster = Cluster::spawn(specs(n, rows, d, WorkerOp::Logistic)).unwrap();
        let x_shares: Vec<Vec<u64>> = (0..n)
            .map(|i| (0..rows * d).map(|e| (i * 10 + e) as u64 % PAPER_PRIME).collect())
            .collect();
        cluster.load_data(x_shares.clone(), None).unwrap();
        let w = vec![2u64, 4, 6];
        cluster
            .dispatch(0, (0..n).map(|_| w.clone()).collect())
            .unwrap();
        let round = cluster.collect_first(n, 0).unwrap();
        assert!(round.ok());
        assert_eq!(round.late_drained, 0);
        let mut results = round.results;
        results.sort_by_key(|r| r.worker);
        assert_eq!(results.len(), n);
        let wc = WorkerComputation::new(f, rows, d, vec![3, 7]);
        for (i, res) in results.iter().enumerate() {
            assert_eq!(res.iter, 0);
            assert!(res.compute_secs >= 0.0);
            assert_eq!(res.data.as_ref().unwrap(), &wc.compute(&x_shares[i], &w));
        }
        let (sent, received) = cluster.wire_bytes();
        assert!(sent > 0, "load + dispatch must be charged");
        assert!(received > 0, "collected results must be charged");
    }

    #[test]
    fn cluster_streams_multiple_iterations() {
        let n = 3;
        let mut cluster = Cluster::spawn(specs(n, 2, 2, WorkerOp::Logistic)).unwrap();
        cluster
            .load_data(vec![vec![1, 2, 3, 4]; n], None)
            .unwrap();
        for iter in 0..5u64 {
            cluster
                .dispatch(iter, vec![vec![iter + 1, iter + 2]; n])
                .unwrap();
            let round = cluster.collect_first(n, iter).unwrap();
            assert_eq!(round.results.len(), n);
            assert!(round.results.iter().all(|r| r.iter == iter));
        }
    }

    #[test]
    fn two_sessions_share_one_pool_without_crossing() {
        // Sessions 0 and 9 run interleaved rounds over the same two
        // workers. Collecting session 9 first forces session-0 results to
        // be parked and drained later — values must never cross.
        let f = PrimeField::new(PAPER_PRIME);
        let base = specs(2, 2, 2, WorkerOp::Logistic);
        let mut cluster = Cluster::spawn(base.clone()).unwrap();
        cluster.register_session(9);
        for spec in &base {
            let mut other = spec.clone();
            other.session = 9;
            cluster.attach_worker(&other).unwrap();
        }
        cluster.load_data_for(0, vec![vec![1, 2, 3, 4]; 2], None).unwrap();
        cluster.load_data_for(9, vec![vec![5, 6, 7, 8]; 2], None).unwrap();
        let wc = WorkerComputation::new(f, 2, 2, vec![3, 7]);
        let want0 = wc.compute(&[1, 2, 3, 4], &[1, 2]);
        let want9 = wc.compute(&[5, 6, 7, 8], &[3, 4]);
        for iter in 0..3u64 {
            cluster.dispatch_for(0, iter, vec![vec![1, 2]; 2]).unwrap();
            cluster.dispatch_for(9, iter, vec![vec![3, 4]; 2]).unwrap();
            let r9 = cluster.collect_first_for(9, 2, iter).unwrap();
            assert!(r9.ok(), "{:?}", r9.failures);
            for r in &r9.results {
                assert_eq!(r.session, 9);
                assert_eq!(r.data.as_ref().unwrap(), &want9);
            }
            let r0 = cluster.collect_first_for(0, 2, iter).unwrap();
            assert!(r0.ok(), "{:?}", r0.failures);
            for r in &r0.results {
                assert_eq!(r.session, 0);
                assert_eq!(r.data.as_ref().unwrap(), &want0);
            }
            assert_eq!(r0.misrouted + r9.misrouted, 0);
        }
        assert_eq!(cluster.misrouted(), 0);
    }

    #[test]
    fn early_exit_leaves_slow_worker_behind_without_deadlock() {
        // Worker 2 sleeps 60 ms per step; the master collects the fastest
        // 2-of-3 each iteration and must never block on it. Its stale
        // results surface as late drains once they do arrive.
        let mut s = specs(3, 2, 2, WorkerOp::Logistic);
        s[2].slow_ms = 60;
        let mut cluster = Cluster::spawn(s).unwrap();
        cluster.load_data(vec![vec![1, 2, 3, 4]; 3], None).unwrap();

        cluster.dispatch(0, vec![vec![1, 2]; 3]).unwrap();
        let t0 = Instant::now();
        let round0 = cluster.collect_first(2, 0).unwrap();
        assert!(round0.ok());
        assert!(
            t0.elapsed() < Duration::from_millis(50),
            "early exit must not wait for the slow worker"
        );
        assert!(round0.results.iter().all(|r| r.worker != 2));

        // Let the slow iter-0 result land, then run the next iteration:
        // it must be drained as late, not decoded into iteration 1.
        std::thread::sleep(Duration::from_millis(150));
        cluster.dispatch(1, vec![vec![3, 4]; 3]).unwrap();
        let round1 = cluster.collect_first(2, 1).unwrap();
        assert!(round1.ok());
        assert_eq!(round1.late_drained, 1, "slow iter-0 result drained");
        assert!(round1.results.iter().all(|r| r.iter == 1));
    }

    #[test]
    fn collect_first_full_need_equals_full_collection() {
        let n = 4;
        let mut cluster = Cluster::spawn(specs(n, 2, 2, WorkerOp::Logistic)).unwrap();
        cluster.load_data(vec![vec![1, 2, 3, 4]; n], None).unwrap();
        cluster.dispatch(0, vec![vec![5, 6]; n]).unwrap();
        let round = cluster.collect_first(n, 0).unwrap();
        let mut workers: Vec<usize> = round.results.iter().map(|r| r.worker).collect();
        workers.sort_unstable();
        assert_eq!(workers, vec![0, 1, 2, 3]);
    }

    #[test]
    fn linear_op_computes_residual_gradient() {
        let f = PrimeField::new(PAPER_PRIME);
        let (rows, d) = (2, 2);
        let mut cluster = Cluster::spawn(specs(1, rows, d, WorkerOp::Linear)).unwrap();
        let x = vec![1u64, 2, 3, 4];
        let y = vec![5u64, 6];
        cluster
            .load_data(vec![x.clone()], Some(vec![y.clone()]))
            .unwrap();
        cluster.dispatch(0, vec![vec![1, 1]]).unwrap();
        let round = cluster.collect_first(1, 0).unwrap();
        let got = round.results[0].data.as_ref().unwrap().clone();
        // Xw = [3, 7]; resid = [-2, 1]; Xᵀresid = [1·-2+3·1, 2·-2+4·1] = [1, 0]
        assert_eq!(got, vec![f.from_i64(1), f.from_i64(0)]);
    }

    #[test]
    fn collect_deadline_turns_stalled_worker_into_failure() {
        // Worker 1 sleeps 500 ms per step; a 100 ms round deadline must
        // convert it into a counted failure instead of a hang.
        let mut s = specs(2, 2, 2, WorkerOp::Logistic);
        s[1].slow_ms = 500;
        let mut cluster = Cluster::spawn(s).unwrap();
        cluster.load_data(vec![vec![1, 2, 3, 4]; 2], None).unwrap();
        cluster.dispatch(0, vec![vec![1, 2]; 2]).unwrap();
        let round = cluster
            .collect_deadline(2, 0, &Deadline::after_ms(100))
            .unwrap();
        assert!(round.deadline_expired);
        assert!(round.complete() && !round.ok());
        assert_eq!(round.results.len(), 1);
        assert_eq!(round.failures.len(), 1);
        assert_eq!(round.failures[0].0, 1);
        assert!(round.failures[0].1.contains("deadline"), "{:?}", round.failures);
    }

    #[test]
    fn revive_respawns_inmemory_worker_and_it_rejoins() {
        let mut s = specs(2, 2, 2, WorkerOp::Logistic);
        s[1].fail_from_iter = Some(0); // fails every step from the start
        let mut cluster = Cluster::spawn(s.clone()).unwrap();
        cluster.load_data(vec![vec![1, 2, 3, 4]; 2], None).unwrap();
        cluster.dispatch(0, vec![vec![1, 2]; 2]).unwrap();
        let round = cluster.collect_first(2, 0).unwrap();
        assert!(!round.ok(), "chaos worker must fail the full collection");

        // Supervisor-style heal: replacement spec without the chaos hook,
        // share re-shipped, and the worker answers from the next dispatch.
        let mut healthy = s[1].clone();
        healthy.fail_from_iter = None;
        cluster.revive(&healthy, vec![1, 2, 3, 4], None).unwrap();
        cluster.dispatch(1, vec![vec![1, 2]; 2]).unwrap();
        let round1 = cluster.collect_first(2, 1).unwrap();
        assert!(round1.ok(), "revived worker rejoins: {:?}", round1.failures);
        let mut workers: Vec<usize> = round1.results.iter().map(|r| r.worker).collect();
        workers.sort_unstable();
        assert_eq!(workers, vec![0, 1]);
    }

    #[test]
    fn xla_backend_failure_surfaces_at_spawn() {
        let mut s = specs(2, 2, 3, WorkerOp::Logistic);
        for spec in s.iter_mut() {
            spec.kind = BackendKind::Xla;
            spec.artifact_dir = PathBuf::from("/definitely/not/here");
        }
        match Cluster::spawn(s) {
            Err(ClusterError::Backend(_)) => {}
            Err(other) => panic!("wrong error: {other:?}"),
            Ok(_) => panic!("spawn should fail"),
        }
    }

    #[test]
    fn connect_rejects_mismatched_tcp_address_count() {
        use crate::cluster::transport::{TcpConfig, TransportConfig, TransportKind};
        let cfg = TransportConfig {
            kind: TransportKind::Tcp,
            tcp: TcpConfig { workers: vec!["127.0.0.1:1".into()], ..TcpConfig::default() },
        };
        match Cluster::connect(specs(2, 2, 2, WorkerOp::Logistic), &cfg) {
            Err(ClusterError::Backend(e)) => {
                assert!(e.contains("2 worker addresses"), "{e}");
            }
            other => panic!("expected Backend error, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn worker_engine_steps_match_direct_computation() {
        let f = PrimeField::new(PAPER_PRIME);
        let (rows, d) = (2, 3);
        let spec = specs(1, rows, d, WorkerOp::Logistic).remove(0);
        let mut engine = WorkerEngine::new(spec).unwrap();
        let x = vec![1u64, 2, 3, 4, 5, 6];
        engine.load(x.clone(), None);
        let w = vec![2u64, 4, 6];
        let res = engine.step(7, &w);
        assert_eq!(res.worker, 0);
        assert_eq!(res.iter, 7);
        let wc = WorkerComputation::new(f, rows, d, vec![3, 7]);
        assert_eq!(res.data.unwrap(), wc.compute(&x, &w));
    }

    #[test]
    fn worker_engine_honors_fail_from_iter() {
        let mut spec = specs(1, 2, 2, WorkerOp::Logistic).remove(0);
        spec.fail_from_iter = Some(2);
        let mut engine = WorkerEngine::new(spec).unwrap();
        engine.load(vec![1, 2, 3, 4], None);
        assert!(engine.step(1, &[1, 1]).data.is_ok());
        assert_eq!(engine.step(2, &[1, 1]).data.unwrap_err(), "injected fault");
    }
}
