//! Worker supervision: liveness tracking, respawn/redial, re-dispatch,
//! and the adaptive per-round deadline controller.
//!
//! The round engine (PR 2) *detects* faults — failures are counted, the
//! fastest-R threshold decides the round. This module closes the loop to
//! fault *recovery*: a [`Supervisor`] owns each worker's health record
//! and, within a configurable respawn budget, re-admits lost workers
//! through the transport seam ([`super::transport::Transport::reconnect`]
//! — TCP redial with capped jittered backoff, or an in-memory replacement
//! thread) and re-ships the worker's encoded share so the pool heals
//! without restarting the session. When a heal lands *mid-round* (the
//! threshold was unreachable), the supervisor also re-dispatches the
//! current iteration's coded weights and reopens the round
//! ([`super::round::Round::heal`]) so collection can resume.
//!
//! The [`DeadlineController`] is the adaptivity piece: it feeds observed
//! round wall times ([`super::straggler::ArrivalStats`]) into the next
//! round's deadline and decides when approximate decoding should be
//! pre-armed. It never touches the wall clock itself — it only consumes
//! `Round::wall_secs` measured by `util::timer` — so the
//! `no-wallclock-nondeterminism` lint stays green.
//!
//! The supervisor deliberately handles only *opaque coded shares*
//! (`Vec<u64>` it was handed at build time): it never imports `data/`, so
//! the no-plaintext-to-workers invariant is preserved by construction.

use super::round::Round;
use super::straggler::ArrivalStats;
use super::worker::{Cluster, WorkerSpec};

/// One worker's liveness record.
#[derive(Debug, Clone, Default)]
pub struct WorkerHealth {
    /// Rounds failed since the last usable result.
    pub consecutive_failures: u32,
    /// Heals spent on this worker so far.
    pub respawns_used: u32,
}

/// What one heal attempt did, for the session's tracer/report accounting.
#[derive(Debug)]
pub struct HealOutcome {
    pub worker: usize,
    /// 1-based respawn count after this attempt (for trace events).
    pub respawn: u32,
    /// `Err` = the worker is still unreachable; it stays down and keeps
    /// its remaining budget for a later round.
    pub result: Result<(), String>,
    /// True when the current iteration's weights were re-dispatched to
    /// the revived worker (mid-round heal).
    pub redispatched: bool,
}

/// Master-side worker supervision: re-admits failed workers within a
/// per-worker respawn budget.
///
/// Owns the original [`WorkerSpec`]s and each worker's encoded share
/// (cloned at session build) so a revived worker can be handed exactly
/// the data its predecessor held — LCC decoding then stays *exact*, and
/// trajectories are bit-identical to a fault-free run whenever the exact
/// path is used.
pub struct Supervisor {
    specs: Vec<WorkerSpec>,
    x_shares: Vec<Vec<u64>>,
    y_shares: Option<Vec<Vec<u64>>>,
    health: Vec<WorkerHealth>,
    max_respawns: u32,
    /// Successful revives, cumulative.
    pub respawns: u64,
    /// Mid-round weight re-dispatches, cumulative.
    pub redispatches: u64,
}

impl Supervisor {
    /// `max_respawns` is per worker; 0 disables healing entirely (the
    /// session then never constructs a Supervisor).
    pub fn new(
        specs: Vec<WorkerSpec>,
        x_shares: Vec<Vec<u64>>,
        y_shares: Option<Vec<Vec<u64>>>,
        max_respawns: u32,
    ) -> Self {
        let n = specs.len();
        assert!(x_shares.len() == n, "one share per worker");
        Supervisor {
            specs,
            x_shares,
            y_shares,
            health: (0..n).map(|_| WorkerHealth::default()).collect(),
            max_respawns,
            respawns: 0,
            redispatches: 0,
        }
    }

    /// Fold a completed round into the health records: every usable
    /// result resets its worker's failure streak, every failure (live or
    /// healed) extends it.
    pub fn observe_round(&mut self, round: &Round) {
        for r in &round.results {
            if let Some(h) = self.health.get_mut(r.worker) {
                h.consecutive_failures = 0;
            }
        }
        for (w, _) in round.failures.iter().chain(round.healed.iter()) {
            if let Some(h) = self.health.get_mut(*w) {
                h.consecutive_failures += 1;
            }
        }
    }

    pub fn health(&self) -> &[WorkerHealth] {
        &self.health
    }

    /// Heal this round's failed workers, within budget.
    ///
    /// For each worker in `round.failures`: build a replacement spec (the
    /// crash chaos hook `fail_from_iter` is cleared — it models a fault of
    /// the *dead* incarnation; `slow_ms` is kept, a slow machine stays
    /// slow), `revive` it through the transport (reconnect + re-ship the
    /// encoded share), and — only when the round fell short of its
    /// threshold — re-dispatch the current iteration's weights and reopen
    /// the round so [`super::worker::Cluster::collect_resume`] can wait
    /// for the replacement's result. When the round already reached R,
    /// revived workers simply rejoin at the next dispatch.
    pub fn heal(
        &mut self,
        cluster: &mut Cluster,
        round: &mut Round,
        w_shares: &[Vec<u64>],
    ) -> Vec<HealOutcome> {
        let mid_round = !round.ok();
        let failed: Vec<usize> = round.failures.iter().map(|(w, _)| *w).collect();
        let mut outcomes = Vec::new();
        for w in failed {
            let (spec, x, y) = match (self.specs.get(w), self.x_shares.get(w)) {
                (Some(spec), Some(x)) => {
                    let y = self.y_shares.as_ref().and_then(|ys| ys.get(w)).cloned();
                    (spec, x.clone(), y)
                }
                _ => continue, // unknown worker id: nothing to heal with
            };
            {
                let h = &mut self.health[w];
                if h.respawns_used >= self.max_respawns {
                    continue; // budget exhausted: stays failed
                }
                h.respawns_used += 1;
            }
            let mut replacement = spec.clone();
            replacement.fail_from_iter = None;
            let revived = cluster.revive(&replacement, x, y);
            let mut redispatched = false;
            if revived.is_ok() {
                self.respawns += 1;
                if mid_round && round.heal(w) {
                    match w_shares.get(w) {
                        Some(ws) => match cluster.dispatch_to_for(
                            round.session,
                            w,
                            round.iter,
                            ws.clone(),
                        ) {
                            Ok(()) => {
                                redispatched = true;
                                self.redispatches += 1;
                            }
                            Err(e) => {
                                // Revive landed but the re-dispatch died:
                                // put the failure back into the round's
                                // accounting so completion stays sound.
                                round.absorb(super::worker::StepResult {
                                    worker: w,
                                    session: round.session,
                                    iter: round.iter,
                                    data: Err(format!("re-dispatch: {e}")),
                                    compute_secs: 0.0,
                                });
                            }
                        },
                        None => {
                            round.absorb(super::worker::StepResult {
                                worker: w,
                                session: round.session,
                                iter: round.iter,
                                data: Err("re-dispatch: no weight share".to_string()),
                                compute_secs: 0.0,
                            });
                        }
                    }
                }
            }
            outcomes.push(HealOutcome {
                worker: w,
                respawn: self.health[w].respawns_used,
                result: revived,
                redispatched,
            });
        }
        outcomes
    }
}

/// Adaptive per-round deadline: starts from the configured
/// `--round-deadline-ms` and, once enough rounds have been observed,
/// tightens it to `mean + 4σ` of the measured round wall times (never
/// above the configured ceiling — the static deadline is a hard cap, the
/// controller only sharpens it). With `adaptive` off it returns the
/// configured value unchanged. Also tracks a deadline-expiry streak so
/// the session can pre-arm approximate decoding instead of burning the
/// full deadline every round on a persistently short-handed pool.
#[derive(Debug, Clone)]
pub struct DeadlineController {
    stats: ArrivalStats,
    base_ms: u64,
    adaptive: bool,
    expired_streak: u32,
}

/// Observed rounds required before the controller trusts its estimate.
const MIN_OBSERVATIONS: u64 = 3;
/// Tail width: deadline = mean + TAIL_SIGMA·σ.
const TAIL_SIGMA: f64 = 4.0;
/// Floor so an adaptively tightened deadline can never hit zero.
const MIN_DEADLINE_MS: u64 = 10;
/// Expiry streak at which approximate decode is pre-armed.
const PRE_ARM_STREAK: u32 = 2;

impl DeadlineController {
    pub fn new(base_ms: u64, adaptive: bool) -> Self {
        DeadlineController {
            stats: ArrivalStats::new(),
            base_ms,
            adaptive,
            expired_streak: 0,
        }
    }

    /// Fold in a completed round: its measured wall time (only rounds
    /// that finished on their own — deadline-expired rounds would bias
    /// the estimate toward the deadline itself) and whether the deadline
    /// fired.
    pub fn observe(&mut self, wall_secs: f64, deadline_expired: bool) {
        if deadline_expired {
            self.expired_streak += 1;
        } else {
            self.expired_streak = 0;
            self.stats.record(wall_secs);
        }
    }

    /// Deadline for the next round, in ms (0 = unbounded).
    pub fn next_deadline_ms(&self) -> u64 {
        if !self.adaptive || self.stats.count() < MIN_OBSERVATIONS {
            return self.base_ms;
        }
        let est_ms = ((self.stats.mean() + TAIL_SIGMA * self.stats.std_dev()) * 1000.0).ceil()
            as u64
            + 1;
        let est_ms = est_ms.max(MIN_DEADLINE_MS);
        if self.base_ms == 0 {
            est_ms
        } else {
            est_ms.min(self.base_ms)
        }
    }

    /// Should the session skip straight to approximate decode when the
    /// next round falls short, rather than spending heal attempts first?
    pub fn pre_arm_approx(&self) -> bool {
        self.expired_streak >= PRE_ARM_STREAK
    }

    pub fn observed_rounds(&self) -> u64 {
        self.stats.count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::worker::{Cluster, StepResult, WorkerOp, WorkerSpec};
    use crate::field::{PrimeField, PAPER_PRIME};
    use crate::runtime::BackendKind;
    use crate::util::par::Parallelism;
    use std::path::PathBuf;

    fn specs(n: usize) -> Vec<WorkerSpec> {
        let f = PrimeField::new(PAPER_PRIME);
        (0..n)
            .map(|id| WorkerSpec {
                id,
                session: 0,
                kind: BackendKind::Native,
                artifact_dir: PathBuf::from("artifacts"),
                field: f,
                rows: 2,
                d: 2,
                coeffs: vec![3, 7],
                op: WorkerOp::Logistic,
                fail_from_iter: None,
                slow_ms: 0,
                par: Parallelism::Serial,
            })
            .collect()
    }

    fn ok_result(worker: usize, iter: u64) -> StepResult {
        StepResult { worker, session: 0, iter, data: Ok(vec![1]), compute_secs: 0.001 }
    }

    fn err_result(worker: usize, iter: u64) -> StepResult {
        StepResult { worker, session: 0, iter, data: Err("boom".into()), compute_secs: 0.0 }
    }

    #[test]
    fn observe_round_tracks_streaks() {
        let mut sup = Supervisor::new(specs(3), vec![vec![1, 2, 3, 4]; 3], None, 2);
        let mut r = Round::new(0, 2, 3);
        r.absorb(ok_result(0, 0));
        r.absorb(err_result(1, 0));
        r.absorb(ok_result(2, 0));
        sup.observe_round(&r);
        sup.observe_round(&r);
        assert_eq!(sup.health()[0].consecutive_failures, 0);
        assert_eq!(sup.health()[1].consecutive_failures, 2);
        let mut r2 = Round::new(1, 2, 3);
        r2.absorb(ok_result(1, 1));
        sup.observe_round(&r2);
        assert_eq!(sup.health()[1].consecutive_failures, 0, "usable result resets");
    }

    #[test]
    fn heal_revives_failed_worker_and_redispatches_mid_round() {
        let s = specs(3);
        let mut chaos = s.clone();
        chaos[1].fail_from_iter = Some(0);
        let x_shares = vec![vec![1u64, 2, 3, 4]; 3];
        let mut cluster = Cluster::spawn(chaos).unwrap();
        cluster.load_data(x_shares.clone(), None).unwrap();
        let w_shares = vec![vec![1u64, 1]; 3];
        cluster.dispatch(0, w_shares.clone()).unwrap();
        // need = 3-of-3 so worker 1's injected fault leaves the round short.
        let mut round = cluster.collect_first(3, 0).unwrap();
        assert!(!round.ok());

        let mut sup = Supervisor::new(s, x_shares, None, 1);
        let outcomes = sup.heal(&mut cluster, &mut round, &w_shares);
        assert_eq!(outcomes.len(), 1);
        assert_eq!(outcomes[0].worker, 1);
        assert!(outcomes[0].result.is_ok(), "{:?}", outcomes[0].result);
        assert!(outcomes[0].redispatched);
        assert_eq!(sup.respawns, 1);
        assert_eq!(sup.redispatches, 1);

        // The reopened round now completes from the replacement's result.
        cluster
            .collect_resume(&mut round, &crate::util::timer::Deadline::none())
            .unwrap();
        assert!(round.ok(), "failures: {:?}", round.failures);
        assert_eq!(round.healed.len(), 1, "original failure stays recorded");

        // Budget exhausted: a second heal attempt is a no-op.
        let mut r2 = Round::new(1, 3, 3);
        r2.absorb(err_result(1, 1));
        let outcomes2 = sup.heal(&mut cluster, &mut r2, &w_shares);
        assert!(outcomes2.is_empty(), "respawn budget is per worker");
    }

    #[test]
    fn controller_is_inert_until_warm_and_capped_by_base() {
        let mut c = DeadlineController::new(500, true);
        assert_eq!(c.next_deadline_ms(), 500, "cold start: configured value");
        for _ in 0..5 {
            c.observe(0.010, false);
        }
        let d = c.next_deadline_ms();
        assert!(d >= MIN_DEADLINE_MS && d <= 500, "tightened: {d}");
        assert!(d < 500, "uniform 10 ms rounds must tighten a 500 ms deadline");

        // Non-adaptive: always the configured value.
        let mut c2 = DeadlineController::new(500, false);
        for _ in 0..5 {
            c2.observe(0.010, false);
        }
        assert_eq!(c2.next_deadline_ms(), 500);
    }

    #[test]
    fn controller_pre_arms_after_expiry_streak() {
        let mut c = DeadlineController::new(100, true);
        assert!(!c.pre_arm_approx());
        c.observe(0.1, true);
        assert!(!c.pre_arm_approx());
        c.observe(0.1, true);
        assert!(c.pre_arm_approx());
        c.observe(0.05, false);
        assert!(!c.pre_arm_approx(), "a clean round clears the streak");
        assert_eq!(c.observed_rounds(), 1, "expired rounds never feed the estimate");
    }
}
