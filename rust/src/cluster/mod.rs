//! Simulated master–worker cluster.
//!
//! The paper runs on Amazon EC2 (`m3.xlarge`, MPI4Py). Here each worker is
//! an OS thread owning its own compute backend; messages are typed channel
//! sends with byte accounting, and a [`NetworkModel`] converts bytes moved
//! into modeled communication time (DESIGN.md §Substitutions).
//!
//! Collection is **streaming**: [`Cluster::collect_first`] consumes
//! results in actual arrival order and returns as soon as the fastest R
//! usable ones land (the [`Round`] state machine); late results are
//! drained on the next iteration, never decoded. Straggling is injected
//! with the shifted-exponential model standard in the coded-computing
//! literature (real slow machines are injected with
//! [`WorkerSpec::slow_ms`]), and per-iteration *modeled* computation time
//! is the R-th order statistic of per-worker (compute + sampled
//! straggle) — the paper's N-independent-machines semantics without
//! requiring N physical hosts.

mod netmodel;
pub mod round;
mod straggler;
pub mod worker;

pub use netmodel::NetworkModel;
pub use round::Round;
pub use straggler::StragglerModel;
pub use worker::{Cluster, ClusterError, StepResult, WorkerOp, WorkerSpec};
