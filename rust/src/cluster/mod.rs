//! Master–worker cluster behind a pluggable transport.
//!
//! The paper runs on Amazon EC2 (`m3.xlarge`, MPI4Py). Here the master
//! drives its N workers through the [`transport::Transport`] seam, with
//! two backends:
//!
//! * **memory** (default) — each worker is an OS thread owning its own
//!   compute backend; messages are typed channel sends. This is the
//!   simulated cluster every unit test runs on, and a [`NetworkModel`]
//!   converts bytes moved into modeled communication time
//!   (DESIGN.md §Substitutions).
//! * **tcp** — each worker is a separate `codedml --worker --listen
//!   <addr>` process; messages are length-prefixed, versioned frames over
//!   `std::net` sockets ([`transport::frame`]). Lost connections surface
//!   as per-round failures (`TrainReport::worker_failures`), never
//!   panics.
//!
//! Both backends charge identical frame-layout byte costs and deliver
//! results in actual arrival order, so decoded gradients are
//! **bit-identical across transports** (LCC decoding is exact on any
//! fastest-R subset; asserted in `rust/tests/transport_conformance.rs`).
//!
//! Collection is **streaming**: [`Cluster::collect_first`] consumes
//! results in actual arrival order and returns as soon as the fastest R
//! usable ones land (the [`Round`] state machine); late results are
//! drained on the next iteration, never decoded. Straggling is injected
//! with the shifted-exponential model standard in the coded-computing
//! literature (real slow machines are injected with
//! [`WorkerSpec::slow_ms`]), and per-iteration *modeled* computation time
//! is the R-th order statistic of per-worker (compute + sampled
//! straggle) — the paper's N-independent-machines semantics without
//! requiring N physical hosts.

mod netmodel;
pub mod round;
mod straggler;
pub mod supervisor;
pub mod transport;
pub mod worker;

pub use netmodel::NetworkModel;
pub use round::Round;
pub use straggler::{ArrivalStats, StragglerModel};
pub use supervisor::{DeadlineController, HealOutcome, Supervisor};
pub use transport::{Transport, TransportConfig, TransportEvent, TransportKind};
pub use worker::{Cluster, ClusterError, StepResult, WorkerEngine, WorkerOp, WorkerSpec};
