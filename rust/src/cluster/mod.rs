//! Simulated master–worker cluster.
//!
//! The paper runs on Amazon EC2 (`m3.xlarge`, MPI4Py). Here each worker is
//! an OS thread owning its own compute backend; messages are typed channel
//! sends with byte accounting, and a [`NetworkModel`] converts bytes moved
//! into modeled communication time (DESIGN.md §Substitutions). Straggling
//! is injected with the shifted-exponential model standard in the coded-
//! computing literature, and per-iteration computation time is the
//! *modeled parallel* time — the R-th order statistic of per-worker
//! (measured compute + sampled straggle) — which matches the paper's
//! N-independent-machines semantics without requiring N physical hosts.

mod netmodel;
mod straggler;
pub mod worker;

pub use netmodel::NetworkModel;
pub use straggler::StragglerModel;
pub use worker::{Cluster, ClusterError, StepResult, WorkerOp, WorkerSpec};
