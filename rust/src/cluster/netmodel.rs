//! Network cost model.
//!
//! All workers are local threads, so real network time is ~0; the model
//! converts bytes moved into the comm-time column of Tables 1–6. Defaults
//! approximate the paper's EC2 `m3.xlarge` testbed (≈1 Gb/s instance
//! networking, sub-millisecond intra-AZ latency).

/// Store-and-forward transfer time: latency + bytes/bandwidth per message,
/// serialized at the sender's NIC when one endpoint sends many messages.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkModel {
    /// Sender bandwidth in bytes/second.
    pub bandwidth: f64,
    /// Per-message latency in seconds.
    pub latency: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        // 1 Gb/s, 0.5 ms.
        NetworkModel { bandwidth: 125e6, latency: 0.5e-3 }
    }
}

impl NetworkModel {
    /// A zero-cost network (for isolating compute in ablations).
    pub fn free() -> Self {
        NetworkModel { bandwidth: f64::INFINITY, latency: 0.0 }
    }

    /// Time for one message of `bytes`.
    pub fn message_time(&self, bytes: u64) -> f64 {
        self.latency + bytes as f64 / self.bandwidth
    }

    /// Time for a sender to push `count` messages of `bytes` each
    /// (serialized on its NIC; latencies pipeline, so one latency term).
    pub fn fanout_time(&self, count: usize, bytes: u64) -> f64 {
        if count == 0 {
            return 0.0;
        }
        self.latency + (count as u64 * bytes) as f64 / self.bandwidth
    }

    /// Time for a receiver to drain `count` messages of `bytes` each.
    pub fn fanin_time(&self, count: usize, bytes: u64) -> f64 {
        self.fanout_time(count, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn message_time_components() {
        let net = NetworkModel { bandwidth: 1000.0, latency: 0.1 };
        assert!((net.message_time(500) - 0.6).abs() < 1e-12);
    }

    #[test]
    fn fanout_serializes_bytes_pipelines_latency() {
        let net = NetworkModel { bandwidth: 1000.0, latency: 0.1 };
        // 4 × 250 bytes = 1 s of wire time + one 0.1 s latency.
        assert!((net.fanout_time(4, 250) - 1.1).abs() < 1e-12);
        assert_eq!(net.fanout_time(0, 1000), 0.0);
    }

    #[test]
    fn free_network_is_zero() {
        let net = NetworkModel::free();
        assert_eq!(net.message_time(1 << 30), 0.0);
        assert_eq!(net.fanout_time(100, 1 << 30), 0.0);
    }

    #[test]
    fn default_is_gigabit() {
        let net = NetworkModel::default();
        // 125 MB at 1 Gb/s ≈ 1 s.
        let t = net.message_time(125_000_000);
        assert!((t - 1.0005).abs() < 1e-6, "t={t}");
    }
}
