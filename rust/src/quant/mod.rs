//! Quantization between ℝ and F_p (paper §3.1) plus the overflow budget
//! checker.
//!
//! - Dataset: deterministic rounding at scale 2^l_x, embedded by φ (eq. 6).
//! - Weights: `r` *independent stochastic* quantizations at scale 2^l_w
//!   (eq. 8–10) — independence is what makes the worker-side polynomial
//!   ḡ an unbiased estimator (Lemma 1) and hence training converge.
//! - Decode: Q_p⁻¹ (eq. 24) with total scale l = l_c + l_x + r(l_x+l_w);
//!   the explicit coefficient scale l_c is our generalization (DESIGN.md
//!   §Numeric design — l_c=0 reproduces the paper's formula but truncates
//!   the leading sigmoid coefficient to an integer).

mod budget;
mod quantizer;

pub use budget::{BudgetReport, OverflowBudget};
pub use quantizer::{
    dequant_scale_bits, DatasetQuantizer, Dequantizer, WeightQuantizer,
};

use crate::field::PrimeField;

/// Deterministic round-half-up (paper eq. 5).
#[inline]
pub fn round_half_up(x: f64) -> i64 {
    let fl = x.floor();
    if x - fl < 0.5 {
        fl as i64
    } else {
        fl as i64 + 1
    }
}

/// Stochastic rounding (paper §3.1): unbiased, `E[round(x)] = x`.
#[inline]
pub fn round_stochastic(x: f64, rng: &mut crate::util::Rng) -> i64 {
    let fl = x.floor();
    let frac = x - fl;
    if rng.f64() < frac {
        fl as i64 + 1
    } else {
        fl as i64
    }
}

/// φ: embed a signed integer into F_p by two's complement (paper eq. 7).
/// Panics in debug if |x| ≥ p/2 (the caller must respect the budget).
#[inline]
pub fn phi(f: &PrimeField, x: i64) -> u64 {
    debug_assert!(
        (x.unsigned_abs()) <= (f.modulus() - 1) / 2,
        "phi: |{x}| exceeds field range"
    );
    f.from_i64(x)
}

/// φ⁻¹: back to the signed representative (paper eq. 25).
#[inline]
pub fn phi_inv(f: &PrimeField, x: u64) -> i64 {
    f.to_i64(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::PAPER_PRIME;
    use crate::util::proptest::check;
    use crate::util::Rng;

    #[test]
    fn round_half_up_matches_eq5() {
        assert_eq!(round_half_up(1.4), 1);
        assert_eq!(round_half_up(1.5), 2);
        assert_eq!(round_half_up(-1.4), -1);
        assert_eq!(round_half_up(-1.5), -1); // floor(-1.5) = -2; -1.5-(-2)=0.5 → +1
        assert_eq!(round_half_up(-1.6), -2);
        assert_eq!(round_half_up(0.0), 0);
    }

    #[test]
    fn stochastic_rounding_unbiased() {
        let mut rng = Rng::new(31);
        let x = 2.3f64;
        let n = 100_000;
        let mean: f64 =
            (0..n).map(|_| round_stochastic(x, &mut rng) as f64).sum::<f64>() / n as f64;
        assert!((mean - x).abs() < 0.01, "mean={mean}");
        // Negative side too.
        let x = -0.75;
        let mean: f64 =
            (0..n).map(|_| round_stochastic(x, &mut rng) as f64).sum::<f64>() / n as f64;
        assert!((mean - x).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn stochastic_rounding_integer_is_exact() {
        let mut rng = Rng::new(33);
        for x in [-3.0, 0.0, 5.0] {
            for _ in 0..100 {
                assert_eq!(round_stochastic(x, &mut rng) as f64, x);
            }
        }
    }

    #[test]
    fn phi_phi_inv_roundtrip_property() {
        let f = PrimeField::new(PAPER_PRIME);
        check("phi-roundtrip", 200, move |rng| {
            let half = ((f.modulus() - 1) / 2) as i64;
            let x = rng.below(2 * half as u64 + 1) as i64 - half;
            if phi_inv(&f, phi(&f, x)) != x {
                return Err(format!("x={x}"));
            }
            Ok(())
        });
    }

    #[test]
    fn phi_is_additive_homomorphism_within_range() {
        let f = PrimeField::new(PAPER_PRIME);
        check("phi-additive", 200, move |rng| {
            let a = rng.below(1000) as i64 - 500;
            let b = rng.below(1000) as i64 - 500;
            let sum_field = f.add(phi(&f, a), phi(&f, b));
            if phi_inv(&f, sum_field) != a + b {
                return Err(format!("a={a} b={b}"));
            }
            let prod_field = f.mul(phi(&f, a), phi(&f, b));
            if phi_inv(&f, prod_field) != a * b {
                return Err(format!("mul a={a} b={b}"));
            }
            Ok(())
        });
    }
}
