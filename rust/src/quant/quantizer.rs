//! Matrix/vector quantizers and the dequantizer Q_p⁻¹.

use super::{phi, phi_inv, round_half_up, round_stochastic};
use crate::field::PrimeField;
use crate::util::Rng;

/// Total dequantization scale (bits): l = l_c + l_x + r·(l_x + l_w).
/// With l_c = 0 this is the paper's l = l_x + r(l_x + l_w) (eq. 24).
pub fn dequant_scale_bits(lx: u32, lw: u32, lc: u32, r: u32) -> u32 {
    lc + lx + r * (lx + lw)
}

/// Deterministic dataset quantizer X → X̄ (paper eq. 6).
#[derive(Debug, Clone, Copy)]
pub struct DatasetQuantizer {
    pub field: PrimeField,
    /// Scale exponent l_x.
    pub lx: u32,
}

impl DatasetQuantizer {
    pub fn new(field: PrimeField, lx: u32) -> Self {
        DatasetQuantizer { field, lx }
    }

    /// Quantize a real matrix (row-major) into field elements.
    pub fn quantize(&self, x: &[f64]) -> Vec<u64> {
        let scale = (1u64 << self.lx) as f64;
        x.iter()
            .map(|&v| phi(&self.field, round_half_up(scale * v)))
            .collect()
    }

    /// The real value represented by a quantized entry.
    pub fn dequantize_entry(&self, q: u64) -> f64 {
        phi_inv(&self.field, q) as f64 / (1u64 << self.lx) as f64
    }

    /// Largest |x| the field can hold at this scale: (p-1)/2^(l_x+1)
    /// (paper §3.1's domain bound).
    pub fn max_abs_value(&self) -> f64 {
        (self.field.modulus() - 1) as f64 / (1u64 << (self.lx + 1)) as f64
    }
}

/// Stochastic weight quantizer producing the r independent quantizations
/// W̄ = [w̄^(t),1 ... w̄^(t),r] (paper eq. 9–10).
#[derive(Debug, Clone, Copy)]
pub struct WeightQuantizer {
    pub field: PrimeField,
    /// Scale exponent l_w.
    pub lw: u32,
    /// Number of independent quantizations == sigmoid polynomial degree r.
    pub r: u32,
}

impl WeightQuantizer {
    pub fn new(field: PrimeField, lw: u32, r: u32) -> Self {
        assert!(r >= 1, "need at least one quantization (r >= 1)");
        WeightQuantizer { field, lw, r }
    }

    /// Quantize `w` (length d) into a row-major d × r matrix whose j-th
    /// column is the j-th independent stochastic quantization.
    pub fn quantize(&self, w: &[f64], rng: &mut Rng) -> Vec<u64> {
        let d = w.len();
        let r = self.r as usize;
        let scale = (1u64 << self.lw) as f64;
        let mut out = vec![0u64; d * r];
        for (i, &wi) in w.iter().enumerate() {
            for j in 0..r {
                out[i * r + j] = phi(&self.field, round_stochastic(scale * wi, rng));
            }
        }
        out
    }

    /// Dequantize one column back to reals (used by tests/diagnostics).
    pub fn dequantize_column(&self, wq: &[u64], d: usize, col: usize) -> Vec<f64> {
        let r = self.r as usize;
        (0..d)
            .map(|i| phi_inv(&self.field, wq[i * r + col]) as f64 / (1u64 << self.lw) as f64)
            .collect()
    }
}

/// Q_p⁻¹ — converts decoded field vectors back to reals at the combined
/// scale (paper eq. 24).
#[derive(Debug, Clone, Copy)]
pub struct Dequantizer {
    pub field: PrimeField,
    /// Total scale bits l.
    pub l: u32,
}

impl Dequantizer {
    pub fn new(field: PrimeField, lx: u32, lw: u32, lc: u32, r: u32) -> Self {
        Dequantizer { field, l: dequant_scale_bits(lx, lw, lc, r) }
    }

    #[inline]
    pub fn dequantize_entry(&self, q: u64) -> f64 {
        phi_inv(&self.field, q) as f64 / (1u64 << self.l) as f64
    }

    pub fn dequantize(&self, qs: &[u64]) -> Vec<f64> {
        qs.iter().map(|&q| self.dequantize_entry(q)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{PrimeField, PAPER_PRIME};
    use crate::util::proptest::check;
    use crate::util::Rng;

    fn field() -> PrimeField {
        PrimeField::new(PAPER_PRIME)
    }

    #[test]
    fn dataset_quantize_dequantize_error_bound() {
        let q = DatasetQuantizer::new(field(), 2);
        check("dataset-quant-error", 100, move |rng| {
            let x: Vec<f64> = (0..32).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let xq = q.quantize(&x);
            for (&orig, &quant) in x.iter().zip(xq.iter()) {
                let back = q.dequantize_entry(quant);
                // Max rounding error is half a quantum = 2^-(lx+1).
                if (back - orig).abs() > 0.5 / 4.0 + 1e-12 {
                    return Err(format!("orig={orig} back={back}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn dataset_quantizer_exact_on_grid() {
        let q = DatasetQuantizer::new(field(), 3);
        // Values on the 2^-3 grid are represented exactly.
        let x = [0.125, -0.5, 1.0, -2.875, 0.0];
        let xq = q.quantize(&x);
        for (&orig, &quant) in x.iter().zip(xq.iter()) {
            assert_eq!(q.dequantize_entry(quant), orig);
        }
    }

    #[test]
    fn weight_quantizer_shape_and_independence() {
        let wq = WeightQuantizer::new(field(), 4, 2);
        let mut rng = Rng::new(41);
        let w: Vec<f64> = (0..16).map(|_| rng.range_f64(-0.5, 0.5)).collect();
        let q = wq.quantize(&w, &mut rng);
        assert_eq!(q.len(), 16 * 2);
        // The two columns should differ somewhere (independent stochastic
        // draws; probability of full agreement is astronomically small for
        // off-grid values).
        let col0 = wq.dequantize_column(&q, 16, 0);
        let col1 = wq.dequantize_column(&q, 16, 1);
        assert_ne!(col0, col1);
    }

    #[test]
    fn weight_quantizer_unbiased_per_entry() {
        let f = field();
        let wq = WeightQuantizer::new(f, 4, 1);
        let mut rng = Rng::new(43);
        let w = [0.3125f64, -0.17, 0.049];
        let trials = 20_000;
        let mut sums = [0.0f64; 3];
        for _ in 0..trials {
            let q = wq.quantize(&w, &mut rng);
            for i in 0..3 {
                sums[i] += phi_inv(&f, q[i]) as f64 / 16.0;
            }
        }
        for i in 0..3 {
            let mean = sums[i] / trials as f64;
            assert!(
                (mean - w[i]).abs() < 0.005,
                "entry {i}: mean={mean} want {}",
                w[i]
            );
        }
    }

    #[test]
    fn dequant_scale_matches_paper_when_lc_zero() {
        // Paper: l = l_x + r(l_x + l_w); ours with l_c = 0 must agree.
        assert_eq!(dequant_scale_bits(2, 4, 0, 1), 2 + 1 * 6);
        assert_eq!(dequant_scale_bits(2, 4, 0, 2), 2 + 2 * 6);
        // And the generalization adds l_c.
        assert_eq!(dequant_scale_bits(2, 4, 3, 1), 3 + 2 + 6);
    }

    #[test]
    fn dequantizer_scales_correctly() {
        let f = field();
        let dq = Dequantizer::new(f, 2, 4, 0, 1); // l = 8
        let v = phi(&f, 256); // represents 1.0
        assert_eq!(dq.dequantize_entry(v), 1.0);
        let v = phi(&f, -128); // represents -0.5
        assert_eq!(dq.dequantize_entry(v), -0.5);
        assert_eq!(dq.dequantize(&[phi(&f, 512), phi(&f, 0)]), vec![2.0, 0.0]);
    }

    #[test]
    fn max_abs_value_honours_domain_bound() {
        let q = DatasetQuantizer::new(field(), 2);
        let bound = q.max_abs_value();
        // p ≥ 2^(lx+1) · max|X| + 1 (paper §3.1) rearranged.
        assert!((bound - (PAPER_PRIME - 1) as f64 / 8.0).abs() < 1e-9);
    }
}
