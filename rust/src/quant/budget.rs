//! Overflow budget analysis.
//!
//! The decoded field value Σ_k X̄_kᵀ ḡ(X̄_k, W̄) is only meaningful if its
//! *integer* value (over ℤ, before reduction mod p) stays within
//! ±(p-1)/2 so that the two's-complement map φ⁻¹ is exact (paper §3.1:
//! "prime p should be large enough ... to avoid wrap-around"). The paper
//! asserts its parameter choice avoids overflow but gives no tool to check
//! one; this module computes the worst-case bound from the data statistics
//! and session parameters, so misconfiguration is a startup error instead
//! of silently corrupted gradients.

use crate::field::PrimeField;

/// Inputs to the overflow analysis.
#[derive(Debug, Clone, Copy)]
pub struct OverflowBudget {
    /// Field modulus.
    pub p: u64,
    /// max |X_ij| of the *real* dataset.
    pub max_abs_x: f64,
    /// Rows per partition (m / K) — decode dequantizes per partition.
    pub rows_per_block: usize,
    /// Dataset scale bits.
    pub lx: u32,
    /// Weight scale bits.
    pub lw: u32,
    /// Coefficient scale bits.
    pub lc: u32,
    /// Sigmoid polynomial degree.
    pub r: u32,
    /// Bound on |ĝ(z)| over the clipped activation range; the fit keeps the
    /// polynomial within [0,1]-ish, we default to 2.0 for slack.
    pub max_abs_g: f64,
}

/// Result of the analysis.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BudgetReport {
    /// Worst-case |integer value| of one decoded sub-gradient entry.
    pub worst_case: f64,
    /// The wrap-around threshold (p-1)/2.
    pub limit: f64,
    /// worst_case / limit — must be < 1 for exact decoding.
    pub utilization: f64,
}

impl BudgetReport {
    pub fn ok(&self) -> bool {
        self.utilization < 1.0
    }
}

impl OverflowBudget {
    pub fn analyze(&self) -> BudgetReport {
        // One decoded entry is Σ_{i ∈ block} X̄_int[i,j] · ḡ_int[i] with
        //   |X̄_int| ≤ 2^lx · max|X| + 0.5   (deterministic rounding)
        //   |ḡ_int| ≤ 2^{lc + r(lx+lw)} · max|ĝ| + slack
        // summed over rows_per_block rows.
        let x_int = (1u64 << self.lx) as f64 * self.max_abs_x + 0.5;
        let g_scale = (1u64 << (self.lc + self.r * (self.lx + self.lw))) as f64;
        let g_int = g_scale * self.max_abs_g;
        let worst = x_int * g_int * self.rows_per_block as f64;
        let limit = (self.p - 1) as f64 / 2.0;
        BudgetReport {
            worst_case: worst,
            limit,
            utilization: worst / limit,
        }
    }

    /// Convenience: analyze against a field context.
    pub fn for_field(field: &PrimeField, max_abs_x: f64, rows_per_block: usize,
                     lx: u32, lw: u32, lc: u32, r: u32) -> BudgetReport {
        OverflowBudget {
            p: field.modulus(),
            max_abs_x,
            rows_per_block,
            lx,
            lw,
            lc,
            r,
            max_abs_g: 2.0,
        }
        .analyze()
    }

    /// Largest rows_per_block that keeps utilization under `headroom`
    /// (< 1.0). Useful for choosing K.
    pub fn max_block_rows(&self, headroom: f64) -> usize {
        let mut probe = *self;
        probe.rows_per_block = 1;
        let per_row = probe.analyze().worst_case;
        let limit = (self.p - 1) as f64 / 2.0 * headroom;
        (limit / per_row).floor().max(0.0) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{PAPER_PRIME, PRIME_26};

    fn base() -> OverflowBudget {
        OverflowBudget {
            p: PAPER_PRIME,
            max_abs_x: 1.0,
            rows_per_block: 1024,
            lx: 2,
            lw: 4,
            lc: 0,
            r: 1,
            max_abs_g: 1.0,
        }
    }

    #[test]
    fn paper_parameters_fit_per_block() {
        // Paper params, K=13 blocks of 12396/13 ≈ 954 rows: must fit.
        let mut b = base();
        b.rows_per_block = 954;
        let rep = b.analyze();
        assert!(rep.ok(), "utilization={}", rep.utilization);
    }

    #[test]
    fn whole_dataset_single_block_overflows_at_paper_prime() {
        // Demonstrates why the decoder dequantizes per block: all 12396
        // rows in one block with l_c=3 would exceed the 24-bit budget.
        let mut b = base();
        b.rows_per_block = 12396;
        b.lc = 3;
        let rep = b.analyze();
        assert!(!rep.ok(), "should overflow, utilization={}", rep.utilization);
        // The 26-bit prime restores the margin at moderate K.
        b.p = PRIME_26;
        b.rows_per_block = 954;
        assert!(b.analyze().ok());
    }

    #[test]
    fn utilization_scales_linearly_with_rows() {
        let mut b = base();
        b.rows_per_block = 100;
        let u1 = b.analyze().utilization;
        b.rows_per_block = 200;
        let u2 = b.analyze().utilization;
        assert!((u2 / u1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn max_block_rows_is_consistent() {
        let b = base();
        let rows = b.max_block_rows(0.9);
        assert!(rows > 0);
        let mut probe = b;
        probe.rows_per_block = rows;
        assert!(probe.analyze().utilization <= 0.9 + 1e-9);
        probe.rows_per_block = rows * 2;
        assert!(probe.analyze().utilization > 0.9);
    }

    #[test]
    fn lc_increases_worst_case() {
        let mut b = base();
        let w0 = b.analyze().worst_case;
        b.lc = 3;
        let w3 = b.analyze().worst_case;
        assert!((w3 / w0 - 8.0).abs() < 1e-9);
    }
}
