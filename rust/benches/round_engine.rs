//! Round-engine bench: full collection vs early-exit wall time with one
//! worker slowed ~10×, at straggler slack N − R = 3 ≥ 2.
//!
//! This measures the tentpole claim directly: with `collect_first(R)` the
//! master's per-iteration wall time is gated by the fastest-R subset, not
//! by the slow machine. `BENCH_JSON=1` also records the decoder's
//! per-subset cache stats (early exit sees varying subsets → some cold
//! decodes; full collection always feeds the same sorted-by-arrival pool).

mod bench_util;
use bench_util::{finish, report, report_metric, report_speedup};

use std::path::PathBuf;
use std::time::Instant;

use codedml::cluster::{Cluster, WorkerOp, WorkerSpec};
use codedml::coding::{CodingParams, Decoder, Encoder, WorkerResult};
use codedml::field::{PrimeField, PAPER_PRIME};
use codedml::runtime::BackendKind;
use codedml::util::{Parallelism, Rng};

fn specs(n: usize, rows: usize, d: usize, coeffs: &[u64], slow_ms: u64) -> Vec<WorkerSpec> {
    let f = PrimeField::new(PAPER_PRIME);
    (0..n)
        .map(|id| WorkerSpec {
            id,
            session: 0,
            kind: BackendKind::Native,
            artifact_dir: PathBuf::from("artifacts"),
            field: f,
            rows,
            d,
            coeffs: coeffs.to_vec(),
            op: WorkerOp::Logistic,
            fail_from_iter: None,
            // Worker 0 is the slow machine.
            slow_ms: if id == 0 { slow_ms } else { 0 },
            par: Parallelism::Serial,
        })
        .collect()
}

fn main() {
    let f = PrimeField::new(PAPER_PRIME);
    let (n, k, t) = (13usize, 3usize, 1usize);
    let params = CodingParams::new(n, k, t, 1).unwrap();
    let need = params.recovery_threshold();
    assert!(n - need >= 2, "bench requires straggler slack ≥ 2");
    let (rows, d) = (412usize, 784usize);
    let m = rows * k;
    let coeffs = vec![3u64, 7];
    let iters = 20u64;

    println!(
        "== round_engine (N={n} K={k} T={t}, R={need}, slack {}) ==",
        n - need
    );

    let mut rng = Rng::new(11);
    let xq = f.random_matrix(&mut rng, m, d);
    let enc = Encoder::new(f, params);
    let x_shares: Vec<Vec<u64>> = enc
        .encode_dataset(&xq, m, d, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();
    let w_shares: Vec<Vec<u64>> = enc
        .encode_weights(&f.random_matrix(&mut rng, d, 1), d, 1, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();

    // Calibrate: time one healthy full round, then slow worker 0 by ~10×.
    let mut calib = Cluster::spawn(specs(n, rows, d, &coeffs, 0)).unwrap();
    calib.load_data(x_shares.clone(), None).unwrap();
    calib.dispatch(0, w_shares.clone()).unwrap();
    calib.collect_first(n, 0).unwrap(); // warmup
    calib.dispatch(1, w_shares.clone()).unwrap();
    let t0 = Instant::now();
    calib.collect_first(n, 1).unwrap();
    let healthy_round = t0.elapsed().as_secs_f64();
    let slow_ms = ((healthy_round * 10.0 * 1e3).ceil() as u64).max(20);
    drop(calib);
    println!(
        "healthy round {:.2} ms → slow worker pinned at {slow_ms} ms (~10x)",
        healthy_round * 1e3
    );

    // One cluster per collection policy, identical shares and slowdown.
    let mut times = [0.0f64; 2];
    let mut cache_stats = [(0u64, 0u64); 2];
    let mut late_total = 0usize;
    for (mode, &collect_n) in [n, need].iter().enumerate() {
        let label = if mode == 0 { "full collection (R=N)" } else { "early exit (fastest R)" };
        let mut cluster = Cluster::spawn(specs(n, rows, d, &coeffs, slow_ms)).unwrap();
        cluster.load_data(x_shares.clone(), None).unwrap();
        let mut dec = Decoder::new(f, params, enc.points.clone());
        // Warmup round (also primes the decoder cache once).
        cluster.dispatch(0, w_shares.clone()).unwrap();
        cluster.collect_first(collect_n, 0).unwrap();

        let t0 = Instant::now();
        for iter in 1..=iters {
            cluster.dispatch(iter, w_shares.clone()).unwrap();
            let round = cluster.collect_first(collect_n, iter).unwrap();
            late_total += round.late_drained;
            let results: Vec<WorkerResult> = round
                .results
                .iter()
                .take(need)
                .map(|r| WorkerResult { worker: r.worker, data: r.data.clone().unwrap() })
                .collect();
            std::hint::black_box(dec.decode(&results, d).unwrap());
        }
        let secs = t0.elapsed().as_secs_f64() / iters as f64;
        times[mode] = secs;
        cache_stats[mode] = dec.cache_stats();
        report(
            &format!("train round, 1 worker {slow_ms} ms slow [{label}]"),
            secs,
            None,
        );
    }

    report_speedup(
        "round_engine early-exit vs full collection",
        times[0],
        times[1],
    );
    report_metric("decode cache hits [full collection]", cache_stats[0].0 as f64);
    report_metric("decode cache misses [full collection]", cache_stats[0].1 as f64);
    report_metric("decode cache hits [early exit]", cache_stats[1].0 as f64);
    report_metric("decode cache misses [early exit]", cache_stats[1].1 as f64);
    report_metric("late results drained", late_total as f64);

    finish("round_engine");
}
