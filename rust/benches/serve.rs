//! Serve bench: what multiplexing buys. Four small sessions, each
//! straggling on its own disjoint pair of shared workers
//! (`chaos_slow_from` offsets the span), run two ways:
//!
//!   1. dedicated clusters, back-to-back — every session pays its
//!      stragglers' wall-clock in sequence;
//!   2. one `Scheduler` over one shared pool — all four sessions' rounds
//!      are in flight at once, so their straggler waits overlap.
//!
//! `scripts/check_bench.py` gates the speedup at ≥ 1.5× in CI (the
//! overlap typically lands near the session count). The trajectories are
//! asserted bit-identical across the two runs first — the speedup must
//! never come at the cost of the isolation invariant.

mod bench_util;
use bench_util::{finish, report, report_metric, report_speedup};

use std::time::Instant;

use codedml::cluster::{NetworkModel, StragglerModel, TransportConfig};
use codedml::coordinator::{CodedMlConfig, CodedMlSession};
use codedml::data::synthetic_3v7;
use codedml::serve::{JobSpec, Scheduler, ServeSpec};

const SESSIONS: usize = 4;
const ITERS: usize = 4;
const SLOW_MS: u64 = 20;

/// Session `s`: N=8 K=2 T=1 (R=7, slack 1) with workers {2s, 2s+1} slow —
/// two stragglers against one slot of slack force every round to wait
/// ~SLOW_MS for one of them.
fn job(s: usize) -> JobSpec {
    JobSpec {
        name: format!("job-{}", s + 1),
        m: 60,
        d: 4,
        data_seed: 3 + s as u64,
        cfg: CodedMlConfig {
            n: 8,
            k: 2,
            t: 1,
            iters: ITERS,
            chaos_slow_from: 2 * s,
            chaos_slow_workers: 2,
            chaos_slow_ms: SLOW_MS,
            net: NetworkModel::free(),
            straggler: StragglerModel::none(),
            ..Default::default()
        },
    }
}

fn main() {
    println!(
        "== serve ({SESSIONS} sessions, N=8 K=2 T=1, {SLOW_MS} ms stragglers \
         on disjoint worker pairs) =="
    );

    // 1. Serial baseline: dedicated clusters, back-to-back.
    let t0 = Instant::now();
    let mut dedicated = Vec::with_capacity(SESSIONS);
    for s in 0..SESSIONS {
        let j = job(s);
        let ds = synthetic_3v7(j.m, j.data_seed);
        let mut sess = CodedMlSession::new(j.cfg.clone(), &ds).unwrap();
        dedicated.push(sess.train(ITERS, None).unwrap());
    }
    let serial_secs = t0.elapsed().as_secs_f64();
    report(
        "4 sessions, dedicated clusters back-to-back",
        serial_secs,
        None,
    );

    // 2. Multiplexed: one scheduler, one shared 8-worker pool. Encode +
    //    pool spawn are inside the timer, matching the baseline's
    //    per-session construction cost.
    let spec = ServeSpec {
        transport: TransportConfig::default(),
        jobs: (0..SESSIONS).map(job).collect(),
    };
    let t0 = Instant::now();
    let mut sched = Scheduler::new(spec).unwrap();
    let rep = sched.run().unwrap();
    let serve_secs = t0.elapsed().as_secs_f64();
    report("4 sessions, multiplexed on one shared pool", serve_secs, None);

    report_metric("misrouted results (must be 0)", rep.misrouted as f64);
    for (s, reference) in rep.sessions.iter().zip(&dedicated) {
        assert_eq!(s.error, None, "session '{}' failed under serve", s.name);
        assert_eq!(
            s.report.weights, reference.weights,
            "session '{}': the speedup must not perturb the trajectory",
            s.name
        );
    }
    assert_eq!(rep.misrouted, 0, "session routing must be airtight");

    report_speedup("serve: shared pool vs back-to-back", serial_secs, serve_secs);

    finish("serve");
}
