//! End-to-end bench: regenerates every paper table and figure at bench
//! scale (one criterion-style target per paper artifact, as `make bench`
//! requires). Scale via `BENCH_SCALE` (default 0.02) and `BENCH_ITERS`
//! (default 5); the full-scale runs recorded in EXPERIMENTS.md use
//! `codedml reproduce all --scale 0.25 --iters 25`.

use codedml::reproduce::{run_experiment, ExpParams, EXPERIMENTS};
use std::time::Instant;

fn main() {
    let scale: f64 = std::env::var("BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.02);
    let iters: usize = std::env::var("BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(5);
    let params = ExpParams { scale, iters, ..Default::default() };
    println!("== tables: all paper artifacts at scale {scale}, {iters} iters ==\n");
    for e in EXPERIMENTS {
        let t0 = Instant::now();
        match run_experiment(e.id, &params) {
            Ok(out) => {
                println!("{}", out.text);
                println!("[{} regenerated in {:.2}s]\n", e.id, t0.elapsed().as_secs_f64());
            }
            Err(err) => {
                println!("[{} FAILED: {err}]\n", e.id);
                std::process::exit(1);
            }
        }
    }
}
