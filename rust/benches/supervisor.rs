//! Supervisor bench: what fault tolerance costs when nothing faults, and
//! what a fault costs when it is healed or degraded.
//!
//! Four runs on a zero-slack pool (N = 10, K = 3, T = 1 → R = N, so any
//! loss is felt immediately):
//!
//!   1. fault tolerance off                    (baseline)
//!   2. fully armed (supervision + approx + deadline), zero chaos
//!   3. one worker killed per run, healed mid-round
//!   4. two workers killed per run, degraded to approximate decode
//!
//! Run 2 is the regression gate: `scripts/check_bench.py` fails the CI
//! chaos job if any degraded-mode counter (approx rounds, respawns,
//! deadline expiries) moves off zero — the fault-tolerance stack must be
//! strictly passive on a healthy pool, and runs 1–3 must share one
//! bit-identical trajectory (asserted here).

mod bench_util;
use bench_util::{finish, report, report_metric};

use std::time::Instant;

use codedml::cluster::{NetworkModel, StragglerModel};
use codedml::coordinator::{CodedMlConfig, CodedMlSession};
use codedml::data::synthetic_3v7;

fn cfg() -> CodedMlConfig {
    CodedMlConfig {
        n: 10, // threshold 3·3+1 = 10 → zero slack
        k: 3,
        t: 1,
        net: NetworkModel::free(),
        straggler: StragglerModel::none(),
        ..Default::default()
    }
}

fn main() {
    let train = synthetic_3v7(120, 51);
    let iters = 12usize;
    println!("== supervisor (N=10 K=3 T=1, R=10, zero slack) ==");

    // 1. Baseline: no supervision, no deadline, no approx.
    let mut plain_sess = CodedMlSession::new(cfg(), &train).unwrap();
    let t0 = Instant::now();
    let plain = plain_sess.train(iters, None).unwrap();
    report(
        "train round, fault tolerance off (baseline)",
        t0.elapsed().as_secs_f64() / iters as f64,
        None,
    );

    // 2. Fully armed, zero chaos: the gated run.
    let mut armed_cfg = cfg();
    armed_cfg.max_respawns = 2;
    armed_cfg.approx_decode = true;
    armed_cfg.round_deadline_ms = 60_000;
    let mut armed_sess = CodedMlSession::new(armed_cfg, &train).unwrap();
    let t0 = Instant::now();
    let armed = armed_sess.train(iters, None).unwrap();
    report(
        "train round, fault tolerance armed, zero chaos",
        t0.elapsed().as_secs_f64() / iters as f64,
        None,
    );
    report_metric("approx rounds (zero chaos)", armed.approx_rounds as f64);
    report_metric("respawns (zero chaos)", armed.respawns as f64);
    report_metric(
        "deadline-expired rounds (zero chaos)",
        armed.deadline_expired_rounds as f64,
    );
    assert_eq!(
        armed.weights, plain.weights,
        "armed-but-idle fault tolerance must not perturb the trajectory"
    );

    // 3. One worker killed from iteration 1, healed mid-round: the
    //    trajectory must still be bit-identical to the baseline.
    let mut healed_cfg = cfg();
    healed_cfg.chaos_failures = 1;
    healed_cfg.chaos_from_iter = 1;
    healed_cfg.max_respawns = 2;
    let mut healed_sess = CodedMlSession::new(healed_cfg, &train).unwrap();
    let t0 = Instant::now();
    let healed = healed_sess.train(iters, None).unwrap();
    report(
        "train round, 1 kill healed mid-round",
        t0.elapsed().as_secs_f64() / iters as f64,
        None,
    );
    report_metric("respawns (healed run)", healed.respawns as f64);
    assert_eq!(
        healed.weights, plain.weights,
        "a healed pool must reproduce the fault-free trajectory exactly"
    );

    // 4. Two workers killed (beyond heal reach: no respawn budget),
    //    degraded to approximate decode from iteration 1 on.
    let mut deg_cfg = cfg();
    deg_cfg.chaos_failures = 2;
    deg_cfg.chaos_from_iter = 1;
    deg_cfg.approx_decode = true;
    let mut deg_sess = CodedMlSession::new(deg_cfg, &train).unwrap();
    let t0 = Instant::now();
    let deg = deg_sess.train(iters, None).unwrap();
    report(
        "train round, 2 kills degraded to approx decode",
        t0.elapsed().as_secs_f64() / iters as f64,
        None,
    );
    report_metric("approx rounds (degraded run)", deg.approx_rounds as f64);
    report_metric("max approx residual (degraded run)", deg.max_approx_residual);
    report_metric("worker failures (degraded run)", deg.worker_failures as f64);

    finish("supervisor");
}
