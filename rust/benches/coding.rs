//! Encode/decode benches — the "Encode" column of Tables 1–6 and the
//! master's decode cost, at paper-relevant shapes; each shape is timed
//! serial and with the thread pool (`--threads auto` equivalent) so the
//! parallel speedup is recorded side by side.

mod bench_util;
use bench_util::{bench_secs, finish, min_secs, report, report_metric, report_speedup};

use codedml::coding::{CodingBackend, CodingParams, Decoder, Encoder, EvalPoints, WorkerResult};
use codedml::field::{PrimeField, PAPER_PRIME, PRIME_NTT_25};
use codedml::util::{Parallelism, Rng};

fn main() {
    let f = PrimeField::new(PAPER_PRIME);
    let secs = min_secs();
    let auto = Parallelism::Auto;
    println!(
        "== coding (LCC encode / decode; auto = {} threads) ==",
        auto.threads()
    );

    // Dataset encode at Case-1 shapes for N ∈ {10, 40} (scaled m).
    for (n, k, t, m, d) in [
        (10usize, 3usize, 1usize, 1236usize, 784usize),
        (40, 13, 1, 1235, 784),
        (40, 7, 7, 1239, 784),
    ] {
        let params = CodingParams::new(n, k, t, 1).unwrap();
        let mut rng = Rng::new(2);
        let m = (m / k) * k;
        let xq = f.random_matrix(&mut rng, m, d);
        // Work: (K+T) muls per output element × N shares × block size.
        let work = (n * (m / k) * d * (k + t)) as f64;
        let mut times = [0.0f64; 2];
        for (i, par) in [Parallelism::Serial, auto].into_iter().enumerate() {
            let enc = Encoder::new(f, params).with_parallelism(par);
            let tsec = bench_secs(secs, || {
                std::hint::black_box(enc.encode_dataset(&xq, m, d, &mut rng));
            });
            times[i] = tsec;
            report(
                &format!("encode_dataset N={n} K={k} T={t} m={m} d={d} [{par}]"),
                tsec,
                Some(work),
            );
        }
        report_speedup(
            &format!("encode_dataset N={n} K={k} T={t} parallel speedup"),
            times[0],
            times[1],
        );
    }

    // Weight encode (per-iteration cost).
    for (n, k, t, d, r) in [(10usize, 3usize, 1usize, 1568usize, 1usize), (40, 7, 7, 1568, 1)] {
        let params = CodingParams::new(n, k, t, r).unwrap();
        let mut rng = Rng::new(3);
        let wq = f.random_matrix(&mut rng, d, r);
        let mut times = [0.0f64; 2];
        for (i, par) in [Parallelism::Serial, auto].into_iter().enumerate() {
            let enc = Encoder::new(f, params).with_parallelism(par);
            let tsec = bench_secs(secs, || {
                std::hint::black_box(enc.encode_weights(&wq, d, r, &mut rng));
            });
            times[i] = tsec;
            report(
                &format!("encode_weights N={n} K={k} T={t} d={d} [{par}]"),
                tsec,
                Some((n * d * (t + 1)) as f64),
            );
        }
        report_speedup(
            &format!("encode_weights N={n} K={k} T={t} parallel speedup"),
            times[0],
            times[1],
        );
    }

    // Decode at recovery-threshold sizes (cold = new subset, warm = cached).
    for (n, k, t, d) in [(10usize, 3usize, 1usize, 784usize), (40, 13, 1, 1568), (40, 7, 7, 1568)] {
        let params = CodingParams::new(n, k, t, 1).unwrap();
        let enc = Encoder::new(f, params);
        let mut rng = Rng::new(4);
        let need = params.recovery_threshold();
        let results: Vec<WorkerResult> = (0..need)
            .map(|w| WorkerResult { worker: w, data: f.random_matrix(&mut rng, d, 1) })
            .collect();
        let mut times = [0.0f64; 2];
        for (i, par) in [Parallelism::Serial, auto].into_iter().enumerate() {
            let mut dec = Decoder::new(f, params, enc.points.clone()).with_parallelism(par);
            let tsec = bench_secs(secs, || {
                std::hint::black_box(dec.decode(&results, d).unwrap());
            });
            times[i] = tsec;
            report(
                &format!("decode warm-cache N={n} K={k} T={t} d={d} (R={need}) [{par}]"),
                tsec,
                Some((k * need * d) as f64),
            );
        }
        report_speedup(
            &format!("decode warm-cache N={n} K={k} T={t} parallel speedup"),
            times[0],
            times[1],
        );
        // Cold path: rotate subsets so every decode misses the cache.
        let all: Vec<WorkerResult> = (0..n)
            .map(|w| WorkerResult { worker: w, data: f.random_matrix(&mut rng, d, 1) })
            .collect();
        let mut start = 0usize;
        let slack = n - need;
        if slack > 0 {
            let mut dec = Decoder::new(f, params, enc.points.clone());
            let tsec = bench_secs(secs, || {
                let subset: Vec<WorkerResult> = (0..need)
                    .map(|i| all[(start + i) % n].clone())
                    .collect();
                start += 1;
                std::hint::black_box(dec.decode(&subset, d).unwrap());
            });
            report(
                &format!("decode cold-cache N={n} K={k} T={t} d={d} (R={need})"),
                tsec,
                None,
            );
        }
    }

    // NTT coset layout vs dense Lagrange at a large shape (K+T = 64,
    // N = 192 → l1 = 64, l2 = 256 on the 25-bit NTT prime). The CI bench
    // smoke job gates on the engaged metric and the speedup row below.
    {
        let (n, k, t, d) = (192usize, 48usize, 16usize, 256usize);
        let fntt = PrimeField::new(PRIME_NTT_25);
        let params = CodingParams::new(n, k, t, 1).unwrap();
        let mut rng = Rng::new(5);
        let m = 2 * k; // 2 rows per block: encode cost scales per element
        let xq = fntt.random_matrix(&mut rng, m, d);
        let pts = EvalPoints::ntt_coset(&fntt, k, t, n).expect("2-adicity 21 hosts l2=256");
        let auto_enc = Encoder::with_points(fntt, params, pts.clone());
        report_metric(
            &format!("ntt backend engaged (K={k} T={t} N={n} p={})", fntt.modulus()),
            (auto_enc.backend() == CodingBackend::Ntt) as u64 as f64,
        );
        let dense_enc = Encoder::with_points(fntt, params, pts.clone()).force_dense();
        let ntt_enc = auto_enc;

        let work = (n * (m / k) * d * (k + t)) as f64;
        let t_dense_enc = bench_secs(secs, || {
            std::hint::black_box(dense_enc.encode_dataset(&xq, m, d, &mut rng));
        });
        report(&format!("encode_dataset dense K={k} T={t} N={n} d={d}"), t_dense_enc, Some(work));
        let t_ntt_enc = bench_secs(secs, || {
            std::hint::black_box(ntt_enc.encode_dataset(&xq, m, d, &mut rng));
        });
        report(&format!("encode_dataset ntt   K={k} T={t} N={n} d={d}"), t_ntt_enc, Some(work));
        report_speedup(&format!("encode ntt vs dense K={k} T={t} N={n}"), t_dense_enc, t_ntt_enc);

        // Decode-row construction: cold cache each iteration so the
        // coefficient build (O(K·R²) dense vs barycentric closed form)
        // dominates, rotating the straggler subset.
        let need = params.recovery_threshold();
        let all: Vec<WorkerResult> = (0..n)
            .map(|w| WorkerResult { worker: w, data: fntt.random_matrix(&mut rng, d, 1) })
            .collect();
        let mut t_decode = [0.0f64; 2];
        for (i, coset) in [false, true].into_iter().enumerate() {
            let points = if coset {
                pts.clone()
            } else {
                // Dense-rows baseline: same alphas, but with the coset
                // geometry hidden the decoder takes the generic
                // lagrange_coeffs path.
                EvalPoints { betas: pts.betas.clone(), alphas: pts.alphas.clone(), coset: None }
            };
            let mut dec = Decoder::new(fntt, params, points).with_cache_cap(1);
            let mut start = 0usize;
            t_decode[i] = bench_secs(secs, || {
                let subset: Vec<WorkerResult> =
                    (0..need).map(|j| all[(start + j) % n].clone()).collect();
                start += 1;
                std::hint::black_box(dec.decode(&subset, d).unwrap());
            });
            report(
                &format!(
                    "decode cold-cache {} K={k} T={t} N={n} (R={need})",
                    if coset { "coset" } else { "dense" }
                ),
                t_decode[i],
                None,
            );
        }
        report_speedup(&format!("decode ntt vs dense K={k} T={t} N={n}"), t_decode[0], t_decode[1]);
        report_speedup(
            &format!("ntt vs dense encode+decode K={k} T={t} N={n}"),
            t_dense_enc + t_decode[0],
            t_ntt_enc + t_decode[1],
        );
    }

    finish("coding");
}
