//! Encode/decode benches — the "Encode" column of Tables 1–6 and the
//! master's decode cost, at paper-relevant shapes; each shape is timed
//! serial and with the thread pool (`--threads auto` equivalent) so the
//! parallel speedup is recorded side by side.

mod bench_util;
use bench_util::{bench_secs, finish, min_secs, report, report_speedup};

use codedml::coding::{CodingParams, Decoder, Encoder, WorkerResult};
use codedml::field::{PrimeField, PAPER_PRIME};
use codedml::util::{Parallelism, Rng};

fn main() {
    let f = PrimeField::new(PAPER_PRIME);
    let secs = min_secs();
    let auto = Parallelism::Auto;
    println!(
        "== coding (LCC encode / decode; auto = {} threads) ==",
        auto.threads()
    );

    // Dataset encode at Case-1 shapes for N ∈ {10, 40} (scaled m).
    for (n, k, t, m, d) in [
        (10usize, 3usize, 1usize, 1236usize, 784usize),
        (40, 13, 1, 1235, 784),
        (40, 7, 7, 1239, 784),
    ] {
        let params = CodingParams::new(n, k, t, 1).unwrap();
        let mut rng = Rng::new(2);
        let m = (m / k) * k;
        let xq = f.random_matrix(&mut rng, m, d);
        // Work: (K+T) muls per output element × N shares × block size.
        let work = (n * (m / k) * d * (k + t)) as f64;
        let mut times = [0.0f64; 2];
        for (i, par) in [Parallelism::Serial, auto].into_iter().enumerate() {
            let enc = Encoder::new(f, params).with_parallelism(par);
            let tsec = bench_secs(secs, || {
                std::hint::black_box(enc.encode_dataset(&xq, m, d, &mut rng));
            });
            times[i] = tsec;
            report(
                &format!("encode_dataset N={n} K={k} T={t} m={m} d={d} [{par}]"),
                tsec,
                Some(work),
            );
        }
        report_speedup(
            &format!("encode_dataset N={n} K={k} T={t} parallel speedup"),
            times[0],
            times[1],
        );
    }

    // Weight encode (per-iteration cost).
    for (n, k, t, d, r) in [(10usize, 3usize, 1usize, 1568usize, 1usize), (40, 7, 7, 1568, 1)] {
        let params = CodingParams::new(n, k, t, r).unwrap();
        let mut rng = Rng::new(3);
        let wq = f.random_matrix(&mut rng, d, r);
        let mut times = [0.0f64; 2];
        for (i, par) in [Parallelism::Serial, auto].into_iter().enumerate() {
            let enc = Encoder::new(f, params).with_parallelism(par);
            let tsec = bench_secs(secs, || {
                std::hint::black_box(enc.encode_weights(&wq, d, r, &mut rng));
            });
            times[i] = tsec;
            report(
                &format!("encode_weights N={n} K={k} T={t} d={d} [{par}]"),
                tsec,
                Some((n * d * (t + 1)) as f64),
            );
        }
        report_speedup(
            &format!("encode_weights N={n} K={k} T={t} parallel speedup"),
            times[0],
            times[1],
        );
    }

    // Decode at recovery-threshold sizes (cold = new subset, warm = cached).
    for (n, k, t, d) in [(10usize, 3usize, 1usize, 784usize), (40, 13, 1, 1568), (40, 7, 7, 1568)] {
        let params = CodingParams::new(n, k, t, 1).unwrap();
        let enc = Encoder::new(f, params);
        let mut rng = Rng::new(4);
        let need = params.recovery_threshold();
        let results: Vec<WorkerResult> = (0..need)
            .map(|w| WorkerResult { worker: w, data: f.random_matrix(&mut rng, d, 1) })
            .collect();
        let mut times = [0.0f64; 2];
        for (i, par) in [Parallelism::Serial, auto].into_iter().enumerate() {
            let mut dec = Decoder::new(f, params, enc.points.clone()).with_parallelism(par);
            let tsec = bench_secs(secs, || {
                std::hint::black_box(dec.decode(&results, d).unwrap());
            });
            times[i] = tsec;
            report(
                &format!("decode warm-cache N={n} K={k} T={t} d={d} (R={need}) [{par}]"),
                tsec,
                Some((k * need * d) as f64),
            );
        }
        report_speedup(
            &format!("decode warm-cache N={n} K={k} T={t} parallel speedup"),
            times[0],
            times[1],
        );
        // Cold path: rotate subsets so every decode misses the cache.
        let all: Vec<WorkerResult> = (0..n)
            .map(|w| WorkerResult { worker: w, data: f.random_matrix(&mut rng, d, 1) })
            .collect();
        let mut start = 0usize;
        let slack = n - need;
        if slack > 0 {
            let mut dec = Decoder::new(f, params, enc.points.clone());
            let tsec = bench_secs(secs, || {
                let subset: Vec<WorkerResult> = (0..need)
                    .map(|i| all[(start + i) % n].clone())
                    .collect();
                start += 1;
                std::hint::black_box(dec.decode(&subset, d).unwrap());
            });
            report(
                &format!("decode cold-cache N={n} K={k} T={t} d={d} (R={need})"),
                tsec,
                None,
            );
        }
    }

    finish("coding");
}
