//! Shared mini-bench harness (criterion is unavailable offline).
//!
//! Each bench target is a `harness = false` binary that times closures
//! with warmup + minimum-duration repetition and prints aligned rows:
//!
//! ```text
//! name                                 time/iter        throughput
//! ```

use std::time::Instant;

/// Time `f` for at least `min_secs` (and ≥ 3 iters); returns secs/iter.
pub fn bench_secs(min_secs: f64, mut f: impl FnMut()) -> f64 {
    f(); // warmup
    let mut iters = 0u32;
    let t0 = Instant::now();
    loop {
        f();
        iters += 1;
        let elapsed = t0.elapsed().as_secs_f64();
        if iters >= 3 && elapsed >= min_secs {
            return elapsed / iters as f64;
        }
    }
}

/// Pretty-print one result row. `work` is optional items/op for
/// throughput (e.g. field multiplications).
pub fn report(name: &str, secs_per_iter: f64, work: Option<f64>) {
    let time = if secs_per_iter >= 1.0 {
        format!("{secs_per_iter:.3} s")
    } else if secs_per_iter >= 1e-3 {
        format!("{:.3} ms", secs_per_iter * 1e3)
    } else {
        format!("{:.3} µs", secs_per_iter * 1e6)
    };
    match work {
        Some(w) => {
            let rate = w / secs_per_iter;
            let rate_s = if rate >= 1e9 {
                format!("{:.2} Gop/s", rate / 1e9)
            } else if rate >= 1e6 {
                format!("{:.2} Mop/s", rate / 1e6)
            } else {
                format!("{:.2} Kop/s", rate / 1e3)
            };
            println!("{name:<52} {time:>12}   {rate_s:>12}");
        }
        None => println!("{name:<52} {time:>12}"),
    }
}

/// Environment knob: `BENCH_SECS` (default 0.3) — raise for stabler
/// numbers in the §Perf runs.
pub fn min_secs() -> f64 {
    std::env::var("BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.3)
}
