//! Shared mini-bench harness (criterion is unavailable offline).
//!
//! Each bench target is a `harness = false` binary that times closures
//! with warmup + minimum-duration repetition and prints aligned rows:
//!
//! ```text
//! name                                 time/iter        throughput
//! ```
//!
//! With `BENCH_JSON=1` in the environment, every reported row is also
//! collected and written to `BENCH_<target>.json` by [`finish`] — machine-
//! readable before/after records for perf work (e.g. the Barrett-vs-divide
//! and serial-vs-parallel comparisons; see README.md § Benchmarks).

#![allow(dead_code)] // each bench binary uses a subset of these helpers

use std::sync::Mutex;
use std::time::Instant;

/// Rows collected for the JSON report:
/// (name, value, work items/iter, is_metric). Timing rows carry secs/iter
/// in `value`; metric rows (cache counters, ratios) carry a plain number
/// and are emitted under a `value` key instead of `secs_per_iter`.
static LOG: Mutex<Vec<(String, f64, Option<f64>, bool)>> = Mutex::new(Vec::new());

/// Time `f` for at least `min_secs` (and ≥ 3 iters); returns secs/iter.
pub fn bench_secs(min_secs: f64, mut f: impl FnMut()) -> f64 {
    f(); // warmup
    let mut iters = 0u32;
    let t0 = Instant::now();
    loop {
        f();
        iters += 1;
        let elapsed = t0.elapsed().as_secs_f64();
        if iters >= 3 && elapsed >= min_secs {
            return elapsed / iters as f64;
        }
    }
}

/// Pretty-print one result row. `work` is optional items/op for
/// throughput (e.g. field multiplications).
pub fn report(name: &str, secs_per_iter: f64, work: Option<f64>) {
    LOG.lock()
        .expect("bench log poisoned")
        .push((name.to_string(), secs_per_iter, work, false));
    let time = if secs_per_iter >= 1.0 {
        format!("{secs_per_iter:.3} s")
    } else if secs_per_iter >= 1e-3 {
        format!("{:.3} ms", secs_per_iter * 1e3)
    } else {
        format!("{:.3} µs", secs_per_iter * 1e6)
    };
    match work {
        Some(w) => {
            let rate = w / secs_per_iter;
            let rate_s = if rate >= 1e9 {
                format!("{:.2} Gop/s", rate / 1e9)
            } else if rate >= 1e6 {
                format!("{:.2} Mop/s", rate / 1e6)
            } else {
                format!("{:.2} Kop/s", rate / 1e3)
            };
            println!("{name:<52} {time:>12}   {rate_s:>12}");
        }
        None => println!("{name:<52} {time:>12}"),
    }
}

/// Print a derived speedup line (baseline / contender) and log it as a
/// dimensionless row so the ratio lands in the JSON record too.
pub fn report_speedup(name: &str, baseline_secs: f64, contender_secs: f64) {
    let speedup = baseline_secs / contender_secs;
    LOG.lock()
        .expect("bench log poisoned")
        .push((format!("{name} [speedup x]"), speedup, None, true));
    println!("{name:<52} {speedup:>11.2}x");
}

/// Log a dimensionless metric (cache counters, drained-result counts…) so
/// it lands in the JSON record alongside the timing rows.
pub fn report_metric(name: &str, value: f64) {
    LOG.lock()
        .expect("bench log poisoned")
        .push((name.to_string(), value, None, true));
    println!("{name:<52} {value:>12.2}");
}

/// If `BENCH_JSON` is set, write the collected rows to
/// `BENCH_<target>.json` in the working directory. Call once at the end
/// of each bench `main`.
pub fn finish(target: &str) {
    if std::env::var("BENCH_JSON").is_err() {
        return;
    }
    let rows = LOG.lock().expect("bench log poisoned");
    let mut out = String::from("{\n  \"rows\": [\n");
    for (i, (name, value, work, is_metric)) in rows.iter().enumerate() {
        let esc: String = name
            .chars()
            .flat_map(|c| match c {
                '"' | '\\' => vec!['\\', c],
                _ => vec![c],
            })
            .collect();
        let key = if *is_metric { "value" } else { "secs_per_iter" };
        out.push_str(&format!("    {{\"name\": \"{esc}\", \"{key}\": {value:e}"));
        if let Some(w) = work {
            out.push_str(&format!(", \"ops_per_sec\": {:e}", w / value));
        }
        out.push('}');
        if i + 1 < rows.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("  ]\n}\n");
    let path = format!("BENCH_{target}.json");
    match std::fs::write(&path, out) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}

/// Environment knob: `BENCH_SECS` (default 0.3) — raise for stabler
/// numbers in the §Perf runs.
pub fn min_secs() -> f64 {
    std::env::var("BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.3)
}
