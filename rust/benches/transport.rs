//! Transport bench: in-memory channel vs loopback TCP, dispatch +
//! `collect_first` round latency at a moderate share size, plus the
//! bytes-on-wire per iteration that both backends account through the
//! same frame-layout arithmetic.
//!
//! The TCP rows answer the deployment question the in-memory default
//! cannot: what does a real socket hop (syscalls, framing, copies) cost
//! per training round, and how many bytes does one iteration move?

mod bench_util;
use bench_util::{finish, report, report_metric, report_speedup};

use std::io::{BufRead, BufReader};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::Instant;

use codedml::cluster::transport::TcpConfig;
use codedml::cluster::{Cluster, TransportConfig, TransportKind, WorkerOp, WorkerSpec};
use codedml::coding::{CodingParams, Encoder};
use codedml::field::{PrimeField, PAPER_PRIME};
use codedml::runtime::BackendKind;
use codedml::util::{Parallelism, Rng};

struct WorkerProc {
    child: Child,
    addr: String,
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_worker() -> WorkerProc {
    let mut child = Command::new(env!("CARGO_BIN_EXE_codedml"))
        .args(["--worker", "--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let stdout = child.stdout.take().unwrap();
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).unwrap();
    let addr = line.trim().rsplit(' ').next().unwrap_or("").to_string();
    assert!(addr.contains(':'), "unexpected worker banner: {line:?}");
    WorkerProc { child, addr }
}

fn specs(n: usize, rows: usize, d: usize, coeffs: &[u64]) -> Vec<WorkerSpec> {
    let f = PrimeField::new(PAPER_PRIME);
    (0..n)
        .map(|id| WorkerSpec {
            id,
            session: 0,
            kind: BackendKind::Native,
            artifact_dir: PathBuf::from("artifacts"),
            field: f,
            rows,
            d,
            coeffs: coeffs.to_vec(),
            op: WorkerOp::Logistic,
            fail_from_iter: None,
            slow_ms: 0,
            par: Parallelism::Serial,
        })
        .collect()
}

fn main() {
    let f = PrimeField::new(PAPER_PRIME);
    let (n, k, t) = (5usize, 1usize, 1usize);
    let params = CodingParams::new(n, k, t, 1).unwrap();
    let need = params.recovery_threshold();
    let (rows, d) = (256usize, 512usize);
    let m = rows * k;
    let coeffs = vec![3u64, 7];
    let iters = 30u64;

    println!("== transport (N={n} K={k} T={t}, R={need}, {rows}x{d} shares) ==");

    let mut rng = Rng::new(17);
    let xq = f.random_matrix(&mut rng, m, d);
    let enc = Encoder::new(f, params);
    let x_shares: Vec<Vec<u64>> = enc
        .encode_dataset(&xq, m, d, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();
    let w_shares: Vec<Vec<u64>> = enc
        .encode_weights(&f.random_matrix(&mut rng, d, 1), d, 1, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();

    let mut times = [0.0f64; 2];
    let mut per_iter_bytes = [0.0f64; 2];
    for mode in 0..2usize {
        let (label, mut cluster, _procs) = if mode == 0 {
            let procs: Vec<WorkerProc> = (0..n).map(|_| spawn_worker()).collect();
            let cfg = TransportConfig {
                kind: TransportKind::Tcp,
                tcp: TcpConfig {
                    workers: procs.iter().map(|p| p.addr.clone()).collect(),
                    ..TcpConfig::default()
                },
            };
            let cluster = Cluster::connect(specs(n, rows, d, &coeffs), &cfg).unwrap();
            ("loopback tcp", cluster, procs)
        } else {
            let cluster = Cluster::spawn(specs(n, rows, d, &coeffs)).unwrap();
            ("in-memory channel", cluster, Vec::new())
        };
        cluster.load_data(x_shares.clone(), None).unwrap();
        // Warmup round (thread scheduling, socket buffers).
        cluster.dispatch(0, w_shares.clone()).unwrap();
        cluster.collect_first(need, 0).unwrap();

        let (sent0, recv0) = cluster.wire_bytes();
        let t0 = Instant::now();
        for iter in 1..=iters {
            cluster.dispatch(iter, w_shares.clone()).unwrap();
            let round = cluster.collect_first(need, iter).unwrap();
            assert!(round.ok());
        }
        let secs = t0.elapsed().as_secs_f64() / iters as f64;
        let (sent1, recv1) = cluster.wire_bytes();
        times[mode] = secs;
        per_iter_bytes[mode] = ((sent1 - sent0) + (recv1 - recv0)) as f64 / iters as f64;
        report(&format!("train round [{label}]"), secs, None);
    }

    report_speedup("transport in-memory vs loopback tcp", times[0], times[1]);
    report_metric("bytes on wire per iteration [loopback tcp]", per_iter_bytes[0]);
    report_metric("bytes on wire per iteration [in-memory model]", per_iter_bytes[1]);

    finish("transport");
}
