//! Microbenches for the F_p substrate: scalar ops, batch inversion,
//! Lagrange coefficient computation, interpolation. These are the inner
//! loops of encode/decode — see EXPERIMENTS.md §Perf.

mod bench_util;
use bench_util::{bench_secs, finish, min_secs, report, report_speedup};

use codedml::field::{
    eval_poly, interpolate, lagrange_coeffs, simd, NttPlan, PrimeField, PAPER_PRIME, PRIME_31,
    PRIME_NTT_25,
};
use codedml::util::Rng;

fn main() {
    let f = PrimeField::new(PAPER_PRIME);
    let mut rng = Rng::new(1);
    let secs = min_secs();
    println!("== field_ops (p = {}) ==", f.modulus());

    // Barrett vs division-based reduction — the tentpole before/after.
    // Same chain, one using the precomputed mul-high path (`mul`), one the
    // hardware divide (`mul_divrem`).
    for &p in &[PAPER_PRIME, PRIME_31] {
        let fp = PrimeField::new(p);
        let xs: Vec<u64> = (0..4096).map(|_| fp.random(&mut rng)).collect();
        let t_barrett = bench_secs(secs, || {
            let mut acc = 1u64;
            for &x in &xs {
                acc = fp.mul(acc, x);
            }
            std::hint::black_box(acc);
        });
        report(&format!("mul chain barrett (4096 elems, p={p})"), t_barrett, Some(4096.0));
        let t_div = bench_secs(secs, || {
            let mut acc = 1u64;
            for &x in &xs {
                acc = fp.mul_divrem(acc, x);
            }
            std::hint::black_box(acc);
        });
        report(&format!("mul chain divrem  (4096 elems, p={p})"), t_div, Some(4096.0));
        report_speedup(&format!("barrett vs divrem mul (p={p})"), t_div, t_barrett);

        let raw: Vec<u64> = (0..4096).map(|_| rng.next_u64()).collect();
        let t_barrett = bench_secs(secs, || {
            let mut acc = 0u64;
            for &x in &raw {
                acc = acc.wrapping_add(fp.reduce_u64(x));
            }
            std::hint::black_box(acc);
        });
        report(&format!("reduce_u64 barrett (4096 elems, p={p})"), t_barrett, Some(4096.0));
        let t_div = bench_secs(secs, || {
            let mut acc = 0u64;
            for &x in &raw {
                acc = acc.wrapping_add(fp.reduce_u64_divrem(x));
            }
            std::hint::black_box(acc);
        });
        report(&format!("reduce_u64 divrem  (4096 elems, p={p})"), t_div, Some(4096.0));
        report_speedup(&format!("barrett vs divrem reduce (p={p})"), t_div, t_barrett);
    }

    // Scalar multiply-add chain.
    let xs: Vec<u64> = (0..4096).map(|_| f.random(&mut rng)).collect();
    let t = bench_secs(secs, || {
        let mut acc = 1u64;
        for &x in &xs {
            acc = f.mul(acc, x);
            acc = f.add(acc, x);
        }
        std::hint::black_box(acc);
    });
    report("mul+add chain (4096 elems)", t, Some(2.0 * 4096.0));

    // Single inversions vs batch.
    let inv_in: Vec<u64> = (0..256).map(|_| 1 + rng.below(f.modulus() - 1)).collect();
    let t = bench_secs(secs, || {
        for &x in &inv_in {
            std::hint::black_box(f.inv(x));
        }
    });
    report("inv x256 (Fermat)", t, Some(256.0));
    let t = bench_secs(secs, || {
        std::hint::black_box(f.batch_inv(&inv_in));
    });
    report("batch_inv x256 (Montgomery trick)", t, Some(256.0));

    // Lagrange basis coefficients at decode sizes (R = threshold).
    for r in [10usize, 22, 40] {
        let pts: Vec<u64> = f.distinct_points(r);
        let t = bench_secs(secs, || {
            std::hint::black_box(lagrange_coeffs(&f, &pts, 999_983).unwrap());
        });
        report(&format!("lagrange_coeffs (R={r})"), t, None);
    }

    // Full interpolation (diagnostics path).
    for n in [16usize, 40] {
        let pts = f.distinct_points(n);
        let vals: Vec<u64> = (0..n).map(|_| f.random(&mut rng)).collect();
        let t = bench_secs(secs, || {
            std::hint::black_box(interpolate(&f, &pts, &vals).unwrap());
        });
        report(&format!("interpolate (n={n})"), t, None);
    }

    // Horner evaluation.
    let coeffs: Vec<u64> = (0..64).map(|_| f.random(&mut rng)).collect();
    let t = bench_secs(secs, || {
        std::hint::black_box(eval_poly(&f, &coeffs, 12345));
    });
    report("eval_poly (deg 63)", t, Some(63.0));

    // Lane kernels vs the always-compiled scalar oracles — the deferred-
    // reduction MAC is the inner loop of encode, decode and worker matmul.
    let fp = PrimeField::new(PAPER_PRIME);
    let src: Vec<u64> = (0..4096).map(|_| fp.random(&mut rng)).collect();
    let wts: Vec<u64> = (0..4096).map(|_| fp.random(&mut rng)).collect();
    let mut acc = vec![0u64; 4096];
    let t_lanes = bench_secs(secs, || {
        simd::lanes::mac_wrapping(&mut acc, &src, 12345);
        std::hint::black_box(&mut acc);
    });
    report("mac_wrapping lanes (4096 elems)", t_lanes, Some(4096.0));
    let t_scalar = bench_secs(secs, || {
        simd::scalar::mac_wrapping(&mut acc, &src, 12345);
        std::hint::black_box(&mut acc);
    });
    report("mac_wrapping scalar (4096 elems)", t_scalar, Some(4096.0));
    report_speedup("mac_wrapping lanes vs scalar", t_scalar, t_lanes);
    let t_lanes = bench_secs(secs, || {
        std::hint::black_box(simd::lanes::dot_wrapping(&src, &wts));
    });
    report("dot_wrapping lanes (4096 elems)", t_lanes, Some(4096.0));
    let t_scalar = bench_secs(secs, || {
        std::hint::black_box(simd::scalar::dot_wrapping(&src, &wts));
    });
    report("dot_wrapping scalar (4096 elems)", t_scalar, Some(4096.0));
    report_speedup("dot_wrapping lanes vs scalar", t_scalar, t_lanes);

    // Radix-2 NTT butterflies vs dense evaluation at the same length —
    // the asymptotic separation behind the coding-layer speedup.
    let fntt = PrimeField::new(PRIME_NTT_25);
    for logn in [6u32, 8] {
        let n = 1usize << logn;
        let plan = NttPlan::new(fntt, n).expect("2-adicity 21 covers these");
        let vals: Vec<u64> = (0..n).map(|_| fntt.random(&mut rng)).collect();
        let mut buf = vals.clone();
        let t_ntt = bench_secs(secs, || {
            buf.copy_from_slice(&vals);
            plan.forward_rows(&mut buf, 1);
            std::hint::black_box(&mut buf);
        });
        report(
            &format!("ntt forward (n={n}, p={PRIME_NTT_25})"),
            t_ntt,
            Some((n / 2 * logn as usize) as f64),
        );
        let t_rt = bench_secs(secs, || {
            buf.copy_from_slice(&vals);
            plan.forward_rows(&mut buf, 1);
            plan.inverse_rows(&mut buf, 1);
            std::hint::black_box(&mut buf);
        });
        report(&format!("ntt round trip (n={n})"), t_rt, Some((n * logn as usize) as f64));
        // Dense apples-to-apples: evaluate the same coefficients at all n
        // subgroup points by Horner.
        let pts: Vec<u64> = {
            let root = plan.root();
            let mut cur = 1u64;
            (0..n).map(|_| { let p = cur; cur = fntt.mul(cur, root); p }).collect()
        };
        let t_dense = bench_secs(secs, || {
            for &x in &pts {
                std::hint::black_box(eval_poly(&fntt, &vals, x));
            }
        });
        report(&format!("dense eval at n={n} points"), t_dense, Some((n * n) as f64));
        report_speedup(&format!("ntt vs dense eval (n={n})"), t_dense, t_ntt);
    }

    finish("field_ops");
}
