//! Worker hot-path bench: native rust kernel vs the AOT JAX/Pallas
//! artifact via PJRT, across manifest shapes. This is the per-iteration
//! per-worker cost that dominates the paper's Comp. column.

mod bench_util;
use bench_util::{bench_secs, finish, min_secs, report, report_speedup};

use codedml::compute::WorkerComputation;
use codedml::field::PrimeField;
use codedml::runtime::{ArtifactKind, XlaRuntime, PJRT_AVAILABLE};
use codedml::util::{Parallelism, Rng};
use std::path::PathBuf;

fn main() {
    let secs = min_secs();
    println!("== worker_compute: f(X̃, W̃) per call ==");

    let shapes = [
        (64usize, 784usize, 1usize),
        (128, 784, 1),
        (256, 784, 1),
        (256, 1568, 1),
        (1024, 784, 1),
        (64, 784, 2),
    ];
    let p = 15_485_863u64;
    let f = PrimeField::new(p);
    let mut rng = Rng::new(5);

    let rt = {
        let dir = PathBuf::from("artifacts");
        if !PJRT_AVAILABLE {
            eprintln!("pjrt feature not compiled in; native only");
            None
        } else if dir.join("manifest.json").exists() {
            match XlaRuntime::new(&dir) {
                Ok(rt) => Some(rt),
                Err(e) => {
                    eprintln!("xla runtime unavailable: {e}");
                    None
                }
            }
        } else {
            eprintln!("artifacts/ not built; native only");
            None
        }
    };

    for (rows, d, r) in shapes {
        let x = f.random_matrix(&mut rng, rows, d);
        let w = f.random_matrix(&mut rng, d, r);
        let coeffs: Vec<u64> = (0..=r).map(|_| f.random(&mut rng)).collect();
        // Work: (r+1) row-dots + transpose pass ≈ (r+2)·rows·d MACs.
        let work = ((r + 2) * rows * d) as f64;

        let wc = WorkerComputation::new(f, rows, d, coeffs.clone());
        let t = bench_secs(secs, || {
            std::hint::black_box(wc.compute(&x, &w));
        });
        report(&format!("native rows={rows} d={d} r={r} [serial]"), t, Some(work));

        let wc_par =
            WorkerComputation::new(f, rows, d, coeffs.clone()).with_parallelism(Parallelism::Auto);
        let t_par = bench_secs(secs, || {
            std::hint::black_box(wc_par.compute(&x, &w));
        });
        report(&format!("native rows={rows} d={d} r={r} [auto]"), t_par, Some(work));
        report_speedup(&format!("native rows={rows} d={d} r={r} parallel speedup"), t, t_par);

        if let Some(rt) = &rt {
            let has = rt
                .manifest()
                .entries
                .iter()
                .any(|e| e.kind == ArtifactKind::WorkerF && e.rows == rows && e.d == d && e.r == r);
            if has {
                let t = bench_secs(secs, || {
                    std::hint::black_box(rt.worker_f(&x, &w, &coeffs, rows, d, p).unwrap());
                });
                report(&format!("xla    rows={rows} d={d} r={r}"), t, Some(work));
            }
        }
    }

    finish("worker_compute");
}
