//! Resilience: the recovery threshold is exactly the fault-tolerance
//! boundary. Killing up to `N − R` workers mid-training must not change
//! the *trajectory at all* (LCC decode is subset-invariant); killing one
//! more must fail loudly, not corrupt gradients.
//!
//! The TCP chaos tests exercise the same boundary over the real wire:
//! a worker *process* killed mid-training and a connect timeout at spawn
//! must both land in `TrainReport::worker_failures` (never a panic, never
//! a deadlocked `collect_first`).

use std::io::{BufRead, BufReader};
use std::net::TcpListener;
use std::process::{Child, Command, Stdio};

use codedml::cluster::transport::TcpConfig;
use codedml::cluster::{NetworkModel, StragglerModel, TransportConfig, TransportKind};
use codedml::coordinator::{CodedMlConfig, CodedMlSession};
use codedml::data::synthetic_3v7;

fn base_cfg() -> CodedMlConfig {
    CodedMlConfig {
        n: 13, // threshold 3·3+1 = 10 → slack 3
        k: 3,
        t: 1,
        net: NetworkModel::free(),
        straggler: StragglerModel::none(),
        ..Default::default()
    }
}

#[test]
fn surviving_within_slack_preserves_trajectory_exactly() {
    let train = synthetic_3v7(120, 17);

    let mut healthy = CodedMlSession::new(base_cfg(), &train).unwrap();
    let ref_report = healthy.train(6, None).unwrap();
    assert_eq!(ref_report.worker_failures, 0);

    // Kill 3 workers (exactly the slack) from iteration 2 on.
    let cfg = CodedMlConfig { chaos_failures: 3, chaos_from_iter: 2, ..base_cfg() };
    let mut wounded = CodedMlSession::new(cfg, &train).unwrap();
    wounded.set_tracer(codedml::coordinator::Tracer::memory());
    let report = wounded.train(6, None).unwrap();

    assert_eq!(
        ref_report.weights, report.weights,
        "trajectory must be identical with slack-many failures"
    );
    // Failures don't vanish: counted in the report (3 per iteration from
    // iteration 2 on) and emitted as structured tracer events. An Err
    // landing after its round completed is drained — and still counted —
    // by the next round, so only the final iteration's in-flight failures
    // can escape the tally.
    let fails = report.worker_failures;
    assert!((9..=12).contains(&fails), "worker_failures = {fails}");
    let failure_events: Vec<_> = wounded
        .tracer()
        .events()
        .iter()
        .filter(|e| e.get("event").unwrap().as_str() == Some("worker_failure"))
        .collect();
    assert_eq!(failure_events.len() as u64, fails);
    assert!(failure_events[0].get("worker").unwrap().as_u64().unwrap() < 3);
    assert_eq!(
        failure_events[0].get("error").unwrap().as_str(),
        Some("injected fault")
    );
}

#[test]
fn one_failure_beyond_slack_errors() {
    let train = synthetic_3v7(120, 18);
    let cfg = CodedMlConfig { chaos_failures: 4, chaos_from_iter: 1, ..base_cfg() };
    let mut sess = CodedMlSession::new(cfg, &train).unwrap();
    // First iteration fine; the second must report the shortage.
    assert!(sess.step().is_ok());
    let err = sess.step().unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("10"), "should mention the threshold: {msg}");
}

#[test]
fn failures_from_start_with_zero_slack_fail_immediately() {
    let train = synthetic_3v7(120, 19);
    let mut cfg = base_cfg();
    cfg.n = 10; // threshold 10 → zero slack
    cfg.chaos_failures = 1;
    cfg.chaos_from_iter = 0;
    let mut sess = CodedMlSession::new(cfg, &train).unwrap();
    assert!(sess.step().is_err());
}

// --- TCP chaos: the same fault-tolerance boundary over real sockets ---

struct WorkerProc {
    child: Child,
    addr: String,
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_worker() -> WorkerProc {
    let mut child = Command::new(env!("CARGO_BIN_EXE_codedml"))
        .args(["--worker", "--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let stdout = child.stdout.take().unwrap();
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).unwrap();
    let addr = line.trim().rsplit(' ').next().unwrap_or("").to_string();
    assert!(addr.contains(':'), "unexpected worker banner: {line:?}");
    WorkerProc { child, addr }
}

fn tcp_cfg(n: usize, addrs: Vec<String>) -> CodedMlConfig {
    CodedMlConfig {
        n, // k=1, t=1 → threshold 4 → slack 1
        k: 1,
        t: 1,
        net: NetworkModel::free(),
        straggler: StragglerModel::none(),
        transport: TransportConfig {
            kind: TransportKind::Tcp,
            tcp: TcpConfig { workers: addrs, ..TcpConfig::default() },
        },
        ..Default::default()
    }
}

/// A worker process killed mid-training is one failure per remaining
/// round — counted, not fatal — and the trajectory stays bit-identical to
/// an in-memory run with the same seed (LCC decode is subset-invariant).
#[test]
fn tcp_worker_killed_mid_training_is_counted_not_fatal() {
    let train = synthetic_3v7(40, 23);
    let n = 5usize;

    let mem_cfg = CodedMlConfig { transport: TransportConfig::default(), ..tcp_cfg(n, Vec::new()) };
    let mut reference = CodedMlSession::new(mem_cfg, &train).unwrap();

    let mut procs: Vec<WorkerProc> = (0..n).map(|_| spawn_worker()).collect();
    let addrs = procs.iter().map(|p| p.addr.clone()).collect();
    let mut tcp = CodedMlSession::new(tcp_cfg(n, addrs), &train).unwrap();

    reference.step().unwrap();
    tcp.step().unwrap();

    // Kill one worker's process — within the slack of 1.
    let _ = procs[2].child.kill();
    let _ = procs[2].child.wait();

    for _ in 0..3 {
        reference.step().unwrap();
        tcp.step().unwrap();
    }

    assert_eq!(
        reference.w, tcp.w,
        "a dead socket must not change the trajectory, only who is decoded"
    );
    let (failures, _) = tcp.round_stats();
    assert!(failures > 0, "the killed process must be counted, got {failures}");
    let (rf, _) = reference.round_stats();
    assert_eq!(rf, 0);
}

/// A connect timeout at spawn marks the worker down rather than aborting:
/// the session builds, trains to completion without deadlocking, and the
/// unreachable worker is charged one failure per iteration.
#[test]
fn tcp_connect_timeout_at_spawn_lands_in_worker_failures() {
    let train = synthetic_3v7(40, 29);
    let n = 5usize;

    // Four live workers plus one address nothing listens on: bind an
    // ephemeral port, then drop the listener before the master dials it.
    let procs: Vec<WorkerProc> = (0..n - 1).map(|_| spawn_worker()).collect();
    let dead_addr = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let mut addrs: Vec<String> = procs.iter().map(|p| p.addr.clone()).collect();
    addrs.push(dead_addr);

    let mut cfg = tcp_cfg(n, addrs);
    cfg.transport.tcp.connect_timeout_ms = 300;
    cfg.transport.tcp.connect_retries = 1;
    cfg.transport.tcp.connect_backoff_ms = 10;

    let mut sess = CodedMlSession::new(cfg, &train).unwrap();
    let report = sess.train(3, None).unwrap();
    assert!(
        report.worker_failures >= 3,
        "one failure per iteration for the unreachable worker, got {}",
        report.worker_failures
    );
}

// --- Aggressive chaos: sustained loss beyond the slack, both transports ---
//
// These run at a modest scale in the regular suite; the CI chaos job sets
// `CODEDML_CHAOS_AGGRESSIVE=1` to raise the kill counts and iteration
// counts, and `CHAOS_TRACE_DIR` to persist each run's trace as an upload
// artifact.

/// True when the CI chaos job asked for the aggressive profile.
fn aggressive() -> bool {
    std::env::var("CODEDML_CHAOS_AGGRESSIVE").map(|v| v != "0").unwrap_or(false)
}

/// Persist a chaos run's trace as newline-delimited JSON when
/// `CHAOS_TRACE_DIR` is set.
fn write_trace(name: &str, tracer: &codedml::coordinator::Tracer) {
    let Ok(dir) = std::env::var("CHAOS_TRACE_DIR") else { return };
    let path = std::path::Path::new(&dir);
    std::fs::create_dir_all(path).unwrap();
    let mut lines = String::new();
    for e in tracer.events().iter() {
        lines.push_str(&e.to_string());
        lines.push('\n');
    }
    std::fs::write(path.join(format!("chaos_{name}.jsonl")), lines).unwrap();
}

/// Memory transport under sustained loss beyond the slack: with no
/// respawn budget, every post-kill round must degrade to approximate
/// decode — training finishes, every degraded round emits a
/// `decode.approx` event with a finite residual, and the loss stays in a
/// sane band (approximate decode is a *liveness* mode: with T ≥ 1 the
/// lost evaluations are information-theoretically irrecoverable, so the
/// run honestly reports residuals instead of pretending accuracy).
#[test]
fn aggressive_chaos_memory_degrades_to_approx_and_survives() {
    let (iters, kills) = if aggressive() { (10usize, 6usize) } else { (5, 4) };
    let train = synthetic_3v7(120, 31);

    let mut clean = CodedMlSession::new(base_cfg(), &train).unwrap();
    let ref_loss = clean.train(iters, None).unwrap().final_loss().unwrap();

    // Slack is 3: killing `kills ≥ 4` leaves every post-kill round short.
    let mut cfg = base_cfg();
    cfg.chaos_failures = kills;
    cfg.chaos_from_iter = 2;
    cfg.approx_decode = true; // r_min auto: K+T = 4 ≤ 13 − kills
    let mut sess = CodedMlSession::new(cfg, &train).unwrap();
    sess.set_tracer(codedml::coordinator::Tracer::memory());
    let report = sess.train(iters, None).unwrap();

    assert!(report.worker_failures > 0);
    assert_eq!(report.approx_rounds, (iters - 2) as u64);
    assert!(report.max_approx_residual > 0.0 && report.max_approx_residual.is_finite());
    let approx_events: Vec<_> = sess
        .tracer()
        .events()
        .iter()
        .filter(|e| e.get("event").and_then(|v| v.as_str()) == Some("decode.approx"))
        .cloned()
        .collect();
    assert_eq!(approx_events.len() as u64, report.approx_rounds);
    for e in &approx_events {
        let residual = e.get("residual").unwrap().as_f64().unwrap();
        assert!(residual.is_finite() && residual >= 0.0, "residual {residual}");
        let r_prime = e.get("r_prime").unwrap().as_u64().unwrap();
        assert!(r_prime < 10, "degraded rounds decode from fewer than R results");
    }
    // The clip bound keeps every degraded update — and therefore the loss
    // — finite and near the fault-free run's scale, even though the
    // trajectory itself is not recoverable.
    let loss = report.final_loss().unwrap();
    assert!(
        loss.is_finite() && (loss - ref_loss).abs() < 10.0,
        "loss {loss} vs fault-free {ref_loss}"
    );
    write_trace("memory", sess.tracer());
}

/// TCP under sustained process loss beyond the slack: the supervisor
/// burns its respawn budget redialing addresses nothing listens on
/// (`worker.respawn` events with ok=false), then every short round
/// degrades to approximate decode — training finishes on the real wire
/// with zero live spare capacity.
#[test]
fn aggressive_chaos_tcp_degrades_when_redial_fails() {
    let (iters, kills) = if aggressive() { (8usize, 3usize) } else { (4, 2) };
    let train = synthetic_3v7(40, 37);
    let n = 5usize; // threshold 4 → slack 1 < kills

    let mut procs: Vec<WorkerProc> = (0..n).map(|_| spawn_worker()).collect();
    let addrs = procs.iter().map(|p| p.addr.clone()).collect();
    let mut cfg = tcp_cfg(n, addrs);
    cfg.approx_decode = true; // r_min auto: K+T = 2
    cfg.max_respawns = 1;
    cfg.transport.tcp.connect_timeout_ms = 300;
    cfg.transport.tcp.connect_retries = 1;
    cfg.transport.tcp.connect_backoff_ms = 10;
    let mut sess = CodedMlSession::new(cfg, &train).unwrap();
    sess.set_tracer(codedml::coordinator::Tracer::memory());

    sess.step().unwrap();
    for p in procs.iter_mut().take(kills) {
        let _ = p.child.kill();
        let _ = p.child.wait();
    }
    let report = sess.train(iters - 1, None).unwrap();

    assert!(report.worker_failures > 0);
    assert!(report.approx_rounds >= 1, "short rounds must degrade: {report:?}");
    assert_eq!(report.respawns, 0, "nothing listens on the dead ports");
    assert!(report.final_loss().unwrap().is_finite());
    let events = sess.tracer().events();
    let respawn_attempts: Vec<_> = events
        .iter()
        .filter(|e| e.get("event").and_then(|v| v.as_str()) == Some("worker.respawn"))
        .collect();
    assert!(
        !respawn_attempts.is_empty(),
        "supervision must have attempted a redial before degrading"
    );
    assert!(respawn_attempts
        .iter()
        .all(|e| e.get("ok").unwrap().as_bool() == Some(false)));
    assert!(events
        .iter()
        .any(|e| e.get("event").and_then(|v| v.as_str()) == Some("decode.approx")));
    write_trace("tcp", sess.tracer());
}
