//! Resilience: the recovery threshold is exactly the fault-tolerance
//! boundary. Killing up to `N − R` workers mid-training must not change
//! the *trajectory at all* (LCC decode is subset-invariant); killing one
//! more must fail loudly, not corrupt gradients.

use codedml::cluster::{NetworkModel, StragglerModel};
use codedml::coordinator::{CodedMlConfig, CodedMlSession};
use codedml::data::synthetic_3v7;

fn base_cfg() -> CodedMlConfig {
    CodedMlConfig {
        n: 13, // threshold 3·3+1 = 10 → slack 3
        k: 3,
        t: 1,
        net: NetworkModel::free(),
        straggler: StragglerModel::none(),
        ..Default::default()
    }
}

#[test]
fn surviving_within_slack_preserves_trajectory_exactly() {
    let train = synthetic_3v7(120, 17);

    let mut healthy = CodedMlSession::new(base_cfg(), &train).unwrap();
    let ref_report = healthy.train(6, None).unwrap();
    assert_eq!(ref_report.worker_failures, 0);

    // Kill 3 workers (exactly the slack) from iteration 2 on.
    let cfg = CodedMlConfig { chaos_failures: 3, chaos_from_iter: 2, ..base_cfg() };
    let mut wounded = CodedMlSession::new(cfg, &train).unwrap();
    wounded.set_tracer(codedml::coordinator::Tracer::memory());
    let report = wounded.train(6, None).unwrap();

    assert_eq!(
        ref_report.weights, report.weights,
        "trajectory must be identical with slack-many failures"
    );
    // Failures don't vanish: counted in the report (3 per iteration from
    // iteration 2 on) and emitted as structured tracer events. An Err
    // landing after its round completed is drained — and still counted —
    // by the next round, so only the final iteration's in-flight failures
    // can escape the tally.
    let fails = report.worker_failures;
    assert!((9..=12).contains(&fails), "worker_failures = {fails}");
    let failure_events: Vec<_> = wounded
        .tracer()
        .events()
        .iter()
        .filter(|e| e.get("event").unwrap().as_str() == Some("worker_failure"))
        .collect();
    assert_eq!(failure_events.len() as u64, fails);
    assert!(failure_events[0].get("worker").unwrap().as_u64().unwrap() < 3);
    assert_eq!(
        failure_events[0].get("error").unwrap().as_str(),
        Some("injected fault")
    );
}

#[test]
fn one_failure_beyond_slack_errors() {
    let train = synthetic_3v7(120, 18);
    let cfg = CodedMlConfig { chaos_failures: 4, chaos_from_iter: 1, ..base_cfg() };
    let mut sess = CodedMlSession::new(cfg, &train).unwrap();
    // First iteration fine; the second must report the shortage.
    assert!(sess.step().is_ok());
    let err = sess.step().unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("10"), "should mention the threshold: {msg}");
}

#[test]
fn failures_from_start_with_zero_slack_fail_immediately() {
    let train = synthetic_3v7(120, 19);
    let mut cfg = base_cfg();
    cfg.n = 10; // threshold 10 → zero slack
    cfg.chaos_failures = 1;
    cfg.chaos_from_iter = 0;
    let mut sess = CodedMlSession::new(cfg, &train).unwrap();
    assert!(sess.step().is_err());
}
